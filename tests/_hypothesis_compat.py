"""Graceful degradation when `hypothesis` is absent.

`hypothesis` is a declared dev extra (``pip install -e '.[dev]'``), but the
suite must still collect and run its non-property tests without it. Property
tests import through this shim:

    from _hypothesis_compat import HAVE_HYPOTHESIS, assume, given, settings, st

With hypothesis installed this re-exports the real API unchanged. Without
it, ``@given(...)`` turns each property test into an individually-skipped
test (reason: "hypothesis not installed") instead of breaking collection of
its whole module.
"""
import pytest

try:
    from hypothesis import assume, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    def assume(_condition):
        return True

    class _AnyStrategy:
        """Stand-in for hypothesis.strategies: every strategy constructor
        returns an inert placeholder (the tests are skipped anyway)."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()

__all__ = ["HAVE_HYPOTHESIS", "assume", "given", "settings", "st"]
