"""Algorithm-1 mapping + Eq.(6)-(10) cost model + §4.3 chain optimizations."""
import math

import jax
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import accelerators as acc
from repro.core import layers as L
from repro.core.chain import Chain
from repro.core.costmodel import (baseline_cost, gconv_chain_cost,
                                  lip_utilization, speedup)
from repro.core.fusion import fuse_chain
from repro.core.gconv import DimSpec, GConv
from repro.core.interpreter import ChainExecutor
from repro.core.mapping import (apply_loop_exchange, consistent_load_width, factors_by, map_gconv, tile_sizes)


def alexnet_conv1() -> GConv:
    """AlexNet conv1: 96 kernels 11x11x3, stride 4, input 227, batch 32."""
    chain = Chain("an_c1")
    x = chain.add_input("x", (32, 3, 227, 227))
    y = L.conv2d(chain, x, out_c=96, k=11, stride=4, bias=False)
    return chain.nodes[y]


SPECS = [acc.eyeriss(), acc.tpu_like(), acc.nlr(), acc.eager_pruning(),
         acc.dnnweaver()]


@pytest.mark.parametrize("spec", SPECS, ids=lambda s: s.name)
def test_mapping_covers_all_loops(spec):
    g = alexnet_conv1()
    m = map_gconv(g, spec)
    covered = factors_by(m.spatial + m.temporal)
    for d in g.dims:
        for p, n in (("g", d.ng), ("op", d.nop), ("opc", d.nopc),
                     ("ks", d.nks)):
            got = covered.get((p, d.name), 1)
            assert got >= n, f"{spec.name}: loop [{p},{d.name}]={n} uncovered"
            # ceil-division never over-covers by more than the rounding
            assert got < 2 * n + 1


@pytest.mark.parametrize("spec", SPECS, ids=lambda s: s.name)
def test_spatial_resources_respected(spec):
    g = alexnet_conv1()
    m = map_gconv(g, spec)
    per_dim = {}
    for e in m.spatial:
        per_dim[e.where] = per_dim.get(e.where, 1) * e.factor
    for name, used in per_dim.items():
        assert used <= spec.spatial_by_name(name).size


def test_eyeriss_overlap_primitive_allocated():
    """Overlap-reuse dims must receive the ks@py / opc@px primitives."""
    g = alexnet_conv1()
    m = map_gconv(g, acc.eyeriss())
    first_two = [(e.param, e.where) for e in m.spatial[:2]]
    assert ("ks", "py") in first_two
    assert ("opc", "px") in first_two
    # the W dimension got the temporal primitive: a sliding opc entry
    assert any(e.sliding for e in m.temporal)


def test_eq6_cycles_formula():
    g = alexnet_conv1()
    spec = acc.eyeriss()
    m = map_gconv(g, spec)
    sp = m.spatial_factors
    expect = 1
    for d in g.dims:
        for p, n in (("g", d.ng), ("op", d.nop), ("opc", d.nopc),
                     ("ks", d.nks)):
            expect *= math.ceil(n / sp.get((p, d.name), 1))
    assert m.cycles() == expect
    # sanity: cycles x PEs >= total MACs (array can't do more than 1/PE/cyc)
    assert m.cycles() * spec.n_pes >= g.macs


def test_ls_capacity_respected():
    g = alexnet_conv1()
    spec = acc.eyeriss()
    m = map_gconv(g, spec)
    for dtype in ("I", "K", "O"):
        ptr = m.pointer(dtype)
        inside = [t for t in m.temporal[: ptr + 1]
                  if not (t.sliding and dtype == "I")]
        assert tile_sizes(inside, g)[dtype] <= spec.ls[dtype]


def test_movement_lower_bounds():
    g = alexnet_conv1()
    m = map_gconv(g, acc.eyeriss())
    mov = m.movement()
    assert mov["O"] >= g.out_elems            # every output leaves the array
    assert mov["K"] >= g.k_elems / 4          # kernels fetched at least ~once
    assert mov["I"] >= g.in_elems / 4


@given(st.integers(1, 4), st.integers(1, 64), st.integers(1, 32),
       st.integers(1, 7), st.integers(1, 3))
@settings(max_examples=60, deadline=None)
def test_mapping_properties_random_gconv(ng, nop, nopc, nks, stride):
    """Property: any GCONV maps on any accelerator with full loop coverage
    and respected resources (paper's generality claim)."""
    g = GConv(name="r",
              dims=(DimSpec("A", ng=ng, nop=nop),
                    DimSpec("B", nopc=nopc, nks=nks, stride=stride)),
              input="x", kernel=None if False else "k",
              main="mul", reduce="add" if nks > 1 else "add")
    for spec in SPECS:
        m = map_gconv(g, spec)
        covered = factors_by(m.spatial + m.temporal)
        for d in g.dims:
            for p, n in (("g", d.ng), ("op", d.nop), ("opc", d.nopc),
                         ("ks", d.nks)):
                assert covered.get((p, d.name), 1) >= n
        per = {}
        for e in m.spatial:
            per[e.where] = per.get(e.where, 1) * e.factor
        for name, used in per.items():
            assert used <= spec.spatial_by_name(name).size
        assert m.cycles() * spec.n_pes >= g.macs


# ---------------------------------------------------------------------------
# §4.3 consistent mapping
# ---------------------------------------------------------------------------
def test_loop_exchange_improves_load_width():
    chain = Chain("c")
    x = chain.add_input("x", (4, 16, 28, 28))
    a = L.conv2d(chain, x, out_c=32, k=3, pad=1, bias=False)
    r = L.relu(chain, a)
    b = L.conv2d(chain, r, out_c=32, k=3, pad=1, bias=False)
    spec = acc.eyeriss()
    mp = map_gconv(chain.nodes[a], spec)
    mc = map_gconv(chain.nodes[b], spec)
    w_after = apply_loop_exchange(mp, mc)
    assert w_after >= consistent_load_width(mp, mc) or w_after >= 1
    # exchange must not change Eq.(6)/Eq.(10) results
    assert mc.cycles() == map_gconv(chain.nodes[b], spec).cycles()


# ---------------------------------------------------------------------------
# §4.3 operation fusion
# ---------------------------------------------------------------------------
def bn_relu_chain():
    chain = Chain("bn_relu")
    x = chain.add_input("x", (8, 4, 6, 6))
    c = L.conv2d(chain, x, out_c=4, k=3, pad=1, bias=False)
    y, fp = L.batch_norm_fp(chain, c)
    r = L.relu(chain, y)
    chain.mark_output(r)
    return chain, r


def test_fusion_shortens_chain_and_preserves_semantics():
    chain, out = bn_relu_chain()
    fused, report = fuse_chain(chain)
    assert report.after_len < report.before_len
    assert report.saved_elems > 0
    ex0, ex1 = ChainExecutor(chain), ChainExecutor(fused)
    params = ex0.init_params(jax.random.PRNGKey(0))
    xv = jax.random.normal(jax.random.PRNGKey(1), (8, 4, 6, 6))
    y0 = ex0({"x": xv}, params)[out]
    y1 = ex1({"x": xv}, {k: v for k, v in params.items()
                         if k in fused.params})[fused.outputs[0]]
    np.testing.assert_allclose(y0, y1, rtol=2e-5, atol=2e-5)


def test_fusion_never_fuses_reduce_gconvs():
    chain, _ = bn_relu_chain()
    fused, _ = fuse_chain(chain)
    # the conv and the two BN reductions (fp1, fp3) must survive
    kinds = [n.reduce for n in fused.gconv_nodes()]
    assert sum(1 for k in kinds if k == "add") >= 3


# ---------------------------------------------------------------------------
# end-to-end cost model behaviour (paper §6.3/§6.5 claims, in-model)
# ---------------------------------------------------------------------------
def small_mobilenet_block():
    """Figure 1(a): conv1x1 -> BN -> depthwise3x3 -> BN -> ReLU."""
    chain = Chain("mn_block")
    x = chain.add_input("x", (8, 32, 14, 14))
    c1 = L.conv2d(chain, x, out_c=64, k=1, bias=False)
    b1, _ = L.batch_norm_fp(chain, c1)
    r1 = L.relu(chain, b1)
    dw = L.conv2d(chain, r1, out_c=64, k=3, pad=1, groups=64, bias=False)
    b2, _ = L.batch_norm_fp(chain, dw)
    r2 = L.relu(chain, b2)
    chain.mark_output(r2)
    return chain


@pytest.mark.parametrize("spec", SPECS, ids=lambda s: s.name)
def test_gconv_speeds_up_heterogeneous_chain(spec):
    chain = small_mobilenet_block()
    s, base, gc = speedup(chain, spec)
    assert s >= 1.0, f"{spec.name}: GCONV Chain slower than baseline ({s:.2f})"


def test_cip_offload_dominates_baseline():
    chain = small_mobilenet_block()
    base = baseline_cost(chain, acc.eyeriss())
    assert base.offload_latency > 0
    gc = gconv_chain_cost(chain, acc.eyeriss())
    assert gc.offload_latency == 0


def test_tip_charges_im2col_replication():
    chain = Chain("conv_only")
    x = chain.add_input("x", (8, 16, 28, 28))
    L.conv2d(chain, x, out_c=16, k=3, pad=1, bias=False)
    tip = baseline_cost(chain, acc.tpu_like())
    gc = gconv_chain_cost(chain, acc.tpu_like())
    mov_tip = sum(n.movement.get("I", 0) for n in tip.nodes)
    mov_gc = sum(n.movement.get("I", 0) for n in gc.nodes)
    assert mov_tip > 2 * mov_gc       # 3x3 stride-1 im2col replicates ~9x


def test_lip_utilization_below_one_for_skewed_nets():
    chain = small_mobilenet_block()
    base = baseline_cost(chain, acc.dnnweaver())
    u = lip_utilization(base)
    assert 0.0 < u < 1.0
