"""Observability regressions: repro.obs trace/metrics/report + the
profiled compiled engine.

Pins the layer's three contracts: the export schema round-trips through
both formats and the report CLI; a disabled tracer costs nothing (no
per-call allocation beyond a flag check — tracemalloc-verified); and
``compile_chain(profile=True)`` attributes >= 95% of a profiled run's
wall time to named fusion-group steps with backend labels while leaving
the computed outputs bit-identical to the unprofiled engine."""
import json
import time
import tracemalloc

import numpy as np
import pytest

from repro.obs import Metrics, Tracer, exp_buckets, load_trace, percentile
from repro.obs import trace as trace_mod
from repro.obs.metrics import Histogram
from repro.obs.report import summarize


# ---------------------------------------------------------------------------
# tracer: nesting, ring buffer, export round-trip
# ---------------------------------------------------------------------------
def test_nested_span_parenting():
    tr = Tracer()
    with tr.span("outer", cat="t") as outer:
        with tr.span("inner", cat="t") as inner:
            with tr.span("leaf", cat="t") as leaf:
                pass
        with tr.span("inner2", cat="t") as inner2:
            pass
    by = {e["name"]: e for e in tr.events}
    assert by["outer"]["parent"] is None
    assert by["inner"]["parent"] == outer.id
    assert by["leaf"]["parent"] == inner.id
    assert by["inner2"]["parent"] == outer.id
    assert inner2.id != inner.id
    # children are contained in the parent's [ts, ts+dur] window
    for child in ("inner", "inner2"):
        assert by[child]["ts"] >= by["outer"]["ts"]
        assert (by[child]["ts"] + by[child]["dur"]
                <= by["outer"]["ts"] + by["outer"]["dur"] + 1e-6)


def test_add_span_explicit_endpoints_and_parenting():
    tr = Tracer()
    t0 = time.perf_counter()
    t1 = t0 + 0.25
    pid = tr.add_span("request", "request", t0, t1, attrs={"rid": 7})
    cid = tr.add_span("queue", "request", t0, t0 + 0.1, parent=pid)
    assert pid is not None and cid == pid + 1
    spans = [e for e in tr.events if e["type"] == "span"]
    req = next(s for s in spans if s["name"] == "request")
    assert req["dur"] == pytest.approx(0.25e6, rel=1e-6)
    assert next(s for s in spans
                if s["name"] == "queue")["parent"] == pid
    # out-of-order endpoints clamp to zero duration, never negative
    assert tr.add_span("x", "t", t1, t0) is not None
    assert [e for e in tr.events if e["name"] == "x"][0]["dur"] == 0.0


def test_ring_buffer_keeps_most_recent_events():
    tr = Tracer(capacity=10)
    for i in range(25):
        tr.instant(f"e{i}")
    assert len(tr.events) == 10
    assert [e["name"] for e in tr.events] == [f"e{i}" for i in range(15, 25)]


@pytest.mark.parametrize("suffix", [".json", ".jsonl"])
def test_export_round_trip_both_formats(tmp_path, suffix):
    tr = Tracer()
    tr.meta["kind"] = "test"
    tr.meta["slots"] = 2
    with tr.span("work", cat="chain", attrs={"signature": "sig0"}):
        with tr.span("step0", cat="execute", attrs={"backend": "pallas"}):
            pass
    tr.instant("marker", cat="serve", attrs={"tick": 3})
    tr.counter("slots", {"active": 2, "queued": 1})
    path = tmp_path / f"trace{suffix}"
    tr.write(str(path))
    got = load_trace(str(path))
    assert got.version == trace_mod.SCHEMA_VERSION
    assert got.meta == {"kind": "test", "slots": 2}
    assert [s["name"] for s in got.spans] == ["step0", "work"]
    step, work = got.spans
    assert step["parent"] == work["id"]
    assert step["args"]["backend"] == "pallas"
    assert got.instants[0]["args"] == {"tick": 3}
    assert got.counters[0]["values"] == {"active": 2, "queued": 1}


def test_chrome_export_is_perfetto_shaped(tmp_path):
    """The .json flavor is literal Chrome trace-event JSON: ph X/i/C
    events under traceEvents plus the schema header in otherData."""
    tr = Tracer()
    with tr.span("s"):
        pass
    tr.counter("c", {"v": 1})
    path = tmp_path / "t.json"
    tr.write(str(path))
    doc = json.loads(path.read_text())
    phs = sorted(e["ph"] for e in doc["traceEvents"])
    assert phs == ["C", "X"]
    x = next(e for e in doc["traceEvents"] if e["ph"] == "X")
    assert {"name", "cat", "ts", "dur", "pid", "tid"} <= set(x)
    assert doc["otherData"]["schema"] == trace_mod.SCHEMA
    assert doc["otherData"]["version"] == trace_mod.SCHEMA_VERSION


def test_load_trace_rejects_wrong_schema_and_version(tmp_path):
    bad = tmp_path / "bad.jsonl"
    bad.write_text(json.dumps({"schema": "other", "version": 1}) + "\n")
    with pytest.raises(ValueError, match="schema"):
        load_trace(str(bad))
    bad.write_text(json.dumps(
        {"schema": trace_mod.SCHEMA, "version": 99}) + "\n")
    with pytest.raises(ValueError, match="version"):
        load_trace(str(bad))
    bad.write_text(json.dumps(
        {"schema": trace_mod.SCHEMA,
         "version": trace_mod.SCHEMA_VERSION}) + "\n"
        + json.dumps({"type": "span", "name": "x"}) + "\n")
    with pytest.raises(ValueError, match="missing fields"):
        load_trace(str(bad))


# ---------------------------------------------------------------------------
# disabled tracer: provably free
# ---------------------------------------------------------------------------
def test_disabled_tracer_records_nothing():
    tr = Tracer(enabled=False)
    with tr.span("s", cat="t", attrs=None):
        pass
    tr.instant("i")
    tr.counter("c", {"v": 1})
    assert tr.add_span("a", "t", 0.0, 1.0) is None
    assert not tr.events


def test_disabled_span_allocates_nothing():
    """span() on a disabled tracer is a flag check returning a module
    singleton — zero allocations attributable to trace.py per call."""
    tr = Tracer(enabled=False)
    for _ in range(16):                    # warm any lazy interpreter state
        with tr.span("warm"):
            pass
    tracemalloc.start()
    try:
        snap0 = tracemalloc.take_snapshot()
        for _ in range(1000):
            with tr.span("hot"):
                pass
        snap1 = tracemalloc.take_snapshot()
    finally:
        tracemalloc.stop()
    flt = (tracemalloc.Filter(True, trace_mod.__file__),)
    stats = snap1.filter_traces(flt).compare_to(
        snap0.filter_traces(flt), "lineno")
    # per-call allocation over 1000 calls would show count_diff ~ 1000
    # (a _Span or attrs dict each time); a couple of live one-off
    # interpreter-state blocks are fine
    grown = [s for s in stats if s.size_diff > 0]
    assert sum(s.count_diff for s in grown) < 10, [str(s) for s in grown]
    assert sum(s.size_diff for s in grown) < 1024, [str(s) for s in grown]


# ---------------------------------------------------------------------------
# metrics: percentile, histogram buckets, registry schema
# ---------------------------------------------------------------------------
def test_percentile_degenerate_and_numpy_agreement():
    assert percentile([], 50) == 0.0
    assert percentile([], 99) == 0.0
    assert percentile([3.25], 50) == 3.25
    assert percentile([3.25], 99) == 3.25
    rng = np.random.default_rng(0)
    xs = rng.exponential(size=37).tolist()
    for q in (0, 25, 50, 90, 99, 100):
        assert percentile(xs, q) == pytest.approx(
            float(np.percentile(xs, q)), abs=1e-12)


def test_histogram_bucket_boundaries():
    h = Histogram([1.0, 2.0, 4.0])
    for v in (0.0, 1.0):                  # le convention: bound inclusive
        h.observe(v)
    h.observe(1.5)
    h.observe(2.0)
    h.observe(4.0)
    h.observe(4.0001)                     # overflow bucket
    assert h.counts == [2, 2, 1, 1]
    assert h.count == 6
    assert h.sum == pytest.approx(12.5001)
    assert h.mean == pytest.approx(12.5001 / 6)
    with pytest.raises(ValueError, match="strictly increasing"):
        Histogram([1.0, 1.0, 2.0])
    bs = exp_buckets(1e-3, 1.0, 4)
    assert bs[0] == pytest.approx(1e-3) and bs[-1] == pytest.approx(1.0)
    assert len(bs) == 4


def test_metrics_schema_round_trip_snapshot_merge_diff():
    reg = Metrics()
    reg.counter("reqs", kind="a").inc(3)
    reg.gauge("active").set(2.5)
    reg.histogram("lat", [0.1, 1.0], kind="a").observe(0.05)
    d = reg.to_dict()
    assert d["schema"] == "repro.obs.metrics" and d["version"] == 1
    back = Metrics.from_dict(json.loads(json.dumps(d)))
    assert back.to_dict() == d

    snap = reg.snapshot()
    reg.counter("reqs", kind="a").inc(2)
    reg.histogram("lat", kind="a").observe(0.5)
    delta = reg.diff(snap)
    assert delta.value("reqs", kind="a") == 2.0
    (s,) = delta.to_dict()["metrics"]["lat"]["series"]
    assert s["count"] == 1 and s["counts"] == [0, 1, 0]

    merged = Metrics().merge(snap).merge(delta)
    assert merged.to_dict() == reg.to_dict()

    with pytest.raises(ValueError, match="counter"):
        reg.gauge("reqs")                 # family type is sticky
    with pytest.raises(ValueError, match="declare buckets"):
        Metrics().histogram("fresh")


# ---------------------------------------------------------------------------
# report: synthetic trace
# ---------------------------------------------------------------------------
def _synthetic_serve_trace():
    tr = Tracer()
    tr.meta.update(kind="serve", slots=2)
    base = time.perf_counter()
    for rid, (qw, ttft, lat) in enumerate(
            [(0.1, 0.2, 1.0), (0.0, 0.1, 0.5), (0.3, 0.5, 2.0)]):
        t0 = base + rid
        pid = tr.add_span("request", "request", t0, t0 + lat,
                          attrs={"rid": rid, "out_len": 4,
                                 "queue_wait_s": qw, "ttft_s": ttft,
                                 "latency_s": lat})
        tr.add_span("queue", "request", t0, t0 + qw, parent=pid)
        tr.add_span("prefill", "request", t0 + qw, t0 + ttft, parent=pid)
        tr.add_span("decode", "request", t0 + ttft, t0 + lat, parent=pid)
    for active in (1, 2, 1, 0):
        tr.counter("slots", {"active": active, "queued": 0})
    return tr


def test_report_summarize_synthetic_serve_trace():
    tr = _synthetic_serve_trace()
    out = summarize(trace_mod.Trace(dict(tr.meta), list(tr.events),
                                    trace_mod.SCHEMA_VERSION))
    assert out["requests"] == 3
    assert out["p50_ttft_s"] == percentile([0.2, 0.1, 0.5], 50)
    assert out["p99_latency_s"] == percentile([1.0, 0.5, 2.0], 99)
    assert out["tokens_out"] == 12
    assert out["slot_utilization"] == pytest.approx(1.0 / 2, abs=1e-4)
    assert set(out["phases"]) == {"queue", "prefill", "decode"}
    assert out["phases"]["decode"]["count"] == 3
    # request spans have children, so self-time ranks the phases on top
    assert out["top_spans"][0]["name"] != "request" or \
        out["top_spans"][0]["self_us"] < out["top_spans"][0]["total_us"]


def test_report_cli_exit_codes(tmp_path):
    from repro.obs.report import main
    tr = _synthetic_serve_trace()
    path = tmp_path / "t.json"
    tr.write(str(path))
    assert main([str(path)]) == 0
    bad = tmp_path / "bad.json"
    bad.write_text("{}")
    assert main([str(bad)]) == 1
    assert main([str(tmp_path / "missing.json")]) == 1


def test_report_cli_text_format(tmp_path, capsys):
    from repro.obs.report import main
    tr = _synthetic_serve_trace()
    path = tmp_path / "t.json"
    tr.write(str(path))
    assert main([str(path), "--format", "json"]) == 0
    out = json.loads(capsys.readouterr().out)     # default stays machine-readable
    assert out["requests"] == 3
    assert main([str(path), "--format", "text"]) == 0
    text = capsys.readouterr().out
    assert "requests" in text and "p50" in text
    with pytest.raises(SystemExit):
        main([str(path), "--format", "yaml"])


# ---------------------------------------------------------------------------
# serve-schema iterators (shared by report + syssim replay)
# ---------------------------------------------------------------------------
def _ticked_serve_trace():
    """Synthetic trace carrying the full tick-stamped lifecycle schema."""
    tr = Tracer()
    tr.meta.update(kind="serve", slots=2)
    base = time.perf_counter()
    lifecycle = [  # rid, submit, admit, done, prompt, out
        (1, 0, 0, 4, 8, 4),
        (0, 0, 1, 3, 6, 2),
        (2, 2, 2, 2, 4, 3),   # done == admit -> service_ticks floors at 1
    ]
    for rid, sub, adm, done, plen, out in lifecycle:
        t0 = base + rid
        pid = tr.add_span("request", "request", t0, t0 + 1.0,
                          attrs={"rid": rid, "prompt_len": plen,
                                 "out_len": out, "max_new": 8,
                                 "submit_tick": sub, "admit_tick": adm,
                                 "done_tick": done, "ttft_s": 0.1,
                                 "latency_s": 1.0, "queue_wait_s": 0.05})
        tr.add_span("queue", "request", t0, t0 + 0.25, parent=pid)
        tr.add_span("decode", "request", t0 + 0.25, t0 + 1.0, parent=pid)
    for i, (active, queued) in enumerate([(1, 2), (2, 1), (2, 0), (1, 0)]):
        tr.counter("slots", {"active": active, "queued": queued, "tick": i})
    return trace_mod.Trace(dict(tr.meta), list(tr.events),
                           trace_mod.SCHEMA_VERSION)


def test_serve_requests_iterator_schema_and_order():
    reqs = _ticked_serve_trace().serve_requests()
    assert [r.rid for r in reqs] == [0, 1, 2]   # (submit_tick, rid) order
    r0 = reqs[0]
    assert r0.submit_tick == 0 and r0.admit_tick == 1 and r0.done_tick == 3
    assert r0.tokens == 6 + 2                   # prompt + recorded out_len
    assert r0.service_ticks == 2
    assert r0.phases["queue"] == pytest.approx(0.25, rel=1e-6)
    assert r0.phases["decode"] == pytest.approx(0.75, rel=1e-6)
    assert reqs[2].service_ticks == 1           # floored, never zero
    # out_len falls back to the max_new budget when not recorded
    partial = trace_mod.ServeRequest(
        rid=9, prompt_len=4, max_new=8, out_len=None, submit_tick=None,
        admit_tick=None, done_tick=None, queue_wait_s=None, ttft_s=None,
        latency_s=None)
    assert partial.tokens == 12 and partial.service_ticks is None


def test_serve_ticks_iterator():
    ticks = _ticked_serve_trace().serve_ticks()
    assert [t.index for t in ticks] == [0, 1, 2, 3]
    assert [t.active for t in ticks] == [1, 2, 2, 1]
    assert [t.queued for t in ticks] == [2, 1, 0, 0]
    # pre-tick-stamp traces fall back to sample order
    legacy = _synthetic_serve_trace()
    lt = trace_mod.Trace(dict(legacy.meta), list(legacy.events),
                         trace_mod.SCHEMA_VERSION).serve_ticks()
    assert [t.index for t in lt] == [0, 1, 2, 3]
    assert [t.active for t in lt] == [1, 2, 1, 0]


def test_recorded_server_trace_round_trips_iterators(tmp_path):
    """A real Server run carries the tick-stamped schema end to end."""
    from benchmarks.serve_bench import _workload
    from repro.launch.serve import Server

    tr = Tracer()
    srv = Server("tinyllama-1.1b", smoke=True, slots=2, max_len=64,
                 tracer=tr)
    srv.run_workload(_workload(3, srv.cfg.vocab, max_new=3),
                     stagger_ticks=1)
    path = tmp_path / "serve.json"
    tr.write(str(path))
    trace = load_trace(str(path))
    reqs = trace.serve_requests()
    assert len(reqs) == 3
    for r in reqs:
        assert r.submit_tick is not None and r.done_tick is not None
        assert r.service_ticks >= 1 and r.tokens > 0
    ticks = trace.serve_ticks()
    assert ticks and [t.index for t in ticks] == list(range(len(ticks)))
    assert max(t.active for t in ticks) <= 2


# ---------------------------------------------------------------------------
# profiled compiled engine
# ---------------------------------------------------------------------------
def _mn_case():
    import jax

    from repro.core.interpreter import init_chain_params
    from repro.models import cnn

    chain = cnn.build("MN", reduced=True, batch=1)
    params = init_chain_params(chain, jax.random.PRNGKey(0))
    return chain, cnn.random_inputs(chain), params


@pytest.mark.slow
def test_profile_mode_coverage_and_attribution():
    import jax

    from repro.exec import compile_chain

    chain, inputs, params = _mn_case()
    plain = compile_chain(chain)
    eng = compile_chain(chain, profile=True)
    assert eng.tracer is not None and eng.tracer.enabled

    first = eng(inputs, params)            # cold: every step compiles
    spans = [e for e in eng.tracer.events if e["type"] == "span"]
    assert {s["cat"] for s in spans if s["name"].startswith("chain:")} \
        == {"chain"}
    step_spans = [s for s in spans if s["cat"] in ("compile", "execute")]
    assert {s["cat"] for s in step_spans} == {"compile"}

    got = eng(inputs, params)              # warm: steady-state execution
    for o in got:
        np.testing.assert_allclose(
            np.asarray(got[o], np.float32),
            np.asarray(jax.block_until_ready(plain(inputs, params))[o],
                       np.float32), rtol=1e-4, atol=1e-5)

    spans = [e for e in eng.tracer.events if e["type"] == "span"]
    chains = [s for s in spans if s["cat"] == "chain"]
    last = chains[-1]
    steps = [s for s in spans if s["parent"] == last["id"]]
    assert steps and all(s["cat"] == "execute" for s in steps)
    assert all(s["args"].get("backend") for s in steps)
    assert all(s["args"]["signature"] == eng._plan.signature for s in steps)
    coverage = sum(s["dur"] for s in steps) / last["dur"]
    assert coverage >= 0.95, f"profile coverage {coverage:.3f} < 0.95"


def test_profile_disabled_is_default_and_matches():
    from repro.exec import compile_chain

    chain, inputs, params = _mn_case()
    eng = compile_chain(chain)
    assert eng.tracer is None and not eng.options.profile
    off = compile_chain(chain, profile=True, tracer=Tracer(enabled=False))
    got, ref = off(inputs, params), eng(inputs, params)
    for o in ref:
        np.testing.assert_allclose(np.asarray(got[o], np.float32),
                                   np.asarray(ref[o], np.float32),
                                   rtol=1e-4, atol=1e-5)
    assert not off.tracer.events
