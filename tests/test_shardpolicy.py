"""Direct unit tests for the shared divisibility-guard sharding policy
(repro.shardpolicy) — every fallback case the launch/sharding.py strategy
docstring names, tested against the policy primitives themselves rather
than indirectly through the model sharder."""
import pytest
from jax.sharding import PartitionSpec as P

from repro import shardpolicy as policy


class FakeMesh:
    """Just enough mesh surface for the policy (shape map + axis names)."""

    def __init__(self, **axes):
        self.shape = dict(axes)
        self.axis_names = tuple(axes)
        self.empty = False


POD = FakeMesh(pod=2, data=16, model=16)
MESH = FakeMesh(data=16, model=16)


def test_axis_size_none_single_and_bundle():
    assert policy.axis_size(MESH, None) == 1
    assert policy.axis_size(MESH, "model") == 16
    assert policy.axis_size(POD, ("pod", "data")) == 32


def test_divides():
    assert policy.divides(MESH, "model", 32000)
    assert not policy.divides(MESH, "model", 32001)
    assert policy.divides(MESH, None, 7)        # replication divides all


# ---------------------------------------------------------------------------
# guard: the vocab=32001 fallback (hymba's embedding on a 16-way axis)
# ---------------------------------------------------------------------------
def test_guard_drops_vocab_32001():
    assert policy.guard(MESH, ("model", "data"), (32001, 2048)) == \
        P(None, "data")
    assert policy.guard(MESH, ("model", "data"), (32000, 2048)) == \
        P("model", "data")


def test_guard_pads_short_specs_with_replication():
    # spec shorter than rank: trailing dims replicate
    assert policy.guard(MESH, ("data",), (32, 4, 4)) == P("data", None, None)
    assert policy.guard(MESH, (), (8, 8)) == P(None, None)


def test_guard_axis_bundles():
    # ("pod","data") = 32-way: 64 divides, 48 does not
    assert policy.guard(POD, (("pod", "data"),), (64,)) == P(("pod", "data"))
    assert policy.guard(POD, (("pod", "data"),), (48,)) == P(None)


# ---------------------------------------------------------------------------
# takeover: KV heads vs the model axis, head_dim takes the sharding
# ---------------------------------------------------------------------------
def test_takeover_prefers_heads_when_divisible():
    # (L, B, S, Hkv, hd) with 32 KV heads: heads win
    shape = (4, 8, 128, 32, 128)
    assert policy.takeover(MESH, "model", shape, (3, 4)) == 3


def test_takeover_head_dim_when_heads_dont_divide():
    # yi's 8 KV heads vs model=16 : the head_dim axis takes the sharding
    shape = (4, 8, 128, 8, 128)
    assert policy.takeover(MESH, "model", shape, (3, 4)) == 4


def test_takeover_none_when_nothing_divides():
    shape = (4, 8, 128, 8, 100)
    assert policy.takeover(MESH, "model", shape, (3, 4)) is None


# ---------------------------------------------------------------------------
# dp_axes / leading_batch_spec
# ---------------------------------------------------------------------------
def test_parse_mesh_spec():
    assert policy.parse_mesh_spec("8") == (8, 1)
    assert policy.parse_mesh_spec("4x2") == (4, 2)
    assert policy.parse_mesh_spec("4X2") == (4, 2)
    with pytest.raises(ValueError):
        policy.parse_mesh_spec("2x2x2")


def test_dp_axes_bundles():
    assert policy.dp_axes(POD) == ("pod", "data")
    assert policy.dp_axes(MESH) == ("data",)
    assert policy.dp_axes(FakeMesh(x=4, y=2)) == ("x",)


def test_leading_batch_spec_guards():
    assert policy.leading_batch_spec(MESH, (32, 8, 8)) == \
        P(("data",), None, None)
    assert policy.leading_batch_spec(MESH, (3, 8, 8)) == P(None, None, None)
    assert policy.leading_batch_spec(MESH, ()) == P()


# ---------------------------------------------------------------------------
# the launch sharder consumes THIS module (no duplicated policy)
# ---------------------------------------------------------------------------
def test_launch_sharding_reuses_policy():
    from repro.launch import sharding as shlib

    assert shlib.guard is policy.guard
    assert shlib.takeover is policy.takeover
    assert shlib._axis_size is policy.axis_size


def test_exec_shardplan_reuses_policy():
    from repro.exec import shardplan

    assert shardplan.policy is policy
