"""Substrate tests: optimizer, data pipeline, checkpointing, fault-tolerant
runtime (restart/replay, straggler flags, corruption recovery)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, Prefetcher, batches, host_slice
from repro.optim import adamw
from repro.optim.compress import dequantize, quantize
from repro.runtime.fault_tolerance import FaultTolerantLoop, StragglerMonitor


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------
def test_adamw_reduces_quadratic_loss():
    cfg = adamw.OptConfig(peak_lr=0.1, warmup_steps=5, total_steps=200,
                          weight_decay=0.0, clip_norm=10.0)
    params = {"w": jnp.ones((8, 8)), "b": jnp.zeros((8,))}
    target = {"w": 0.3 * jnp.ones((8, 8)), "b": 0.5 * jnp.ones((8,))}
    state = adamw.init_state(cfg, params)

    def loss(p):
        return sum(jnp.sum((p[k] - target[k]) ** 2) for k in p)

    l0 = float(loss(params))
    for _ in range(100):
        g = jax.grad(loss)(params)
        params, state, stats = adamw.update(cfg, params, g, state)
    assert float(loss(params)) < 0.05 * l0
    assert int(state["step"]) == 100


def test_adamw_bf16_moments_and_schedule():
    cfg = adamw.OptConfig(moment_dtype="bfloat16", warmup_steps=10,
                          total_steps=100, peak_lr=1e-3)
    params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    state = adamw.init_state(cfg, params)
    assert state["m"]["w"].dtype == jnp.bfloat16
    # warmup is linear
    assert float(adamw.schedule(cfg, jnp.asarray(5))) == pytest.approx(
        0.5e-3, rel=1e-5)
    # cosine tail ends at min_lr_ratio * peak
    assert float(adamw.schedule(cfg, jnp.asarray(100))) == pytest.approx(
        cfg.min_lr_ratio * cfg.peak_lr, rel=1e-4)


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=30, deadline=None)
def test_int8_compression_error_feedback_bounded(seed):
    """Property: with error feedback, the *cumulative* quantization error
    stays bounded by one quantization step (it never accumulates)."""
    g = np.asarray(jax.random.normal(jax.random.PRNGKey(seed), (64,)))
    err = jnp.zeros((64,))
    total_true = jnp.zeros((64,))
    total_sent = jnp.zeros((64,))
    for t in range(5):
        q, scale, err = quantize(jnp.asarray(g) * (t + 1), err)
        total_true = total_true + jnp.asarray(g) * (t + 1)
        total_sent = total_sent + dequantize(q, scale)
    resid = np.abs(np.asarray(total_true - total_sent))
    step = float(scale)
    assert resid.max() <= step * 1.01 + 1e-6


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_pipeline_determinism_and_host_sharding():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=8)
    full = [next(batches(cfg, start_step=s)) for s in range(3)]
    # restart at step 2 reproduces batch 2 exactly
    again = next(batches(cfg, start_step=2))
    np.testing.assert_array_equal(full[2]["tokens"], again["tokens"])
    # two "hosts" see disjoint row slices that concatenate to the global
    h0 = next(batches(cfg, start_step=1, process_index=0, process_count=2))
    h1 = next(batches(cfg, start_step=1, process_index=1, process_count=2))
    np.testing.assert_array_equal(
        np.concatenate([h0["tokens"], h1["tokens"]]), full[1]["tokens"])
    assert host_slice(8, 1, 2) == (4, 8)


def test_pipeline_labels_are_shifted_tokens():
    cfg = DataConfig(vocab=50, seq_len=8, global_batch=2)
    b = next(batches(cfg))
    assert b["tokens"].shape == (2, 8)
    assert b["labels"].shape == (2, 8)


def test_prefetcher():
    cfg = DataConfig(vocab=10, seq_len=4, global_batch=2)
    pf = Prefetcher(batches(cfg), depth=2)
    b0 = next(pf)
    b1 = next(pf)
    assert b0["step"] == 0 and b1["step"] == 1
    pf.close()


def test_file_backed_reader(tmp_path):
    path = tmp_path / "tokens.bin"
    arr = np.arange(10000, dtype=np.uint16) % 97
    arr.tofile(path)
    cfg = DataConfig(vocab=97, seq_len=16, global_batch=4, path=str(path),
                     dtype="int32")
    b = next(batches(cfg))
    assert b["tokens"].shape == (4, 16)
    assert b["tokens"].max() < 97
    # window contents come from the file (consecutive values mod 97)
    row = b["tokens"][0]
    diffs = np.diff(row.astype(int)) % 97
    assert np.all(diffs == 1)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------
def _tree():
    return {"params": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4)},
            "opt": {"m": jnp.ones((3, 4)), "step": jnp.asarray(7)}}


def test_checkpoint_roundtrip_and_rotation(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=2, async_write=False)
    tree = _tree()
    for s in (10, 20, 30):
        mgr.save(s, tree)
    assert mgr.all_steps() == [20, 30]          # rotation keeps last 2
    step, restored = mgr.restore(jax.tree.map(jnp.zeros_like, tree))
    assert step == 30
    np.testing.assert_array_equal(restored["params"]["w"],
                                  tree["params"]["w"])


def test_checkpoint_corruption_falls_back(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=3, async_write=False)
    tree = _tree()
    mgr.save(1, tree)
    mgr.save(2, tree)
    # corrupt the newest checkpoint
    victim = os.path.join(str(tmp_path), "step_2", "params__w.npy")
    size = os.path.getsize(victim)
    with open(victim, "r+b") as f:
        f.seek(size - 8)                 # inside the payload region
        f.write(b"\xff\xff\xff\xff")
    step, restored = mgr.restore(jax.tree.map(jnp.zeros_like, tree))
    assert step == 1                             # fell back past corruption
    np.testing.assert_array_equal(restored["params"]["w"],
                                  tree["params"]["w"])


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=True)
    mgr.save(5, _tree())
    mgr.wait()
    assert mgr.latest_step() == 5


# ---------------------------------------------------------------------------
# fault-tolerant loop
# ---------------------------------------------------------------------------
def test_restart_replay_recovers_and_is_deterministic(tmp_path):
    """Inject a failure mid-run; the loop must restore and converge to the
    same final state as a clean run."""
    def make_step():
        def step_fn(state, step):
            return {"x": state["x"] + step, "step": jnp.asarray(step + 1)}
        return step_fn

    clean_mgr = CheckpointManager(str(tmp_path / "clean"), async_write=False)
    loop = FaultTolerantLoop(clean_mgr, ckpt_every=3, max_restarts=3)
    clean = loop.run({"x": jnp.zeros(()), "step": jnp.asarray(0)},
                     make_step(), n_steps=10)

    boom = {"armed": True}

    def fault(step):
        if step == 7 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("injected node failure")

    f_mgr = CheckpointManager(str(tmp_path / "faulty"), async_write=False)
    floop = FaultTolerantLoop(f_mgr, ckpt_every=3, max_restarts=3,
                              fault_hook=fault)
    faulty = floop.run({"x": jnp.zeros(()), "step": jnp.asarray(0)},
                       make_step(), n_steps=10)
    assert faulty["restarts"] == 1
    assert faulty["final_step"] == clean["final_step"] == 10
    _, s_clean = clean_mgr.restore({"x": jnp.zeros(()),
                                    "step": jnp.asarray(0)})
    _, s_faulty = f_mgr.restore({"x": jnp.zeros(()), "step": jnp.asarray(0)})
    np.testing.assert_allclose(s_clean["x"], s_faulty["x"])


def test_repeated_failure_escalates(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=False)

    def always_fail(step):
        raise RuntimeError("dead node")

    loop = FaultTolerantLoop(mgr, ckpt_every=5, max_restarts=2,
                             fault_hook=always_fail)
    with pytest.raises(RuntimeError):
        loop.run({"x": jnp.zeros(())}, lambda s, i: s, n_steps=3)


def test_straggler_monitor():
    m = StragglerMonitor(threshold=2.0)
    assert not m.observe(1.0)
    assert not m.observe(1.1)
    assert m.observe(5.0)
    assert m.flagged == 1
