"""The seven paper CNNs: reduced-config execution smoke tests + full-size
chain statistics sanity (Table 1 directional checks) + simulator runs.

Execution smoke tests run through the compiled engine (repro.exec) — the
hot path. The oracle interpreter stays the allclose reference at analysis
scale in tests/test_exec.py."""
import jax
import numpy as np
import pytest

from repro.core import accelerators as acc
from repro.core.costmodel import speedup
from repro.core.fusion import fuse_chain
from repro.exec import compile_chain
from repro.models import cnn


@pytest.mark.parametrize("name", list(cnn.ZOO))
@pytest.mark.slow
def test_reduced_chain_executes(name):
    chain = cnn.build(name, reduced=True, batch=2)
    eng = compile_chain(chain)
    params = eng.init_params(jax.random.PRNGKey(0))
    outs = eng(cnn.random_inputs(chain), params)
    for o, v in outs.items():
        assert np.all(np.isfinite(np.asarray(v))), f"{name}:{o} not finite"


def test_full_chains_build_with_expected_heterogeneity():
    stats = {n: cnn.build(n).stats() for n in cnn.ZOO}
    # Table 1 directional checks
    assert stats["C3D"]["nontraditional_macs"] / stats["C3D"]["macs"] > 0.9
    assert stats["CapNN"]["nontraditional_macs"] / stats["CapNN"]["macs"] > 0.9
    for n in ("AN", "GLN", "ZFFR"):
        assert stats[n]["nontraditional_macs"] / stats[n]["macs"] < 0.05
    for n in ("DN", "MN"):
        r = stats[n]["nontraditional_elems"] / stats[n]["intermediate_elems"]
        assert r > 0.5, f"{n}: non-traditional data footprint only {r:.2f}"


def test_alexnet_conv1_macs():
    chain = cnn.build("AN")
    conv1 = chain.nodes["conv1"]
    # 32 x 96 x 55 x 55 x 11 x 11 x 3
    assert conv1.macs == 32 * 96 * 55 * 55 * 11 * 11 * 3


@pytest.mark.parametrize("name", ["AN", "MN"])
def test_fusion_on_real_networks(name):
    chain = cnn.build(name)
    fused, rep = fuse_chain(chain)
    # paper reports up to 30% chain-length reduction; our pass fuses
    # consumer-side duplicates too, reaching ~55% on MN
    assert 0.05 < rep.length_reduction <= 0.7


@pytest.mark.slow
def test_training_block_chain_executes():
    chain = cnn.training_block_chain(batch=4, ch=8, hw=8)
    eng = compile_chain(chain)
    params = eng.init_params(jax.random.PRNGKey(0))
    xv = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 8, 8))
    gv = jax.random.normal(jax.random.PRNGKey(2), (4, 8, 8, 8))
    outs = eng({"x": xv, "gO": gv}, params, keep_all=True)
    # conv BP input-gradient must match autodiff through conv+BN+ReLU
    import jax.numpy as jnp

    w = params["conv.w"].reshape(8, 8, 3, 3)

    def f(x):
        y = jax.lax.conv_general_dilated(x, w, (1, 1), [(1, 1), (1, 1)])
        mu = y.mean(axis=0, keepdims=True)
        var = ((y - mu) ** 2).mean(axis=0, keepdims=True)
        o = (y - mu) / jnp.sqrt(var + 1e-5)
        return jnp.maximum(o, 0)

    _, vjp = jax.vjp(f, xv)
    ref_gi = vjp(gv)[0]
    np.testing.assert_allclose(outs["conv_bp.gi"], ref_gi,
                               rtol=5e-3, atol=1e-4)

    def fw(w_):
        y = jax.lax.conv_general_dilated(
            xv, w_, (1, 1), [(1, 1), (1, 1)])
        mu = y.mean(axis=0, keepdims=True)
        var = ((y - mu) ** 2).mean(axis=0, keepdims=True)
        o = (y - mu) / jnp.sqrt(var + 1e-5)
        return jnp.maximum(o, 0)

    _, vjpw = jax.vjp(fw, w)
    ref_gw = vjpw(gv)[0]                       # (oc, ic, kh, kw)
    got_gw = np.asarray(outs["conv_bp.gw"])[0].transpose(1, 0, 2, 3)
    np.testing.assert_allclose(got_gw, ref_gw, rtol=5e-3, atol=1e-4)


def test_speedup_simulation_small_subset():
    """Fig. 13/14-style run at analysis scale: GCONV Chain never slower."""
    chain = cnn.build("MN")
    for spec in (acc.eyeriss(), acc.tpu_like()):
        s, _, _ = speedup(chain, spec)
        assert s >= 1.0
