"""Tests for the whole-life-cost design-space explorer (repro.dse) and its
core hooks (Mapping.from_entries / chain_mappings overrides)."""
import json
import os
import random
import subprocess
import sys

import pytest

from repro.core import accelerators as acc
from repro.core.costmodel import chain_mappings, gconv_chain_cost
from repro.core.gconv import DimSpec, GConv
from repro.core.mapping import Entry, Mapping, MappingError, map_gconv
from repro.dse import (Evaluator, EvalRecord, SpecSpace, baseline_points,
                       load_suite, pareto_front, search_mapping)
from repro.dse.search import STRATEGIES


@pytest.fixture(scope="module")
def space():
    return SpecSpace()


@pytest.fixture(scope="module")
def reduced_suite():
    return load_suite("zoo", reduced=True)


@pytest.fixture(scope="module")
def evaluator(space, reduced_suite):
    return Evaluator(space, reduced_suite)


# ---------------------------------------------------------------------------
# space: encode/decode, validity, generation
# ---------------------------------------------------------------------------
def test_encode_decode_roundtrip(space):
    rng = random.Random(7)
    for _ in range(50):
        p = space.sample(rng)
        enc = space.encode(p)
        assert space.decode(enc) == p
        spec = space.to_spec(p)
        assert spec.n_pes <= space.max_pes
        assert sum(spec.gb.values()) <= space.max_gb_words


def test_decode_rejects_garbage(space):
    with pytest.raises(ValueError):
        space.decode("ax0=999999")           # missing fields + off-grid
    with pytest.raises(ValueError):
        space.decode("nonsense")
    good = space.encode(space.sample(random.Random(0)))
    with pytest.raises(ValueError):
        space.decode(good + ",bogus=1")      # unknown extra field


def test_sampling_and_mutation_stay_valid(space):
    rng = random.Random(3)
    p = space.sample(rng)
    for _ in range(100):
        q = space.mutate(p, rng)
        assert space.is_valid(q)
        child = space.crossover(p, q, rng)
        assert space.is_valid(child)
        p = q


def test_baseline_seeds_match_table4(space):
    pts = baseline_points(space)
    assert set(pts) == {"ER", "TPU", "EP"}
    for name, p in pts.items():
        real = acc.get(name)
        spec = space.to_spec(p)
        assert spec.n_pes == real.n_pes
        assert spec.gb == real.gb
        assert spec.ls == real.ls
        assert spec.gb_bandwidth == real.gb_bandwidth
        assert spec.has_overlap_primitive == real.has_overlap_primitive
        for enc_dim, real_dim in zip(spec.spatial, real.spatial):
            assert enc_dim.size == real_dim.size
            assert enc_dim.reduce == real_dim.reduce
            assert enc_dim.overlap == real_dim.overlap
            assert enc_dim.priority == real_dim.priority


# ---------------------------------------------------------------------------
# evaluator: objective + Pareto
# ---------------------------------------------------------------------------
def test_wlc_normalized_to_er(evaluator):
    assert evaluator.score_spec(acc.get("ER")).wlc == pytest.approx(1.0)


def test_pareto_toy_correctness():
    def rec(key, lat, energy, area):
        return EvalRecord(key=key, spec_name=key, point=None, lat=lat,
                          energy=energy, area=area, n_pes=1, gb_words=1,
                          wlc=lat + energy + area)

    a = rec("a", 1.0, 2.0, 3.0)          # frontier
    b = rec("b", 2.0, 1.0, 3.0)          # frontier (trades lat for energy)
    c = rec("c", 2.0, 2.0, 3.0)          # dominated by both a and b
    d = rec("d", 1.0, 2.0, 2.0)          # dominates a
    e = rec("e", 1.0, 2.0, 2.0)          # duplicate of d -> collapsed
    front = pareto_front([a, b, c, d, e])
    assert sorted(r.key for r in front) == ["b", "d"]


def test_baselines_in_space_score_identically(space, evaluator):
    """The encoded Table-4 seed points must cost exactly like the real
    specs they mirror (same resources + priorities => same mappings)."""
    for name, p in baseline_points(space).items():
        enc = evaluator.score_point(p)
        real = evaluator.score_spec(acc.get(name))
        assert enc.lat == pytest.approx(real.lat)
        assert enc.energy == pytest.approx(real.energy)


# ---------------------------------------------------------------------------
# strategies: determinism + budget
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("strategy", sorted(STRATEGIES))
def test_search_deterministic_under_seed(strategy, space, reduced_suite):
    def once():
        ev = Evaluator(space, reduced_suite)
        seeds = list(baseline_points(space).values())
        res = STRATEGIES[strategy]().run(space, ev.objective, budget=15,
                                         seed=11, seeds=seeds)
        frontier = pareto_front(ev.records)
        return (res.best, res.best_score, [r.key for r in frontier],
                [(p, s) for p, s in res.history])

    assert once() == once()


@pytest.mark.parametrize("strategy", sorted(STRATEGIES))
def test_search_respects_budget(strategy, space, evaluator):
    before = evaluator.n_evals
    res = STRATEGIES[strategy]().run(space, evaluator.objective, budget=9,
                                     seed=2)
    assert res.n_evals <= 9
    assert evaluator.n_evals - before <= 9
    assert res.best_score == min(s for _, s in res.history)


def test_seeded_search_never_worse_than_seeds(space, evaluator):
    """Seeding the baselines guarantees the search result is at least as
    good as the best hand-designed configuration."""
    seeds = list(baseline_points(space).values())
    seed_best = min(evaluator.objective(p) for p in seeds)
    res = STRATEGIES["anneal"]().run(space, evaluator.objective, budget=12,
                                     seed=5, seeds=seeds)
    assert res.best_score <= seed_best


# ---------------------------------------------------------------------------
# Mapping.from_entries / validate (the shared resource-limit path)
# ---------------------------------------------------------------------------
def _toy_gconv():
    return GConv(name="g", input="x", kernel="w", main="mul", reduce="add",
                 dims=(DimSpec("C", nop=8, nks=16),
                       DimSpec("W", nopc=32, nks=3, pad=1)))


def test_from_entries_reconstructs_algorithm1():
    g = _toy_gconv()
    spec = acc.get("ER")
    m = map_gconv(g, spec)
    rebuilt = Mapping.from_entries(g, spec, spatial=m.spatial,
                                   temporal=m.temporal)
    assert rebuilt.cycles() == m.cycles()
    assert rebuilt.movement() == m.movement()


def test_from_entries_rejects_axis_overflow():
    g = _toy_gconv()
    spec = acc.get("ER")                      # py axis has 12 PEs
    with pytest.raises(MappingError):
        Mapping.from_entries(g, spec,
                             spatial=[Entry("ks", "C", 16, "py")],
                             temporal=[Entry("op", "C", 8, "T"),
                                       Entry("opc", "W", 32, "T"),
                                       Entry("ks", "W", 3, "T")])


def test_from_entries_rejects_under_coverage():
    g = _toy_gconv()
    spec = acc.get("ER")
    with pytest.raises(MappingError):
        Mapping.from_entries(g, spec, spatial=[],
                             temporal=[Entry("op", "C", 8, "T")])


def test_from_entries_rejects_bad_placement():
    g = _toy_gconv()
    spec = acc.get("ER")
    full = [Entry("ks", "C", 16, "T"), Entry("op", "C", 8, "T"),
            Entry("opc", "W", 32, "T"), Entry("ks", "W", 3, "T")]
    with pytest.raises(MappingError):     # unknown spatial axis
        Mapping.from_entries(g, spec,
                             spatial=[Entry("ks", "C", 4, "nope")],
                             temporal=full)
    with pytest.raises(MappingError):     # temporal must be @T
        Mapping.from_entries(g, spec, spatial=[],
                             temporal=full[:-1] + [Entry("ks", "W", 3, "py")])
    with pytest.raises(MappingError):     # only opc entries slide
        Mapping.from_entries(
            g, spec, spatial=[],
            temporal=full[:-1] + [Entry("ks", "W", 3, "T", sliding=True)])


def test_chain_mappings_overrides_flow_through(reduced_suite):
    """An override replaces Algorithm 1's mapping for that node in both the
    mapping table and the chain cost — and the caller's object is cloned,
    not mutated, by the §4.3 loop exchange."""
    name, chain = reduced_suite[0]
    spec = acc.get("ER")
    node = next(n for n, g in chain.nodes.items() if isinstance(g, GConv))
    ov = map_gconv(chain.nodes[node], spec)
    before = [e for e in ov.temporal]
    mappings, _ = chain_mappings(chain, spec, overrides={node: ov})
    assert mappings[node] is not ov
    assert ov.temporal == before
    cost = gconv_chain_cost(chain, spec, overrides={node: ov})
    assert cost.latency > 0


# ---------------------------------------------------------------------------
# mapping search: never worse than Algorithm 1
# ---------------------------------------------------------------------------
def test_chain_mappings_overrides_reject_foreign_resources(reduced_suite):
    """A mapping built for a different accelerator's resources must not be
    injectable (it would smuggle that accelerator's scratchpads/bandwidth
    into the chain cost); priority-variant specs with identical resources
    are fine (that is how the mapping search works)."""
    import dataclasses

    name, chain = reduced_suite[0]
    spec = acc.get("ER")
    node = next(n for n, g in chain.nodes.items() if isinstance(g, GConv))
    foreign = map_gconv(chain.nodes[node], acc.get("EP"))
    with pytest.raises(MappingError):
        chain_mappings(chain, spec, overrides={node: foreign})
    variant = dataclasses.replace(
        spec, spatial=tuple(
            dataclasses.replace(s, priority=("op", "opc", "ks", "g"))
            for s in spec.spatial))
    ok = map_gconv(chain.nodes[node], variant)
    mappings, _ = chain_mappings(chain, spec, overrides={node: ok})
    assert mappings[node].cycles() == ok.cycles()


def test_chain_mappings_overrides_reject_unknown_node(reduced_suite):
    name, chain = reduced_suite[0]
    spec = acc.get("ER")
    node = next(n for n, g in chain.nodes.items() if isinstance(g, GConv))
    ov = map_gconv(chain.nodes[node], spec)
    with pytest.raises(MappingError):
        chain_mappings(chain, spec, overrides={"no_such_node": ov})


def test_search_mapping_never_worse_reduced(reduced_suite):
    spec = acc.get("ER")
    for name, chain in reduced_suite[:3]:
        ov, rep = search_mapping(chain, spec, budget=12, seed=0)
        assert rep["searched_latency"] <= rep["greedy_latency"]
        # overrides must reproduce the reported latency through the public
        # chain_mappings/gconv_chain_cost path
        cost = gconv_chain_cost(chain, spec, overrides=ov)
        assert cost.latency == pytest.approx(rep["searched_latency"])


@pytest.mark.slow
def test_search_mapping_never_worse_zoo_fullsize():
    """The ISSUE's regression: searched mappings are never worse than
    Algorithm 1's greedy output across the full-size zoo."""
    suite = load_suite("zoo")
    for accel in ("ER", "TPU", "EP"):
        spec = acc.get(accel)
        for name, chain in suite:
            ov, rep = search_mapping(chain, spec, budget=16, seed=0)
            assert rep["searched_latency"] <= rep["greedy_latency"], (
                f"{name}@{accel} regressed")


# ---------------------------------------------------------------------------
# driver: promotion, domination, artifacts
# ---------------------------------------------------------------------------
def test_run_dse_promotion_and_artifacts(tmp_path):
    from repro.dse import run_dse

    payload = run_dse(suite="zoo", budget=18, seed=0, strategy="anneal",
                      topk=2, map_budget=4, out_dir=str(tmp_path),
                      reduced=True, quiet=True)
    assert payload["frontier_size"] > 0
    best = payload["best"]
    assert best["fidelity"] == "sim"
    assert best["sim"]["within_tolerance"]
    assert best["sim"]["movement_drift"] <= 1e-9
    for fn in ("evals.json", "frontier.json", "best.json"):
        with open(tmp_path / fn) as f:
            json.load(f)
    # equal-budget domination claims carry the sim cross-check flag
    for name, verdict in payload["domination"].items():
        if verdict["dominated"]:
            assert verdict["by_wlc"] < verdict["baseline_wlc"]


@pytest.mark.slow
def test_dse_cli_end_to_end(tmp_path):
    """The module CLI writes artifacts and exits 0 (agreement holds)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.dse.run", "--suite", "zoo",
         "--budget", "30", "--seed", "0", "--strategy", "genetic",
         "--topk", "3", "--map-budget", "4", "--reduced",
         "--out", str(tmp_path)],
        capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 0, proc.stderr
    with open(tmp_path / "best.json") as f:
        best = json.load(f)
    assert best["agreement_ok"]
    assert best["config"]["budget"] == 30


# ---------------------------------------------------------------------------
# satellite: hillclimb import hygiene
# ---------------------------------------------------------------------------
def test_hillclimb_import_is_side_effect_free():
    proc = subprocess.run(
        [sys.executable, "-c",
         "import os, sys; before = os.environ.get('XLA_FLAGS');"
         "sys.path.insert(0, 'src');"
         "import repro.launch.hillclimb as hc;"
         "assert os.environ.get('XLA_FLAGS') == before;"
         "assert 'jax' not in sys.modules;"
         "assert not hasattr(hc, 'OUT')"],
        capture_output=True, text=True,
        cwd=os.path.join(os.path.dirname(__file__), ".."), timeout=120)
    assert proc.returncode == 0, proc.stderr
