"""Mesh-aware compiled execution (compile_chain(mesh=...), ServeEngine
data-parallel mode).

Two layers:
  * in-process: ShardPlan derivation (column/row/replicate decisions, dp
    guards, step wrapping) on fake meshes, plus end-to-end execution on a
    1x1 debug mesh — no extra devices needed;
  * subprocess (slow): the real 8-fake-device differential checks via
    ``python -m repro.exec.shardcheck`` — the device count locks at the
    first jax initialization, so multi-device runs need their own process
    (same pattern as the dry-run tests).
"""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core.interpreter import ChainExecutor
from repro.exec import compile_chain, derive_plan
from repro.exec.shardplan import wrap_steps
from repro.launch.mesh import make_debug_mesh
from repro.models import cnn, lm_chain
from repro.models.common import ModelConfig

TOL = dict(rtol=1e-4, atol=1e-4)


class FakeMesh:
    def __init__(self, **axes):
        self.shape = dict(axes)
        self.axis_names = tuple(axes)
        self.empty = False


def _tiny_cfg(**kw):
    base = dict(name="tiny", family="dense", n_layers=1, d_model=16,
                n_heads=2, n_kv_heads=2, d_ff=32, vocab=64)
    base.update(kw)
    return ModelConfig(**base)


def _compiled(chain):
    eng = compile_chain(chain)
    return eng


# ---------------------------------------------------------------------------
# ShardPlan derivation (pure policy, no devices)
# ---------------------------------------------------------------------------
def test_plan_column_splits_divisible_matmuls():
    ch = lm_chain.block_chain(_tiny_cfg(), 2, 8)
    eng = _compiled(ch)
    plan = derive_plan(eng.chain, eng.dispatch, FakeMesh(data=4, model=2))
    # d_ff = 32 and d_model = 16 divide model=2: the projection matmuls
    # column-split (no collective)
    assert plan.step_tp.get("w_gate") == "column"
    assert plan.step_tp.get("wq") == "column"
    assert plan.tp == "model" and plan.dp == ("data",)


def test_plan_no_tp_without_model_axis_or_at_size_one():
    ch = lm_chain.block_chain(_tiny_cfg(), 2, 8)
    eng = _compiled(ch)
    assert derive_plan(eng.chain, eng.dispatch,
                       FakeMesh(data=8, model=1)).step_tp == {}
    plan = derive_plan(eng.chain, eng.dispatch, FakeMesh(replica=8))
    assert plan.step_tp == {} and plan.tp is None
    assert plan.dp == ("replica",)


def test_plan_row_splits_when_only_k_divides():
    # Cout = 7 (odd), K = 32: the column split is impossible, the row
    # split (explicit psum) takes over
    from repro.core.chain import Chain
    from repro.core.gconv import DimSpec, GConv

    c = Chain("rowsplit")
    c.add_input("x", (5, 32))
    c.add_param("w", (1, 32 * 7))
    c.add(GConv("y", dims=(DimSpec("b", ng=5), DimSpec("c", nks=32, nop=7)),
                input="x", kernel="w", main="mul", reduce="add"))
    c.outputs = ["y"]
    eng = _compiled(c)
    assert eng.dispatch["y"] == "matmul:jnp"
    plan = derive_plan(eng.chain, eng.dispatch, FakeMesh(data=4, model=2))
    assert plan.step_tp == {"y": "row"}
    # neither divides (model=13): replication fallback
    plan13 = derive_plan(eng.chain, eng.dispatch, FakeMesh(data=1, model=13))
    assert plan13.step_tp == {}


def test_plan_input_specs_guarded():
    ch = lm_chain.block_chain(_tiny_cfg(), 2, 8)
    eng = _compiled(ch)
    plan = derive_plan(eng.chain, eng.dispatch, FakeMesh(data=2, model=1))
    for name, spec in plan.in_specs.items():
        shape = eng.chain.inputs[name].shape
        if shape and shape[0] % 2 == 0:
            assert spec[0] == ("data",), name
        else:
            assert tuple(spec) == (None,) * len(spec), name


def test_wrap_steps_tags_tp_modes():
    ch = lm_chain.block_chain(_tiny_cfg(), 2, 8)
    eng = _compiled(ch)
    plan = derive_plan(eng.chain, eng.dispatch, FakeMesh(data=4, model=2))
    wrapped = wrap_steps(eng.chain, eng.steps, plan)
    tags = {s.name: s.backend for s in wrapped}
    assert tags["w_gate"] == "matmul:jnp+tp:column"
    # non-matmul steps pass through untouched
    plain = {s.name: s.backend for s in eng.steps}
    for name, tag in tags.items():
        if name not in plan.step_tp:
            assert tag == plain[name]


# ---------------------------------------------------------------------------
# end-to-end on the 1x1 debug mesh (sharded machinery, single device)
# ---------------------------------------------------------------------------
def test_sharded_engine_runs_on_debug_mesh():
    mesh = make_debug_mesh(1, 1)
    ch = lm_chain.block_chain(_tiny_cfg(), 2, 8)
    params = ChainExecutor(ch).init_params(jax.random.PRNGKey(0))
    inputs = cnn.random_inputs(ch, 1)
    ref = compile_chain(ch)(inputs, params)
    eng = compile_chain(ch, mesh=mesh)
    assert eng.shard_plan is not None and eng.mesh is mesh
    got = eng(inputs, params)
    for o in ref:
        np.testing.assert_allclose(np.asarray(got[o]), np.asarray(ref[o]),
                                   err_msg=o, **TOL)
    # batched mode through the sharded in-shardings path
    import jax.numpy as jnp
    batched = {k: jnp.stack([v, v, v]) for k, v in inputs.items()}
    got_b = eng(batched, params)
    for o in ref:
        np.testing.assert_allclose(np.asarray(got_b[o][1]),
                                   np.asarray(ref[o]), err_msg=o, **TOL)


def test_sharded_signature_distinct_from_plain():
    mesh = make_debug_mesh(1, 1)
    ch = lm_chain.block_chain(_tiny_cfg(), 2, 8)
    plain = compile_chain(ch)
    sharded = compile_chain(ch, mesh=mesh)
    assert plain.signature != sharded.signature
    assert "mesh=data1xmodel1" in sharded.signature
    again = compile_chain(lm_chain.block_chain(_tiny_cfg(), 2, 8),
                          mesh=make_debug_mesh(1, 1))
    assert again.signature == sharded.signature


def test_serve_engine_debug_mesh_matches_unsharded():
    from repro.exec.serving import ServeEngine
    from repro.models import api

    from repro import configs

    cfg = configs.get("tinyllama-1.1b", smoke=True)
    model = api.build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    plain = ServeEngine(model, slots=2, max_len=32)
    sharded = ServeEngine(model, slots=2, max_len=32,
                          mesh=make_debug_mesh(1, 1))
    params_sh = sharded.shard_params(params)
    logits_p, rows_p, _ = plain.prefill(params, [[1, 2, 3], [4, 5]])
    logits_s, rows_s, _ = sharded.prefill(params_sh, [[1, 2, 3], [4, 5]])
    np.testing.assert_array_equal(np.asarray(logits_p),
                                  np.asarray(logits_s))
    cache_p = plain.splice_many(plain.init_state(), [0, 1], rows_p)
    cache_s = sharded.splice_many(sharded.init_state(), [0, 1], rows_s)
    import jax.numpy as jnp
    toks = jnp.asarray([[7], [9]], jnp.int32)
    lg_p, cache_p = plain.decode(params, toks, cache_p)
    lg_s, cache_s = sharded.decode(params_sh, toks, cache_s)
    np.testing.assert_array_equal(np.asarray(lg_p), np.asarray(lg_s))
    for k in cache_p:
        np.testing.assert_array_equal(np.asarray(cache_p[k]),
                                      np.asarray(cache_s[k]), err_msg=k)


# ---------------------------------------------------------------------------
# the real multi-device checks (subprocess: 8 faked host devices)
# ---------------------------------------------------------------------------
def _shardcheck(*args, devices=8, timeout=1500):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count"
                          f"={devices}")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.exec.shardcheck", *args],
        capture_output=True, text=True, env=env, timeout=timeout)
    assert proc.stdout.strip(), proc.stderr[-2000:]
    report = json.loads(proc.stdout.strip().splitlines()[-1])
    assert proc.returncode == 0, (report, proc.stderr[-2000:])
    return report


@pytest.mark.slow
def test_sharded_zoo_allclose_on_8_devices():
    report = _shardcheck("--mesh", "4x2", "--nets", "all")
    assert report["devices"] >= 8
    assert len(report["rows"]) == len(cnn.ZOO)
    for row in report["rows"]:
        assert row["ok"], row


@pytest.mark.slow
def test_sharded_lm_blocks_allclose_on_8_devices():
    report = _shardcheck("--mesh", "4x2", "--lm")
    rows = {r["check"]: r for r in report["rows"]}
    assert rows["lm:dense"]["ok"] and rows["lm:moe"]["ok"], rows
    # tensor-parallel splits actually engaged on the 4x2 mesh
    assert rows["lm:dense"]["tp_steps"] > 0
    assert rows["lm:dense"]["batched_max_err"] <= 1e-4


@pytest.mark.slow
def test_sharded_serve_byte_identical_on_8_devices():
    report = _shardcheck("--mesh", "8x1", "--serve")
    (row,) = report["rows"]
    assert row["identical_to_sequential"], row
    assert row["slots"] == 8
