"""Per-kernel shape/dtype sweeps: Pallas (interpret=True on CPU) vs the
pure-jnp oracles in kernels/ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.chain_norm import chain_norm
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gconv_matmul import gconv_matmul
from repro.kernels.gconv_spatial import gconv_spatial


def rnd(key, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# grouped matmul
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("g,m,k,n", [
    (1, 8, 16, 8), (4, 32, 64, 16), (2, 17, 33, 9),   # ragged shapes
    (8, 128, 128, 128),                               # tile-aligned
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gconv_matmul_sweep(g, m, k, n, dtype):
    x, w = rnd(0, (g, m, k), dtype), rnd(1, (g, k, n), dtype)
    got = gconv_matmul(x, w, block_m=32, block_n=32, block_k=32,
                       interpret=True)
    want = ref.gconv_matmul_ref(x, w)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


@pytest.mark.parametrize("post,scale", [("relu", 1.0), ("silu", 0.5),
                                        ("exp", 0.1)])
def test_gconv_matmul_epilogue(post, scale):
    x, w = rnd(2, (2, 16, 24), jnp.float32), rnd(3, (2, 24, 8), jnp.float32)
    got = gconv_matmul(x, w, post=post, scale=scale, block_m=8, block_n=8,
                       block_k=8, interpret=True)
    want = ref.gconv_matmul_ref(x, w, post=post, scale=scale)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_gconv_matmul_fused_operand_sequences():
    """§4.3-fused pre/post sequences with tensor operands ride in-register:
    prologue (per-K gamma, per-M stat, const) + epilogue (per-N bias, relu,
    const scale) against the jnp composition."""
    x, w = rnd(30, (2, 17, 33), jnp.float32), rnd(31, (2, 33, 9), jnp.float32)
    gamma = rnd(32, (1, 1, 33), jnp.float32)
    ms = rnd(33, (2, 17, 1), jnp.float32)
    bias = rnd(34, (1, 1, 9), jnp.float32)
    got = gconv_matmul(
        x, w,
        prologue=(("mul", None, 0), ("add", None, 1),
                  ("add_const", 0.3, None)),
        epilogue=(("add", None, 2), ("relu", None, None),
                  ("scale", 2.0, None)),
        operands=(gamma, ms, bias),
        block_m=8, block_n=8, block_k=8, interpret=True)
    want = jnp.einsum("gmk,gkn->gmn", x * gamma + ms + 0.3, w)
    want = jnp.maximum(want + bias, 0) * 2.0
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_gconv_matmul_grouped_epilogue_operand():
    """Per-group epilogue operand (G, 1, N) — the MoE bias layout."""
    x, w = rnd(35, (3, 8, 16), jnp.float32), rnd(36, (3, 16, 8), jnp.float32)
    bias = rnd(37, (3, 1, 8), jnp.float32)
    got = gconv_matmul(x, w, epilogue=(("add", None, 0),), operands=(bias,),
                       block_m=8, block_n=8, block_k=8, interpret=True)
    want = jnp.einsum("gmk,gkn->gmn", x, w) + bias
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# spatial conv
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,h,c,o,kk,stride,pad", [
    (1, 8, 8, 8, 3, 1, 1), (2, 12, 4, 8, 3, 2, 1), (1, 11, 3, 5, 5, 2, 2),
    (2, 9, 16, 32, 1, 1, 0),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gconv_spatial_sweep(b, h, c, o, kk, stride, pad, dtype):
    x = rnd(4, (b, h, h, c), dtype)
    w = rnd(5, (kk, kk, c, o), dtype)
    got = gconv_spatial(x, w, stride=stride, pad=pad, interpret=True)
    want = ref.gconv_spatial_ref(x, w, stride=stride, pad=pad)
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# fused norm
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("t,c", [(16, 64), (33, 40), (256, 128)])
@pytest.mark.parametrize("mode", ["rms", "layer"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_chain_norm_sweep(t, c, mode, dtype):
    x = rnd(6, (t, c), dtype)
    g = rnd(7, (c,), dtype) * 0.1 + 1.0
    b = rnd(8, (c,), dtype) * 0.1 if mode == "layer" else None
    got = chain_norm(x, g, b, mode=mode, block_t=32, interpret=True)
    want = ref.chain_norm_ref(x, g, b, mode=mode)
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("h,tq,tk,d,causal", [
    (2, 32, 32, 16, True), (2, 32, 32, 16, False),
    (1, 17, 40, 8, False), (1, 40, 40, 8, True),
    (4, 64, 64, 32, True),
])
def test_flash_attention_sweep(h, tq, tk, d, causal):
    q, k, v = (rnd(i, (h, tq if i == 9 else tk, d), jnp.float32)
               for i in (9, 10, 11))
    got = flash_attention(q, k, v, causal=causal, block_q=16, block_k=16,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_flash_attention_decode_offset():
    """Decode: 1 query attending a long KV prefix with q_offset."""
    h, tk, d = 2, 48, 16
    q = rnd(12, (h, 1, d), jnp.float32)
    k = rnd(13, (h, tk, d), jnp.float32)
    v = rnd(14, (h, tk, d), jnp.float32)
    got = flash_attention(q, k, v, causal=True, q_offset=tk - 1,
                          block_q=8, block_k=16, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, q_offset=tk - 1)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    q, k, v = (rnd(i, (2, 32, 32), jnp.bfloat16) for i in (15, 16, 17))
    got = flash_attention(q, k, v, causal=True, block_q=16, block_k=16,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)


# ---------------------------------------------------------------------------
# kernels vs the GCONV chain oracle (the end-to-end equivalence the paper
# needs: mapped/fused execution == chain semantics)
# ---------------------------------------------------------------------------
def test_gconv_matmul_equals_chain_interpreter():
    from repro.core import layers as L
    from repro.core.chain import Chain
    from repro.core.interpreter import ChainExecutor

    B, Cin, Cout = 8, 24, 16
    chain = Chain("fc")
    xin = chain.add_input("x", (B, Cin))
    y = L.fc(chain, xin, out_f=Cout, bias=False)
    ex = ChainExecutor(chain)
    params = ex.init_params(jax.random.PRNGKey(0))
    xv = rnd(20, (B, Cin), jnp.float32)
    chain_out = ex({"x": xv}, params)[y]
    w = params[f"{y}.w"].reshape(Cout, Cin).T[None]     # (1, K, N)
    kern_out = gconv_matmul(xv[None], w, block_m=8, block_n=8, block_k=8,
                            interpret=True)[0]
    np.testing.assert_allclose(kern_out, chain_out, rtol=2e-5, atol=2e-5)


def test_flash_equals_attention_chain_segment():
    from repro.core import layers as L
    from repro.core.chain import Chain
    from repro.core.interpreter import ChainExecutor

    B, H, T, D = 1, 2, 16, 8
    chain = Chain("attn")
    qi = chain.add_input("q", (B, H, T, 1, D))
    ki = chain.add_input("k", (B, H, 1, T, D))
    vi = chain.add_input("v", (B, H, 1, T, D))
    s = L.attention_scores(chain, qi, ki, scale=D ** -0.5)
    pr = L.softmax(chain, s, axis=3)
    o = L.attention_values(chain, pr, vi)
    ex = ChainExecutor(chain)
    q = rnd(21, (H, T, D), jnp.float32)
    k = rnd(22, (H, T, D), jnp.float32)
    v = rnd(23, (H, T, D), jnp.float32)
    chain_out = ex({"q": q[None, :, :, None, :], "k": k[None, :, None],
                    "v": v[None, :, None]}, {})[o][0, :, :, 0, :]
    kern_out = flash_attention(q, k, v, causal=False, block_q=8, block_k=8,
                               interpret=True)
    np.testing.assert_allclose(kern_out, chain_out, rtol=2e-4, atol=2e-4)
