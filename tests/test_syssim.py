"""System-simulator tests: degenerate exactness vs repro.sim,
heterogeneous overlap, serve-trace replay, and the arbitration
invariants (word conservation, monotone latency under contention)."""
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import accelerators as acc
from repro.sim.validate import DEFAULT_ACCELS, DRIFT_TOL
from repro.syssim import (ChainJob, RoutedChain, SystemSpec, Task,
                          hetero, hetero_utilization_gain, maxmin_fair,
                          replay_trace, route_chain, simulate_system,
                          single_array, validate_degenerate)
from repro.syssim.system import ArrayUnit, VectorUnit

FAST_NETS = ("MN", "AN")


# ---------------------------------------------------------------------------
# degenerate contract: 1 unit + no contention == repro.sim
# ---------------------------------------------------------------------------
def test_degenerate_single_unit_matches_sim_reduced():
    rows, summary = validate_degenerate(nets=FAST_NETS,
                                        accels=DEFAULT_ACCELS, reduced=True)
    assert summary["pairs"] == len(FAST_NETS) * len(DEFAULT_ACCELS)
    for r in rows:
        assert r["exact"], r
        assert r["contention_stall_cycles"] == 0.0
        assert r["cycles_drift"] <= DRIFT_TOL
    assert summary["all_within_tolerance"]


@pytest.mark.slow
def test_degenerate_single_unit_matches_sim_full_zoo():
    rows, summary = validate_degenerate(reduced=False)
    assert summary["all_exact"], \
        [r for r in rows if not r["exact"]]
    assert summary["all_within_tolerance"]


def test_degenerate_report_reproduces_sim_breakdown():
    """Movement/energy/compute agree per-unit, not just in aggregate."""
    from repro.models import cnn

    chain = cnn.build("MN", reduced=True)
    system = single_array("ER")
    routed = route_chain(chain, system)
    report = simulate_system([ChainJob(routed=routed)], system)
    (u,) = report.units
    sim = routed.sim
    assert u.energy == pytest.approx(sim.energy, rel=1e-12)
    assert u.offered_words == pytest.approx(sim.movement_words, rel=1e-12)
    assert u.injected_words == pytest.approx(u.offered_words, rel=1e-9)
    assert report.word_conservation_err <= 1e-9
    assert report.makespan == pytest.approx(sim.total_cycles, rel=1e-12)
    # credits only apply to back-to-back same-unit tasks, and they did:
    assert report.handoff_overlap_cycles == pytest.approx(
        sim.handoff_overlap_cycles, rel=1e-12)


# ---------------------------------------------------------------------------
# heterogeneous routing + overlap
# ---------------------------------------------------------------------------
def test_routing_follows_plan_backend_tags():
    from repro.models import cnn

    chain = cnn.build("MN", reduced=True)
    system = hetero("ER")
    routed = route_chain(chain, system)
    kinds = {t.unit: system.unit(t.unit).kind for t in routed.tasks}
    assert set(kinds.values()) == {"array", "vector"}
    for t in routed.tasks:
        if system.unit(t.unit).kind == "vector":
            assert t.backend.startswith(
                ("elementwise", "reduce", "concat", "movement",
                 "segment:norm", "segment:softmax")), t.backend
    # forcing the array keeps every group on the array
    homo = route_chain(chain, system, use_vector=False)
    assert {t.unit for t in homo.tasks} == {"array0"}


def test_hetero_two_unit_overlap_beats_array_only():
    g = hetero_utilization_gain("MN", accel="ER", n_jobs=2, reduced=True)
    assert g["vector_tasks"] > 0
    assert g["strictly_higher"]
    assert g["hetero_utilization"] > g["array_only_utilization"]
    assert g["hetero_makespan"] < g["array_only_makespan"]


# ---------------------------------------------------------------------------
# serve-trace replay
# ---------------------------------------------------------------------------
def _recorded_trace(tmp_path, n=3, max_new=3):
    from benchmarks.serve_bench import _workload
    from repro.launch.serve import Server
    from repro.obs import Tracer

    tr = Tracer()
    srv = Server("tinyllama-1.1b", smoke=True, slots=2, max_len=64,
                 tracer=tr)
    srv.run_workload(_workload(n, srv.cfg.vocab, max_new=max_new),
                     stagger_ticks=1)
    path = str(tmp_path / "serve_trace.json")
    tr.write(path)
    return path


def test_replay_recorded_trace_no_dropped_requests(tmp_path):
    path = _recorded_trace(tmp_path)
    res = replay_trace(path, hetero("ER"), reduced=True)
    assert res.requests_recorded == 3
    assert res.requests_simulated == 3 and res.dropped == 0
    rep = res.report
    assert rep.goodput > 0 and rep.energy > 0
    assert rep.word_conservation_err <= 1e-9
    assert {j.rid for j in rep.jobs} == {0, 1, 2}
    # staggered submits -> distinct arrivals spaced by tick_cycles
    arrivals = sorted(j.arrival for j in rep.jobs)
    assert arrivals[0] == 0.0 and arrivals[1] > 0.0
    summ = res.summary()
    assert summ["dropped"] == 0 and summ["requests_recorded"] == 3


def test_replay_fixed_tick_cycles_is_comparable(tmp_path):
    """An explicit tick_cycles (the dse cross-candidate mode) is honored
    and scales arrivals linearly."""
    path = _recorded_trace(tmp_path)
    a = replay_trace(path, hetero("ER"), reduced=True, tick_cycles=100.0)
    b = replay_trace(path, hetero("ER"), reduced=True, tick_cycles=200.0)
    assert a.tick_cycles == 100.0 and b.tick_cycles == 200.0
    arr_a = sorted(j.arrival for j in a.report.jobs)
    arr_b = sorted(j.arrival for j in b.report.jobs)
    for x, y in zip(arr_a, arr_b):
        assert y == pytest.approx(2 * x)


def test_replay_rejects_requestless_trace(tmp_path):
    from repro.obs import Tracer

    tr = Tracer()
    tr.counter("slots", {"active": 0, "queued": 0})
    path = str(tmp_path / "empty.json")
    tr.write(path)
    with pytest.raises(ValueError, match="no 'request'"):
        replay_trace(path, single_array("ER"), reduced=True)


# ---------------------------------------------------------------------------
# arbitration invariants (property tests)
# ---------------------------------------------------------------------------
demand_list = st.lists(st.floats(min_value=0.0, max_value=64.0,
                                 allow_nan=False), min_size=1, max_size=8)


@given(demand_list, st.floats(min_value=0.01, max_value=256.0,
                              allow_nan=False))
@settings(max_examples=80, deadline=None)
def test_maxmin_fair_is_feasible_and_work_conserving(ds, capacity):
    demands = {f"u{i}": d for i, d in enumerate(ds)}
    alloc = maxmin_fair(demands, capacity)
    assert set(alloc) == set(demands)
    for u, a in alloc.items():
        assert -1e-9 <= a <= demands[u] + 1e-9         # never over-granted
    total = sum(alloc.values())
    want = min(capacity, sum(demands.values()))
    assert total == pytest.approx(want, rel=1e-9, abs=1e-9)  # no idle waste
    # max-min fairness: an unsatisfied unit's share is >= any other share
    for u, a in alloc.items():
        if a < demands[u] - 1e-6:
            assert a >= max(alloc.values()) - 1e-6


def _toy_system(n_tasks_bw):
    spec = acc.get("ER")
    return SystemSpec(name="toy", units=(ArrayUnit(spec=spec),),
                      interconnect_bw=n_tasks_bw)


def _toy_jobs(task_params, arrivals):
    """Synthetic single-unit jobs: (work, words) per task."""
    jobs = []
    for j, (tasks, arr) in enumerate(zip(task_params, arrivals)):
        tl = [Task(chain=f"job{j}", name=f"t{i}", unit="array0",
                   backend="oracle", work=w, compute=w * 0.5,
                   bus_words=words, movement={"I": words}, energy=1.0)
              for i, (w, words) in enumerate(tasks)]
        routed = RoutedChain(name=f"job{j}", tasks=tl, dispatch={},
                             sim=None)
        jobs.append(ChainJob(routed=routed, arrival=arr, name=f"job{j}"))
    return jobs


task_strategy = st.lists(
    st.tuples(st.floats(min_value=1.0, max_value=100.0, allow_nan=False),
              st.floats(min_value=0.0, max_value=500.0, allow_nan=False)),
    min_size=1, max_size=4)


@given(st.lists(task_strategy, min_size=1, max_size=3),
       st.floats(min_value=0.5, max_value=32.0, allow_nan=False))
@settings(max_examples=60, deadline=None)
def test_engine_conserves_words_under_contention(jobs_params, bw):
    arrivals = [3.0 * i for i in range(len(jobs_params))]
    jobs = _toy_jobs(jobs_params, arrivals)
    report = simulate_system(jobs, _toy_system(bw))
    offered = sum(words for tasks in jobs_params for _, words in tasks)
    assert report.movement_words == pytest.approx(offered, rel=1e-9,
                                                  abs=1e-9)
    assert report.interconnect.forwarded_words == pytest.approx(
        offered, rel=1e-9, abs=1e-6)
    injected = sum(u.injected_words for u in report.units)
    assert injected == pytest.approx(offered, rel=1e-9, abs=1e-6)
    assert len(report.jobs) == len(jobs_params)
    for j in report.jobs:
        assert j.finish >= j.arrival - 1e-9


@given(task_strategy, st.floats(min_value=1.0, max_value=32.0,
                                allow_nan=False),
       st.floats(min_value=1.1, max_value=8.0, allow_nan=False))
@settings(max_examples=60, deadline=None)
def test_engine_latency_monotone_in_capacity(tasks, bw, squeeze):
    wide = simulate_system(_toy_jobs([tasks], [0.0]), _toy_system(bw))
    narrow = simulate_system(_toy_jobs([tasks], [0.0]),
                             _toy_system(bw / squeeze))
    assert narrow.makespan >= wide.makespan - 1e-6
    # every lost cycle is attributed to arbitration stall
    slip = narrow.makespan - wide.makespan
    assert narrow.contention_stall_cycles >= slip - 1e-6


@given(st.lists(task_strategy, min_size=1, max_size=2), task_strategy,
       st.floats(min_value=0.5, max_value=16.0, allow_nan=False))
@settings(max_examples=40, deadline=None)
def test_engine_latency_monotone_in_load(base_jobs, extra, bw):
    arrivals = [0.0] * len(base_jobs)
    before = simulate_system(_toy_jobs(base_jobs, arrivals),
                             _toy_system(bw))
    after = simulate_system(_toy_jobs(base_jobs + [extra],
                                      arrivals + [0.0]), _toy_system(bw))
    # adding a concurrent job never speeds the shared system up
    assert after.makespan >= before.makespan - 1e-6


def test_engine_rejects_bad_jobs():
    jobs = _toy_jobs([[(10.0, 5.0)]], [-1.0])
    with pytest.raises(ValueError, match="negative arrival"):
        simulate_system(jobs, _toy_system(8.0))
    stray = _toy_jobs([[(10.0, 5.0)]], [0.0])
    stray[0].routed.tasks[0].unit = "nope"
    with pytest.raises(KeyError):
        simulate_system(stray, _toy_system(8.0))


# ---------------------------------------------------------------------------
# system spec validation
# ---------------------------------------------------------------------------
def test_system_spec_validation():
    spec = acc.get("ER")
    with pytest.raises(ValueError, match="at least one unit"):
        SystemSpec(name="x", units=())
    with pytest.raises(ValueError, match="ArrayUnit"):
        SystemSpec(name="x", units=(VectorUnit(),))
    with pytest.raises(ValueError, match="duplicate"):
        SystemSpec(name="x", units=(ArrayUnit(spec=spec, name="u"),
                                    VectorUnit(name="u")))
    with pytest.raises(ValueError, match="capacity"):
        SystemSpec(name="x", units=(ArrayUnit(spec=spec),),
                   interconnect_bw=0.0)
    sys2 = hetero(spec)
    assert sys2.capacity == pytest.approx(
        sum(u.link_bw for u in sys2.units))
    assert sys2.unit("vec0").kind == "vector"
    with pytest.raises(KeyError):
        sys2.unit("nope")
