"""LM block as a GCONV chain: executes through the compiled engine and
matches a plain-jnp transformer block (no RoPE/causal mask on either side).
Compiled-vs-oracle equivalence for the same chain lives in test_exec.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.exec import compile_chain
from repro.models import lm_chain


@pytest.mark.slow
def test_lm_block_chain_matches_jnp_reference():
    cfg = configs.get("tinyllama-1.1b", smoke=True)
    B, T, D = 2, 8, cfg.d_model
    H, hd = cfg.n_heads, cfg.hd
    ch = lm_chain.block_chain(cfg, B, T)
    eng = compile_chain(ch)
    params = eng.init_params(jax.random.PRNGKey(0))
    xv = 0.3 * jax.random.normal(jax.random.PRNGKey(1), (B, T, D))
    out = eng({"x": xv}, params)[ch.outputs[0]]

    def rms(z, g):
        zf = z / jnp.sqrt((z ** 2).mean(-1, keepdims=True) + 1e-6)
        return zf * g

    def lin(z, w, f):
        return jnp.einsum("btc,fc->btf", z, w.reshape(f, z.shape[-1]))

    h = rms(xv, params["ln1.gamma"].reshape(D))
    q = lin(h, params["wq.w"], cfg.q_dim).reshape(B, T, H, hd)
    k = lin(h, params["wk.w"], cfg.q_dim).reshape(B, T, H, hd)
    v = lin(h, params["wv.w"], cfg.q_dim).reshape(B, T, H, hd)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) * hd ** -0.5
    p = jax.nn.softmax(s, -1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v).reshape(B, T, cfg.q_dim)
    r1 = lin(o, params["wo.w"], D) + xv
    h2 = rms(r1, params["ln2.gamma"].reshape(D))
    g = jax.nn.silu(lin(h2, params["w_gate.w"], cfg.d_ff))
    u = lin(h2, params["w_up.w"], cfg.d_ff)
    ref = lin(g * u, params["w_down.w"], D) + r1
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_lm_moe_chain_builds_and_maps():
    """MoE block chain: experts appear as ONE grouped GCONV (Ng = E) and
    Algorithm 1 maps it onto the TPU spec."""
    from repro.core import accelerators as acc
    from repro.core.mapping import factors_by, map_gconv

    cfg = configs.get("olmoe-1b-7b", smoke=True)
    ch = lm_chain.block_chain(cfg, 2, 16)
    e_gate = ch.nodes["e_gate"]
    assert e_gate.dim("E").ng == cfg.n_experts
    m = map_gconv(e_gate, acc.tpu_v5e())
    covered = factors_by(m.spatial + m.temporal)
    for d in e_gate.dims:
        for pname, n in (("g", d.ng), ("op", d.nop), ("opc", d.nopc),
                         ("ks", d.nks)):
            assert covered.get((pname, d.name), 1) >= n


def test_chain_stats_table():
    rows = lm_chain.chain_stats_table(batch=2, seq=32)
    assert len(rows) == 3
    for r in rows:
        assert r["mxu_eligible"] >= 5
