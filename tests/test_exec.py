"""Differential tests: the compiled chain engine (repro.exec) vs the oracle
interpreter, across the CNN zoo, the LM chain segments, fusion-group
execution, the fused-segment dispatch targets and randomized GCONVs.

The oracle stays the semantic reference; here it runs under one jax.jit so
the reference cost is a single compile of the oracle's own (deliberately
expansion-heavy) program rather than per-op eager dispatch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.chain import Chain
from repro.core.fusion import fuse_chain
from repro.core.gconv import DimSpec, GConv, Op
from repro.core.interpreter import ChainExecutor, eval_gconv
from repro.core import layers as L
from repro.exec import compile_chain, execute_gconv
from repro.models import cnn, lm_chain
from repro.models.common import ModelConfig

TOL = dict(rtol=1e-4, atol=1e-4)


def _inputs_and_params(chain, seed=0):
    ex = ChainExecutor(chain)
    params = ex.init_params(jax.random.PRNGKey(seed))
    return ex, cnn.random_inputs(chain, seed + 1), params


def _oracle(ex, inputs, params, **kw):
    return jax.jit(lambda i, p: ex(i, p, **kw))(inputs, params)


def _assert_allclose(got, ref):
    assert set(got) == set(ref)
    for o in ref:
        np.testing.assert_allclose(np.asarray(got[o]), np.asarray(ref[o]),
                                   err_msg=o, **TOL)


# ---------------------------------------------------------------------------
# the seven zoo networks + the training (FP+BP) chain
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", list(cnn.ZOO))
@pytest.mark.slow
def test_zoo_compiled_matches_oracle(name):
    chain = cnn.build(name, reduced=True, batch=2)
    ex, inputs, params = _inputs_and_params(chain)
    ref = _oracle(ex, inputs, params)
    got = compile_chain(chain)(inputs, params)
    _assert_allclose(got, ref)


@pytest.mark.slow
def test_training_block_compiled_matches_oracle():
    chain = cnn.training_block_chain(batch=4, ch=8, hw=8)
    ex = ChainExecutor(chain)
    params = ex.init_params(jax.random.PRNGKey(0))
    ins = {"x": jax.random.normal(jax.random.PRNGKey(1), (4, 8, 8, 8)),
           "gO": jax.random.normal(jax.random.PRNGKey(2), (4, 8, 8, 8))}
    ref = _oracle(ex, ins, params, keep_all=True)
    got = compile_chain(chain)(ins, params, keep_all=True)
    for o in got:          # every surviving node, node-for-node
        np.testing.assert_allclose(np.asarray(got[o]), np.asarray(ref[o]),
                                   err_msg=o, **TOL)


# ---------------------------------------------------------------------------
# LM chain segments (dense + MoE)
# ---------------------------------------------------------------------------
def _tiny_cfg(**kw):
    base = dict(name="tiny", family="dense", n_layers=1, d_model=16,
                n_heads=2, n_kv_heads=2, d_ff=32, vocab=64)
    base.update(kw)
    return ModelConfig(**base)


def test_lm_block_compiled_matches_oracle():
    ch = lm_chain.block_chain(_tiny_cfg(), 2, 8)
    ex, inputs, params = _inputs_and_params(ch)
    ref = _oracle(ex, inputs, params)
    for fuse in (True, False):
        got = compile_chain(ch, fuse=fuse)(inputs, params)
        _assert_allclose(got, ref)


def test_lm_moe_block_compiled_matches_oracle():
    cfg = _tiny_cfg(name="tiny-moe", family="moe", n_experts=4, top_k=2)
    ch = lm_chain.block_chain(cfg, 2, 8)
    ex, inputs, params = _inputs_and_params(ch)
    ref = _oracle(ex, inputs, params)
    eng = compile_chain(ch)
    _assert_allclose(eng(inputs, params), ref)
    # the expert FFN must hit the grouped-matmul backend (Ng = n_experts)
    assert eng.dispatch["e_gate"].startswith("matmul")
    assert eng.dispatch["e_up"].startswith("matmul")
    assert eng.dispatch["e_down"].startswith("matmul")


# ---------------------------------------------------------------------------
# fused segments: the hand-fused paths are now dispatch targets
# ---------------------------------------------------------------------------
def test_segments_dispatch_to_hand_fused_paths():
    ch = lm_chain.block_chain(_tiny_cfg(), 2, 8)
    eng = compile_chain(ch, fuse=False)          # unfused form of the chain
    tags = set(eng.dispatch.values())
    assert "segment:norm:jnp" in tags            # models.common.norm
    assert "segment:attention:jnp" in tags       # models.common.attention_naive
    ex, inputs, params = _inputs_and_params(ch)
    _assert_allclose(eng(inputs, params), _oracle(ex, inputs, params))


def test_segments_dispatch_to_pallas_kernels():
    """backend='pallas' routes the same segments through chain_norm /
    flash_attention / gconv_matmul (interpret mode on CPU)."""
    ch = lm_chain.block_chain(_tiny_cfg(), 1, 4)
    eng = compile_chain(ch, fuse=False, backend="pallas")
    tags = set(eng.dispatch.values())
    assert "segment:norm:pallas" in tags
    assert "segment:attention:pallas" in tags
    assert "matmul:pallas" in tags
    ex, inputs, params = _inputs_and_params(ch)
    _assert_allclose(eng(inputs, params), _oracle(ex, inputs, params))


def test_pallas_matmul_runs_fused_sequences_in_register():
    """fuse=True + backend='pallas': the rmsnorm that fusion folded into
    the linears' pre sequence rides the gconv_matmul prologue (and the
    softmax-into-values pre likewise), still allclose to the oracle."""
    ch = lm_chain.block_chain(_tiny_cfg(), 1, 4)
    eng = compile_chain(ch, fuse=True, backend="pallas")
    assert "matmul:pallas" in set(eng.dispatch.values())
    ex, inputs, params = _inputs_and_params(ch)
    _assert_allclose(eng(inputs, params), _oracle(ex, inputs, params))


def test_softmax_segment_detected_in_zoo_chain():
    chain = cnn.build("AN", reduced=True, batch=2)
    eng = compile_chain(chain)
    assert "segment:softmax" in set(eng.dispatch.values())


def test_segment_honors_out_dtype():
    """Segment lowerings must keep the oracle's out_dtype contract."""
    import dataclasses

    c = Chain("sm")
    xin = c.add_input("x", (2, 3, 5))
    y = L.softmax(c, xin, axis=-1)
    c.nodes[y] = dataclasses.replace(c.nodes[y], out_dtype="bfloat16")
    c.mark_output(y)
    eng = compile_chain(c)
    assert "segment:softmax" in set(eng.dispatch.values())
    xv = jax.random.normal(jax.random.PRNGKey(2), (2, 3, 5))
    got = eng({"x": xv}, {})[y]
    ref = ChainExecutor(c)({"x": xv}, {})[y]
    assert got.dtype == ref.dtype == jnp.bfloat16

    # interior out_dtype: the oracle quantizes the intermediate, so the
    # f32 segment must refuse and fall back to per-node dispatch
    c2 = Chain("sm2")
    xin2 = c2.add_input("x", (2, 3, 5))
    y2 = L.softmax(c2, xin2, axis=-1)
    c2.nodes[f"{y2}.exp"] = dataclasses.replace(
        c2.nodes[f"{y2}.exp"], out_dtype="bfloat16")
    c2.mark_output(y2)
    eng2 = compile_chain(c2)
    assert "segment:softmax" not in set(eng2.dispatch.values())
    got2 = eng2({"x": xv}, {})[y2]
    ref2 = ChainExecutor(c2)({"x": xv}, {})[y2]
    np.testing.assert_allclose(np.asarray(got2), np.asarray(ref2), **TOL)


# ---------------------------------------------------------------------------
# fusion-group execution == unfused execution, node for node
# ---------------------------------------------------------------------------
def _bn_block_chain(c=4, hw=6):
    chain = Chain("fuseblk")
    x = chain.add_input("x", (2, c, hw, hw))
    y = L.conv2d(chain, x, out_c=c, k=1, bias=False)
    y, _ = L.batch_norm_fp(chain, y)
    y = L.relu(chain, y)
    y = L.scale_layer(chain, y)
    chain.mark_output(y)
    return chain


def test_fusion_group_execution_matches_unfused_node_for_node():
    chain = _bn_block_chain()
    fused, report = fuse_chain(chain)
    assert report.groups                          # something actually fused
    ex = ChainExecutor(chain)
    params = ex.init_params(jax.random.PRNGKey(3))
    ins = {"x": jax.random.normal(jax.random.PRNGKey(4), (2, 4, 6, 6))}
    ref_all = _oracle(ex, ins, params, keep_all=True)
    got_all = compile_chain(chain, fuse=True)(ins, params, keep_all=True)
    # every surviving (host) node's value equals its unfused oracle value
    for name in got_all:
        np.testing.assert_allclose(np.asarray(got_all[name]),
                                   np.asarray(ref_all[name]),
                                   err_msg=name, **TOL)
    # and the unfused compile agrees on every original node
    got_unfused = compile_chain(chain, fuse=False)(ins, params, keep_all=True)
    for name in got_unfused:
        np.testing.assert_allclose(np.asarray(got_unfused[name]),
                                   np.asarray(ref_all[name]),
                                   err_msg=name, **TOL)


def test_execution_partitions_cover_fused_chain():
    chain = _bn_block_chain()
    eng = compile_chain(chain)
    hosts = [g.host for g in eng.partitions]
    assert hosts == list(eng.chain.nodes)
    members = [m for g in eng.partitions for m in g.members]
    expected = {m for ms in eng.fusion_report.groups.values() for m in ms}
    assert set(members) == expected
    # fused members are reported in the dispatch table, not executed
    for m in members:
        assert eng.dispatch[m].startswith("fused:")


# ---------------------------------------------------------------------------
# randomized GCONVs across main/reduce/pre/post combinations
# ---------------------------------------------------------------------------
dim_strategy = st.builds(
    dict,
    ng=st.integers(1, 3), nop=st.integers(1, 3), nopc=st.integers(1, 4),
    nks=st.integers(1, 3), stride=st.integers(1, 2))

PRES = [(), (Op("square"),), (Op("abs"),)]
POSTS = [(), (Op("relu"),), (Op("scale", const=0.5),)]


@given(dim_strategy, dim_strategy,
       st.sampled_from(["none", "mul", "add", "sub", "max"]),
       st.sampled_from(["none", "add", "max"]),
       st.integers(0, len(PRES) - 1), st.integers(0, len(POSTS) - 1),
       st.integers(0, 2 ** 31 - 1))
@settings(max_examples=40, deadline=None)
def test_compiled_gconv_matches_oracle_random(d1, d2, main, reduce,
                                              pre_i, post_i, seed):
    if reduce == "none":                  # no taps without a reduce
        d1 = dict(d1, nks=1)
        d2 = dict(d2, nks=1)
    if main == "none":                    # no Nop replication without a kernel
        d1 = dict(d1, nop=1)              # (the oracle defines no semantics
        d2 = dict(d2, nop=1)              #  for kernel-less replication)
    g = GConv(name="g", dims=(DimSpec("A", **d1), DimSpec("B", **d2)),
              input="x", kernel=None if main == "none" else "k",
              main=main, reduce=reduce,
              pre=PRES[pre_i], post=POSTS[post_i])
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, g.in_shape)
    kk = (jax.random.normal(k2, g.k_shape) if main != "none" else None)
    want = np.asarray(eval_gconv(g, x, kk))
    got = np.asarray(execute_gconv(g, x, kk))
    np.testing.assert_allclose(got, want, **TOL)


def test_compiled_gconv_broadcast_kernel():
    """Kernel with broadcast (size-1) axes — the chain's Table-2 usage."""
    g = GConv(name="g",
              dims=(DimSpec("A", ng=3), DimSpec("B", nop=2, nks=4)),
              input="x", kernel="k", main="mul", reduce="add")
    x = jax.random.normal(jax.random.PRNGKey(0), g.in_shape)
    kk = jax.random.normal(jax.random.PRNGKey(1), (1, 8))  # bcast over A
    want = np.asarray(eval_gconv(g, x, kk))
    got = np.asarray(execute_gconv(g, x, kk))
    np.testing.assert_allclose(got, want, **TOL)


# ---------------------------------------------------------------------------
# kernels.common satellites
# ---------------------------------------------------------------------------
def test_pick_block_invariants():
    from repro.kernels.common import cdiv, pick_block, round_up

    for n in list(range(1, 40)) + [100, 127, 128, 129, 130, 255, 300, 513]:
        for target in (8, 64, 128, 256, 512):
            for align in (8, 128):
                b = pick_block(n, target, align)
                assert b >= 1
                # a grid of cdiv(n, b) blocks always covers the axis: the
                # remainder is never silently dropped
                assert cdiv(n, b) * b >= n, (n, target, align, b)
                assert b <= round_up(n, align), (n, target, align, b)
                if n > align:
                    assert b % align == 0, (n, target, align, b)


def test_gconv_matmul_remainder_blocks():
    """n just above the 128 alignment (e.g. 130) must not drop the
    remainder: the padded grid covers it and results match the oracle."""
    from repro.kernels import ref
    from repro.kernels.gconv_matmul import gconv_matmul

    x = jax.random.normal(jax.random.PRNGKey(0), (1, 130, 130))
    w = jax.random.normal(jax.random.PRNGKey(1), (1, 130, 130))
    got = gconv_matmul(x, w, interpret=True)       # default (big) targets
    np.testing.assert_allclose(got, ref.gconv_matmul_ref(x, w),
                               rtol=1e-4, atol=1e-4)


def test_use_interpret_env_override(monkeypatch):
    from repro.kernels import common

    monkeypatch.setenv("REPRO_FORCE_INTERPRET", "0")
    assert common.use_interpret() is False
    monkeypatch.setenv("REPRO_FORCE_INTERPRET", "1")
    assert common.use_interpret() is True
    monkeypatch.delenv("REPRO_FORCE_INTERPRET")
    assert common.use_interpret() is common._backend_wants_interpret()
