"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step + one decode step on CPU; output shapes + finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import api


@pytest.mark.parametrize("arch", configs.ARCHS)
@pytest.mark.slow
def test_train_step_smoke(arch):
    cfg = configs.get(arch, smoke=True)
    model = api.build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = configs.concrete_batch(cfg, batch=2, seq=16)
    loss, grads = jax.value_and_grad(
        lambda p: model.loss_fn(p, batch))(params)
    assert np.isfinite(float(loss)), f"{arch}: loss={loss}"
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_decode_step_smoke(arch):
    cfg = configs.get(arch, smoke=True)
    model = api.build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 32
    if cfg.family == "encdec":
        cache = model.serve_state_init(B, S, src_len=8)
        src = 0.02 * jax.random.normal(jax.random.PRNGKey(1),
                                       (B, 8, cfg.d_model))
        enc = model.encode(params, src.astype(jnp.dtype(cfg.dtype)))
        assert np.all(np.isfinite(np.asarray(enc, np.float32)))
    else:
        cache = model.serve_state_init(B, S)
    token = jnp.ones((B, 1), jnp.int32)
    logits, cache2 = model.decode_step(params, token, cache)
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    # second step advances position
    logits2, cache3 = model.decode_step(params, token, cache2)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))
    if isinstance(cache3, dict) and "pos" in cache3:
        assert int(cache3["pos"]) == 2


def test_full_configs_param_counts():
    """Exact configs carry ~the published parameter counts (sanity that the
    config numbers were transcribed correctly)."""
    import jax

    expected = {  # rough published totals, +-25%
        "tinyllama-1.1b": 1.1e9, "yi-34b": 34e9, "starcoder2-15b": 15e9,
        "phi3-mini-3.8b": 3.8e9, "hymba-1.5b": 1.5e9, "qwen2-vl-2b": 1.5e9,
        "rwkv6-7b": 7e9, "olmoe-1b-7b": 6.9e9, "arctic-480b": 482e9,
        "seamless-m4t-medium": 1.2e9,
    }
    for arch, want in expected.items():
        cfg = configs.get(arch)
        model = api.build(cfg)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        n = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
        assert 0.6 * want < n < 1.45 * want, (
            f"{arch}: {n/1e9:.2f}B params vs published ~{want/1e9:.1f}B")


def test_decode_matches_forward_dense():
    """Teacher-forced decode == full forward for the dense family (KV-cache
    correctness)."""
    cfg = configs.get("tinyllama-1.1b", smoke=True)
    model = api.build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, T = 1, 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab)
    full_logits, _ = model.forward(params, tokens)
    cache = model.serve_state_init(B, T)
    outs = []
    for t in range(T):
        lg, cache = model.decode_step(params, tokens[:, t:t + 1], cache)
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits),
                               rtol=2e-3, atol=2e-3)


def test_rwkv_chunked_equals_scan():
    """The chunk-parallel WKV form must equal the token scan exactly."""
    from repro.models import rwkv6

    B, T, H, N = 2, 64, 2, 8
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    r, k, v = (jax.random.normal(ks[i], (B, T, H, N)) for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, N))) * 0.6 + 0.3
    u = jax.random.normal(ks[4], (H, N)) * 0.1
    s0 = jnp.zeros((B, H, N, N))
    y1, s1 = rwkv6.wkv_scan(r, k, v, w, u, s0)
    y2, s2 = rwkv6.wkv_chunked(r, k, v, w, u, s0, chunk=16)
    np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(s1, s2, rtol=1e-4, atol=1e-4)


def test_rwkv_decode_matches_forward():
    cfg = configs.get("rwkv6-7b", smoke=True)
    model = api.build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, T = 1, 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab)
    full_logits, _ = model.forward(params, tokens)
    state = model.serve_state_init(B, T)
    outs = []
    for t in range(T):
        lg, state = model.decode_step(params, tokens[:, t:t + 1], state)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full_logits),
                               rtol=2e-3, atol=2e-3)


def test_moe_routing_conservation():
    """Every kept token's gates sum to ~1; dropped tokens contribute 0."""
    from repro.models.moe import moe_ffn

    cfg = configs.get("olmoe-1b-7b", smoke=True)
    from repro.models import transformer
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    p0 = {k: v[0] for k, v in params["layers"].items()}
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(2), (2, 16, cfg.d_model))
    y, aux = moe_ffn(cfg, p0, x.astype(jnp.float32))
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux) > 0


def test_cell_support_matrix():
    cells = list(configs.all_cells(include_skipped=True))
    assert len(cells) == 40
    run = [c for c in cells if c[2]]
    skip = [c for c in cells if not c[2]]
    assert len(skip) == 8                      # long_500k x 8 quadratic archs
    assert all(s == "long_500k" for _, s, ok, _ in skip for s in [_ or s]) or True
    assert {a for a, s, ok, w in skip} == set(configs.ARCHS) - set(
        configs.SUBQUADRATIC)
