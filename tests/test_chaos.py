"""Chaos / resilience regressions: deterministic fault injection through
``repro.runtime.chaos``, and the serving driver's recovery ladder
(bounded retries -> NaN watchdog quarantine + replay -> graceful
degradation -> snapshot/resume).

The recovery contract throughout is BYTE-identity: prompts are
deterministic and every compiled program is row-independent, so a
workload served through injected faults must reproduce the fault-free
``sequential_reference`` outputs bit for bit."""
import numpy as np
import pytest

from repro.launch.serve import (Request, ResilienceConfig, Server,
                                sequential_reference)
from repro.runtime.chaos import (ChaosInjector, ChaosPlan, FaultSpec,
                                 InjectedFault)

ARCH = "tinyllama-1.1b"


def _reqs(n, max_new=4, seed=0, deadline=None):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, 256,
                                        int(rng.integers(2, 6))).tolist(),
                    max_new=max_new, deadline_ticks=deadline)
            for i in range(n)]


@pytest.fixture(scope="module")
def srv():
    """One resilient 2-slot server for the whole module (programs compile
    once); every test re-arms it via _arm, which factory-resets state."""
    return Server(ARCH, smoke=True, slots=2, max_len=48,
                  resilience=ResilienceConfig())


@pytest.fixture(scope="module")
def ref():
    """Fault-free sequential outputs for the canonical _reqs(4) workload."""
    return sequential_reference(ARCH, _reqs(4), smoke=True, max_len=48)


def _arm(srv, spec, **res_kw):
    """Factory-reset the module server and arm it with a fresh injector
    (None spec = fault-free) and a fresh ResilienceConfig."""
    srv.reset_state()
    srv.tracer = None
    srv.resilience = ResilienceConfig(**res_kw)
    inj = ChaosInjector(ChaosPlan.parse(spec)) if spec else None
    srv.chaos = inj
    srv.engine.chaos = inj
    if inj is not None:
        inj.observe(srv.metrics, srv.tracer)
    return inj


def _run_and_check(srv, ref, n=4, stagger=1, max_new=4):
    report = srv.run_workload(_reqs(n, max_new=max_new),
                              stagger_ticks=stagger)
    got = {r.rid: r.out for r in srv.finished if r.status == "ok"}
    assert set(got) == set(range(n)), report["statuses"]
    for i in range(n):
        assert got[i] == ref[i], f"rid {i} diverged from fault-free ref"
    return report


# ---------------------------------------------------------------------------
# plan / injector semantics
# ---------------------------------------------------------------------------
def test_plan_parse_roundtrip():
    spec = "decode@4=raise; decode@7=nan:1,splice@0=latency:0.25"
    plan = ChaosPlan.parse(spec)
    assert len(plan) == 3
    assert plan.faults[0] == FaultSpec("decode", 4, "raise")
    assert plan.faults[1] == FaultSpec("decode", 7, "nan", 1.0)
    assert plan.faults[2] == FaultSpec("splice", 0, "latency", 0.25)
    # str() re-parses to the same plan
    assert ChaosPlan.parse(str(plan)).faults == plan.faults


def test_plan_parse_rejects_bad_specs():
    for bad in ("decode=raise", "decode@x=raise", "nowhere@1=raise",
                "decode@1=explode", "decode@-1=raise"):
        with pytest.raises(ValueError):
            ChaosPlan.parse(bad)


def test_plan_for_steps_targets_step_site():
    plan = ChaosPlan.for_steps([3, 9])
    assert all(f.site == "step" and f.kind == "raise" for f in plan.faults)
    assert [f.at for f in plan.faults] == [3, 9]


def test_injector_fires_each_fault_exactly_once():
    inj = ChaosInjector(ChaosPlan.parse("decode@1=raise"))
    assert inj.enter("decode") == ()                 # invocation 0
    with pytest.raises(InjectedFault):
        inj.enter("decode")                          # invocation 1: boom
    assert inj.enter("decode") == ()                 # 2: fault is spent
    assert inj.invocations("decode") == 3
    assert inj.remaining == 0
    assert inj.kinds_fired() == {"raise"}


def test_injector_explicit_index_is_replay_safe():
    """The training loop keys the step site by step number: replaying a
    restored step must NOT re-fire its (already fired) fault, and the
    explicit index must not advance the internal counter."""
    inj = ChaosInjector(ChaosPlan.for_steps([5]))
    with pytest.raises(InjectedFault):
        inj.enter("step", index=5)
    inj.enter("step", index=5)                       # replay: clean
    assert inj.invocations("step") == 0
    assert inj.remaining == 0


def test_injector_latency_sleeps_and_data_faults_return():
    slept = []
    inj = ChaosInjector(ChaosPlan.parse("decode@0=latency:0.5;"
                                        "decode@0=nan:1"),
                        sleep=slept.append)
    post = inj.enter("decode")
    assert slept == [0.5]
    assert [f.kind for f in post] == ["nan"]         # returned, not raised


# ---------------------------------------------------------------------------
# satellite: StragglerMonitor EMA regression
# ---------------------------------------------------------------------------
def test_straggler_monitor_keeps_flagging_sustained_straggler():
    """Flagged samples must not feed the EMA: the old code absorbed them,
    inflating the baseline until a SUSTAINED straggler stopped being
    flagged after a couple of observations."""
    from repro.runtime.fault_tolerance import StragglerMonitor

    m = StragglerMonitor(alpha=0.5, threshold=3.0)
    assert not m.observe(1.0)                        # baseline
    for _ in range(5):
        assert m.observe(10.0), "sustained straggler stopped being flagged"
    assert m.flagged == 5
    assert m.ema == 1.0                              # baseline unpolluted
    assert not m.observe(1.0)                        # healthy still healthy


# ---------------------------------------------------------------------------
# serving recovery ladder, each rung byte-identical to the fault-free ref
# ---------------------------------------------------------------------------
def test_raised_decode_fault_retried_in_place(srv, ref):
    _arm(srv, "decode@1=raise")
    report = _run_and_check(srv, ref)
    assert report["retries"] >= 1
    assert report["faults"] >= 1
    assert report["quarantines"] == 0                # retry, not replay


def test_nan_logits_quarantine_and_replay(srv, ref):
    _arm(srv, "decode@1=nan:0")                      # slot 0 mid-decode
    report = _run_and_check(srv, ref)
    assert report["quarantines"] >= 1
    assert report["statuses"]["failed"] == 0


def test_corrupted_cache_row_detected_next_tick(srv, ref):
    """A corrupt fault NaNs slot 0's KV rows in the COMMITTED cache; the
    masked-attention 0*NaN leak surfaces as NaN logits on the next decode
    tick, where the watchdog quarantines exactly that slot."""
    _arm(srv, "decode@1=corrupt:0")
    report = _run_and_check(srv, ref)
    assert report["quarantines"] >= 1


def test_prefill_fault_requeues_admission_batch(srv, ref):
    _arm(srv, "prefill@0=raise", max_retries=0)      # no in-tick retry
    report = _run_and_check(srv, ref)
    assert report["faults"] >= 1


def test_replay_budget_exhaustion_fails_request(srv):
    _arm(srv, "decode@0=nan:0;decode@1=nan:0", max_replays=0)
    report = srv.run_workload(_reqs(1), stagger_ticks=0)
    assert report["statuses"]["failed"] == 1
    assert report["statuses"]["ok"] == 0
    assert srv.finished[0].status == "failed"


def test_infeasible_deadline_is_shed_up_front(srv):
    _arm(srv, None)
    # max_new=4 needs 3 ticks after admission; a 1-tick deadline can never
    # be met -> admission control sheds instead of wasting a slot
    report = srv.run_workload(_reqs(3, max_new=4, deadline=1),
                              stagger_ticks=0)
    assert report["statuses"] == {"ok": 0, "expired": 0, "shed": 3,
                                  "failed": 0}
    assert report["requests_submitted"] == 3


def test_queued_request_expires_when_shedding_disabled(srv):
    _arm(srv, None, shed=False)
    # 3 requests, 2 slots: the third waits; with shed off it sits in the
    # queue until its deadline passes and is evicted as expired
    report = srv.run_workload(_reqs(3, max_new=4, deadline=3),
                              stagger_ticks=0)
    assert report["statuses"]["ok"] == 2
    assert report["statuses"]["expired"] == 1


@pytest.mark.slow
def test_degraded_fallback_then_recovery(srv):
    """Persistent decode failures degrade to the per-request teacher-
    forced path; once the faults clear, probe successes recover the
    compiled path. Outputs stay byte-identical throughout."""
    spec = ";".join(f"decode@{k}=raise" for k in range(6))
    _arm(srv, spec, max_retries=0, degrade_after=2, recover_after=1)
    reqs = _reqs(6)
    report = srv.run_workload(_reqs(6), stagger_ticks=0)
    assert report["degraded_transitions"] >= 2       # down AND back up
    assert not report["degraded"]
    assert report["statuses"]["ok"] == 6
    ref6 = sequential_reference(ARCH, reqs, smoke=True, max_len=48)
    got = {r.rid: r.out for r in srv.finished if r.status == "ok"}
    for i in range(6):
        assert got[i] == ref6[i]


def test_decode_single_matches_sequential_reference(srv, ref):
    """The degraded-mode fallback path in isolation: decode_single runs
    the same compiled programs/shapes as a 1-slot server, so its stream
    is the reference stream bit for bit."""
    _arm(srv, None)
    for i, req in enumerate(_reqs(4)):
        out = srv.engine.decode_single(srv.params, req.prompt, req.max_new)
        assert out == ref[i]


# ---------------------------------------------------------------------------
# snapshot / resume
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_snapshot_resume_after_crash_is_byte_identical(tmp_path):
    reqs = _reqs(5, max_new=5, seed=3)
    chaos = ChaosInjector(ChaosPlan.parse("tick@6=raise"))
    srv = Server(ARCH, smoke=True, slots=2, max_len=48,
                 resilience=ResilienceConfig(), chaos=chaos,
                 snapshot_dir=str(tmp_path), snapshot_every=2)
    with pytest.raises(InjectedFault):
        srv.run_workload([Request(rid=r.rid, prompt=list(r.prompt),
                                  max_new=r.max_new) for r in reqs],
                         stagger_ticks=1)
    srv._snap.wait()
    crashed_ok = {r.rid for r in srv.finished if r.status == "ok"}
    assert crashed_ok, "crash landed before any request finished"

    res = Server.resume(ARCH, str(tmp_path), smoke=True, slots=2,
                        max_len=48, resilience=ResilienceConfig())
    # finished outputs restored, in-flight requests re-queued for replay
    assert {r.rid for r in res.finished
            if r.status == "ok"} == crashed_ok
    assert {r.rid for r in res.queue} == \
        {r.rid for r in reqs} - crashed_ok
    report = res.run_until_drained()
    # statuses count restored + replayed requests: all of them end ok
    assert report["statuses"]["ok"] == len(reqs)
    ref = sequential_reference(ARCH, reqs, smoke=True, max_len=48)
    got = {r.rid: r.out for r in res.finished if r.status == "ok"}
    for i, r in enumerate(reqs):
        assert got[r.rid] == ref[i]


def test_resume_from_empty_dir_starts_fresh(tmp_path):
    res = Server.resume(ARCH, str(tmp_path), smoke=True, slots=1,
                        max_len=48, resilience=ResilienceConfig())
    assert res.finished == [] and res.queue == []


# ---------------------------------------------------------------------------
# observability: the fault timeline in the trace report
# ---------------------------------------------------------------------------
def test_report_fault_timeline(srv, ref, tmp_path):
    from repro.obs import Tracer, load_trace
    from repro.obs.report import summarize

    # fault-free traced run: no fault timeline, summary unchanged
    _arm(srv, None)
    srv.tracer = tr = Tracer()
    srv.run_workload(_reqs(2), stagger_ticks=0)
    clean = tmp_path / "clean.json"
    tr.write(str(clean))
    assert summarize(load_trace(str(clean)))["faults"] is None

    # injected fault run: chaos.inject + quarantine instants in order
    inj = _arm(srv, "decode@1=nan:0")
    srv.tracer = tr = Tracer()
    inj.observe(srv.metrics, tr)
    _run_and_check(srv, ref)
    srv.tracer = None
    faulty = tmp_path / "faulty.json"
    tr.write(str(faulty))
    faults = summarize(load_trace(str(faulty)))["faults"]
    assert faults is not None
    assert faults["counts"].get("chaos.inject", 0) >= 1
    assert faults["counts"].get("quarantine", 0) >= 1
    ts = [e["ts_us"] for e in faults["events"]]
    assert ts == sorted(ts)


# ---------------------------------------------------------------------------
# satellite: training-side injection through the chaos module
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_train_inject_fault_recovers_via_checkpoints(tmp_path):
    """The --inject-fault CLI mapping: ChaosPlan.for_steps -> the
    FaultTolerantLoop fault_hook. The injected step fault fires once,
    the loop restores from the last checkpoint, and the replayed step
    does NOT re-fire (explicit step keying), so training completes."""
    from repro.launch.train import train

    hook = ChaosInjector(ChaosPlan.for_steps([6])).train_fault_hook()
    report = train(ARCH, steps=12, smoke=True, batch=2, seq=16,
                   ckpt_dir=str(tmp_path), ckpt_every=4, fault_hook=hook)
    assert report["restarts"] == 1
    assert report["final_step"] == 12
