"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.chain import Chain
from repro.core.fusion import fuse_chain
from repro.core.gconv import DimSpec, GConv
from repro.core.interpreter import ChainExecutor, eval_gconv
from repro.core import layers as L

dim_strategy = st.builds(
    dict,
    ng=st.integers(1, 3), nop=st.integers(1, 3), nopc=st.integers(1, 4),
    nks=st.integers(1, 3), stride=st.integers(1, 2))


@given(dim_strategy)
@settings(max_examples=80, deadline=None)
def test_eq1_shape_algebra(d):
    """Eq. (1) (corrected): Nips reconstructs the input size; padding keeps
    the identity; sizes stay positive."""
    ds = DimSpec("A", **d)
    assert ds.in_size == ds.ng * ((ds.nopc - 1) * ds.stride + ds.nks)
    assert ds.out_size == ds.ng * ds.nop * ds.nopc
    assert ds.k_size == ds.ng * ds.nop * ds.nks
    if ds.nks > ds.stride and ds.nopc > 1:
        assert ds.has_overlap_reuse


@given(dim_strategy, dim_strategy, st.integers(0, 2 ** 31 - 1))
@settings(max_examples=25, deadline=None)
def test_gconv_matches_explicit_loop_semantics(d1, d2, seed):
    """Interpreter == the paper's Fig.-4 nested loop, on random 2-D GCONVs."""
    g = GConv(name="g",
              dims=(DimSpec("A", **d1), DimSpec("B", **d2)),
              input="x", kernel="k", main="mul", reduce="add")
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, g.in_shape)
    kk = jax.random.normal(k2, g.k_shape)
    got = np.asarray(eval_gconv(g, x, kk))

    # explicit loops (paper Fig. 4)
    dA, dB = g.dims
    want = np.zeros(g.out_shape, np.float32)
    xv = np.asarray(x).reshape(dA.ng, dA.nips, dB.ng, dB.nips)
    kv = np.asarray(kk).reshape(dA.ng, dA.nop, dA.nks, dB.ng, dB.nop, dB.nks)
    for gA in range(dA.ng):
        for opA in range(dA.nop):
            for ocA in range(dA.nopc):
                for ksA in range(dA.nks):
                    for gB in range(dB.ng):
                        for opB in range(dB.nop):
                            for ocB in range(dB.nopc):
                                for ksB in range(dB.nks):
                                    ia = ksA + dA.stride * ocA
                                    ib = ksB + dB.stride * ocB
                                    want[gA * dA.nop * dA.nopc
                                         + opA * dA.nopc + ocA,
                                         gB * dB.nop * dB.nopc
                                         + opB * dB.nopc + ocB] += (
                                        xv[gA, ia, gB, ib]
                                        * kv[gA, opA, ksA, gB, opB, ksB])
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@given(st.integers(2, 6), st.integers(2, 8), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=15, deadline=None)
def test_fusion_preserves_semantics_random_chain(c, hw, seed):
    """Property: §4.3 fusion never changes chain numerics."""
    chain = Chain("r")
    x = chain.add_input("x", (2, c, hw, hw))
    y = L.conv2d(chain, x, out_c=c, k=1, bias=False)
    y, _ = L.batch_norm_fp(chain, y)
    y = L.relu(chain, y)
    y = L.scale_layer(chain, y)
    chain.mark_output(y)
    fused, rep = fuse_chain(chain)
    ex0, ex1 = ChainExecutor(chain), ChainExecutor(fused)
    params = ex0.init_params(jax.random.PRNGKey(seed))
    xv = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, c, hw, hw))
    out0 = ex0({"x": xv}, params)[y]
    out1 = ex1({"x": xv}, {k: v for k, v in params.items()
                           if k in fused.params})[fused.outputs[0]]
    np.testing.assert_allclose(out0, out1, rtol=1e-4, atol=1e-4)
    # fusion is idempotent once it reaches a fixpoint
    fused2, rep2 = fuse_chain(fused)
    assert rep2.after_len == rep.after_len


@given(st.integers(1, 3), st.integers(2, 5), st.integers(1, 3),
       st.integers(0, 2 ** 31 - 1))
@settings(max_examples=20, deadline=None)
def test_softmax_chain_rows_sum_to_one(b, t, c, seed):
    chain = Chain("s")
    x = chain.add_input("x", (b, t, c + 1))
    y = L.softmax(chain, x, axis=-1)
    ex = ChainExecutor(chain)
    xv = 3 * jax.random.normal(jax.random.PRNGKey(seed), (b, t, c + 1))
    out = np.asarray(ex({"x": xv}, {})[y])
    np.testing.assert_allclose(out.sum(-1), 1.0, rtol=1e-5)
    assert (out >= 0).all()


@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 3))
@settings(max_examples=10, deadline=None)
def test_adamw_step_invariants(seed, dim):
    """Optimizer property: a step moves params opposite to the gradient for
    fresh state (warmup>0, no decay), and never produces non-finite values."""
    from repro.optim import adamw

    cfg = adamw.OptConfig(peak_lr=1e-2, warmup_steps=1, total_steps=100,
                          weight_decay=0.0, clip_norm=1e9)
    w = jax.random.normal(jax.random.PRNGKey(seed), (dim, dim))
    params = {"w": w}
    g = {"w": jnp.ones_like(w)}
    state = adamw.init_state(cfg, params)
    new_p, state, _ = adamw.update(cfg, params, g, state)
    assert np.isfinite(np.asarray(new_p["w"])).all()
    assert (np.asarray(new_p["w"]) <= np.asarray(w) + 1e-9).all()
