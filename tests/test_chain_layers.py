"""Layer-decomposition correctness: every GCONV chain must match the plain
JAX/XLA reference implementation of its layer (the paper's Table 2 / §3
claims, checked numerically)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import layers as L
from repro.core.chain import Chain
from repro.core.interpreter import ChainExecutor

jax.config.update("jax_enable_x64", False)


def run_chain(chain, inputs, params=None, seed=0):
    ex = ChainExecutor(chain)
    p = ex.init_params(jax.random.PRNGKey(seed))
    if params:
        p.update(params)
    return ex(inputs, p, keep_all=True), p


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape)


# ---------------------------------------------------------------------------
# traditional layers
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("groups,stride,pad,k,H", [
    (1, 1, 0, 3, 13), (1, 2, 1, 3, 13), (2, 1, 1, 3, 13), (8, 1, 0, 1, 13),
    (1, 4, 0, 11, 15),   # AlexNet-conv1-like geometry (exact, Eq. 1)
])
def test_conv2d_matches_lax(groups, stride, pad, k, H):
    B, C, W, OC = 2, 8, H, 16
    chain = Chain("t")
    x = chain.add_input("x", (B, C, H, W))
    y = L.conv2d(chain, x, out_c=OC, k=k, stride=stride, pad=pad,
                 groups=groups, bias=True)
    env, p = run_chain(chain, {"x": rand(0, B, C, H, W)})
    w = p[f"{y}.w"].reshape(OC, C // groups, k, k)
    b = p[f"{y}.b"].reshape(OC)
    ref = jax.lax.conv_general_dilated(
        env["x"], w, (stride, stride), [(pad, pad), (pad, pad)],
        feature_group_count=groups) + b[None, :, None, None]
    np.testing.assert_allclose(env[y], ref, rtol=2e-5, atol=2e-5)


def test_depthwise_conv():
    B, C, H, W = 2, 6, 9, 9
    chain = Chain("t")
    x = chain.add_input("x", (B, C, H, W))
    y = L.conv2d(chain, x, out_c=C, k=3, stride=1, pad=1, groups=C, bias=False)
    assert chain.meta[y]["layer"] == "depthwise_conv"
    assert not chain.meta[y]["traditional"]
    env, p = run_chain(chain, {"x": rand(1, B, C, H, W)})
    w = p[f"{y}.w"].reshape(C, 1, 3, 3)
    ref = jax.lax.conv_general_dilated(
        env["x"], w, (1, 1), [(1, 1), (1, 1)], feature_group_count=C)
    np.testing.assert_allclose(env[y], ref, rtol=2e-5, atol=2e-5)


def test_conv3d_matches_lax():
    B, C, T, H, W = 1, 3, 8, 9, 9
    chain = Chain("t")
    x = chain.add_input("x", (B, C, T, H, W))
    y = L.conv3d(chain, x, out_c=4, k=3, kt=3, pad=1, pad_t=1, bias=False)
    env, p = run_chain(chain, {"x": rand(2, B, C, T, H, W)})
    w = p[f"{y}.w"].reshape(4, C, 3, 3, 3)
    ref = jax.lax.conv_general_dilated(
        env["x"], w, (1, 1, 1), [(1, 1)] * 3,
        dimension_numbers=("NCTHW", "OITHW", "NCTHW"))
    np.testing.assert_allclose(env[y], ref, rtol=2e-5, atol=2e-5)


def test_fc_and_linear():
    B, C, F = 4, 10, 7
    chain = Chain("t")
    x = chain.add_input("x", (B, C))
    y = L.fc(chain, x, out_f=F)
    env, p = run_chain(chain, {"x": rand(3, B, C)})
    ref = env["x"] @ p[f"{y}.w"].reshape(F, C).T + p[f"{y}.b"].reshape(F)
    np.testing.assert_allclose(env[y], ref, rtol=2e-5, atol=2e-5)

    chain2 = Chain("t2")
    x2 = chain2.add_input("x", (2, 5, C))
    y2 = L.linear(chain2, x2, out_f=F, bias=True)
    env2, p2 = run_chain(chain2, {"x": rand(4, 2, 5, C)})
    ref2 = jnp.einsum("btc,fc->btf", env2["x"],
                      p2[f"{y2}.w"].reshape(F, C)) + p2[f"{y2}.b"].reshape(F)
    np.testing.assert_allclose(env2[y2], ref2, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("mode", ["max", "avg"])
def test_pool2d(mode):
    B, C, H, W = 2, 3, 8, 8
    chain = Chain("t")
    x = chain.add_input("x", (B, C, H, W))
    fn = L.maxpool2d if mode == "max" else L.avgpool2d
    y = fn(chain, x, k=3, stride=2, pad=1)
    env, _ = run_chain(chain, {"x": rand(5, B, C, H, W)})
    if mode == "max":
        ref = jax.lax.reduce_window(
            env["x"], -jnp.inf, jax.lax.max, (1, 1, 3, 3), (1, 1, 2, 2),
            [(0, 0), (0, 0), (1, 1), (1, 1)])
    else:
        ref = jax.lax.reduce_window(
            env["x"], 0.0, jax.lax.add, (1, 1, 3, 3), (1, 1, 2, 2),
            [(0, 0), (0, 0), (1, 1), (1, 1)]) / 9.0
    np.testing.assert_allclose(env[y], ref, rtol=2e-5, atol=2e-5)


def test_softmax_chain():
    chain = Chain("t")
    x = chain.add_input("x", (3, 5, 11))
    y = L.softmax(chain, x, axis=-1)
    env, _ = run_chain(chain, {"x": 3 * rand(6, 3, 5, 11)})
    np.testing.assert_allclose(env[y], jax.nn.softmax(env["x"], axis=-1),
                               rtol=2e-5, atol=2e-6)


# ---------------------------------------------------------------------------
# non-traditional layers (the paper's motivating cases)
# ---------------------------------------------------------------------------
def test_lrn_matches_formula():
    B, C, H, W = 2, 16, 5, 5
    n, alpha, beta, k = 5, 1e-4, 0.75, 2.0
    chain = Chain("t")
    x = chain.add_input("x", (B, C, H, W))
    y = L.lrn(chain, x, n=n, alpha=alpha, beta=beta, k_const=k)
    xv = rand(7, B, C, H, W)
    env, _ = run_chain(chain, {"x": xv})
    sq = xv * xv
    pad = jnp.pad(sq, [(0, 0), (n // 2, n // 2), (0, 0), (0, 0)])
    win = sum(pad[:, i:i + C] for i in range(n))
    ref = xv * (k + alpha / n * win) ** (-beta)
    np.testing.assert_allclose(env[y], ref, rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("spatial", [False, True])
def test_batchnorm_fp_table2(spatial):
    B, C, H, W = 8, 4, 3, 3
    eps = 1e-5
    chain = Chain("t")
    x = chain.add_input("x", (B, C, H, W))
    y, fp = L.batch_norm_fp(chain, x, eps=eps, spatial=spatial)
    xv = rand(8, B, C, H, W) * 2 + 1
    env, _ = run_chain(chain, {"x": xv})
    axes = (0, 2, 3) if spatial else (0,)
    mu = xv.mean(axis=axes, keepdims=True)
    var = ((xv - mu) ** 2).mean(axis=axes, keepdims=True)
    ref = (xv - mu) / jnp.sqrt(var + eps)
    np.testing.assert_allclose(env[y], ref, rtol=2e-4, atol=2e-5)
    # intermediates match Table 2's columns too
    np.testing.assert_allclose(env[fp["fp1"]], mu, rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(env[fp["fp3"]], 1 / jnp.sqrt(var + eps),
                               rtol=2e-4, atol=2e-5)


def test_batchnorm_bp_matches_autodiff():
    """BP1–BP6 must equal jax.grad of the FP formula (paper Eq. 5)."""
    B, C, H, W = 8, 4, 3, 3
    eps = 1e-5
    chain = Chain("t")
    x = chain.add_input("x", (B, C, H, W))
    g = chain.add_input("gO", (B, C, H, W))
    y, fp = L.batch_norm_fp(chain, x, eps=eps)
    gi, _ = L.batch_norm_bp(chain, g, fp)
    xv = rand(9, B, C, H, W) * 1.5
    gv = rand(10, B, C, H, W)
    env, _ = run_chain(chain, {"x": xv, "gO": gv})

    def bn(x):
        mu = x.mean(axis=0, keepdims=True)
        var = ((x - mu) ** 2).mean(axis=0, keepdims=True)
        return (x - mu) / jnp.sqrt(var + eps)

    _, vjp = jax.vjp(bn, xv)
    ref = vjp(gv)[0]
    np.testing.assert_allclose(env[gi], ref, rtol=5e-3, atol=1e-5)


def test_scale_and_residual_and_concat():
    B, C, H, W = 2, 4, 3, 3
    chain = Chain("t")
    x = chain.add_input("x", (B, C, H, W))
    s = L.scale_layer(chain, x)
    r = L.add_tensors(chain, s, x)
    c = L.concat(chain, [r, x], axis=1)
    xv = rand(11, B, C, H, W)
    env, p = run_chain(chain, {"x": xv})
    ref_s = xv * p[f"{s}.gamma"] + p[f"{s}.beta"]
    np.testing.assert_allclose(env[s], ref_s, rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(env[r], ref_s + xv, rtol=2e-5, atol=2e-6)
    assert env[c].shape == (B, 2 * C, H, W)


def test_dropout_mask():
    chain = Chain("t")
    x = chain.add_input("x", (4, 6))
    y = L.dropout(chain, x, rate=0.5)
    xv = rand(12, 4, 6)
    mask = (jax.random.uniform(jax.random.PRNGKey(1), (4, 6)) > 0.5)
    env, _ = run_chain(chain, {"x": xv, f"{y}.mask": mask.astype(jnp.float32)})
    np.testing.assert_allclose(env[y], xv * mask * 2.0, rtol=2e-5, atol=2e-6)


# ---------------------------------------------------------------------------
# LM-era layers
# ---------------------------------------------------------------------------
def test_rmsnorm():
    B, T, C = 2, 5, 16
    chain = Chain("t")
    x = chain.add_input("x", (B, T, C))
    y = L.rms_norm(chain, x)
    xv = rand(13, B, T, C)
    env, p = run_chain(chain, {"x": xv})
    ref = xv / jnp.sqrt((xv ** 2).mean(-1, keepdims=True) + 1e-6)
    ref = ref * p[f"{y}.gamma"]
    np.testing.assert_allclose(env[y], ref, rtol=2e-5, atol=2e-6)


def test_attention_segment():
    """QK^T -> softmax -> PV as a 5-GCONV chain segment == jnp attention."""
    B, H, T, D = 2, 3, 6, 8
    chain = Chain("t")
    qi = chain.add_input("q", (B, H, T, 1, D))
    ki = chain.add_input("k", (B, H, 1, T, D))
    vi = chain.add_input("v", (B, H, 1, T, D))
    s = L.attention_scores(chain, qi, ki, scale=1.0 / np.sqrt(D))
    pr = L.softmax(chain, s, axis=3)
    o = L.attention_values(chain, pr, vi)
    q = rand(14, B, H, T, D)
    k = rand(15, B, H, T, D)
    v = rand(16, B, H, T, D)
    env, _ = run_chain(chain, {
        "q": q[:, :, :, None, :], "k": k[:, :, None, :, :],
        "v": v[:, :, None, :, :]})
    att = jax.nn.softmax(jnp.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(D), -1)
    ref = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    np.testing.assert_allclose(env[o][:, :, :, 0, :], ref,
                               rtol=2e-5, atol=2e-5)


def test_movement_view():
    chain = Chain("t")
    x = chain.add_input("x", (2, 6, 4))
    y = L.view(chain, x, (2, 3, 2, 4))
    z = L.view(chain, y, (2, 2, 3, 4), perm=(0, 2, 1, 3))
    xv = rand(17, 2, 6, 4)
    env, _ = run_chain(chain, {"x": xv})
    np.testing.assert_allclose(
        env[z], xv.reshape(2, 3, 2, 4).transpose(0, 2, 1, 3))


def test_fresh_probes_all_namespaces():
    # fresh() must avoid inputs and params too, not just nodes: a
    # collision with either makes the subsequent add() raise
    chain = Chain("t")
    chain.add_input("x", (2, 4))
    chain.add_param("x_1", (2, 4))
    L.relu(chain, "x", name="x_2")
    name = chain.fresh("x")
    assert name == "x_3"
    L.relu(chain, "x", name=name)       # must not raise "duplicate"
    assert chain.fresh("y") == "y"


def test_chain_stats_traditional_split():
    chain = Chain("t")
    x = chain.add_input("x", (2, 4, 8, 8))
    c = L.conv2d(chain, x, out_c=8, k=3, pad=1)
    r = L.relu(chain, c)
    b, _ = L.batch_norm_fp(chain, r)
    st = chain.stats()
    assert st["n_gconv"] == 6            # conv + relu + 4 BN GCONVs
    assert st["traditional_macs"] > 0
    assert st["nontraditional_macs"] > 0
    assert st["macs"] == st["traditional_macs"] + st["nontraditional_macs"]
