"""Autotuner regressions: the tuning DB contract, the measured-selection
plumbing, the `_prefer_pallas_matmul` M-axis fix, and the lint audit of
applied decisions.

Property tests (hypothesis, self-skipping) pin the DB's tolerance
invariants: valid entries round-trip byte-for-byte, unknown keys are
misses, and corrupted entries are quarantined — never applied, never a
crash (the tuner falls back to the heuristic plan)."""
import json
import os
import tempfile

import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import layers as L
from repro.core.chain import Chain
from repro.core.interpreter import init_chain_params
from repro.exec import compile_chain
from repro.exec import tune as T

TUNE_BACKENDS = list(T.TUNABLE)


def _small_chain(batch=8, c=64, name="tune_small"):
    ch = Chain(name)
    x = ch.add_input("x", (batch, c))
    h = L.fc(ch, x, out_f=c, name="fc1")
    h = L.relu(ch, h, name="act")
    h = L.fc(ch, h, out_f=c, name="fc2")
    ch.mark_output(h)
    return ch


def _case(batch=8, c=64):
    ch = _small_chain(batch, c)
    params = init_chain_params(ch, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (batch, c))
    return ch, {"x": x}, params


# ---------------------------------------------------------------------------
# DB property tests
# ---------------------------------------------------------------------------
_valid_blocks = st.one_of(
    st.none(),
    st.dictionaries(st.sampled_from(["m", "n", "k", "o"]),
                    st.integers(min_value=1, max_value=8192), min_size=1))
_valid_entries = st.fixed_dictionaries(dict(
    backend=st.sampled_from(TUNE_BACKENDS),
    block=_valid_blocks,
    latency_us=st.floats(min_value=1e-3, max_value=1e6,
                         allow_nan=False, allow_infinity=False)))
_keys = st.text(min_size=1, max_size=40)

_bad_entries = st.one_of(
    st.none(), st.just([]), st.just("einsum"), st.just(7),
    st.fixed_dictionaries(dict(backend=st.just(""),
                               latency_us=st.just(1.0))),
    st.fixed_dictionaries(dict(
        backend=st.sampled_from(TUNE_BACKENDS),
        latency_us=st.sampled_from([0.0, -4.2, float("nan"),
                                    float("inf"), True, "fast"]))),
    st.fixed_dictionaries(dict(
        backend=st.sampled_from(TUNE_BACKENDS), latency_us=st.just(1.0),
        block=st.sampled_from([{}, {"z": 4}, {"m": 0}, {"m": -8},
                               {"m": True}, {"m": 1.5}, "blk"]))))


@given(st.dictionaries(_keys, _valid_entries, max_size=6))
@settings(max_examples=20, deadline=None)
def test_db_round_trip(entries):
    """Valid entries survive save/load unchanged and hit on lookup."""
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "db.json")
        db = T.TuneDB(path)
        for k, e in entries.items():
            db.record(k, dict(e))
        db.save()
        back = T.TuneDB.load(path)
        assert back.quarantined == {}
        assert back.entries == entries
        for k, e in entries.items():
            assert back.lookup(k) == e


@given(_keys, _keys, _valid_entries)
@settings(max_examples=20, deadline=None)
def test_db_unknown_key_misses(k1, k2, entry):
    """A key never recorded — e.g. any signature change — is a miss."""
    db = T.TuneDB("unused")
    db.record(k1, dict(entry))
    if k2 != k1:
        assert db.lookup(k2) is None
    assert db.lookup(k1) is not None


@given(st.dictionaries(_keys, _bad_entries, min_size=1, max_size=4),
       st.dictionaries(_keys, _valid_entries, max_size=3))
@settings(max_examples=20, deadline=None)
def test_db_corrupted_entries_quarantined(bad, good):
    """Corrupted entries read as misses and move to the quarantine
    section; intact entries in the same file keep working."""
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "db.json")
        with open(path, "w") as f:
            json.dump(dict(schema=T.SCHEMA,
                           entries={**good, **bad}), f, default=float)
        db = T.TuneDB.load(path)
        for k in bad:
            assert db.lookup(k) is None
            if k not in good:
                assert k in db.quarantined
        for k in set(good) - set(bad):
            assert db.lookup(k) == good[k]


def test_db_unrecognized_schema_quarantined_wholesale():
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "db.json")
        with open(path, "w") as f:
            json.dump(dict(schema="somebody-else/v9",
                           entries={"k": {"backend": "einsum",
                                          "latency_us": 1.0}}), f)
        db = T.TuneDB.load(path)
        assert db.entries == {}
        assert "__file__" in db.quarantined


def test_db_unreadable_file_starts_empty():
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "db.json")
        with open(path, "w") as f:
            f.write("{not json")
        db = T.TuneDB.load(path)
        assert db.entries == {} and db.lookup("k") is None


# ---------------------------------------------------------------------------
# measured selection (shared repro.search engines)
# ---------------------------------------------------------------------------
def test_measured_select_deterministic_and_budgeted():
    lat = [5.0, 3.0, 9.0, 1.0, 7.0]
    calls = []

    def measure(i):
        calls.append(i)
        return lat[i]

    win, win_s, res = T.measured_select(len(lat), measure, budget=16,
                                        seed=0)
    assert (win, win_s) == (3, 1.0)
    assert 0 in calls                     # heuristic always measured
    again = T.measured_select(len(lat), lambda i: lat[i], budget=16,
                              seed=0)
    assert (again[0], again[1]) == (win, win_s)
    assert again[2].n_evals == res.n_evals

    calls.clear()
    T.measured_select(len(lat), measure, budget=2, seed=0)
    assert len(set(calls)) <= 2           # budget caps the enumeration


def test_kernel_space_points_stay_in_range():
    import random
    space = T.KernelSpace(4)
    rng = random.Random(0)
    for _ in range(50):
        (i,) = space.sample(rng)
        assert 0 <= i < 4
        (j,) = space.mutate((i,), rng)
        assert 0 <= j < 4 and j != i


# ---------------------------------------------------------------------------
# tuned compilation: correctness, warm path, fallback
# ---------------------------------------------------------------------------
def test_tuned_compile_matches_heuristic_and_warms_from_db():
    ch, inputs, params = _case()
    with tempfile.TemporaryDirectory() as td:
        db_path = os.path.join(td, "db.json")
        heur = compile_chain(ch)
        tuned = compile_chain(ch, tune="auto", tune_db=db_path)
        a = heur(inputs, params)
        b = tuned(inputs, params)
        for k in a:
            assert jnp.allclose(a[k], b[k], rtol=1e-4, atol=1e-5)
        rep = tuned.tune_report
        assert rep["measured"] >= 1 and rep["from_db"] == 0
        # the tuned signature extends the heuristic one
        base = heur.signature.rsplit("|", 1)[0]
        assert tuned.signature.startswith(base)
        # warm compile: pure DB lookups, nothing re-measured, same program
        warm = compile_chain(ch, tune="auto", tune_db=db_path)
        wrep = warm.tune_report
        assert wrep["measured"] == 0
        assert wrep["from_db"] == rep["measured"]
        assert warm.signature == tuned.signature
        c = warm(inputs, params)
        for k in a:
            assert jnp.allclose(a[k], c[k], rtol=1e-4, atol=1e-5)


def test_corrupted_db_falls_back_to_heuristic_without_raising():
    ch, inputs, params = _case()
    with tempfile.TemporaryDirectory() as td:
        db_path = os.path.join(td, "db.json")
        # seed the DB, then corrupt every recorded decision
        compile_chain(ch, tune="auto", tune_db=db_path)
        with open(db_path) as f:
            raw = json.load(f)
        for key in raw["entries"]:
            raw["entries"][key] = {"backend": "", "latency_us": -1}
        with open(db_path, "w") as f:
            json.dump(raw, f)
        heur = compile_chain(ch)
        eng = compile_chain(ch, tune="readonly", tune_db=db_path)
        rep = eng.tune_report
        assert rep["from_db"] == 0 and rep["measured"] == 0
        assert rep["kept_heuristic"] >= 1
        assert eng.dispatch == heur.dispatch
        a, b = heur(inputs, params), eng(inputs, params)
        for k in a:
            assert jnp.allclose(a[k], b[k], rtol=1e-4, atol=1e-5)
        # ... and the quarantine is observable on a fresh load
        db = T.TuneDB.load(db_path)
        assert db.entries == {} and db.quarantined


def test_tune_rejects_unknown_mode():
    ch, _, _ = _case()
    with pytest.raises(ValueError):
        compile_chain(ch, tune="always")


# ---------------------------------------------------------------------------
# the no-DB fallback heuristic: M-axis regression
# ---------------------------------------------------------------------------
def _matmul_plan(ch, name):
    from repro.exec import lowering as low
    node = ch.nodes[name]
    classes = low.dim_classes(node)
    kshape = tuple(ch.shape_of(node.kernel))
    return node, low.match_grouped_matmul(node, classes, kshape)


def test_prefer_pallas_rejects_tiny_m_huge_k(monkeypatch):
    """(1, 4096) @ (4096, 4096) is a matvec: its Pallas grid degenerates
    to one padded M-row, so the heuristic must keep jnp even though K
    and N dwarf mxu_min (the pre-fix code only looked at K/N)."""
    from repro.exec.dispatch import _prefer_pallas_matmul
    monkeypatch.setenv("REPRO_FORCE_INTERPRET", "0")
    ch = Chain("matvec")
    x = ch.add_input("x", (1, 4096))
    ch.mark_output(L.fc(ch, x, out_f=4096, name="fc1"))
    node, plan = _matmul_plan(ch, "fc1")
    assert plan is not None
    assert not _prefer_pallas_matmul("auto", 128, plan, node)


def test_prefer_pallas_accepts_aligned_m(monkeypatch):
    from repro.exec.dispatch import _prefer_pallas_matmul
    monkeypatch.setenv("REPRO_FORCE_INTERPRET", "0")
    ch = Chain("fat")
    x = ch.add_input("x", (8, 512))
    ch.mark_output(L.fc(ch, x, out_f=512, name="fc1"))
    node, plan = _matmul_plan(ch, "fc1")
    assert _prefer_pallas_matmul("auto", 128, plan, node)
    # forced pallas bypasses the heuristic; small K/N still fails auto
    assert _prefer_pallas_matmul("pallas", 128, plan, node)
    ch2 = Chain("thin")
    x2 = ch2.add_input("x", (8, 64))
    ch2.mark_output(L.fc(ch2, x2, out_f=64, name="fc1"))
    node2, plan2 = _matmul_plan(ch2, "fc1")
    assert not _prefer_pallas_matmul("auto", 128, plan2, node2)


# ---------------------------------------------------------------------------
# lint audits the applied decisions
# ---------------------------------------------------------------------------
def test_lint_catches_tampered_tuned_meta():
    from repro.lint import lint_compiled
    ch, _, _ = _case()
    with tempfile.TemporaryDirectory() as td:
        eng = compile_chain(ch, tune="auto",
                            tune_db=os.path.join(td, "db.json"))
        assert not any(f.rule == "plan.tuned-contract"
                       for f in lint_compiled(eng))
        for st_ in eng.steps:
            if (st_.meta or {}).get("tuned"):
                st_.meta["tuned"]["backend"] = "oracle"
        assert any(f.rule == "plan.tuned-contract"
                   for f in lint_compiled(eng))


# ---------------------------------------------------------------------------
# serving: readonly tune on an empty DB is a safe no-op
# ---------------------------------------------------------------------------
def test_serve_tune_readonly_empty_db_keeps_config():
    from repro.launch.serve import Server
    srv = Server("tinyllama-1.1b", smoke=True, slots=2, max_len=32)
    cfg_before = srv.engine.cfg
    with tempfile.TemporaryDirectory() as td:
        rep = srv.engine.tune(srv.params, mode="readonly",
                              db_path=os.path.join(td, "db.json"))
    assert rep["applied"] == {}
    assert all(g["source"] == "heuristic"
               for g in rep["groups"].values())
    assert srv.engine.cfg == cfg_before
