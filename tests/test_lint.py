"""repro.lint: the static verifier itself.

Covers the three acceptance claims: the real corpus is clean at error
severity, every seeded mutant is caught by its intended rule with no
false positives on the clean bases, and the ``compile_chain(...,
lint=...)`` gate raises/records as documented.
"""
import json
import os
import subprocess
import sys

import pytest

from repro.core import layers as L
from repro.core.chain import Chain, Movement
from repro.lint import (FakeMesh, build_context, fake_mesh, lint_chain,
                        lint_compiled)
from repro.lint.findings import LintError, severity_rank
from repro.lint.registry import RULES, run_passes
from repro.lint.mutations import MUTANTS, corpus_ok, run_corpus


def small_chain(name="t"):
    c = Chain(name)
    x = c.add_input("x", (8, 64))
    h = L.fc(c, x, out_f=64, name="fc1")
    h = L.relu(c, h, name="act1")
    h = L.fc(c, h, out_f=64, name="fc2")
    c.mark_output(h)
    return c


# ---------------------------------------------------------------------------
# clean corpus
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mesh_spec", [None, "4x2"])
@pytest.mark.parametrize("zoo_name", ["AN", "MN"])
def test_zoo_reduced_clean(zoo_name, mesh_spec):
    from repro.models import cnn
    chain = cnn.build(zoo_name, reduced=True, batch=2)
    mesh = fake_mesh(mesh_spec) if mesh_spec else None
    rep = lint_chain(chain, mesh=mesh)
    assert rep.errors() == [], rep.to_text()


@pytest.mark.parametrize("mesh_spec", [None, "4x2"])
def test_lm_dense_clean(mesh_spec):
    from repro.lint.cli import _tiny_lm_cfg
    from repro.models import lm_chain
    chain = lm_chain.block_chain(_tiny_lm_cfg("dense"), 2, 8)
    mesh = fake_mesh(mesh_spec) if mesh_spec else None
    rep = lint_chain(chain, mesh=mesh)
    assert rep.errors() == [], rep.to_text()


# ---------------------------------------------------------------------------
# mutation corpus: every rule fires, two-sided
# ---------------------------------------------------------------------------
def test_mutation_corpus_all_caught():
    rows = run_corpus()
    assert len(rows) >= 10
    missed = [r["mutant"] for r in rows if not r["caught"]]
    fps = [r["mutant"] for r in rows if r["false_positive"]]
    dirty = [r["mutant"] for r in rows if r["clean_errors"]]
    assert not missed, f"mutants not flagged by their rule: {missed}"
    assert not fps, f"intended rule fired on the CLEAN base: {fps}"
    assert not dirty, f"clean bases with error findings: {dirty}"
    assert corpus_ok(rows)


def test_mutation_corpus_spans_all_layers():
    layers = {m[4] for m in MUTANTS}
    assert layers == {"chain", "plan", "shard"}
    # the PR 5 bug class is reconstructed explicitly
    rules = {m[1] for m in MUTANTS}
    assert "shard.missing-psum" in rules
    assert "shard.unconstrained-replication" in rules


def test_every_finding_rule_is_registered():
    rows = run_corpus()
    for row in rows:
        for rid in row["fired"]:
            assert rid in RULES, f"unregistered rule id {rid!r}"


# ---------------------------------------------------------------------------
# compile_chain gate
# ---------------------------------------------------------------------------
def test_compile_chain_lint_gate():
    from repro.exec.engine import compile_chain
    c = small_chain()
    c.add_param("w_unused", (4, 4))        # a warn-severity finding
    with pytest.raises(LintError) as ei:
        compile_chain(c, lint="warn")
    assert "chain.unused-param" in str(ei.value)
    eng = compile_chain(c, lint="error")   # warn does not trip "error"
    assert eng.lint_report is not None
    assert any(f.rule == "chain.unused-param"
               for f in eng.lint_report.findings)
    assert eng.lint_report.errors() == []


def test_compile_chain_lint_env(monkeypatch):
    from repro.exec.engine import compile_chain
    c = small_chain()
    c.add_param("w_unused", (4, 4))
    monkeypatch.setenv("REPRO_LINT", "warn")
    with pytest.raises(LintError):
        compile_chain(c)
    monkeypatch.setenv("REPRO_LINT", "off")
    eng = compile_chain(c)
    assert eng.lint_report is None


def test_lint_compiled_matches_lint_chain():
    from repro.exec.engine import compile_chain
    c = small_chain()
    eng = compile_chain(c, lint="error")
    rep = lint_compiled(eng)
    assert rep.errors() == []
    assert {f.rule for f in rep.findings} \
        == {f.rule for f in lint_chain(c).findings}


# ---------------------------------------------------------------------------
# individual passes
# ---------------------------------------------------------------------------
def test_noop_movement_flagged_but_real_movement_not():
    c = small_chain()
    mv = c.add(Movement("mv", input="fc2", perm=(1, 0),
                        out_shape=(64, 8)))
    c.outputs = [mv]
    rep = lint_chain(c)
    assert not any(f.rule == "chain.noop-movement" for f in rep.findings)
    c2 = small_chain()
    mv2 = c2.add(Movement("mv", input="fc2", perm=(0, 1),
                          out_shape=(8, 64)))
    c2.outputs = [mv2]
    rep2 = lint_chain(c2)
    assert any(f.rule == "chain.noop-movement" for f in rep2.findings)


def test_liveness_peak_handcrafted():
    # x(8,64) + fc1.w are live together at step 1: peak must cover both
    c = Chain("live")
    x = c.add_input("x", (4, 8))
    h = L.relu(c, x, name="r1")
    h = L.relu(c, h, name="r2")
    c.mark_output(h)
    rep = lint_chain(c)
    peaks = [f for f in rep.findings if f.rule == "chain.peak-live-bytes"]
    assert len(peaks) == 1
    # input + one relu output live simultaneously = 64 words; the other
    # relu never overlaps both
    assert peaks[0].data["peak_words"] == 64
    assert peaks[0].data["peak_bytes"] == 64 * 4


def test_shard_passes_on_fake_mesh():
    # column (N=512 divides model=2) and row (K=512, N=511) plans both
    # derive + verify clean without a single real device
    from repro.lint.mutations import base_col, base_row
    for builder, mode in ((base_col, "column"), (base_row, "row")):
        ctx = build_context(builder(), mesh=fake_mesh("4x2"))
        assert ctx.shard_plan is not None
        assert list(ctx.shard_plan.step_tp.values()) == [mode]
        rep = run_passes(ctx)
        assert rep.errors() == [], rep.to_text()


def test_fake_mesh_shape():
    m = fake_mesh("4x2")
    assert m.shape == {"data": 4, "model": 2}
    assert not m.empty and m.size == 8
    assert FakeMesh({}).empty


def test_severity_rank_ordering():
    assert severity_rank("info") < severity_rank("warn") \
        < severity_rank("error")
    with pytest.raises(ValueError):
        severity_rank("fatal")


def test_broken_chain_does_not_crash_lint():
    c = small_chain()
    c.outputs.append("ghost")
    rep = lint_chain(c)    # build_context would raise; lint_chain degrades
    assert any(f.rule == "chain.dangling-output" for f in rep.errors())


# ---------------------------------------------------------------------------
# CLI (subprocess; exercises the exit-code contract end to end)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_cli_exit_codes():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    clean = subprocess.run(
        [sys.executable, "-m", "repro.lint", "--format", "json"],
        capture_output=True, text=True, env=env)
    assert clean.returncode == 0, clean.stdout + clean.stderr
    summary = json.loads(clean.stdout.strip().splitlines()[-1])
    assert summary["clean"] and summary["counts"]["error"] == 0
    mut = subprocess.run(
        [sys.executable, "-m", "repro.lint", "--format", "json",
         "--mutants"],
        capture_output=True, text=True, env=env)
    assert mut.returncode == 1, mut.stdout + mut.stderr
    msum = json.loads(mut.stdout.strip().splitlines()[-1])
    assert msum["mutants"]["all_caught"]
    assert msum["mutants"]["false_positives"] == 0
