"""Serving regressions: continuous batching through repro.exec.serving.

Pin down the two historical corruption bugs (cross-slot cache writes under
global position bookkeeping; first-token seeding from another request's —
or no — logits) plus the corrected stats surface."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.serve import Request, Server, sequential_reference

ARCH = "tinyllama-1.1b"


def _mk(slots=2, max_len=48, **kw):
    return Server(ARCH, smoke=True, slots=slots, max_len=max_len, **kw)


def _prompts(n, rng=None, lo=2, hi=6):
    rng = rng or np.random.default_rng(0)
    srv_vocab = 256                     # tinyllama smoke vocab
    return [rng.integers(0, srv_vocab, rng.integers(lo, hi)).tolist()
            for _ in range(n)]


# ---------------------------------------------------------------------------
# _admit: first token comes from the request's OWN prefill logits
# ---------------------------------------------------------------------------
def test_admit_two_requests_one_call_seed_own_logits():
    """Two requests admitted in ONE call must each seed from their own
    prefill row (the old driver reused the last prompt's logits for all)."""
    prompts = _prompts(2, lo=3, hi=7)
    assert prompts[0] != prompts[1]
    srv = _mk(slots=2)
    for i, p in enumerate(prompts):
        srv.submit(Request(rid=i, prompt=list(p), max_new=1))
    srv.tick()                          # one _admit over both
    got = {r.rid: r.out for r in srv.finished}
    for i, p in enumerate(prompts):
        ref = _mk(slots=1)
        ref.submit(Request(rid=0, prompt=list(p), max_new=1))
        ref.run_until_drained()
        assert got[i] == ref.finished[0].out, f"request {i} seeded wrong"


def test_empty_prompt_bos_seeded_not_nameerror():
    """Empty prompt: defined behavior (BOS seed), and the first admission
    must not blow up on unbound logits (the old driver's NameError)."""
    srv = _mk(slots=2)
    srv.submit(Request(rid=0, prompt=[], max_new=3))
    rep = srv.run_until_drained()
    assert rep["requests"] == 1
    assert len(srv.finished[0].out) == 3
    assert srv.finished[0].prompt == [0]          # seeded BOS
    # matches an explicit-BOS request byte for byte
    ref = _mk(slots=2)
    ref.submit(Request(rid=0, prompt=[0], max_new=3))
    ref.run_until_drained()
    assert srv.finished[0].out == ref.finished[0].out


def test_empty_prompt_rejected_without_bos():
    srv = _mk(slots=1, bos_id=None)
    with pytest.raises(ValueError, match="empty prompt"):
        srv.submit(Request(rid=0, prompt=[], max_new=2))


def test_oversized_request_rejected_at_submit():
    srv = _mk(slots=1, max_len=16)
    with pytest.raises(ValueError, match="max_len"):
        srv.submit(Request(rid=0, prompt=[1] * 10, max_new=10))


def test_nonpositive_max_new_rejected_at_submit():
    srv = _mk(slots=1)
    for bad in (0, -3):
        with pytest.raises(ValueError, match="max_new"):
            srv.submit(Request(rid=0, prompt=[1, 2], max_new=bad))


# ---------------------------------------------------------------------------
# per-slot isolation: staggered == sequential, byte for byte
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_staggered_byte_identical_to_sequential():
    rng = np.random.default_rng(7)
    prompts = _prompts(6, rng)
    reqs = [Request(rid=i, prompt=list(p), max_new=6)
            for i, p in enumerate(prompts)]
    srv = _mk(slots=3, max_len=64)
    rep = srv.run_workload(reqs, stagger_ticks=2)
    assert rep["requests"] == 6
    got = {r.rid: r.out for r in srv.finished}
    ref = sequential_reference(
        ARCH, [Request(rid=i, prompt=list(p), max_new=6)
               for i, p in enumerate(prompts)], smoke=True, max_len=64)
    for i in range(6):
        assert got[i] == ref[i], f"request {i} diverged under churn"


@pytest.mark.slow
def test_slot_reuse_under_churn_does_not_exhaust_max_len():
    """Many short requests through few slots: per-slot positions must not
    accumulate globally (the old driver ran out of max_len and failed to
    drain)."""
    srv = _mk(slots=2, max_len=24)
    reqs = [Request(rid=i, prompt=[1 + i % 5, 2, 3], max_new=4)
            for i in range(10)]
    rep = srv.run_workload(reqs, stagger_ticks=1)
    assert rep["requests"] == 10
    assert all(len(r.out) == 4 for r in srv.finished)


def test_splice_and_reset_touch_only_their_slot():
    from repro.exec.serving import ServeEngine
    from repro import configs
    from repro.models import api

    cfg = configs.get(ARCH, smoke=True)
    model = api.build(cfg)
    eng = ServeEngine(model, slots=3, max_len=16)
    key = jax.random.PRNGKey(0)
    cache = {k: jax.random.normal(jax.random.fold_in(key, i),
                                  v.shape).astype(v.dtype)
             for i, (k, v) in enumerate(sorted(eng.init_state().items()))}
    cache["pos"] = jnp.array([3, 5, 7], jnp.int32)
    params = model.init(jax.random.PRNGKey(1))
    _lg, rows, _n = eng.prefill(params, [[4, 5]])
    spliced = eng.splice(cache, 1, rows, 0)
    for k in cache:
        ax = eng.axes[k]
        for s in (0, 2):                      # untouched slots, bitwise
            np.testing.assert_array_equal(
                np.asarray(jnp.take(spliced[k], s, axis=ax)),
                np.asarray(jnp.take(cache[k], s, axis=ax)), err_msg=k)
    assert int(spliced["pos"][1]) == 2        # spliced slot got its length
    reset = eng.reset_slot(spliced, 1)
    assert float(jnp.abs(jnp.take(reset["k"], 1, axis=1)).sum()) == 0.0
    np.testing.assert_array_equal(
        np.asarray(jnp.take(reset["k"], 0, axis=1)),
        np.asarray(jnp.take(spliced["k"], 0, axis=1)))


# ---------------------------------------------------------------------------
# stats: prefill+decode token counts, queue-wait and TTFT percentiles
# ---------------------------------------------------------------------------
def test_report_token_accounting_and_latency_split():
    srv = _mk(slots=2, max_len=48)
    reqs = [Request(rid=i, prompt=[1, 2, 3], max_new=4) for i in range(3)]
    rep = srv.run_workload(reqs, stagger_ticks=0)
    assert rep["requests"] == 3
    assert rep["tokens_prefill"] == 9
    assert rep["tokens_out"] == 12                 # 3 requests x max_new
    assert rep["tokens_decode"] == 9               # first token from prefill
    assert rep["tokens_total"] == rep["tokens_prefill"] + rep["tokens_out"]
    assert rep["tok_per_s"] > rep["tok_per_s_out"] > 0
    for k in ("p50_queue_wait_s", "p99_queue_wait_s", "p50_ttft_s",
              "p99_ttft_s", "p50_latency_s", "p99_latency_s"):
        assert k in rep and rep[k] >= 0.0
    # TTFT includes queue wait but precedes full completion
    assert rep["p50_queue_wait_s"] <= rep["p50_ttft_s"] <= \
        rep["p50_latency_s"]


def test_stats_zero_finished_requests_is_well_formed():
    """stats() must be callable at any point in the server's life; with
    nothing finished every percentile is 0.0 and nothing divides by zero
    (the old _report assumed a drained non-empty workload)."""
    srv = _mk(slots=2)
    rep = srv.stats()
    assert rep["requests"] == 0
    assert rep["tokens_out"] == 0
    for k in ("p50_queue_wait_s", "p99_queue_wait_s", "p50_ttft_s",
              "p99_ttft_s", "p50_latency_s", "p99_latency_s"):
        assert rep[k] == 0.0
    assert rep["tok_per_s"] >= 0.0 and rep["tok_per_s_out"] >= 0.0
    # still well-formed mid-flight (in progress, nothing finished yet)
    srv.submit(Request(rid=0, prompt=[1, 2], max_new=6))
    srv.tick()
    mid = srv.stats()
    assert mid["requests"] == 0 and mid["p99_ttft_s"] == 0.0
    srv.run_until_drained()


def test_stats_single_finished_request_p50_equals_p99():
    """One sample is its own p50 AND p99 (the percentile() contract) —
    the old percentile index arithmetic was only exercised at n >= 2."""
    srv = _mk(slots=2)
    srv.submit(Request(rid=0, prompt=[1, 2, 3], max_new=2))
    rep = srv.run_until_drained()
    assert rep["requests"] == 1
    req = srv.finished[0]
    ttft = req.first_token_at - req.submitted_at
    assert rep["p50_ttft_s"] == rep["p99_ttft_s"] == ttft
    assert rep["p50_latency_s"] == rep["p99_latency_s"] \
        == req.done_at - req.submitted_at
    assert rep["p50_queue_wait_s"] == rep["p99_queue_wait_s"] \
        == req.admitted_at - req.submitted_at


# ---------------------------------------------------------------------------
# trace: the replayable request-lifecycle schema + stats agreement
# ---------------------------------------------------------------------------
def test_trace_round_trip_agrees_with_stats(tmp_path):
    from repro.obs import Tracer, load_trace
    from repro.obs.report import summarize

    tr = Tracer()
    srv = _mk(slots=2, tracer=tr)
    reqs = [Request(rid=i, prompt=[1 + i, 2, 3], max_new=3)
            for i in range(4)]
    rep = srv.run_workload(reqs, stagger_ticks=1)
    path = tmp_path / "serve.json"
    tr.write(str(path))
    out = summarize(load_trace(str(path)))
    assert out["requests"] == rep["requests"] == 4
    # bit-for-bit: both route through repro.obs.metrics.percentile
    for k in ("p50_ttft_s", "p99_ttft_s", "p50_queue_wait_s",
              "p99_queue_wait_s", "p50_latency_s", "p99_latency_s"):
        assert out[k] == rep[k], k
    assert out["tokens_out"] == rep["tokens_out"]
    assert out["slot_utilization"] is not None
    assert 0.0 < out["slot_utilization"] <= 1.0


def test_trace_request_lifecycle_schema(tmp_path):
    """The replayable schema: per request one `request` span carrying the
    tick indices and measured waits, with queue/prefill/decode children
    parented onto it, plus the per-tick slots counter track."""
    from repro.obs import Tracer, load_trace

    tr = Tracer()
    srv = _mk(slots=2, tracer=tr)
    reqs = [Request(rid=i, prompt=[1, 2], max_new=2) for i in range(3)]
    srv.run_workload(reqs, stagger_ticks=2)
    path = tmp_path / "serve.jsonl"
    tr.write(str(path))
    trace = load_trace(str(path))

    req_spans = [s for s in trace.spans
                 if s["cat"] == "request" and s["name"] == "request"]
    assert len(req_spans) == 3
    assert sorted(s["args"]["rid"] for s in req_spans) == [0, 1, 2]
    for s in req_spans:
        a = s["args"]
        for k in ("rid", "prompt_len", "max_new", "out_len",
                  "submit_tick", "admit_tick", "done_tick",
                  "queue_wait_s", "ttft_s", "latency_s"):
            assert k in a, k
        # the sim replay clock: tick indices are orderable integers
        assert 0 <= a["submit_tick"] <= a["admit_tick"] <= a["done_tick"]
        kids = [c for c in trace.spans if c.get("parent") == s["id"]]
        assert sorted(c["name"] for c in kids) \
            == ["decode", "prefill", "queue"]

    ticks = [s for s in trace.spans if s["cat"] == "serve"]
    assert len(ticks) == srv.ticks
    slot_samples = [c for c in trace.counters if c["name"] == "slots"]
    assert len(slot_samples) == srv.ticks
    assert all({"active", "queued"} <= set(c["values"])
               for c in slot_samples)
    assert max(c["values"]["active"] for c in slot_samples) <= srv.slots

    # engine spans ride along: decode/splice under cat "engine", prefill
    # under compile/execute (cold vs warm program, like the profiled chain)
    names = {s["name"] for s in trace.spans}
    assert {"engine.prefill", "engine.decode", "engine.splice"} <= names
    prefills = [s for s in trace.spans if s["name"] == "engine.prefill"]
    assert {s["cat"] for s in prefills} <= {"compile", "execute"}


def test_untraced_server_has_no_tracer_and_metrics_schema():
    srv = _mk(slots=2)
    assert srv.tracer is None
    srv.submit(Request(rid=0, prompt=[1, 2], max_new=2))
    srv.run_until_drained()
    d = srv.metrics_dict()
    assert d["schema"] == "repro.obs.metrics" and d["version"] == 1
    fam = d["metrics"]["serve_requests"]["series"]
    assert fam[0]["value"] == 1.0


# ---------------------------------------------------------------------------
# resilient-mode status accounting (satellite of the resilience PR; the
# fault-path behaviors themselves live in tests/test_chaos.py)
# ---------------------------------------------------------------------------
def test_stats_status_counts_sum_to_submitted():
    """Every submitted request is exactly one of: terminal (ok/expired/
    shed/failed), queued, or active — at ANY point in the server's life."""
    from repro.launch.serve import ResilienceConfig

    srv = _mk(slots=2, resilience=ResilienceConfig())

    def invariant():
        st = srv.stats()
        assert (sum(st["statuses"].values()) + st["queued"] + st["active"]
                == st["requests_submitted"])

    invariant()                                      # zero submitted
    feasible = [Request(rid=i, prompt=[1 + i, 2, 3], max_new=3)
                for i in range(3)]
    doomed = [Request(rid=10 + i, prompt=[5, 6], max_new=4,
                      deadline_ticks=1) for i in range(2)]
    for r in feasible + doomed:
        srv.submit(r)
    invariant()                                      # all still queued
    while srv.queue or any(r is not None for r in srv.slot_req):
        srv.tick()
        invariant()                                  # mid-flight, every tick
    st = srv.stats()
    assert st["statuses"]["ok"] == 3
    assert st["statuses"]["shed"] == 2
    assert st["requests_submitted"] == 5
    assert st["queued"] == 0 and st["active"] == 0


def test_stats_well_formed_when_every_request_is_shed():
    from repro.launch.serve import ResilienceConfig

    srv = _mk(slots=2, resilience=ResilienceConfig())
    n = 4
    for i in range(n):
        # max_new=6 needs 5 post-admission ticks; deadline 2 is infeasible
        srv.submit(Request(rid=i, prompt=[1, 2], max_new=6,
                           deadline_ticks=2))
    report = srv.run_until_drained()
    assert report["statuses"] == {"ok": 0, "expired": 0, "shed": n,
                                  "failed": 0}
    assert report["requests"] == n and report["requests_submitted"] == n
    # no ok requests -> empty percentile inputs -> 0.0 (never NaN/raise)
    for k in ("p50_queue_wait_s", "p99_ttft_s", "p50_latency_s"):
        assert report[k] == 0.0
    assert report["tokens_out"] == 0
    # the labeled serve_requests counter agrees with the stats surface
    d = srv.metrics_dict()
    shed = [s for s in d["metrics"]["serve_requests"]["series"]
            if s["labels"].get("status") == "shed"]
    assert shed and shed[0]["value"] == float(n)
