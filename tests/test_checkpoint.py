"""CheckpointManager corruption handling: restore must fall back to the
newest INTEGRITY-VERIFIED older step when the latest checkpoint on disk
is truncated or bit-flipped, and must return None (never garbage) when
every checkpoint is corrupt. ``verified_meta`` walks back identically
without loading arrays — the serving snapshot/resume path depends on it."""
import glob
import json
import os

import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _tree(step):
    return {"w": np.full((4, 3), float(step), np.float32),
            "b": np.arange(6, dtype=np.int32) + step}


def _mgr(tmp_path, steps=(1, 2, 3)):
    mgr = CheckpointManager(str(tmp_path), keep_n=10, async_write=False)
    for s in steps:
        mgr.save(s, _tree(s), extra={"tag": f"step{s}"})
    return mgr


def _leaf_files(tmp_path, step):
    files = sorted(glob.glob(os.path.join(str(tmp_path), f"step_{step}",
                                          "*.npy")))
    assert files
    return files


def _truncate(path):
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(size // 2)


def _bit_flip(path):
    with open(path, "r+b") as f:
        f.seek(-1, os.SEEK_END)
        byte = f.read(1)
        f.seek(-1, os.SEEK_END)
        f.write(bytes([byte[0] ^ 0xFF]))


@pytest.mark.parametrize("corrupt", [_truncate, _bit_flip],
                         ids=["truncate", "bit_flip"])
def test_restore_falls_back_over_corrupt_latest(tmp_path, corrupt):
    mgr = _mgr(tmp_path)
    corrupt(_leaf_files(tmp_path, 3)[0])
    step, tree = mgr.restore(_tree(0))
    assert step == 2                      # newest VERIFIED, not newest
    np.testing.assert_array_equal(tree["w"], _tree(2)["w"])
    np.testing.assert_array_equal(tree["b"], _tree(2)["b"])


def test_restore_walks_back_over_multiple_corrupt_steps(tmp_path):
    mgr = _mgr(tmp_path)
    _bit_flip(_leaf_files(tmp_path, 3)[0])
    _truncate(_leaf_files(tmp_path, 2)[1])
    step, tree = mgr.restore(_tree(0))
    assert step == 1
    np.testing.assert_array_equal(tree["w"], _tree(1)["w"])


def test_restore_returns_none_when_all_corrupt(tmp_path):
    mgr = _mgr(tmp_path)
    for s in (1, 2, 3):
        _bit_flip(_leaf_files(tmp_path, s)[0])
    step, tree = mgr.restore(_tree(0))
    assert step is None
    # the caller's tree comes back untouched, not half-loaded garbage
    np.testing.assert_array_equal(tree["w"], _tree(0)["w"])


def test_restore_skips_missing_meta_and_shape_mismatch(tmp_path):
    mgr = _mgr(tmp_path)
    os.remove(os.path.join(str(tmp_path), "step_3", "meta.json"))
    # shape drift: rewrite a leaf with the wrong shape but a "valid" file
    f = _leaf_files(tmp_path, 2)[0]
    np.save(f, np.zeros((2, 2), np.float32))
    step, _tree_out = mgr.restore(_tree(0))
    assert step == 1


def test_verified_meta_walks_back_and_carries_extra(tmp_path):
    mgr = _mgr(tmp_path)
    step, meta = mgr.verified_meta()
    assert (step, meta["tag"]) == (3, "step3")
    _truncate(_leaf_files(tmp_path, 3)[0])
    step, meta = mgr.verified_meta()
    assert (step, meta["tag"]) == (2, "step2")
    for s in (1, 2):
        _bit_flip(_leaf_files(tmp_path, s)[0])
    assert mgr.verified_meta() == (None, None)


def test_verified_meta_rejects_tampered_meta_json(tmp_path):
    mgr = _mgr(tmp_path, steps=(1, 2))
    meta_path = os.path.join(str(tmp_path), "step_2", "meta.json")
    with open(meta_path) as f:
        meta = json.load(f)
    next(iter(meta["manifest"].values()))["crc32"] ^= 0x1
    with open(meta_path, "w") as f:
        json.dump(meta, f)
    step, meta = mgr.verified_meta()
    assert (step, meta["tag"]) == (1, "step1")
