"""analysis.roofline: HLO shape-byte parsing edge cases."""
from repro.analysis.roofline import _shape_bytes, collective_bytes


def test_shape_bytes_scalar():
    # a scalar f32[] has one element
    assert _shape_bytes("f32[]") == 4
    assert _shape_bytes("bf16[]") == 2


def test_shape_bytes_simple():
    assert _shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert _shape_bytes("s8[3]") == 3


def test_shape_bytes_tuple_sums_elements():
    assert _shape_bytes("(f32[2,3], bf16[4])") == 2 * 3 * 4 + 4 * 2


def test_shape_bytes_unknown_dtype_skipped():
    assert _shape_bytes("opaque[8]") == 0
    assert _shape_bytes("(opaque[8], f32[2])") == 8


def test_collective_bytes_done_not_double_counted():
    hlo = """
  %ag = f32[16,8] all-gather(%p), dimensions={0}
  %ar-start = f32[4,4] all-reduce-start(%q)
  %ar-done = f32[4,4] all-reduce-done(%ar-start)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 16 * 8 * 4
    # -start counted once, -done skipped
    assert out["all-reduce"] == 4 * 4 * 4
    assert out["reduce-scatter"] == 0
