"""Batched (leading-batch) execution mode of the compiled chain engine:
differential vs the per-sample compiled path, bucketed compile-cache
accounting, and the exec.batch primitives."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.interpreter import ChainExecutor, init_chain_params
from repro.exec import batch_bucket, compile_chain, pad_leading, unpad_leading
from repro.models import cnn, lm_chain
from repro.models.common import ModelConfig

TOL = dict(rtol=1e-4, atol=1e-4)


def _tiny_cfg(**kw):
    base = dict(name="tiny", family="dense", n_layers=1, d_model=16,
                n_heads=2, n_kv_heads=2, d_ff=32, vocab=64)
    base.update(kw)
    return ModelConfig(**base)


def _batched(inputs, n, seed=0):
    key = jax.random.PRNGKey(seed)
    return {k: jax.random.normal(jax.random.fold_in(key, i),
                                 (n,) + tuple(v.shape), jnp.float32)
            for i, (k, v) in enumerate(sorted(inputs.items()))}


def _assert_rows_match_per_sample(eng, batched, params):
    got = eng(batched, params)
    n = next(iter(batched.values())).shape[0]
    for j in range(n):
        one = eng({k: v[j] for k, v in batched.items()}, params)
        for o in one:
            np.testing.assert_allclose(
                np.asarray(got[o][j]), np.asarray(one[o]),
                err_msg=f"row {j} output {o}", **TOL)


# ---------------------------------------------------------------------------
# bucketing primitives
# ---------------------------------------------------------------------------
def test_batch_bucket_ladder():
    assert [batch_bucket(n) for n in (1, 2, 3, 4, 5, 7, 8, 9)] == \
        [1, 2, 4, 4, 8, 8, 8, 16]
    assert batch_bucket(3, min_bucket=8) == 8
    with pytest.raises(ValueError):
        batch_bucket(0)


def test_pad_unpad_roundtrip():
    x = {"a": jnp.arange(6).reshape(3, 2), "b": jnp.ones((3,))}
    p = pad_leading(x, 4)
    assert p["a"].shape == (4, 2) and p["b"].shape == (4,)
    assert float(p["a"][3].sum()) == 0.0
    u = unpad_leading(p, 3)
    np.testing.assert_array_equal(np.asarray(u["a"]), np.asarray(x["a"]))


# ---------------------------------------------------------------------------
# batched vs per-sample compiled execution
# ---------------------------------------------------------------------------
def test_lm_block_batched_matches_per_sample():
    ch = lm_chain.block_chain(_tiny_cfg(), 2, 8)
    ex = ChainExecutor(ch)
    params = ex.init_params(jax.random.PRNGKey(0))
    eng = compile_chain(ch)
    _assert_rows_match_per_sample(eng, _batched(ch_inputs(ch), 3), params)


def ch_inputs(chain):
    return cnn.random_inputs(chain, 1)


def test_batched_matches_oracle_rows():
    """Batched rows vs the ORACLE per sample (not just engine-vs-engine)."""
    ch = lm_chain.block_chain(_tiny_cfg(), 2, 8)
    ex = ChainExecutor(ch)
    params = ex.init_params(jax.random.PRNGKey(0))
    eng = compile_chain(ch)
    batched = _batched(ch_inputs(ch), 2)
    got = eng(batched, params)
    for j in range(2):
        ref = ex({k: v[j] for k, v in batched.items()}, params)
        for o in ref:
            np.testing.assert_allclose(np.asarray(got[o][j]),
                                       np.asarray(ref[o]), err_msg=o, **TOL)


@pytest.mark.slow
@pytest.mark.parametrize("name", list(cnn.ZOO))
def test_zoo_batched_matches_per_sample(name):
    chain = cnn.build(name, reduced=True, batch=1)
    params = init_chain_params(chain, jax.random.PRNGKey(0))
    eng = compile_chain(chain)
    _assert_rows_match_per_sample(eng, _batched(ch_inputs(chain), 2), params)


def test_batched_keep_all():
    ch = lm_chain.block_chain(_tiny_cfg(), 2, 8)
    ex = ChainExecutor(ch)
    params = ex.init_params(jax.random.PRNGKey(0))
    eng = compile_chain(ch)
    batched = _batched(ch_inputs(ch), 2)
    got = eng(batched, params, keep_all=True)
    one = eng({k: v[0] for k, v in batched.items()}, params, keep_all=True)
    for o in one:
        got_o = got[o]
        if got_o.ndim == one[o].ndim:        # params broadcast un-batched
            continue
        np.testing.assert_allclose(np.asarray(got_o[0]), np.asarray(one[o]),
                                   err_msg=o, **TOL)


# ---------------------------------------------------------------------------
# bucketed compile cache: #compiles == #buckets, not #batch-sizes
# ---------------------------------------------------------------------------
def test_bucketed_cache_compile_count():
    ch = lm_chain.block_chain(_tiny_cfg(), 2, 8)
    params = ChainExecutor(ch).init_params(jax.random.PRNGKey(0))
    eng = compile_chain(ch)
    sizes = [1, 2, 3, 4, 5, 3, 2, 5, 4, 1]
    for n in sizes:
        eng(_batched(ch_inputs(ch), n, seed=n), params)
    want_buckets = sorted({batch_bucket(n) for n in sizes})
    assert eng.batch_buckets == want_buckets == [1, 2, 4, 8]
    assert eng.batch_compiles == len(want_buckets)
    # exact-shape calls bypass the batched cache entirely
    eng(ch_inputs(ch), params)
    assert eng.batch_compiles == len(want_buckets)


def test_batched_shape_validation():
    ch = lm_chain.block_chain(_tiny_cfg(), 2, 8)
    params = ChainExecutor(ch).init_params(jax.random.PRNGKey(0))
    eng = compile_chain(ch)
    with pytest.raises(ValueError, match="batch-extended"):
        eng({"x": jnp.zeros((2, 8, 17))}, params)      # trailing mismatch
    with pytest.raises(ValueError, match="batch-extended"):
        eng({"x": jnp.zeros((3, 2, 2, 8, 16))}, params)  # two extra axes


def test_plan_signature_stable():
    ch = lm_chain.block_chain(_tiny_cfg(), 2, 8)
    a = compile_chain(ch)
    b = compile_chain(lm_chain.block_chain(_tiny_cfg(), 2, 8))
    assert a.signature and a.signature == b.signature
    c = compile_chain(lm_chain.block_chain(_tiny_cfg(), 2, 16))
    assert c.signature != a.signature
