"""Batched (leading-batch) execution mode of the compiled chain engine:
differential vs the per-sample compiled path, bucketed compile-cache
accounting, and the exec.batch primitives."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.interpreter import ChainExecutor, init_chain_params
from repro.exec import batch_bucket, compile_chain, pad_leading, unpad_leading
from repro.models import cnn, lm_chain
from repro.models.common import ModelConfig

TOL = dict(rtol=1e-4, atol=1e-4)


def _tiny_cfg(**kw):
    base = dict(name="tiny", family="dense", n_layers=1, d_model=16,
                n_heads=2, n_kv_heads=2, d_ff=32, vocab=64)
    base.update(kw)
    return ModelConfig(**base)


def _batched(inputs, n, seed=0):
    key = jax.random.PRNGKey(seed)
    return {k: jax.random.normal(jax.random.fold_in(key, i),
                                 (n,) + tuple(v.shape), jnp.float32)
            for i, (k, v) in enumerate(sorted(inputs.items()))}


def _assert_rows_match_per_sample(eng, batched, params):
    got = eng(batched, params)
    n = next(iter(batched.values())).shape[0]
    for j in range(n):
        one = eng({k: v[j] for k, v in batched.items()}, params)
        for o in one:
            np.testing.assert_allclose(
                np.asarray(got[o][j]), np.asarray(one[o]),
                err_msg=f"row {j} output {o}", **TOL)


# ---------------------------------------------------------------------------
# bucketing primitives
# ---------------------------------------------------------------------------
def test_batch_bucket_ladder():
    assert [batch_bucket(n) for n in (1, 2, 3, 4, 5, 7, 8, 9)] == \
        [1, 2, 4, 4, 8, 8, 8, 16]
    assert batch_bucket(3, min_bucket=8) == 8
    with pytest.raises(ValueError):
        batch_bucket(0)


def test_pad_unpad_roundtrip():
    x = {"a": jnp.arange(6).reshape(3, 2), "b": jnp.ones((3,))}
    p = pad_leading(x, 4)
    assert p["a"].shape == (4, 2) and p["b"].shape == (4,)
    assert float(p["a"][3].sum()) == 0.0
    u = unpad_leading(p, 3)
    np.testing.assert_array_equal(np.asarray(u["a"]), np.asarray(x["a"]))


# -- property tests (hypothesis; self-skip when it is not installed) --------
@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(min_value=1, max_value=5), min_size=1,
                max_size=4),
       st.integers(min_value=1, max_value=6),
       st.integers(min_value=0, max_value=8))
def test_pad_unpad_roundtrip_property(shape, n, extra):
    """pad_leading/unpad_leading round-trip for arbitrary leading shapes:
    rows survive bit-for-bit, pad rows are zeros, unpad restores n."""
    bucket = n + extra
    rng = np.random.default_rng(n * 131 + extra)
    x = {"a": rng.normal(size=(n, *shape)).astype(np.float32),
         "b": rng.integers(0, 9, size=(n,)).astype(np.int32)}
    p = pad_leading(x, bucket)
    for k in x:
        assert p[k].shape == (bucket,) + x[k].shape[1:]
        np.testing.assert_array_equal(np.asarray(p[k][:n]), x[k])
        assert float(jnp.abs(p[k][n:]).sum()) == 0.0       # inert pad rows
    u = unpad_leading(p, n)
    for k in x:
        np.testing.assert_array_equal(np.asarray(u[k]), x[k])


@settings(max_examples=80, deadline=None)
@given(st.integers(min_value=1, max_value=4096),
       st.integers(min_value=1, max_value=64))
def test_batch_bucket_contract_property(n, min_bucket):
    """batch_bucket contract: >= n, >= min_bucket, exactly min_bucket
    times a power of two, monotone, idempotent — so a data-axis-sized
    floor guarantees every bucket divides the mesh axis."""
    b = batch_bucket(n, min_bucket)
    assert b >= n and b >= min_bucket
    q, r = divmod(b, min_bucket)
    assert r == 0 and q & (q - 1) == 0                     # power of two
    if min_bucket == 1:
        assert b & (b - 1) == 0
    assert batch_bucket(b, min_bucket) == b                # idempotent
    if n > 1:
        assert batch_bucket(n - 1, min_bucket) <= b        # monotone
    assert b % min_bucket == 0                             # mesh-divisible


# ---------------------------------------------------------------------------
# batched vs per-sample compiled execution
# ---------------------------------------------------------------------------
def test_lm_block_batched_matches_per_sample():
    ch = lm_chain.block_chain(_tiny_cfg(), 2, 8)
    ex = ChainExecutor(ch)
    params = ex.init_params(jax.random.PRNGKey(0))
    eng = compile_chain(ch)
    _assert_rows_match_per_sample(eng, _batched(ch_inputs(ch), 3), params)


def ch_inputs(chain):
    return cnn.random_inputs(chain, 1)


def test_batched_matches_oracle_rows():
    """Batched rows vs the ORACLE per sample (not just engine-vs-engine)."""
    ch = lm_chain.block_chain(_tiny_cfg(), 2, 8)
    ex = ChainExecutor(ch)
    params = ex.init_params(jax.random.PRNGKey(0))
    eng = compile_chain(ch)
    batched = _batched(ch_inputs(ch), 2)
    got = eng(batched, params)
    for j in range(2):
        ref = ex({k: v[j] for k, v in batched.items()}, params)
        for o in ref:
            np.testing.assert_allclose(np.asarray(got[o][j]),
                                       np.asarray(ref[o]), err_msg=o, **TOL)


@pytest.mark.slow
@pytest.mark.parametrize("name", list(cnn.ZOO))
def test_zoo_batched_matches_per_sample(name):
    chain = cnn.build(name, reduced=True, batch=1)
    params = init_chain_params(chain, jax.random.PRNGKey(0))
    eng = compile_chain(chain)
    _assert_rows_match_per_sample(eng, _batched(ch_inputs(chain), 2), params)


def test_batched_keep_all():
    ch = lm_chain.block_chain(_tiny_cfg(), 2, 8)
    ex = ChainExecutor(ch)
    params = ex.init_params(jax.random.PRNGKey(0))
    eng = compile_chain(ch)
    batched = _batched(ch_inputs(ch), 2)
    got = eng(batched, params, keep_all=True)
    one = eng({k: v[0] for k, v in batched.items()}, params, keep_all=True)
    for o in one:
        got_o = got[o]
        if got_o.ndim == one[o].ndim:        # params broadcast un-batched
            continue
        np.testing.assert_allclose(np.asarray(got_o[0]), np.asarray(one[o]),
                                   err_msg=o, **TOL)


# ---------------------------------------------------------------------------
# bucketed compile cache: #compiles == #buckets, not #batch-sizes
# ---------------------------------------------------------------------------
def test_bucketed_cache_compile_count():
    ch = lm_chain.block_chain(_tiny_cfg(), 2, 8)
    params = ChainExecutor(ch).init_params(jax.random.PRNGKey(0))
    eng = compile_chain(ch)
    sizes = [1, 2, 3, 4, 5, 3, 2, 5, 4, 1]
    for n in sizes:
        eng(_batched(ch_inputs(ch), n, seed=n), params)
    want_buckets = sorted({batch_bucket(n) for n in sizes})
    assert eng.batch_buckets == want_buckets == [1, 2, 4, 8]
    assert eng.batch_compiles == len(want_buckets)
    # exact-shape calls bypass the batched cache entirely
    eng(ch_inputs(ch), params)
    assert eng.batch_compiles == len(want_buckets)


def test_batched_shape_validation():
    ch = lm_chain.block_chain(_tiny_cfg(), 2, 8)
    params = ChainExecutor(ch).init_params(jax.random.PRNGKey(0))
    eng = compile_chain(ch)
    with pytest.raises(ValueError, match="batch-extended"):
        eng({"x": jnp.zeros((2, 8, 17))}, params)      # trailing mismatch
    with pytest.raises(ValueError, match="batch-extended"):
        eng({"x": jnp.zeros((3, 2, 2, 8, 16))}, params)  # two extra axes


def test_plan_signature_stable():
    ch = lm_chain.block_chain(_tiny_cfg(), 2, 8)
    a = compile_chain(ch)
    b = compile_chain(lm_chain.block_chain(_tiny_cfg(), 2, 8))
    assert a.signature and a.signature == b.signature
    c = compile_chain(lm_chain.block_chain(_tiny_cfg(), 2, 16))
    assert c.signature != a.signature
