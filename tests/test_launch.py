"""Launch-layer tests: sharding rules, train step on a multi-device debug
mesh (subprocess with virtual devices), serving driver, dry-run machinery."""
import json
import os
import subprocess
import sys

import jax
import pytest

from repro import configs
from repro.launch.mesh import dp_axes, make_debug_mesh


def test_dp_axes_and_debug_mesh():
    mesh = make_debug_mesh(1, 1)
    assert dp_axes(mesh) == ("data",)


def test_param_sharding_rules_guarded():
    """Divisibility guards: hymba vocab 32001 must fall back to replicated
    vocab dim; dense dims shard 2-D."""
    from jax.sharding import PartitionSpec as P
    from repro.launch import sharding as shlib
    from repro.models import api

    # single-device mesh but with axis sizes (1,1): everything divides -> all
    # rules apply; check the specs structurally instead of axis sizes
    mesh = make_debug_mesh(1, 1)
    cfg = configs.get("hymba-1.5b", smoke=False)
    model = api.build(cfg)
    ps = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    sh = shlib.param_shardings(cfg, mesh, ps)
    assert sh["embed"].spec == P("model", "data")     # 32001 % 1 == 0 here
    assert sh["layers"]["wq"].spec == P(None, "data", "model")
    assert sh["layers"]["ln1"].spec == P()


def test_guard_drops_nondivisible_axes():
    from jax.sharding import PartitionSpec as P
    from repro.launch.sharding import guard

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    # vocab 32001 not divisible by 16 -> replicated; 32000 divisible
    assert guard(FakeMesh, ("model", "data"), (32001, 2048)) == \
        P(None, "data")
    assert guard(FakeMesh, ("model", "data"), (32000, 2048)) == \
        P("model", "data")


@pytest.mark.slow
def test_train_smoke_loss_falls(tmp_path):
    from repro.launch.train import train

    report = train("tinyllama-1.1b", steps=40, smoke=True, batch=4, seq=32,
                   peak_lr=2e-3, ckpt_dir=str(tmp_path))
    losses = report["losses"]
    assert len(losses) == 40
    # random-token data: compare window means (single steps are noise)
    first = sum(losses[:8]) / 8
    last = sum(losses[-8:]) / 8
    assert last < first, (first, last)


@pytest.mark.slow
def test_train_survives_injected_failure(tmp_path):
    from repro.launch.train import train

    boom = {"armed": True}

    def fault(step):
        if step == 12 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("node died")

    report = train("tinyllama-1.1b", steps=20, smoke=True, batch=2, seq=16,
                   ckpt_dir=str(tmp_path), ckpt_every=5, fault_hook=fault)
    assert report["restarts"] == 1
    assert report["final_step"] == 20


@pytest.mark.slow
def test_serve_continuous_batching():
    from repro.launch.serve import Request, Server

    srv = Server("tinyllama-1.1b", smoke=True, slots=2, max_len=48)
    for i in range(3):
        srv.submit(Request(rid=i, prompt=[1, 2, 3], max_new=4))
    report = srv.run_until_drained()
    assert report["requests"] == 3
    assert report["tokens_out"] >= 12
    outs = [r.out for r in srv.finished]
    assert all(len(o) == 4 for o in outs)


def test_collective_parser():
    from repro.analysis.roofline import collective_bytes

    hlo = """
  %ag = f32[128,256]{1,0} all-gather(%x), replica_groups={}
  %ar.1 = bf16[64]{0} all-reduce(%y), to_apply=%add
  %ag2-start = (f32[8], f32[16]) all-gather-start(%z)
  %ag2-done = f32[16]{0} all-gather-done(%ag2-start)
  %rs = f32[32,32]{1,0} reduce-scatter(%w), dimensions={0}
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 128 * 256 * 4 + (8 + 16) * 4
    assert out["all-reduce"] == 64 * 2
    assert out["reduce-scatter"] == 32 * 32 * 4


def test_dryrun_import_is_side_effect_free():
    """Importing launch.dryrun must not mutate XLA_FLAGS (the hillclimb
    env-purity contract, extended to the dry-run: the fake-device flag is
    set in main(), before the first jax INITIALIZATION — module-level jax
    imports do not lock the device count)."""
    proc = subprocess.run(
        [sys.executable, "-c",
         "import os, sys; before = os.environ.get('XLA_FLAGS');"
         "sys.path.insert(0, 'src');"
         "import jax;"          # jax first, as in any test process
         "import repro.launch.dryrun as dr;"
         "assert os.environ.get('XLA_FLAGS') == before, 'env mutated';"
         "assert callable(dr.main)"],
        capture_output=True, text=True,
        cwd=os.path.join(os.path.dirname(__file__), ".."), timeout=240)
    assert proc.returncode == 0, proc.stderr


@pytest.mark.slow
def test_dryrun_smoke_cell_subprocess():
    """End-to-end dry-run of one small cell in a subprocess (own XLA_FLAGS),
    asserting the JSON record has the roofline terms."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out_dir = os.path.join(os.path.dirname(__file__), "..", "results",
                           "dryrun")
    target = os.path.join(out_dir,
                          "tinyllama-1.1b__decode_32k__single.json")
    if not os.path.exists(target):
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch",
             "tinyllama-1.1b", "--shape", "decode_32k", "--mesh", "single"],
            env=env, capture_output=True, text=True, timeout=1200)
        assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    with open(target) as f:
        rec = json.load(f)
    assert rec["status"] == "ok"
    assert rec["chips"] == 256
    roof = rec["roofline"]
    assert roof["compute_s"] > 0 and roof["memory_s"] > 0
    assert roof["dominant"] in ("compute", "memory", "collective")


def test_input_specs_cover_all_cells():
    for arch, shape, ok, why in configs.all_cells(include_skipped=True):
        cfg = configs.get(arch)
        spec = configs.input_specs(arch, shape, cfg)
        assert spec, (arch, shape)
        for leaf in jax.tree.leaves(spec):
            assert hasattr(leaf, "shape") and hasattr(leaf, "dtype")
