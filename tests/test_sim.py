"""Cycle-level simulator (repro.sim): schedule invariants, closed-form vs
explicit trace agreement, bandwidth monotonicity, analytic cross-validation.
"""
import dataclasses

import pytest

from repro.core import accelerators as acc
from repro.core import layers as L
from repro.core.chain import Chain
from repro.core.costmodel import MISALIGN_FACTOR, gconv_chain_cost
from repro.core.fusion import fuse_chain
from repro.core.gconv import DimSpec, GConv
from repro.core.mapping import map_gconv, tile_sizes
from repro.sim.engine import simulate_chain, simulate_node
from repro.sim.schedule import TileSchedule
from repro.sim.validate import validate_pair

SPECS = [acc.eyeriss(), acc.tpu_like(), acc.eager_pruning(), acc.nlr(),
         acc.dnnweaver()]


def small_gconvs():
    return [
        GConv("conv", (DimSpec("C", ng=2, nop=8),
                       DimSpec("H", nopc=14, nks=3),
                       DimSpec("W", nopc=14, nks=3)),
              input="x", kernel="k"),
        GConv("strided", (DimSpec("B", ng=4),
                          DimSpec("C", nop=16, nks=8),
                          DimSpec("H", nopc=9, nks=5, stride=2)),
              input="x", kernel="k"),
        GConv("grouped", (DimSpec("A", ng=3, nop=5, nopc=7, nks=2),),
              input="x", kernel="k"),
        GConv("fc_like", (DimSpec("C", nop=64, nks=32),
                          DimSpec("T", ng=6, nopc=4)),
              input="x", kernel="k"),
    ]


def conv_chain():
    chain = Chain("c")
    x = chain.add_input("x", (4, 16, 28, 28))
    a = L.conv2d(chain, x, out_c=32, k=3, pad=1, bias=False)
    r = L.relu(chain, a)
    b = L.conv2d(chain, r, out_c=32, k=3, pad=1, bias=False)
    chain.mark_output(b)
    return chain


# ---------------------------------------------------------------------------
# schedule invariants
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("spec", SPECS, ids=lambda s: s.name)
def test_tile_totals_match_node_totals(spec):
    """Per-tile word totals equal the node's analytic movement exactly, and
    tile MAC slots cover the node's effectual MACs."""
    for g in small_gconvs():
        m = map_gconv(g, spec)
        sched = TileSchedule(g, m)
        mov = m.movement()
        tot = sched.total_words()
        for d in ("I", "K", "O"):
            assert tot[d] == pytest.approx(mov[d]), (g.name, d)
        assert sched.total_compute_cycles() >= m.cycles()
        assert sched.total_mac_slots() >= g.macs
        # ceil-splitting never over-issues by more than ~2x per covered loop
        assert sched.total_mac_slots() <= 16 * g.macs
        ts = sched.structure
        for d in ("I", "K", "O"):
            assert ts.strides[d] * ts.reloads[d] == ts.n_steps


@pytest.mark.parametrize("spec", SPECS, ids=lambda s: s.name)
def test_resident_tiles_fit_scratchpads(spec):
    """The scratchpad-resident region behind each reuse pointer fits the
    per-PE capacity (sliding input entries stream and are exempt)."""
    for g in small_gconvs():
        m = map_gconv(g, spec)
        ts = m.tile_structure()
        for d in ("I", "K", "O"):
            ptr = ts.pointers[d]
            if ptr < 0:
                continue
            inside = [e for e in m.temporal[: ptr + 1]
                      if not (e.sliding and d == "I")]
            assert tile_sizes(inside, g)[d] <= spec.ls[d], (g.name, d)


def test_explicit_trace_ordering():
    g = small_gconvs()[0]
    spec = acc.eyeriss()
    sched = TileSchedule(g, map_gconv(g, spec))
    steps = list(sched.steps())
    assert len(steps) == sched.n_steps
    assert [s.index for s in steps] == list(range(sched.n_steps))
    # every step computes; step 0 fills both in-streams; O drains on
    # window boundaries only
    assert steps[0].loads.get("I", 0) > 0
    assert steps[0].loads.get("K", 0) > 0
    s_o = sched.strides["O"]
    for s in steps:
        assert s.compute_cycles == sched.compute_per_step
        assert ("O" in s.drains) == ((s.index + 1) % s_o == 0)


# ---------------------------------------------------------------------------
# engine: closed-form aggregation == explicit tile-by-tile reference
# ---------------------------------------------------------------------------
def _reference_double_buffer(g, spec, mapping, aligned=True):
    """Naive per-tile double-buffer timing loop over the explicit trace."""
    sched = TileSchedule(g, mapping)
    steps = list(sched.steps())

    def cyc(d, w):
        bw = max(1, spec.gb_bandwidth.get(d, 1))
        pen = (MISALIGN_FACTOR
               if d == "I" and not aligned and spec.ls.get("I", 1) > 1
               else 1.0)
        return w / bw * pen

    total = max((cyc(d, w) for d, w in steps[0].loads.items()), default=0.0)
    for t, stp in enumerate(steps):
        prefetch = 0.0
        if t + 1 < len(steps):
            prefetch = max((cyc(d, w)
                            for d, w in steps[t + 1].loads.items()),
                           default=0.0)
        writeback = 0.0
        if t > 0 and steps[t - 1].drains:
            writeback = max(cyc(d, w)
                            for d, w in steps[t - 1].drains.items())
        total += max(stp.compute_cycles, prefetch, writeback)
    total += max((cyc(d, w) for d, w in steps[-1].drains.items()),
                 default=0.0)
    return total


@pytest.mark.parametrize("spec", SPECS, ids=lambda s: s.name)
@pytest.mark.parametrize("aligned", [True, False])
def test_closed_form_equals_explicit_reference(spec, aligned):
    for g in small_gconvs():
        m = map_gconv(g, spec)
        if TileSchedule(g, m).n_steps > 200_000:
            continue
        ref = _reference_double_buffer(g, spec, m, aligned=aligned)
        got = simulate_node(g, spec, mapping=map_gconv(g, spec),
                            aligned=aligned).total_cycles
        assert got == pytest.approx(ref, rel=1e-9), (g.name, spec.name)


def test_stall_accounting_is_exhaustive():
    """fill + drain + stalls account for every non-compute cycle."""
    for g in small_gconvs():
        for spec in SPECS:
            ns = simulate_node(g, spec, mapping=map_gconv(g, spec))
            assert ns.stall_cycles == pytest.approx(
                ns.total_cycles - ns.compute_cycles, rel=1e-9, abs=1e-6)
            assert ns.utilization <= 1.0 + 1e-9


def test_chain_stall_accounting_is_exhaustive():
    """compute + exposed stalls == total at chain level too (handoff-hidden
    cycles leave both the total and the stall count; movement pseudo-nodes
    book their transfer as stall time)."""
    chain = Chain("c")
    x = chain.add_input("x", (4, 16, 8, 8))
    a = L.conv2d(chain, x, out_c=8, k=3, pad=1, bias=False)
    v = L.view(chain, a, (4, 8 * 8 * 8))          # Movement pseudo-node
    f = L.fc(chain, v, out_f=16)
    chain.mark_output(f)
    for spec in SPECS:
        cs = simulate_chain(chain, spec)
        assert any(n.kind == "movement" for n in cs.nodes)
        assert cs.stall_cycles == pytest.approx(
            cs.total_cycles - cs.compute_cycles, rel=1e-9, abs=1e-6)
        assert cs.utilization <= 1.0 + 1e-9


# ---------------------------------------------------------------------------
# stall monotonicity in GB bandwidth
# ---------------------------------------------------------------------------
def _with_bandwidth_scale(spec, factor):
    return dataclasses.replace(
        spec, gb_bandwidth={k: max(1, int(v * factor))
                            for k, v in spec.gb_bandwidth.items()})


@pytest.mark.parametrize("spec", SPECS, ids=lambda s: s.name)
def test_sim_cycles_monotone_in_bandwidth(spec):
    for g in small_gconvs():
        totals = []
        for factor in (0.5, 1, 2, 4):
            s = _with_bandwidth_scale(spec, factor)
            totals.append(simulate_node(g, s, mapping=map_gconv(g, s))
                          .total_cycles)
        for slower, faster in zip(totals, totals[1:]):
            assert faster <= slower * (1 + 1e-9), (g.name, totals)


# ---------------------------------------------------------------------------
# analytic cross-validation
# ---------------------------------------------------------------------------
def test_sim_node_at_least_analytic_latency():
    """Per node, tile-granularity timing can only add to the analytic
    max(compute, load): Σ_t max(a_t, b_t) >= max(Σa, Σb)."""
    chain = conv_chain()
    fused = fuse_chain(chain)[0]
    for spec in SPECS:
        analytic = gconv_chain_cost(fused, spec)
        sim = simulate_chain(fused, spec, fuse=False)
        for ns, nc in zip(sim.nodes, analytic.nodes):
            assert ns.name == nc.name
            assert ns.total_cycles >= nc.latency - 1e-6, (spec.name, ns.name)


def test_sim_energy_and_movement_match_analytic():
    """Same mappings, same movement equations, same energy constants:
    the two engines must agree exactly on words and energy."""
    chain = conv_chain()
    fused = fuse_chain(chain)[0]
    for spec in SPECS:
        analytic = gconv_chain_cost(fused, spec)
        sim = simulate_chain(fused, spec, fuse=False)
        assert sim.movement_words == pytest.approx(analytic.movement_words,
                                                   rel=1e-9)
        assert sim.energy == pytest.approx(analytic.energy, rel=1e-9)


def test_shared_bus_contention_never_faster():
    chain = conv_chain()
    for spec in (acc.eyeriss(), acc.tpu_like()):
        ports = simulate_chain(chain, spec, contention="ports").total_cycles
        shared = simulate_chain(chain, spec, contention="shared").total_cycles
        assert shared >= ports - 1e-6


def test_fusion_groups_reported():
    sim = simulate_chain(conv_chain(), acc.eyeriss(), fuse=True)
    members = [m for ms in sim.fused_groups.values() for m in ms]
    assert any("relu" in m for m in members)
    # fused members are gone from the simulated node list
    names = {n.name for n in sim.nodes}
    assert not any(m in names for m in members)


def test_validate_pair_small_network():
    from repro.models import cnn

    chain = cnn.build("AN")
    for spec in (acc.eyeriss(), acc.tpu_like()):
        row, sim = validate_pair(chain, spec)
        assert row["above_compute_bound"]
        assert row["energy_drift"] < 1e-6
        assert row["movement_drift"] < 1e-6
        assert 1.0 <= row["cycles_ratio"] < 4.0, row
        assert any(n.kind == "gconv" and n.stall_cycles >= 0
                   for n in sim.nodes)


@pytest.mark.slow
def test_zoo_cross_validation_agreement():
    """Fig.-14-grade sweep: the sim stays above the analytic compute lower
    bound and within a stated factor of the analytic latency on the zoo."""
    from repro.sim.validate import cross_validate

    rows, summary = cross_validate(accels=("ER", "TPU", "EP"))
    assert summary["all_above_compute_bound"]
    assert summary["max_energy_drift"] < 1e-6
    assert summary["max_movement_drift"] < 1e-6
    assert summary["max_cycles_ratio"] < 3.0


# ---------------------------------------------------------------------------
# stats arithmetic + the unified metrics schema (repro.obs.metrics)
# ---------------------------------------------------------------------------
def test_node_stats_arithmetic_direct():
    """stall_cycles / utilization on hand-built numbers — the derived
    properties, not the simulator."""
    from repro.sim.stats import NodeSimStats

    ns = NodeSimStats(name="n", kind="gconv", tiles=10,
                      compute_cycles=80.0, total_cycles=100.0,
                      fill_cycles=5.0, drain_cycles=3.0,
                      stalls={"x": 12.0, "k": 8.0},
                      movement={"x": 64.0, "y": 32.0}, energy=7.5)
    assert ns.stall_cycles == pytest.approx(20.0)
    assert ns.utilization == pytest.approx(0.8)

    # zero-total edges: an all-hidden gconv is fully utilized; a movement
    # pseudo-node with no cycles did no useful array work
    assert NodeSimStats(name="g", kind="gconv").utilization == 1.0
    assert NodeSimStats(name="m", kind="movement").utilization == 0.0
    assert NodeSimStats(name="g", kind="gconv").stall_cycles == 0.0


def test_chain_stats_handoff_subtraction_direct():
    from repro.sim.stats import ChainSimStats, NodeSimStats

    a = NodeSimStats(name="a", kind="gconv", compute_cycles=60.0,
                     total_cycles=100.0, stalls={"x": 40.0})
    b = NodeSimStats(name="b", kind="gconv", compute_cycles=30.0,
                     total_cycles=50.0, stalls={"k": 20.0})
    cs = ChainSimStats(chain_name="c", accel="ER", nodes=[a, b],
                       handoff_overlap_cycles=10.0)
    # the overlap leaves BOTH the total and the stall count, keeping
    # compute + stalls == total exactly
    assert cs.total_cycles == pytest.approx(140.0)
    assert cs.stall_cycles == pytest.approx(50.0)
    assert cs.compute_cycles + cs.stall_cycles \
        == pytest.approx(cs.total_cycles)
    assert cs.utilization == pytest.approx(90.0 / 140.0)
    # degenerate: no nodes -> no cycles -> utilization defined as 1.0
    empty = ChainSimStats(chain_name="e", accel="ER", nodes=[])
    assert empty.total_cycles == 0.0 and empty.utilization == 1.0


def test_summary_consistent_with_metrics_registry():
    """summary() is DERIVED from to_metrics() — the flat dict and the
    versioned schema cannot drift. Checked on a real simulated chain."""
    from repro.obs.metrics import Metrics

    chain = conv_chain()
    spec = acc.eyeriss()
    cs = simulate_chain(chain, spec)

    s = cs.summary()
    reg = cs.to_metrics()
    lbl = dict(chain=cs.chain_name, accel=cs.accel)
    assert s["cycles"] == reg.value("sim_chain_cycles", phase="total", **lbl)
    assert s["energy"] == reg.value("sim_chain_energy", **lbl)
    assert s["stall_cycles"] == pytest.approx(
        reg.value("sim_chain_cycles", phase="stall", **lbl), abs=0.05)
    d = reg.to_dict()
    assert d["schema"] == "repro.obs.metrics" and d["version"] == 1

    n = cs.nodes[0]
    nsum = n.summary()
    nreg = n.to_metrics()
    nlbl = dict(node=n.name, kind=n.kind)
    assert nsum["cycles"] == nreg.value("sim_cycles", phase="total", **nlbl)
    assert nsum["compute_cycles"] == nreg.value("sim_cycles",
                                                phase="compute", **nlbl)
    assert nsum["utilization"] == pytest.approx(n.utilization, abs=1e-4)
    assert nsum["tiles"] == n.tiles
    assert set(nsum["stalls"]) == set(n.stalls)
    assert set(nsum["movement"]) == set(n.movement)

    # per_node=True emits node series alongside chain series in one registry
    both = cs.to_metrics(Metrics(), per_node=True)
    assert both.value("sim_cycles", phase="total", node=n.name,
                      kind=n.kind, **lbl) == n.total_cycles
