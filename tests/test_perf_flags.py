"""§Perf levers must be numerically transparent: every perf_flag variant
equals the baseline implementation bit-for-bit (or to fp tolerance)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import api, hymba, transformer
from repro.models.moe import moe_ffn


def _moe_setup():
    cfg = configs.get("olmoe-1b-7b", smoke=True)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    p0 = {k: v[0] for k, v in params["layers"].items()}
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(2), (2, 16, cfg.d_model))
    return cfg, p0, x


@pytest.mark.parametrize("flag", ["moe_sort", "moe_gather_combine"])
def test_moe_variants_match_baseline(flag):
    cfg, p0, x = _moe_setup()
    y0, a0 = moe_ffn(cfg, p0, x)
    y1, a1 = moe_ffn(cfg.replace(perf_flags=(flag,)), p0, x)
    np.testing.assert_allclose(y0, y1, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(a0), float(a1), rtol=1e-6)


def test_gqa_norepeat_decode_matches():
    cfg = configs.get("tinyllama-1.1b", smoke=True)
    m0 = api.build(cfg)
    m1 = api.build(cfg.replace(perf_flags=("gqa_norepeat",)))
    params = m0.init(jax.random.PRNGKey(0))
    cache = m0.serve_state_init(2, 16)
    tok = jnp.asarray([[3], [5]], jnp.int32)
    l0, c0 = m0.decode_step(params, tok, cache)
    l1, c1 = m1.decode_step(params, tok, cache)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                               rtol=1e-5, atol=1e-5)


def test_hymba_ssd_matches_scan_and_grad():
    cfg = configs.get("hymba-1.5b", smoke=True)
    params = hymba.init_params(cfg, jax.random.PRNGKey(0))
    p0 = {k: v[0] for k, v in params["layers"].items()}
    B, T = 2, 128
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.d_model))
    st = jnp.zeros((B, cfg.n_heads, cfg.hd, cfg.ssm_state))
    y0, s0 = hymba.ssm_heads(cfg, p0, x, st)
    cfg2 = cfg.replace(perf_flags=("ssm_chunked",))
    y1, s1 = hymba.ssm_heads(cfg2, p0, x, st)
    np.testing.assert_allclose(y0, y1, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(s0, s1, rtol=1e-4, atol=1e-5)
    # gradients flow through the SSD form
    g = jax.grad(lambda xx: hymba.ssm_heads(cfg2, p0, xx, st)[0].sum())(x)
    assert np.isfinite(np.asarray(g)).all()


@pytest.mark.slow
def test_perf_flag_train_step_still_learns():
    """A full train step with all train-side levers on remains finite."""
    from repro.launch.train import train

    # monkeypatch the smoke config with levers
    import repro.configs.olmoe_1b_7b as mod
    orig = mod.SMOKE
    try:
        mod.SMOKE = orig.replace(
            perf_flags=("moe_sort", "moe_gather_combine"))
        report = train("olmoe-1b-7b", steps=6, smoke=True, batch=2, seq=16,
                       peak_lr=1e-3)
        assert all(np.isfinite(l) for l in report["losses"])
    finally:
        mod.SMOKE = orig
