"""Heuristic-vs-tuned compiled-engine benchmarks (the autotuner cell).

``tune``       — per-zoo-network steady-state wall time of the heuristic
                 plan vs ``compile_chain(tune="auto")`` against the
                 persisted DB under ``results/tune/``, plus the tuned
                 winners per fusion group and the warm-cache compile
                 overhead (tuned compile with a fully-populated DB vs
                 plain heuristic compile). Seeds the tuner rows of
                 ``results/benchmarks.json``.
``tune_micro`` — one smoke network against a throwaway DB, run by the
                 FAST CI tier; ``benchmarks.run`` exits nonzero when the
                 tuned plan regresses past noise vs the heuristic, the
                 warm-cache compile overhead exceeds its 5% budget, or
                 tuned outputs diverge from the heuristic plan.
"""
from __future__ import annotations

import tempfile
import time


def _zoo_case(name, batch=2):
    import jax

    from repro.core.interpreter import init_chain_params
    from repro.models import cnn

    chain = cnn.build(name, reduced=True, batch=batch)
    params = init_chain_params(chain, jax.random.PRNGKey(0))
    return chain, cnn.random_inputs(chain), params


def _paired_steady_us(eng_a, eng_b, inputs, params, iters=10, repeats=6):
    """Steady-state noise floors for two engines sampled interleaved.

    Wall-clock cost on a shared box drifts over seconds, so timing the
    two engines in separate blocks biases whichever ran in the quieter
    window. Alternating A/B blocks (order flipped each repeat) exposes
    both engines to the same interference, and the per-engine min then
    estimates the same-window noise floor for each.
    """
    import jax

    jax.block_until_ready(eng_a(inputs, params))   # warmup / compile
    jax.block_until_ready(eng_b(inputs, params))

    def block(eng):
        t0 = time.perf_counter()
        for _ in range(iters):
            jax.block_until_ready(eng(inputs, params))
        return (time.perf_counter() - t0) / iters * 1e6

    a_s, b_s = [], []
    for i in range(repeats):
        if i % 2:
            b_s.append(block(eng_b))
            a_s.append(block(eng_a))
        else:
            a_s.append(block(eng_a))
            b_s.append(block(eng_b))
    return min(a_s), min(b_s)


def _max_err(a, b):
    import jax.numpy as jnp

    err = 0.0
    for k in a:
        err = max(err, float(jnp.max(jnp.abs(
            jnp.asarray(a[k], jnp.float32) - jnp.asarray(b[k],
                                                         jnp.float32)))))
    return err


def _bench_net(name, db_path, batch=2, iters=10):
    import jax

    from repro.exec import compile_chain

    chain, inputs, params = _zoo_case(name, batch=batch)
    heur = compile_chain(chain)
    tuned = compile_chain(chain, tune="auto", tune_db=db_path)
    err = _max_err(jax.block_until_ready(heur(inputs, params)),
                   jax.block_until_ready(tuned(inputs, params)))
    heur_us, tuned_us = _paired_steady_us(heur, tuned, inputs, params,
                                          iters=iters)
    rep = tuned.tune_report or {}
    winners = {g: m.get("backend") for g, m in rep.get("groups",
                                                       {}).items()}
    speedup = heur_us / max(tuned_us, 1e-9)
    return dict(
        net=name,
        heuristic_us=round(heur_us, 1),
        tuned_us=round(tuned_us, 1),
        speedup=round(speedup, 2),
        _speedup_raw=speedup,      # unrounded, for gates; stripped below
        max_err=round(err, 6),
        winners=winners,
        measured=rep.get("measured", 0),
        from_db=rep.get("from_db", 0),
    )


def _warm_overhead(chain, db_path, compiles=20):
    """Warm-cache tune cost as a ratio over the heuristic compile.

    The DB must already hold every group for ``chain`` (the caller's
    cold tuned compile guarantees that), so the warm ``tune_plan`` stage
    is pure lookups — and it is the *only* thing
    ``compile_chain(tune="auto")`` adds over a plain compile. Timing
    that ~100us stage under its own timer resolves it where
    differencing two ~4ms full-compile timings cannot (compile cost
    swings far more than the quantity under test on a busy box). GC is
    held off during sampling, ``timeit``-style, so a shared collection
    cycle isn't attributed to one sample; each quantity keeps its noise
    floor — interference only ever adds time.
    """
    import gc

    from repro.exec import compile_chain
    from repro.exec.dispatch import plan_chain
    from repro.exec.partition import partition_chain
    from repro.exec.tune import tune_plan

    compile_chain(chain, tune="auto", tune_db=db_path)  # prime caches
    fused, _report, _parts = partition_chain(chain)
    base = tune = 1e9
    gc.collect()
    gc.disable()
    try:
        for _ in range(compiles):
            t0 = time.perf_counter()
            compile_chain(chain)
            base = min(base, time.perf_counter() - t0)
            plan = plan_chain(fused)         # fresh plan; not timed
            t0 = time.perf_counter()
            tune_plan(fused, plan, mode="auto", db_path=db_path)
            tune = min(tune, time.perf_counter() - t0)
    finally:
        gc.enable()
    return 1.0 + tune / max(base, 1e-12)


def tune_speedup():
    """Full cell: heuristic-vs-tuned sweep over the seven zoo CNNs
    against the committed DB under ``results/tune/``."""
    import numpy as np

    from repro.exec.tune import default_db_path
    from repro.models import cnn

    db_path = default_db_path()
    rows = []
    for name in cnn.ZOO:
        rows.append(_bench_net(name, db_path))
    speedups = [r.pop("_speedup_raw") for r in rows]
    geomean = float(np.exp(np.mean(np.log(np.maximum(speedups, 1e-9)))))
    # warm-cache compile overhead on one representative net (its groups
    # were just persisted by the sweep above)
    chain, _, _ = _zoo_case("MN")
    overhead = _warm_overhead(chain, db_path)
    summary = dict(
        networks=len(rows),
        geomean_speedup=round(geomean, 3),
        min_speedup=round(min(speedups), 3),
        worst_err=max(r["max_err"] for r in rows),
        warm_compile_overhead=round(overhead - 1.0, 4),
        target="tuned geomean > 1.0 over the heuristic plan; "
               "warm-cache compile overhead < 5%",
        met=bool(geomean > 1.0 and (overhead - 1.0) < 0.05),
    )
    return rows, summary


def tune_micro():
    """FAST-tier smoke: one network, throwaway DB; fails CI on a tuned
    regression past noise, warm-compile overhead >= 5%, or divergence."""
    with tempfile.TemporaryDirectory() as td:
        db_path = td + "/tune_db.json"
        r = _bench_net("MN", db_path, batch=2, iters=20)
        raw = r.pop("_speedup_raw")
        chain, _, _ = _zoo_case("MN", batch=2)
        overhead = _warm_overhead(chain, db_path, compiles=20)
        # a gate this tight on a shared box needs a confirmation run: a
        # single bad reading (load spike spanning a whole measurement
        # window) must not fail CI, while a genuine regression fails
        # both readings
        if not (raw > 0.95 and (overhead - 1.0) < 0.05):
            r2 = _bench_net("MN", db_path, batch=2, iters=20)
            raw = max(raw, r2.pop("_speedup_raw"))
            overhead = min(overhead,
                           _warm_overhead(chain, db_path, compiles=20))
            r["max_err"] = max(r["max_err"], r2["max_err"])
            r["speedup"] = round(raw, 2)
            r["tuned_us"] = min(r["tuned_us"], r2["tuned_us"])
            r["heuristic_us"] = min(r["heuristic_us"],
                                    r2["heuristic_us"])
    summary = dict(
        speedup=r["speedup"],
        max_err=r["max_err"],
        warm_compile_overhead=round(overhead - 1.0, 4),
        # the tuner must never make the plan slower (0.95 absorbs CI
        # timer noise — winners are picked from measurements on this
        # same box, so a genuine regression shows up well below that)
        ok=bool(raw > 0.95 and (overhead - 1.0) < 0.05
                and r["max_err"] <= 1e-3),
    )
    return [r], summary
