"""Chaos benchmarks: the serving resilience layer under injected faults.

``chaos_micro`` — FAST-tier CI gate (via ``benchmarks.run``). Two checks,
both of which must hold for ``ok``:

  * **chaos differential** — a staggered smoke workload is served through
    a fixed deterministic fault spec injecting four fault kinds (a raised
    device error, NaN'd logits, a corrupted KV-cache slot, a latency
    spike) plus two requests with infeasible SLO deadlines. Every fault
    in the spec must actually fire, the deadline-infeasible requests must
    be shed, every other request must end ``ok``, and every ``ok``
    output must be byte-identical to the fault-free
    ``sequential_reference`` — the recovery contract (bounded retries,
    watchdog quarantine + replay-from-prompt) is bit-exactness, not
    approximate correctness.
  * **resilience overhead** — the SAME fault-free workload on a warm
    plain server vs a warm server with the resilience layer enabled (no
    chaos): the resilient arm must cost no more than
    ``MAX_RESILIENCE_OVERHEAD`` extra per driver tick. Estimator:
    interleaved workload runs (arms alternate run by run), every tick
    individually timed, per-arm MEDIAN tick duration compared — hundreds
    of tick samples per arm make the median immune to the scheduler
    bursts that make whole-workload wall times flaky at the 5% scale.
"""
from __future__ import annotations

import time

ARCH = "tinyllama-1.1b"

# the resilience layer (retry wrappers, SLO scan, NaN watchdog) must be
# near-free when nothing goes wrong; the acceptance bar is <= 5% on the
# fault-free serve path (ISSUE 7)
MAX_RESILIENCE_OVERHEAD = 0.05
OVERHEAD_PAIRS = 12

# the fixed differential spec: raise + nan + corrupt + latency across the
# engine's decode/prefill sites and the driver tick loop (indices chosen
# inside the busy window of the 8-request stagger-1 workload)
CHAOS_SPEC = ("decode@3=raise;decode@5=nan:1;decode@8=corrupt:0;"
              "prefill@2=raise;tick@1=latency:0.002")
REQUIRED_KINDS = {"raise", "nan", "corrupt", "latency"}


def _workload(n, vocab, max_new, seed=0, deadline=None):
    import numpy as np

    from repro.launch.serve import Request

    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, vocab,
                                        rng.integers(2, 6)).tolist(),
                    max_new=max_new, deadline_ticks=deadline)
            for i in range(n)]


def _clone(reqs):
    from repro.launch.serve import Request

    return [Request(rid=r.rid, prompt=list(r.prompt), max_new=r.max_new,
                    deadline_ticks=r.deadline_ticks) for r in reqs]


def _chaos_differential():
    """Serve through CHAOS_SPEC; compare ok outputs byte-for-byte against
    the fault-free sequential reference."""
    from repro.launch.serve import (ResilienceConfig, Request, Server,
                                    sequential_reference)
    from repro.runtime.chaos import ChaosInjector, ChaosPlan

    chaos = ChaosInjector(ChaosPlan.parse(CHAOS_SPEC))
    srv = Server(ARCH, smoke=True, slots=4, max_len=96,
                 resilience=ResilienceConfig(), chaos=chaos)
    reqs = _workload(8, srv.cfg.vocab, max_new=8)
    # two deadline-infeasible requests ride along: max_new=8 needs 7 ticks
    # after admission, a 3-tick deadline can never be met -> shed up front
    doomed = [Request(rid=100 + i, prompt=[1 + i, 2, 3], max_new=8,
                      deadline_ticks=3) for i in range(2)]
    submit = _clone(reqs)
    submit[2:2] = _clone(doomed)
    report = srv.run_workload(submit, stagger_ticks=1)
    got = {r.rid: r.out for r in srv.finished if r.status == "ok"}
    ref = sequential_reference(ARCH, reqs, smoke=True, max_len=96)
    identical = (set(got) == {r.rid for r in reqs}
                 and all(got[r.rid] == ref[i] for i, r in enumerate(reqs)))
    st = report["statuses"]
    row = dict(
        check="chaos_differential",
        spec=CHAOS_SPEC,
        kinds_fired=sorted(chaos.kinds_fired()),
        faults_unfired=chaos.remaining,
        statuses=st,
        retries=report["retries"],
        quarantines=report["quarantines"],
        identical_to_reference=bool(identical),
    )
    ok = bool(identical
              and chaos.remaining == 0
              and REQUIRED_KINDS <= chaos.kinds_fired()
              and st["ok"] == len(reqs)
              and st["shed"] == len(doomed)
              and st["failed"] == 0 and st["expired"] == 0)
    row["ok"] = ok
    return row, ok


def _resilience_overhead():
    """Warm fault-free workload: plain driver vs resilience enabled,
    per-arm median TICK duration (see module docstring for why tick
    granularity, not whole-workload wall time)."""
    from repro.launch.serve import ResilienceConfig, Server

    plain = Server(ARCH, smoke=True, slots=4, max_len=96)
    resil = Server(ARCH, smoke=True, slots=4, max_len=96,
                   resilience=ResilienceConfig())
    reqs = _workload(8, plain.cfg.vocab, max_new=8)

    def one(srv, durs=None):
        srv.reset_state()
        for r in _clone(reqs):
            srv.submit(r)
        while srv.queue or any(x is not None for x in srv.slot_req):
            t0 = time.perf_counter()
            srv.tick()
            if durs is not None:
                durs.append(time.perf_counter() - t0)

    for srv in (plain, resil):               # pay the compiles up front
        one(srv)

    plains, resils = [], []
    for i in range(OVERHEAD_PAIRS):
        if i % 2:
            one(resil, resils)
            one(plain, plains)
        else:
            one(plain, plains)
            one(resil, resils)

    p = sorted(plains)[len(plains) // 2]
    r = sorted(resils)[len(resils) // 2]
    return p, r, r / p - 1.0


def chaos_micro():
    """FAST-tier gate: recovered outputs must be byte-identical to the
    fault-free reference, and the fault-free resilient path must stay
    within the 5% overhead budget."""
    diff_row, diff_ok = _chaos_differential()
    plain_s, resil_s, overhead = _resilience_overhead()
    if overhead > MAX_RESILIENCE_OVERHEAD:
        # same anti-flake policy as obs_micro: one re-measure, keep the
        # smaller — noise passes on the retry, a real hot-path regression
        # fails twice
        p2, r2, o2 = _resilience_overhead()
        if o2 < overhead:
            plain_s, resil_s, overhead = p2, r2, o2
    rows = [diff_row,
            dict(check="resilience_overhead",
                 plain_tick_ms=round(plain_s * 1e3, 3),
                 resilient_tick_ms=round(resil_s * 1e3, 3),
                 overhead=round(overhead, 4),
                 budget=MAX_RESILIENCE_OVERHEAD,
                 ok=bool(overhead <= MAX_RESILIENCE_OVERHEAD))]
    summary = dict(
        identical_to_reference=diff_row["identical_to_reference"],
        kinds_fired=diff_row["kinds_fired"],
        statuses=diff_row["statuses"],
        resilience_overhead=round(overhead, 4),
        ok=bool(diff_ok and overhead <= MAX_RESILIENCE_OVERHEAD),
    )
    return rows, summary
