"""Benchmark harness: one entry per paper table/figure + kernel
microbenchmarks + the roofline table from the dry-run artifacts.

    PYTHONPATH=src python -m benchmarks.run [--only table1,fig14,...]
                                            [--engine analytic|sim]

Two evaluation engines cover the zoo x accelerator grid:
  * ``analytic`` (default) — the paper's closed-form cost model
    (Eqs. 6-10, repro.core.costmodel); runs every table/figure.
  * ``sim`` — the cycle-level tiled simulator (repro.sim); runs the
    analytic-vs-sim cross-validation and writes per-node
    stall/utilization breakdowns to results/sim/.

Prints ``name,us_per_call,derived`` CSV lines per benchmark plus a summary
block comparing each reproduced number to the paper's claim.
"""
from __future__ import annotations

import argparse
import json
import os
import time

# "simval" (the cycle-level sim sweep) is not in ALL: the default analytic
# run stays pure closed-form; select it with --engine sim or --only simval.
# "exec_micro" / "dse_micro" / "serve_micro" / "exec_sharded_micro" /
# "obs_micro" (the FAST-tier smokes) likewise only run via --only.
ALL = ("table1", "fig12", "fig13", "fig14", "fig15", "fusion", "fig18",
       "fig20", "kernels", "roofline", "exec", "exec_sharded", "dse",
       "serve", "syssim", "lint", "tune")

MICRO = ("exec_micro", "dse_micro", "serve_micro", "exec_sharded_micro",
         "obs_micro", "chaos_micro", "syssim_micro", "lint_micro",
         "tune_micro")


def _run(name, fn):
    t0 = time.perf_counter()
    rows, summary = fn()
    dt = (time.perf_counter() - t0) * 1e6
    print(f"\n=== {name} ===")
    for r in rows[:12]:
        print("  " + json.dumps(r))
    if len(rows) > 12:
        print(f"  ... ({len(rows)} rows total)")
    print(f"  summary: {json.dumps(summary)}")
    print(f"{name},{dt:.0f},{json.dumps(summary)}")
    return rows, summary


def bench_kernels():
    """Kernel wall-times (interpret mode on CPU -> correctness-scale only;
    the derived column is max |err| vs the jnp oracle)."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import ref
    from repro.kernels.chain_norm import chain_norm
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.gconv_matmul import gconv_matmul
    from repro.kernels.gconv_spatial import gconv_spatial

    rows = []

    def one(name, fn, fn_ref, *args):
        y = fn(*args)
        jax.block_until_ready(y)
        t0 = time.perf_counter()
        for _ in range(3):
            y = fn(*args)
        jax.block_until_ready(y)
        us = (time.perf_counter() - t0) / 3 * 1e6
        err = float(jnp.max(jnp.abs(
            jnp.asarray(y, jnp.float32)
            - jnp.asarray(fn_ref(*args), jnp.float32))))
        rows.append(dict(kernel=name, us_per_call=round(us),
                         max_err=round(err, 6)))

    k = jax.random.PRNGKey(0)
    x = jax.random.normal(k, (4, 64, 64))
    w = jax.random.normal(k, (4, 64, 64))
    one("gconv_matmul(4x64x64x64)",
        lambda a, b: gconv_matmul(a, b, block_m=32, block_n=32, block_k=32,
                                  interpret=True),
        ref.gconv_matmul_ref, x, w)
    xs = jax.random.normal(k, (2, 16, 16, 8))
    ws = jax.random.normal(k, (3, 3, 8, 16))
    one("gconv_spatial(2x16x16x8)",
        lambda a, b: gconv_spatial(a, b, pad=1, interpret=True),
        lambda a, b: ref.gconv_spatial_ref(a, b, pad=1), xs, ws)
    xn = jax.random.normal(k, (128, 256))
    g = jnp.ones((256,))
    one("chain_norm(128x256)",
        lambda a, b: chain_norm(a, b, block_t=64, interpret=True),
        ref.chain_norm_ref, xn, g)
    q = jax.random.normal(k, (2, 64, 32))
    one("flash_attention(2x64x32)",
        lambda a: flash_attention(a, a, a, block_q=32, block_k=32,
                                  interpret=True),
        lambda a: ref.flash_attention_ref(a, a, a), q)
    worst = max(r["max_err"] for r in rows)
    return rows, {"kernels": len(rows), "worst_err": worst,
                  "all_match_oracle": bool(worst < 5e-2)}


def bench_roofline():
    """Roofline table from the dry-run JSON cache (run launch/dryrun first)."""
    out_dir = os.path.join(os.path.dirname(__file__), "..", "results",
                           "dryrun")
    rows = []
    if not os.path.isdir(out_dir):
        return [], {"note": "no dry-run results yet "
                            "(python -m repro.launch.dryrun --all)"}
    for fn in sorted(os.listdir(out_dir)):
        if not fn.endswith(".json"):
            continue
        with open(os.path.join(out_dir, fn)) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            rows.append(dict(cell=fn[:-5], status=rec.get("status"),
                             reason=str(rec.get("reason",
                                                rec.get("error", "")))[:60]))
            continue
        r = rec["roofline"]
        rows.append(dict(
            cell=fn[:-5], status="ok", dominant=r["dominant"],
            compute_ms=round(r["compute_s"] * 1e3, 3),
            memory_ms=round(r["memory_s"] * 1e3, 3),
            collective_ms=round(r["collective_s"] * 1e3, 3),
            useful=round(r["useful_ratio"], 3),
            per_dev_gb=rec.get("per_device_gb")))
    ok = [r for r in rows if r.get("status") == "ok"]
    doms = {}
    for r in ok:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    return rows, {"cells_ok": len(ok), "dominant_histogram": doms}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--engine", choices=("analytic", "sim"),
                    default="analytic",
                    help="analytic: closed-form cost model over every "
                         "table/figure; sim: cycle-level tiled simulator "
                         "cross-validated against the analytic model")
    ap.add_argument("--mesh", default="4x2",
                    help="mesh for the exec_sharded cells, 'D' or 'DxM' "
                         "(the devices are faked in a subprocess via "
                         "--xla_force_host_platform_device_count)")
    args = ap.parse_args()
    if args.only:
        want = args.only.split(",")
        if args.engine == "sim" and set(want) != {"simval"}:
            ap.error("--engine sim only runs the 'simval' benchmark; "
                     "drop --only or use --only simval")
    elif args.engine == "sim":
        want = ["simval"]
    else:
        want = list(ALL)

    from benchmarks import (chaos_bench, dse_bench, exec_bench, lint_bench,
                            obs_bench, serve_bench, syssim_bench,
                            tune_bench)
    from benchmarks import paper_tables as pt
    from repro.obs import Metrics, provenance

    table = {
        "table1": pt.table1_layers, "fig12": pt.fig12_breakdown,
        "fig13": pt.fig13_conv_speedup, "fig14": pt.fig14_speedup,
        "fig15": pt.fig15_code_density, "fusion": pt.fusion_gains,
        "fig18": pt.fig18_energy, "fig20": pt.fig20_wholelife,
        "kernels": bench_kernels, "roofline": bench_roofline,
        "simval": pt.sim_validation,
        "exec": exec_bench.exec_speedup, "exec_micro": exec_bench.exec_micro,
        "exec_sharded": lambda: exec_bench.exec_sharded(mesh=args.mesh),
        "exec_sharded_micro":
            lambda: exec_bench.exec_sharded_micro(mesh=args.mesh),
        "dse": dse_bench.dse_search, "dse_micro": dse_bench.dse_micro,
        "serve": serve_bench.serve_bench,
        "serve_micro": serve_bench.serve_micro,
        "obs_micro": obs_bench.obs_micro,
        "chaos_micro": chaos_bench.chaos_micro,
        "syssim": syssim_bench.syssim_bench,
        "syssim_micro": syssim_bench.syssim_micro,
        "lint": lint_bench.lint_scan,
        "lint_micro": lint_bench.lint_micro,
        "tune": tune_bench.tune_speedup,
        "tune_micro": tune_bench.tune_micro,
    }
    # harness wall-times go through the unified metrics registry so the
    # committed artifact carries the same schema every other subsystem emits
    reg = Metrics()
    results = {}
    for name in want:
        t0 = time.perf_counter()
        results[name] = _run(name, table[name])
        reg.histogram("bench_wall_s", buckets=[0.1, 1, 10, 60, 600],
                      bench=name).observe(time.perf_counter() - t0)
        reg.counter("bench_runs", bench=name).inc()
    out = os.path.join(os.path.dirname(__file__), "..", "results",
                       "benchmarks.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    # merge into the existing artifact so partial runs (--only, --engine
    # sim) update their entries without destroying the others
    merged = {}
    if os.path.exists(out):
        try:
            with open(out) as f:
                merged = json.load(f)
        except (OSError, ValueError):
            merged = {}
    # the *_micro benchmarks are per-machine CI smoke gates: keep their wall
    # times out of the committed perf-trajectory artifact (every FAST CI run
    # would otherwise clobber the curated rows with laptop numbers)
    merged.update({k: {"rows": v[0], "summary": v[1]}
                   for k, v in results.items() if k not in MICRO})
    # provenance + harness metrics are stamped once per invocation that
    # contributes rows, so every committed number is attributable to a git
    # SHA / jax version / device; micro-only (FAST CI) runs leave the
    # stamp alone for the same reason their rows are excluded — a smoke
    # box's identity must not masquerade as the curated rows' origin
    if any(k not in MICRO for k in results):
        merged["provenance"] = provenance()
        merged["metrics"] = reg.to_dict()
    with open(out, "w") as f:
        json.dump(merged, f, indent=1, default=str)
    print(f"\nwrote {os.path.abspath(out)}")

    # CI gates (scripts/ci.sh FAST tier): the compiled engine must beat the
    # oracle interpreter on the smoke network, and the design-space smoke
    # must produce a frontier whose best point passes the analytic-vs-sim
    # agreement contract
    if "exec_micro" in results and not results["exec_micro"][1].get(
            "compiled_faster"):
        raise SystemExit("exec_micro: compiled engine slower than the "
                         "oracle interpreter")
    if "dse_micro" in results and not results["dse_micro"][1].get("ok"):
        raise SystemExit("dse_micro: no frontier or the best point's "
                         "analytic cost disagrees with its sim promotion")
    if "serve_micro" in results and not results["serve_micro"][1].get("ok"):
        raise SystemExit(
            "serve_micro: continuous-batching outputs diverge from "
            "sequential single-slot decode (cache corruption) or batched "
            "serving lost its throughput edge over per-request execution")
    if "exec_sharded_micro" in results and not results[
            "exec_sharded_micro"][1].get("ok"):
        raise SystemExit(
            "exec_sharded_micro: the sharded compiled engine diverged "
            "from the single-device engine (allclose, rtol 1e-4) on the "
            "zoo net / LM blocks, or lost its >1 data-parallel throughput "
            "scaling over one device")
    if "obs_micro" in results and not results["obs_micro"][1].get("ok"):
        raise SystemExit(
            "obs_micro: serve trace failed schema validation, the report "
            "CLI disagrees with Server.stats() on request count or "
            "p50/p99 TTFT, or disabled-mode tracing overhead on the exec "
            "micro cell exceeded the 2% budget")
    if "chaos_micro" in results and not results["chaos_micro"][1].get("ok"):
        raise SystemExit(
            "chaos_micro: recovered outputs diverged byte-for-byte from "
            "the fault-free sequential reference under the fixed fault "
            "spec, a spec'd fault never fired, a request landed in the "
            "wrong terminal status, or the resilience layer cost more "
            "than 5% on the fault-free serve path")
    if "syssim_micro" in results and not results["syssim_micro"][1].get(
            "ok"):
        raise SystemExit(
            "syssim_micro: the degenerate 1-unit uncontended system "
            "diverged from repro.sim (movement/energy/cycles drift or "
            "analytic agreement out of tolerance), or the serve-trace "
            "replay dropped recorded requests")
    if "lint_micro" in results and not results["lint_micro"][1].get("ok"):
        raise SystemExit(
            "lint_micro: the static-verifier CLI failed its exit-code "
            "contract — the clean reduced sweep must exit 0 with zero "
            "error findings, and the --mutants run must exit nonzero "
            "with every seeded mutant caught by its intended rule and "
            "no false positives on the clean bases")
    if "tune_micro" in results and not results["tune_micro"][1].get("ok"):
        raise SystemExit(
            "tune_micro: the autotuned plan regressed past noise vs the "
            "heuristic plan on the smoke network, the warm-cache tuned "
            "compile exceeded its 5% overhead budget over a plain "
            "compile, or tuned outputs diverged from the heuristic plan")


if __name__ == "__main__":
    main()
