"""Observability benchmarks: trace schema + report fidelity + overhead.

``obs_micro`` — FAST-tier CI gate (via ``benchmarks.run``). Three checks,
all of which must hold for ``ok``:

  * **trace fidelity** — a tiny traced serve workload is written to disk,
    re-loaded through :func:`repro.obs.trace.load_trace` (schema
    validation) and summarized by ``repro.obs.report``; the report must
    reconstruct the request count and the p50/p99 TTFT that
    ``Server.stats()`` printed, bit for bit (both route through the same
    ``repro.obs.metrics.percentile``).
  * **report CLI** — ``python -m repro.obs.report`` must exit 0 on the
    trace just written.
  * **disabled overhead** — the exec micro cell (zoo net ``MN``, batch 1)
    run on a plain engine vs an engine built with ``profile=True`` but a
    *disabled* tracer: the latter walks the full profiling code path and
    must cost no more than ``MAX_DISABLED_OVERHEAD`` extra (interleaved
    min-of-repeats timing, so machine noise cancels).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time

ARCH = "tinyllama-1.1b"

# tracing must be provably near-zero-cost when disabled; the gate budget
# is 2% on the exec micro cell (min-of-repeats absorbs scheduler noise)
MAX_DISABLED_OVERHEAD = 0.02
OVERHEAD_PAIRS = 300


def _traced_serve(trace_path):
    """Tiny staggered workload with a tracer attached; returns the
    driver's stats dict and the written trace's report summary."""
    from benchmarks.serve_bench import _workload
    from repro.launch.serve import Server
    from repro.obs import Tracer, load_trace
    from repro.obs.report import summarize

    tr = Tracer()
    srv = Server(ARCH, smoke=True, slots=2, max_len=64, tracer=tr)
    reqs = _workload(4, srv.cfg.vocab, max_new=4)
    srv.run_workload(reqs, stagger_ticks=1)
    stats = srv.stats()
    tr.write(trace_path)
    trace = load_trace(trace_path)          # raises ValueError on schema
    return stats, summarize(trace)


def _report_cli_ok(trace_path):
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.obs.report", trace_path],
        capture_output=True, text=True, env=env, timeout=300)
    if proc.returncode != 0:
        return False, proc.stderr[-500:]
    json.loads(proc.stdout)                  # must print one JSON object
    return True, ""


def _disabled_overhead():
    """Steady-state us/call: plain engine vs profile=True + disabled
    tracer (identical execution path, flag checks only). Interleaved
    min-of-repeats so a noise spike hits both arms equally."""
    import jax

    from benchmarks.exec_bench import _zoo_case
    from repro.exec import compile_chain
    from repro.obs import Tracer

    chain, inputs, params = _zoo_case("MN", batch=1)
    plain = compile_chain(chain)
    traced = compile_chain(chain, profile=True, tracer=Tracer(enabled=False))
    for eng in (plain, traced):              # compile both programs
        jax.block_until_ready(eng(inputs, params))

    def one(eng):
        t0 = time.perf_counter()
        jax.block_until_ready(eng(inputs, params))
        return (time.perf_counter() - t0) * 1e6

    # single-call interleaving with per-arm medians: machine-noise bursts
    # on this box are shorter than any multi-call timing block, so arms
    # must alternate at call granularity (order flipped each pair) and the
    # median — not the min or mean — is what survives the bursts.
    plains, traceds = [], []
    for i in range(OVERHEAD_PAIRS):
        if i % 2:
            traceds.append(one(traced))
            plains.append(one(plain))
        else:
            plains.append(one(plain))
            traceds.append(one(traced))
    assert not traced.tracer.events, "disabled tracer recorded events"

    def iqm(xs):                 # interquartile mean: lower-variance than
        xs = sorted(xs)          # a lone median, still burst-immune
        q = len(xs) // 4
        mid = xs[q:len(xs) - q]
        return sum(mid) / len(mid)

    med_p, med_t = iqm(plains), iqm(traceds)
    return med_p, med_t, med_t / med_p - 1.0


def obs_micro():
    """FAST-tier gate: schema-valid replayable serve trace whose report
    agrees with Server.stats(), working report CLI, and <= 2% disabled-
    mode tracing overhead on the exec micro cell."""
    with tempfile.TemporaryDirectory() as td:
        trace_path = os.path.join(td, "serve_trace.json")
        stats, report = _traced_serve(trace_path)
        cli_ok, cli_err = _report_cli_ok(trace_path)
    agree = (report["requests"] == stats["requests"]
             and report["p50_ttft_s"] == stats["p50_ttft_s"]
             and report["p99_ttft_s"] == stats["p99_ttft_s"]
             and report["p50_latency_s"] == stats["p50_latency_s"])
    plain_us, traced_us, overhead = _disabled_overhead()
    if overhead > MAX_DISABLED_OVERHEAD:
        # estimator noise on a contended box is ~ +/-1.5%; one re-measure
        # (keep the smaller) stops that tail from flaking CI while a real
        # regression — a hot-path change, not noise — still fails twice
        plain2, traced2, over2 = _disabled_overhead()
        if over2 < overhead:
            plain_us, traced_us, overhead = plain2, traced2, over2
    rows = [dict(check="trace_report_agreement",
                 requests=report["requests"],
                 p50_ttft_s=report["p50_ttft_s"],
                 p99_ttft_s=report["p99_ttft_s"],
                 slot_utilization=report["slot_utilization"],
                 ok=bool(agree)),
            dict(check="report_cli", ok=bool(cli_ok),
                 **({"stderr": cli_err} if cli_err else {})),
            dict(check="disabled_overhead",
                 plain_us=round(plain_us, 1),
                 traced_us=round(traced_us, 1),
                 overhead=round(overhead, 4),
                 budget=MAX_DISABLED_OVERHEAD,
                 ok=bool(overhead <= MAX_DISABLED_OVERHEAD))]
    summary = dict(
        requests=report["requests"],
        stats_report_agree=bool(agree),
        report_cli_ok=bool(cli_ok),
        disabled_overhead=round(overhead, 4),
        ok=bool(agree and cli_ok and overhead <= MAX_DISABLED_OVERHEAD),
    )
    return rows, summary
