"""System-simulator benchmarks: heterogeneous utilization + trace replay.

``syssim`` — full cell (via ``benchmarks.run``): per-unit utilization and
contention-stall share of the 2-unit heterogeneous system (GCONV array +
vector/SIMD unit) serving concurrent chains on a couple of zoo networks,
plus an end-to-end replay of a freshly recorded serve trace; the per-unit
breakdown lands in ``results/benchmarks.json``.

``syssim_micro`` — FAST-tier CI gate. Two invariants, both of which must
hold for ``ok``:

  * **degenerate fidelity** — the 1-unit uncontended system reproduces
    ``repro.sim.simulate_chain`` exactly (movement/energy to
    ``DRIFT_TOL``, cycles bit-for-bit) on a reduced zoo slice across the
    Table-4 accelerators, and stays inside the analytic-vs-sim
    ``CYCLES_RATIO_TOL`` contract;
  * **lossless replay** — a recorded serve trace replays on the
    heterogeneous ER system with zero dropped requests.
"""
from __future__ import annotations

import os
import tempfile

ARCH = "tinyllama-1.1b"

# reduced zoo slice for the FAST gate: one depthwise-heavy and one
# plain-conv net keeps both routing classes (vector + array) exercised
MICRO_NETS = ("MN", "AN")
FULL_NETS = ("AN", "MN", "GLN")
N_JOBS = 2


def _record_trace(trace_path, n=4, max_new=4):
    """Tiny staggered traced serve workload (same shape as obs_micro)."""
    from benchmarks.serve_bench import _workload
    from repro.launch.serve import Server
    from repro.obs import Tracer

    tr = Tracer()
    srv = Server(ARCH, smoke=True, slots=2, max_len=64, tracer=tr)
    srv.run_workload(_workload(n, srv.cfg.vocab, max_new=max_new),
                     stagger_ticks=1)
    tr.write(trace_path)
    return trace_path


def _replay_rows(reduced):
    """Replay a freshly recorded trace on the hetero ER system."""
    from repro.syssim import hetero, replay_trace

    with tempfile.TemporaryDirectory() as td:
        path = _record_trace(os.path.join(td, "serve_trace.json"))
        res = replay_trace(path, hetero("ER"), reduced=reduced)
    rep = res.report
    row = dict(
        check="trace_replay", accel="ER",
        requests_recorded=res.requests_recorded,
        requests_simulated=res.requests_simulated,
        dropped=res.dropped,
        goodput_tokens_per_kcycle=round(rep.goodput, 6),
        p50_latency_cycles=round(rep.latency_percentile(50), 1),
        p99_latency_cycles=round(rep.latency_percentile(99), 1),
        aggregate_utilization=round(rep.aggregate_utilization, 4),
        contention_stall_share=round(rep.contention_stall_share, 6),
        unit_utilization={u.name: round(u.utilization(rep.makespan), 4)
                          for u in rep.units},
        ok=bool(res.dropped == 0),
    )
    return row


def syssim_bench():
    """Full cell: hetero vs array-only utilization on zoo nets + replay."""
    from repro.syssim import hetero_utilization_gain

    rows = []
    gains = []
    for net in FULL_NETS:
        g = hetero_utilization_gain(net, accel="ER", n_jobs=N_JOBS)
        gains.append(g)
        rows.append(dict(
            check="hetero_utilization", net=net, accel="ER",
            n_jobs=N_JOBS, vector_tasks=g["vector_tasks"],
            hetero_utilization=round(g["hetero_utilization"], 4),
            array_only_utilization=round(g["array_only_utilization"], 4),
            gain=round(g["gain"], 4),
            makespan_speedup=round(g["array_only_makespan"]
                                   / max(g["hetero_makespan"], 1e-12), 4),
            strictly_higher=g["strictly_higher"]))
    replay = _replay_rows(reduced=False)
    rows.append(replay)
    summary = dict(
        nets=len(gains),
        hetero_higher_on=sum(1 for g in gains if g["strictly_higher"]),
        mean_utilization_gain=round(
            sum(g["gain"] for g in gains) / len(gains), 4),
        replay_dropped=replay["dropped"],
        replay_contention_stall_share=replay["contention_stall_share"],
        replay_unit_utilization=replay["unit_utilization"],
        ok=bool(any(g["strictly_higher"] for g in gains)
                and replay["dropped"] == 0),
    )
    return rows, summary


def syssim_micro():
    """FAST-tier gate: exact degenerate parity with repro.sim on the
    reduced zoo slice x Table-4 accelerators, and a lossless replay of a
    recorded serve trace on the heterogeneous system."""
    from repro.syssim import validate_degenerate

    deg_rows, deg = validate_degenerate(nets=MICRO_NETS, reduced=True)
    rows = [dict(check="degenerate", net=r["net"], accel=r["accel"],
                 cycles_drift=r["cycles_drift"],
                 movement_drift=r["movement_drift"],
                 energy_drift=r["energy_drift"],
                 cycles_ratio=round(r["cycles_ratio"], 4),
                 exact=r["exact"]) for r in deg_rows]
    replay = _replay_rows(reduced=True)
    rows.append(replay)
    summary = dict(
        degenerate_pairs=deg["pairs"],
        degenerate_exact=deg["all_exact"],
        degenerate_within_tolerance=deg["all_within_tolerance"],
        max_cycles_drift=deg["max_cycles_drift"],
        replay_dropped=replay["dropped"],
        ok=bool(deg["all_exact"] and deg["all_within_tolerance"]
                and replay["dropped"] == 0),
    )
    return rows, summary
