"""Interpreter-vs-compiled execution benchmarks (the perf trajectory).

``exec``       — per-zoo-network wall time: the eager oracle interpreter
                 (``core.interpreter.ChainExecutor``) vs the compiled engine
                 (``repro.exec``), steady-state (post-warmup), plus the
                 allclose divergence between the two. Seeds the
                 ``results/benchmarks.json`` perf trajectory.
``exec_micro`` — one smoke network, run by the FAST CI tier;
                 ``benchmarks.run`` exits nonzero if the compiled engine is
                 not faster than the interpreter.
"""
from __future__ import annotations

import time


def _bench_pair(chain, inputs, params, iters=3):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.interpreter import ChainExecutor
    from repro.exec import compile_chain

    ex = ChainExecutor(chain)
    eng = compile_chain(chain)

    t0 = time.perf_counter()
    got = jax.block_until_ready(eng(inputs, params))
    compile_s = time.perf_counter() - t0
    ref = jax.block_until_ready(ex(inputs, params))       # eager warmup
    err = 0.0
    for o in ref:
        err = max(err, float(jnp.max(jnp.abs(
            jnp.asarray(got[o], jnp.float32)
            - jnp.asarray(ref[o], jnp.float32)))))

    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(ex(inputs, params))
    oracle_us = (time.perf_counter() - t0) / iters * 1e6
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(eng(inputs, params))
    compiled_us = (time.perf_counter() - t0) / iters * 1e6
    speedup = oracle_us / max(compiled_us, 1e-9)
    return dict(
        oracle_us=round(oracle_us),
        compiled_us=round(compiled_us, 1),
        speedup=round(speedup, 1),
        _speedup_raw=speedup,        # unrounded, for gates; stripped below
        compile_us=round(compile_s * 1e6),
        max_err=round(err, 6),
        backends=eng.backend_histogram(),
    )


def _zoo_case(name, batch=2):
    import jax

    from repro.core.interpreter import init_chain_params
    from repro.models import cnn

    chain = cnn.build(name, reduced=True, batch=batch)
    params = init_chain_params(chain, jax.random.PRNGKey(0))
    return chain, cnn.random_inputs(chain), params


def exec_speedup():
    """Fig.-style interpreter-vs-compiled sweep over the seven zoo CNNs."""
    import numpy as np

    from repro.models import cnn

    rows = []
    for name in cnn.ZOO:
        chain, inputs, params = _zoo_case(name)
        r = _bench_pair(chain, inputs, params)
        r["net"] = name
        rows.append(r)
    # gates use the unrounded ratios (rounding 1.04 -> 1.0 must not fail CI)
    speedups = [r.pop("_speedup_raw") for r in rows]
    geomean = float(np.exp(np.mean(np.log(np.maximum(speedups, 1e-9)))))
    summary = dict(
        networks=len(rows),
        geomean_speedup=round(geomean, 1),
        min_speedup=round(min(speedups), 1),
        all_faster=bool(min(speedups) > 1.0),
        worst_err=max(r["max_err"] for r in rows),
        target="geomean >= 3x over the oracle interpreter at test scale",
        met=bool(geomean >= 3.0),
    )
    return rows, summary


def exec_micro():
    """FAST-tier smoke: one network; fails CI when compiled is slower."""
    chain, inputs, params = _zoo_case("MN", batch=1)
    r = _bench_pair(chain, inputs, params)
    r["net"] = "MN"
    raw = r.pop("_speedup_raw")
    summary = dict(
        speedup=r["speedup"],
        max_err=r["max_err"],
        # gate both speed (unrounded: 1.04 must pass) and correctness —
        # the zoo differential tests are @slow and absent from FAST CI
        compiled_faster=bool(raw > 1.0 and r["max_err"] <= 1e-3),
    )
    return [r], summary
