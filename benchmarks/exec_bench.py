"""Interpreter-vs-compiled execution benchmarks (the perf trajectory).

``exec``       — per-zoo-network wall time: the eager oracle interpreter
                 (``core.interpreter.ChainExecutor``) vs the compiled engine
                 (``repro.exec``), steady-state (post-warmup), plus the
                 allclose divergence between the two. Seeds the
                 ``results/benchmarks.json`` perf trajectory.
``exec_micro`` — one smoke network, run by the FAST CI tier;
                 ``benchmarks.run`` exits nonzero if the compiled engine is
                 not faster than the interpreter.
``exec_sharded``       — mesh-aware engine (``compile_chain(mesh=...)``) on
                 faked host devices, in a subprocess (the device count
                 locks at first jax init): full zoo + LM blocks sharded-vs-
                 single-device divergence, and 1-device vs N-fake-device
                 batched throughput scaling. Rides
                 ``python -m repro.exec.shardcheck``.
``exec_sharded_micro`` — FAST CI gate: one zoo net + the LM blocks + the
                 scaling bench; ``benchmarks.run`` exits nonzero when the
                 sharded program diverges (allclose, rtol 1e-4) or loses
                 its >1 scaling over one device.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time


def _bench_pair(chain, inputs, params, iters=3):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.interpreter import ChainExecutor
    from repro.exec import compile_chain

    ex = ChainExecutor(chain)
    eng = compile_chain(chain)

    t0 = time.perf_counter()
    got = jax.block_until_ready(eng(inputs, params))
    compile_s = time.perf_counter() - t0
    ref = jax.block_until_ready(ex(inputs, params))       # eager warmup
    err = 0.0
    for o in ref:
        err = max(err, float(jnp.max(jnp.abs(
            jnp.asarray(got[o], jnp.float32)
            - jnp.asarray(ref[o], jnp.float32)))))

    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(ex(inputs, params))
    oracle_us = (time.perf_counter() - t0) / iters * 1e6
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(eng(inputs, params))
    compiled_us = (time.perf_counter() - t0) / iters * 1e6
    speedup = oracle_us / max(compiled_us, 1e-9)
    return dict(
        oracle_us=round(oracle_us),
        compiled_us=round(compiled_us, 1),
        speedup=round(speedup, 1),
        _speedup_raw=speedup,        # unrounded, for gates; stripped below
        compile_us=round(compile_s * 1e6),
        max_err=round(err, 6),
        backends=eng.backend_histogram(),
    )


def _zoo_case(name, batch=2):
    import jax

    from repro.core.interpreter import init_chain_params
    from repro.models import cnn

    chain = cnn.build(name, reduced=True, batch=batch)
    params = init_chain_params(chain, jax.random.PRNGKey(0))
    return chain, cnn.random_inputs(chain), params


def exec_speedup():
    """Fig.-style interpreter-vs-compiled sweep over the seven zoo CNNs."""
    import numpy as np

    from repro.models import cnn

    rows = []
    for name in cnn.ZOO:
        chain, inputs, params = _zoo_case(name)
        r = _bench_pair(chain, inputs, params)
        r["net"] = name
        rows.append(r)
    # gates use the unrounded ratios (rounding 1.04 -> 1.0 must not fail CI)
    speedups = [r.pop("_speedup_raw") for r in rows]
    geomean = float(np.exp(np.mean(np.log(np.maximum(speedups, 1e-9)))))
    summary = dict(
        networks=len(rows),
        geomean_speedup=round(geomean, 1),
        min_speedup=round(min(speedups), 1),
        all_faster=bool(min(speedups) > 1.0),
        worst_err=max(r["max_err"] for r in rows),
        target="geomean >= 3x over the oracle interpreter at test scale",
        met=bool(geomean >= 3.0),
    )
    return rows, summary


def exec_micro():
    """FAST-tier smoke: one network; fails CI when compiled is slower."""
    chain, inputs, params = _zoo_case("MN", batch=1)
    r = _bench_pair(chain, inputs, params)
    r["net"] = "MN"
    raw = r.pop("_speedup_raw")
    summary = dict(
        speedup=r["speedup"],
        max_err=r["max_err"],
        # gate both speed (unrounded: 1.04 must pass) and correctness —
        # the zoo differential tests are @slow and absent from FAST CI
        compiled_faster=bool(raw > 1.0 and r["max_err"] <= 1e-3),
    )
    return [r], summary


# ---------------------------------------------------------------------------
# mesh-aware engine: sharded-vs-single-device + throughput scaling
# ---------------------------------------------------------------------------
def _run_shardcheck(args, mesh: str, timeout=1800):
    """Spawn ``repro.exec.shardcheck`` with the mesh's device count faked
    (multi-device CPU needs its own process: the count locks at the first
    jax initialization, and this process already initialized)."""
    from repro.shardpolicy import parse_mesh_spec

    d, m = parse_mesh_spec(mesh)
    devices = d * m
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count"
                          f"={devices}")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.exec.shardcheck", "--mesh", mesh,
         *args],
        capture_output=True, text=True, env=env, timeout=timeout)
    if not proc.stdout.strip():
        raise RuntimeError(f"shardcheck produced no output: "
                           f"{proc.stderr[-2000:]}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def _sharded_summary(report):
    rows = report["rows"]
    errs = [r["max_err"] for r in rows if "max_err" in r]
    bench = next((r for r in rows if r["check"] == "bench"), None)
    return dict(
        mesh=report["mesh"],
        devices=report["devices"],
        checks=len(rows),
        worst_err=max(errs) if errs else None,
        all_allclose=all(r["ok"] for r in rows if "max_err" in r),
        scaling=bench["scaling"] if bench else None,
        scaling_gt_1=bool(bench and bench["ok"]),
        ok=bool(report["ok"]),
    )


def exec_sharded(mesh: str = "4x2"):
    """Full sweep: all zoo nets + LM blocks sharded on faked devices, plus
    the data-parallel throughput-scaling bench (1 device vs all)."""
    report = _run_shardcheck(["--nets", "all", "--lm", "--bench", "0"],
                             mesh)
    return report["rows"], _sharded_summary(report)


def exec_sharded_micro(mesh: str = "4x2"):
    """FAST-tier gate: one zoo net + the LM blocks + the scaling bench;
    nonzero exit from benchmarks.run on divergence or scaling <= 1."""
    report = _run_shardcheck(["--nets", "MN", "--lm", "--bench", "0"],
                             mesh)
    return report["rows"], _sharded_summary(report)
