"""Serving benchmarks: continuous batching through repro.exec.serving.

``serve``       — staggered multi-slot workload on the smoke LM: total and
                  generated tok/s, queue-wait / TTFT / end-to-end latency
                  percentiles, and the speedup of batched continuous
                  serving over per-request (single-slot, sequential)
                  execution. Seeds the ``results/benchmarks.json``
                  trajectory.
``serve_micro`` — FAST-tier CI gate: drains a small staggered workload,
                  exits nonzero (via benchmarks.run) when outputs diverge
                  from sequential single-slot decode (cache corruption) or
                  when batched serving loses its throughput edge over
                  per-request execution.
"""
from __future__ import annotations

ARCH = "tinyllama-1.1b"

# serve_micro throughput gate: batched continuous serving must keep at
# least this edge over per-request sequential execution. The acceptance
# target is >= 2x at smoke scale (the 'serve' cell records the real
# ratio); the CI gate sits lower so machine noise cannot flake FAST CI
# while still catching a real regression to per-request throughput.
MICRO_MIN_SPEEDUP = 1.3


def _workload(n, vocab, max_new, seed=0):
    import numpy as np

    from repro.launch.serve import Request

    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, vocab,
                                        rng.integers(2, 6)).tolist(),
                    max_new=max_new)
            for i in range(n)]


def _clone(reqs):
    from repro.launch.serve import Request

    return [Request(rid=r.rid, prompt=list(r.prompt), max_new=r.max_new)
            for r in reqs]


def _run_serve(n_requests, slots, max_new, stagger, max_len=96):
    """Batched continuous serving vs per-request execution on the same
    workload, both WARM (first run pays the compiles, the second is
    timed), and the byte-identity corruption check between the two."""
    from repro.launch.serve import Server

    srv = Server(ARCH, smoke=True, slots=slots, max_len=max_len)
    reqs = _workload(n_requests, srv.cfg.vocab, max_new)
    srv.run_workload(_clone(reqs), stagger_ticks=stagger)    # warm-up
    srv.reset_stats()
    report = srv.run_workload(_clone(reqs), stagger_ticks=stagger)
    got = {r.rid: r.out for r in srv.finished}

    # per-request execution: ONE single-slot server (warm programs), every
    # request decoded alone in submission order
    seq = Server(ARCH, smoke=True, slots=1, max_len=max_len)
    seq.run_workload(_clone(reqs), stagger_ticks=0)          # warm-up
    seq.reset_stats()
    seq_report = seq.run_workload(_clone(reqs), stagger_ticks=0)
    ref = {r.rid: r.out for r in seq.finished}
    identical = all(got[r.rid] == ref[r.rid] for r in reqs)
    seq_tok_per_s = seq_report["tok_per_s"]
    speedup = (report["tok_per_s"] / seq_tok_per_s if seq_tok_per_s
               else 0.0)
    row = dict(
        requests=report["requests"],
        slots=slots,
        stagger_ticks=stagger,
        tokens_total=report["tokens_total"],
        tok_per_s=round(report["tok_per_s"], 1),
        tok_per_s_out=round(report["tok_per_s_out"], 1),
        p50_ttft_ms=round(report["p50_ttft_s"] * 1e3, 2),
        p99_ttft_ms=round(report["p99_ttft_s"] * 1e3, 2),
        p50_latency_ms=round(report["p50_latency_s"] * 1e3, 2),
        p99_latency_ms=round(report["p99_latency_s"] * 1e3, 2),
        p50_queue_wait_ms=round(report["p50_queue_wait_s"] * 1e3, 2),
        prefill_compiles=report["prefill_compiles"],
        seq_tok_per_s=round(seq_tok_per_s, 1),
        speedup_vs_sequential=round(speedup, 2),
        identical_to_sequential=bool(identical),
    )
    return row, speedup, identical


def serve_bench():
    """Perf-trajectory cell: staggered workload at two slot counts, warm
    batched serving vs a warm single-slot per-request baseline."""
    rows = []
    speedups = []
    ok = True
    for slots in (2, 4):
        row, speedup, identical = _run_serve(
            n_requests=8, slots=slots, max_new=12, stagger=2)
        rows.append(row)
        speedups.append(speedup)
        ok = ok and identical
    summary = dict(
        cells=len(rows),
        best_speedup_vs_sequential=round(max(speedups), 2),
        all_identical_to_sequential=bool(ok),
        target="batched continuous serving >= 2x per-request execution "
               "at smoke scale, byte-identical outputs",
        met=bool(ok and max(speedups) >= 2.0),
    )
    return rows, summary


def serve_micro():
    """FAST-tier smoke gate: corruption => not ok; lost throughput edge
    over per-request execution => not ok."""
    row, speedup, identical = _run_serve(
        n_requests=8, slots=4, max_new=8, stagger=1)
    summary = dict(
        speedup_vs_sequential=row["speedup_vs_sequential"],
        identical_to_sequential=row["identical_to_sequential"],
        min_speedup=MICRO_MIN_SPEEDUP,
        ok=bool(identical and speedup >= MICRO_MIN_SPEEDUP),
    )
    return [row], summary
