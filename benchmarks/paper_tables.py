"""Paper tables/figures reproduced on the analytical simulator.

One function per artifact; each returns (rows, summary) where rows are dicts
(CSV-able) and summary holds the headline numbers compared against the
paper's claims. All seven CNNs x five Table-4 accelerators.
"""
from __future__ import annotations

import os
from typing import Dict, List, Tuple

from repro.core import accelerators as acc
from repro.core.chain import Chain
from repro.core.costmodel import (baseline_cost, gconv_chain_cost, lip_utilization, speedup)
from repro.core.fusion import fuse_chain
from repro.core.gconv import GConv
from repro.models import cnn

NETS = ("AN", "GLN", "DN", "MN", "ZFFR", "C3D", "CapNN")
ACCELS = ("TPU", "DNNW", "ER", "EP", "NLR")
_CHAINS: Dict[str, Chain] = {}


def get_chain(net: str) -> Chain:
    if net not in _CHAINS:
        _CHAINS[net] = cnn.build(net)
    return _CHAINS[net]


# ---------------------------------------------------------------------------
# Table 1(a): non-traditional layer impact
# ---------------------------------------------------------------------------
def table1_layers() -> Tuple[List[dict], dict]:
    # paper's Table 1(a) values for comparison: (layers%, compute%, data%)
    paper = {"AN": (24, 1, 5), "GLN": (13, 1, 17), "DN": (66, 5, 76),
             "MN": (62, 8, 73), "ZFFR": (29, 1, 41), "C3D": (52, 99, 46),
             "CapNN": (18, 95, 6)}
    rows = []
    for net in NETS:
        ch = get_chain(net)
        st = ch.stats()
        nt_nodes = sum(1 for n in ch.nodes
                       if not ch.meta.get(n, {}).get("traditional", False))
        row = dict(
            net=net,
            nontrad_layers_pct=round(100 * nt_nodes / len(ch.nodes), 1),
            nontrad_compute_pct=round(
                100 * st["nontraditional_macs"] / max(st["macs"], 1), 1),
            nontrad_data_pct=round(
                100 * st["nontraditional_elems"]
                / max(st["intermediate_elems"], 1), 1),
            paper_layers_pct=paper[net][0],
            paper_compute_pct=paper[net][1],
            paper_data_pct=paper[net][2],
        )
        rows.append(row)
    return rows, {"nets": len(rows)}


# ---------------------------------------------------------------------------
# Fig. 12: baseline latency breakdown (offload / pipeline bubbles)
# ---------------------------------------------------------------------------
def fig12_breakdown() -> Tuple[List[dict], dict]:
    rows = []
    for net in NETS:
        ch = get_chain(net)
        for name in ACCELS:
            spec = acc.get(name)
            try:
                base = baseline_cost(ch, spec)
            except ValueError:
                continue
            rec = dict(net=net, accel=name,
                       latency=base.latency,
                       offload_frac=round(
                           base.offload_latency / max(base.latency, 1), 3))
            if spec.kind == "LIP":
                rec["all_busy"] = round(lip_utilization(base), 3)
            rows.append(rec)
    ep_off = [r["offload_frac"] for r in rows if r["accel"] == "EP"]
    return rows, {"EP_mean_offload_frac": round(sum(ep_off) / len(ep_off), 3),
                  "paper_EP_offload_frac": 0.43}


# ---------------------------------------------------------------------------
# Fig. 13: convolution-layers-only speedup (GCONV no worse on convs)
# ---------------------------------------------------------------------------
def fig13_conv_speedup() -> Tuple[List[dict], dict]:
    rows = []
    worst = 10.0
    for net in ("AN", "GLN", "DN", "MN"):
        ch = get_chain(net)
        for name in ACCELS:
            spec = acc.get(name)
            base = baseline_cost(ch, spec)
            gc = gconv_chain_cost(ch, spec)
            b = sum(n.latency for n in base.nodes
                    if n.kind == "gconv" and n.traditional)
            g = sum(n.latency for n in gc.nodes
                    if n.kind == "gconv" and n.traditional)
            if b == 0 or g == 0:
                continue
            s = b / g
            worst = min(worst, s)
            rows.append(dict(net=net, accel=name, conv_speedup=round(s, 3)))
    return rows, {"min_conv_speedup": round(worst, 3),
                  "paper_claim": ">= 1.0 in all cases"}


# ---------------------------------------------------------------------------
# Fig. 14: end-to-end speedup
# ---------------------------------------------------------------------------
def fig14_speedup() -> Tuple[List[dict], dict]:
    rows = []
    vals = []
    for net in NETS:
        ch = get_chain(net)
        for name in ACCELS:
            # paper: ZFFR/CapNN/C3D not evaluated on DNNW; C3D not on CIPs
            if name == "DNNW" and net in ("ZFFR", "C3D", "CapNN"):
                continue
            if net == "C3D" and acc.get(name).kind == "CIP":
                continue
            spec = acc.get(name)
            s, base, gc = speedup(ch, spec)
            rows.append(dict(net=net, accel=name, speedup=round(s, 2)))
            vals.append(s)
    gmean = 1.0
    for v in vals:
        gmean *= v
    gmean **= 1.0 / len(vals)
    return rows, {"mean_speedup": round(sum(vals) / len(vals), 2),
                  "gmean_speedup": round(gmean, 2),
                  "max_speedup": round(max(vals), 2),
                  "paper_mean": 3.4, "paper_max": 8.2}


# ---------------------------------------------------------------------------
# Fig. 15: code density
# ---------------------------------------------------------------------------
def fig15_code_density() -> Tuple[List[dict], dict]:
    rows = []
    ratios_lip, ratios_tip = [], []
    for net in NETS:
        ch = get_chain(net)
        fused, _ = fuse_chain(ch)
        gc_len = len(fused.nodes)                      # one instr per GCONV
        lip_len = len({ch.meta.get(n, {}).get("layer", n)
                       for n in ch.nodes})             # one instr per layer
        # TIP: per GCONV, explicit loads (I,K) + compute + store, plus
        # windowing control when the op does not map to one matmul
        tip_len = 0
        for name, node in ch.nodes.items():
            if isinstance(node, GConv):
                ctrl = 2 if any(d.nks > 1 and d.nopc > 1
                                for d in node.dims) else 0
                tip_len += 4 + ctrl
            else:
                tip_len += 2
        rows.append(dict(net=net, gc_cip=gc_len, lip=lip_len, tip=tip_len,
                         gc_vs_lip=round(gc_len / lip_len, 2),
                         tip_vs_gc=round(tip_len / gc_len, 2)))
        ratios_lip.append(gc_len / lip_len)
        ratios_tip.append(tip_len / gc_len)
    return rows, {
        "gc_vs_lip_mean": round(sum(ratios_lip) / len(ratios_lip), 2),
        "tip_vs_gc_mean": round(sum(ratios_tip) / len(ratios_tip), 2),
        "paper": "GC-CIP 5.8x longer than LIP; TIP 2.6x worse than GC-CIP"}


# ---------------------------------------------------------------------------
# §4.3 fusion gains
# ---------------------------------------------------------------------------
def fusion_gains() -> Tuple[List[dict], dict]:
    rows = []
    for net in NETS:
        ch = get_chain(net)
        fused, rep = fuse_chain(ch)
        spec = acc.eyeriss()
        lat0 = gconv_chain_cost(ch, spec).latency
        lat1 = gconv_chain_cost(fused, spec).latency
        mov0 = gconv_chain_cost(ch, spec).movement_words
        mov1 = gconv_chain_cost(fused, spec).movement_words
        rows.append(dict(net=net,
                         len_reduction=round(rep.length_reduction, 3),
                         perf_gain=round(lat0 / lat1, 2),
                         movement_reduction=round(1 - mov1 / mov0, 3)))
    mean_perf = sum(r["perf_gain"] for r in rows) / len(rows)
    return rows, {"mean_perf_gain": round(mean_perf, 2),
                  "paper": "len -30%, input movement -63%, perf +1.1x"}


# ---------------------------------------------------------------------------
# Fig. 18/19: data movement energy + energy efficiency
# ---------------------------------------------------------------------------
def fig18_energy() -> Tuple[List[dict], dict]:
    rows = []
    tpu_base = {}
    for net in NETS:
        ch = get_chain(net)
        tpu_base[net] = baseline_cost(ch, acc.tpu_like()).energy
    edges = []
    for net in NETS:
        ch = get_chain(net)
        for name in ACCELS:
            spec = acc.get(name)
            base = baseline_cost(ch, spec)
            gc = gconv_chain_cost(fuse_chain(ch)[0], spec)
            rows.append(dict(
                net=net, accel=name,
                base_energy_norm=round(base.energy / tpu_base[net], 3),
                gc_energy_norm=round(gc.energy / tpu_base[net], 3),
                gc_gain=round(base.energy / gc.energy, 2)))
            if name in ("ER", "EP"):
                edges.append(tpu_base[net] / gc.energy)
    return rows, {
        "gc_cip_vs_tip_mean": round(sum(edges) / len(edges), 2),
        "paper": "GC-CIP over TIP up to 3.4x, 2.1x on average"}


# ---------------------------------------------------------------------------
# cycle-level simulator cross-validation (repro.sim)
# ---------------------------------------------------------------------------
def sim_validation() -> Tuple[List[dict], dict]:
    """Analytic model vs cycle-level simulator over the zoo (Table-4 subset).

    Writes the per-node stall/utilization breakdown of every pair to
    ``results/sim/<net>__<accel>.json``; the returned rows summarize the
    divergence per (network, accelerator) pair.
    """
    from repro.sim.validate import cross_validate

    out_dir = os.path.join(os.path.dirname(__file__), "..", "results", "sim")
    return cross_validate(nets=NETS, accels=("ER", "TPU", "EP"),
                          out_dir=out_dir)


# ---------------------------------------------------------------------------
# Fig. 20/21: whole-life cost (the paper's own constants)
# ---------------------------------------------------------------------------
def fig20_wholelife() -> Tuple[List[dict], dict]:
    # development cost: HW NRE + SW NRE + updates (paper's quoted numbers)
    hw_nre = {"TIP": 152_000, "GC-CIP": 165_000, "LIP": 220_000}
    # SW person-cost: salary ~ $75/h, 10 LoC/day (paper's refs [44][45]);
    # LoC from our prototype compiler scale: TIP codegen is the largest
    loc = {"TIP": 12_000, "GC-CIP": 6_000, "LIP": 9_000}
    per_loc = 75 * 8 / 10
    updates = 10
    update_cost = {"TIP": 0.15 * loc["TIP"] * per_loc,
                   "GC-CIP": 0.05 * loc["GC-CIP"] * per_loc,
                   "LIP": 200_000 + 0.1 * loc["LIP"] * per_loc}
    dev_rows = []
    for k in hw_nre:
        dev = hw_nre[k] + loc[k] * per_loc + updates * update_cost[k]
        dev_rows.append(dict(kind=k, dev_cost_usd=round(dev)))
    dev_rows.sort(key=lambda r: r["dev_cost_usd"])

    # TCO: CAPEX scaled to equal GPU-performance, OPEX from energy use
    # (relative energy efficiencies from fig18/19 style analysis)
    mn = get_chain("MN")
    eff = {}
    for name in ("TPU", "DNNW", "ER"):
        spec = acc.get(name)
        gc = gconv_chain_cost(fuse_chain(mn)[0], spec)
        base = baseline_cost(mn, spec)
        eff[name] = dict(perf=1.0 / gc.latency
                         if name == "ER" else 1.0 / base.latency,
                         energy=gc.energy if name == "ER" else base.energy)
    # normalize to TIP=1
    p0 = eff["TPU"]["perf"]
    e0 = eff["TPU"]["energy"]
    capex = {"TIP": 8000, "GC-CIP": 8000 * p0 / eff["ER"]["perf"],
             "LIP-ASIC": 8000 * p0 / eff["DNNW"]["perf"],
             "GPU": 12000, "LIP-FPGA": 18000}
    opex_rate = {"TIP": 1.0, "GC-CIP": eff["ER"]["energy"] / e0,
                 "LIP-ASIC": eff["DNNW"]["energy"] / e0,
                 "GPU": 2.2, "LIP-FPGA": 1.4}
    kwh_year = 7000
    usd_kwh = 0.13
    tco_rows = []
    for k in capex:
        for years in (3, 10):
            tco = capex[k] + years * opex_rate[k] * kwh_year * usd_kwh
            tco_rows.append(dict(kind=k, years=years, tco_usd=round(tco)))
    gc3 = next(r["tco_usd"] for r in tco_rows
               if r["kind"] == "GC-CIP" and r["years"] == 3)
    tip3 = next(r["tco_usd"] for r in tco_rows
                if r["kind"] == "TIP" and r["years"] == 3)
    return dev_rows + tco_rows, {
        "gc_cheapest_dev": dev_rows[0]["kind"],
        "gc_vs_tip_tco_3y": round(gc3 / tip3, 2),
        "paper": "GC-CIP costs 45% less than TIP after 3 years, "
                 "65% after 10"}
