"""Design-space-explorer benchmarks (the search-throughput trajectory).

``dse``       — a modest genetic search over the full zoo suite; records
                search throughput (analytic points/sec), the frontier, and
                the equal-budget baseline-domination verdicts into
                ``results/benchmarks.json``.
``dse_micro`` — FAST-CI smoke on the reduced suite: asserts a Pareto
                frontier is produced and that the best point's analytic cost
                matches its cycle-level-sim promotion within the
                ``repro.sim.validate`` agreement contract.
                ``benchmarks.run`` exits nonzero when the check fails.
"""
from __future__ import annotations


def dse_search():
    """Search-throughput benchmark: genetic search, full-size zoo suite."""
    from repro.dse.run import run_dse

    payload = run_dse(suite="zoo", budget=60, seed=0, strategy="genetic",
                      topk=4, map_budget=8, out_dir=None, quiet=True)
    rows = [r.to_json() for r in payload["_frontier"][:8]]
    for r in rows:
        r.pop("per_chain", None)
    # only sim-confirmed verdicts make the committed trajectory artifact
    dominated = sorted(k for k, v in payload["domination"].items()
                       if v["sim_confirmed"])
    summary = dict(
        points=payload["n_evals"],
        points_per_sec=payload["points_per_sec"],
        frontier_size=payload["frontier_size"],
        best_wlc=round(payload["best"]["wlc"], 4),
        best_sim_wlc=round(payload["best"]["sim"]["wlc"], 4),
        dominates_at_equal_budget=dominated,
        agreement_ok=payload["agreement_ok"],
        max_mapping_gain=round(max(r["improvement"]
                                   for r in payload["mapping_search"]), 4),
    )
    return rows, summary


def dse_micro():
    """FAST-tier smoke: tiny budget on the reduced suite; ``ok`` gates CI."""
    from repro.dse.run import run_dse

    payload = run_dse(suite="zoo", budget=16, seed=0, strategy="anneal",
                      topk=2, map_budget=0, out_dir=None, reduced=True,
                      quiet=True)
    best = payload["best"]
    rows = [dict(key=best["key"], wlc=round(best["wlc"], 4),
                 sim_wlc=round(best["sim"]["wlc"], 4),
                 cycles_ratio_max=best["sim"]["cycles_ratio_max"])]
    ok = (payload["frontier_size"] > 0
          and payload["agreement_ok"]
          and best["sim"]["within_tolerance"])
    summary = dict(
        ok=bool(ok),
        frontier_size=payload["frontier_size"],
        best_wlc=round(best["wlc"], 4),
        cycles_ratio_max=best["sim"]["cycles_ratio_max"],
        cycles_ratio_tol=best["sim"]["cycles_ratio_tol"],
        movement_drift=best["sim"]["movement_drift"],
        energy_drift=best["sim"]["energy_drift"],
    )
    return rows, summary
