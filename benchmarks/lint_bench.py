"""Static-analysis benchmark cells.

``lint_scan`` (full tier) sweeps the paper-scale zoo + LM chains through
every ``repro.lint`` pass layer in-process and lands the per-chain
severity counts — plus the ``lint_findings``/``dispatch_oracle_nodes``
metrics — in results/benchmarks.json, so regressions in the static
health of the corpus show up in the committed artifact's trajectory.

``lint_micro`` (FAST CI gate) exercises the actual ``python -m
repro.lint`` entry point twice in subprocesses: the clean reduced sweep
must exit 0 with zero errors, and the ``--mutants`` run must exit
nonzero (the seeded corpus is present) with every mutant caught by its
intended rule and no false positives on the clean bases.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time


def lint_scan():
    from repro.lint import fake_mesh, lint_chain
    from repro.lint.cli import corpus_chains
    from repro.obs import Metrics

    reg = Metrics()
    rows = []
    for chain in corpus_chains("full"):
        for backend in ("auto", "pallas"):
            for spec in (None, "4x2"):
                t0 = time.perf_counter()
                mesh = fake_mesh(spec) if spec else None
                rep = lint_chain(chain, backend=backend, mesh=mesh)
                rep.to_metrics(reg)
                c = rep.counts()
                rows.append(dict(
                    chain=chain.name, backend=backend,
                    mesh=spec or "none", errors=c["error"],
                    warns=c["warn"], infos=c["info"],
                    oracle_nodes=rep.oracle_nodes(),
                    us_per_lint=round((time.perf_counter() - t0) * 1e6)))
    errors = sum(r["errors"] for r in rows)
    summary = dict(
        chains=len(rows), errors=errors,
        warns=sum(r["warns"] for r in rows),
        oracle_nodes=max(r["oracle_nodes"] for r in rows),
        zero_errors=errors == 0,
        metrics=reg.to_dict())
    return rows, summary


def _run_cli(*extra):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    proc = subprocess.run(
        [sys.executable, "-m", "repro.lint", "--scale", "reduced",
         "--format", "json", *extra],
        capture_output=True, text=True, env=env)
    summary = None
    for line in reversed(proc.stdout.strip().splitlines()):
        try:
            summary = json.loads(line)
            break
        except ValueError:
            continue
    return proc.returncode, summary or {}


def lint_micro():
    """FAST gate: the CLI exits nonzero iff a mutant is present."""
    rows = []
    rc_clean, s_clean = _run_cli()
    rows.append(dict(run="clean", rc=rc_clean,
                     errors=s_clean.get("counts", {}).get("error", -1),
                     clean=s_clean.get("clean")))
    rc_mut, s_mut = _run_cli("--mutants")
    mut = s_mut.get("mutants") or {}
    rows.append(dict(run="mutants", rc=rc_mut,
                     caught=mut.get("caught"), total=mut.get("total"),
                     false_positives=mut.get("false_positives")))
    ok = (rc_clean == 0 and s_clean.get("clean") is True
          and s_clean.get("counts", {}).get("error") == 0
          and rc_mut == 1 and mut.get("all_caught") is True
          and mut.get("false_positives") == 0)
    return rows, dict(ok=bool(ok), rc_clean=rc_clean, rc_mutants=rc_mut,
                      mutants_caught=mut.get("caught"),
                      mutants_total=mut.get("total"))
