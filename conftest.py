import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "src"))
# test-local helpers (e.g. the hypothesis degradation shim) import flat
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "tests"))


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: heavy end-to-end tests (subprocess launches, full-size "
        "networks); deselect with -m 'not slow' for the fast smoke tier")
