import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "src"))
# test-local helpers (e.g. the hypothesis degradation shim) import flat
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "tests"))

# every compile_chain() in the test suite runs the repro.lint static
# passes and fails on error-severity findings (compile_chain reads this
# when its lint= option is None); export REPRO_LINT=off to opt out
os.environ.setdefault("REPRO_LINT", "error")


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: heavy end-to-end tests (subprocess launches, full-size "
        "networks); deselect with -m 'not slow' for the fast smoke tier")
