#!/usr/bin/env bash
# CI entry point.
#
#   ./scripts/ci.sh                 tier-1: full suite (the ROADMAP verify)
#   FAST=1 ./scripts/ci.sh          smoke tier: skip @slow tests, then run
#                                   the compiled-engine smoke benchmark
#                                   (fails if the compiled engine is slower
#                                   than the oracle interpreter), the
#                                   design-space-explorer smoke (fails if no
#                                   frontier is produced or the best point
#                                   violates the analytic-vs-sim agreement),
#                                   the serving smoke (drains a small
#                                   staggered workload through the compiled
#                                   serving programs; fails on cache
#                                   corruption — outputs diverging from
#                                   sequential single-slot decode — or on a
#                                   throughput regression vs per-request
#                                   execution) and the sharded-engine smoke
#                                   (8 faked host devices in a subprocess;
#                                   fails if the mesh-compiled program
#                                   diverges from the single-device engine
#                                   on a zoo net / the LM blocks, or loses
#                                   its >1 data-parallel scaling) and the
#                                   observability smoke (traced serve
#                                   workload round-tripped through the
#                                   trace schema + report CLI; fails if
#                                   the report disagrees with
#                                   Server.stats() or disabled-mode
#                                   tracing overhead exceeds 2%) and the
#                                   chaos smoke (staggered workload served
#                                   through a fixed fault-injection spec;
#                                   fails if recovered outputs diverge
#                                   byte-for-byte from the fault-free
#                                   reference or the resilience layer
#                                   costs >5% on the fault-free path) and
#                                   the system-simulator smoke (fails if
#                                   the degenerate 1-unit uncontended
#                                   system diverges from repro.sim or the
#                                   serve-trace replay drops recorded
#                                   requests)
#   CI_INSTALL=1 ./scripts/ci.sh    pip install -e '.[dev]' first (networked
#                                   CI; the dev extras declare pytest and
#                                   hypothesis — without them the property
#                                   tests self-skip)
#
# Extra arguments are passed through to pytest.
set -euo pipefail
cd "$(dirname "$0")/.."

if [ "${CI_INSTALL:-0}" = "1" ]; then
  python -m pip install -e '.[dev]'
fi

marker_args=()
if [ "${FAST:-0}" = "1" ]; then
  marker_args=(-m "not slow")
fi

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python -m pytest -x -q ${marker_args[@]+"${marker_args[@]}"} "$@"

if [ "${FAST:-0}" = "1" ]; then
  # smoke gates: benchmarks.run exits nonzero when the compiled engine does
  # not beat the interpreter (exec_micro), when the design-space explorer
  # produces no frontier / fails the analytic-vs-sim agreement (dse_micro),
  # when continuous-batching serving corrupts caches / regresses below
  # per-request throughput (serve_micro), or when the mesh-sharded engine
  # diverges from the single-device one / loses >1 data-parallel scaling
  # on faked host devices (exec_sharded_micro), or when the observability
  # layer breaks — serve trace failing schema validation, the report CLI
  # disagreeing with Server.stats(), or disabled-mode tracing overhead
  # above 2% on the exec micro cell (obs_micro), or when serving through
  # the fixed chaos spec loses byte-identity with the fault-free
  # reference / the resilience layer costs >5% fault-free (chaos_micro),
  # or when the system simulator's degenerate 1-unit case diverges from
  # repro.sim / the serve-trace replay drops requests (syssim_micro)
  # ... and the static-analysis smoke: the repro.lint CLI must exit 0
  # with zero error findings on the clean reduced corpus, and exit
  # nonzero on the seeded mutation corpus with every mutant caught by
  # its intended rule (lint_micro), and the autotuner smoke: a tuned
  # compile against a throwaway DB must not regress past noise vs the
  # heuristic plan, diverge from it, or exceed the 5% warm-cache
  # compile-overhead budget (tune_micro)
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.run \
    --only exec_micro,dse_micro,serve_micro,exec_sharded_micro,obs_micro,chaos_micro,syssim_micro,lint_micro,tune_micro
fi

# pyflakes-class static checks (config in pyproject [tool.ruff]); the
# runtime container does not ship ruff (no-install constraint), so this
# gate only arms where the dev extras are installed
if command -v ruff >/dev/null 2>&1; then
  ruff check .
else
  echo "ruff not installed; skipping static check (pip install -e '.[dev]')"
fi
