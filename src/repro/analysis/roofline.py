"""Roofline-term extraction from compiled XLA artifacts.

Three terms per (arch x shape x mesh), in seconds (TPU v5e constants):

    compute    = HLO_FLOPs   / (chips * 197e12 FLOP/s)       [bf16 MXU]
    memory     = HLO_bytes   / (chips * 819e9  B/s)           [HBM]
    collective = coll_bytes  / (chips * 50e9   B/s/link)      [ICI]

HLO_FLOPs / bytes come from ``compiled.cost_analysis()`` of the PARTITIONED
module — i.e. PER-DEVICE quantities (verified empirically; the SPMD
executable is the per-device program). The three terms are therefore
per-chip times directly:

    compute_s    = flops_per_device / 197e12
    memory_s     = bytes_per_device / 819e9
    collective_s = collective_bytes_per_device / 50e9

Collective bytes are NOT in cost_analysis: we parse the compiled module text
and sum result-shape bytes of every all-gather / all-reduce / reduce-scatter
/ all-to-all / collective-permute. MODEL_FLOPS (6*N*D dense / 6*N_active*D
MoE) / (chips * flops_per_device) gives the useful-compute ratio — it
catches remat recompute, padding waste, AND replicated work (e.g. batch=1
decode replicated across the data axis shows up as a low ratio).
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass
from typing import Dict

# TPU v5e per-chip constants (assignment-specified)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                   "all-to-all", "collective-permute")

# shapes like f32[128,256]{1,0} or (f32[2,3], bf16[4])
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
    re.M)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes per collective kind from compiled HLO text.
    ``-done`` ops are skipped so async pairs are not double-counted."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVE_OPS}
    for m in _INSTR_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        full = m.group(0)
        if f"{kind}-done" in full:
            continue
        out[kind] += _shape_bytes(shape_str)
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_breakdown: Dict[str, int]
    model_flops: float
    per_device_hbm_bytes: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / PEAK_FLOPS          # per-device flops

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / HBM_BW              # per-device bytes

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / ICI_BW             # per-device coll bytes

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        denom = self.hlo_flops * self.chips
        return self.model_flops / denom if denom else 0.0

    @property
    def roofline_fraction(self) -> float:
        """compute-term / total — how close the step is to compute-bound
        (1.0 = perfectly compute-limited = at the roofline for this shape)."""
        bound = max(self.compute_s, self.memory_s, self.collective_s)
        return self.compute_s / bound if bound else 0.0

    def to_dict(self) -> dict:
        d = asdict(self)
        d.update(compute_s=self.compute_s, memory_s=self.memory_s,
                 collective_s=self.collective_s, dominant=self.dominant,
                 useful_ratio=self.useful_ratio,
                 roofline_fraction=self.roofline_fraction)
        return d


def model_flops(cfg, cell, n_params_total: int, n_params_active: int) -> float:
    """6*N*D (train) / 2*N*D (inference fwd) over the cell's token count."""
    tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode"
                                  else 1)
    n = n_params_active or n_params_total
    mult = 6.0 if cell.kind == "train" else 2.0
    return mult * n * tokens


def from_compiled(arch: str, shape: str, mesh_name: str, chips: int,
                  compiled, model_fl: float) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    bts = float(ca.get("bytes accessed", 0.0))
    text = compiled.as_text()
    coll = collective_bytes(text)
    mem = compiled.memory_analysis()
    per_dev = 0.0
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes"):
        per_dev += float(getattr(mem, attr, 0.0) or 0.0)
    return Roofline(arch=arch, shape=shape, mesh=mesh_name, chips=chips,
                    hlo_flops=flops, hlo_bytes=bts,
                    coll_bytes=float(sum(coll.values())),
                    coll_breakdown=coll, model_flops=model_fl,
                    per_device_hbm_bytes=per_dev)
