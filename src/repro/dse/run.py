"""Whole-life-cost design-space exploration driver.

    PYTHONPATH=src python -m repro.dse.run --suite zoo --budget 200 --seed 0

Runs a seeded search (the three Table-4 baselines ER/TPU/EP are always in
the initial population), promotes the top-k Pareto-frontier points to
cycle-level validation (``repro.sim``), compares the best point against
every baseline *at equal-or-smaller PE/buffer budget*, hill-climbs per-node
GCONV mappings for the best point's spec, and writes three artifacts to
``results/dse/``:

  * ``evals.json``      — the run config + every per-point evaluation
    record;
  * ``frontier.json``   — the (latency, energy, area) Pareto set;
  * ``best.json``       — the best point's spec, per-workload breakdown,
    sim cross-check, baseline-domination verdicts and the mapping-search
    report;
  * ``trajectory.json`` — best-fitness-vs-evaluations convergence curve in
    the shared ``repro.search.trajectory/v1`` schema (``metric: "wlc"``,
    ``[{n, fitness, best_fitness}...]`` in evaluation order), directly
    comparable with the kernel-tuner trajectories under ``results/tune/``.

Exit status is nonzero when a promoted point violates the analytic-vs-sim
agreement contract (``repro.sim.validate``) — the searched designs must stay
inside the region where the cheap fidelity is trustworthy.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Dict, List, Optional, Sequence

from repro.core import accelerators as acc

from .evaluate import SUITES, EvalRecord, Evaluator, load_suite, pareto_front
from repro.search import TrajectoryRecorder

from .search import STRATEGIES, SearchResult, search_mapping
from .space import SpecSpace, baseline_points

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dse")
BASELINES = ("ER", "TPU", "EP")


def _spec_json(spec) -> dict:
    d = dataclasses.asdict(spec)
    d["n_pes"] = spec.n_pes
    return d


def dominates_at_budget(rec: EvalRecord, base: EvalRecord) -> bool:
    """Strictly better whole-life cost while using no more PEs and no more
    buffer capacity than the baseline — the equal-budget domination claim."""
    return (rec.n_pes <= base.n_pes and rec.gb_words <= base.gb_words
            and rec.wlc < base.wlc)


def run_dse(suite: str = "zoo", budget: int = 200, seed: int = 0,
            strategy: str = "genetic", topk: int = 8,
            map_budget: int = 32, out_dir: Optional[str] = RESULTS_DIR,
            reduced: bool = False, quiet: bool = False,
            trace: Optional[str] = None) -> dict:
    """Programmatic entry point; returns the ``best.json`` payload plus the
    frontier and evaluator (used by benchmarks and tests).

    ``trace`` (required for ``--suite serve``, optional elsewhere) scores
    the promoted frontier and the baselines against a recorded serve
    trace via ``repro.syssim`` — the best point is then chosen by the
    system-under-traffic WLC, and the trace's identity + provenance are
    recorded into ``best.json``."""
    if budget < 1:
        raise ValueError(f"--budget must be >= 1, got {budget}")
    if suite == "serve" and trace is None:
        raise ValueError("--suite serve needs --trace PATH "
                         "(a launch/serve.py --trace recording)")
    t0 = time.perf_counter()
    say = (lambda *a: None) if quiet else print
    chains = load_suite(suite, reduced=reduced)
    space = SpecSpace()
    ev = Evaluator(space, chains)
    seeds = baseline_points(space)

    say(f"dse: suite={suite} ({len(chains)} chains) strategy={strategy} "
        f"budget={budget} seed={seed}")
    # points/sec is the committed search-throughput trajectory metric: time
    # the analytic search alone (not suite building, sim promotion or
    # mapping search)
    t_search = time.perf_counter()
    res: SearchResult = STRATEGIES[strategy]().run(
        space, ev.objective, budget, seed=seed,
        seeds=[seeds[b] for b in BASELINES])
    search_s = time.perf_counter() - t_search

    records = ev.records
    frontier = pareto_front(records)
    say(f"dse: {ev.n_evals} points evaluated, frontier size {len(frontier)}")

    # ---- search trajectory: best fitness vs evaluations -------------------
    # Evaluator.cache preserves insertion order, so `records` IS the
    # evaluation order; the shared recorder's running minimum is the
    # convergence curve the strategy benchmarks (and the archgym-style viz
    # loop) consume — same schema as the kernel-tuner trajectories.
    recorder = TrajectoryRecorder(metric="wlc")
    recorder.extend([rec.wlc for rec in records])
    best_so_far = recorder.best_fitness
    evals_to_best = recorder.evals_to_best
    say(f"dse: trajectory converged to wlc {best_so_far:.4f} after "
        f"{evals_to_best}/{len(recorder.entries)} evaluations")

    # ---- multi-fidelity promotion: top-k frontier points -> repro.sim -----
    all_promoted: List[EvalRecord] = []   # every sim promotion feeds the gate
    promoted = ev.promote(frontier[:max(1, topk)])
    all_promoted += promoted
    say(f"dse: promoted {len(promoted)} frontier points to cycle-level sim")

    # ---- system-under-traffic promotion: recorded trace -> repro.syssim ---
    loaded_trace = None
    if trace is not None:
        from repro.obs.trace import load_trace

        loaded_trace = load_trace(trace)
        ev.promote_syssim(promoted, loaded_trace, reduced=reduced)
        say(f"dse: replayed {trace} on {len(promoted)} promoted points "
            f"({len(loaded_trace.serve_requests())} recorded requests)")

    def _rank(r: EvalRecord):
        # the deepest fidelity available decides: trace replay beats
        # per-chain sim beats analytic
        if r.syssim is not None:
            return (r.syssim["wlc"], r.key)
        return ((r.sim or {}).get("wlc", r.wlc), r.key)

    best = min(promoted, key=_rank)

    # ---- baselines, sim-checked the same way ------------------------------
    base_recs: Dict[str, EvalRecord] = {}
    for name in BASELINES:
        rec = ev.score_spec(acc.get(name))
        all_promoted += ev.promote([rec])
        if loaded_trace is not None:
            ev.promote_syssim([rec], loaded_trace, reduced=reduced)
        base_recs[name] = rec
    domination = {}
    for name, base in base_recs.items():
        cands = [r for r in records if dominates_at_budget(r, base)]
        winner = min(cands, key=lambda r: (r.wlc, r.key)) if cands else None
        if winner is not None and winner.fidelity != "sim":
            all_promoted += ev.promote([winner])
        domination[name] = dict(
            baseline_wlc=base.wlc,
            baseline_sim_wlc=(base.sim or {}).get("wlc"),
            dominated=winner is not None,
            by=winner.key if winner else None,
            by_wlc=winner.wlc if winner else None,
            by_sim_wlc=(winner.sim or {}).get("wlc") if winner else None,
            sim_confirmed=bool(
                winner is not None and winner.sim is not None
                and base.sim is not None
                and winner.sim["wlc"] < base.sim["wlc"]),
        )
        say(f"dse: vs {name}: wlc {base.wlc:.3f} -> "
            + (f"{winner.wlc:.3f} ({winner.key[:40]}...) "
               f"sim_confirmed={domination[name]['sim_confirmed']}"
               if winner else "not dominated"))

    agree_ok = all((r.sim or {}).get("within_tolerance")
                   for r in all_promoted)
    say(f"dse: analytic-vs-sim agreement over {len(all_promoted)} promoted "
        f"points: {'ok' if agree_ok else 'VIOLATED'}")

    # ---- mapping search on the best point's spec --------------------------
    best_spec = space.to_spec(best.point)
    mapping_reports = []
    for name, chain in chains:
        _, rep = search_mapping(chain, best_spec, budget=map_budget,
                                seed=seed)
        mapping_reports.append(rep)
    map_gain = max(r["improvement"] for r in mapping_reports)
    say(f"dse: mapping search (budget {map_budget}/chain): max chain "
        f"improvement {map_gain:.4f}x over Algorithm 1")

    wall_s = time.perf_counter() - t0
    payload = dict(
        config=dict(suite=suite, budget=budget, seed=seed, strategy=strategy,
                    topk=topk, map_budget=map_budget, reduced=reduced,
                    trace=trace),
        n_evals=ev.n_evals, wall_s=round(wall_s, 3),
        search_s=round(search_s, 3),
        points_per_sec=round(ev.n_evals / max(search_s, 1e-9), 2),
        best=best.to_json(), best_spec=_spec_json(best_spec),
        baselines={k: v.to_json() for k, v in base_recs.items()},
        domination=domination,
        agreement_ok=bool(agree_ok),
        mapping_search=mapping_reports,
        frontier_size=len(frontier),
        search=dict(strategy=res.strategy, best_score=res.best_score,
                    n_evals=res.n_evals),
        trajectory=dict(points=len(recorder.entries), best_wlc=best_so_far,
                        evals_to_best=evals_to_best),
    )
    if loaded_trace is not None:
        # the served-traffic claim is only as good as the trace it was
        # scored on: stamp the trace's identity (path + content hash +
        # recorded meta) and this run's provenance into best.json
        import hashlib

        from repro.obs import provenance

        with open(trace, "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()
        payload["trace"] = dict(
            path=os.path.abspath(trace), sha256=digest,
            meta=dict(loaded_trace.meta),
            requests=len(loaded_trace.serve_requests()),
            provenance=provenance())

    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, "evals.json"), "w") as f:
            json.dump(dict(config=payload["config"],
                           records=[r.to_json() for r in records]),
                      f, indent=1, default=float)
        with open(os.path.join(out_dir, "frontier.json"), "w") as f:
            json.dump(dict(config=payload["config"],
                           frontier=[r.to_json() for r in frontier]),
                      f, indent=1, default=float)
        with open(os.path.join(out_dir, "best.json"), "w") as f:
            json.dump(payload, f, indent=1, default=float)
        recorder.write(os.path.join(out_dir, "trajectory.json"),
                       config=payload["config"], strategy=res.strategy)
        say(f"dse: wrote {os.path.abspath(out_dir)}/"
            f"{{evals,frontier,best,trajectory}}.json")

    payload["_frontier"] = frontier
    payload["_evaluator"] = ev
    return payload


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--suite", choices=sorted(SUITES), default="zoo")
    ap.add_argument("--budget", type=int, default=200,
                    help="unique analytic point evaluations")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--strategy", choices=sorted(STRATEGIES),
                    default="genetic")
    ap.add_argument("--topk", type=int, default=8,
                    help="frontier points promoted to cycle-level sim "
                         "(clamped to >= 1: the best point is always "
                         "sim-cross-checked)")
    ap.add_argument("--map-budget", type=int, default=32,
                    help="mapping-search trials per chain on the best spec")
    ap.add_argument("--out", default=RESULTS_DIR)
    ap.add_argument("--reduced", action="store_true",
                    help="test-scale chain variants (CI smoke)")
    ap.add_argument("--trace", default=None,
                    help="recorded serve trace (launch/serve.py --trace); "
                         "scores the promoted frontier against the "
                         "recorded traffic via repro.syssim and records "
                         "the trace's provenance into best.json "
                         "(required for --suite serve)")
    args = ap.parse_args(argv)
    if args.suite == "serve" and args.trace is None:
        ap.error("--suite serve requires --trace PATH")
    payload = run_dse(suite=args.suite, budget=args.budget, seed=args.seed,
                      strategy=args.strategy, topk=args.topk,
                      map_budget=args.map_budget, out_dir=args.out,
                      reduced=args.reduced, trace=args.trace)
    # the headline claim counts only sim-confirmed domination (the analytic
    # verdict alone could flip inside the sim agreement tolerance)
    dominated = [k for k, v in payload["domination"].items()
                 if v["sim_confirmed"]]
    print(f"dse: best wlc={payload['best']['wlc']:.4f} "
          f"(sim {payload['best'].get('sim', {}).get('wlc', float('nan')):.4f}) "
          f"dominates at equal budget (sim-confirmed): "
          f"{', '.join(dominated) or 'none'}")
    return 0 if payload["agreement_ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
