"""Whole-life-cost design-space exploration (the repo's third subsystem,
alongside ``repro.exec`` and ``repro.sim``).

Multi-fidelity search over accelerator specs and per-GCONV mappings: every
candidate is scored with the paper's analytic cost model
(``core.costmodel``), and only the Pareto-frontier survivors are promoted to
the cycle-level simulator (``repro.sim``) for validation.

    PYTHONPATH=src python -m repro.dse.run --suite zoo --budget 200 --seed 0
"""
from .evaluate import (EvalRecord, Evaluator, SUITES, area_proxy, geomean,
                       load_suite, pareto_front, suite_names)
from .search import (STRATEGIES, GeneticSearch, RandomSearch, SearchResult,
                     SimulatedAnnealing, search_mapping)
from .space import (FIELDS, PRIORITIES, TEMPORAL_PRIORITIES, Point,
                    SpecSpace, baseline_points)


def __getattr__(name):
    # lazy: importing .run at package-import time would shadow
    # ``python -m repro.dse.run`` (runpy double-import warning)
    if name in ("run_dse", "dominates_at_budget", "RESULTS_DIR"):
        from . import run as _run
        return getattr(_run, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "EvalRecord", "Evaluator", "SUITES", "area_proxy", "geomean",
    "load_suite", "pareto_front", "suite_names",
    "STRATEGIES", "GeneticSearch", "RandomSearch", "SearchResult",
    "SimulatedAnnealing", "search_mapping",
    "FIELDS", "PRIORITIES", "TEMPORAL_PRIORITIES", "Point", "SpecSpace",
    "baseline_points",
    "dominates_at_budget", "run_dse",
]
