"""DSE-facing search surface: shared strategy engines + mapping search.

The spec-space strategy engines (seeded random sampling, simulated
annealing, elitist genetic search, plus the budget-counting scorer and
result record) live in the shared :mod:`repro.search` package — the DSE is
one consumer (accelerator-spec index tuples, analytic WLC objective), the
kernel autotuner (:mod:`repro.exec.tune`) is another. This module re-exports
them under their historical names so ``repro.dse.search.STRATEGIES`` et al.
keep working, and keeps the chain-level *mapping* search, which is
DSE-specific.

Mapping search (:func:`search_mapping`): a chain-level hill climb over
Algorithm-1 *priority variants* — per the paper (§4.4), accelerators differ
only in the parameter priorities of Lines 7-22, so re-running the mapper
under permuted spatial/temporal priorities explores alternative legal
mappings without ever constructing an invalid one. Candidates flow through
``core.costmodel.chain_mappings(overrides=...)`` (and thus
``Mapping.validate``), the climb starts from the greedy Algorithm-1 chain
cost and accepts strict improvements only — so the searched result is
*never worse* than Algorithm 1's output, by construction.
"""
from __future__ import annotations

import random
from dataclasses import replace
from typing import Dict, Tuple

from repro.core.costmodel import gconv_chain_cost
from repro.core.gconv import GConv
from repro.core.mapping import Mapping, map_gconv
from repro.search import (
    BudgetExhausted,
    GeneticSearch,
    RandomSearch,
    Scorer,
    SearchResult,
    SimulatedAnnealing,
    Strategy,
    STRATEGIES,
)

from .space import PRIORITIES, TEMPORAL_PRIORITIES, Point  # noqa: F401

# historical private name, still used by tests exercising budget accounting
_Scorer = Scorer

__all__ = [
    "BudgetExhausted", "GeneticSearch", "Point", "RandomSearch",
    "SearchResult", "SimulatedAnnealing", "Strategy", "STRATEGIES",
    "_Scorer", "search_mapping",
]


# ---------------------------------------------------------------------------
# per-chain GCONV mapping search
# ---------------------------------------------------------------------------
def _variant_spec(spec, rng) -> "object":
    """A priority-permuted copy of ``spec`` (same resources — per §4.4 only
    Algorithm 1's Lines 7-22 change)."""
    spatial = tuple(replace(s, priority=rng.choice(PRIORITIES))
                    for s in spec.spatial)
    return replace(spec, spatial=spatial,
                   temporal_priority=rng.choice(TEMPORAL_PRIORITIES))


def search_mapping(chain, spec, budget: int = 48, seed: int = 0,
                   consistent: bool = True,
                   ) -> Tuple[Dict[str, Mapping], dict]:
    """Hill-climb per-node mappings of an (already fused) chain on ``spec``.

    Each trial re-maps one GCONV node under permuted Algorithm-1 priorities
    and re-scores the *whole chain* (producer/consumer alignment and the
    §4.3 loop exchange react to every per-node change, so node-local scoring
    would not be sound). A candidate override set is kept only when it
    strictly improves chain latency (energy breaks ties), starting from the
    greedy Algorithm-1 chain — the result is therefore never worse than
    Algorithm 1's output.

    Returns ``(overrides, report)``; ``overrides`` maps node name ->
    :class:`Mapping` and plugs directly into
    ``chain_mappings(chain, spec, overrides=...)``, ``gconv_chain_cost`` or
    ``repro.sim.engine.simulate_chain``.
    """
    rng = random.Random(seed)
    base = gconv_chain_cost(chain, spec, consistent=consistent)
    gnodes = [name for name, node in chain.nodes.items()
              if isinstance(node, GConv)]
    overrides: Dict[str, Mapping] = {}
    best = (base.latency, base.energy)
    accepted = 0
    for _ in range(budget if gnodes else 0):
        name = rng.choice(gnodes)
        cand_map = map_gconv(chain.nodes[name], _variant_spec(spec, rng))
        cand = dict(overrides)
        cand[name] = cand_map
        cost = gconv_chain_cost(chain, spec, consistent=consistent,
                                overrides=cand)
        if (cost.latency, cost.energy) < best:
            overrides, best = cand, (cost.latency, cost.energy)
            accepted += 1
    report = dict(
        chain=chain.name, accel=spec.name, budget=budget, seed=seed,
        greedy_latency=base.latency, searched_latency=best[0],
        greedy_energy=base.energy, searched_energy=best[1],
        improvement=base.latency / max(best[0], 1e-12),
        n_overrides=len(overrides), accepted=accepted,
    )
    assert best[0] <= base.latency, "mapping search regressed vs Algorithm 1"
    return overrides, report
