"""Multi-fidelity whole-life-cost evaluation of design points.

Objective (the paper's §7 whole-life framing, folded into one scalar): a
deployment serving fixed traffic needs ``#chips ∝ latency``, each chip's
CAPEX is ``∝ area``, and the fleet's OPEX is ``∝ energy per inference`` —
so, normalizing every term to the Eyeriss (ER) reference point on the same
workload suite,

    WLC = W_CAPEX * (latency/latency_ER) * (area/area_ER)
        + W_OPEX  * (energy/energy_ER)

with latency and energy the *geomeans across the whole suite* (that is the
whole-life claim: one substrate amortized over every current and future
workload, §2) and area a silicon proxy from PE count, scratchpad/global
buffer words and GB port width. ``WLC(ER) == 1`` by construction.

Fidelities:
  * ``analytic`` — ``core.costmodel.gconv_chain_cost`` (Eqs. 6-10), a few ms
    per (point, chain): every searched point is scored here.
  * ``sim``      — ``repro.sim`` cycle-level validation, promoted for the
    top-k frontier points only (:meth:`Evaluator.promote`). Both engines
    charge the *same* ``chain_mappings`` result, so movement and energy must
    agree word-for-word and latency within
    :data:`repro.sim.validate.CYCLES_RATIO_TOL`.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import accelerators as acc
from repro.core.accelerators import AcceleratorSpec
from repro.core.costmodel import chain_mappings, gconv_chain_cost
from repro.core.fusion import fuse_chain

from .space import Point, SpecSpace

# ---------------------------------------------------------------------------
# area proxy (relative units; one PE datapath = 4 scratchpad words)
# ---------------------------------------------------------------------------
A_PE = 4.0            # MAC + control per PE
A_LS_WORD = 0.25      # per-PE scratchpad word (registers/SRAM)
A_GB_WORD = 0.03125   # global-buffer word (denser SRAM)
A_BW_PORT = 64.0      # per word/cycle of GB port width (wires + banking)

W_CAPEX = 0.5
W_OPEX = 0.5

LM_CHAINS = ("lm_dense", "lm_moe")
# "serve" scores the LM chains analytically during the search (the served
# traffic is LM serving) and promotes frontier points into the syssim
# trace-replay fidelity (repro.dse.run --suite serve --trace PATH)
SUITES = ("zoo", "lm", "all", "serve")


def suite_names(suite: str) -> Tuple[str, ...]:
    """Member workloads of a named suite. The zoo membership is derived
    from ``repro.models.cnn.ZOO`` so a network added there is picked up
    here (and by the WLC geomeans / domination verdicts) automatically."""
    from repro.models import cnn

    zoo = tuple(cnn.ZOO)
    return {"zoo": zoo, "lm": LM_CHAINS, "all": zoo + LM_CHAINS,
            "serve": LM_CHAINS}[suite]


def area_proxy(spec: AcceleratorSpec) -> float:
    """Silicon-area/TCO proxy of a spec (works for Table-4 baselines and
    searched points alike — everything is derived from the spec itself)."""
    ls_words = sum(spec.ls.values()) * spec.n_pes
    gb_words = sum(spec.gb.values())
    ports = sum(spec.gb_bandwidth.values())
    return (A_PE * spec.n_pes + A_LS_WORD * ls_words
            + A_GB_WORD * gb_words + A_BW_PORT * ports)


def load_suite(suite: str | Sequence[str],
               reduced: bool = False) -> List[Tuple[str, object]]:
    """Build + fuse the workload chains once (fusion is accelerator- and
    design-point-independent). ``suite`` is a :data:`SUITES` name or an
    explicit list of member names; ``reduced`` selects the small test-scale
    chain variants."""
    from repro.models import cnn

    names = suite_names(suite) if isinstance(suite, str) else tuple(suite)
    out = []
    for name in names:
        if name in LM_CHAINS:
            chain = _lm_chain(name, reduced)
        else:
            chain = cnn.build(name, reduced=reduced)
        out.append((name, fuse_chain(chain)[0]))
    return out


def _lm_chain(name: str, reduced: bool):
    from repro import configs
    from repro.models.lm_chain import block_chain

    arch = "tinyllama-1.1b" if name == "lm_dense" else "olmoe-1b-7b"
    seq = 16 if reduced else 128
    return block_chain(configs.get(arch), batch=1, seq=seq)


def geomean(xs: Sequence[float]) -> float:
    return math.exp(sum(math.log(max(x, 1e-12)) for x in xs) / len(xs))


@dataclass
class EvalRecord:
    """One scored design point (or baseline spec)."""

    key: str                       # canonical point encoding / baseline name
    spec_name: str
    point: Optional[Point]         # None for baseline specs
    lat: float                     # geomean latency (cycles) over the suite
    energy: float                  # geomean energy (relative units)
    area: float
    n_pes: int
    gb_words: int
    wlc: float
    per_chain: Dict[str, Dict[str, float]] = field(default_factory=dict)
    fidelity: str = "analytic"
    sim: Optional[dict] = None     # filled in by Evaluator.promote
    syssim: Optional[dict] = None  # filled in by Evaluator.promote_syssim

    def objectives(self) -> Tuple[float, float, float]:
        """(latency, energy, area) — the Pareto axes, all minimized."""
        return (self.lat, self.energy, self.area)

    def to_json(self) -> dict:
        d = dict(key=self.key, spec=self.spec_name,
                 lat=self.lat, energy=self.energy, area=self.area,
                 n_pes=self.n_pes, gb_words=self.gb_words, wlc=self.wlc,
                 fidelity=self.fidelity, per_chain=self.per_chain)
        if self.sim is not None:
            d["sim"] = self.sim
        if self.syssim is not None:
            d["syssim"] = self.syssim
        return d


def pareto_front(records: Sequence[EvalRecord]) -> List[EvalRecord]:
    """Non-dominated subset under (latency, energy, area), all minimized.
    ``a`` dominates ``b`` iff a <= b on every axis and a < b on at least
    one. Returned sorted by scalar WLC (ties broken by key) so the order is
    deterministic and the head is the promotion queue."""
    out: List[EvalRecord] = []
    for r in records:
        ro = r.objectives()
        dominated = False
        for s in records:
            if s is r:
                continue
            so = s.objectives()
            if all(x <= y for x, y in zip(so, ro)) and so != ro:
                dominated = True
                break
        if not dominated:
            out.append(r)
    # collapse exact-objective duplicates to the lexicographically first key
    seen: Dict[Tuple[float, float, float], EvalRecord] = {}
    for r in sorted(out, key=lambda r: r.key):
        seen.setdefault(r.objectives(), r)
    return sorted(seen.values(), key=lambda r: (r.wlc, r.key))


class Evaluator:
    """Caches analytic scores per point and promotes frontier points to the
    cycle-level simulator. The ER Table-4 spec on the same suite is the
    normalization reference, so ``score_spec(acc.get('ER')).wlc == 1``."""

    def __init__(self, space: SpecSpace, suite: List[Tuple[str, object]],
                 w_capex: float = W_CAPEX, w_opex: float = W_OPEX):
        self.space = space
        self.suite = suite
        self.w_capex = w_capex
        self.w_opex = w_opex
        self.cache: Dict[Point, EvalRecord] = {}
        self.n_evals = 0
        self._ref_raw = self._raw(acc.get("ER"))
        self._ref_lat, self._ref_energy, self._ref_area = self._ref_raw[:3]

    # ------------------------------------------------------------------
    def _raw(self, spec: AcceleratorSpec):
        # the ER reference pass from __init__ is reused for later ER
        # scorings (run_dse scores the baselines through this path too)
        if (spec.name == "ER" and getattr(self, "_ref_raw", None) is not None
                and spec == acc.get("ER")):
            lat, energy, area, per_chain = self._ref_raw
            return lat, energy, area, {k: dict(v)
                                       for k, v in per_chain.items()}
        per_chain: Dict[str, Dict[str, float]] = {}
        lats, energies = [], []
        for name, chain in self.suite:
            cost = gconv_chain_cost(chain, spec)
            per_chain[name] = dict(latency=cost.latency, energy=cost.energy)
            lats.append(cost.latency)
            energies.append(cost.energy)
        return geomean(lats), geomean(energies), area_proxy(spec), per_chain

    def wlc(self, lat: float, energy: float, area: float) -> float:
        return (self.w_capex * (lat / self._ref_lat) * (area / self._ref_area)
                + self.w_opex * (energy / self._ref_energy))

    def score_spec(self, spec: AcceleratorSpec,
                   key: Optional[str] = None,
                   point: Optional[Point] = None) -> EvalRecord:
        """Score an arbitrary spec (baselines; not budget-counted)."""
        lat, energy, area, per_chain = self._raw(spec)
        return EvalRecord(
            key=key or spec.name, spec_name=spec.name, point=point,
            lat=lat, energy=energy, area=area,
            n_pes=spec.n_pes, gb_words=sum(spec.gb.values()),
            wlc=self.wlc(lat, energy, area), per_chain=per_chain)

    def score_point(self, point: Point) -> EvalRecord:
        if point in self.cache:
            return self.cache[point]
        rec = self.score_spec(self.space.to_spec(point),
                              key=self.space.encode(point), point=point)
        self.cache[point] = rec
        self.n_evals += 1
        return rec

    def objective(self, point: Point) -> float:
        return self.score_point(point).wlc

    @property
    def records(self) -> List[EvalRecord]:
        return list(self.cache.values())

    # ------------------------------------------------------------------
    def promote(self, records: Sequence[EvalRecord]) -> List[EvalRecord]:
        """Cycle-level validation of chosen points (the expensive fidelity).

        Re-maps each (point, chain) pair once and feeds the identical
        ``chain_mappings`` result to both engines, then records the sim's
        latency geomean, a sim-corrected WLC, and the agreement checks from
        ``repro.sim.validate`` (compute bound, latency tolerance, exact
        movement/energy parity). Mutates the records in place
        (``fidelity='sim'``) and returns them."""
        from repro.sim.engine import simulate_chain
        from repro.sim.validate import CYCLES_RATIO_TOL, DRIFT_TOL, agreement

        for rec in records:
            spec = (self.space.to_spec(rec.point) if rec.point is not None
                    else acc.get(rec.spec_name))
            sim_lats: List[float] = []
            ratios: Dict[str, float] = {}
            max_mov_drift = max_e_drift = 0.0
            above = within = True
            for name, chain in self.suite:
                pre = chain_mappings(chain, spec)
                analytic = gconv_chain_cost(chain, spec, precomputed=pre)
                sim = simulate_chain(chain, spec, fuse=False,
                                     precomputed=pre)
                agree = agreement(sim.total_cycles, analytic)
                ratios[name] = agree["cycles_ratio"]
                above &= agree["above_compute_bound"]
                within &= agree["within_tolerance"]
                max_mov_drift = max(max_mov_drift, abs(
                    sim.movement_words
                    / max(analytic.movement_words, 1e-12) - 1))
                max_e_drift = max(max_e_drift, abs(
                    sim.energy / max(analytic.energy, 1e-12) - 1))
                sim_lats.append(sim.total_cycles)
                rec.per_chain[name]["sim_cycles"] = sim.total_cycles
            sim_lat = geomean(sim_lats)
            rec.fidelity = "sim"
            rec.sim = dict(
                lat=sim_lat,
                wlc=self.wlc(sim_lat, rec.energy, rec.area),
                cycles_ratio_max=max(ratios.values()),
                cycles_ratio=ratios,
                above_compute_bound=bool(above),
                within_tolerance=bool(
                    within and max_mov_drift <= DRIFT_TOL
                    and max_e_drift <= DRIFT_TOL),
                movement_drift=max_mov_drift,
                energy_drift=max_e_drift,
                cycles_ratio_tol=CYCLES_RATIO_TOL,
            )
        return list(records)

    # ------------------------------------------------------------------
    def promote_syssim(self, records: Sequence[EvalRecord], trace,
                       reduced: bool = False, use_vector: bool = True,
                       lanes: int = 64,
                       bandwidth: float = 16.0) -> List[EvalRecord]:
        """System-under-traffic fidelity: replay a recorded serve trace
        (``repro.syssim.replay``) on each record's system.

        The whole-life framing carries over with the per-chain geomean
        latency replaced by the *makespan serving the recorded traffic*
        (a deployment needs ``#chips ∝ makespan`` to keep up with it) and
        energy by the replay's total energy, both normalized against the
        ER reference system replaying the same trace. The replay clock
        (``tick_cycles``) is calibrated once on the ER reference and held
        fixed across candidates so every record sees the identical
        arrival schedule. Mutates the records in place (``rec.syssim``)
        and returns them."""
        from repro.obs.trace import Trace, load_trace
        from repro.syssim import hetero, replay_trace, single_array
        from repro.syssim.replay import default_chain

        if not isinstance(trace, Trace):
            trace = load_trace(trace)
        chain = default_chain(trace, reduced=reduced)

        def system_for(spec):
            if use_vector:
                return hetero(spec, lanes=lanes, bandwidth=bandwidth)
            return single_array(spec)

        ref = replay_trace(trace, system_for(acc.get("ER")), chain=chain,
                           use_vector=use_vector)
        tick_cycles = ref.tick_cycles
        ref_makespan = ref.report.makespan
        ref_energy = ref.report.energy
        for rec in records:
            spec = (self.space.to_spec(rec.point) if rec.point is not None
                    else acc.get(rec.spec_name))
            res = replay_trace(trace, system_for(spec), chain=chain,
                               tick_cycles=tick_cycles,
                               use_vector=use_vector)
            rep = res.report
            rec.syssim = dict(
                wlc=(self.w_capex * (rep.makespan / ref_makespan)
                     * (rec.area / self._ref_area)
                     + self.w_opex * (rep.energy / ref_energy)),
                makespan_cycles=rep.makespan,
                goodput_tokens_per_kcycle=rep.goodput,
                p50_latency_cycles=rep.latency_percentile(50),
                p99_latency_cycles=rep.latency_percentile(99),
                energy=rep.energy,
                aggregate_utilization=rep.aggregate_utilization,
                contention_stall_share=rep.contention_stall_share,
                requests=res.requests_simulated,
                dropped=res.dropped,
                tick_cycles=tick_cycles,
            )
        return list(records)
