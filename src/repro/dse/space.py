"""Declarative design space over accelerator specs and mapping priorities.

A design *point* is a tuple of indices into per-field choice lists
(:data:`FIELDS`) — hashable, totally ordered, and trivially reproducible.
:meth:`SpecSpace.encode` / :meth:`SpecSpace.decode` give the canonical
``field=value,...`` string form used in artifacts; :meth:`SpecSpace.to_spec`
materializes the :class:`~repro.core.accelerators.AcceleratorSpec` that both
evaluation engines (``core.costmodel``, ``repro.sim``) score directly.

The parameterization covers everything the paper's Table 4 varies between
accelerators (§4.4): the two PE-array axes (sizes, reduce-link placement on
axis 0, overlap-reuse primitives), per-PE scratchpad words, per-type global
buffer capacity and bandwidth, and — because "different accelerators only
change the priorities and resources" of Algorithm 1 — the per-axis and
temporal parameter priorities that steer the mapper. Choice grids include
the exact Table-4 values so ER / TPU / EP are encodable as seed points
(:func:`baseline_points`).

Validity (:meth:`SpecSpace.is_valid`) enforces the *equal-budget* frame the
whole-life-cost comparison needs: PE count and total buffer capacity are
capped at the largest Table-4 baseline budget, so a searched point never
wins by simply spending more silicon than the baselines it is compared to.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Dict, List, Tuple

from repro.core.accelerators import AcceleratorSpec, SpatialDim

Point = Tuple[int, ...]

# Algorithm-1 parameter priorities offered to the search (spatial axes).
PRIORITIES: Tuple[Tuple[str, ...], ...] = (
    ("ks", "opc", "op", "g"),       # Table-4 reduce-axis default
    ("opc", "op", "ks", "g"),       # ER px
    ("op", "opc", "ks", "g"),       # TPU cols / EP sub
    ("op", "ks", "opc", "g"),
    ("opc", "ks", "op", "g"),
    ("g", "op", "opc", "ks"),       # group-first (MoE-style workloads)
)
# temporal unrolling priorities (scratchpad fill order)
TEMPORAL_PRIORITIES: Tuple[Tuple[str, ...], ...] = (
    ("op", "ks", "opc", "g"),       # AcceleratorSpec default
    ("ks", "op", "opc", "g"),
    ("opc", "op", "ks", "g"),
    ("g", "op", "ks", "opc"),
)

_K = 1024
# (name, choices) — choice grids deliberately include the odd Table-4 sizes
# (12x14 ER array, 11 words/cycle TPU kernel bus, ER's 0.05 MB buffers) so
# the paper baselines are exact members of the space.
FIELDS: Tuple[Tuple[str, Tuple], ...] = (
    ("ax0", (2, 4, 8, 12, 16, 32, 64, 128, 256, 512)),   # reduce-link axis
    ("ax1", (1, 2, 4, 8, 14, 16, 32, 64)),
    ("overlap", (0, 1, 2)),      # overlap primitives: none / ax0 only / both
    ("ls_i", (1, 4, 12, 24, 64, 224, 256)),              # per-PE words
    ("ls_k", (1, 4, 12, 24, 64, 224, 256)),
    ("ls_o", (1, 4, 12, 24, 64, 224, 256)),
    ("gb_i", (4 * _K, 16 * _K, 26214, 64 * _K, 131072, 262144, 393216,
              786432)),                                  # GB words per type
    ("gb_k", (4194, 4 * _K, 16 * _K, 64 * _K, 131072, 262144, 393216,
              786432)),
    ("gb_o", (4 * _K, 16 * _K, 26214, 64 * _K, 131072, 262144, 393216,
              786432)),
    ("bw_i", (4, 8, 16, 32, 64, 128, 256)),              # words/cycle
    ("bw_k", (4, 8, 11, 16, 32, 64, 128, 256)),
    ("bw_o", (4, 8, 16, 32, 64, 128, 256)),
    ("prio0", tuple(range(len(PRIORITIES)))),
    ("prio1", tuple(range(len(PRIORITIES)))),
    ("tprio", tuple(range(len(TEMPORAL_PRIORITIES)))),
)

_INDEX = {name: i for i, (name, _) in enumerate(FIELDS)}


@dataclass(frozen=True)
class SpecSpace:
    """Budget-constrained accelerator + mapping-priority search space.

    The default budgets are the largest Table-4 baseline budgets: 4096 PEs
    (TPU 64x64), 3 x 0.75 MB-words of global buffer (EP), and EP-scale total
    scratchpad capacity — the "equal PE/buffer budget" envelope of the
    whole-life-cost comparison.
    """

    max_pes: int = 4096
    max_gb_words: int = 3 * 786432
    max_ls_words: int = 512 * _K          # sum(ls per PE) * n_pes

    # ------------------------------------------------------------------
    @property
    def n_fields(self) -> int:
        return len(FIELDS)

    def values(self, point: Point) -> Dict[str, object]:
        """Decode a point into its ``{field: value}`` dict."""
        self._check_shape(point)
        return {name: choices[i]
                for (name, choices), i in zip(FIELDS, point)}

    def _check_shape(self, point: Point):
        if len(point) != len(FIELDS):
            raise ValueError(f"point has {len(point)} fields, "
                             f"expected {len(FIELDS)}")
        for (name, choices), i in zip(FIELDS, point):
            if not (0 <= i < len(choices)):
                raise ValueError(f"field {name!r}: index {i} out of range")

    # ---- budgets / validity ------------------------------------------
    def budget(self, point: Point) -> Tuple[int, int]:
        """(PE count, total GB words) — the equal-budget comparison pair."""
        v = self.values(point)
        return (v["ax0"] * v["ax1"], v["gb_i"] + v["gb_k"] + v["gb_o"])

    def is_valid(self, point: Point) -> bool:
        v = self.values(point)
        pes = v["ax0"] * v["ax1"]
        gb = v["gb_i"] + v["gb_k"] + v["gb_o"]
        ls = (v["ls_i"] + v["ls_k"] + v["ls_o"]) * pes
        return (pes <= self.max_pes and gb <= self.max_gb_words
                and ls <= self.max_ls_words)

    # ---- canonical string form ---------------------------------------
    def encode(self, point: Point) -> str:
        v = self.values(point)
        return ",".join(f"{name}={v[name]}" for name, _ in FIELDS)

    def decode(self, s: str) -> Point:
        vals: Dict[str, str] = {}
        for part in s.split(","):
            name, _, raw = part.partition("=")
            if not _:
                raise ValueError(f"malformed field {part!r}")
            vals[name] = raw
        point: List[int] = []
        for name, choices in FIELDS:
            if name not in vals:
                raise ValueError(f"missing field {name!r}")
            want = int(vals.pop(name))
            for i, c in enumerate(choices):
                if int(c) == want:
                    point.append(i)
                    break
            else:
                raise ValueError(f"field {name!r}: {want} not in grid "
                                 f"{choices}")
        if vals:
            raise ValueError(f"unknown fields {sorted(vals)}")
        return tuple(point)

    # ---- materialization ---------------------------------------------
    def to_spec(self, point: Point) -> AcceleratorSpec:
        v = self.values(point)
        enc = self.encode(point)
        digest = hashlib.sha1(enc.encode()).hexdigest()[:8]
        ov = v["overlap"]
        spatial = (
            SpatialDim("d0", v["ax0"], reduce=True, overlap=ov >= 1,
                       priority=PRIORITIES[v["prio0"]]),
            SpatialDim("d1", v["ax1"], reduce=False, overlap=ov >= 2,
                       priority=PRIORITIES[v["prio1"]]),
        )
        return AcceleratorSpec(
            name=f"DSE-{digest}", kind="DSE", spatial=spatial,
            ls={"I": v["ls_i"], "K": v["ls_k"], "O": v["ls_o"]},
            gb={"I": v["gb_i"], "K": v["gb_k"], "O": v["gb_o"]},
            gb_bandwidth={"I": v["bw_i"], "K": v["bw_k"], "O": v["bw_o"]},
            temporal_priority=TEMPORAL_PRIORITIES[v["tprio"]],
            offload=False, has_overlap_primitive=ov >= 1)

    # ---- point generation --------------------------------------------
    def sample(self, rng, max_tries: int = 10_000) -> Point:
        for _ in range(max_tries):
            p = tuple(rng.randrange(len(choices)) for _, choices in FIELDS)
            if self.is_valid(p):
                return p
        raise RuntimeError("could not sample a valid point "
                           "(budgets too tight for the grid?)")

    def mutate(self, point: Point, rng, n_fields: int = 1,
               max_tries: int = 1000) -> Point:
        """Resample ``n_fields`` random fields; retries until valid."""
        self._check_shape(point)
        for _ in range(max_tries):
            p = list(point)
            for f in rng.sample(range(len(FIELDS)), n_fields):
                p[f] = rng.randrange(len(FIELDS[f][1]))
            p = tuple(p)
            if p != point and self.is_valid(p):
                return p
        return point

    def crossover(self, a: Point, b: Point, rng) -> Point:
        """Uniform crossover; falls back to mutation-repair when the child
        breaks a budget (e.g. one parent's big array with the other's big
        buffers), and to a parent when even repair cannot restore validity
        (``mutate`` returns its input unchanged after ``max_tries``)."""
        self._check_shape(a)
        self._check_shape(b)
        child = tuple(x if rng.random() < 0.5 else y for x, y in zip(a, b))
        if self.is_valid(child):
            return child
        child = self.mutate(child, rng, n_fields=2)
        return child if self.is_valid(child) else a


def _point_from_values(space: SpecSpace, **values) -> Point:
    return space.decode(",".join(f"{name}={values[name]}"
                                 for name, _ in FIELDS))


def baseline_points(space: SpecSpace) -> Dict[str, Point]:
    """The three paper baselines (ER / TPU / EP, Table 4) encoded as design
    points — exact members of the grid, used to seed every search so the
    explorer always starts from (and therefore never loses to) the
    hand-designed configurations' neighborhoods."""
    pts = {
        "ER": _point_from_values(
            space, ax0=12, ax1=14, overlap=2,
            ls_i=12, ls_k=224, ls_o=24,
            gb_i=26214, gb_k=4194, gb_o=26214,
            bw_i=16, bw_k=16, bw_o=16,
            prio0=0, prio1=1, tprio=0),
        "TPU": _point_from_values(
            space, ax0=64, ax1=64, overlap=0,
            ls_i=1, ls_k=1, ls_o=1,
            gb_i=393216, gb_k=131072, gb_o=393216,
            bw_i=64, bw_k=11, bw_o=64,
            prio0=0, prio1=2, tprio=0),
        "EP": _point_from_values(
            space, ax0=512, ax1=4, overlap=1,
            ls_i=64, ls_k=1, ls_o=1,
            gb_i=786432, gb_k=786432, gb_o=786432,
            bw_i=128, bw_k=128, bw_o=128,
            prio0=0, prio1=2, tprio=0),
    }
    for name, p in pts.items():
        if not space.is_valid(p):
            raise ValueError(f"baseline seed {name} violates space budgets")
    return pts
