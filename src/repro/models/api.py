"""Uniform model API over all families — what launch/train/serve/dryrun use.

    model = build(cfg)
    params = model.init(key)
    loss   = model.loss_fn(params, batch, shard_fn)
    logits, cache = model.decode_step(params, token, cache, shard_fn)
    cache  = model.serve_state_init(batch, max_len)

Serving extras (consumed by repro.exec.serving):

    cache  = model.serve_state_init(batch, max_len, per_slot_pos=True)
        per-slot position vector instead of the lock-step scalar
    model.serve_axes
        dict mapping each serve-state key to the axis that indexes the
        batch slot in that leaf (positions index axis 0 of the ``pos``
        vector; K/V and SSM leaves stack layers first, so the slot is
        axis 1). Slot splicing/reset in the serving engine is pure
        tree arithmetic over this table — no per-family code. The same
        table doubles as the SHARDING table in the engine's mesh mode
        (``ServeEngine(mesh=...)``): the named axis of every leaf shards
        over the mesh's data-parallel bundle (divisibility-guarded via
        repro.shardpolicy), which is sound for exactly the reason
        splicing is — serving programs never communicate across the
        slot axis.
"""
from __future__ import annotations

from types import SimpleNamespace

import jax

from . import encdec, hymba, rwkv6, transformer
from .common import ModelConfig, kv_cache_init

_noshard = lambda x, tag=None: x


def build(cfg: ModelConfig) -> SimpleNamespace:
    if cfg.family in ("dense", "vlm", "moe"):
        ffn_fn = None
        if cfg.n_experts:
            from .moe import moe_ffn
            ffn_fn = moe_ffn

        def loss_fn(params, batch, shard_fn=_noshard):
            return transformer.loss_fn(cfg, params, batch, shard_fn,
                                       ffn_fn=ffn_fn)

        def decode_step(params, token, cache, shard_fn=_noshard):
            return transformer.decode_step(cfg, params, token, cache,
                                           shard_fn, ffn_fn=ffn_fn)

        return SimpleNamespace(
            cfg=cfg,
            init=lambda key: transformer.init_params(cfg, key),
            loss_fn=loss_fn,
            forward=lambda params, tokens, **kw: transformer.forward(
                cfg, params, tokens, ffn_fn=ffn_fn, **kw),
            prefill=lambda params, tokens, **kw: transformer.prefill(
                cfg, params, tokens, ffn_fn=ffn_fn, **kw),
            decode_step=decode_step,
            serve_state_init=lambda batch, max_len, **kw: kv_cache_init(
                cfg, batch, max_len, **kw),
            serve_axes={"k": 1, "v": 1, "pos": 0},
        )

    if cfg.family == "ssm":
        return SimpleNamespace(
            cfg=cfg,
            init=lambda key: rwkv6.init_params(cfg, key),
            loss_fn=lambda params, batch, shard_fn=_noshard:
                rwkv6.loss_fn(cfg, params, batch, shard_fn),
            forward=lambda params, tokens, **kw: rwkv6.forward(
                cfg, params, tokens, **kw),
            decode_step=lambda params, token, cache, shard_fn=_noshard:
                rwkv6.decode_step(cfg, params, token, cache, shard_fn),
            serve_state_init=lambda batch, max_len, per_slot_pos=False:
                rwkv6.init_state(cfg, batch),   # stateful: no positions
            serve_axes={"wkv": 1, "tm_x": 1, "cm_x": 1},
        )

    if cfg.family == "hybrid":
        return SimpleNamespace(
            cfg=cfg,
            init=lambda key: hymba.init_params(cfg, key),
            loss_fn=lambda params, batch, shard_fn=_noshard:
                hymba.loss_fn(cfg, params, batch, shard_fn),
            forward=lambda params, tokens, **kw: hymba.forward(
                cfg, params, tokens, **kw),
            decode_step=lambda params, token, cache, shard_fn=_noshard:
                hymba.decode_step(cfg, params, token, cache, shard_fn),
            serve_state_init=lambda batch, max_len, **kw:
                hymba.serve_state_init(cfg, batch, max_len, **kw),
            serve_axes={"k": 1, "v": 1, "ssm": 1, "pos": 0},
        )

    if cfg.family == "encdec":
        return SimpleNamespace(
            cfg=cfg,
            init=lambda key: encdec.init_params(cfg, key),
            loss_fn=lambda params, batch, shard_fn=_noshard:
                encdec.loss_fn(cfg, params, batch, shard_fn),
            encode=lambda params, src, **kw: encdec.encode(
                cfg, params, src, **kw),
            decode_step=lambda params, token, cache, shard_fn=_noshard:
                encdec.decode_step(cfg, params, token, cache, shard_fn),
            serve_state_init=lambda batch, max_len, src_len=None:
                encdec.serve_state_init(cfg, batch, max_len,
                                        src_len or max_len),
        )

    raise ValueError(f"unknown model family {cfg.family!r}")


def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))
