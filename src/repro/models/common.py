"""Shared LM building blocks: config, norms, RoPE/M-RoPE, GQA attention
(naive / chunked-flash / Pallas), KV caches, FFN, losses.

Everything is a pure function over explicit param pytrees (stacked per-layer
leaves scanned with ``jax.lax.scan`` — one layer's HLO regardless of depth,
which keeps 60-layer dry-run compiles tractable on one CPU core and is also
what a production framework wants for compile time).

GCONV integration (DESIGN.md §3): each of these ops has a GCONV-chain
decomposition in ``core.layers``; the implementations here are the *fused*
execution paths the §4.3 optimizations produce (chain_norm == the fused
FP1..FP4-style norm segment; chunked attention == the fused 5-GCONV
attention segment), tested for equivalence against the chain interpreter.
Since PR 2 they are no longer hand-wired only: the compiled chain engine
(``repro.exec``) recognizes the norm / softmax / attention GCONV segments
and dispatches them to :func:`norm` / :func:`attention_naive` (or the
Pallas ``chain_norm`` / ``flash_attention`` kernels), so any chain using
these patterns gets the fused paths automatically.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# config
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 => d_model // n_heads
    norm: str = "rms"            # rms | layer
    act: str = "silu"            # silu (=> SwiGLU) | gelu (=> plain MLP)
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, int, int] = ()   # M-RoPE (qwen2-vl)
    tie_embeddings: bool = False
    # attention variants
    sliding_window: int = 0      # 0 => full causal
    attn_impl: str = "chunked"   # naive | chunked | pallas
    attn_chunk: int = 1024
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_dense_ff: int = 0        # arctic-style parallel dense residual FFN
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # SSM / hybrid
    ssm_state: int = 0
    ssm_heads: int = 0           # rwkv/mamba head count (head size = d/h)
    # enc-dec
    n_enc_layers: int = 0        # family == encdec: encoder depth
    # frontends (vlm/audio): inputs are precomputed embeddings, not ids
    embed_inputs: bool = False
    # training
    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "nothing"   # nothing | dots (save matmul outputs)
    # perf hillclimb levers (EXPERIMENTS.md §Perf):
    #   "sp"         sequence-parallel activations (shard T over "model")
    #   "tp_serve"   serve params TP-only (no FSDP all-gather per token)
    #   "decode_q"   consistent head_dim sharding through decode attention
    #   "moe_sort"   sort-based MoE dispatch (replaces O(N*E) cumsum)
    perf_flags: Tuple[str, ...] = ()
    # dry-run cost-accounting knobs: XLA cost_analysis counts a while-loop
    # body ONCE, so the dry-run compiles at 2-3 unroll factors and fits
    # total = outside + trips * body (see launch/dryrun.py). These do not
    # change semantics, only HLO structure.
    layer_unroll: int = 1        # layer-stack scan
    time_unroll: int = 1         # attention / wkv chunk scans
    ssm_unroll: int = 1          # per-token ssm scans (hymba)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.hd

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.hd

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def remat_policy(cfg: ModelConfig):
    import jax
    if cfg.remat_policy == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint_policies.nothing_saveable


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------
def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    s = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (s * jax.random.truncated_normal(key, -2, 2, shape,
                                            jnp.float32)).astype(dtype)


def stacked_init(key, n: int, shape, dtype, scale=None):
    return dense_init(key, (n,) + tuple(shape), dtype, scale)


# ---------------------------------------------------------------------------
# norms (fused chain segment; kernels.chain_norm on TPU)
# ---------------------------------------------------------------------------
def norm(x, gamma, beta=None, *, kind: str = "rms", eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "layer":
        xf = xf - xf.mean(-1, keepdims=True)
    y = xf * jax.lax.rsqrt((xf * xf).mean(-1, keepdims=True) + eps)
    y = y * gamma.astype(jnp.float32)
    if beta is not None:
        y = y + beta.astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------
def rope_freqs(hd: int, theta: float):
    return theta ** (-jnp.arange(0, hd // 2, dtype=jnp.float32) / (hd // 2))


def apply_rope(x, positions, theta: float,
               mrope_sections: Tuple[int, ...] = ()):
    """x: (B, T, H, hd); positions: (B, T) int or (B, 3, T) for M-RoPE."""
    B, T, H, hd = x.shape
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    if mrope_sections:
        # Qwen2-VL M-RoPE: frequency slots split into (t, h, w) sections,
        # each rotated by its own position stream.
        assert positions.ndim == 3 and positions.shape[1] == 3
        sec = mrope_sections
        assert sum(sec) == hd // 2, (sec, hd)
        pos_parts = []
        start = 0
        for i, s in enumerate(sec):
            pos_parts.append(
                jnp.broadcast_to(positions[:, i, :, None].astype(jnp.float32),
                                 (B, T, s)))
            start += s
        pos = jnp.concatenate(pos_parts, axis=-1)       # (B, T, hd/2)
        ang = pos * freqs[None, None, :]
    else:
        ang = positions[..., None].astype(jnp.float32) * freqs  # (B,T,hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (the fused 5-GCONV chain segment, three execution paths)
# ---------------------------------------------------------------------------
def _repeat_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    B, T, Hkv, hd = k.shape
    return jnp.repeat(k, n_rep, axis=2)


def attention_naive(q, k, v, *, causal: bool, q_offset=0,
                    sliding_window: int = 0,
                    scale: Optional[float] = None):
    """q: (B,Tq,H,hd); k/v: (B,Tk,H,hd). Reference path (small shapes).

    Also the jnp dispatch target of the compiled chain engine
    (``repro.exec``): a scores->softmax->values GCONV segment lowers to one
    call of this function, with ``scale`` carried over from the segment's
    fused ``post`` scale operator (default: 1/sqrt(hd)).
    """
    B, Tq, H, hd = q.shape
    Tk = k.shape[1]
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (hd ** -0.5 if scale is None
                                             else scale)
    q_ids = q_offset + jnp.arange(Tq)[:, None]
    k_ids = jnp.arange(Tk)[None, :]
    mask = jnp.ones((Tq, Tk), bool)
    if causal:
        mask &= q_ids >= k_ids
    if sliding_window:
        mask &= q_ids - k_ids < sliding_window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def safe_unroll(n_trips: int, u: int) -> int:
    """Unroll factor that divides the trip count (else 1)."""
    return u if (u > 1 and n_trips % u == 0) else 1


def attention_chunked(q, k, v, *, causal: bool, q_offset=0,
                      sliding_window: int = 0, chunk: int = 1024,
                      unroll: int = 1, shard_fn=None):
    """Online-softmax over key chunks in pure JAX (lax.scan) — the fused
    attention chain segment without materializing (Tq, Tk). This is the
    dry-run/roofline path: HLO memory reflects O(Tq*chunk), not O(Tq*Tk)."""
    B, Tq, H, hd = q.shape
    Tk = k.shape[1]
    n_ch = -(-Tk // chunk)
    Tkp = n_ch * chunk
    if Tkp != Tk:
        k = jnp.pad(k, ((0, 0), (0, Tkp - Tk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Tkp - Tk), (0, 0), (0, 0)))
    kc = k.reshape(B, n_ch, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_ch, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    qf = q.astype(jnp.float32) * hd ** -0.5
    q_ids = q_offset + jnp.arange(Tq)[:, None]

    def step(carry, inp):
        acc, m_prev, l_prev = carry
        ci, kci, vci = inp
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kci.astype(jnp.float32))
        k_ids = ci * chunk + jnp.arange(chunk)[None, :]
        mask = k_ids < Tk
        if causal:
            mask = mask & (q_ids >= k_ids)
        if sliding_window:
            mask = mask & (q_ids - k_ids < sliding_window)
        s = jnp.where(mask[None, None], s, -1e30)
        m_cur = jnp.maximum(m_prev, s.max(-1))
        p = jnp.exp(s - m_cur[..., None])
        alpha = jnp.exp(m_prev - m_cur)
        l_cur = l_prev * alpha + p.sum(-1)
        acc = (acc * alpha[..., None]
               + jnp.einsum("bhqk,bkhd->bhqd", p, vci.astype(jnp.float32)))
        return (acc, m_cur, l_cur), None

    init = (jnp.zeros((B, H, Tq, hd), jnp.float32),
            jnp.full((B, H, Tq), -1e30, jnp.float32),
            jnp.zeros((B, H, Tq), jnp.float32))
    if shard_fn is not None:
        # the f32 online-softmax carries are the big live tensors of the
        # chunk sweep: constrain them or GSPMD replicates them per device
        init = (shard_fn(init[0], "attn_state"),
                shard_fn(init[1], "attn_vec"),
                shard_fn(init[2], "attn_vec"))
    (acc, m, l), _ = jax.lax.scan(
        step, init, (jnp.arange(n_ch), kc, vc),
        unroll=safe_unroll(n_ch, unroll))
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    return o.transpose(0, 2, 1, 3).astype(q.dtype)


def attention(cfg: ModelConfig, q, k, v, *, causal=True, q_offset=0,
              shard_fn=None):
    """GQA attention dispatch. q: (B,T,H,hd); k/v: (B,Tk,Hkv,hd)."""
    n_rep = cfg.n_heads // cfg.n_kv_heads
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)
    if cfg.attn_impl == "pallas":
        from repro.kernels import ops as kops
        B, T, H, hd = q.shape
        o = jax.vmap(lambda qi, ki, vi: kops.attention(
            qi, ki, vi, causal=causal, q_offset=q_offset))(
                q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                v.transpose(0, 2, 1, 3))
        return o.transpose(0, 2, 1, 3)
    if cfg.attn_impl == "chunked":
        return attention_chunked(
            q, k, v, causal=causal, q_offset=q_offset,
            sliding_window=cfg.sliding_window, chunk=cfg.attn_chunk,
            unroll=cfg.time_unroll, shard_fn=shard_fn)
    return attention_naive(q, k, v, causal=causal, q_offset=q_offset,
                           sliding_window=cfg.sliding_window)


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------
def ffn(cfg: ModelConfig, p: Dict[str, Any], x):
    """SwiGLU (silu) or plain gelu MLP; weights may carry a gate or not."""
    if cfg.act == "silu":
        g = jnp.einsum("btd,df->btf", x, p["w_gate"].astype(x.dtype))
        u = jnp.einsum("btd,df->btf", x, p["w_up"].astype(x.dtype))
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        u = jnp.einsum("btd,df->btf", x, p["w_up"].astype(x.dtype))
        h = jax.nn.gelu(u.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("btf,fd->btd", h, p["w_down"].astype(x.dtype))


def ffn_param_shapes(cfg: ModelConfig, d_ff: Optional[int] = None):
    f = d_ff or cfg.d_ff
    shapes = {"w_up": (cfg.d_model, f), "w_down": (f, cfg.d_model)}
    if cfg.act == "silu":
        shapes["w_gate"] = (cfg.d_model, f)
    return shapes


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------
def softmax_xent(logits, labels, ignore_id: int = -1):
    """logits: (B,T,V) any dtype; labels: (B,T) int. Mean over valid."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(
        lf, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = lse - ll
    valid = (labels != ignore_id).astype(jnp.float32)
    return (nll * valid).sum() / jnp.maximum(valid.sum(), 1.0)


# ---------------------------------------------------------------------------
# KV cache
# ---------------------------------------------------------------------------
def kv_cache_init(cfg: ModelConfig, batch: int, max_len: int, dtype=None,
                  per_slot_pos: bool = False):
    """Stacked-over-layers KV cache. Sliding-window models allocate only the
    window (ring buffer).

    ``per_slot_pos=True`` allocates ``pos`` as a ``(batch,)`` vector — one
    independent write/mask position per batch row. This is the continuous-
    batching serving layout (launch/serve via repro.exec.serving): each slot
    advances only by its own decoded tokens, so admitting or draining one
    request never moves another slot's position. The default scalar ``pos``
    is the lock-step layout (dry-run decode cells, single-sequence demos).
    """
    L = cfg.n_layers if cfg.family != "encdec" else cfg.n_layers
    size = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    dt = dtype or cdtype(cfg)
    shape = (L, batch, size, cfg.n_kv_heads, cfg.hd)
    pos = (jnp.zeros((batch,), jnp.int32) if per_slot_pos
           else jnp.zeros((), jnp.int32))
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt), "pos": pos}


def kv_cache_append_layer(cache_k, cache_v, pos, k_new, v_new,
                          sliding_window: int = 0):
    """Insert (B, 1, Hkv, hd) at position pos (ring-buffered if windowed).

    ``pos`` may be a scalar (every row writes the same index — lock-step
    decode) or a ``(B,)`` vector (per-slot serving: each row writes at its
    own position)."""
    size = cache_k.shape[1]
    pos = jnp.asarray(pos)
    idx = (pos % size) if sliding_window else jnp.minimum(pos, size - 1)
    if pos.ndim == 0:
        ck = jax.lax.dynamic_update_slice_in_dim(cache_k, k_new, idx, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache_v, v_new, idx, axis=1)
        return ck, cv
    upd = jax.vmap(
        lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(c, n, i, axis=0))
    return upd(cache_k, k_new, idx), upd(cache_v, v_new, idx)
