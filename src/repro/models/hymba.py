"""Hymba — hybrid-head architecture: attention heads and SSM (Mamba-style)
heads run **in parallel** on the same input, their normalized outputs fused
(arXiv:2411.13676). Attention uses a sliding window (sub-quadratic => the
long_500k cell runs for this arch); the SSM path carries (heads x d_head x
ssm_state) recurrent state => O(1) decode.

Simplifications vs. the released checkpoint (recorded in DESIGN.md
§Arch-applicability): no meta-tokens, no cross-layer KV sharing; every layer
is SWA+SSM parallel (the released model mixes 3 full-attention layers in).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .common import (ModelConfig, apply_rope, attention, cdtype, dense_init,
                     ffn, ffn_param_shapes, norm, softmax_xent)
from .transformer import decode_attention

_noshard = lambda x, tag=None: x


def layer_param_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, ...]]:
    D, Q, KV, F, S = (cfg.d_model, cfg.q_dim, cfg.kv_dim, cfg.d_ff,
                      cfg.ssm_state)
    H = cfg.n_heads
    return {
        "ln1": (D,), "ln2": (D,),
        # attention heads
        "wq": (D, Q), "wk": (D, KV), "wv": (D, KV),
        # ssm heads (Mamba2-style, scalar-ish data-dependent transition)
        "s_in": (D, Q),                 # x -> per-head inner stream
        "s_gate": (D, Q),
        "s_dt": (Q, H),                 # per-head step size
        "s_B": (Q, S), "s_C": (Q, S),   # state in/out projections
        "s_A": (H,),                    # per-head log-decay base
        "s_D": (Q,),                    # skip
        # fusion + output
        "beta_attn": (D,), "beta_ssm": (D,),
        "wo": (Q, D),
        **ffn_param_shapes(cfg),
    }


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    dt = cdtype(cfg)
    L = cfg.n_layers
    layers = {}
    for i, (name, shape) in enumerate(sorted(layer_param_shapes(cfg).items())):
        sub = jax.random.fold_in(key, i)
        if name.startswith(("ln", "beta")):
            layers[name] = jnp.ones((L,) + shape, jnp.float32)
        elif name == "s_A":
            layers[name] = jnp.log(
                jnp.broadcast_to(jnp.arange(1, cfg.n_heads + 1, dtype=jnp.float32),
                                 (L, cfg.n_heads)))
        elif name == "s_D":
            layers[name] = jnp.ones((L,) + shape, jnp.float32)
        else:
            layers[name] = dense_init(sub, (L,) + shape, dt)
    k1, k2 = jax.random.split(key)
    return {
        "embed": dense_init(k1, (cfg.vocab, cfg.d_model), dt, scale=1.0),
        "final_ln": jnp.ones((cfg.d_model,), jnp.float32),
        "lm_head": dense_init(k2, (cfg.d_model, cfg.vocab), dt),
        "layers": layers,
    }


def ssm_heads(cfg: ModelConfig, p, x, state):
    """Selective-SSM head path. x: (B,T,D); state: (B,H,hd,S).
    h_t = exp(-dt_t * A) h_{t-1} + dt_t * (x_t  B_t^T);  y = h C_t + D x.

    Two execution paths (tested equal):
      * token scan (reference + decode),
      * perf flag "ssm_chunked": the SSD/linear-attention dual — within a
        chunk, y = tril(exp(cum_t - cum_s) * (C_t . B_s)) @ (dt*x): MXU
        matmuls instead of a length-T sequential chain; the (hd x S) state
        carries across chunks. Every decay exponent is a difference
        cum_t - cum_s <= 0, so the form is overflow-safe by construction.
    """
    B, T, D = x.shape
    H, hd, S = cfg.n_heads, cfg.hd, cfg.ssm_state
    xi = jnp.einsum("btd,dq->btq", x, p["s_in"].astype(x.dtype))
    gate = jnp.einsum("btd,dq->btq", x, p["s_gate"].astype(x.dtype))
    dt = jax.nn.softplus(jnp.einsum(
        "btq,qh->bth", xi.astype(jnp.float32),
        p["s_dt"].astype(jnp.float32)))                        # (B,T,H)
    Bm = jnp.einsum("btq,qs->bts", xi.astype(jnp.float32),
                    p["s_B"].astype(jnp.float32))              # (B,T,S)
    Cm = jnp.einsum("btq,qs->bts", xi.astype(jnp.float32),
                    p["s_C"].astype(jnp.float32))
    A = jnp.exp(p["s_A"].astype(jnp.float32))                  # (H,)
    logd = -dt * A[None, None]                                 # (B,T,H) <= 0
    xh = xi.astype(jnp.float32).reshape(B, T, H, hd)

    chunk = 128
    if "ssm_chunked" in cfg.perf_flags and T > 1 and T % chunk == 0:
        y, state = _ssm_chunked(xh, dt, Bm, Cm, logd, state, chunk,
                                cfg.ssm_unroll)
    else:
        decay = jnp.exp(logd)

        def step(h, inp):
            d_t, x_t, b_t, c_t, dt_t = inp  # (B,H) (B,H,hd) (B,S)x2 (B,H)
            upd = jnp.einsum("bhn,bs->bhns", x_t * dt_t[..., None], b_t)
            h = d_t[..., None, None] * h + upd
            y = jnp.einsum("bhns,bs->bhn", h, c_t)
            return h, y

        xs = (decay.transpose(1, 0, 2), xh.transpose(1, 0, 2, 3),
              Bm.transpose(1, 0, 2), Cm.transpose(1, 0, 2),
              dt.transpose(1, 0, 2))
        from .common import safe_unroll
        state, ys = jax.lax.scan(step, state, xs,
                                 unroll=safe_unroll(T, cfg.ssm_unroll))
        y = ys.transpose(1, 0, 2, 3)
    y = y.reshape(B, T, H * hd)
    y = y + p["s_D"].astype(jnp.float32) * xi.astype(jnp.float32)
    y = y * jax.nn.silu(gate.astype(jnp.float32))
    return y, state


def _ssm_chunked(xh, dt, Bm, Cm, logd, state, chunk: int, unroll: int):
    """Chunk-parallel SSD form. xh: (B,T,H,hd); dt/logd: (B,T,H);
    Bm/Cm: (B,T,S); state: (B,H,hd,S). Returns (y (B,T,H,hd), state)."""
    from .common import safe_unroll

    B, T, H, hd = xh.shape
    nc = T // chunk

    def resh(a):
        return a.reshape((B, nc, chunk) + a.shape[2:]).transpose(
            (1, 0, 2) + tuple(range(3, a.ndim + 1)))

    xc, dtc, bc, cc, ldc = map(resh, (xh, dt, Bm, Cm, logd))

    def per_chunk(h, inp):
        x_, dt_, b_, c_, ld_ = inp        # (B,c,H,hd) (B,c,H) (B,c,S) ...
        cum = jnp.cumsum(ld_, axis=1)     # (B,c,H), <= 0, decreasing
        # inter-chunk: y_t += exp(cum_t) * (h . C_t)
        y = (jnp.exp(cum)[..., None]
             * jnp.einsum("bhns,bcs->bchn", h, c_))
        # intra-chunk: score[t,s] = exp(cum_t - cum_s) * (C_t . B_s), s<=t
        # (mask BEFORE exp: the s>t deltas are positive and would overflow)
        delta = cum[:, :, None, :] - cum[:, None, :, :]        # (B,t,s,H)
        tri = jnp.tril(jnp.ones((chunk, chunk), jnp.bool_))
        delta = jnp.where(tri[None, :, :, None], delta, -jnp.inf)
        cb = jnp.einsum("bts,bus->btu", c_, b_)                # (B,t,s)
        score = jnp.exp(delta) * cb[..., None]                 # (B,t,s,H)
        y = y + jnp.einsum("btuh,buhn->bthn", score,
                           x_ * dt_[..., None])
        # state: h' = exp(cum_last) h + sum_s exp(cum_last - cum_s) upd_s
        k_dec = jnp.exp(cum[:, -1:, :] - cum)                  # (B,c,H)
        h = (jnp.exp(cum[:, -1])[:, :, None, None] * h
             + jnp.einsum("bchn,bcs->bhns",
                          x_ * (dt_ * k_dec)[..., None], b_))
        return h, y

    state, ys = jax.lax.scan(per_chunk, state, (xc, dtc, bc, cc, ldc),
                             unroll=safe_unroll(nc, unroll))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, T, H, hd)
    return y, state


def _fuse(cfg, p, attn_out, ssm_out):
    """Hymba head fusion: per-channel normalized average with learned betas."""
    def nrm(z):
        zf = z.astype(jnp.float32)
        return zf * jax.lax.rsqrt((zf * zf).mean(-1, keepdims=True) + 1e-6)

    return 0.5 * (nrm(attn_out) * p["beta_attn"].astype(jnp.float32)
                  + nrm(ssm_out) * p["beta_ssm"].astype(jnp.float32))


def block(cfg: ModelConfig, p, x, positions, state, shard_fn=_noshard):
    B, T, D = x.shape
    h = norm(x, p["ln1"], kind="rms")
    # attention path (sliding window)
    q = jnp.einsum("btd,dq->btq", h, p["wq"].astype(x.dtype))
    k = jnp.einsum("btd,dq->btq", h, p["wk"].astype(x.dtype))
    v = jnp.einsum("btd,dq->btq", h, p["wv"].astype(x.dtype))
    q = apply_rope(q.reshape(B, T, cfg.n_heads, cfg.hd), positions,
                   cfg.rope_theta)
    k = apply_rope(k.reshape(B, T, cfg.n_kv_heads, cfg.hd), positions,
                   cfg.rope_theta)
    v = v.reshape(B, T, cfg.n_kv_heads, cfg.hd)
    attn_out = attention(cfg, q, k, v, causal=True,
                         shard_fn=shard_fn).reshape(B, T, cfg.q_dim)
    # ssm path (parallel, same input)
    ssm_out, new_state = ssm_heads(cfg, p, h, state)
    fused = _fuse(cfg, p, attn_out, ssm_out).astype(x.dtype)
    x = x + jnp.einsum("btq,qd->btd", fused, p["wo"].astype(x.dtype))
    x = shard_fn(x, "act")
    h2 = norm(x, p["ln2"], kind="rms")
    x = x + ffn(cfg, p, h2)
    return shard_fn(x, "act"), new_state


def init_state(cfg: ModelConfig, batch: int):
    return jnp.zeros((cfg.n_layers, batch, cfg.n_heads, cfg.hd,
                      cfg.ssm_state), jnp.float32)


def forward(cfg: ModelConfig, params, tokens, shard_fn=_noshard):
    B, T = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    x = params["embed"][tokens].astype(cdtype(cfg))
    state = init_state(cfg, B)

    blk = functools.partial(block, cfg, shard_fn=shard_fn)
    if cfg.remat:
        from .common import remat_policy
        blk = jax.checkpoint(blk, policy=remat_policy(cfg))

    def scan_body(x, layer_in):
        p_layer, st = layer_in
        x, st2 = blk(p_layer, x, positions, st)
        return x, st2

    from .common import safe_unroll
    x, _ = jax.lax.scan(scan_body, x, (params["layers"], state),
                        unroll=safe_unroll(cfg.n_layers, cfg.layer_unroll))
    x = norm(x, params["final_ln"], kind="rms")
    logits = jnp.einsum("btd,dv->btv", x, params["lm_head"].astype(x.dtype))
    return shard_fn(logits, "logits")


def loss_fn(cfg: ModelConfig, params, batch, shard_fn=_noshard):
    logits = forward(cfg, params, batch["tokens"], shard_fn=shard_fn)
    return softmax_xent(logits, batch["labels"])


# ---------------------------------------------------------------------------
# serving: windowed KV cache + SSM state
# ---------------------------------------------------------------------------
def serve_state_init(cfg: ModelConfig, batch: int, max_len: int,
                     per_slot_pos: bool = False):
    win = min(cfg.sliding_window or max_len, max_len)
    dt = cdtype(cfg)
    pos = (jnp.zeros((batch,), jnp.int32) if per_slot_pos
           else jnp.zeros((), jnp.int32))
    return {
        "k": jnp.zeros((cfg.n_layers, batch, win, cfg.n_kv_heads, cfg.hd), dt),
        "v": jnp.zeros((cfg.n_layers, batch, win, cfg.n_kv_heads, cfg.hd), dt),
        "ssm": init_state(cfg, batch),
        "pos": pos,
    }


def decode_step(cfg: ModelConfig, params, token, cache, shard_fn=_noshard):
    """cache["pos"] may be scalar (lock-step) or (B,) per-slot (serving)."""
    from .common import kv_cache_append_layer

    B = token.shape[0]
    pos = cache["pos"]
    pos_b = (jnp.broadcast_to(pos[None], (B,)) if jnp.ndim(pos) == 0
             else pos)
    positions = pos_b[:, None]
    x = params["embed"][token].astype(cdtype(cfg))

    def scan_body(x, layer_in):
        p, ck, cv, st = layer_in
        h = norm(x, p["ln1"], kind="rms")
        q = jnp.einsum("btd,dq->btq", h, p["wq"].astype(x.dtype))
        k = jnp.einsum("btd,dq->btq", h, p["wk"].astype(x.dtype))
        v = jnp.einsum("btd,dq->btq", h, p["wv"].astype(x.dtype))
        q = apply_rope(q.reshape(B, 1, cfg.n_heads, cfg.hd), positions,
                       cfg.rope_theta)
        k = apply_rope(k.reshape(B, 1, cfg.n_kv_heads, cfg.hd), positions,
                       cfg.rope_theta)
        v = v.reshape(B, 1, cfg.n_kv_heads, cfg.hd)
        ck, cv = kv_cache_append_layer(ck, cv, pos, k, v, cfg.sliding_window)
        attn_out = decode_attention(cfg, q, ck, cv, pos).reshape(B, 1,
                                                                 cfg.q_dim)
        ssm_out, st2 = ssm_heads(cfg, p, h, st)
        fused = _fuse(cfg, p, attn_out, ssm_out).astype(x.dtype)
        x = x + jnp.einsum("btq,qd->btd", fused, p["wo"].astype(x.dtype))
        h2 = norm(x, p["ln2"], kind="rms")
        x = x + ffn(cfg, p, h2)
        return x, (ck, cv, st2)

    from .common import safe_unroll
    x, (ck, cv, st) = jax.lax.scan(
        scan_body, x, (params["layers"], cache["k"], cache["v"],
                       cache["ssm"]),
        unroll=safe_unroll(cfg.n_layers, cfg.layer_unroll))
    x = norm(x, params["final_ln"], kind="rms")
    logits = jnp.einsum("btd,dv->btv", x, params["lm_head"].astype(x.dtype))
    return shard_fn(logits, "logits"), {
        "k": ck, "v": cv, "ssm": st, "pos": pos + 1}
