"""The paper's seven benchmark CNNs as GCONV chains (Table 1(a)).

  AN    AlexNet            — LRN, dropout
  GLN   GoogLeNet          — ave pool, concat
  DN    DenseNet-121       — batch norm, scale
  MN    MobileNet v1       — depthwise conv
  ZFFR  ZFNet+Faster R-CNN — RoI pooling, proposal
  C3D   C3D                — 3-D conv, 3-D pool
  CapNN CapsNet            — primary/digit capsules (dynamic routing)

Every builder returns a full-size analysis :class:`Chain` (chains are
metadata — nothing is allocated; the interpreter only ever executes reduced
variants, see ``reduced=True``). Layer/traditional tags drive the Table-1 and
baseline-offload benchmarks.

Training-mode microbenchmarks (FP+BP) are provided for the paper's own
example (batch norm, Table 2) via :func:`training_block_chain`.
"""
from __future__ import annotations


from repro.core import layers as L
from repro.core.chain import Chain, Movement
from repro.core.gconv import DimSpec, GConv, Op


# ---------------------------------------------------------------------------
# AlexNet
# ---------------------------------------------------------------------------
def alexnet(batch: int = 32, reduced: bool = False) -> Chain:
    if reduced:
        return _alexnet_reduced(batch)
    c = Chain("AN")
    x = c.add_input("x", (batch, 3, 227, 227))
    x = L.conv2d(c, x, out_c=96, k=11, stride=4, name="conv1")
    x = L.relu(c, x)
    x = L.lrn(c, x)
    x = L.maxpool2d(c, x, k=3, stride=2)
    x = L.conv2d(c, x, out_c=256, k=5, pad=2, groups=2, name="conv2")
    x = L.relu(c, x)
    x = L.lrn(c, x)
    x = L.maxpool2d(c, x, k=3, stride=2)
    x = L.conv2d(c, x, out_c=384, k=3, pad=1, name="conv3")
    x = L.relu(c, x)
    x = L.conv2d(c, x, out_c=384, k=3, pad=1, groups=2, name="conv4")
    x = L.relu(c, x)
    x = L.conv2d(c, x, out_c=256, k=3, pad=1, groups=2, name="conv5")
    x = L.relu(c, x)
    x = L.maxpool2d(c, x, k=3, stride=2)
    x = L.view(c, x, (batch, 256 * 6 * 6))
    x = L.fc(c, x, out_f=4096, name="fc6")
    x = L.relu(c, x)
    x = L.dropout(c, x)
    x = L.fc(c, x, out_f=4096, name="fc7")
    x = L.relu(c, x)
    x = L.dropout(c, x)
    x = L.fc(c, x, out_f=1000, name="fc8")
    x = L.softmax(c, x)
    c.mark_output(x)
    return c


def _alexnet_reduced(batch: int) -> Chain:
    c = Chain("AN-reduced")
    x = c.add_input("x", (batch, 3, 19, 19))
    x = L.conv2d(c, x, out_c=8, k=3, stride=2, name="conv1")
    x = L.relu(c, x)
    x = L.lrn(c, x, n=3)
    x = L.maxpool2d(c, x, k=3, stride=2)
    x = L.conv2d(c, x, out_c=16, k=3, pad=1, groups=2, name="conv2")
    x = L.relu(c, x)
    x = L.view(c, x, (batch, 16 * 4 * 4))
    x = L.fc(c, x, out_f=32, name="fc6")
    x = L.relu(c, x)
    x = L.dropout(c, x)
    x = L.fc(c, x, out_f=10, name="fc8")
    x = L.softmax(c, x)
    c.mark_output(x)
    return c


# ---------------------------------------------------------------------------
# GoogLeNet (Inception v1)
# ---------------------------------------------------------------------------
_INCEPTION = {  # name: (b1, b3r, b3, b5r, b5, pool_proj)
    "3a": (64, 96, 128, 16, 32, 32),
    "3b": (128, 128, 192, 32, 96, 64),
    "4a": (192, 96, 208, 16, 48, 64),
    "4b": (160, 112, 224, 24, 64, 64),
    "4c": (128, 128, 256, 24, 64, 64),
    "4d": (112, 144, 288, 32, 64, 64),
    "4e": (256, 160, 320, 32, 128, 128),
    "5a": (256, 160, 320, 32, 128, 128),
    "5b": (384, 192, 384, 48, 128, 128),
}


def _inception(c: Chain, x: str, cfg, name: str) -> str:
    b1, b3r, b3, b5r, b5, pp = cfg
    y1 = L.conv2d(c, x, out_c=b1, k=1, name=f"{name}.1x1")
    y1 = L.relu(c, y1)
    y3 = L.conv2d(c, x, out_c=b3r, k=1, name=f"{name}.3x3r")
    y3 = L.relu(c, y3)
    y3 = L.conv2d(c, y3, out_c=b3, k=3, pad=1, name=f"{name}.3x3")
    y3 = L.relu(c, y3)
    y5 = L.conv2d(c, x, out_c=b5r, k=1, name=f"{name}.5x5r")
    y5 = L.relu(c, y5)
    y5 = L.conv2d(c, y5, out_c=b5, k=5, pad=2, name=f"{name}.5x5")
    y5 = L.relu(c, y5)
    yp = L.maxpool2d(c, x, k=3, stride=1, pad=1, name=f"{name}.pool")
    yp = L.conv2d(c, yp, out_c=pp, k=1, name=f"{name}.proj")
    yp = L.relu(c, yp)
    return L.concat(c, [y1, y3, y5, yp], axis=1, name=f"{name}.concat")


def googlenet(batch: int = 32, reduced: bool = False) -> Chain:
    if reduced:
        return _googlenet_reduced(batch)
    c = Chain("GLN")
    x = c.add_input("x", (batch, 3, 224, 224))
    x = L.conv2d(c, x, out_c=64, k=7, stride=2, pad=3, name="conv1")
    x = L.relu(c, x)
    x = L.maxpool2d(c, x, k=3, stride=2, ceil_mode=True)
    x = L.lrn(c, x)
    x = L.conv2d(c, x, out_c=64, k=1, name="conv2r")
    x = L.relu(c, x)
    x = L.conv2d(c, x, out_c=192, k=3, pad=1, name="conv2")
    x = L.relu(c, x)
    x = L.lrn(c, x)
    x = L.maxpool2d(c, x, k=3, stride=2, ceil_mode=True)
    for n in ("3a", "3b"):
        x = _inception(c, x, _INCEPTION[n], n)
    x = L.maxpool2d(c, x, k=3, stride=2, ceil_mode=True)
    for n in ("4a", "4b", "4c", "4d", "4e"):
        x = _inception(c, x, _INCEPTION[n], n)
    x = L.maxpool2d(c, x, k=3, stride=2, ceil_mode=True)
    for n in ("5a", "5b"):
        x = _inception(c, x, _INCEPTION[n], n)
    x = L.global_avgpool2d(c, x)
    x = L.dropout(c, x, rate=0.4)
    x = L.view(c, x, (batch, 1024))
    x = L.fc(c, x, out_f=1000, name="loss3")
    x = L.softmax(c, x)
    c.mark_output(x)
    return c


def _googlenet_reduced(batch: int) -> Chain:
    c = Chain("GLN-reduced")
    x = c.add_input("x", (batch, 3, 16, 16))
    x = L.conv2d(c, x, out_c=8, k=3, stride=2, pad=1, name="conv1")
    x = L.relu(c, x)
    x = _inception(c, x, (4, 4, 8, 2, 4, 4), "3a")
    x = L.global_avgpool2d(c, x)
    x = L.view(c, x, (batch, 20))
    x = L.fc(c, x, out_f=10)
    x = L.softmax(c, x)
    c.mark_output(x)
    return c


# ---------------------------------------------------------------------------
# DenseNet-121
# ---------------------------------------------------------------------------
def _bn_scale_relu(c: Chain, x: str, name: str) -> str:
    y, _ = L.batch_norm_fp(c, x, name=f"{name}.bn")
    y = L.scale_layer(c, y, name=f"{name}.scale")
    return L.relu(c, y)


def densenet121(batch: int = 32, reduced: bool = False,
                growth: int = 32) -> Chain:
    if reduced:
        return _densenet_reduced(batch)
    blocks = (6, 12, 24, 16)
    c = Chain("DN")
    x = c.add_input("x", (batch, 3, 224, 224))
    x = L.conv2d(c, x, out_c=64, k=7, stride=2, pad=3, bias=False,
                 name="conv1")
    x = _bn_scale_relu(c, x, "conv1")
    x = L.maxpool2d(c, x, k=3, stride=2, pad=1)
    ch = 64
    for bi, n_layers in enumerate(blocks):
        for li in range(n_layers):
            name = f"b{bi}l{li}"
            y = _bn_scale_relu(c, x, f"{name}.a")
            y = L.conv2d(c, y, out_c=4 * growth, k=1, bias=False,
                         name=f"{name}.conv1x1")
            y = _bn_scale_relu(c, y, f"{name}.b")
            y = L.conv2d(c, y, out_c=growth, k=3, pad=1, bias=False,
                         name=f"{name}.conv3x3")
            x = L.concat(c, [x, y], axis=1, name=f"{name}.cat")
            ch += growth
        if bi < len(blocks) - 1:
            name = f"t{bi}"
            x = _bn_scale_relu(c, x, name)
            ch //= 2
            x = L.conv2d(c, x, out_c=ch, k=1, bias=False, name=f"{name}.conv")
            x = L.avgpool2d(c, x, k=2, stride=2, name=f"{name}.pool")
    x = _bn_scale_relu(c, x, "final")
    x = L.global_avgpool2d(c, x)
    x = L.view(c, x, (batch, ch))
    x = L.fc(c, x, out_f=1000)
    x = L.softmax(c, x)
    c.mark_output(x)
    return c


def _densenet_reduced(batch: int) -> Chain:
    c = Chain("DN-reduced")
    x = c.add_input("x", (batch, 3, 16, 16))
    x = L.conv2d(c, x, out_c=8, k=3, stride=2, pad=1, bias=False)
    x = _bn_scale_relu(c, x, "stem")
    for li in range(2):
        y = _bn_scale_relu(c, x, f"l{li}.a")
        y = L.conv2d(c, y, out_c=8, k=1, bias=False, name=f"l{li}.c1")
        y = _bn_scale_relu(c, y, f"l{li}.b")
        y = L.conv2d(c, y, out_c=4, k=3, pad=1, bias=False, name=f"l{li}.c3")
        x = L.concat(c, [x, y], axis=1, name=f"l{li}.cat")
    x = L.global_avgpool2d(c, x)
    x = L.view(c, x, (batch, 16))
    x = L.fc(c, x, out_f=10)
    x = L.softmax(c, x)
    c.mark_output(x)
    return c


# ---------------------------------------------------------------------------
# MobileNet v1
# ---------------------------------------------------------------------------
_MOBILENET_CFG = [  # (out_c, stride) for depthwise-separable pairs
    (64, 1), (128, 2), (128, 1), (256, 2), (256, 1), (512, 2),
    (512, 1), (512, 1), (512, 1), (512, 1), (512, 1), (1024, 2), (1024, 1),
]


def mobilenet(batch: int = 32, reduced: bool = False) -> Chain:
    if reduced:
        return _mobilenet_reduced(batch)
    c = Chain("MN")
    x = c.add_input("x", (batch, 3, 224, 224))
    x = L.conv2d(c, x, out_c=32, k=3, stride=2, pad=1, bias=False,
                 name="conv1")
    x = _bn_scale_relu(c, x, "conv1")
    ch = 32
    for i, (out_c, s) in enumerate(_MOBILENET_CFG):
        x = L.conv2d(c, x, out_c=ch, k=3, stride=s, pad=1, groups=ch,
                     bias=False, name=f"dw{i}")
        x = _bn_scale_relu(c, x, f"dw{i}")
        x = L.conv2d(c, x, out_c=out_c, k=1, bias=False, name=f"pw{i}")
        x = _bn_scale_relu(c, x, f"pw{i}")
        ch = out_c
    x = L.global_avgpool2d(c, x)
    x = L.view(c, x, (batch, 1024))
    x = L.fc(c, x, out_f=1000)
    x = L.softmax(c, x)
    c.mark_output(x)
    return c


def _mobilenet_reduced(batch: int) -> Chain:
    c = Chain("MN-reduced")
    x = c.add_input("x", (batch, 3, 16, 16))
    x = L.conv2d(c, x, out_c=8, k=3, stride=2, pad=1, bias=False)
    x = _bn_scale_relu(c, x, "stem")
    x = L.conv2d(c, x, out_c=8, k=3, pad=1, groups=8, bias=False, name="dw0")
    x = _bn_scale_relu(c, x, "dw0")
    x = L.conv2d(c, x, out_c=16, k=1, bias=False, name="pw0")
    x = _bn_scale_relu(c, x, "pw0")
    x = L.global_avgpool2d(c, x)
    x = L.view(c, x, (batch, 16))
    x = L.fc(c, x, out_f=10)
    x = L.softmax(c, x)
    c.mark_output(x)
    return c


# ---------------------------------------------------------------------------
# ZFNet + Faster R-CNN
# ---------------------------------------------------------------------------
def zffr(batch: int = 1, n_rois: int = 128, reduced: bool = False) -> Chain:
    if reduced:
        batch, n_rois, hw = 1, 4, 35
    else:
        hw = 224
    c = Chain("ZFFR" + ("-reduced" if reduced else ""))
    x = c.add_input("x", (batch, 3, hw, hw))
    if reduced:
        x = L.conv2d(c, x, out_c=8, k=7, stride=2, pad=1, name="conv1")
        feat_c = 8
    else:
        x = L.conv2d(c, x, out_c=96, k=7, stride=2, pad=1, name="conv1")
        x = L.relu(c, x)
        x = L.lrn(c, x)
        x = L.maxpool2d(c, x, k=3, stride=2, pad=1, ceil_mode=True)
        x = L.conv2d(c, x, out_c=256, k=5, stride=2, pad=1, name="conv2")
        x = L.relu(c, x)
        x = L.lrn(c, x)
        x = L.maxpool2d(c, x, k=3, stride=2, pad=1, ceil_mode=True)
        x = L.conv2d(c, x, out_c=384, k=3, pad=1, name="conv3")
        x = L.relu(c, x)
        x = L.conv2d(c, x, out_c=384, k=3, pad=1, name="conv4")
        x = L.relu(c, x)
        x = L.conv2d(c, x, out_c=256, k=3, pad=1, name="conv5")
        feat_c = 256
    x = L.relu(c, x)
    _, _, fh, fw = c.shape_of(x)
    # RPN head
    r = L.conv2d(c, x, out_c=feat_c, k=3, pad=1, name="rpn.conv")
    r = L.relu(c, r)
    cls = L.conv2d(c, r, out_c=18, k=1, name="rpn.cls")
    cls = L.view(c, cls, (batch, 2, 9 * fh, fw), name="rpn.cls_view")
    cls = L.softmax(c, cls, axis=1, name="rpn.cls_prob")
    bbox = L.conv2d(c, r, out_c=36, k=1, name="rpn.bbox")
    # proposal layer: anchor scoring + NMS — pure data movement/sort on the
    # scored anchors (non-traditional; offloaded by CIP baselines)
    prop = c.add(Movement(name="proposal", input=cls,
                          out_shape=(n_rois, 4), gather=True),
                 layer="proposal", traditional=False)
    # RoI pooling: gather (movement) + per-RoI max-pool to 6x6
    roi_sz = 6
    gather = c.add(Movement(name="roi.gather", input=x, perm=None,
                            out_shape=(n_rois, feat_c,
                                       2 * roi_sz, 2 * roi_sz),
                            gather=True),
                   layer="roi_pool", traditional=False)
    # NB: gather re-tiles (fh,fw) -> per-roi 12x12 regions; element count
    # changes are movement-level detail, modeled by the out_shape above.
    pooled = c.add(
        GConv(name="roi.pool",
              dims=(DimSpec("B", ng=n_rois), DimSpec("C", ng=feat_c),
                    DimSpec("H", nopc=roi_sz, nks=2, stride=2),
                    DimSpec("W", nopc=roi_sz, nks=2, stride=2)),
              input=gather, main="none", reduce="max"),
        layer="roi_pool", traditional=False)
    x = L.view(c, pooled, (n_rois, feat_c * roi_sz * roi_sz))
    fcw = 128 if reduced else 4096
    x = L.fc(c, x, out_f=fcw, name="fc6")
    x = L.relu(c, x)
    x = L.dropout(c, x)
    x = L.fc(c, x, out_f=fcw, name="fc7")
    x = L.relu(c, x)
    cls_s = L.fc(c, x, out_f=21, name="cls_score")
    cls_p = L.softmax(c, cls_s, name="cls_prob")
    bbox_p = L.fc(c, x, out_f=84, name="bbox_pred")
    c.mark_output(cls_p)
    c.mark_output(bbox_p)
    return c


# ---------------------------------------------------------------------------
# C3D
# ---------------------------------------------------------------------------
def c3d(batch: int = 8, reduced: bool = False) -> Chain:
    c = Chain("C3D" + ("-reduced" if reduced else ""))
    if reduced:
        x = c.add_input("x", (batch, 3, 4, 12, 12))
        x = L.conv3d(c, x, out_c=8, k=3, kt=3, pad=1, pad_t=1, name="conv1a")
        x = L.relu(c, x)
        x = L.maxpool3d(c, x, k=2, stride=2, kt=1, stride_t=1)
        x = L.view(c, x, (batch, 8 * 4 * 6 * 6))
        x = L.fc(c, x, out_f=32, name="fc6")
        x = L.relu(c, x)
        x = L.fc(c, x, out_f=10, name="fc8")
        x = L.softmax(c, x)
        c.mark_output(x)
        return c
    x = c.add_input("x", (batch, 3, 16, 112, 112))
    x = L.conv3d(c, x, out_c=64, k=3, kt=3, pad=1, pad_t=1, name="conv1a")
    x = L.relu(c, x)
    x = L.maxpool3d(c, x, k=2, stride=2, kt=1, stride_t=1, name="pool1")
    x = L.conv3d(c, x, out_c=128, k=3, kt=3, pad=1, pad_t=1, name="conv2a")
    x = L.relu(c, x)
    x = L.maxpool3d(c, x, k=2, stride=2, kt=2, stride_t=2, name="pool2")
    for i, ch in ((3, 256), (4, 512), (5, 512)):
        x = L.conv3d(c, x, out_c=ch, k=3, kt=3, pad=1, pad_t=1,
                     name=f"conv{i}a")
        x = L.relu(c, x)
        x = L.conv3d(c, x, out_c=ch, k=3, kt=3, pad=1, pad_t=1,
                     name=f"conv{i}b")
        x = L.relu(c, x)
        x = L.maxpool3d(c, x, k=2, stride=2, kt=2, stride_t=2,
                        name=f"pool{i}")
    x = L.view(c, x, (batch, 512 * 1 * 3 * 3))
    x = L.fc(c, x, out_f=4096, name="fc6")
    x = L.relu(c, x)
    x = L.dropout(c, x)
    x = L.fc(c, x, out_f=4096, name="fc7")
    x = L.relu(c, x)
    x = L.dropout(c, x)
    x = L.fc(c, x, out_f=487, name="fc8")
    x = L.softmax(c, x)
    c.mark_output(x)
    return c


# ---------------------------------------------------------------------------
# CapsNet (dynamic routing, 3 iterations unrolled)
# ---------------------------------------------------------------------------
def _squash(c: Chain, x: str, name: str) -> str:
    """v = (||s||^2 / (1+||s||^2)) * s / ||s|| over the capsule D axis.
    x: (B, NCaps, D). GCONVs: squared-norm reduce, two coefficient nodes,
    two elementwise multiplies (same recipe as Table 2's LUT-class posts)."""
    B, N, D = c.shape_of(x)
    nrm = c.add(GConv(name=f"{name}.n2",
                      dims=(DimSpec("B", ng=B), DimSpec("N", ng=N),
                            DimSpec("D", nks=D)),
                      input=x, pre=(Op("square"),), main="none",
                      reduce="add"),
                layer="capsule", traditional=False)       # ||s||^2
    coef = c.add(GConv(name=f"{name}.coef",
                       dims=(DimSpec("B", ng=B), DimSpec("N", ng=N),
                             DimSpec("D", ng=1)),
                       input=nrm, main="none", reduce="none",
                       post=(Op("add_const", const=1.0), Op("recip"),
                             Op("mul", operand=nrm))),
                 layer="capsule", traditional=False)      # n2/(1+n2)
    rs = c.add(GConv(name=f"{name}.rs",
                     dims=(DimSpec("B", ng=B), DimSpec("N", ng=N),
                           DimSpec("D", ng=1)),
                     input=nrm, main="none", reduce="none",
                     post=(Op("rsqrt_eps", const=1e-7),)),
               layer="capsule", traditional=False)        # 1/||s||
    scaled = c.add(GConv(name=f"{name}.v",
                         dims=(DimSpec("B", ng=B), DimSpec("N", ng=N),
                               DimSpec("D", ng=D)),
                         input=x, kernel=coef, main="mul", reduce="none"),
                   layer="capsule", traditional=False)
    v = c.add(GConv(name=f"{name}.out",
                    dims=(DimSpec("B", ng=B), DimSpec("N", ng=N),
                          DimSpec("D", ng=D)),
                    input=scaled, kernel=rs, main="mul", reduce="none"),
              layer="capsule", traditional=False)
    return v


def capsnet(batch: int = 32, reduced: bool = False,
            routing_iters: int = 3) -> Chain:
    c = Chain("CapNN" + ("-reduced" if reduced else ""))
    if reduced:
        x = c.add_input("x", (batch, 1, 12, 12))
        x = L.conv2d(c, x, out_c=16, k=5, name="conv1")
        x = L.relu(c, x)
        x = L.conv2d(c, x, out_c=16, k=5, stride=2, name="prim.conv")
        n_caps, caps_d, n_out, out_d = 2 * 2 * 2, 8, 4, 8
        x = L.view(c, x, (batch, n_caps, caps_d), name="prim.view")
    else:
        x = c.add_input("x", (batch, 1, 28, 28))
        x = L.conv2d(c, x, out_c=256, k=9, name="conv1")
        x = L.relu(c, x)
        x = L.conv2d(c, x, out_c=256, k=9, stride=2, name="prim.conv")
        n_caps, caps_d, n_out, out_d = 32 * 6 * 6, 8, 10, 16
        x = L.view(c, x, (batch, n_caps, caps_d), name="prim.view")
    for n in list(c.nodes)[-2:]:
        c.meta.setdefault(n, {}).update(layer="primary_caps",
                                        traditional=False)
    u = _squash(c, x, "prim.squash")
    # u_hat[b, i, j, d_out] = sum_d W[i, j, d_out, d] u[b, i, d]
    B = batch
    uv = L.view(c, u, (B, n_caps, 1, 1, caps_d), name="uhat.view")
    w = c.add_param("digit.W", (1, n_caps, n_out, out_d, caps_d))
    uhat = c.add(GConv(name="uhat",
                       dims=(DimSpec("B", ng=B), DimSpec("I", ng=n_caps),
                             DimSpec("J", nop=n_out), DimSpec("Do", nop=out_d),
                             DimSpec("D", nks=caps_d)),
                       input=uv, kernel=w, main="mul", reduce="add"),
                 layer="digit_caps", traditional=False)   # (B,I,J,Do,1)
    uhat = L.view(c, uhat, (B, n_caps, n_out, out_d), name="uhat.sq")
    # routing logits start at zero; they are a (zero-filled) chain input —
    # RNG/initialization happens outside the accelerator, like dropout masks.
    blogit = c.add_input("route.b0", (B, n_caps, n_out))
    v = None
    for it in range(routing_iters):
        cprob = L.softmax(c, blogit, axis=2, name=f"route{it}.softmax")
        # s[b,j,do] = sum_i c[b,i,j] * uhat[b,i,j,do]
        cview = L.view(c, cprob, (B, n_caps, n_out, 1),
                       name=f"route{it}.cview")
        s = c.add(GConv(name=f"route{it}.s",
                        dims=(DimSpec("B", ng=B), DimSpec("I", nks=n_caps),
                              DimSpec("J", ng=n_out), DimSpec("Do", ng=out_d)),
                        input=uhat, kernel=cview, main="mul", reduce="add"),
                  layer="digit_caps", traditional=False)   # (B,1,J,Do)
        s = L.view(c, s, (B, n_out, out_d), name=f"route{it}.sview")
        v = _squash(c, s, f"route{it}.squash")
        if it < routing_iters - 1:
            # agreement: b[b,i,j] += sum_do uhat[b,i,j,do] * v[b,j,do]
            vv = L.view(c, v, (B, 1, n_out, out_d), name=f"route{it}.vview")
            agree = c.add(GConv(
                name=f"route{it}.agree",
                dims=(DimSpec("B", ng=B), DimSpec("I", ng=n_caps),
                      DimSpec("J", ng=n_out), DimSpec("Do", nks=out_d)),
                input=uhat, kernel=vv, main="mul", reduce="add"),
                layer="digit_caps", traditional=False)     # (B,I,J,1)
            agree = L.view(c, agree, (B, n_caps, n_out),
                           name=f"route{it}.aview")
            blogit = L.add_tensors(c, blogit, agree, name=f"route{it}.b",
                                   layer="digit_caps")
    c.mark_output(v)
    return c


def zero_inputs(chain: Chain):
    """Zero-filled arrays for every chain input (dropout masks, routing
    logits, images) — convenient for smoke/stat runs."""
    import numpy as np
    return {name: np.zeros(info.shape, dtype="float32")
            for name, info in chain.inputs.items()}


def random_inputs(chain: Chain, seed: int = 1):
    """:func:`zero_inputs` with a non-degenerate first input (the image):
    the shared recipe of the execution tests and benchmarks."""
    import jax
    import numpy as np
    inputs = zero_inputs(chain)
    first = next(iter(chain.inputs))
    inputs[first] = np.asarray(jax.random.normal(
        jax.random.PRNGKey(seed), chain.inputs[first].shape))
    return inputs


# ---------------------------------------------------------------------------
# training microbenchmark: conv -> BN -> ReLU forward + full backward
# ---------------------------------------------------------------------------
def training_block_chain(batch: int = 8, ch: int = 16, hw: int = 14) -> Chain:
    """FP+BP chain for a conv/BN/ReLU block — the paper's Table-2 scenario."""
    c = Chain("train_block")
    x = c.add_input("x", (batch, ch, hw, hw))
    g = c.add_input("gO", (batch, ch, hw, hw))
    y = L.conv2d(c, x, out_c=ch, k=3, pad=1, bias=False, name="conv")
    bn, fp = L.batch_norm_fp(c, y, name="bn")
    r = L.relu(c, bn, name="relu")
    # ---- backward ----
    # relu BP: gate the gradient by (bn > 0): mask = relu'(bn)
    mask = c.add(GConv(name="relu_bp.mask",
                       dims=tuple(DimSpec(n, ng=s) for n, s in
                                  zip("BCHW", (batch, ch, hw, hw))),
                       input=bn, main="none", reduce="none",
                       post=(Op("gtz"),)),
                 layer="relu_bp", traditional=False)
    g1 = c.add(GConv(name="relu_bp",
                     dims=tuple(DimSpec(n, ng=s) for n, s in
                                zip("BCHW", (batch, ch, hw, hw))),
                     input=g, kernel=mask, main="mul", reduce="none"),
               layer="relu_bp", traditional=False)
    gbn, _ = L.batch_norm_bp(c, g1, fp, name="bn_bp")
    # conv BP (stride 1): gI = gO conv W^T(rot180). Weight view via Movement.
    # W viewed (ic, oc, kh', kw') with spatially flipped taps (rot180)
    wt = c.add(Movement(name="conv_bp.wt", input="conv.w",
                        pre_shape=(ch, ch, 3, 3), perm=(1, 0, 2, 3),
                        flip=(2, 3), out_shape=(1, ch * ch, 3, 3)),
               layer="conv_bp", traditional=True)
    gi = c.add(GConv(name="conv_bp.gi",
                     dims=(DimSpec("B", nopc=batch),
                           DimSpec("C", nop=ch, nks=ch),
                           DimSpec("H", nopc=hw, nks=3, pad=1),
                           DimSpec("W", nopc=hw, nks=3, pad=1)),
                     input=gbn, kernel=wt, main="mul", reduce="add"),
               layer="conv_bp", traditional=True)
    # gW[ic,oc,kh,kw] = sum_b sum_hw x[b,ic,h+kh-1,w+kw-1] gbn[b,oc,h,w]:
    # a GCONV whose kernel is the upstream gradient (taps cover H/W/batch)
    gx = L.view(c, gbn, (batch, 1, ch, hw, hw), name="conv_bp.gview")
    xv = L.view(c, x, (batch, ch, 1, hw, hw), name="conv_bp.xview")
    gw = c.add(GConv(name="conv_bp.gw",
                     dims=(DimSpec("B", nks=batch),
                           DimSpec("Ci", ng=ch),
                           DimSpec("Co", nop=ch),
                           DimSpec("H", nopc=3, nks=hw, pad=1),
                           DimSpec("W", nopc=3, nks=hw, pad=1)),
                     input=xv, kernel=gx, main="mul", reduce="add"),
               layer="conv_bp", traditional=True)   # (1, ch_i, ch_o, 3, 3)
    c.mark_output(r)
    c.mark_output(gi)
    return c


ZOO = {
    "AN": alexnet, "GLN": googlenet, "DN": densenet121, "MN": mobilenet,
    "ZFFR": zffr, "C3D": c3d, "CapNN": capsnet,
}


def build(name: str, reduced: bool = False, **kw) -> Chain:
    return ZOO[name](reduced=reduced, **kw)
