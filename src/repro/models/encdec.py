"""Encoder-decoder transformer (seamless-m4t-medium text backbone).

The audio/modality frontend is a stub per the assignment: the encoder
consumes precomputed frame embeddings (B, Ts, D) from ``input_specs``. The
decoder is a standard causal transformer with cross-attention into the
encoder output. "12L" is realized as 12 encoder + 12 decoder layers
(published text enc/dec depths); LayerNorm + GELU per the seamless stack.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from .common import (ModelConfig, apply_rope, attention, cdtype, dense_init, ffn, ffn_param_shapes, norm, softmax_xent)

_noshard = lambda x, tag=None: x


def _attn_shapes(cfg):
    return {"wq": (cfg.d_model, cfg.q_dim), "wk": (cfg.d_model, cfg.kv_dim),
            "wv": (cfg.d_model, cfg.kv_dim), "wo": (cfg.q_dim, cfg.d_model)}


def enc_layer_shapes(cfg: ModelConfig):
    D = cfg.d_model
    return {"ln1": (D,), "ln1_b": (D,), "ln2": (D,), "ln2_b": (D,),
            **_attn_shapes(cfg), **ffn_param_shapes(cfg)}


def dec_layer_shapes(cfg: ModelConfig):
    D = cfg.d_model
    return {"ln1": (D,), "ln1_b": (D,), "ln2": (D,), "ln2_b": (D,),
            "ln3": (D,), "ln3_b": (D,),
            **_attn_shapes(cfg),
            **{f"x_{k}": v for k, v in _attn_shapes(cfg).items()},
            **ffn_param_shapes(cfg)}


def _init_stack(key, n, shapes, dt):
    out = {}
    for i, (name, shape) in enumerate(sorted(shapes.items())):
        sub = jax.random.fold_in(key, i)
        if name.startswith("ln"):
            init = jnp.zeros if name.endswith("_b") else jnp.ones
            out[name] = init((n,) + shape, jnp.float32)
        else:
            out[name] = dense_init(sub, (n,) + shape, dt)
    return out


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    dt = cdtype(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    n_enc = cfg.n_enc_layers or cfg.n_layers
    return {
        "embed": dense_init(k1, (cfg.vocab, cfg.d_model), dt, scale=1.0),
        "enc_layers": _init_stack(k2, n_enc, enc_layer_shapes(cfg), dt),
        "dec_layers": _init_stack(k3, cfg.n_layers, dec_layer_shapes(cfg), dt),
        "enc_ln": jnp.ones((cfg.d_model,), jnp.float32),
        "enc_ln_b": jnp.zeros((cfg.d_model,), jnp.float32),
        "dec_ln": jnp.ones((cfg.d_model,), jnp.float32),
        "dec_ln_b": jnp.zeros((cfg.d_model,), jnp.float32),
        "lm_head": dense_init(k4, (cfg.d_model, cfg.vocab), dt),
    }


def _mha(cfg, p, xq, xkv, positions_q, positions_kv, *, causal,
         prefix="", shard_fn=None):
    B, Tq, D = xq.shape
    Tk = xkv.shape[1]
    q = jnp.einsum("btd,dq->btq", xq, p[f"{prefix}wq"].astype(xq.dtype))
    k = jnp.einsum("btd,dq->btq", xkv, p[f"{prefix}wk"].astype(xq.dtype))
    v = jnp.einsum("btd,dq->btq", xkv, p[f"{prefix}wv"].astype(xq.dtype))
    q = q.reshape(B, Tq, cfg.n_heads, cfg.hd)
    k = k.reshape(B, Tk, cfg.n_kv_heads, cfg.hd)
    v = v.reshape(B, Tk, cfg.n_kv_heads, cfg.hd)
    if positions_q is not None:
        q = apply_rope(q, positions_q, cfg.rope_theta)
        k = apply_rope(k, positions_kv, cfg.rope_theta)
    o = attention(cfg, q, k, v, causal=causal, shard_fn=shard_fn)
    o = o.reshape(B, Tq, cfg.q_dim)
    return jnp.einsum("btq,qd->btd", o, p[f"{prefix}wo"].astype(xq.dtype))


def encode(cfg: ModelConfig, params, src_embeds, shard_fn=_noshard):
    """src_embeds: (B, Ts, D) — stubbed frontend output."""
    B, Ts, D = src_embeds.shape
    pos = jnp.broadcast_to(jnp.arange(Ts)[None], (B, Ts))
    x = src_embeds.astype(cdtype(cfg))

    def body(x, p):
        h = norm(x, p["ln1"], p["ln1_b"], kind="layer")
        x = x + _mha(cfg, p, h, h, pos, pos, causal=False,
                     shard_fn=shard_fn)
        h2 = norm(x, p["ln2"], p["ln2_b"], kind="layer")
        x = shard_fn(x + ffn(cfg, p, h2), "act")
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    from .common import safe_unroll
    n_enc = cfg.n_enc_layers or cfg.n_layers
    x, _ = jax.lax.scan(body, x, params["enc_layers"],
                        unroll=safe_unroll(n_enc, cfg.layer_unroll))
    return norm(x, params["enc_ln"], params["enc_ln_b"], kind="layer")


def decode_train(cfg: ModelConfig, params, tgt_tokens, enc_out,
                 shard_fn=_noshard):
    B, Tt = tgt_tokens.shape
    Ts = enc_out.shape[1]
    pos_t = jnp.broadcast_to(jnp.arange(Tt)[None], (B, Tt))
    pos_s = jnp.broadcast_to(jnp.arange(Ts)[None], (B, Ts))
    x = params["embed"][tgt_tokens].astype(cdtype(cfg))

    def body(x, p):
        h = norm(x, p["ln1"], p["ln1_b"], kind="layer")
        x = x + _mha(cfg, p, h, h, pos_t, pos_t, causal=True,
                     shard_fn=shard_fn)
        h2 = norm(x, p["ln2"], p["ln2_b"], kind="layer")
        x = x + _mha(cfg, p, h2, enc_out, None, None, causal=False,
                     prefix="x_", shard_fn=shard_fn)
        h3 = norm(x, p["ln3"], p["ln3_b"], kind="layer")
        x = shard_fn(x + ffn(cfg, p, h3), "act")
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    from .common import safe_unroll
    x, _ = jax.lax.scan(body, x, params["dec_layers"],
                        unroll=safe_unroll(cfg.n_layers, cfg.layer_unroll))
    x = norm(x, params["dec_ln"], params["dec_ln_b"], kind="layer")
    return jnp.einsum("btd,dv->btv", x, params["lm_head"].astype(x.dtype))


def loss_fn(cfg: ModelConfig, params, batch, shard_fn=_noshard):
    enc_out = encode(cfg, params, batch["src_embeds"], shard_fn)
    logits = decode_train(cfg, params, batch["tgt_tokens"], enc_out, shard_fn)
    return softmax_xent(shard_fn(logits, "logits"), batch["labels"])


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------
def serve_state_init(cfg: ModelConfig, batch: int, max_len: int, src_len: int):
    dt = cdtype(cfg)
    L = cfg.n_layers
    return {
        "k": jnp.zeros((L, batch, max_len, cfg.n_kv_heads, cfg.hd), dt),
        "v": jnp.zeros((L, batch, max_len, cfg.n_kv_heads, cfg.hd), dt),
        # cross-attention K/V computed once from enc_out at prefill
        "xk": jnp.zeros((L, batch, src_len, cfg.n_kv_heads, cfg.hd), dt),
        "xv": jnp.zeros((L, batch, src_len, cfg.n_kv_heads, cfg.hd), dt),
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_step(cfg: ModelConfig, params, token, cache, shard_fn=_noshard):
    """One target token against self KV cache + precomputed cross KV."""
    from .common import kv_cache_append_layer
    from .transformer import decode_attention

    B = token.shape[0]
    pos = cache["pos"]
    positions = jnp.broadcast_to(pos[None, None], (B, 1))
    x = params["embed"][token].astype(cdtype(cfg))

    def body(x, layer_in):
        p, ck, cv, xk, xv = layer_in
        h = norm(x, p["ln1"], p["ln1_b"], kind="layer")
        q = jnp.einsum("btd,dq->btq", h, p["wq"].astype(x.dtype))
        k = jnp.einsum("btd,dq->btq", h, p["wk"].astype(x.dtype))
        v = jnp.einsum("btd,dq->btq", h, p["wv"].astype(x.dtype))
        q = apply_rope(q.reshape(B, 1, cfg.n_heads, cfg.hd), positions,
                       cfg.rope_theta)
        k = apply_rope(k.reshape(B, 1, cfg.n_kv_heads, cfg.hd), positions,
                       cfg.rope_theta)
        v = v.reshape(B, 1, cfg.n_kv_heads, cfg.hd)
        ck, cv = kv_cache_append_layer(ck, cv, pos, k, v)
        o = decode_attention(cfg, q, ck, cv, pos).reshape(B, 1, cfg.q_dim)
        x = x + jnp.einsum("btq,qd->btd", o, p["wo"].astype(x.dtype))
        # cross attention over the cached encoder projections
        h2 = norm(x, p["ln2"], p["ln2_b"], kind="layer")
        q2 = jnp.einsum("btd,dq->btq", h2, p["x_wq"].astype(x.dtype))
        q2 = q2.reshape(B, 1, cfg.n_heads, cfg.hd)
        o2 = decode_attention(cfg, q2, xk, xv,
                              jnp.asarray(xk.shape[1], jnp.int32))
        o2 = o2.reshape(B, 1, cfg.q_dim)
        x = x + jnp.einsum("btq,qd->btd", o2, p["x_wo"].astype(x.dtype))
        h3 = norm(x, p["ln3"], p["ln3_b"], kind="layer")
        x = x + ffn(cfg, p, h3)
        return x, (ck, cv)

    from .common import safe_unroll
    x, (ck, cv) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]),
        unroll=safe_unroll(cfg.n_layers, cfg.layer_unroll))
    x = norm(x, params["dec_ln"], params["dec_ln_b"], kind="layer")
    logits = jnp.einsum("btd,dv->btv", x, params["lm_head"].astype(x.dtype))
    cache = dict(cache, k=ck, v=cv, pos=pos + 1)
    return shard_fn(logits, "logits"), cache
