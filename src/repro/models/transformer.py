"""Dense decoder-only transformer family (tinyllama / yi / starcoder2 /
phi3 / qwen2-vl backbone).

Pure-function model: ``init_params`` builds a stacked-per-layer pytree,
``forward`` scans one block over the stack (compact HLO), ``decode_step``
runs one token against a KV cache. GQA + RoPE/M-RoPE + SwiGLU-or-GELU FFN,
optional sliding window. MoE subclasses override the FFN (see moe.py).

``shard_fn(x, tag)`` is an injection point for activation sharding
constraints; the launcher supplies it (models stay mesh-agnostic).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import (ModelConfig, apply_rope, attention, cdtype, dense_init, ffn, ffn_param_shapes, norm, softmax_xent, stacked_init)
from .common import safe_unroll as _safe_unroll

Params = Dict[str, Any]
_noshard = lambda x, tag=None: x


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------
def layer_param_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, ...]]:
    D, Q, KV = cfg.d_model, cfg.q_dim, cfg.kv_dim
    shapes = {
        "ln1": (D,), "ln2": (D,),
        "wq": (D, Q), "wk": (D, KV), "wv": (D, KV), "wo": (Q, D),
    }
    if cfg.norm == "layer":
        shapes["ln1_b"] = (D,)
        shapes["ln2_b"] = (D,)
    if cfg.n_experts:
        from .moe import moe_layer_param_shapes
        shapes.update(moe_layer_param_shapes(cfg))
    else:
        shapes.update(ffn_param_shapes(cfg))
    return shapes


def init_params(cfg: ModelConfig, key) -> Params:
    keys = jax.random.split(key, 8)
    dt = cdtype(cfg)
    L = cfg.n_layers
    layers = {}
    for i, (name, shape) in enumerate(sorted(layer_param_shapes(cfg).items())):
        sub = jax.random.fold_in(keys[0], i)
        if name.startswith("ln"):
            init = jnp.ones if not name.endswith("_b") else jnp.zeros
            layers[name] = init((L,) + shape, jnp.float32)
        else:
            layers[name] = stacked_init(sub, L, shape, dt)
    params = {
        "embed": dense_init(keys[1], (cfg.vocab, cfg.d_model), dt, scale=1.0),
        "final_ln": jnp.ones((cfg.d_model,), jnp.float32),
        "layers": layers,
    }
    if cfg.norm == "layer":
        params["final_ln_b"] = jnp.zeros((cfg.d_model,), jnp.float32)
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[2], (cfg.d_model, cfg.vocab), dt)
    return params


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------
def _norm(cfg, x, g, b=None):
    return norm(x, g, b, kind=cfg.norm)


def _qkv(cfg: ModelConfig, p, x, positions):
    B, T, D = x.shape
    q = jnp.einsum("btd,dq->btq", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("btd,dq->btq", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("btd,dq->btq", x, p["wv"].astype(x.dtype))
    q = q.reshape(B, T, cfg.n_heads, cfg.hd)
    k = k.reshape(B, T, cfg.n_kv_heads, cfg.hd)
    v = v.reshape(B, T, cfg.n_kv_heads, cfg.hd)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    return q, k, v


def block(cfg: ModelConfig, p, x, positions, shard_fn=_noshard,
          ffn_fn: Optional[Callable] = None):
    """One decoder block (pre-norm). Returns (x, aux_loss)."""
    h = _norm(cfg, x, p["ln1"], p.get("ln1_b"))
    q, k, v = _qkv(cfg, p, h, positions)
    o = attention(cfg, q, k, v, causal=True, shard_fn=shard_fn)
    o = o.reshape(*x.shape[:2], cfg.q_dim)
    x = x + jnp.einsum("btq,qd->btd", o, p["wo"].astype(x.dtype))
    x = shard_fn(x, "act")
    h2 = _norm(cfg, x, p["ln2"], p.get("ln2_b"))
    if ffn_fn is None:
        y, aux = ffn(cfg, p, h2), 0.0
    else:
        y, aux = ffn_fn(cfg, p, h2, shard_fn)
    x = x + y
    return shard_fn(x, "act"), aux


def _default_positions(cfg: ModelConfig, B: int, T: int):
    pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    if cfg.mrope_sections:
        # text-only stream: all three M-RoPE position channels coincide
        pos = jnp.broadcast_to(pos[:, None], (B, 3, T))
    return pos


def embed_tokens(cfg: ModelConfig, params, tokens):
    return params["embed"][tokens].astype(cdtype(cfg))


def apply_embed_overlay(x, overlay, mask):
    """VLM/audio frontends: replace masked positions with precomputed
    modality embeddings (the stubbed frontend output)."""
    return jnp.where(mask[..., None], overlay.astype(x.dtype), x)


def forward(cfg: ModelConfig, params: Params, tokens, positions=None,
            shard_fn=_noshard, embed_overlay=None, overlay_mask=None,
            ffn_fn: Optional[Callable] = None):
    """Full-sequence forward -> (logits, aux_loss)."""
    B, T = tokens.shape[:2]
    if positions is None:
        positions = _default_positions(cfg, B, T)
    x = embed_tokens(cfg, params, tokens)
    if embed_overlay is not None:
        x = apply_embed_overlay(x, embed_overlay, overlay_mask)
    x = shard_fn(x, "act")

    blk = functools.partial(block, cfg, shard_fn=shard_fn, ffn_fn=ffn_fn)
    if cfg.remat:
        from .common import remat_policy
        blk = jax.checkpoint(blk, policy=remat_policy(cfg))

    def scan_body(carry, p_layer):
        x, aux = carry
        x, a = blk(p_layer, x, positions)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(
        scan_body, (x, 0.0), params["layers"],
        unroll=_safe_unroll(cfg.n_layers, cfg.layer_unroll))
    x = norm(x, params["final_ln"], params.get("final_ln_b"), kind=cfg.norm)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(x.dtype)
    logits = jnp.einsum("btd,dv->btv", x, head)
    return shard_fn(logits, "logits"), aux


def loss_fn(cfg: ModelConfig, params: Params, batch, shard_fn=_noshard,
            ffn_fn=None):
    logits, aux = forward(
        cfg, params, batch["tokens"], batch.get("positions"),
        shard_fn=shard_fn, embed_overlay=batch.get("embed_overlay"),
        overlay_mask=batch.get("overlay_mask"), ffn_fn=ffn_fn)
    return softmax_xent(logits, batch["labels"]) + aux


# ---------------------------------------------------------------------------
# serving: prefill + decode with KV cache
# ---------------------------------------------------------------------------
def _pos_col(pos, ndim: int):
    """Broadcast pos against a (B, ..., S) score tensor: scalars apply
    globally (lock-step decode); (B,) vectors mask per batch row (per-slot
    serving positions)."""
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        return pos
    return pos.reshape(pos.shape + (1,) * (ndim - 1))


def decode_attention(cfg: ModelConfig, q, cache_k, cache_v, pos,
                     shard_fn=None):
    """q: (B,1,H,hd); cache: (B,S,Hkv,hd); pos = tokens already in cache
    (the new token's index) — a scalar, or (B,) for per-slot positions.
    Ring-buffered caches attend every slot once full; before that, slots
    beyond pos are masked."""
    B, S = cache_k.shape[:2]
    n_rep = cfg.n_heads // cfg.n_kv_heads
    if "gqa_norepeat" in cfg.perf_flags and n_rep > 1:
        # grouped form: never materialize the n_rep-times-repeated cache
        # (the repeat multiplies decode HBM traffic by n_rep — §Perf H-A4)
        T1 = q.shape[1]
        qg = q.reshape(B, T1, cfg.n_kv_heads, n_rep, cfg.hd)
        s = jnp.einsum("bqgrd,bkgd->bgrqk", qg.astype(jnp.float32),
                       cache_k.astype(jnp.float32)) * cfg.hd ** -0.5
        k_ids = jnp.arange(S)[None, None, None, None, :]
        pc = _pos_col(pos, s.ndim)
        valid = (k_ids <= pc) | (pc >= S)
        s = jnp.where(valid, s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bgrqk,bkgd->bqgrd", p,
                       cache_v.astype(jnp.float32))
        return o.reshape(B, T1, cfg.n_heads, cfg.hd).astype(q.dtype)
    k = jnp.repeat(cache_k, n_rep, axis=2)
    v = jnp.repeat(cache_v, n_rep, axis=2)
    if shard_fn is not None and "decode_q" in cfg.perf_flags:
        # keep q/k/v consistently head_dim-sharded so the score contraction
        # psums over "model" instead of resharding the whole cache per step
        q = shard_fn(q, "decode_qkv")
        k = shard_fn(k, "decode_qkv")
        v = shard_fn(v, "decode_qkv")
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * cfg.hd ** -0.5
    k_ids = jnp.arange(S)[None, None, None, :]
    pc = _pos_col(pos, s.ndim)
    valid = (k_ids <= pc) | (pc >= S)
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def decode_step(cfg: ModelConfig, params: Params, token, cache,
                shard_fn=_noshard, ffn_fn: Optional[Callable] = None):
    """token: (B, 1) int; cache from kv_cache_init. Returns (logits, cache).

    The dry-run's ``serve_step``: one new token against a seq_len-deep KV
    cache (decode_32k / long_500k cells). ``cache["pos"]`` may be a scalar
    (lock-step: all rows share one position) or a (B,) vector (continuous-
    batching serving: each slot carries its own position; pad-token steps
    on other slots never advance or overwrite this slot's rows).
    """
    from .common import kv_cache_append_layer

    B = token.shape[0]
    pos = cache["pos"]
    pos_b = (jnp.broadcast_to(pos[None], (B,)) if jnp.ndim(pos) == 0
             else pos)
    positions = pos_b[:, None]
    if cfg.mrope_sections:
        positions = jnp.broadcast_to(pos_b[:, None, None], (B, 3, 1))
    x = embed_tokens(cfg, params, token)

    def scan_body(carry, layer_in):
        x = carry
        p_layer, ck, cv = layer_in
        h = _norm(cfg, x, p_layer["ln1"], p_layer.get("ln1_b"))
        q, k, v = _qkv(cfg, p_layer, h, positions)
        ck, cv = kv_cache_append_layer(ck, cv, pos, k, v,
                                       cfg.sliding_window)
        o = decode_attention(cfg, q, ck, cv, pos, shard_fn=shard_fn)
        o = o.reshape(B, 1, cfg.q_dim)
        x = x + jnp.einsum("btq,qd->btd", o, p_layer["wo"].astype(x.dtype))
        h2 = _norm(cfg, x, p_layer["ln2"], p_layer.get("ln2_b"))
        if ffn_fn is None:
            y = ffn(cfg, p_layer, h2)
        else:
            y, _ = ffn_fn(cfg, p_layer, h2, shard_fn)
        return x + y, (ck, cv)

    (x), (ck, cv) = jax.lax.scan(
        scan_body, x, (params["layers"], cache["k"], cache["v"]),
        unroll=_safe_unroll(cfg.n_layers, cfg.layer_unroll))
    x = norm(x, params["final_ln"], params.get("final_ln_b"), kind=cfg.norm)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(x.dtype)
    logits = jnp.einsum("btd,dv->btv", x, head)
    new_cache = {"k": ck, "v": cv, "pos": pos + 1}
    return shard_fn(logits, "logits"), new_cache


def prefill(cfg: ModelConfig, params: Params, tokens, shard_fn=_noshard,
            ffn_fn=None, lengths=None):
    """Full-sequence forward that also returns the populated KV cache.
    (Windowed models cache only the trailing window.)

    ``lengths`` (B,) enables right-padded batched prefill (the serving
    path): each row's logits are taken at its own last real token and the
    returned ``cache["pos"]`` is the per-row length vector. Causality makes
    the pad tail inert for the real prefix; K/V rows past a row's length
    are garbage but sit above ``pos`` and are therefore masked (and later
    overwritten) during decode. Windowed models must prefill exact-length
    (the trailing-window crop would otherwise capture pad rows).
    """

    B, T = tokens.shape
    if lengths is not None and cfg.sliding_window:
        raise ValueError(
            "padded prefill (lengths=...) is unsupported for sliding-window "
            "models: prefill exact-length per row instead")
    positions = _default_positions(cfg, B, T)
    x = embed_tokens(cfg, params, tokens)
    caches_k, caches_v = [], []

    # prefill keeps the per-layer loop unscanned=False: scan with per-layer
    # cache outputs stacked
    def scan_body(x, p_layer):
        h = _norm(cfg, x, p_layer["ln1"], p_layer.get("ln1_b"))
        q, k, v = _qkv(cfg, p_layer, h, positions)
        o = attention(cfg, q, k, v, causal=True, shard_fn=shard_fn)
        o = o.reshape(B, T, cfg.q_dim)
        x = x + jnp.einsum("btq,qd->btd", o, p_layer["wo"].astype(x.dtype))
        h2 = _norm(cfg, x, p_layer["ln2"], p_layer.get("ln2_b"))
        if ffn_fn is None:
            y = ffn(cfg, p_layer, h2)
        else:
            y, _ = ffn_fn(cfg, p_layer, h2, shard_fn)
        x = shard_fn(x + y, "act")
        if cfg.sliding_window and cfg.sliding_window < T:
            k = k[:, -cfg.sliding_window:]
            v = v[:, -cfg.sliding_window:]
        return x, (k, v)

    x, (ck, cv) = jax.lax.scan(
        scan_body, x, params["layers"],
        unroll=_safe_unroll(cfg.n_layers, cfg.layer_unroll))
    x = norm(x, params["final_ln"], params.get("final_ln_b"), kind=cfg.norm)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(x.dtype)
    if lengths is None:
        logits = jnp.einsum("bd,dv->bv", x[:, -1], head)
        pos = jnp.asarray(min(T, cfg.sliding_window) if
                          cfg.sliding_window else T, jnp.int32)
    else:
        lengths = jnp.asarray(lengths, jnp.int32)
        last = jnp.clip(lengths - 1, 0, T - 1)
        x_last = jnp.take_along_axis(x, last[:, None, None], axis=1)[:, 0]
        logits = jnp.einsum("bd,dv->bv", x_last, head)
        pos = lengths                                    # (B,) per-slot
    cache = {"k": ck, "v": cv, "pos": pos}
    return logits, cache
