"""LM transformer block as a GCONV Chain (DESIGN.md §3).

This is the paper's thesis applied to the assigned architectures: every op
in a modern decoder block lowers to the GCONV vocabulary —

    rmsnorm      -> reduce-GCONV + broadcast-GCONV   (Table-2 pattern)
    qkv/out/ffn  -> FC-pattern GCONVs (kernel covers the input)
    attention    -> the 5-GCONV segment (scores, softmax chain, values)
    swiglu       -> two FCs + silu post + elementwise-mul GCONV
    MoE experts  -> ONE grouped GCONV with Ng = n_experts

The chain is used for (a) Table-1-style heterogeneity analysis of the LM
archs, (b) Algorithm-1 mapping / cost-model studies on the TPU spec, and
(c) interpreter-vs-model equivalence tests at smoke scale (RoPE and causal
masking are omitted here — they are ``pre`` operators in chain terms and do
not change any loop structure; the equivalence test disables them on the
model side too).
"""
from __future__ import annotations


from repro.core import layers as L
from repro.core.chain import Chain
from repro.core.gconv import DimSpec, GConv, Op
from repro.models.common import ModelConfig


def block_chain(cfg: ModelConfig, batch: int, seq: int,
                name: str = "lm_block") -> Chain:
    """One pre-norm decoder block (no RoPE / causal mask; MHA form)."""
    B, T, D = batch, seq, cfg.d_model
    H, hd = cfg.n_heads, cfg.hd
    c = Chain(f"{name}[{cfg.name}]")
    x = c.add_input("x", (B, T, D))

    h = L.rms_norm(c, x, name="ln1")
    q = L.linear(c, h, out_f=cfg.q_dim, name="wq")
    k = L.linear(c, h, out_f=cfg.q_dim, name="wk")   # MHA view for the chain
    v = L.linear(c, h, out_f=cfg.q_dim, name="wv")
    # (B,T,H*hd) -> (B,T,H,hd) -> (B,H,T,hd) -> insert singleton axis
    qv = L.view(c, q, (B, H, T, 1, hd), pre_shape=(B, T, H, hd),
                perm=(0, 2, 1, 3), name="q5")
    kv = L.view(c, k, (B, H, 1, T, hd), pre_shape=(B, T, H, hd),
                perm=(0, 2, 1, 3), name="k5")
    vv = L.view(c, v, (B, H, 1, T, hd), pre_shape=(B, T, H, hd),
                perm=(0, 2, 1, 3), name="v5")
    s = L.attention_scores(c, qv, kv, scale=hd ** -0.5, name="scores")
    pr = L.softmax(c, s, axis=3, name="probs")
    o = L.attention_values(c, pr, vv, name="attnv")      # (B,H,T,1,hd)
    of = L.view(c, o, (B, T, H * hd), perm=(0, 2, 1, 3, 4), name="oflat")
    wo = L.linear(c, of, out_f=D, name="wo")
    r1 = L.add_tensors(c, wo, x, name="res1", layer="residual")

    h2 = L.rms_norm(c, r1, name="ln2")
    if cfg.n_experts:
        y = _moe_chain(c, cfg, h2, B, T)
    else:
        g = L.linear(c, h2, out_f=cfg.d_ff, name="w_gate")
        gs = L.activation(c, g, "silu", name="silu")
        u = L.linear(c, h2, out_f=cfg.d_ff, name="w_up")
        gu = L.mul_tensors(c, gs, u, name="swiglu")
        y = L.linear(c, gu, out_f=D, name="w_down")
    out = L.add_tensors(c, y, r1, name="res2", layer="residual")
    c.mark_output(out)
    return c


def _moe_chain(c: Chain, cfg: ModelConfig, h2: str, B: int, T: int) -> str:
    """Capacity-dispatch MoE as chain nodes: the expert FFN is ONE grouped
    GCONV with Ng = n_experts (the paper's group parameter, literally)."""
    from repro.core.chain import Movement

    D, E, F = cfg.d_model, cfg.n_experts, cfg.d_ff
    N = B * T
    C = max(8, int(cfg.capacity_factor * cfg.top_k * N / E))
    router = L.linear(c, h2, out_f=E, name="router")
    L.softmax(c, router, axis=-1, name="router_probs")
    # dispatch: runtime-dependent gather (chain models it as movement)
    flat = L.view(c, h2, (N, D), name="tok_flat")
    disp = c.add(Movement(name="dispatch", input=flat,
                          out_shape=(E, C, D), gather=True),
                 layer="moe_dispatch", traditional=False)
    w_g = c.add_param("experts.gate", (E, D * F, 1))
    w_u = c.add_param("experts.up", (E, D * F, 1))
    w_d = c.add_param("experts.down", (E, F * D, 1))
    gate = c.add(GConv(name="e_gate",
                       dims=(DimSpec("E", ng=E),
                             DimSpec("C", nop=F, nks=D),
                             DimSpec("Dd", nopc=C)),
                 input=_ecd_to_edc(c, disp, E, C, D, "disp_t"),
                 kernel=w_g, main="mul", reduce="add",
                 post=(Op("silu"),)),
                 layer="moe_expert", traditional=True)
    up = c.add(GConv(name="e_up",
                     dims=(DimSpec("E", ng=E),
                           DimSpec("C", nop=F, nks=D),
                           DimSpec("Dd", nopc=C)),
                     input=_ecd_to_edc(c, disp, E, C, D, "disp_t2"),
                     kernel=w_u, main="mul", reduce="add"),
               layer="moe_expert", traditional=True)
    hidden = L.mul_tensors(c, gate, up, name="e_swiglu", layer="moe_expert")
    down = c.add(GConv(name="e_down",
                       dims=(DimSpec("E", ng=E),
                             DimSpec("F", nop=D, nks=F),
                             DimSpec("Cc", nopc=C)),
                       input=_efc_view(c, hidden, E, F, C),
                       kernel=w_d, main="mul", reduce="add"),
                 layer="moe_expert", traditional=True)
    comb = c.add(Movement(name="combine", input=down, out_shape=(B, T, D),
                          gather=True),
                 layer="moe_combine", traditional=False)
    return comb


def _ecd_to_edc(c, disp, E, C, D, name):
    return L.view(c, disp, (E, D, C), perm=(0, 2, 1), name=name)


def _efc_view(c, hidden, E, F, C):
    # hidden: (E, F, C) already in e_gate/e_up output layout (g, op, opc)
    return hidden


def chain_stats_table(batch: int = 4, seq: int = 128):
    """Table-1-style heterogeneity stats for the LM archs (per block)."""
    from repro import configs

    rows = []
    for arch in ("tinyllama-1.1b", "yi-34b", "olmoe-1b-7b"):
        cfg = configs.get(arch)
        ch = block_chain(cfg, batch, seq)
        st = ch.stats()
        rows.append(dict(arch=arch, gconvs=st["n_gconv"],
                         macs=st["macs"],
                         mxu_eligible=sum(1 for g in ch.gconv_nodes()
                                          if g.is_mxu_eligible)))
    return rows
