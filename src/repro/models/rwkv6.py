"""RWKV6 "Finch" — attention-free RNN with data-dependent decay.

Per head (size N): state S in R^{N x N};
    y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
    S_t = diag(w_t) S_{t-1} + k_t^T v_t
with the *data-dependent* per-channel decay (the Finch contribution)
    w_t = exp(-exp(w0 + tanh(x_t A) B)).

Two equivalent execution paths, tested against each other:
  * ``wkv_scan``    — token-level lax.scan (the semantic reference; also the
    decode step with T=1),
  * ``wkv_chunked`` — chunk-parallel form (cumulative log-decays inside a
    chunk, state carried across chunks) — the TPU-friendly path: MXU matmuls
    of (chunk x N) blocks instead of a length-T sequential chain.

GCONV note (DESIGN.md §6): the projections and channel-mix are ordinary
GCONVs; the recurrence has data-dependent kernel parameters, outside the
paper's static-chain model — documented as the technique's limit.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, cdtype, dense_init, norm, softmax_xent

_noshard = lambda x, tag=None: x
LORA_R = 64


def layer_param_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, ...]]:
    D, F = cfg.d_model, cfg.d_ff
    H = cfg.ssm_heads or (cfg.d_model // 64)
    return {
        "ln1": (D,), "ln2": (D,),
        # time-mix token-shift interpolation factors (static part)
        "mu_r": (D,), "mu_k": (D,), "mu_v": (D,), "mu_w": (D,), "mu_g": (D,),
        "wr": (D, D), "wk": (D, D), "wv": (D, D), "wg": (D, D),
        "wo": (D, D),
        # data-dependent decay LoRA: w_t = exp(-exp(w0 + tanh(x A) B))
        "w0": (D,), "decay_A": (D, LORA_R), "decay_B": (LORA_R, D),
        "u": (D,),                       # per-channel bonus
        "gn": (D,),                      # per-head group-norm gain
        # channel mix
        "mu_ck": (D,), "mu_cr": (D,),
        "ck": (D, F), "cv": (F, D), "cr": (D, D),
    }


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    dt = cdtype(cfg)
    L = cfg.n_layers
    layers = {}
    for i, (name, shape) in enumerate(sorted(layer_param_shapes(cfg).items())):
        sub = jax.random.fold_in(key, i)
        if name.startswith(("ln", "gn")):
            layers[name] = jnp.ones((L,) + shape, jnp.float32)
        elif name.startswith("mu_"):
            layers[name] = 0.5 * jnp.ones((L,) + shape, jnp.float32)
        elif name == "w0":
            layers[name] = jnp.full((L,) + shape, -1.0, jnp.float32)
        elif name == "u":
            layers[name] = jnp.zeros((L,) + shape, jnp.float32)
        else:
            layers[name] = dense_init(sub, (L,) + shape, dt)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "embed": dense_init(k1, (cfg.vocab, cfg.d_model), dt, scale=1.0),
        "final_ln": jnp.ones((cfg.d_model,), jnp.float32),
        "lm_head": dense_init(k2, (cfg.d_model, cfg.vocab), dt),
        "layers": layers,
    }


# ---------------------------------------------------------------------------
# WKV recurrence
# ---------------------------------------------------------------------------
def wkv_scan(r, k, v, w, u, state):
    """Reference/decode path. r,k,v,w: (B,T,H,N); u: (H,N);
    state: (B,H,N,N) [key x value]. Returns (y, state)."""
    B, T, H, N = r.shape

    def step(S, inp):
        rt, kt, vt, wt = inp                       # (B,H,N)
        kv = kt[..., :, None] * vt[..., None, :]   # (B,H,N,N)
        y = jnp.einsum("bhn,bhnm->bhm", rt, S + u[None, :, :, None] * kv)
        S = wt[..., :, None] * S + kv
        return S, y

    xs = tuple(a.transpose(1, 0, 2, 3) for a in (r, k, v, w))
    state, ys = jax.lax.scan(step, state, xs)
    return ys.transpose(1, 0, 2, 3), state        # (B,T,H,N)


def wkv_chunked(r, k, v, w, u, state, chunk: int = 64, unroll: int = 1):
    """Chunk-parallel WKV: within a chunk, O(T*N) cumulative decays + two
    (T x N) matmuls; across chunks, a scan over the (N x N) state."""
    B, T, H, N = r.shape
    assert T % chunk == 0, (T, chunk)
    nc = T // chunk
    rs = r.reshape(B, nc, chunk, H, N).transpose(1, 0, 2, 3, 4)
    ks = k.reshape(B, nc, chunk, H, N).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nc, chunk, H, N).transpose(1, 0, 2, 3, 4)
    ws = w.reshape(B, nc, chunk, H, N).transpose(1, 0, 2, 3, 4)

    def per_chunk(S, inp):
        rc, kc, vc, wc = inp                       # (B,chunk,H,N)
        logw = jnp.log(jnp.maximum(wc, 1e-20))
        cum = jnp.cumsum(logw, axis=1)             # prod_{s<=t} w_s
        # inter-chunk: y_t += (r_t * prod_{s<t} w_s) @ S
        r_dec = rc * jnp.exp(cum - logw)           # prod_{s<t}
        y = jnp.einsum("bthn,bhnm->bthm", r_dec, S)
        # intra-chunk: y_t += sum_{s<t} (r_t * W(s,t)) . k_s v_s + u bonus
        # W(s,t) = prod_{s<u<t} w_u = exp(cum_{t-1} - cum_s)
        a = rc * jnp.exp(cum - logw)               # (B,t,H,N)
        b = kc * jnp.exp(-cum)                     # (B,s,H,N)
        att = jnp.einsum("bthn,bshn->bhts", a, b)
        tri = jnp.tril(jnp.ones((chunk, chunk)), -1)
        att = att * tri[None, None]
        y = y + jnp.einsum("bhts,bshn->bthn", att, vc)
        y = y + (jnp.einsum("bthn,bthn->bth", rc, u[None, None] * kc)
                 [..., None] * vc)
        # state update: S' = diag(prod_all w) S + sum_s diag(prod_{u>s}) k v
        k_dec = kc * jnp.exp(cum[:, -1:] - cum)
        S = (jnp.exp(cum[:, -1])[..., None] * S
             + jnp.einsum("bshn,bshm->bhnm", k_dec, vc))
        return S, y

    from .common import safe_unroll
    state, ys = jax.lax.scan(per_chunk, state, (rs, ks, vs, ws),
                             unroll=safe_unroll(nc, unroll))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, T, H, N)
    return y, state


def _ddlerp(x, x_prev, mu):
    return x + (x_prev - x) * mu.astype(x.dtype)


def time_mix(cfg: ModelConfig, p, x, x_prev, wkv_state, *, chunked: bool):
    """x: (B,T,D); x_prev: (B,1,D) last token of previous segment.
    Returns (y, last_x, new_state)."""
    B, T, D = x.shape
    H = cfg.ssm_heads or (D // 64)
    N = D // H
    xs = jnp.concatenate([x_prev, x[:, :-1]], axis=1)     # token shift
    xr = _ddlerp(x, xs, p["mu_r"])
    xk = _ddlerp(x, xs, p["mu_k"])
    xv = _ddlerp(x, xs, p["mu_v"])
    xw = _ddlerp(x, xs, p["mu_w"])
    xg = _ddlerp(x, xs, p["mu_g"])
    r = jnp.einsum("btd,de->bte", xr, p["wr"].astype(x.dtype))
    k = jnp.einsum("btd,de->bte", xk, p["wk"].astype(x.dtype))
    v = jnp.einsum("btd,de->bte", xv, p["wv"].astype(x.dtype))
    g = jnp.einsum("btd,de->bte", xg, p["wg"].astype(x.dtype))
    # data-dependent decay (Finch): w = exp(-exp(w0 + tanh(x A) B)).
    # The log-decay is clamped to [-2.5, -1e-4] so the chunked form's
    # exp(-cumsum) stays inside f32 range (standard practice in RWKV
    # kernels; the scan path uses the same clamp for exact equivalence).
    lora = jnp.einsum(
        "btr,rd->btd",
        jnp.tanh(jnp.einsum("btd,dr->btr", xw.astype(jnp.float32),
                            p["decay_A"].astype(jnp.float32))),
        p["decay_B"].astype(jnp.float32))
    log_w = jnp.clip(-jnp.exp(p["w0"].astype(jnp.float32) + lora),
                     -2.5, -1e-4)
    w = jnp.exp(log_w)                                 # (B,T,D) in (0,1)

    shp = (B, T, H, N)
    rh, kh, vh = (a.astype(jnp.float32).reshape(shp) for a in (r, k, v))
    wh = w.reshape(shp)
    uh = p["u"].astype(jnp.float32).reshape(H, N)
    if chunked and T % 32 == 0 and T > 1:
        y, state = wkv_chunked(rh, kh, vh, wh, uh, wkv_state, chunk=32,
                               unroll=cfg.time_unroll)
    else:
        y, state = wkv_scan(rh, kh, vh, wh, uh, wkv_state)
    # per-head group norm + silu(g) gate
    y = y.reshape(B, T, H, N)
    y = y * jax.lax.rsqrt((y * y).mean(-1, keepdims=True) + 1e-5)
    y = (y.reshape(B, T, D) * p["gn"].astype(jnp.float32)
         * jax.nn.silu(g.astype(jnp.float32)))
    out = jnp.einsum("btd,de->bte", y.astype(x.dtype),
                     p["wo"].astype(x.dtype))
    return out, x[:, -1:], state


def channel_mix(cfg: ModelConfig, p, x, x_prev):
    xs = jnp.concatenate([x_prev, x[:, :-1]], axis=1)
    xk = _ddlerp(x, xs, p["mu_ck"])
    xr = _ddlerp(x, xs, p["mu_cr"])
    kk = jnp.einsum("btd,df->btf", xk, p["ck"].astype(x.dtype))
    kk = jnp.square(jax.nn.relu(kk.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("btf,fd->btd", kk, p["cv"].astype(x.dtype))
    rr = jax.nn.sigmoid(jnp.einsum(
        "btd,de->bte", xr, p["cr"].astype(x.dtype)).astype(jnp.float32))
    return (rr * out.astype(jnp.float32)).astype(x.dtype), x[:, -1:]


def block(cfg: ModelConfig, p, x, states, *, chunked: bool,
          shard_fn=_noshard):
    """states: dict(wkv (B,H,N,N), tm_x (B,1,D), cm_x (B,1,D))."""
    h = norm(x, p["ln1"], kind="rms")
    y, tm_x, wkv = time_mix(cfg, p, h, states["tm_x"], states["wkv"],
                            chunked=chunked)
    x = shard_fn(x + y, "act")
    h2 = norm(x, p["ln2"], kind="rms")
    y2, cm_x = channel_mix(cfg, p, h2, states["cm_x"])
    x = shard_fn(x + y2, "act")
    return x, {"wkv": wkv, "tm_x": tm_x, "cm_x": cm_x}


def init_state(cfg: ModelConfig, batch: int):
    D = cfg.d_model
    H = cfg.ssm_heads or (D // 64)
    N = D // H
    return {
        "wkv": jnp.zeros((cfg.n_layers, batch, H, N, N), jnp.float32),
        "tm_x": jnp.zeros((cfg.n_layers, batch, 1, D), cdtype(cfg)),
        "cm_x": jnp.zeros((cfg.n_layers, batch, 1, D), cdtype(cfg)),
    }


def forward(cfg: ModelConfig, params, tokens, state=None, *,
            chunked: bool = True, shard_fn=_noshard):
    """Returns (logits, new_state)."""
    B, T = tokens.shape
    x = params["embed"][tokens].astype(cdtype(cfg))
    if state is None:
        state = init_state(cfg, B)

    blk = functools.partial(block, cfg, chunked=chunked, shard_fn=shard_fn)
    if cfg.remat and T > 1:
        from .common import remat_policy
        blk = jax.checkpoint(blk, policy=remat_policy(cfg))

    def scan_body(x, layer_in):
        p_layer, st = layer_in
        x, st2 = blk(p_layer, x, st)
        return x, st2

    from .common import safe_unroll
    x, new_state = jax.lax.scan(
        scan_body, x, (params["layers"], state),
        unroll=safe_unroll(cfg.n_layers, cfg.layer_unroll))
    x = norm(x, params["final_ln"], kind="rms")
    logits = jnp.einsum("btd,dv->btv", x,
                        params["lm_head"].astype(x.dtype))
    return shard_fn(logits, "logits"), new_state


def loss_fn(cfg: ModelConfig, params, batch, shard_fn=_noshard):
    logits, _ = forward(cfg, params, batch["tokens"], shard_fn=shard_fn)
    return softmax_xent(logits, batch["labels"])


def decode_step(cfg: ModelConfig, params, token, state, shard_fn=_noshard):
    """token: (B,1). State-carried decode — O(1) in context length (the
    long_500k cell's whole point)."""
    logits, state = forward(cfg, params, token, state, chunked=False,
                            shard_fn=shard_fn)
    return logits, state