"""Mixture-of-Experts FFN (olmoe 64e/top-8, arctic 128e/top-2+dense).

The paper's flagship GCONV fit (DESIGN.md §3): experts are literally the
``Ng`` group parameter of a grouped GCONV — expert FFN compute is the grouped
matmul kernel's native workload, and the dispatch/combine edges are chain
data movement.

Dispatch is gather-based with static capacity (GShard-style, but with a
token-index table instead of a one-hot dispatch tensor, so HLO compute is
E*C*D*F — the MODEL_FLOPS of the active experts — rather than the dense
N*E*C mask einsum):

  1. router top-k + renormalized gates,
  2. per-expert token table (E, C) via a position-in-expert cumsum
     (capacity-dropped tokens contribute nothing),
  3. gather -> grouped FFN (einsum or the Pallas grouped kernel) -> weighted
     scatter-add back.

Aux load-balance loss per Fedus et al.; both MoE archs use it.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, ffn, ffn_param_shapes

_noshard = lambda x, tag=None: x


def moe_layer_param_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, ...]]:
    D, E, F = cfg.d_model, cfg.n_experts, cfg.d_ff
    shapes = {
        "router": (D, E),
        "e_gate": (E, D, F),
        "e_up": (E, D, F),
        "e_down": (E, F, D),
    }
    if cfg.moe_dense_ff:
        for k, s in ffn_param_shapes(cfg, cfg.moe_dense_ff).items():
            shapes[f"dense_{k}"] = s
    return shapes


def capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(cfg.capacity_factor * cfg.top_k * n_tokens / cfg.n_experts)
    return max(8, -(-c // 8) * 8)


def moe_ffn(cfg: ModelConfig, p: Dict[str, Any], x, shard_fn=_noshard):
    """x: (B, T, D) -> (y, aux_loss)."""
    B, T, D = x.shape
    N = B * T
    E, K = cfg.n_experts, cfg.top_k
    C = capacity(cfg, N)
    xf = x.reshape(N, D)

    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)          # (N, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch/GShard): E * sum_e f_e * P_e
    me = probs.mean(axis=0)                                   # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[expert_idx.reshape(-1)].add(
        1.0 / (N * K))
    aux = cfg.router_aux_coef * E * jnp.sum(me * ce)

    flat_expert = expert_idx.T.reshape(-1)                    # (K*N,)
    if "moe_sort" in cfg.perf_flags:
        # sort-based position-in-expert: O(KN log KN) instead of the
        # O(KN*E) one-hot cumsum — §Perf hillclimb for the MoE cells
        order = jnp.argsort(flat_expert)
        sorted_e = flat_expert[order]
        first = jnp.searchsorted(sorted_e, sorted_e, side="left")
        pos_sorted = jnp.arange(sorted_e.shape[0]) - first
        pos = jnp.zeros_like(pos_sorted).at[order].set(pos_sorted)
    else:
        # position-in-expert via one-hot cumsum over (K*N, E) (GShard-style)
        onehot = jax.nn.one_hot(flat_expert, E, dtype=jnp.int32)
        pos_in_e = jnp.cumsum(onehot, axis=0) * onehot        # rank within e
        pos = (pos_in_e.sum(-1) - 1)                          # (K*N,)
    keep = pos < C
    # token table: (E, C) -> flat token index (N); dropped slots point at
    # token 0 with zero combine weight
    token_ids = jnp.tile(jnp.arange(N), K)
    slot = jnp.where(keep, pos, C)        # dropped -> out of bounds -> "drop"
    table = jnp.zeros((E, C), jnp.int32)
    table = table.at[flat_expert, slot].set(token_ids, mode="drop")
    gates_flat = gate_vals.T.reshape(-1)
    gate_table = jnp.zeros((E, C), jnp.float32)
    gate_table = gate_table.at[flat_expert, slot].set(
        gates_flat, mode="drop")

    xg = xf[table]                                            # (E, C, D)
    xg = shard_fn(xg, "moe_dispatch")
    # grouped GCONV: Ng=E groups of (C x D) @ (D x F)
    g = jnp.einsum("ecd,edf->ecf", xg, p["e_gate"].astype(xg.dtype))
    u = jnp.einsum("ecd,edf->ecf", xg, p["e_up"].astype(xg.dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(xg.dtype) * u
    ye = jnp.einsum("ecf,efd->ecd", h, p["e_down"].astype(xg.dtype))
    ye = ye * gate_table[..., None].astype(ye.dtype)
    ye = shard_fn(ye, "moe_combine")

    if "moe_gather_combine" in cfg.perf_flags:
        # combine by GATHERING each token's k expert outputs instead of
        # scatter-adding into a replicated (N, D) buffer: the gather indexes
        # the already-gated ye by (expert, slot) per (k, token); dropped
        # tokens read slot C-1 of their expert with gate 0 via the gate
        # gathered alongside (ye already carries the gate weighting, and
        # dropped slots hold some other token's value — so gather the raw
        # expert output and re-apply this token's gate, zeroed when dropped)
        h_raw = jnp.einsum("ecf,efd->ecd", h, p["e_down"].astype(h.dtype))
        h_raw = shard_fn(h_raw, "moe_combine")
        slot_c = jnp.minimum(slot, C - 1).reshape(K, N)
        exp_c = flat_expert.reshape(K, N)
        picked = h_raw[exp_c, slot_c]                     # (K, N, D)
        g = jnp.where(keep, gates_flat, 0.0).reshape(K, N)
        y = jnp.einsum("kn,knd->nd", g, picked.astype(jnp.float32))
        y = y.astype(ye.dtype)
    else:
        y = jnp.zeros((N, D), ye.dtype).at[table.reshape(-1)].add(
            ye.reshape(E * C, D))
    # constrain the combined output back to the token sharding immediately
    y = shard_fn(y.reshape(B, T, D), "act")
    if cfg.moe_dense_ff:
        dense_p = {k[len("dense_"):]: v for k, v in p.items()
                   if k.startswith("dense_")}
        y = y + ffn(cfg, dense_p, x)
    return y, aux
