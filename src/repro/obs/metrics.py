"""Metrics registry: labeled counters, gauges, fixed-bucket histograms.

The one numbers schema the repo's reporters emit through — the serving
driver's stats, the simulator's per-node/per-chain summaries and the
benchmark harness all build their dicts over this registry, so their
outputs stay mergeable and diffable across runs (``snapshot``/``merge``/
``diff``) instead of each subsystem hand-rolling its own dict shape.

A *family* is a metric name + type; a *series* is one labeled instance of
it (``reg.counter("sim_cycles", node="conv1")``). ``to_dict()`` emits the
versioned schema::

    {"schema": "repro.obs.metrics", "version": 1,
     "metrics": {name: {"type": "counter"|"gauge"|"histogram",
                        "series": [{"labels": {...}, ...values...}]}}}

counter/gauge series carry ``{"value": v}``; histogram series carry
``{"buckets": [ub...], "counts": [c...], "count": n, "sum": s}`` with
``counts`` one longer than ``buckets`` (the overflow bucket). The
registry is pure stdlib — importable from anywhere (sim, launch,
benchmarks) without dragging jax in.
"""
from __future__ import annotations

import math
from bisect import bisect_left
from typing import Dict, List, Optional, Sequence, Tuple

SCHEMA = "repro.obs.metrics"
SCHEMA_VERSION = 1

LabelKey = Tuple[Tuple[str, str], ...]


def percentile(xs: Sequence[float], q: float) -> float:
    """Linear-interpolated percentile (``numpy.percentile`` semantics),
    well-formed on degenerate inputs: ``[] -> 0.0``, ``[x] -> x``. The
    serving driver's stats and the trace report CLI both compute through
    THIS function, so their percentiles agree bit for bit."""
    xs = sorted(float(x) for x in xs)
    if not xs:
        return 0.0
    if len(xs) == 1:
        return xs[0]
    rank = (len(xs) - 1) * (q / 100.0)
    lo = math.floor(rank)
    hi = math.ceil(rank)
    if lo == hi:
        return xs[int(rank)]
    frac = rank - lo
    return xs[lo] * (1.0 - frac) + xs[hi] * frac


def exp_buckets(lo: float, hi: float, n: int) -> List[float]:
    """``n`` geometrically spaced bucket upper bounds spanning [lo, hi]."""
    if not (lo > 0 and hi > lo and n >= 2):
        raise ValueError(f"need hi > lo > 0 and n >= 2, got {lo}, {hi}, {n}")
    ratio = (hi / lo) ** (1.0 / (n - 1))
    return [lo * ratio ** i for i in range(n)]


class Counter:
    """Monotonically increasing sum."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, v: float = 1.0):
        if v < 0:
            raise ValueError(f"counter increment must be >= 0, got {v}")
        self.value += v
        return self


class Gauge:
    """Last-written value."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float):
        self.value = float(v)
        return self


class Histogram:
    """Fixed upper-bound buckets + an overflow bucket.

    ``buckets[i]`` is the inclusive upper bound of bucket ``i`` (the
    Prometheus ``le`` convention): an observation lands in the first
    bucket whose bound is ``>= v``, or in the overflow bucket when it
    exceeds every bound.
    """

    __slots__ = ("buckets", "counts", "count", "sum")

    def __init__(self, buckets: Sequence[float]):
        bs = [float(b) for b in buckets]
        if not bs or sorted(bs) != bs or len(set(bs)) != len(bs):
            raise ValueError(f"buckets must be strictly increasing: {bs}")
        self.buckets = bs
        self.counts = [0] * (len(bs) + 1)
        self.count = 0
        self.sum = 0.0

    def observe(self, v: float):
        self.counts[bisect_left(self.buckets, float(v))] += 1
        self.count += 1
        self.sum += float(v)
        return self

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """Bucket-resolution quantile (upper bound of the bucket holding
        the q-th observation; the overflow bucket reports its lower
        bound). Coarse by construction — exact percentiles come from the
        raw samples via :func:`percentile`."""
        if self.count == 0:
            return 0.0
        target = q / 100.0 * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= target and c:
                return (self.buckets[i] if i < len(self.buckets)
                        else self.buckets[-1])
        return self.buckets[-1]


_TYPES = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


def _label_key(labels: Dict[str, object]) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class Metrics:
    """The registry: families of labeled series, one schema out."""

    def __init__(self):
        # name -> {"type": str, "buckets": [...]|None, "series": {key: m}}
        self._families: Dict[str, dict] = {}

    # -- creation/access ------------------------------------------------
    def _series(self, name: str, typ: str, buckets=None, labels=None):
        fam = self._families.get(name)
        if fam is None:
            fam = {"type": typ, "buckets": list(buckets) if buckets else None,
                   "series": {}}
            self._families[name] = fam
        elif fam["type"] != typ:
            raise ValueError(f"metric {name!r} is a {fam['type']}, "
                             f"not a {typ}")
        key = _label_key(labels or {})
        m = fam["series"].get(key)
        if m is None:
            m = (Histogram(buckets if buckets is not None
                           else fam["buckets"])
                 if typ == "histogram" else _TYPES[typ]())
            fam["series"][key] = m
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._series(name, "counter", labels=labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._series(name, "gauge", labels=labels)

    def histogram(self, name: str, buckets: Optional[Sequence[float]] = None,
                  **labels) -> Histogram:
        if buckets is None and name not in self._families:
            raise ValueError(f"first use of histogram {name!r} must "
                             f"declare buckets")
        return self._series(name, "histogram", buckets=buckets,
                            labels=labels)

    def value(self, name: str, **labels) -> float:
        """Scalar value of a counter/gauge series (KeyError if absent)."""
        fam = self._families[name]
        m = fam["series"][_label_key(labels)]
        if isinstance(m, Histogram):
            raise TypeError(f"{name!r} is a histogram; read its series")
        return m.value

    def families(self) -> List[str]:
        return sorted(self._families)

    # -- schema ---------------------------------------------------------
    def to_dict(self) -> dict:
        out = {}
        for name in sorted(self._families):
            fam = self._families[name]
            series = []
            for key in sorted(fam["series"]):
                m = fam["series"][key]
                entry = {"labels": dict(key)}
                if isinstance(m, Histogram):
                    entry.update(buckets=list(m.buckets),
                                 counts=list(m.counts),
                                 count=m.count, sum=m.sum)
                else:
                    entry["value"] = m.value
                series.append(entry)
            out[name] = {"type": fam["type"], "series": series}
        return {"schema": SCHEMA, "version": SCHEMA_VERSION, "metrics": out}

    @classmethod
    def from_dict(cls, d: dict) -> "Metrics":
        if d.get("schema") != SCHEMA or d.get("version") != SCHEMA_VERSION:
            raise ValueError(f"not a {SCHEMA}/{SCHEMA_VERSION} payload: "
                             f"{d.get('schema')!r}/{d.get('version')!r}")
        reg = cls()
        for name, fam in d["metrics"].items():
            for s in fam["series"]:
                labels = s["labels"]
                if fam["type"] == "histogram":
                    h = reg.histogram(name, buckets=s["buckets"], **labels)
                    h.counts = [int(c) for c in s["counts"]]
                    h.count = int(s["count"])
                    h.sum = float(s["sum"])
                elif fam["type"] == "counter":
                    reg.counter(name, **labels).inc(float(s["value"]))
                else:
                    reg.gauge(name, **labels).set(float(s["value"]))
        return reg

    # -- snapshot / merge / diff ---------------------------------------
    def snapshot(self) -> "Metrics":
        return Metrics.from_dict(self.to_dict())

    def merge(self, other: "Metrics") -> "Metrics":
        """Fold ``other`` into ``self``: counters and histogram buckets
        add, gauges take ``other``'s value. Returns ``self``."""
        for name, fam in other._families.items():
            for key, m in fam["series"].items():
                labels = dict(key)
                if fam["type"] == "counter":
                    self.counter(name, **labels).inc(m.value)
                elif fam["type"] == "gauge":
                    self.gauge(name, **labels).set(m.value)
                else:
                    h = self.histogram(name, buckets=m.buckets, **labels)
                    if h.buckets != m.buckets:
                        raise ValueError(f"histogram {name!r}{labels}: "
                                         f"bucket mismatch")
                    h.counts = [a + b for a, b in zip(h.counts, m.counts)]
                    h.count += m.count
                    h.sum += m.sum
        return self

    def diff(self, earlier: "Metrics") -> "Metrics":
        """New registry holding ``self - earlier``: counters and histogram
        buckets subtract (a series absent from ``earlier`` passes through
        whole); gauges keep ``self``'s current value."""
        out = self.snapshot()
        for name, fam in earlier._families.items():
            if name not in out._families:
                continue
            ofam = out._families[name]
            for key, m in fam["series"].items():
                o = ofam["series"].get(key)
                if o is None:
                    continue
                if fam["type"] == "counter":
                    o.value -= m.value
                elif fam["type"] == "histogram":
                    if o.buckets != m.buckets:
                        raise ValueError(f"histogram {name!r}: bucket "
                                         f"mismatch in diff")
                    o.counts = [a - b for a, b in zip(o.counts, m.counts)]
                    o.count -= m.count
                    o.sum -= m.sum
        return out
