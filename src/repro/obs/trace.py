"""Span tracer: nested timed spans, counter tracks, Chrome-trace export.

The tracing substrate every subsystem emits through (the profiled
compiled engine, the serving driver's per-request lifecycle, the DSE
driver). Design constraints, in priority order:

  1. **Provably near-zero cost when disabled.** ``Tracer.span`` on a
     disabled tracer returns a module-level singleton no-op context
     manager — no object, dict or closure is allocated per call
     (regression-tested with ``tracemalloc`` in tests/test_obs.py), and
     hot paths additionally gate on ``tracer.enabled`` before building
     attr dicts.
  2. **Bounded memory.** Finished events land in a ring buffer
     (``collections.deque(maxlen=capacity)``); a long serve run keeps the
     most recent ``capacity`` events rather than growing without bound.
  3. **Standard viewers.** ``write(path)`` emits Chrome trace-event JSON
     (``*.json`` — load it in Perfetto / ``chrome://tracing``) or the
     line-oriented JSONL form (``*.jsonl``); both carry the schema name
     and version and round-trip through :func:`load_trace`.

Event schema (version :data:`SCHEMA_VERSION`) — one dict per event:

  * ``span``:    ``{type, name, cat, id, parent, ts, dur, wall, args}``
                 — ``ts``/``dur`` in microseconds on the tracer's
                 monotonic clock, ``wall`` the wall-clock epoch seconds
                 of the span start, ``parent`` the enclosing span's id
                 (``None`` at top level).
  * ``instant``: ``{type, name, cat, ts, args}``
  * ``counter``: ``{type, name, ts, values}`` — a named multi-series
                 counter track (Chrome ``C`` events; e.g. the serving
                 driver's per-tick slot occupancy).

The serving trace additionally follows the *request lifecycle* schema
that ``repro.sim`` can replay: per finished request one ``request`` span
(cat ``request``) whose ``args`` carry ``rid``, ``prompt_len``,
``max_new``, ``out_len``, ``submit_tick``/``admit_tick``/``done_tick``
(driver tick indices, the simulator's replay clock) and the measured
``queue_wait_s``/``ttft_s``/``latency_s``, plus ``queue``/``prefill``/
``decode`` child spans subdividing it.
"""
from __future__ import annotations

import json
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional

SCHEMA = "repro.obs.trace"
SCHEMA_VERSION = 1

_EVENT_TYPES = ("span", "instant", "counter")


class _NullSpan:
    """Singleton no-op context manager returned by a disabled tracer."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, et, ev, tb):
        return False

    def set(self, key, value):
        return self


_NULL_SPAN = _NullSpan()


class _Span:
    """A live span; records itself into the tracer's ring on exit."""

    __slots__ = ("_tr", "name", "cat", "attrs", "id", "parent", "_t0",
                 "_wall0")

    def __init__(self, tr: "Tracer", name: str, cat: str,
                 attrs: Optional[dict]):
        self._tr = tr
        self.name = name
        self.cat = cat
        self.attrs = attrs

    def set(self, key, value):
        """Attach one attribute after entry (lazy attrs on live spans)."""
        if self.attrs is None:
            self.attrs = {}
        self.attrs[key] = value
        return self

    def __enter__(self):
        tr = self._tr
        self.id = tr._next_id
        tr._next_id += 1
        stack = tr._stack
        self.parent = stack[-1].id if stack else None
        stack.append(self)
        self._wall0 = time.time()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, et, ev, tb):
        t1 = time.perf_counter()
        tr = self._tr
        tr._stack.pop()
        tr.events.append({
            "type": "span", "name": self.name, "cat": self.cat,
            "id": self.id, "parent": self.parent,
            "ts": (self._t0 - tr._epoch) * 1e6,
            "dur": (t1 - self._t0) * 1e6,
            "wall": self._wall0,
            "args": self.attrs or {},
        })
        return False


class Tracer:
    """Ring-buffered span/counter tracer.

    ``enabled`` may be flipped at runtime; while ``False`` every emission
    method is a flag check returning a shared no-op. ``meta`` is free-form
    context (arch, slot count, ...) carried in the exported header.
    """

    def __init__(self, capacity: int = 65536, enabled: bool = True):
        self.enabled = enabled
        self.capacity = int(capacity)
        self.events: deque = deque(maxlen=self.capacity)
        self.meta: Dict[str, object] = {}
        self._stack: List[_Span] = []
        self._next_id = 0
        self._epoch = time.perf_counter()   # monotonic trace time zero
        self._wall_epoch = time.time()

    # -- emission -------------------------------------------------------
    def span(self, name, cat="default", attrs=None):
        """Context manager timing a nested span. Disabled: no-op
        singleton, no per-call allocation beyond this flag check."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, cat, attrs)

    def instant(self, name, cat="default", attrs=None):
        if not self.enabled:
            return
        self.events.append({
            "type": "instant", "name": name, "cat": cat,
            "ts": (time.perf_counter() - self._epoch) * 1e6,
            "args": attrs or {}})

    def counter(self, name, values):
        """One sample of a multi-series counter track (Chrome ``C``)."""
        if not self.enabled:
            return
        self.events.append({
            "type": "counter", "name": name,
            "ts": (time.perf_counter() - self._epoch) * 1e6,
            "values": dict(values)})

    def add_span(self, name, cat, start, end, parent=None, attrs=None):
        """Record a span from explicit ``time.perf_counter()`` endpoints
        (the serving driver's request lifecycle: the timestamps were taken
        long before the span is emitted). Returns the span id so callers
        can parent children onto it."""
        if not self.enabled:
            return None
        sid = self._next_id
        self._next_id += 1
        self.events.append({
            "type": "span", "name": name, "cat": cat,
            "id": sid, "parent": parent,
            "ts": (start - self._epoch) * 1e6,
            "dur": max(0.0, (end - start) * 1e6),
            "wall": self._wall_epoch + (start - self._epoch),
            "args": attrs or {}})
        return sid

    def us(self, t_perf: float) -> float:
        """Trace-relative microseconds of a ``time.perf_counter()`` value."""
        return (t_perf - self._epoch) * 1e6

    # -- export ---------------------------------------------------------
    def _header(self) -> dict:
        return {"schema": SCHEMA, "version": SCHEMA_VERSION,
                "meta": dict(self.meta)}

    def chrome(self) -> dict:
        """Chrome trace-event JSON object (Perfetto-loadable)."""
        evs = []
        for e in self.events:
            if e["type"] == "span":
                args = dict(e["args"])
                args["id"] = e["id"]
                if e["parent"] is not None:
                    args["parent"] = e["parent"]
                evs.append({"name": e["name"], "cat": e["cat"], "ph": "X",
                            "ts": e["ts"], "dur": e["dur"],
                            "pid": 0, "tid": 0, "args": args})
            elif e["type"] == "instant":
                evs.append({"name": e["name"], "cat": e["cat"], "ph": "i",
                            "s": "t", "ts": e["ts"], "pid": 0, "tid": 0,
                            "args": dict(e["args"])})
            elif e["type"] == "counter":
                evs.append({"name": e["name"], "ph": "C", "ts": e["ts"],
                            "pid": 0, "args": dict(e["values"])})
        return {"traceEvents": evs, "otherData": self._header()}

    def write(self, path: str):
        """``*.jsonl`` -> the JSONL schema; anything else -> Chrome JSON."""
        if str(path).endswith(".jsonl"):
            with open(path, "w") as f:
                f.write(json.dumps(self._header()) + "\n")
                for e in self.events:
                    f.write(json.dumps(e, default=float) + "\n")
        else:
            with open(path, "w") as f:
                json.dump(self.chrome(), f, default=float)


# ---------------------------------------------------------------------------
# loading + validation
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ServeRequest:
    """One recorded request lifecycle (the serving driver's ``request``
    span). Tick fields are driver tick indices — the replay clock the
    system simulator schedules against; seconds fields are the measured
    wall-clock latencies. Missing args load as ``None`` so partial traces
    still iterate."""

    rid: Optional[int]
    prompt_len: Optional[int]
    max_new: Optional[int]
    out_len: Optional[int]
    submit_tick: Optional[int]
    admit_tick: Optional[int]
    done_tick: Optional[int]
    queue_wait_s: Optional[float]
    ttft_s: Optional[float]
    latency_s: Optional[float]
    phases: Dict[str, float] = field(default_factory=dict)  # name -> secs
    args: Dict[str, object] = field(default_factory=dict)

    @property
    def tokens(self) -> float:
        """Prompt + generated tokens (generated falls back to the
        ``max_new`` budget when ``out_len`` was not recorded)."""
        out = self.out_len if self.out_len is not None else (self.max_new
                                                            or 0)
        return float((self.prompt_len or 0) + out)

    @property
    def service_ticks(self) -> Optional[int]:
        if self.admit_tick is None or self.done_tick is None:
            return None
        return max(1, self.done_tick - self.admit_tick)


@dataclass(frozen=True)
class ServeTick:
    """One serving-driver tick: slot occupancy sampled from the ``slots``
    counter track (``index`` prefers the recorded tick number, falling
    back to sample order for pre-tick-stamp traces)."""

    index: int
    active: int
    queued: int
    ts: float


class Trace:
    """A loaded, schema-validated trace (either export format)."""

    def __init__(self, meta: dict, events: List[dict], version: int):
        self.meta = meta
        self.events = events
        self.version = version

    @property
    def spans(self) -> List[dict]:
        return [e for e in self.events if e["type"] == "span"]

    @property
    def instants(self) -> List[dict]:
        return [e for e in self.events if e["type"] == "instant"]

    @property
    def counters(self) -> List[dict]:
        return [e for e in self.events if e["type"] == "counter"]

    # -- serve-schema iterators ----------------------------------------
    # The stable request/tick API shared by repro.obs.report and the
    # repro.syssim replay frontend (so the two cannot drift on how the
    # lifecycle schema is interpreted).
    def serve_requests(self) -> List[ServeRequest]:
        """Recorded request lifecycles, sorted by (submit_tick, rid).
        Child ``queue``/``prefill``/``decode`` spans are folded into
        ``phases`` (seconds)."""
        spans = self.spans
        kids: Dict[object, List[dict]] = {}
        for s in spans:
            p = s.get("parent")
            if p is not None:
                kids.setdefault(p, []).append(s)
        out = []
        for s in spans:
            if s["cat"] != "request" or s["name"] != "request":
                continue
            a = s["args"]
            phases = {c["name"]: c["dur"] / 1e6
                      for c in kids.get(s.get("id"), ())
                      if c["cat"] == "request"}
            out.append(ServeRequest(
                rid=a.get("rid"), prompt_len=a.get("prompt_len"),
                max_new=a.get("max_new"), out_len=a.get("out_len"),
                submit_tick=a.get("submit_tick"),
                admit_tick=a.get("admit_tick"),
                done_tick=a.get("done_tick"),
                queue_wait_s=a.get("queue_wait_s"),
                ttft_s=a.get("ttft_s"), latency_s=a.get("latency_s"),
                phases=phases, args=dict(a)))
        inf = float("inf")
        out.sort(key=lambda r: (r.submit_tick if r.submit_tick is not None
                                else inf,
                                r.rid if r.rid is not None else inf))
        return out

    def serve_ticks(self) -> List[ServeTick]:
        """Per-tick slot occupancy from the ``slots`` counter track, in
        emission order."""
        out = []
        for c in self.counters:
            if c["name"] != "slots":
                continue
            v = c["values"]
            out.append(ServeTick(index=int(v.get("tick", len(out))),
                                 active=int(v.get("active", 0)),
                                 queued=int(v.get("queued", 0)),
                                 ts=float(c["ts"])))
        return out


_REQUIRED = {
    "span": ("name", "cat", "id", "ts", "dur", "args"),
    "instant": ("name", "cat", "ts", "args"),
    "counter": ("name", "ts", "values"),
}


def validate_event(e: dict):
    t = e.get("type")
    if t not in _EVENT_TYPES:
        raise ValueError(f"unknown trace event type {t!r}")
    missing = [k for k in _REQUIRED[t] if k not in e]
    if missing:
        raise ValueError(f"{t} event missing fields {missing}: {e}")


def _validate_header(hdr: dict) -> dict:
    if hdr.get("schema") != SCHEMA:
        raise ValueError(f"not a {SCHEMA} trace: schema={hdr.get('schema')!r}")
    v = hdr.get("version")
    if v != SCHEMA_VERSION:
        raise ValueError(f"unsupported {SCHEMA} version {v!r} "
                         f"(supported: {SCHEMA_VERSION})")
    return hdr


def _from_chrome(doc: dict) -> Trace:
    hdr = _validate_header(doc.get("otherData") or {})
    events = []
    for ev in doc.get("traceEvents", []):
        ph = ev.get("ph")
        if ph == "X":
            args = dict(ev.get("args") or {})
            sid = args.pop("id", None)
            parent = args.pop("parent", None)
            events.append({"type": "span", "name": ev["name"],
                           "cat": ev.get("cat", "default"), "id": sid,
                           "parent": parent, "ts": ev["ts"],
                           "dur": ev.get("dur", 0.0), "args": args})
        elif ph == "i":
            events.append({"type": "instant", "name": ev["name"],
                           "cat": ev.get("cat", "default"), "ts": ev["ts"],
                           "args": dict(ev.get("args") or {})})
        elif ph == "C":
            events.append({"type": "counter", "name": ev["name"],
                           "ts": ev["ts"],
                           "values": dict(ev.get("args") or {})})
    for e in events:
        validate_event(e)
    return Trace(hdr.get("meta", {}), events, hdr["version"])


def _from_jsonl(lines: List[str]) -> Trace:
    if not lines:
        raise ValueError("empty trace file")
    hdr = _validate_header(json.loads(lines[0]))
    events = []
    for ln in lines[1:]:
        ln = ln.strip()
        if not ln:
            continue
        e = json.loads(ln)
        validate_event(e)
        events.append(e)
    return Trace(hdr.get("meta", {}), events, hdr["version"])


def load_trace(path: str) -> Trace:
    """Load + validate a trace written by :meth:`Tracer.write` (either
    format, auto-detected). Raises ``ValueError`` on schema violations."""
    with open(path) as f:
        text = f.read()
    stripped = text.lstrip()
    if stripped.startswith("{") and '"traceEvents"' in stripped[:4096]:
        return _from_chrome(json.loads(text))
    return _from_jsonl(text.splitlines())
