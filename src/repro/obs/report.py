"""Trace summarizer: ``python -m repro.obs.report TRACE[.json|.jsonl]``.

Reconstructs, from any trace written by :class:`repro.obs.trace.Tracer`:

  * **top spans by self-time** — per span name, call count, total and
    self time (duration minus nested children), the profiler's headline;
  * **per-backend time share** — execute spans attributed with a
    ``backend`` arg (the profiled compiled engine) aggregated into a
    time-share map;
  * **request latency breakdown** — ``request``-category lifecycle spans:
    request count plus queue-wait/TTFT/latency p50/p99 recomputed from
    the per-request args through the same :func:`repro.obs.metrics.
    percentile` the serving driver's ``Server.stats()`` uses, so the two
    agree bit for bit;
  * **slot utilization** — the serving driver's per-tick ``slots``
    counter track averaged against the slot capacity in the trace meta;
  * **profile coverage** — for profiled engine runs, the fraction of the
    latest ``chain`` span's wall time attributed to named child steps
    (the acceptance bar is >= 0.95);
  * **fault timeline** — ``chaos``/``resilience``-category instants
    (injected faults, retries, quarantines, sheds, degrade/recover
    transitions) in tick order, with per-event counts.

Prints one JSON object; exits nonzero on unreadable/invalid traces.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional, Sequence

from .metrics import percentile
from .trace import Trace, load_trace


def _span_children(spans: List[dict]) -> Dict[object, List[dict]]:
    kids: Dict[object, List[dict]] = {}
    for s in spans:
        p = s.get("parent")
        if p is not None:
            kids.setdefault(p, []).append(s)
    return kids


def top_spans(trace: Trace, n: int = 15) -> List[dict]:
    spans = trace.spans
    kids = _span_children(spans)
    agg: Dict[str, dict] = {}
    for s in spans:
        child_t = sum(c["dur"] for c in kids.get(s.get("id"), ()))
        a = agg.setdefault(s["name"], dict(name=s["name"], cat=s["cat"],
                                           calls=0, total_us=0.0,
                                           self_us=0.0))
        a["calls"] += 1
        a["total_us"] += s["dur"]
        a["self_us"] += max(0.0, s["dur"] - child_t)
    out = sorted(agg.values(), key=lambda a: -a["self_us"])[:n]
    for a in out:
        a["total_us"] = round(a["total_us"], 1)
        a["self_us"] = round(a["self_us"], 1)
    return out


def backend_share(trace: Trace) -> Dict[str, float]:
    """Time share per ``backend`` arg over backend-attributed spans."""
    by: Dict[str, float] = {}
    for s in trace.spans:
        b = s["args"].get("backend")
        if b is not None:
            by[b] = by.get(b, 0.0) + s["dur"]
    total = sum(by.values())
    return ({b: round(v / total, 4) for b, v in sorted(by.items())}
            if total > 0 else {})


def request_stats(trace: Trace) -> dict:
    """Request count + latency percentiles from the stable
    :meth:`Trace.serve_requests` lifecycle iterator (shared with the
    ``repro.syssim`` replay frontend). Keys are well-formed for zero and
    one finished request (percentile() contract)."""
    reqs = trace.serve_requests()
    qw = [r.queue_wait_s for r in reqs if r.queue_wait_s is not None]
    ttft = [r.ttft_s for r in reqs if r.ttft_s is not None]
    lat = [r.latency_s for r in reqs if r.latency_s is not None]
    return {
        "requests": len(reqs),
        "p50_queue_wait_s": percentile(qw, 50),
        "p99_queue_wait_s": percentile(qw, 99),
        "p50_ttft_s": percentile(ttft, 50),
        "p99_ttft_s": percentile(ttft, 99),
        "p50_latency_s": percentile(lat, 50),
        "p99_latency_s": percentile(lat, 99),
        "tokens_out": sum(int(r.out_len or 0) for r in reqs),
    }


def phase_breakdown(trace: Trace) -> Dict[str, dict]:
    """p50/total seconds per request-lifecycle phase (queue/prefill/
    decode child spans folded into each ``ServeRequest``)."""
    phases: Dict[str, List[float]] = {}
    for r in trace.serve_requests():
        for name, secs in r.phases.items():
            phases.setdefault(name, []).append(secs)
    return {name: {"count": len(xs), "p50_s": percentile(xs, 50),
                   "total_s": round(sum(xs), 6)}
            for name, xs in sorted(phases.items())}


def slot_utilization(trace: Trace) -> Optional[float]:
    ticks = trace.serve_ticks()
    if not ticks:
        return None
    slots = trace.meta.get("slots")
    mean_active = sum(t.active for t in ticks) / len(ticks)
    return round(mean_active / slots, 4) if slots else round(mean_active, 4)


def profile_coverage(trace: Trace) -> Optional[dict]:
    """Fraction of the latest ``chain`` span attributed to named child
    steps — how much of a profiled run the profiler can explain."""
    chains = [s for s in trace.spans if s["cat"] == "chain"]
    if not chains:
        return None
    kids = _span_children(trace.spans)
    last = chains[-1]
    steps = kids.get(last.get("id"), [])
    child_t = sum(c["dur"] for c in steps)
    cov = child_t / last["dur"] if last["dur"] > 0 else 0.0
    return {"chain": last["name"], "span_us": round(last["dur"], 1),
            "steps": len(steps), "attributed_us": round(child_t, 1),
            "coverage": round(min(cov, 1.0), 4),
            "signature": last["args"].get("signature")}


def fault_timeline(trace: Trace) -> Optional[dict]:
    """Resilience timeline from ``chaos``/``resilience``-category instants
    (injected faults, retries, quarantines, sheds, degrade/recover
    transitions, snapshots). ``events`` is the chronological list (tick,
    event name, site/kind detail); ``counts`` aggregates per event name.
    None when the trace carries no fault activity — fault-free traces
    keep their summary unchanged."""
    marks = [e for e in trace.instants
             if e["cat"] in ("chaos", "resilience")]
    if not marks:
        return None
    marks.sort(key=lambda e: e["ts"])
    counts: Dict[str, int] = {}
    events = []
    for e in marks:
        counts[e["name"]] = counts.get(e["name"], 0) + 1
        a = e["args"]
        detail = {k: a[k] for k in ("site", "kind", "status", "rid",
                                    "slot", "error", "index")
                  if k in a}
        events.append({"ts_us": round(e["ts"], 1), "event": e["name"],
                       "tick": a.get("tick"), **detail})
    return {"counts": dict(sorted(counts.items())), "events": events}


def summarize(trace: Trace, top: int = 15) -> dict:
    out = {"schema_version": trace.version, "meta": trace.meta,
           "events": len(trace.events), "spans": len(trace.spans)}
    out.update(request_stats(trace))
    out["phases"] = phase_breakdown(trace)
    out["slot_utilization"] = slot_utilization(trace)
    out["backend_share"] = backend_share(trace)
    out["profile"] = profile_coverage(trace)
    out["faults"] = fault_timeline(trace)
    out["top_spans"] = top_spans(trace, top)
    return out


def render_text(out: dict) -> str:
    """Terminal-friendly rendering of a :func:`summarize` dict."""
    lines = [f"trace: schema v{out['schema_version']}, "
             f"{out['events']} events, {out['spans']} spans",
             f"meta: {json.dumps(out['meta'], default=str)}",
             f"requests: {out['requests']}  "
             f"tokens_out: {out['tokens_out']}"]
    for k in ("queue_wait", "ttft", "latency"):
        p50, p99 = out[f"p50_{k}_s"], out[f"p99_{k}_s"]
        if p50 is not None:
            lines.append(f"  {k}: p50 {p50:.6f}s  p99 {p99:.6f}s")
    for name, ph in (out.get("phases") or {}).items():
        lines.append(f"  phase {name}: x{ph['count']} "
                     f"p50 {ph['p50_s']:.6f}s total {ph['total_s']:.6f}s")
    if out.get("slot_utilization") is not None:
        lines.append(f"slot_utilization: {out['slot_utilization']}")
    if out.get("backend_share"):
        lines.append("backend_share: " + ", ".join(
            f"{b}={v:.2%}" for b, v in out["backend_share"].items()))
    if out.get("profile"):
        pr = out["profile"]
        lines.append(f"profile: {pr['chain']} coverage {pr['coverage']:.2%}"
                     f" over {pr['steps']} steps")
    if out.get("faults"):
        lines.append("faults: " + json.dumps(out["faults"]["counts"]))
    lines.append(f"top spans (self time, top {len(out['top_spans'])}):")
    for a in out["top_spans"]:
        lines.append(f"  {a['self_us']:>12.1f}us self "
                     f"{a['total_us']:>12.1f}us total x{a['calls']:<6} "
                     f"{a['name']} [{a['cat']}]")
    return "\n".join(lines)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Summarize a repro.obs trace (Chrome JSON or JSONL).")
    ap.add_argument("trace", help="path written by Tracer.write / --trace")
    ap.add_argument("--top", type=int, default=15,
                    help="span-name rows in the self-time table")
    ap.add_argument("--format", choices=("json", "text"), default="json",
                    help="json (default): one machine-readable object, "
                         "consumed by benchmark cells and syssim tooling; "
                         "text: terminal rendering of the same summary")
    args = ap.parse_args(argv)
    try:
        trace = load_trace(args.trace)
    except (OSError, ValueError) as e:
        print(f"report: invalid trace {args.trace!r}: {e}", file=sys.stderr)
        return 1
    out = summarize(trace, top=args.top)
    try:
        if args.format == "text":
            print(render_text(out))
        else:
            print(json.dumps(out, indent=1, default=float))
    except BrokenPipeError:            # | head etc. closed stdout
        pass
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
