"""``repro.obs`` — unified tracing, metrics and profiling substrate.

Dependency-free (stdlib only at import time) so every layer can emit
through it: the compiled engine's ``profile=True`` mode, the serving
driver's ``--trace`` request-lifecycle trace, the simulator's stats and
the benchmark harness's provenance-stamped artifacts.

  * :mod:`repro.obs.trace`   — ring-buffered span tracer, Chrome/JSONL
    export (:data:`~repro.obs.trace.SCHEMA_VERSION`), :func:`load_trace`.
  * :mod:`repro.obs.metrics` — labeled counters/gauges/histograms with
    ``snapshot``/``merge``/``diff`` and one versioned ``to_dict`` schema;
    the shared :func:`~repro.obs.metrics.percentile`.
  * :mod:`repro.obs.report`  — ``python -m repro.obs.report TRACE``
    (top spans by self-time, backend time share, slot utilization,
    request-latency breakdown, profile coverage).
  * :func:`provenance` — git SHA / dirty flag / jax version / device kind
    stamp for result artifacts.
"""
from __future__ import annotations

import os
import subprocess
import sys
import time

from .metrics import Metrics, exp_buckets, percentile  # noqa: F401
from .trace import Trace, Tracer, load_trace  # noqa: F401


def provenance(repo_root: str = None) -> dict:
    """One attribution stamp per artifact-writing invocation: git SHA +
    dirty flag, jax version, device kind, timestamp. Every field degrades
    to ``None`` rather than raising — provenance must never break the run
    it describes."""
    root = repo_root or os.path.join(os.path.dirname(__file__), "..", "..",
                                     "..")
    sha, dirty = None, None
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=root, capture_output=True,
            text=True, timeout=10).stdout.strip() or None
        status = subprocess.run(
            ["git", "status", "--porcelain"], cwd=root, capture_output=True,
            text=True, timeout=10)
        dirty = bool(status.stdout.strip()) if status.returncode == 0 \
            else None
    except (OSError, subprocess.SubprocessError):
        pass
    jax_version, device = None, None
    try:
        import jax
        jax_version = jax.__version__
        dev = jax.devices()[0]
        device = f"{dev.platform}:{getattr(dev, 'device_kind', '?')}"
    except Exception:
        pass
    return {
        "git_sha": sha,
        "git_dirty": dirty,
        "jax": jax_version,
        "device": device,
        "python": sys.version.split()[0],
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
    }
