"""Shared divisibility-guarded sharding policy.

Extracted from ``launch/sharding.py`` so that BOTH sharding worlds apply
the same rules instead of duplicating them:

  * the launch-layer model sharder (``repro.launch.sharding``) — parameter
    / optimizer / batch / serve-cache rules for the LM model families;
  * the compiled chain engine (``repro.exec.shardplan``) — per-chain
    ``ShardPlan`` derivation for GCONV programs.

The policy is three primitives:

  * :func:`guard` — drop any spec axis that does not divide the
    corresponding array dim (an axis that does not divide falls back to
    replication for that dim; e.g. hymba's vocab=32001 on a 16-way axis).
  * :func:`takeover` — the first of several candidate dims the axis DOES
    divide takes the sharding (e.g. yi's 8 KV heads vs model=16: the
    head_dim axis takes the "model" sharding instead of the heads axis).
  * :func:`dp_axes` — the data-parallel axis bundle of a mesh
    (``("pod", "data")`` on multi-pod meshes, ``("data",)`` in-pod; on
    meshes without a "data" axis, the leading axis).

``axis_size``/``divides`` accept anything with a ``mesh.shape`` mapping
(a real ``jax.sharding.Mesh`` or a test fake), so the policy stays
unit-testable without devices.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

from jax.sharding import PartitionSpec as P


def axis_size(mesh, axis) -> int:
    """Total device count behind ``axis`` (None -> 1; tuples multiply)."""
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def divides(mesh, axis, dim: int) -> bool:
    """True when sharding ``dim`` over ``axis`` needs no padding."""
    return dim % axis_size(mesh, axis) == 0


def guard(mesh, spec: Tuple, shape: Tuple[int, ...]) -> P:
    """Drop spec axes that don't divide the corresponding dim."""
    out = []
    for dim, axis in zip(shape, tuple(spec) + (None,) * len(shape)):
        if axis is not None and divides(mesh, axis, dim):
            out.append(axis)
        else:
            out.append(None)
    return P(*out)


def takeover(mesh, axis, shape: Sequence[int],
             candidates: Sequence[int]) -> Optional[int]:
    """First candidate dim index that ``axis`` divides, else None.

    The fallback ladder behind the launch sharder's serve-cache rules: when
    the preferred dim (KV heads) doesn't divide the tensor-parallel axis,
    the next one (head_dim) takes the sharding rather than replicating.
    """
    for i in candidates:
        if divides(mesh, axis, shape[i]):
            return i
    return None


def dp_axes(mesh) -> tuple:
    """The data-parallel axis bundle of ``mesh``.

    ``("pod", "data")`` on multi-pod meshes, ``("data",)`` when present,
    otherwise the mesh's leading axis (debug/CI meshes with custom names).
    """
    names = tuple(mesh.axis_names)
    if "pod" in names and "data" in names:
        return ("pod", "data")
    if "data" in names:
        return ("data",)
    return names[:1]


def leading_batch_spec(mesh, shape: Tuple[int, ...], dp=None) -> P:
    """Data-parallel spec for an activation/batch leaf: leading axis over
    the dp bundle when divisible, everything else replicated."""
    if not shape:
        return P()
    dp = dp_axes(mesh) if dp is None else dp
    return guard(mesh, (dp,), shape)


def parse_mesh_spec(spec: str) -> Tuple[int, int]:
    """``--mesh`` flag grammar, in ONE place: ``"8"`` -> (8, 1) data-
    parallel, ``"4x2"`` -> (4, 2) (data, model). Consumed by
    ``launch.mesh.mesh_from_spec``, ``repro.exec.shardcheck`` and the
    benchmark harness."""
    parts = spec.lower().split("x")
    if not 1 <= len(parts) <= 2:
        raise ValueError(f"--mesh must be 'D' or 'DxM', got {spec!r}")
    return int(parts[0]), (int(parts[1]) if len(parts) == 2 else 1)
