"""Fused normalization-segment kernel (the Table-2 chain as ONE kernel).

RMSNorm / (batch-free) LayerNorm decompose into the paper's reduce-GCONV +
broadcast-GCONV chain (FP1..FP4 pattern). After §4.3 operation fusion the
whole segment collapses to one pass over the row: a VPU reduction feeding an
elementwise epilogue, with gamma (and beta) as fused ``post`` operands. One
kernel = one HBM round-trip for x instead of four.

Blocking: grid (T/bt,); block (bt, C) rows resident in VMEM; the C-axis
reduction is a VPU tree-reduce; the rescale re-reads the same VMEM block.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import cdiv, use_interpret


def _kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float, mode: str):
    x = x_ref[...].astype(jnp.float32)           # (bt, C)
    if mode == "layer":
        mu = jnp.mean(x, axis=-1, keepdims=True)
        xc = x - mu
    else:
        xc = x
    ms = jnp.mean(xc * xc, axis=-1, keepdims=True)
    y = xc * jax.lax.rsqrt(ms + eps)
    y = y * g_ref[...].astype(jnp.float32)
    if b_ref is not None:
        y = y + b_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def chain_norm(x: jax.Array, gamma: jax.Array,
               beta: Optional[jax.Array] = None, *, eps: float = 1e-6,
               mode: str = "rms", block_t: int = 256,
               interpret: Optional[bool] = None) -> jax.Array:
    """x: (T, C); gamma/beta: (C,). Returns same dtype as x.

    ``interpret`` resolves outside the jit boundary so the
    ``REPRO_FORCE_INTERPRET`` override keys the jit cache."""
    if interpret is None:
        interpret = use_interpret()
    return _chain_norm(x, gamma, beta, eps=eps, mode=mode, block_t=block_t,
                       interpret=bool(interpret))


@functools.partial(
    jax.jit, static_argnames=("eps", "mode", "block_t", "interpret"))
def _chain_norm(x, gamma, beta, *, eps, mode, block_t, interpret):
    T, C = x.shape
    bt = min(block_t, T)
    grid = (cdiv(T, bt),)
    in_specs = [
        pl.BlockSpec((bt, C), lambda t: (t, 0)),
        pl.BlockSpec((C,), lambda t: (0,)),
    ]
    args = [x, gamma]
    if beta is not None:
        in_specs.append(pl.BlockSpec((C,), lambda t: (0,)))
        args.append(beta)
        kern = functools.partial(_kernel, eps=eps, mode=mode)
    else:
        def kern(x_ref, g_ref, o_ref, *, _e=eps, _m=mode):
            _kernel(x_ref, g_ref, None, o_ref, eps=_e, mode=_m)

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bt, C), lambda t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((T, C), x.dtype),
        interpret=interpret,
    )(*args)
