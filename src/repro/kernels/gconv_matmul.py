"""Grouped GCONV matmul kernel — the TPU "GCONV engine" for mul/add GCONVs.

This is the MXU-eligible half of the paper's generalized PE array
(DESIGN.md §2): any GCONV with ``main=mul, reduce=add`` whose loops the
mapper assigns to the MXU lowers to a grouped contraction

    out[g, m, n] = post( sum_k pre(x)[g, m, k] * w[g, k, n] )

with the paper's ``pre``/``post`` operators fused as the epilogue/prologue —
the §4.3 operation-fusion result executed in registers instead of ever
touching HBM. ``Ng`` maps to the grid's group axis (experts in MoE, groups in
grouped convolution, heads in attention), ``Nop/Nopc`` to the (m, n) output
tile, ``Nks`` to the contraction.

Blocking: grid (G, M/bm, N/bn, K/bk), K innermost so each (g, m, n) output
block stays resident in VMEM while the contraction streams over K
(output-stationary; kernel/input blocks are the streamed operands). f32
accumulation in the output block; the cast to the storage dtype happens on
the last K step together with the ``post`` epilogue.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import cdiv, pick_block, use_interpret

# epilogue/prologue vocabulary (a subset of core.operators.UNARY that makes
# sense in-register; extend as chains demand)
EPILOGUES = {
    "id": lambda x: x,
    "relu": lambda x: jnp.maximum(x, 0),
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "sigmoid": jax.nn.sigmoid,
    "tanh": jnp.tanh,
    "exp": jnp.exp,
    "square": lambda x: x * x,
}


def _kernel(x_ref, w_ref, o_ref, *, n_k: int, post: str, scale: float,
            out_dtype):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[0].astype(jnp.float32)         # (bm, bk)
    w = w_ref[0].astype(jnp.float32)         # (bk, bn)
    acc = jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    o_ref[...] += acc[None]

    @pl.when(k == n_k - 1)
    def _epilogue():
        y = o_ref[...]
        if scale != 1.0:
            y = y * scale
        y = EPILOGUES[post](y)
        o_ref[...] = y


@functools.partial(
    jax.jit,
    static_argnames=("post", "scale", "block_m", "block_n", "block_k",
                     "interpret"))
def gconv_matmul(x: jax.Array, w: jax.Array, *, post: str = "id",
                 scale: float = 1.0, block_m: int = 256, block_n: int = 256,
                 block_k: int = 512,
                 interpret: Optional[bool] = None) -> jax.Array:
    """out[g] = post(scale * (x[g] @ w[g])), f32 accumulation.

    x: (G, M, K); w: (G, K, N) -> (G, M, N) in f32 (callers cast).
    Shapes need not be tile-aligned; blocks are shrunk to fit.
    """
    if interpret is None:
        interpret = use_interpret()
    G, M, K = x.shape
    G2, K2, N = w.shape
    assert G == G2 and K == K2, (x.shape, w.shape)
    bm = min(block_m, pick_block(M, block_m, 8))
    bn = min(block_n, pick_block(N, block_n, 128))
    bk = min(block_k, pick_block(K, block_k, 128))
    # pad to tile multiples: boundary-block contents are implementation-
    # defined in Pallas, and a mul/add GCONV is exactly zero-pad-safe
    Mp, Kp, Np = (cdiv(M, bm) * bm, cdiv(K, bk) * bk, cdiv(N, bn) * bn)
    if (Mp, Kp) != (M, K):
        x = jnp.pad(x, ((0, 0), (0, Mp - M), (0, Kp - K)))
    if (Kp, Np) != (K, N):
        w = jnp.pad(w, ((0, 0), (0, Kp - K), (0, Np - N)))
    n_k = Kp // bk
    grid = (G, Mp // bm, Np // bn, n_k)

    out = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k, post=post, scale=scale,
                          out_dtype=jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda g, m, n, k: (g, m, k)),
            pl.BlockSpec((1, bk, bn), lambda g, m, n, k: (g, k, n)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda g, m, n, k: (g, m, n)),
        out_shape=jax.ShapeDtypeStruct((G, Mp, Np), jnp.float32),
        interpret=interpret,
    )(x, w)
    return out[:, :M, :N]
