"""Grouped GCONV matmul kernel — the TPU "GCONV engine" for mul/add GCONVs.

This is the MXU-eligible half of the paper's generalized PE array
(DESIGN.md §2): any GCONV with ``main=mul, reduce=add`` whose loops the
mapper assigns to the MXU lowers to a grouped contraction

    out[g, m, n] = post( sum_k pre(x)[g, m, k] * w[g, k, n] )

with the paper's ``pre``/``post`` operators fused as the epilogue/prologue —
the §4.3 operation-fusion result executed in registers instead of ever
touching HBM. ``Ng`` maps to the grid's group axis (experts in MoE, groups in
grouped convolution, heads in attention), ``Nop/Nopc`` to the (m, n) output
tile, ``Nks`` to the contraction.

Fused operator sequences: beyond the single-op ``post=``/``scale=`` form,
``prologue=``/``epilogue=`` accept whole §4.3 pre/post sequences as
``(name, const, operand_slot)`` triples over the ``core.operators.UNARY``
vocabulary. Tensor operands (bias, scale, fused norm statistics, …) ride in
``operands[slot]`` shaped ``(G|1, M, 1)`` / ``(G|1, 1, K)`` for the prologue
and ``(G|1, M, 1)`` / ``(G|1, 1, N)`` for the epilogue; each is blocked with
the matching (m/k/n) grid axis so the op applies in-register per tile.

Blocking: grid (G, M/bm, N/bn, K/bk), K innermost so each (g, m, n) output
block stays resident in VMEM while the contraction streams over K
(output-stationary; kernel/input blocks are the streamed operands). f32
accumulation in the output block; the cast to the storage dtype happens on
the last K step together with the ``post`` epilogue. Padded-K columns are
re-masked to the additive identity after a prologue (prologue ops need not
preserve zero).
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core import operators as core_ops
from .common import cdiv, pick_block, use_interpret

# legacy single-op epilogue vocabulary (post=/scale= form), defined in
# terms of core.operators.UNARY so the two epilogue paths share one source
EPILOGUES = {
    name: (lambda f: lambda x: f(x, None, None))(core_ops.UNARY[name])
    for name in ("id", "relu", "silu", "gelu", "sigmoid", "tanh", "exp",
                 "square")
}

# ops legal in a fused prologue/epilogue sequence: every UNARY entry that is
# elementwise in its input and (optionally) one broadcast operand.
FUSABLE_OPS = frozenset(core_ops.UNARY)

# (name, const, operand_slot): one fused pre/post operator application.
FusedOp = Tuple[str, Optional[float], Optional[int]]


def _apply_fused(seq: Sequence[FusedOp], y, operand_refs):
    for name, const, slot in seq:
        p = None
        if slot is not None:
            p = operand_refs[slot][...].astype(jnp.float32)
        y = core_ops.UNARY[name](y, const, p)
    return y


def _kernel(x_ref, w_ref, *rest, n_k: int, post: str, scale: float,
            prologue: Tuple[FusedOp, ...], epilogue: Tuple[FusedOp, ...],
            k_true: int, bk: int):
    o_ref = rest[-1]
    op_refs = rest[:-1]
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)       # (1, bm, bk)
    if prologue:
        x = _apply_fused(prologue, x, op_refs)
        # prologue ops need not map 0 -> 0: re-zero the padded K tail so it
        # stays the additive identity of the contraction
        k_ids = k * bk + jax.lax.broadcasted_iota(jnp.int32, x.shape, 2)
        x = jnp.where(k_ids < k_true, x, 0.0)
    w = w_ref[0].astype(jnp.float32)         # (bk, bn)
    acc = jax.lax.dot_general(
        x[0], w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    o_ref[...] += acc[None]

    @pl.when(k == n_k - 1)
    def _epilogue():
        y = o_ref[...]
        if scale != 1.0:
            y = y * scale
        y = EPILOGUES[post](y)
        if epilogue:
            y = _apply_fused(epilogue, y, op_refs)
        o_ref[...] = y


def _operand_spec(shape, slot, G, M, L, bm, blk, stage):
    """BlockSpec for a fused-op operand. Legal shapes: (G|1, M|1, 1) or
    (G|1, 1, L|1) with L = K (prologue) / N (epilogue); every axis must be
    the full extent or a broadcast 1 — anything else is rejected (a
    mismatched group axis must not silently read group 0)."""
    g, a, b = shape
    if g not in (1, G):
        raise ValueError(f"operand {slot}: group axis {g} != 1 or {G}")
    if (a, b) not in {(1, 1), (M, 1), (1, L)}:
        raise ValueError(
            f"operand {slot}: shape {shape} not broadcastable over "
            f"(G={G}, M={M}, {'K' if stage == 'pro' else 'N'}={L})")
    gi = (lambda g_, m, n, k: g_) if g == G and G > 1 else (lambda *_: 0)
    if (a, b) == (1, 1):                      # per-group scalar
        return pl.BlockSpec((1, 1, 1),
                            lambda g_, m, n, k, _gi=gi: (_gi(g_, m, n, k), 0, 0))
    if b == 1:                                # (G|1, M, 1): follows the m axis
        return pl.BlockSpec((1, bm, 1),
                            lambda g_, m, n, k, _gi=gi: (_gi(g_, m, n, k), m, 0))
    if stage == "pro":                        # (G|1, 1, K): follows the k axis
        return pl.BlockSpec((1, 1, blk),
                            lambda g_, m, n, k, _gi=gi: (_gi(g_, m, n, k), 0, k))
    return pl.BlockSpec((1, 1, blk),          # (G|1, 1, N): follows the n axis
                        lambda g_, m, n, k, _gi=gi: (_gi(g_, m, n, k), 0, n))


def _pad_operand(arr, G, Mp, Lp):
    """Zero-pad an operand's non-unit M and K/N axes to block multiples."""
    g, a, b = arr.shape
    pad_a = (Mp - a) if a != 1 else 0
    pad_b = (Lp - b) if b != 1 else 0
    if pad_a or pad_b:
        arr = jnp.pad(arr, ((0, 0), (0, pad_a), (0, pad_b)))
    return arr


# default tile targets + alignments (MXU wants 128-multiples on the
# contraction/output dims, VPU sublanes 8-multiples on M). Single source
# for the kernel signature below AND the repro.lint block-contract audit.
BLOCK_M, BLOCK_N, BLOCK_K = 256, 256, 512
M_ALIGN, N_ALIGN, K_ALIGN = 8, 128, 128


def gconv_matmul(x: jax.Array, w: jax.Array, *, post: str = "id",
                 scale: float = 1.0,
                 prologue: Tuple[FusedOp, ...] = (),
                 epilogue: Tuple[FusedOp, ...] = (),
                 operands: Tuple[jax.Array, ...] = (),
                 block_m: int = BLOCK_M, block_n: int = BLOCK_N,
                 block_k: int = BLOCK_K,
                 interpret: Optional[bool] = None) -> jax.Array:
    """out[g] = epilogue(scale * (prologue(x)[g] @ w[g])), f32 accumulation.

    x: (G, M, K); w: (G, K, N) -> (G, M, N) in f32 (callers cast).
    ``prologue``/``epilogue`` are ``(name, const, operand_slot)`` sequences
    over ``core.operators.UNARY``; slot ``i`` reads ``operands[i]``, shaped
    ``(G|1, M, 1)``, ``(G|1, 1, K)`` (prologue) or ``(G|1, 1, N)``
    (epilogue). Shapes need not be tile-aligned; blocks are shrunk to fit
    and the remainders zero-padded (see ``kernels.common.pick_block``).

    ``interpret`` is resolved here, OUTSIDE the jit boundary, so the
    ``REPRO_FORCE_INTERPRET`` override keys the jit cache — both modes can
    run (and stay cached separately) within one process.
    """
    if interpret is None:
        interpret = use_interpret()
    return _gconv_matmul(x, w, post=post, scale=scale,
                         prologue=tuple(prologue), epilogue=tuple(epilogue),
                         operands=tuple(operands), block_m=block_m,
                         block_n=block_n, block_k=block_k,
                         interpret=bool(interpret))


@functools.partial(
    jax.jit,
    static_argnames=("post", "scale", "prologue", "epilogue", "block_m",
                     "block_n", "block_k", "interpret"))
def _gconv_matmul(x, w, *, post, scale, prologue, epilogue, operands,
                  block_m, block_n, block_k, interpret):
    for name, _c, _s in tuple(prologue) + tuple(epilogue):
        if name not in FUSABLE_OPS:
            raise ValueError(f"unfusable operator {name!r}")
    G, M, K = x.shape
    G2, K2, N = w.shape
    assert G == G2 and K == K2, (x.shape, w.shape)
    bm = min(block_m, pick_block(M, block_m, M_ALIGN))
    bn = min(block_n, pick_block(N, block_n, N_ALIGN))
    bk = min(block_k, pick_block(K, block_k, K_ALIGN))
    # pick_block contract: a block may undershoot the axis; pad to tile
    # multiples (making the padded extents divisible by construction) —
    # boundary-block contents are implementation-defined in Pallas, and a
    # mul/add GCONV is exactly zero-pad-safe (prologues re-mask below)
    Mp, Kp, Np = (cdiv(M, bm) * bm, cdiv(K, bk) * bk, cdiv(N, bn) * bn)
    if (Mp, Kp) != (M, K):
        x = jnp.pad(x, ((0, 0), (0, Mp - M), (0, Kp - K)))
    if (Kp, Np) != (K, N):
        w = jnp.pad(w, ((0, 0), (0, Kp - K), (0, Np - N)))
    n_k = Kp // bk
    grid = (G, Mp // bm, Np // bn, n_k)

    in_specs = [
        pl.BlockSpec((1, bm, bk), lambda g, m, n, k: (g, m, k)),
        pl.BlockSpec((1, bk, bn), lambda g, m, n, k: (g, k, n)),
    ]
    args = [x, w]

    def _bind(seq, stage, full_l, blk, pad_l):
        """Append each op's operand array and rewrite its slot to the
        kernel-local operand position (x/w excluded)."""
        out_seq = []
        for nm, c, s in seq:
            if s is None:
                out_seq.append((nm, c, None))
                continue
            arr = operands[s]
            if arr.ndim != 3:
                raise ValueError(f"operand {s}: rank {arr.ndim} != 3")
            in_specs.append(
                _operand_spec(arr.shape, s, G, M, full_l, bm, blk, stage))
            args.append(_pad_operand(arr, G, Mp, pad_l))
            out_seq.append((nm, c, len(args) - 3))
        return tuple(out_seq)

    pro_seq = _bind(prologue, "pro", K, bk, Kp)
    epi_seq = _bind(epilogue, "epi", N, bn, Np)

    out = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k, post=post, scale=scale,
                          prologue=pro_seq, epilogue=epi_seq,
                          k_true=K, bk=bk),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bm, bn), lambda g, m, n, k: (g, m, n)),
        out_shape=jax.ShapeDtypeStruct((G, Mp, Np), jnp.float32),
        interpret=interpret,
    )(*args)
    return out[:, :M, :N]
