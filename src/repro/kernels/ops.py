"""Public jit'd entry points for the kernel layer.

Models call these; each dispatches to the Pallas kernel (TPU Mosaic on
hardware, interpret mode on CPU) with shape-aware block choices. A
``REPRO_FORCE_REF=1`` env escape hatch routes to the jnp oracles — useful for
bisecting kernel-vs-model bugs and for the CPU dry-run path (the distributed
dry-run lowers the pure-JAX path; see DESIGN.md §7).
"""
from __future__ import annotations

import os
from typing import Optional


from . import ref
from .chain_norm import chain_norm
from .flash_attention import flash_attention
from .gconv_matmul import gconv_matmul
from .gconv_spatial import gconv_spatial


def _force_ref() -> bool:
    return os.environ.get("REPRO_FORCE_REF", "0") == "1"


def grouped_matmul(x, w, *, post: str = "id", scale: float = 1.0,
                   out_dtype=None, **block_kw):
    """(G,M,K) x (G,K,N) -> (G,M,N); the MoE-expert / grouped-GCONV engine."""
    if _force_ref():
        y = ref.gconv_matmul_ref(x, w, post=post, scale=scale)
    else:
        y = gconv_matmul(x, w, post=post, scale=scale, **block_kw)
    return y.astype(out_dtype or x.dtype)


def conv2d_nhwc(x, w, *, stride: int = 1, pad: int = 0, out_dtype=None,
                **block_kw):
    if _force_ref():
        y = ref.gconv_spatial_ref(x, w, stride=stride, pad=pad)
    else:
        y = gconv_spatial(x, w, stride=stride, pad=pad, **block_kw)
    return y.astype(out_dtype or x.dtype)


def fused_norm(x, gamma, beta=None, *, eps: float = 1e-6, mode: str = "rms",
               **block_kw):
    if _force_ref():
        return ref.chain_norm_ref(x, gamma, beta, eps=eps, mode=mode)
    return chain_norm(x, gamma, beta, eps=eps, mode=mode, **block_kw)


def attention(q, k, v, *, causal: bool = True, scale: Optional[float] = None,
              q_offset: int = 0, **block_kw):
    if _force_ref():
        return ref.flash_attention_ref(q, k, v, causal=causal, scale=scale,
                                       q_offset=q_offset)
    return flash_attention(q, k, v, causal=causal, scale=scale,
                           q_offset=q_offset, **block_kw)
