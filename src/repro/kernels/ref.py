"""Pure-jnp oracles for every kernel (the allclose references)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .gconv_matmul import EPILOGUES


def gconv_matmul_ref(x, w, *, post: str = "id", scale: float = 1.0):
    y = jnp.einsum("gmk,gkn->gmn", x.astype(jnp.float32),
                   w.astype(jnp.float32))
    return EPILOGUES[post](y * scale)


def gconv_spatial_ref(x, w, *, stride: int = 1, pad: int = 0):
    # NHWC x (KH,KW,C,O) via lax.conv_general_dilated
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w.astype(jnp.float32),
        (stride, stride), [(pad, pad), (pad, pad)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def chain_norm_ref(x, gamma, beta=None, *, eps: float = 1e-6,
                   mode: str = "rms"):
    xf = x.astype(jnp.float32)
    if mode == "layer":
        xf = xf - xf.mean(-1, keepdims=True)
    y = xf * jax.lax.rsqrt((xf * xf).mean(-1, keepdims=True) + eps)
    y = y * gamma.astype(jnp.float32)
    if beta is not None:
        y = y + beta.astype(jnp.float32)
    return y.astype(x.dtype)


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        scale: Optional[float] = None, q_offset: int = 0):
    H, Tq, D = q.shape
    Tk = k.shape[1]
    s = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (scale or D ** -0.5)
    if causal:
        q_ids = q_offset + jnp.arange(Tq)[:, None]
        k_ids = jnp.arange(Tk)[None, :]
        s = jnp.where(q_ids >= k_ids, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
