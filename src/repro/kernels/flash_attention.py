"""Flash attention — the fused 5-GCONV attention chain segment.

The attention block is, in chain terms, scores-GCONV -> softmax chain
(max/sub-exp/sum/div GCONVs) -> values-GCONV (core.layers.attention_*). The
paper's fusion rule says reduce-free links fold into neighbors; the *online
softmax* trick extends that across the two reduce-GCONVs as well, so the
whole segment becomes one kernel whose intermediates (the Tq x Tk score
matrix!) never exist in HBM. This is the strongest instance of the paper's
thesis on TPU: chain-level fusion beats any per-GCONV mapping.

Blocking: grid (H, Tq/bq, Tk/bk) with the key axis innermost-sequential.
Each step holds the (bq, D) query block plus ONE (bk, D) key and value block
in VMEM; running (acc, m, l) statistics live in VMEM scratch across the key
sweep (output-stationary). Causal steps that are fully masked skip their
MXU work via pl.when.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import cdiv, use_interpret

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, bq: int, bk: int, t_k: int,
            q_offset: int, n_kb: int):
    qi = pl.program_id(1)
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causal: key block fully after the query block -> nothing to do
    first_masked = (q_offset + qi * bq + bq - 1) // bk + 1
    live = jnp.logical_or(jnp.logical_not(causal), kb < first_masked)

    @pl.when(live)
    def _step():
        q = q_ref[0].astype(jnp.float32) * scale       # (bq, D)
        k = k_ref[0].astype(jnp.float32)               # (bk, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        k_ids = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = k_ids < t_k                             # zero-padded tail keys
        if causal:
            q_ids = (q_offset + qi * bq
                     + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0))
            mask = jnp.logical_and(mask, q_ids >= k_ids)
        s = jnp.where(mask, s, NEG_INF)
        m_prev, l_prev, acc = m_ref[...], l_ref[...], acc_ref[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_cur)
        alpha = jnp.exp(m_prev - m_cur)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_cur

    @pl.when(kb == n_kb - 1)
    def _epilogue():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, scale: Optional[float] = None,
                    block_q: int = 256, block_k: int = 256,
                    q_offset: int = 0,
                    interpret: Optional[bool] = None) -> jax.Array:
    """q: (H, Tq, D); k, v: (H, Tk, D) -> (H, Tq, D), q.dtype.

    ``q_offset`` positions the query block on the key timeline for
    decode/chunked-prefill causal masking (query i attends keys
    <= q_offset + i).

    ``interpret`` resolves outside the jit boundary so the
    ``REPRO_FORCE_INTERPRET`` override keys the jit cache.
    """
    if interpret is None:
        interpret = use_interpret()
    return _flash_attention(q, k, v, causal=causal, scale=scale,
                            block_q=block_q, block_k=block_k,
                            q_offset=q_offset, interpret=bool(interpret))


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_k", "q_offset",
                     "interpret"))
def _flash_attention(q, k, v, *, causal, scale, block_q, block_k, q_offset,
                     interpret):
    H, Tq, D = q.shape
    H2, Tk, D2 = k.shape
    assert (H, D) == (H2, D2), (q.shape, k.shape)
    bq = min(block_q, Tq)
    bk = min(block_k, Tk)
    n_kb = cdiv(Tk, bk)
    if Tk % bk:
        pad = n_kb * bk - Tk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
    grid = (H, cdiv(Tq, bq), n_kb)

    return pl.pallas_call(
        functools.partial(_kernel, scale=scale or D ** -0.5, causal=causal,
                          bq=bq, bk=bk, t_k=Tk, q_offset=q_offset,
                          n_kb=n_kb),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, D), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((1, bk, D), lambda h, i, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((H, Tq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
