"""Shared kernel utilities: interpret-mode dispatch and tile helpers."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.lru_cache(maxsize=1)
def use_interpret() -> bool:
    """Pallas kernels target TPU Mosaic; anywhere else (this CPU container)
    they run in interpret mode, which executes the kernel body with the
    same blocking semantics for correctness validation."""
    return jax.default_backend() != "tpu"


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


def pick_block(n: int, target: int, align: int = 128) -> int:
    """Largest hardware-aligned block <= target that does not overshoot n
    too badly. MXU wants multiples of 128 in contraction/output dims; VPU
    lanes want multiples of 8 in sublanes."""
    if n <= align:
        return max(1, n)
    b = min(target, round_up(n, align))
    b = (b // align) * align
    return max(align, b)
