"""Shared kernel utilities: interpret-mode dispatch and tile helpers."""
from __future__ import annotations

import functools
import os

import jax


@functools.lru_cache(maxsize=1)
def _backend_wants_interpret() -> bool:
    return jax.default_backend() != "tpu"


def use_interpret() -> bool:
    """Pallas kernels target TPU Mosaic; anywhere else (this CPU container)
    they run in interpret mode, which executes the kernel body with the
    same blocking semantics for correctness validation.

    ``REPRO_FORCE_INTERPRET=1`` (or ``0``) overrides the backend-derived
    default; the env var is re-read on every call so a single TPU CI
    process can exercise both modes (the backend probe itself stays
    cached — it cannot change within a process).
    """
    forced = os.environ.get("REPRO_FORCE_INTERPRET")
    if forced is not None and forced != "":
        return forced.lower() not in ("0", "false", "no")
    return _backend_wants_interpret()


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


def pick_block(n: int, target: int, align: int = 128) -> int:
    """Hardware-aligned block size for an axis of length ``n``.

    Contract: the result ``b`` satisfies ``1 <= b <= round_up(n, align)``
    and, for ``n > align``, ``b % align == 0``. A block may be *smaller*
    than ``n`` (it never silently covers the remainder): callers MUST pad
    the axis to ``cdiv(n, b) * b`` (or mask the tail in-kernel) before
    launching a grid of ``cdiv(n, b)`` steps (enforced by
    ``tests/test_exec.py::test_pick_block_invariants``).

    MXU wants multiples of 128 in contraction/output dims; VPU lanes want
    multiples of 8 in sublanes.
    """
    if n <= align:
        return max(1, min(n, target))
    b = min(target, round_up(n, align))
    b = (b // align) * align
    return max(align, b)


def block_contract_ok(n: int, b: int, align: int) -> bool:
    """Audit form of the :func:`pick_block` contract above — ``True`` iff
    ``1 <= b <= round_up(n, align)`` and, for ``n > align``,
    ``b % align == 0``. Used by the ``plan.pallas-block-contract`` rule
    in `repro.lint` so a future block-picking change that overshoots an
    axis or breaks tile alignment fails at compile time, not in Mosaic."""
    if not 1 <= b <= round_up(n, align):
        return False
    if n > align and b % align != 0:
        return False
    return True
