"""Spatial GCONV kernel — sliding-window convolution with VMEM overlap-reuse.

The paper's core efficiency argument against im2col (TIP) is that overlap
windows should be *reused*, not replicated. On TPU that means: land the input
tile in VMEM ONCE and let every (kh, kw) tap read shifted views of the same
resident block, feeding the MXU with (spatial-positions x C) @ (C x O)
contractions. HBM traffic is exactly the unique input footprint — the
Table-3 input-movement formula, not the im2col-replicated one.

Blocking: grid (B, O-tiles). Each step holds one padded input image
(H+2p, W+2p, C) and one kernel slice (KH, KW, C, bo) in VMEM and produces the
(OH, OW, bo) output block. The static KH x KW Python loop unrolls into
MXU dots over the same VMEM block — this is the Eyeriss overlap-reuse
primitive (paper Fig. 8) re-derived for a vector/matrix memory hierarchy.
For feature maps too large for VMEM the chain mapper splits H into
halo-overlapped tiles before lowering (see core.mapping); benchmark-scale
CNNs fit comfortably (<= 16 MB).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import cdiv, use_interpret


def _kernel(x_ref, w_ref, o_ref, *, kh: int, kw: int, stride: int,
            oh: int, ow: int):
    x = x_ref[0].astype(jnp.float32)            # (H+2p, W+2p, C)
    C = x.shape[-1]
    acc = jnp.zeros((oh * ow, o_ref.shape[-1]), jnp.float32)
    for i in range(kh):                          # unrolled taps: overlap-reuse
        for j in range(kw):
            win = jax.lax.slice(
                x, (i, j, 0),
                (i + (oh - 1) * stride + 1, j + (ow - 1) * stride + 1, C),
                (stride, stride, 1))             # (oh, ow, C) shifted view
            wij = w_ref[i, j].astype(jnp.float32)     # (C, bo)
            acc += jax.lax.dot_general(
                win.reshape(oh * ow, C), wij,
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
    o_ref[0] = acc.reshape(oh, ow, -1)


def gconv_spatial(x: jax.Array, w: jax.Array, *, stride: int = 1,
                  pad: int = 0, block_o: int = 128,
                  interpret: Optional[bool] = None) -> jax.Array:
    """NHWC conv: x (B, H, W, C), w (KH, KW, C, O) -> (B, OH, OW, O) f32.

    ``interpret`` resolves outside the jit boundary so the
    ``REPRO_FORCE_INTERPRET`` override keys the jit cache."""
    if interpret is None:
        interpret = use_interpret()
    return _gconv_spatial(x, w, stride=stride, pad=pad, block_o=block_o,
                          interpret=bool(interpret))


@functools.partial(
    jax.jit, static_argnames=("stride", "pad", "block_o", "interpret"))
def _gconv_spatial(x, w, *, stride, pad, block_o, interpret):
    B, H, W, C = x.shape
    KH, KW, C2, O = w.shape
    assert C == C2
    oh = (H + 2 * pad - KH) // stride + 1
    ow = (W + 2 * pad - KW) // stride + 1
    if pad:
        x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    Hp, Wp = H + 2 * pad, W + 2 * pad
    bo = min(block_o, O)
    Op = cdiv(O, bo) * bo
    if Op != O:          # boundary blocks must be well-defined: zero-pad O
        w = jnp.pad(w, ((0, 0), (0, 0), (0, 0), (0, Op - O)))
    grid = (B, Op // bo)

    out = pl.pallas_call(
        functools.partial(_kernel, kh=KH, kw=KW, stride=stride, oh=oh, ow=ow),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Hp, Wp, C), lambda b, o: (b, 0, 0, 0)),
            pl.BlockSpec((KH, KW, C, bo), lambda b, o: (0, 0, 0, o)),
        ],
        out_specs=pl.BlockSpec((1, oh, ow, bo), lambda b, o: (b, 0, 0, o)),
        out_shape=jax.ShapeDtypeStruct((B, oh, ow, Op), jnp.float32),
        interpret=interpret,
    )(x, w)
    return out[..., :O]
