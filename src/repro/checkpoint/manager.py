"""Sharded, async, integrity-checked checkpointing with rotation + elastic
restore.

Layout per step:
    <dir>/step_<N>.tmp/ -> (atomic rename) -> <dir>/step_<N>/
        meta.json            step, leaf manifest, crc32 per leaf, mesh shape
        <leaf-path>.npy      one file per pytree leaf

Design notes for real clusters (single-process container runs the same
code):
  * every host writes only the shards it owns (here: the lone process owns
    all); the manifest records the logical global shape, so a RESTORE ONTO A
    DIFFERENT MESH (elastic scale-up/down) just device_puts each leaf with
    the new NamedSharding — GSPMD resharding does the rest;
  * writes happen on a background thread (training continues), fsync +
    tmp-dir + atomic rename make partial checkpoints invisible;
  * crc32 per leaf catches torn/corrupt files at restore; corrupted or
    incomplete checkpoints are skipped and the previous one is used —
    that's the node-failure recovery path (runtime/fault_tolerance.py).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3,
                 async_write: bool = True):
        self.dir = directory
        self.keep_n = keep_n
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def save(self, step: int, tree, extra: Optional[dict] = None):
        """Snapshot to host memory now; write in the background."""
        flat = _flatten(tree)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        self.wait()
        if self.async_write:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, extra or {}),
                daemon=True)
            self._thread.start()
        else:
            self._write(step, host, extra or {})

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host: Dict[str, np.ndarray], extra: dict):
        tmp = os.path.join(self.dir, f"step_{step}.tmp")
        final = os.path.join(self.dir, f"step_{step}")
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        manifest = {}
        for key, arr in host.items():
            fn = key.replace("/", "__") + ".npy"
            np.save(os.path.join(tmp, fn), arr)
            manifest[key] = {
                "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype),
                "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
            }
        meta = {"step": step, "manifest": manifest, **extra}
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        self._rotate()

    def _rotate(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep_n]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _verify(self, path: str) -> Optional[dict]:
        meta_path = os.path.join(path, "meta.json")
        if not os.path.exists(meta_path):
            return None
        try:
            with open(meta_path) as f:
                meta = json.load(f)
            for key, info in meta["manifest"].items():
                arr = np.load(os.path.join(path, info["file"]), mmap_mode="r")
                if list(arr.shape) != info["shape"]:
                    return None
                crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
                if crc != info["crc32"]:
                    return None
            return meta
        except Exception:
            return None

    def verified_meta(self, step: Optional[int] = None
                      ) -> Tuple[Optional[int], Optional[dict]]:
        """``(step, meta)`` of the newest integrity-clean checkpoint (or the
        given ``step``), walking back over corrupt/partial ones exactly like
        :meth:`restore` — without loading the arrays. ``(None, None)`` when
        nothing verifies. This is how the serving driver reads back the
        ``extra`` payload it saved next to its state snapshot."""
        candidates = ([step] if step is not None
                      else list(reversed(self.all_steps())))
        for s in candidates:
            meta = self._verify(os.path.join(self.dir, f"step_{s}"))
            if meta is not None:
                return s, meta
        return None, None

    def restore(self, tree_like, step: Optional[int] = None,
                shardings=None) -> Tuple[Optional[int], Any]:
        """Restore into the structure of ``tree_like``. Walks back through
        checkpoints until an integrity-clean one is found. ``shardings``
        (same pytree structure or a callable leaf->sharding) enables elastic
        restore onto a different mesh."""
        candidates = ([step] if step is not None
                      else list(reversed(self.all_steps())))
        for s in candidates:
            path = os.path.join(self.dir, f"step_{s}")
            meta = self._verify(path)
            if meta is None:
                continue
            flat_like = _flatten(tree_like)
            out = {}
            ok = True
            for key, leaf in flat_like.items():
                info = meta["manifest"].get(key)
                if info is None:
                    ok = False
                    break
                arr = np.load(os.path.join(path, info["file"]))
                out[key] = arr
            if not ok:
                continue
            leaves, treedef = jax.tree_util.tree_flatten(tree_like)
            keys = list(_flatten(tree_like).keys())
            new_leaves = []
            for key, leaf in zip(keys, leaves):
                arr = out[key].astype(leaf.dtype)
                if shardings is not None:
                    sh = (shardings(key) if callable(shardings)
                          else _flatten(shardings)[key])
                    arr = jax.device_put(arr, sh)
                new_leaves.append(arr)
            return s, jax.tree_util.tree_unflatten(treedef, new_leaves)
        return None, tree_like
