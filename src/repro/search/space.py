"""Generic point-space protocol consumed by the strategy engines.

A *point* is any hashable, totally-orderable value (index tuples in
practice — both ``repro.dse.space.SpecSpace`` and
``repro.exec.tune.KernelSpace`` encode candidates as ``Tuple[int, ...]``).
Hashability feeds the scorer's memo table; orderability makes tie-breaks
(``min(..., key=lambda ps: (score, point))``) deterministic under a fixed
seed.
"""
from __future__ import annotations

import random
from typing import Protocol, Tuple, runtime_checkable

# Index-tuple encoding shared by every concrete space in the repo. Kept as
# an alias (not an ABC) so spaces stay plain dataclasses.
Point = Tuple[int, ...]


@runtime_checkable
class PointSpace(Protocol):
    """What a strategy needs from a search space — nothing more.

    Implementations may expose richer API (``decode``, ``is_valid``,
    ``to_spec``...) for their own consumers; the engines only ever call
    these three, always passing the run's seeded ``random.Random``.
    """

    def sample(self, rng: random.Random) -> Point:
        """A uniformly drawn valid point."""
        ...

    def mutate(self, point: Point, rng: random.Random,
               n_fields: int = 1) -> Point:
        """A valid neighbor of ``point`` differing in ``n_fields`` fields."""
        ...

    def crossover(self, a: Point, b: Point, rng: random.Random) -> Point:
        """A valid recombination of two parents."""
        ...
