"""Seeded search strategies over a generic :class:`PointSpace`.

Three strategies behind one :class:`Strategy` protocol — seeded random
sampling, simulated annealing, and a small elitist genetic search. All draw
exclusively from a ``random.Random(seed)`` stream and iterate deterministic
data structures, so a fixed seed reproduces the exact evaluation history
and best point. The engines know nothing about what a point *means*: the
DSE layer feeds accelerator-spec index tuples scored by the analytic cost
model, the kernel tuner feeds (backend, block) index tuples scored by
measured on-device latency.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Protocol, Sequence, Tuple

from .space import Point, PointSpace


class BudgetExhausted(Exception):
    """Raised by the scorer when the evaluation budget is spent."""


class Scorer:
    """Budget-counting, memoizing objective wrapper handed to strategies.
    Repeat evaluations of a point are free (cache hit); only unique points
    consume budget."""

    def __init__(self, objective: Callable[[Point], float], budget: int):
        self._objective = objective
        self.left = budget
        self.memo: Dict[Point, float] = {}
        self.history: List[Tuple[Point, float]] = []
        # consecutive cache hits: when a (small or tightly-budgeted) space
        # runs out of unseen valid points, proposals stop consuming budget —
        # declare exhaustion rather than letting a strategy loop forever
        self._stale = 0

    def __call__(self, point: Point) -> float:
        if point in self.memo:
            self._stale += 1
            if self._stale > 100 * max(1, self.left):
                raise BudgetExhausted
            return self.memo[point]
        if self.left <= 0:
            raise BudgetExhausted
        self._stale = 0
        self.left -= 1
        s = self._objective(point)
        self.memo[point] = s
        self.history.append((point, s))
        return s

    def best(self) -> Tuple[Point, float]:
        return min(self.history, key=lambda ps: (ps[1], ps[0]))


@dataclass
class SearchResult:
    strategy: str
    best: Point
    best_score: float
    n_evals: int
    history: List[Tuple[Point, float]] = field(default_factory=list)


class Strategy(Protocol):
    name: str

    def run(self, space: PointSpace, objective: Callable[[Point], float],
            budget: int, seed: int = 0,
            seeds: Sequence[Point] = ()) -> SearchResult:
        """Spend up to ``budget`` unique evaluations minimizing
        ``objective``; deterministic under a fixed ``seed``."""
        ...


def _finish(name: str, scorer: Scorer) -> SearchResult:
    if not scorer.history:
        raise ValueError("search budget must allow at least 1 evaluation")
    best, best_score = scorer.best()
    return SearchResult(strategy=name, best=best, best_score=best_score,
                        n_evals=len(scorer.history),
                        history=list(scorer.history))


class RandomSearch:
    """Seeded uniform sampling — the multi-fidelity baseline strategy."""

    name = "random"

    def run(self, space, objective, budget, seed=0, seeds=()):
        rng = random.Random(seed)
        scorer = Scorer(objective, budget)
        try:
            for p in seeds:
                scorer(p)
            while True:
                scorer(space.sample(rng))
        except BudgetExhausted:
            pass
        return _finish(self.name, scorer)


class SimulatedAnnealing:
    """Single-chain Metropolis walk with a geometric temperature schedule.
    Defaults are calibrated to objectives normalized near 1.0 (the WLC
    scale, where ER == 1.0); measured-latency consumers normalize or pass
    their own ``t0``/``t1``."""

    name = "anneal"

    def __init__(self, t0: float = 0.25, t1: float = 0.005):
        self.t0, self.t1 = t0, t1

    def run(self, space, objective, budget, seed=0, seeds=()):
        rng = random.Random(seed)
        scorer = Scorer(objective, budget)
        try:
            cur = min(seeds, key=scorer) if seeds else space.sample(rng)
            cur_s = scorer(cur)
            steps = max(1, budget - len(scorer.history))
            decay = (self.t1 / self.t0) ** (1.0 / steps)
            t = self.t0
            while True:
                cand = space.mutate(cur, rng,
                                    n_fields=1 if rng.random() < 0.7 else 2)
                cand_s = scorer(cand)
                d = cand_s - cur_s
                if d <= 0 or rng.random() < math.exp(-d / max(t, 1e-9)):
                    cur, cur_s = cand, cand_s
                t *= decay
        except BudgetExhausted:
            pass
        return _finish(self.name, scorer)


class GeneticSearch:
    """Small elitist GA: tournament selection, uniform crossover with
    budget-repair, per-child mutation."""

    name = "genetic"

    def __init__(self, pop_size: int = 12, n_elite: int = 2,
                 p_mutate: float = 0.35):
        self.pop_size, self.n_elite, self.p_mutate = (
            pop_size, n_elite, p_mutate)

    def run(self, space, objective, budget, seed=0, seeds=()):
        rng = random.Random(seed)
        scorer = Scorer(objective, budget)

        def tournament(pop: List[Point]) -> Point:
            a, b = rng.choice(pop), rng.choice(pop)
            return a if scorer.memo[a] <= scorer.memo[b] else b

        try:
            pop: List[Point] = []
            for p in seeds:
                scorer(p)
                pop.append(p)
            while len(pop) < self.pop_size:
                p = space.sample(rng)
                if p not in scorer.memo:
                    scorer(p)
                    pop.append(p)
            stale = 0
            while True:
                ranked = sorted(pop, key=lambda p: (scorer.memo[p], p))
                nxt = ranked[: self.n_elite]
                while len(nxt) < self.pop_size:
                    child = space.crossover(tournament(pop), tournament(pop),
                                            rng)
                    if rng.random() < self.p_mutate:
                        child = space.mutate(child, rng)
                    # converged populations breed already-scored children
                    # (free, but no progress): push them further out
                    if child in scorer.memo:
                        child = space.mutate(child, rng, n_fields=2)
                        stale += 1
                        if stale > 50 * budget:
                            raise BudgetExhausted
                    else:
                        stale = 0
                    scorer(child)
                    nxt.append(child)
                pop = nxt
        except BudgetExhausted:
            pass
        return _finish(self.name, scorer)


STRATEGIES: Dict[str, Callable[[], Strategy]] = {
    "random": RandomSearch,
    "anneal": SimulatedAnnealing,
    "genetic": GeneticSearch,
}
