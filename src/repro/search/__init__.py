"""Shared seeded search engines over generic point spaces.

Extracted from ``repro.dse.search`` so the same strategy implementations,
budget accounting and trajectory records drive *every* search in the repo:

  * ``repro.dse``       — accelerator-spec space, analytic WLC objective;
  * ``repro.exec.tune`` — per-fusion-group (backend, block) space, measured
    on-device latency objective.

A consumer supplies a :class:`PointSpace` (``sample``/``mutate``/
``crossover`` over hashable, orderable points) and an objective callable;
the engines guarantee seeded determinism — a fixed seed reproduces the
exact evaluation history, including tie-breaks.
"""
from .strategies import (  # noqa: F401
    BudgetExhausted,
    GeneticSearch,
    RandomSearch,
    Scorer,
    SearchResult,
    SimulatedAnnealing,
    Strategy,
    STRATEGIES,
)
from .space import Point, PointSpace  # noqa: F401
from .trajectory import TrajectoryRecorder  # noqa: F401

__all__ = [
    "BudgetExhausted", "GeneticSearch", "Point", "PointSpace",
    "RandomSearch", "Scorer", "SearchResult", "SimulatedAnnealing",
    "Strategy", "STRATEGIES", "TrajectoryRecorder",
]
