"""Shared best-fitness-vs-evaluations trajectory recorder.

Every search consumer (``repro.dse.run``, ``repro.exec.tune``) emits the
same convergence-curve schema so strategy benchmarks and the viz loop can
overlay runs regardless of what the fitness *is* (analytic WLC, measured
microseconds): ``{"schema": "repro.search.trajectory/v1", "metric": ...,
"trajectory": [{"n": 1, "fitness": ..., "best_fitness": ...}, ...]}``.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Dict, List, Sequence

SCHEMA = "repro.search.trajectory/v1"


@dataclass
class TrajectoryRecorder:
    """Running-minimum convergence curve over fitness values in evaluation
    order. Feed it scores as they happen (:meth:`record`) or all at once
    (:meth:`extend`); read the curve, the converged best and the
    evaluations-to-best count; serialize with :meth:`to_json`/:meth:`write`.
    """

    metric: str = "fitness"
    entries: List[Dict[str, float]] = field(default_factory=list)

    def record(self, fitness: float) -> None:
        best = min(fitness, self.best_fitness)
        self.entries.append(dict(n=len(self.entries) + 1, fitness=fitness,
                                 best_fitness=best))

    def extend(self, scores: Sequence[float]) -> None:
        for s in scores:
            self.record(s)

    @property
    def best_fitness(self) -> float:
        return (self.entries[-1]["best_fitness"] if self.entries
                else float("inf"))

    @property
    def evals_to_best(self) -> int:
        """1-based index of the evaluation that reached the final best
        (0 when empty)."""
        best = self.best_fitness
        return next((e["n"] for e in self.entries
                     if e["best_fitness"] == best), 0)

    def to_json(self, **header) -> dict:
        """The committed artifact: caller-supplied header fields (config,
        strategy, ...) ahead of the canonical curve fields."""
        return dict(schema=SCHEMA, **header, metric=self.metric,
                    evals_to_best=self.evals_to_best,
                    trajectory=list(self.entries))

    def write(self, path: str, **header) -> None:
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_json(**header), f, indent=1, default=float)
