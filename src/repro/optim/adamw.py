"""AdamW from scratch, with dtype policies and schedules.

Moments can be stored in bf16 (``moment_dtype``) for memory-bound giants
(arctic-480b's optimizer state would not fit 256 chips in f32 — see
DESIGN.md); the update math always runs in f32. Global-norm clipping and a
warmup+cosine schedule are built in. The whole state is a pytree that shards
exactly like the params (FSDP-style over data x model).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 2000
    total_steps: int = 100_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"      # "bfloat16" for memory-bound models


def schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.peak_lr * warm * (cfg.min_lr_ratio
                                 + (1 - cfg.min_lr_ratio) * cos)


def init_state(cfg: OptConfig, params) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                        for g in jax.tree.leaves(tree)))


def _decay_mask(path_leaf) -> bool:
    """No weight decay on norms/biases/scalars."""
    return path_leaf.ndim >= 2


def update(cfg: OptConfig, params, grads, state
           ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    step = state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32) * scale
        mf = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
        vf = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * gf * gf
        mhat = mf / bc1
        vhat = vf / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if _decay_mask(p):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), mf.astype(mdt), vf.astype(mdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    stats = {"lr": lr, "grad_norm": gnorm}
    return new_p, {"m": new_m, "v": new_v, "step": step}, stats
