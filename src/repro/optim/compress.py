"""Int8 error-feedback gradient compression for cross-pod all-reduce.

At 512+ chips the pod-to-pod (DCN/optical) links are the thin pipe: bf16
gradient all-reduce across pods moves 2 bytes/param/step. Per-tensor-scaled
int8 quantization with error feedback (residual carried to the next step)
cuts that 2x with no accuracy cliff (standard in large-scale data-parallel
training). Inside a pod the ICI all-reduce stays full precision.

Usage (inside train_step, under shard_map or via GSPMD collectives):
    q, scale, new_err = quantize(g + err)
    q_sum = lax.psum(q.astype(f32) * scale, 'pod')   # wire format int8
    g_hat = q_sum / n_pods
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize(g: jax.Array, err: jax.Array) -> Tuple[jax.Array, jax.Array,
                                                    jax.Array]:
    """-> (int8 q, f32 scale, new residual). g+err is quantized; the
    quantization error becomes the next step's residual (error feedback)."""
    gf = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, gf - deq


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def pod_allreduce_compressed(grads, errors, axis: str = "pod"):
    """Compressed mean-all-reduce over ``axis`` for a gradient pytree.
    Returns (averaged grads, new error pytree). Must run inside shard_map
    (or pmap) where ``axis`` is a named mapped axis."""
    n = jax.lax.psum(1, axis)

    def one(g, e):
        q, scale, new_e = quantize(g, e)
        # wire: int8 payload + one f32 scale; psum of dequantized values is
        # mathematically what the ring does after per-hop dequant/requant
        total = jax.lax.psum(dequantize(q, scale), axis)
        return (total / n).astype(g.dtype), new_e

    flat_g, td = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(errors)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(td, [o[0] for o in out]),
            jax.tree.unflatten(td, [o[1] for o in out]))


def init_errors(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
