"""Fault-tolerant training runtime: checkpoint/restart loop, straggler
detection, failure injection for tests.

At 1000+ nodes the mean time between node failures is hours; the framework
treats a failed step as normal control flow:

  1. every ``ckpt_every`` steps -> async integrity-checked checkpoint;
  2. a step raising (device loss, NaN watchdog, injected fault) triggers
     restore-from-latest + replay (the data pipeline is (seed, step)-keyed,
     so replays are bit-identical);
  3. repeated failures back off and finally re-raise (operator escalation);
  4. a straggler monitor (EMA of step wall-time) flags slow steps — the
     multi-host deployment hooks this to its collective-timeout /
     re-mesh path (elastic restore onto fewer hosts via
     CheckpointManager.restore with new shardings).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

from repro.checkpoint.manager import CheckpointManager


@dataclass
class StragglerMonitor:
    """EMA step-time watchdog; in multi-host mode the per-host heartbeats
    feed the same interface."""

    alpha: float = 0.1
    threshold: float = 3.0
    ema: Optional[float] = None
    flagged: int = 0

    def observe(self, dt: float) -> bool:
        if self.ema is None:
            self.ema = dt
            return False
        slow = dt > self.threshold * self.ema
        if slow:
            # flagged samples must NOT feed the EMA: absorbing them
            # inflates the baseline until a sustained straggler stops
            # being flagged at all (regression: tests/test_chaos.py)
            self.flagged += 1
        else:
            self.ema = (1 - self.alpha) * self.ema + self.alpha * dt
        return slow


@dataclass
class RunState:
    step: int = 0
    restarts: int = 0
    history: list = field(default_factory=list)


class FaultTolerantLoop:
    def __init__(self, manager: CheckpointManager, *, ckpt_every: int = 50,
                 max_restarts: int = 5,
                 fault_hook: Optional[Callable[[int], None]] = None,
                 log: Optional[Callable[[dict], None]] = None):
        self.manager = manager
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.fault_hook = fault_hook
        self.monitor = StragglerMonitor()
        self.log = log or (lambda rec: None)

    def run(self, state_tree, step_fn: Callable[[Any, int], Any],
            n_steps: int, start_step: int = 0,
            shardings=None) -> Dict[str, Any]:
        """step_fn(state_tree, step) -> state_tree. Returns run report."""
        run = RunState(step=start_step)
        restored, state_tree = self._maybe_restore(state_tree, shardings)
        if restored is not None:
            run.step = restored
        consecutive_failures = 0
        while run.step < n_steps:
            t0 = time.perf_counter()
            try:
                if self.fault_hook is not None:
                    self.fault_hook(run.step)     # test/chaos injection
                state_tree = step_fn(state_tree, run.step)
                dt = time.perf_counter() - t0
                slow = self.monitor.observe(dt)
                self.log({"step": run.step, "dt": dt, "straggler": slow})
                run.step += 1
                consecutive_failures = 0
                if run.step % self.ckpt_every == 0:
                    self.manager.save(run.step, state_tree)
            except Exception as e:       # noqa: BLE001 — any step failure
                run.restarts += 1
                consecutive_failures += 1
                self.log({"step": run.step, "error": repr(e),
                          "restarts": run.restarts})
                if consecutive_failures > self.max_restarts:
                    raise
                time.sleep(min(0.05 * 2 ** consecutive_failures, 2.0))
                restored, state_tree = self._maybe_restore(
                    state_tree, shardings)
                run.step = restored if restored is not None else start_step
        self.manager.save(run.step, state_tree)
        self.manager.wait()
        return {"final_step": run.step, "restarts": run.restarts,
                "stragglers": self.monitor.flagged}

    def _maybe_restore(self, state_tree, shardings):
        self.manager.wait()
        return self.manager.restore(state_tree, shardings=shardings)
