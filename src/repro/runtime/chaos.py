"""Deterministic fault injection: the chaos plane behind resilient serving.

Production failure modes (device errors, numerically-poisoned state,
latency spikes) are injected here as *data*, not as monkeypatching: a
:class:`ChaosPlan` is a set of :class:`FaultSpec` entries keyed by
``site x invocation index``, and a :class:`ChaosInjector` threads them
through the execution hooks that ``repro.exec.serving.ServeEngine`` (the
``decode``/``prefill``/``splice``/``reset`` sites), the serving driver's
tick loop (``tick``) and the training loop
(:class:`~repro.runtime.fault_tolerance.FaultTolerantLoop` via the
``step`` site) already expose. Everything is deterministic:

  * each fault fires exactly once, at a fixed (site, index) key — a
    retried program sees the next invocation index, so bounded retries
    deterministically clear a one-shot fault;
  * the *recovery* contract is byte-identity: prompts are deterministic,
    replay is bit-identical, so a workload served through an injected
    fault spec must produce exactly the outputs of the fault-free run
    (enforced by the ``chaos_micro`` CI gate and tests/test_chaos.py).

Fault kinds:

  ``raise``    raise :class:`InjectedFault` before the site's program
               runs (a lost device / failed launch);
  ``nan``      overwrite one logits row (``arg`` = slot for ``decode``,
               admission row for ``prefill``) with NaN after the program
               runs (numerically-poisoned output);
  ``corrupt``  overwrite slot ``arg``'s rows of every floating-point
               serve-state leaf with NaN after the program runs (a torn
               KV-cache row — detected one tick later by the watchdog);
  ``latency``  sleep ``arg`` seconds before the program runs (a
               straggling device / network stall).

Spec grammar (CLI flags, benchmarks, docs)::

    spec  := fault (";" fault)*
    fault := site "@" index "=" kind [":" arg]
    site  := decode | prefill | splice | reset | tick | step

e.g. ``"decode@4=raise;decode@7=nan:1;decode@9=corrupt:0"`` — raise on
the 4th decode call, NaN slot 1's logits on the 7th, NaN slot 0's cache
rows on the 9th. Indices count per-site invocations from 0, except the
``step`` site, which is keyed by the *training step number* the loop
passes explicitly (so restore-and-replay of a failed step does not
re-fire its fault).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

SITES = ("decode", "prefill", "splice", "reset", "tick", "step")
KINDS = ("raise", "nan", "corrupt", "latency")


class InjectedFault(RuntimeError):
    """The error an injected ``raise`` fault throws — a stand-in for a
    lost device / failed program launch. Deliberately a plain
    RuntimeError subclass: recovery paths must not special-case it."""


@dataclass(frozen=True)
class FaultSpec:
    """One fault: ``kind`` at the ``at``-th invocation of ``site``.

    ``arg`` is kind-specific: slot/row index for ``nan``/``corrupt``,
    seconds for ``latency``, ignored for ``raise``."""

    site: str
    at: int
    kind: str
    arg: float = 0.0

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r} "
                             f"(sites: {', '.join(SITES)})")
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(kinds: {', '.join(KINDS)})")
        if self.at < 0:
            raise ValueError(f"fault index must be >= 0, got {self.at}")

    def __str__(self):
        base = f"{self.site}@{self.at}={self.kind}"
        if self.kind == "latency":
            return f"{base}:{self.arg}"
        if self.kind in ("nan", "corrupt"):
            return f"{base}:{int(self.arg)}"
        return base


class ChaosPlan:
    """An immutable set of fault specs; parseable from the CLI grammar."""

    def __init__(self, faults: Sequence[FaultSpec] = ()):
        self.faults: Tuple[FaultSpec, ...] = tuple(faults)

    @classmethod
    def parse(cls, text: str) -> "ChaosPlan":
        faults = []
        for part in text.replace(",", ";").split(";"):
            part = part.strip()
            if not part:
                continue
            try:
                loc, rhs = part.split("=", 1)
                site, at = loc.split("@", 1)
                kind, _, arg = rhs.partition(":")
                faults.append(FaultSpec(site.strip(), int(at),
                                        kind.strip(),
                                        float(arg) if arg else 0.0))
            except ValueError as e:
                raise ValueError(
                    f"bad fault spec {part!r} (want site@index=kind[:arg], "
                    f"e.g. decode@4=raise): {e}") from None
        return cls(faults)

    @classmethod
    def for_steps(cls, steps: Sequence[int]) -> "ChaosPlan":
        """Training-CLI form: one ``raise`` per listed step number
        (``launch/train.py --inject-fault STEP[,STEP...]``)."""
        return cls([FaultSpec("step", int(s), "raise") for s in steps])

    def __len__(self):
        return len(self.faults)

    def __str__(self):
        return ";".join(str(f) for f in self.faults)


class ChaosInjector:
    """Stateful per-run injector: consumes a plan's faults exactly once.

    Execution layers call :meth:`enter` at the top of each hooked site;
    it advances that site's invocation counter, sleeps through
    ``latency`` faults, raises ``raise`` faults, and returns the data
    faults (``nan``/``corrupt``) for the caller to apply to the site's
    outputs via :meth:`apply_decode`. Every fired fault is recorded in
    ``self.fired`` and counted into the optional metrics registry
    (``chaos_injected{site,kind}``) / trace (``chaos.inject`` instants),
    so ``repro.obs.report`` can show the fault timeline.
    """

    def __init__(self, plan: ChaosPlan, *, metrics=None, tracer=None,
                 sleep=time.sleep):
        self.plan = plan
        self.metrics = metrics
        self.tracer = tracer
        self._sleep = sleep
        self._pending: Dict[Tuple[str, int], List[FaultSpec]] = {}
        for f in plan.faults:
            self._pending.setdefault((f.site, f.at), []).append(f)
        self._counts: Dict[str, int] = {}
        self.fired: List[FaultSpec] = []

    # -- bookkeeping ----------------------------------------------------
    def observe(self, metrics, tracer):
        """Late-bind the driver's metrics registry / tracer (the server
        owns both and constructs after the injector)."""
        self.metrics = metrics
        self.tracer = tracer

    def invocations(self, site: str) -> int:
        return self._counts.get(site, 0)

    def kinds_fired(self) -> set:
        return {f.kind for f in self.fired}

    @property
    def remaining(self) -> int:
        return sum(len(v) for v in self._pending.values())

    def _record(self, f: FaultSpec, index: int):
        self.fired.append(f)
        if self.metrics is not None:
            self.metrics.counter("chaos_injected", site=f.site,
                                 kind=f.kind).inc()
        tr = self.tracer
        if tr is not None and tr.enabled:
            tr.instant("chaos.inject", cat="chaos",
                       attrs={"site": f.site, "kind": f.kind,
                              "index": index, "arg": f.arg})

    # -- the hook -------------------------------------------------------
    def enter(self, site: str,
              index: Optional[int] = None) -> Tuple[FaultSpec, ...]:
        """Arm the faults keyed at this site invocation. ``index`` is
        normally the internal per-site counter (advanced here); the
        training loop passes its step number explicitly so checkpoint
        replay of a failed step does not re-fire the step's fault.

        Sleeps through ``latency`` faults, raises the first ``raise``
        fault, returns the data faults for the caller to apply."""
        if index is None:
            index = self._counts.get(site, 0)
            self._counts[site] = index + 1
        faults = self._pending.pop((site, index), None)
        if not faults:
            return ()
        post = []
        boom = None
        for f in faults:
            self._record(f, index)
            if f.kind == "latency":
                self._sleep(f.arg)
            elif f.kind == "raise":
                boom = f
            else:
                post.append(f)
        if boom is not None:
            raise InjectedFault(f"injected fault at {site}@{index}")
        return tuple(post)

    # -- data-fault application ----------------------------------------
    def apply_decode(self, faults: Sequence[FaultSpec], logits, state,
                     axes: Dict[str, int]):
        """Apply ``nan``/``corrupt`` faults to a (logits, serve-state)
        pair — decode outputs or prefill (logits, row_state). ``axes``
        is the model's ``serve_axes`` table (slot axis per leaf)."""
        import jax.numpy as jnp

        for f in faults:
            if f.kind == "nan":
                logits = logits.at[int(f.arg)].set(jnp.nan)
            elif f.kind == "corrupt":
                state = _corrupt_slot(state, axes, int(f.arg))
        return logits, state

    # -- training-side adapter -----------------------------------------
    def train_fault_hook(self):
        """``FaultTolerantLoop(fault_hook=...)`` adapter: fires the
        ``step``-site faults keyed by the loop's step number."""
        def hook(step: int):
            self.enter("step", index=step)
        return hook


def _corrupt_slot(state, axes: Dict[str, int], slot: int):
    """NaN slot ``slot``'s rows of every floating-point leaf (integer
    leaves — positions — cannot hold NaN and stay intact), mirroring the
    shape logic of ``ServeEngine._reset_impl``."""
    import jax
    import jax.numpy as jnp

    def one(leaf, axis):
        if not jnp.issubdtype(leaf.dtype, jnp.floating):
            return leaf
        shape = list(leaf.shape)
        shape[axis] = 1
        rows = jnp.full(shape, jnp.nan, leaf.dtype)
        start = [0] * leaf.ndim
        start[axis] = slot
        return jax.lax.dynamic_update_slice(leaf, rows, start)

    return {k: one(state[k], axes[k]) for k in state}
