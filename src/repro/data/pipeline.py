"""Token data pipeline: synthetic + file-backed, host-sharded, prefetched.

Production layout: each host reads only its slice of the global batch
(``host_slice``), a background thread keeps ``prefetch`` batches ready, and
the launcher device_puts with the batch NamedSharding. Determinism: batch
content is a pure function of (seed, step) so restarts resume bit-identically
without data-state checkpoints (the step counter in the checkpoint is the
data cursor).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    path: Optional[str] = None       # None => synthetic
    dtype: str = "int32"


def _synthetic_batch(cfg: DataConfig, step: int, lo: int, hi: int):
    """Deterministic (seed, step)-keyed batch rows [lo, hi) of the global
    batch — each host materializes only its rows."""
    rows = hi - lo
    rng = np.random.Generator(np.random.Philox(key=cfg.seed + step))
    # skip-ahead: draw per-row from independent streams keyed by (step, row)
    out = np.empty((rows, cfg.seq_len + 1), np.int64)
    for i, r in enumerate(range(lo, hi)):
        rr = np.random.Generator(np.random.Philox(
            key=(cfg.seed << 20) ^ (step << 8) ^ r))
        out[i] = rr.integers(0, cfg.vocab, cfg.seq_len + 1)
    return out


class TokenFileReader:
    """Flat binary token file (np.memmap) chopped into (seq_len+1) windows,
    strided by a (seed, step, row)-keyed permutation-free random offset —
    restart-deterministic without an index file."""

    def __init__(self, path: str, dtype="uint16"):
        self.tokens = np.memmap(path, dtype=dtype, mode="r")

    def window(self, cfg: DataConfig, step: int, row: int):
        span = cfg.seq_len + 1
        n_windows = max(1, len(self.tokens) - span)
        rr = np.random.Generator(np.random.Philox(
            key=(cfg.seed << 20) ^ (step << 8) ^ row))
        off = int(rr.integers(0, n_windows))
        return np.asarray(self.tokens[off:off + span], np.int64)


def host_slice(global_batch: int, process_index: int, process_count: int):
    per = global_batch // process_count
    assert per * process_count == global_batch, (
        f"global_batch {global_batch} not divisible by hosts {process_count}")
    return process_index * per, (process_index + 1) * per


def batches(cfg: DataConfig, start_step: int = 0, process_index: int = 0,
            process_count: int = 1) -> Iterator[Dict[str, np.ndarray]]:
    lo, hi = host_slice(cfg.global_batch, process_index, process_count)
    reader = TokenFileReader(cfg.path) if cfg.path else None
    step = start_step
    while True:
        if reader is None:
            chunk = _synthetic_batch(cfg, step, lo, hi)
        else:
            chunk = np.stack([reader.window(cfg, step, r)
                              for r in range(lo, hi)])
        yield {
            "tokens": chunk[:, :-1].astype(cfg.dtype),
            "labels": chunk[:, 1:].astype(cfg.dtype),
            "step": step,
        }
        step += 1


class Prefetcher:
    """Background-thread prefetch of ``depth`` batches."""

    def __init__(self, it: Iterator, depth: int = 2):
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def worker():
            for item in it:
                if self._stop.is_set():
                    return
                self.q.put(item)
            self.q.put(None)

        self.t = threading.Thread(target=worker, daemon=True)
        self.t.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
