"""Discrete-event multi-chain system simulator.

Each in-flight request is a :class:`ChainJob` — an ordered task list from
:func:`repro.syssim.route.route_chain` plus an arrival cycle. Tasks queue
FIFO at their routed unit (one task in service per unit); while a task is
in service its unit injects interconnect traffic at its average demand
rate, the shared :class:`~repro.syssim.interconnect.Interconnect`
arbitrates max-min fair shares each interval, and a task's progress
scales with its granted fraction of demand. Consequences:

  * one unit, one chain, ample capacity -> every rate is 1.0 and the
    makespan is exactly ``repro.sim.simulate_chain`` (handoff credits are
    honored when chain-adjacent tasks run back-to-back on one unit);
  * taking capacity away (or adding concurrent jobs) can only slow tasks
    down — latency is monotone under added contention — and every lost
    cycle is attributed (``queue`` vs ``interconnect`` stalls);
  * words are conserved: granted flow integrates to exactly the offered
    task traffic, never more, never less.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from .interconnect import Interconnect
from .route import RoutedChain, Task
from .stats import JobStats, SystemReport, UnitStats
from .system import SystemSpec

_EPS = 1e-9


@dataclass
class ChainJob:
    """One request: a routed chain instance entering at ``arrival``."""

    routed: RoutedChain
    arrival: float = 0.0
    tokens: float = 1.0
    name: Optional[str] = None
    rid: Optional[int] = None

    @property
    def tasks(self) -> List[Task]:
        return self.routed.tasks


@dataclass
class _Running:
    job: int
    task_idx: int
    task: Task
    remaining: float
    work0: float                       # service cycles after handoff credit
    demand: float                      # words/cycle while in service


@dataclass
class _UnitState:
    stats: UnitStats
    link_bw: float
    running: Optional[_Running] = None
    queue: List[tuple] = field(default_factory=list)  # (ready, seq, job, ti)
    last_done: Optional[tuple] = None                 # (job, task_idx)


def _demand(task: Task, link_bw: float) -> float:
    if task.work <= 0 or task.bus_words <= 0:
        return 0.0
    return min(task.bus_words / task.work, link_bw)


def simulate_system(jobs: Sequence[ChainJob],
                    system: SystemSpec) -> SystemReport:
    """Run ``jobs`` to completion on ``system``; returns the full report
    (per-unit utilization/stalls, interconnect accounting, per-job
    latency/energy, makespan)."""
    units: Dict[str, _UnitState] = {
        u.name: _UnitState(stats=UnitStats(name=u.name, kind=u.kind),
                           link_bw=u.link_bw)
        for u in system.units}
    ic = Interconnect(capacity=system.capacity)
    job_stats: List[JobStats] = [
        JobStats(name=j.name or j.routed.name, arrival=float(j.arrival),
                 finish=float(j.arrival), tokens=float(j.tokens),
                 rid=j.rid)
        for j in jobs]
    for i, j in enumerate(jobs):
        if j.arrival < 0:
            raise ValueError(f"job {i} has negative arrival {j.arrival}")
        for t in j.tasks:
            if t.unit not in units:
                raise KeyError(f"task {t.name} routed to unknown unit "
                               f"{t.unit!r}")

    arrivals = sorted(range(len(jobs)), key=lambda i: (jobs[i].arrival, i))
    next_arrival = 0
    seq = 0                       # FIFO tie-break for same-ready-time tasks
    now = 0.0
    handoff_applied = 0.0
    remaining_tasks = sum(len(j.tasks) for j in jobs)

    def enqueue(job_idx: int, task_idx: int, ready: float):
        nonlocal seq
        task = jobs[job_idx].tasks[task_idx]
        us = units[task.unit]
        us.queue.append((ready, seq, job_idx, task_idx))
        us.queue.sort()
        seq += 1

    def complete(us: _UnitState, r: _Running):
        nonlocal remaining_tasks
        st = us.stats
        st.tasks += 1
        st.compute_cycles += r.task.compute
        st.offered_words += r.task.bus_words
        st.energy += r.task.energy
        # conservation true-up: the fluid flow integrates demand over the
        # *credited* service window; the words hidden under the handoff
        # overlap (and any fp residue) still crossed the interconnect —
        # book them at retirement so injected == offered exactly
        shortfall = r.task.bus_words - r.demand * r.work0
        if shortfall > 0.0:
            st.injected_words += shortfall
            ic.injected[us.stats.name] = (
                ic.injected.get(us.stats.name, 0.0) + shortfall)
            ic.forwarded_words += shortfall
        us.last_done = (r.job, r.task_idx)
        us.running = None
        remaining_tasks -= 1
        nxt = r.task_idx + 1
        if nxt < len(jobs[r.job].tasks):
            enqueue(r.job, nxt, now)
        else:
            job_stats[r.job].finish = now
            job_stats[r.job].energy = jobs[r.job].routed.energy

    def start_ready():
        """Move queued tasks into service; zero-work tasks retire
        immediately (possibly unblocking their successor on this unit)."""
        nonlocal handoff_applied
        progressed = True
        while progressed:
            progressed = False
            for us in units.values():
                if us.running is not None or not us.queue:
                    continue
                ready, _, job_idx, task_idx = us.queue[0]
                if ready > now + _EPS:
                    continue
                us.queue.pop(0)
                task = jobs[job_idx].tasks[task_idx]
                us.stats.queue_cycles += max(0.0, now - ready)
                work = task.work
                if (task.handoff_credit > 0.0
                        and us.last_done == (job_idx, task_idx - 1)):
                    credit = min(task.handoff_credit, work)
                    work -= credit
                    handoff_applied += credit
                us.running = _Running(job=job_idx, task_idx=task_idx,
                                      task=task, remaining=work, work0=work,
                                      demand=_demand(task, us.link_bw))
                progressed = True
                if work <= _EPS:
                    complete(us, us.running)

    # admit nothing yet; the loop advances time across arrivals,
    # completions and arbitration changes
    max_steps = 1000 * max(1, remaining_tasks) + 1000
    steps = 0
    while remaining_tasks > 0:
        steps += 1
        if steps > max_steps:
            raise RuntimeError("syssim: event-loop failed to converge "
                               f"({remaining_tasks} tasks stranded)")
        while (next_arrival < len(arrivals)
               and jobs[arrivals[next_arrival]].arrival <= now + _EPS):
            enqueue(arrivals[next_arrival], 0,
                    jobs[arrivals[next_arrival]].arrival)
            next_arrival += 1
        start_ready()
        active = {n: us for n, us in units.items() if us.running is not None}
        if not active:
            if next_arrival < len(arrivals):
                now = max(now, jobs[arrivals[next_arrival]].arrival)
                continue
            # tasks queued in the future only (handoff of ready times)
            pending = [q[0] for us in units.values() for q in us.queue]
            if not pending:
                break
            now = max(now, min(pending))
            continue

        demands = {n: us.running.demand for n, us in active.items()}
        alloc = ic.allocate(demands)
        rates = {}
        for n, us in active.items():
            d = demands[n]
            rates[n] = 1.0 if d <= 0 else min(1.0, alloc[n] / d)

        dt = min(us.running.remaining / max(rates[n], 1e-30)
                 for n, us in active.items())
        if next_arrival < len(arrivals):
            dt = min(dt, jobs[arrivals[next_arrival]].arrival - now)
        dt = max(dt, 0.0)

        flows = {}
        for n, us in active.items():
            r = rates[n]
            us.running.remaining -= r * dt
            us.stats.busy_cycles += dt
            us.stats.contention_stall_cycles += (1.0 - r) * dt
            w = demands[n] * r
            if w > 0:
                flows[n] = w
                us.stats.injected_words += w * dt
        ic.advance(flows, dt, sum(demands.values()))
        now += dt

        for n, us in list(active.items()):
            if us.running is not None and us.running.remaining <= _EPS:
                complete(us, us.running)

    makespan = max([now] + [j.finish for j in job_stats]) if job_stats \
        else now
    return SystemReport(system=system.name,
                        units=[us.stats for us in units.values()],
                        jobs=job_stats, interconnect=ic,
                        makespan=makespan,
                        handoff_overlap_cycles=handoff_applied)
