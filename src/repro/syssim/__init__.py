"""System-level simulator: heterogeneous units, shared interconnect,
multi-chain concurrency, serve-trace replay.

Layers on top of the single-chain cycle-level simulator (``repro.sim``):
an :class:`~repro.syssim.system.ArrayUnit` charges the exact per-node
``repro.sim`` costs, a :class:`~repro.syssim.system.VectorUnit` services
the movement-dominated fusion groups, the router follows the execution
plan's backend metadata, and the engine arbitrates a shared interconnect
across concurrently in-flight chains. The degenerate 1-unit uncontended
configuration reproduces ``repro.sim.simulate_chain`` exactly
(:mod:`repro.syssim.validate`), and the replay frontend
(:mod:`repro.syssim.replay`) scores a candidate system against recorded
serving traffic — the fidelity ``repro.dse`` promotes Pareto points into
for the whole-life-cost-under-traffic objective.
"""
from .engine import ChainJob, simulate_system
from .interconnect import Interconnect, maxmin_fair
from .replay import ReplayResult, calibrate_tick_cycles, replay_trace
from .route import RoutedChain, Task, route_chain
from .stats import JobStats, SystemReport, UnitStats
from .system import (ArrayUnit, SystemSpec, VectorUnit, hetero,
                     single_array)
from .validate import (degenerate_pair, hetero_utilization_gain,
                       validate_degenerate)

__all__ = [
    "ArrayUnit", "ChainJob", "Interconnect", "JobStats", "ReplayResult",
    "RoutedChain", "SystemReport", "SystemSpec", "Task", "UnitStats",
    "VectorUnit", "calibrate_tick_cycles", "degenerate_pair", "hetero",
    "hetero_utilization_gain", "maxmin_fair", "replay_trace",
    "route_chain", "simulate_system", "single_array",
    "validate_degenerate",
]
