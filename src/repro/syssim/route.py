"""Fusion-group router: chain nodes -> unit-tagged task lists.

Routing is driven by the *execution plan's* backend metadata
(``repro.exec.dispatch.plan_chain``), not re-derived structure: the §4.3
fusion pass collapses streaming members into their host node, then the
plan classifies each surviving node (``matmul:*``, ``conv:*``,
``elementwise``, ``reduce``, ``segment:norm:*``, ...). Movement-dominated
tags go to a SIMD :class:`~repro.syssim.system.VectorUnit` when the
system has one; everything compute-shaped stays on the GCONV array.
Segment members tagged ``fused:<out>`` follow their segment's output so a
fused softmax/norm/attention group never straddles two units.

Task costs:
  * array tasks are the ``repro.sim`` per-node stats verbatim (shared
    ``chain_mappings`` result, same handoff-credit rule) — the degenerate
    1-unit system is *by construction* the cycle-level simulator;
  * vector tasks charge ``ceil(macs / lanes)`` compute cycles against a
    streaming ``words / bandwidth`` transfer, whichever dominates, with
    the same word counts and energy units as the analytic model.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.core.chain import Chain, Concat, Movement
from repro.core.costmodel import (E_GB, _k_elems, chain_mappings,
                                  gconv_energy, kernel_movement_scale)
from repro.core.fusion import fuse_chain
from repro.core.gconv import GConv
from repro.sim.engine import handoff_credit, simulate_chain
from repro.sim.stats import ChainSimStats

from .system import SystemSpec, Unit, VectorUnit

# Backend-tag prefixes that a vector/SIMD unit can service: the
# movement-dominated groups whose arithmetic runs at streaming rate.
VECTOR_ROUTABLE = ("elementwise", "reduce", "concat", "movement",
                   "segment:norm", "segment:softmax")


@dataclass
class Task:
    """One routed fusion group on one unit."""

    chain: str
    name: str
    unit: str
    backend: str
    work: float                  # isolated service cycles on its unit
    compute: float               # arithmetic-busy cycles (<= work)
    bus_words: float             # interconnect words (demand = words/work)
    movement: Dict[str, float]
    energy: float
    # producer-drain/consumer-fill overlap vs the chain predecessor,
    # honored only when both run back-to-back on the same unit
    handoff_credit: float = 0.0
    pred: Optional[str] = None


@dataclass
class RoutedChain:
    """A chain lowered to per-unit tasks (one job template)."""

    name: str
    tasks: List[Task]
    dispatch: Dict[str, str]
    sim: ChainSimStats           # the 1-array reference costing

    @property
    def work(self) -> float:
        return sum(t.work for t in self.tasks)

    @property
    def energy(self) -> float:
        return sum(t.energy for t in self.tasks)

    @property
    def movement_words(self) -> float:
        return sum(t.bus_words for t in self.tasks)

    def scaled(self, w: float) -> "RoutedChain":
        """Linearly scale every task (trace replay weights a request by
        its token count relative to the template chain)."""
        if w == 1.0:
            return self
        tasks = [Task(chain=t.chain, name=t.name, unit=t.unit,
                      backend=t.backend, work=t.work * w,
                      compute=t.compute * w, bus_words=t.bus_words * w,
                      movement={k: v * w for k, v in t.movement.items()},
                      energy=t.energy * w,
                      handoff_credit=t.handoff_credit * w, pred=t.pred)
                 for t in self.tasks]
        return RoutedChain(name=self.name, tasks=tasks,
                           dispatch=self.dispatch, sim=self.sim)


def _plan_tags(fused: Chain) -> Dict[str, str]:
    """Backend tag per surviving node from the execution plan; falls back
    to a structural classification when the chain carries no executable
    inputs (plan building needs shapes)."""
    try:
        from repro.exec.dispatch import plan_chain

        return dict(plan_chain(fused).dispatch)
    except Exception:                                     # noqa: BLE001
        tags: Dict[str, str] = {}
        for name, node in fused.nodes.items():
            if isinstance(node, Concat):
                tags[name] = "concat"
            elif isinstance(node, Movement):
                tags[name] = "movement"
            elif isinstance(node, GConv) and node.main == "none" \
                    and node.reduce == "none":
                tags[name] = "elementwise"
            else:
                tags[name] = "oracle"
        return tags


def _vector_routable(tag: str) -> bool:
    return tag.startswith(VECTOR_ROUTABLE)


def _vector_cost(node, chain: Chain, vu: VectorUnit):
    """(work, compute, movement, energy) of one group on the SIMD unit."""
    if isinstance(node, (Concat, Movement)):
        elems = float(node.out_elems)
        movement = {"I": elems, "O": elems}
        compute = 0.0
        energy = 2.0 * elems * E_GB * (1.0 + vu.energy_overhead)
    else:
        kwords = node.k_elems * kernel_movement_scale(
            node, _k_elems(chain, node))
        movement = {"I": float(node.in_elems), "O": float(node.out_elems)}
        if kwords > 0:
            movement["K"] = float(kwords)
        compute = float(math.ceil(node.macs / max(1, vu.lanes)))
        energy = gconv_energy(node, movement, vu.energy_overhead)
    words = sum(movement.values())
    work = max(compute, words / vu.link_bw)
    return work, compute, movement, energy


def route_chain(chain: Chain, system: SystemSpec,
                energy_overhead: float = 0.19,
                use_vector: bool = True) -> RoutedChain:
    """Fuse, cost, and route one chain onto ``system``'s units.

    ``use_vector=False`` forces every group onto the GCONV array (the
    homogeneous baseline the heterogeneous-utilization claim is measured
    against)."""
    array = system.arrays[0]
    fused, _report = fuse_chain(chain)
    pre = chain_mappings(fused, array.spec)
    sim = simulate_chain(fused, array.spec, fuse=False,
                         energy_overhead=energy_overhead, precomputed=pre)
    node_stats = {ns.name: ns for ns in sim.nodes}
    tags = _plan_tags(fused)
    # segment members follow their segment's output tag
    for name, tag in list(tags.items()):
        if tag.startswith("fused:"):
            tags[name] = tags.get(tag[len("fused:"):], tag)

    # least-loaded assignment within a unit class keeps multi-array /
    # multi-vector systems deterministic (ties break on unit order)
    load = {u.name: 0.0 for u in system.units}

    def pick(units) -> Unit:
        return min(units, key=lambda u: (load[u.name],
                                         system.units.index(u)))

    tasks: List[Task] = []
    prev_name: Optional[str] = None
    prev_unit: Optional[str] = None
    prev_stats = None
    for name, node in fused.nodes.items():
        tag = tags.get(name, "oracle")
        ns = node_stats[name]
        vectors = system.vectors if use_vector else ()
        if vectors and _vector_routable(tag):
            vu = pick(vectors)
            work, compute, movement, energy = _vector_cost(node, fused, vu)
            task = Task(chain=chain.name, name=name, unit=vu.name,
                        backend=tag, work=work, compute=compute,
                        bus_words=sum(movement.values()),
                        movement=movement, energy=energy, pred=prev_name)
        else:
            au = pick(system.arrays)
            credit = 0.0
            if prev_unit == au.name:
                credit = handoff_credit(prev_name, prev_stats, node, ns)
            task = Task(chain=chain.name, name=name, unit=au.name,
                        backend=tag, work=float(ns.total_cycles),
                        compute=float(ns.compute_cycles),
                        bus_words=float(sum(ns.movement.values())),
                        movement={k: float(v)
                                  for k, v in ns.movement.items()},
                        energy=float(ns.energy),
                        handoff_credit=credit, pred=prev_name)
        load[task.unit] += task.work
        tasks.append(task)
        prev_name, prev_unit, prev_stats = name, task.unit, ns
    return RoutedChain(name=chain.name, tasks=tasks, dispatch=tags, sim=sim)
