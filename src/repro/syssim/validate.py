"""Degenerate-case contract: 1 unit + no contention == ``repro.sim``.

The system simulator's costing authority is the cycle-level simulator —
an uncontended single-array system must reproduce
``repro.sim.simulate_chain`` *exactly* (movement and energy to
``DRIFT_TOL``, cycles bit-for-bit; the analytic model stays within
``CYCLES_RATIO_TOL`` as everywhere else). This module sweeps the zoo x
accelerator grid with the same tolerances as ``repro.sim.validate`` and
is reused by tests/test_syssim.py and the ``syssim_micro`` CI gate.

It also carries the heterogeneous-utilization check: a 2-unit
(array + SIMD) system serving concurrent requests must overlap units —
strictly higher aggregate utilization than routing every group to the
GCONV array alone.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

from repro.core import accelerators as acc
from repro.core.costmodel import gconv_chain_cost
from repro.sim.validate import (CYCLES_RATIO_TOL, DEFAULT_ACCELS, DRIFT_TOL,
                                agreement)

from .engine import ChainJob, simulate_system
from .route import route_chain
from .system import hetero, single_array


def _build(net: str, reduced: bool):
    from repro.models import cnn

    return cnn.build(net, reduced=reduced)


def degenerate_pair(chain, spec) -> dict:
    """Compare the 1-unit uncontended system against ``repro.sim`` (and
    the analytic model) on one (chain, spec) pair."""
    system = single_array(spec)
    routed = route_chain(chain, system)
    report = simulate_system([ChainJob(routed=routed)], system)
    sim = routed.sim                       # the repro.sim reference costing
    analytic = gconv_chain_cost(chain, spec)
    agree = agreement(report.makespan, analytic)
    cycles_drift = abs(report.makespan
                       / max(sim.total_cycles, 1e-12) - 1)
    movement_drift = abs(report.movement_words
                         / max(sim.movement_words, 1e-12) - 1)
    energy_drift = abs(report.energy / max(sim.energy, 1e-12) - 1)
    return dict(
        net=chain.name, accel=spec.name,
        syssim_cycles=report.makespan, sim_cycles=sim.total_cycles,
        cycles_drift=cycles_drift,
        movement_drift=movement_drift, energy_drift=energy_drift,
        contention_stall_cycles=report.contention_stall_cycles,
        word_conservation_err=report.word_conservation_err,
        cycles_ratio=agree["cycles_ratio"],
        within_tolerance=bool(agree["within_tolerance"]),
        exact=bool(cycles_drift <= DRIFT_TOL
                   and movement_drift <= DRIFT_TOL
                   and energy_drift <= DRIFT_TOL
                   and report.contention_stall_cycles == 0.0
                   and report.word_conservation_err <= 1e-6),
    )


def validate_degenerate(nets: Optional[Sequence[str]] = None,
                        accels: Sequence[str] = DEFAULT_ACCELS,
                        reduced: bool = False) -> Tuple[list, dict]:
    """Sweep the degenerate contract over ``nets x accels``."""
    from repro.models import cnn

    nets = tuple(nets) if nets is not None else tuple(cnn.ZOO)
    rows = []
    for net in nets:
        chain = _build(net, reduced)
        for name in accels:
            rows.append(degenerate_pair(chain, acc.get(name)))
    summary = dict(
        pairs=len(rows),
        all_exact=bool(all(r["exact"] for r in rows)),
        all_within_tolerance=bool(all(r["within_tolerance"]
                                      for r in rows)),
        max_cycles_drift=max(r["cycles_drift"] for r in rows),
        max_movement_drift=max(r["movement_drift"] for r in rows),
        max_energy_drift=max(r["energy_drift"] for r in rows),
        max_cycles_ratio=max(r["cycles_ratio"] for r in rows),
        cycles_ratio_tol=CYCLES_RATIO_TOL, drift_tol=DRIFT_TOL,
    )
    return rows, summary


def hetero_utilization_gain(net: str, accel: str = "ER",
                            n_jobs: int = 2, reduced: bool = False,
                            lanes: int = 64,
                            bandwidth: float = 16.0) -> dict:
    """Aggregate utilization of the 2-unit heterogeneous system vs the
    same concurrent workload with every group routed to the array."""
    chain = _build(net, reduced)
    spec = acc.get(accel)
    system = hetero(spec, lanes=lanes, bandwidth=bandwidth)

    def run(use_vector: bool):
        routed = route_chain(chain, system, use_vector=use_vector)
        jobs = [ChainJob(routed=routed, arrival=0.0, name=f"{net}#{i}")
                for i in range(n_jobs)]
        return simulate_system(jobs, system), routed

    het, routed_het = run(True)
    homo, _ = run(False)
    vector_tasks = sum(1 for t in routed_het.tasks
                       if system.unit(t.unit).kind == "vector")
    return dict(
        net=net, accel=accel, n_jobs=n_jobs,
        vector_tasks=vector_tasks,
        hetero_utilization=het.aggregate_utilization,
        array_only_utilization=homo.aggregate_utilization,
        hetero_makespan=het.makespan, array_only_makespan=homo.makespan,
        gain=het.aggregate_utilization - homo.aggregate_utilization,
        strictly_higher=bool(het.aggregate_utilization
                             > homo.aggregate_utilization),
    )
