"""System descriptions for the multi-unit simulator.

A :class:`SystemSpec` is a set of heterogeneous compute units sharing one
interconnect to the global buffer / DRAM:

  * :class:`ArrayUnit` — the paper's GCONV tile array, described by an
    :class:`repro.core.accelerators.AcceleratorSpec`.  Per-task costs are
    *delegated* to the cycle-level node simulator (``repro.sim``), so a
    single-array system with an uncontended interconnect reproduces
    ``repro.sim.simulate_chain`` exactly (the degenerate-case contract
    checked by :mod:`repro.syssim.validate`).
  * :class:`VectorUnit` — an MPNA-style SIMD lane array for the
    movement-dominated fusion groups (elementwise, reductions,
    normalization/softmax segments, concat/movement traffic) with its own
    throughput/bandwidth cost model (:mod:`repro.syssim.route`).

The interconnect capacity defaults to the sum of every unit's link
bandwidth: a unit alone can never contend against itself (its average
injection rate is bounded by its own link), and contention only appears
when several units are simultaneously active or the capacity is set
below the aggregate link width.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Tuple, Union

from repro.core import accelerators as acc
from repro.core.accelerators import AcceleratorSpec


@dataclass(frozen=True)
class ArrayUnit:
    """GCONV tile array; costs come from ``repro.sim.simulate_node``."""

    spec: AcceleratorSpec
    name: str = "array0"
    kind: str = field(default="array", init=False)

    @property
    def link_bw(self) -> float:
        """Words/cycle of the unit's interconnect link (its GB ports)."""
        return float(sum(self.spec.gb_bandwidth.values()))


@dataclass(frozen=True)
class VectorUnit:
    """SIMD vector unit: ``lanes`` MAC/ALU ops per cycle, one shared
    ``bandwidth``-words/cycle streaming port to the interconnect."""

    name: str = "vec0"
    lanes: int = 64
    bandwidth: float = 16.0
    energy_overhead: float = 0.0
    kind: str = field(default="vector", init=False)

    @property
    def link_bw(self) -> float:
        return float(self.bandwidth)


Unit = Union[ArrayUnit, VectorUnit]


@dataclass(frozen=True)
class SystemSpec:
    """Units + shared interconnect. ``interconnect_bw`` of ``None`` means
    the full aggregate link width (contention-free unless oversubscribed
    by construction)."""

    name: str
    units: Tuple[Unit, ...]
    interconnect_bw: float | None = None

    def __post_init__(self):
        if not self.units:
            raise ValueError("SystemSpec needs at least one unit")
        names = [u.name for u in self.units]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate unit names: {names}")
        if not self.arrays:
            raise ValueError("SystemSpec needs at least one ArrayUnit "
                             "(the GCONV array hosts un-routable groups)")
        if self.capacity <= 0:
            raise ValueError("interconnect capacity must be positive")

    @property
    def arrays(self) -> Tuple[ArrayUnit, ...]:
        return tuple(u for u in self.units if u.kind == "array")

    @property
    def vectors(self) -> Tuple[VectorUnit, ...]:
        return tuple(u for u in self.units if u.kind == "vector")

    @property
    def capacity(self) -> float:
        if self.interconnect_bw is not None:
            return float(self.interconnect_bw)
        return sum(u.link_bw for u in self.units)

    def unit(self, name: str) -> Unit:
        for u in self.units:
            if u.name == name:
                return u
        raise KeyError(name)


def _spec(spec_or_name: Union[str, AcceleratorSpec]) -> AcceleratorSpec:
    if isinstance(spec_or_name, str):
        return acc.get(spec_or_name)
    return spec_or_name


def single_array(spec_or_name: Union[str, AcceleratorSpec],
                 interconnect_bw: float | None = None) -> SystemSpec:
    """The degenerate 1-unit system: one GCONV array, uncontended
    interconnect — must reproduce ``repro.sim`` exactly."""
    spec = _spec(spec_or_name)
    return SystemSpec(name=f"{spec.name}-sys1",
                      units=(ArrayUnit(spec=spec),),
                      interconnect_bw=interconnect_bw)


def hetero(spec_or_name: Union[str, AcceleratorSpec],
           lanes: int = 64, bandwidth: float = 16.0,
           interconnect_bw: float | None = None) -> SystemSpec:
    """GCONV array + one SIMD vector unit (the MPNA deployment shape)."""
    spec = _spec(spec_or_name)
    return SystemSpec(
        name=f"{spec.name}-sys2",
        units=(ArrayUnit(spec=spec),
               VectorUnit(lanes=lanes, bandwidth=bandwidth)),
        interconnect_bw=interconnect_bw)
