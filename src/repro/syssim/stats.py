"""Result dataclasses for the system-level simulator.

Cycle counts are accelerator cycles and energy the same relative units as
``repro.core.costmodel`` / ``repro.sim``, so a syssim number is directly
comparable to both evaluation engines. Like ``repro.sim.stats``, the
report emits through the unified :mod:`repro.obs.metrics` registry
(``syssim_*`` families) and ``summary()`` is derived from that registry,
so the flat dicts and the versioned metrics schema cannot drift.

Stall attribution per unit splits into two causes:
  * ``queue`` — cycles a ready task waited for its unit (occupancy);
  * ``interconnect`` — cycles lost to bandwidth arbitration (the task was
    running but progressed below its isolated rate).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

from repro.obs.metrics import Metrics, percentile

from .interconnect import Interconnect


@dataclass
class UnitStats:
    name: str
    kind: str                          # "array" | "vector"
    tasks: int = 0
    busy_cycles: float = 0.0           # task-occupied cycles
    compute_cycles: float = 0.0        # arithmetic-busy cycles
    queue_cycles: float = 0.0          # ready tasks waiting for the unit
    contention_stall_cycles: float = 0.0   # arbitration-induced slip
    injected_words: float = 0.0        # fluid accounting (Interconnect)
    offered_words: float = 0.0         # exact task traffic
    energy: float = 0.0

    def utilization(self, makespan: float) -> float:
        return self.busy_cycles / makespan if makespan > 0 else 0.0


@dataclass
class JobStats:
    name: str
    arrival: float
    finish: float
    tokens: float = 1.0
    energy: float = 0.0
    rid: Optional[int] = None

    @property
    def latency(self) -> float:
        return self.finish - self.arrival


@dataclass
class SystemReport:
    system: str
    units: List[UnitStats]
    jobs: List[JobStats]
    interconnect: Interconnect
    makespan: float = 0.0
    handoff_overlap_cycles: float = 0.0

    # ------------------------------------------------------------------
    @property
    def total_cycles(self) -> float:
        return self.makespan

    @property
    def energy(self) -> float:
        return sum(u.energy for u in self.units)

    @property
    def movement_words(self) -> float:
        return sum(u.offered_words for u in self.units)

    @property
    def aggregate_utilization(self) -> float:
        """Busy unit-cycles per wall cycle — the average number of busy
        units (> 1 means the heterogeneous units genuinely overlap)."""
        if self.makespan <= 0:
            return 0.0
        return sum(u.busy_cycles for u in self.units) / self.makespan

    @property
    def contention_stall_cycles(self) -> float:
        return sum(u.contention_stall_cycles for u in self.units)

    @property
    def contention_stall_share(self) -> float:
        """Arbitration-lost cycles per busy unit-cycle."""
        busy = sum(u.busy_cycles for u in self.units)
        return self.contention_stall_cycles / busy if busy > 0 else 0.0

    @property
    def word_conservation_err(self) -> float:
        """Relative gap between fluid-injected and offered words (the
        conservation invariant; ~1e-9 float noise in practice)."""
        offered = self.movement_words
        injected = self.interconnect.forwarded_words
        return abs(injected - offered) / max(offered, 1e-12)

    @property
    def tokens(self) -> float:
        return sum(j.tokens for j in self.jobs)

    @property
    def goodput(self) -> float:
        """Tokens per kilocycle over the whole run."""
        if self.makespan <= 0:
            return 0.0
        return self.tokens / self.makespan * 1e3

    def latency_percentile(self, q: float) -> float:
        return percentile([j.latency for j in self.jobs], q)

    # ------------------------------------------------------------------
    def to_metrics(self, reg: Optional[Metrics] = None,
                   **labels) -> Metrics:
        reg = Metrics() if reg is None else reg
        lbl = dict(system=self.system, **labels)
        reg.counter("syssim_cycles", phase="makespan", **lbl).inc(
            self.makespan)
        reg.counter("syssim_cycles", phase="handoff_overlap", **lbl).inc(
            self.handoff_overlap_cycles)
        for u in self.units:
            ul = dict(unit=u.name, kind=u.kind, **lbl)
            reg.counter("syssim_tasks", **ul).inc(u.tasks)
            reg.counter("syssim_unit_cycles", phase="busy", **ul).inc(
                u.busy_cycles)
            reg.counter("syssim_unit_cycles", phase="compute", **ul).inc(
                u.compute_cycles)
            reg.counter("syssim_stall_cycles", cause="queue", **ul).inc(
                u.queue_cycles)
            reg.counter("syssim_stall_cycles", cause="interconnect",
                        **ul).inc(u.contention_stall_cycles)
            reg.counter("syssim_words", dir="injected", **ul).inc(
                u.injected_words)
            reg.counter("syssim_words", dir="offered", **ul).inc(
                u.offered_words)
            reg.counter("syssim_energy", **ul).inc(u.energy)
            reg.gauge("syssim_utilization", **ul).set(
                round(u.utilization(self.makespan), 6))
        reg.counter("syssim_forwarded_words", **lbl).inc(
            self.interconnect.forwarded_words)
        reg.counter("syssim_requests", **lbl).inc(len(self.jobs))
        reg.counter("syssim_tokens", **lbl).inc(self.tokens)
        reg.gauge("syssim_aggregate_utilization", **lbl).set(
            round(self.aggregate_utilization, 6))
        reg.gauge("syssim_contention_stall_share", **lbl).set(
            round(self.contention_stall_share, 6))
        return reg

    def summary(self) -> dict:
        reg = self.to_metrics()
        lbl = dict(system=self.system)
        units = {}
        for u in self.units:
            ul = dict(unit=u.name, kind=u.kind, **lbl)
            units[u.name] = dict(
                kind=u.kind,
                tasks=int(reg.value("syssim_tasks", **ul)),
                busy_cycles=reg.value("syssim_unit_cycles", phase="busy",
                                      **ul),
                compute_cycles=reg.value("syssim_unit_cycles",
                                         phase="compute", **ul),
                queue_stall_cycles=reg.value("syssim_stall_cycles",
                                             cause="queue", **ul),
                contention_stall_cycles=reg.value(
                    "syssim_stall_cycles", cause="interconnect", **ul),
                injected_words=reg.value("syssim_words", dir="injected",
                                         **ul),
                offered_words=reg.value("syssim_words", dir="offered",
                                        **ul),
                energy=reg.value("syssim_energy", **ul),
                utilization=reg.value("syssim_utilization", **ul))
        return dict(
            system=self.system,
            makespan_cycles=reg.value("syssim_cycles", phase="makespan",
                                      **lbl),
            handoff_overlap_cycles=reg.value(
                "syssim_cycles", phase="handoff_overlap", **lbl),
            requests=int(reg.value("syssim_requests", **lbl)),
            tokens=reg.value("syssim_tokens", **lbl),
            goodput_tokens_per_kcycle=round(self.goodput, 6),
            p50_latency_cycles=self.latency_percentile(50),
            p99_latency_cycles=self.latency_percentile(99),
            energy=self.energy,
            movement_words=self.movement_words,
            forwarded_words=reg.value("syssim_forwarded_words", **lbl),
            word_conservation_err=self.word_conservation_err,
            aggregate_utilization=reg.value(
                "syssim_aggregate_utilization", **lbl),
            contention_stall_share=reg.value(
                "syssim_contention_stall_share", **lbl),
            interconnect=self.interconnect.summary(),
            units=units)
