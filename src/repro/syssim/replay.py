"""Serve-trace replay: recorded traffic -> system simulation.

    PYTHONPATH=src python -m repro.syssim.replay TRACE [--accel ER]

Loads a ``launch/serve.py --trace`` file through
``repro.obs.trace.load_trace`` and re-simulates the recorded tick/request
schedule on a candidate system:

  * every recorded request (``Trace.serve_requests()``) becomes one
    :class:`~repro.syssim.engine.ChainJob` — the served model's block
    chain (from the trace's ``arch`` meta), linearly weighted by the
    request's recorded token count;
  * the recorded ``submit_tick`` is the arrival clock; one driver tick is
    ``tick_cycles`` accelerator cycles, calibrated (by default) so the
    template chain's isolated service time spreads over the recorded mean
    per-request service ticks — replayed traffic intensity then matches
    the recorded one. Pass an explicit ``tick_cycles`` when comparing
    candidate systems (``repro.dse`` calibrates once on the ER reference
    and holds it fixed across candidates).

The result carries goodput/latency/energy *under production traffic*
plus the full per-unit utilization and contention breakdown, and the
invariant that no recorded request is dropped (``dropped == 0``) is a CI
gate (``syssim_micro``).
"""
from __future__ import annotations

import argparse
import json
import sys
from dataclasses import dataclass
from typing import List, Optional, Sequence, Union

from repro.obs.trace import ServeRequest, Trace, load_trace

from .engine import ChainJob, simulate_system
from .route import RoutedChain, route_chain
from .stats import SystemReport
from .system import SystemSpec, hetero, single_array

DEFAULT_ARCH = "tinyllama-1.1b"


def default_chain(trace: Trace, reduced: bool = False):
    """The served model's transformer block chain (the workload each
    recorded request replays), from the trace's ``arch`` meta."""
    from repro import configs
    from repro.models.lm_chain import block_chain

    arch = trace.meta.get("arch") or DEFAULT_ARCH
    try:
        cfg = configs.get(arch)
    except (KeyError, ValueError):
        cfg = configs.get(DEFAULT_ARCH)
    seq = 16 if reduced else 128
    return block_chain(cfg, batch=1, seq=seq)


def calibrate_tick_cycles(requests: Sequence[ServeRequest],
                          routed: RoutedChain) -> float:
    """Cycles per driver tick such that the mean-weight request's
    isolated service time spans the recorded mean service ticks."""
    ticks = [r.service_ticks for r in requests
             if r.service_ticks is not None]
    mean_ticks = (sum(ticks) / len(ticks)) if ticks else 1.0
    return max(routed.work / max(mean_ticks, 1.0), 1e-9)


@dataclass
class ReplayResult:
    report: SystemReport
    requests_recorded: int
    tick_cycles: float
    trace_meta: dict

    @property
    def requests_simulated(self) -> int:
        return len(self.report.jobs)

    @property
    def dropped(self) -> int:
        return self.requests_recorded - self.requests_simulated

    def summary(self) -> dict:
        out = self.report.summary()
        out.update(requests_recorded=self.requests_recorded,
                   requests_simulated=self.requests_simulated,
                   dropped=self.dropped,
                   tick_cycles=round(self.tick_cycles, 3),
                   trace_meta=self.trace_meta)
        return out


def replay_trace(trace: Union[str, Trace], system: SystemSpec,
                 chain=None, tick_cycles: Optional[float] = None,
                 reduced: bool = False, use_vector: bool = True,
                 energy_overhead: float = 0.19) -> ReplayResult:
    """Simulate the recorded request schedule on ``system``."""
    if isinstance(trace, str):
        trace = load_trace(trace)
    requests = trace.serve_requests()
    if not requests:
        raise ValueError("trace records no finished requests "
                         "(no 'request' lifecycle spans)")
    if chain is None:
        chain = default_chain(trace, reduced=reduced)
    routed = route_chain(chain, system, energy_overhead=energy_overhead,
                         use_vector=use_vector)
    if tick_cycles is None:
        tick_cycles = calibrate_tick_cycles(requests, routed)

    tokens = [r.tokens for r in requests]
    base_tokens = max(sum(tokens) / len(tokens), 1.0)
    submit0 = min((r.submit_tick for r in requests
                   if r.submit_tick is not None), default=0)
    jobs: List[ChainJob] = []
    for r in requests:
        weight = max(r.tokens, 1.0) / base_tokens
        arrival = ((r.submit_tick - submit0) * tick_cycles
                   if r.submit_tick is not None else 0.0)
        jobs.append(ChainJob(routed=routed.scaled(weight),
                             arrival=arrival, tokens=max(r.tokens, 1.0),
                             name=f"rid{r.rid}" if r.rid is not None
                             else routed.name,
                             rid=r.rid))
    report = simulate_system(jobs, system)
    return ReplayResult(report=report, requests_recorded=len(requests),
                        tick_cycles=tick_cycles,
                        trace_meta=dict(trace.meta))


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.syssim.replay",
        description="Replay a recorded serve trace on a candidate "
                    "accelerator system.")
    ap.add_argument("trace", help="path written by launch/serve.py --trace")
    ap.add_argument("--accel", default="ER",
                    help="Table-4 accelerator spec for the GCONV array")
    ap.add_argument("--no-vector", action="store_true",
                    help="route everything to the GCONV array "
                         "(homogeneous baseline)")
    ap.add_argument("--lanes", type=int, default=64,
                    help="SIMD lanes of the vector unit")
    ap.add_argument("--bandwidth", type=float, default=16.0,
                    help="vector unit link words/cycle")
    ap.add_argument("--interconnect-bw", type=float, default=None,
                    help="shared interconnect words/cycle "
                         "(default: aggregate link width)")
    ap.add_argument("--tick-cycles", type=float, default=None,
                    help="cycles per recorded driver tick "
                         "(default: calibrated from the trace)")
    ap.add_argument("--reduced", action="store_true",
                    help="test-scale replay chain (CI smoke)")
    args = ap.parse_args(argv)
    system = (single_array(args.accel, interconnect_bw=args.interconnect_bw)
              if args.no_vector else
              hetero(args.accel, lanes=args.lanes,
                     bandwidth=args.bandwidth,
                     interconnect_bw=args.interconnect_bw))
    try:
        res = replay_trace(args.trace, system, reduced=args.reduced,
                           tick_cycles=args.tick_cycles,
                           use_vector=not args.no_vector)
    except (OSError, ValueError) as e:
        print(f"replay: {e}", file=sys.stderr)
        return 1
    print(json.dumps(res.summary(), indent=1, default=float))
    return 0 if res.dropped == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
