"""Shared interconnect: max-min fair bandwidth arbitration + accounting.

The engine models traffic as a fluid: while a task is active its unit
injects words at an average demand rate (task words / isolated service
cycles, never above the unit's link width). Each scheduling interval the
arbiter grants every active unit a max-min fair share of the interconnect
capacity (water-filling: demands below the fair share are fully granted,
the remainder is split evenly among the rest), and a task's progress
scales with its granted fraction — so an uncontended unit runs at its
isolated speed, and capacity taken away shows up as attributable
contention stall, never as lost words.

Accounting invariants (property-tested in tests/test_syssim.py):
  * allocations never exceed demands or capacity;
  * the arbiter is work-conserving: granted bandwidth equals
    ``min(capacity, total demand)``;
  * words are conserved: the sum of per-unit injected words equals the
    interconnect's forwarded words equals the offered task traffic.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Mapping

_EPS = 1e-12


def maxmin_fair(demands: Mapping[str, float],
                capacity: float) -> Dict[str, float]:
    """Max-min fair (water-filling) allocation of ``capacity`` across
    ``demands`` (words/cycle). Zero/negative demands get zero."""
    alloc = {u: 0.0 for u in demands}
    active = {u: float(d) for u, d in demands.items() if d > _EPS}
    cap = max(0.0, float(capacity))
    while active and cap > _EPS:
        share = cap / len(active)
        satisfied = [u for u, d in active.items() if d <= share + _EPS]
        if not satisfied:
            for u in active:
                alloc[u] = share
            return alloc
        for u in satisfied:
            alloc[u] = active[u]
            cap -= active[u]
            del active[u]
    return alloc


@dataclass
class Interconnect:
    """Arbitration + conservation bookkeeping for one simulation run."""

    capacity: float
    injected: Dict[str, float] = field(default_factory=dict)  # per unit
    forwarded_words: float = 0.0
    busy_cycles: float = 0.0        # any traffic in flight
    saturated_cycles: float = 0.0   # total demand above capacity

    def allocate(self, demands: Mapping[str, float]) -> Dict[str, float]:
        return maxmin_fair(demands, self.capacity)

    def advance(self, flows: Mapping[str, float], dt: float,
                total_demand: float):
        """Record ``dt`` cycles of per-unit granted word flow."""
        moved = 0.0
        for u, rate in flows.items():
            w = rate * dt
            if w <= 0.0:
                continue
            self.injected[u] = self.injected.get(u, 0.0) + w
            moved += w
        self.forwarded_words += moved
        if total_demand > _EPS:
            self.busy_cycles += dt
            if total_demand > self.capacity + _EPS:
                self.saturated_cycles += dt

    def summary(self) -> dict:
        return dict(capacity=self.capacity,
                    forwarded_words=self.forwarded_words,
                    injected={u: v for u, v in sorted(self.injected.items())},
                    busy_cycles=round(self.busy_cycles, 1),
                    saturated_cycles=round(self.saturated_cycles, 1))
