"""Accelerator abstractions + the paper's Table 4 configurations.

Per paper §4.4, every evaluated accelerator manifests (a) spatial unrolling
dimensions — differing in count and *functions* (reduce links, output
bandwidth, overlap-reuse primitives) — and (b) temporal unrolling into a
memory hierarchy (per-PE local scratchpads, shared global buffer). The
mapping algorithm (mapping.py) is generic over this spec; per-accelerator
parameter priorities "slightly change Lines 7–22 of Algorithm 1".

Sizes are in words (one operand), bandwidths in words/cycle, matching the
paper's Table 4 conventions. ``offload`` marks CIPs that must ship
non-traditional layers to a host CPU (ARM A53 over PCIe 4.0 in §6.2).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple


@dataclass(frozen=True)
class SpatialDim:
    """One spatial unrolling dimension of a PE array."""

    name: str                       # e.g. "py", "px", "sub"
    size: int
    reduce: bool = False            # partial-result forwarding links
    overlap: bool = False           # overlap-reuse primitive lives here
    priority: Tuple[str, ...] = ("ks", "opc", "op", "g")


@dataclass(frozen=True)
class AcceleratorSpec:
    name: str
    kind: str                       # "TIP" | "LIP" | "CIP"
    spatial: Tuple[SpatialDim, ...]
    ls: Dict[str, int]              # per-PE scratchpad words: {"I","K","O"}
    gb: Dict[str, int]              # global buffer words per data type
    gb_bandwidth: Dict[str, int]    # words/cycle between GB and array
    temporal_priority: Tuple[str, ...] = ("op", "ks", "opc", "g")
    freq_mhz: int = 700
    offload: bool = False           # CIP: non-traditional layers -> host
    has_overlap_primitive: bool = False

    @property
    def n_pes(self) -> int:
        n = 1
        for s in self.spatial:
            n *= s.size
        return n

    def spatial_by_name(self, name: str) -> SpatialDim:
        for s in self.spatial:
            if s.name == name:
                return s
        raise KeyError(name)


MB = 1024 * 1024 // 2  # words of 16-bit data per MB (paper uses 16-bit ops)
KB = 1024 // 2


# ---------------------------------------------------------------------------
# Table 4 configurations
# ---------------------------------------------------------------------------
def tpu_like() -> AcceleratorSpec:
    """TIP: TPU basic block scaled down 4x4 (64x64 systolic array)."""
    return AcceleratorSpec(
        name="TPU", kind="TIP",
        spatial=(
            SpatialDim("rows", 64, reduce=True,
                       priority=("ks", "opc", "op", "g")),
            SpatialDim("cols", 64, reduce=False,
                       priority=("op", "opc", "ks", "g")),
        ),
        ls={"I": 1, "K": 1, "O": 1},        # no per-PE scratchpads
        gb={"I": int(0.75 * MB), "O": int(0.75 * MB), "K": int(0.25 * MB)},
        gb_bandwidth={"I": 64, "O": 64, "K": 11},
        offload=False, has_overlap_primitive=False)


def dnnweaver() -> AcceleratorSpec:
    """LIP: DNNWeaver, 14 PUs x 74 PEs (AlexNet config, Stratix V)."""
    return AcceleratorSpec(
        name="DNNW", kind="LIP",
        spatial=(
            SpatialDim("pe", 74, reduce=True, overlap=True,
                       priority=("ks", "opc", "op", "g")),
            SpatialDim("pu", 14, reduce=False,
                       priority=("op", "opc", "ks", "g")),
        ),
        ls={"I": 1, "K": 1, "O": 1},
        gb={"I": 4 * KB * 14, "O": 4 * KB * 14, "K": int(8.5 * KB) * 14},
        gb_bandwidth={"I": 14, "O": 14, "K": 14},
        offload=False, has_overlap_primitive=True)


def eyeriss() -> AcceleratorSpec:
    """CIP: Eyeriss 12x14, row-stationary (paper Fig. 7/8, Alg. 1 defaults)."""
    return AcceleratorSpec(
        name="ER", kind="CIP",
        spatial=(
            SpatialDim("py", 12, reduce=True, overlap=True,
                       priority=("ks", "opc", "op", "g")),
            SpatialDim("px", 14, reduce=False, overlap=True,
                       priority=("opc", "op", "ks", "g")),
        ),
        ls={"I": 12, "K": 224, "O": 24},
        gb={"I": int(0.05 * MB), "O": int(0.05 * MB), "K": int(0.008 * MB)},
        gb_bandwidth={"I": 16, "O": 16, "K": 16},
        offload=True, has_overlap_primitive=True)


def eager_pruning() -> AcceleratorSpec:
    """CIP: EagerPruning, 4 subsystems x 512 PEs; single spatial dim per
    subsystem exploits reduce and overlap simultaneously (paper §4.4)."""
    return AcceleratorSpec(
        name="EP", kind="CIP",
        spatial=(
            SpatialDim("pe", 512, reduce=True, overlap=True,
                       priority=("ks", "opc", "op", "g")),
            SpatialDim("sub", 4, reduce=False,
                       priority=("op", "opc", "ks", "g")),
        ),
        ls={"I": 64, "K": 1, "O": 1},
        gb={"I": int(1.5 * MB), "O": int(1.5 * MB), "K": int(1.5 * MB)},
        gb_bandwidth={"I": 128, "O": 128, "K": 128},
        offload=True, has_overlap_primitive=True)


def nlr() -> AcceleratorSpec:
    """CIP: NLR (Zhang FPGA'15), Tm=64 output x Tn=7 input unrolling; no
    overlap-reuse (paper §6.5 notes its high on-chip movement)."""
    return AcceleratorSpec(
        name="NLR", kind="CIP",
        spatial=(
            SpatialDim("tn", 7, reduce=True,
                       priority=("ks", "opc", "op", "g")),
            SpatialDim("tm", 64, reduce=False,
                       priority=("op", "opc", "ks", "g")),
        ),
        ls={"I": 1, "K": 1, "O": 1},
        gb={"I": int(0.75 * MB), "K": int(0.75 * MB), "O": int(0.375 * MB)},
        gb_bandwidth={"I": 7, "K": 7, "O": 64},
        offload=True, has_overlap_primitive=False)


def tpu_v5e() -> AcceleratorSpec:
    """Our TPU-native target (DESIGN.md §2): one MXU modeled as a 128x128
    contraction array with VMEM as the (shared) local store. Used by the
    kernel mapper / cost model to pick BlockSpec tiles; roofline analysis of
    the real compiled HLO supersedes this for §Roofline."""
    vmem_words = 64 * MB        # 128 MB VMEM, 16-bit words
    return AcceleratorSpec(
        name="TPUv5e", kind="GC-TPU",
        spatial=(
            SpatialDim("mxu_k", 128, reduce=True,
                       priority=("ks", "opc", "op", "g")),
            SpatialDim("mxu_n", 128, reduce=False,
                       priority=("op", "opc", "ks", "g")),
        ),
        ls={"I": vmem_words // 4, "K": vmem_words // 4, "O": vmem_words // 2},
        gb={"I": 8 * 1024 * MB, "O": 8 * 1024 * MB, "K": 8 * 1024 * MB},
        gb_bandwidth={"I": 256, "O": 256, "K": 256},
        freq_mhz=940,
        offload=False, has_overlap_primitive=True)


TABLE4: Dict[str, AcceleratorSpec] = {}
for _f in (tpu_like, dnnweaver, eyeriss, eager_pruning, nlr):
    _spec = _f()
    TABLE4[_spec.name] = _spec


def get(name: str) -> AcceleratorSpec:
    if name == "TPUv5e":
        return tpu_v5e()
    return TABLE4[name]
