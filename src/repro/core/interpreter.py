"""Pure-JAX GCONV chain interpreter (the semantic oracle).

Executes a :class:`~repro.core.chain.Chain` node by node, realizing the paper's
nested-loop semantics (Fig. 4) with vectorized JAX ops. Per dimension the input
axis (size ``Ng*Nips``) is viewed as ``(Ng, Nips)``, padded with the *reduce
identity*, and expanded into sliding windows ``(Ng, Nopc, Nks)``; the kernel
axis is viewed as ``(Ng, Nop, Nks)``; ``main`` combines them with broadcasting
and ``reduce`` folds every ``Nks`` axis, yielding ``(Ng, Nop, Nopc)`` per
dimension, re-flattened to the output axis.

This is deliberately the *simple, obviously-correct* realization: it is the
oracle against which the mapped/fused/Pallas execution paths are tested. It is
only meant to run at test sizes (the expanded main-operand tensor has
``macs`` elements).
"""
from __future__ import annotations

from typing import Callable, Dict, Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import operators as ops
from .chain import Chain, Concat, Movement
from .gconv import DimSpec, GConv


def init_chain_params(chain: Chain, key, scale: float = 0.1
                      ) -> Dict[str, jnp.ndarray]:
    """Random parameter pytree for a chain (shared by the oracle executor
    and the compiled engine so both draw the identical values)."""
    out = {}
    for name, info in chain.params.items():
        key, sub = jax.random.split(key)
        out[name] = scale * jax.random.normal(sub, info.shape,
                                              dtype=info.dtype)
    return out


def apply_movement(node: Movement, x: jnp.ndarray) -> jnp.ndarray:
    """Movement semantics (reshape/transpose/flip + the deterministic
    gather stand-in) — the single definition both engines execute.

    Runtime-dependent selection (RoI boxes / NMS) is modeled as a
    deterministic stand-in: cycle through the flattened source (movement
    cost is what matters here)."""
    if node.pre_shape is not None:
        x = x.reshape(node.pre_shape)
    if node.perm is not None:
        x = jnp.transpose(x, node.perm)
    for ax in node.flip:
        x = jnp.flip(x, axis=ax)
    if node.gather:
        flat = x.reshape(-1)
        n = node.out_elems
        reps = -(-n // flat.size)
        flat = jnp.tile(flat, reps)[:n]
        return flat.reshape(node.out_shape)
    return x.reshape(node.out_shape)


def _window_axis(x: jnp.ndarray, axis: int, d: DimSpec, pad_val: float):
    """(…, Ng*Nips, …) -> (…, Ng, Nopc, Nks, …) at ``axis``."""
    x = jnp.moveaxis(x, axis, -1)
    lead = x.shape[:-1]
    x = x.reshape(lead + (d.ng, d.nips))
    if d.padr < 0:                      # crop: trailing elements never read
        x = x[..., : d.nips + d.padr]
    if d.pad > 0 or d.padr > 0:
        pad = [(0, 0)] * (x.ndim - 1) + [(d.pad, max(d.padr, 0))]
        x = jnp.pad(x, pad, constant_values=pad_val)
    # gather windows: idx[opc, ks] = opc*s + ks
    idx = (np.arange(d.nopc)[:, None] * d.stride + np.arange(d.nks)[None, :])
    x = x[..., idx]                     # (…, Ng, Nopc, Nks)
    return x


def eval_gconv(node: GConv,
               x: jnp.ndarray,
               k: Optional[jnp.ndarray],
               operand_lookup: Optional[Callable] = None) -> jnp.ndarray:
    """Evaluate one GCONV on concrete arrays (oracle semantics)."""
    nd = len(node.dims)
    compute_dtype = jnp.result_type(x.dtype, jnp.float32)
    x = x.astype(compute_dtype)
    # pre operators act on the loaded inputs (before windowing / padding)
    x = ops.apply_unary_seq(node.pre, x, operand_lookup)
    pad_val = ops.pad_value(node.reduce)
    # expand each dim into (g, opc, ks); axes triple per original dim
    for i, d in enumerate(node.dims):
        # current position of the i-th original axis = 3*i (each processed dim
        # has been replaced by 3 axes in-place)
        x = _window_axis(x, 3 * i, d, pad_val)
        # _window_axis moves the processed axis to the end; bring the triple
        # back to position 3*i
        x = jnp.moveaxis(x, (-3, -2, -1), (3 * i, 3 * i + 1, 3 * i + 2))
    # x now has per-dim axes (g, opc, ks); insert op axis -> (g, op, opc, ks)
    x_shape = []
    for i, d in enumerate(node.dims):
        x_shape += [d.ng, 1, d.nopc, d.nks]
    x = x.reshape(x_shape)
    if node.main != "none":
        assert k is not None
        k = k.astype(compute_dtype)
        k_shape = []
        for i, d in enumerate(node.dims):
            if k.shape[i] == 1:
                k_shape += [1, 1, 1, 1]
            else:
                k_shape += [d.ng, d.nop, 1, d.nks]
        k = k.reshape(k_shape)
        y = ops.apply_main(node.main, x, k)
    else:
        y = x
    ks_axes = tuple(4 * i + 3 for i in range(nd))
    y = ops.apply_reduce(node.reduce, y, ks_axes)
    if node.reduce == "none":
        y = y.reshape([s for i, s in enumerate(y.shape) if i % 4 != 3])
    # y axes per dim: (g, op, opc) -> flatten to out axis
    y = y.reshape(node.out_shape)
    y = ops.apply_unary_seq(node.post, y, operand_lookup)
    if node.out_dtype is not None:
        y = y.astype(node.out_dtype)
    return y


class ChainExecutor:
    """Executes a chain on concrete inputs/params, returns all node outputs."""

    def __init__(self, chain: Chain):
        chain.validate()
        self.chain = chain

    def init_params(self, key, scale: float = 0.1) -> Dict[str, jnp.ndarray]:
        return init_chain_params(self.chain, key, scale)

    def __call__(self,
                 inputs: Mapping[str, jnp.ndarray],
                 params: Optional[Mapping[str, jnp.ndarray]] = None,
                 keep_all: bool = False) -> Dict[str, jnp.ndarray]:
        params = params or {}
        env: Dict[str, jnp.ndarray] = {}
        for name, info in self.chain.inputs.items():
            if name not in inputs:
                raise ValueError(f"missing chain input {name!r}")
            arr = jnp.asarray(inputs[name])
            if tuple(arr.shape) != info.shape:
                raise ValueError(
                    f"input {name!r}: got {arr.shape}, want {info.shape}")
            env[name] = arr
        for name, info in self.chain.params.items():
            if name not in params:
                raise ValueError(f"missing chain param {name!r}")
            env[name] = jnp.asarray(params[name])

        lookup = lambda op: env[op.operand]
        for name, node in self.chain.nodes.items():
            if isinstance(node, Concat):
                env[name] = jnp.concatenate(
                    [env[r] for r in node.inputs], axis=node.axis)
            elif isinstance(node, Movement):
                env[name] = apply_movement(node, env[node.input])
            else:
                k = env[node.kernel] if node.kernel is not None else None
                env[name] = eval_gconv(node, env[node.input], k, lookup)
        if keep_all:
            return env
        outs = self.chain.outputs or [list(self.chain.nodes)[-1]]
        return {o: env[o] for o in outs}
