"""Chain-level performance/energy simulator (paper §4.2 + §6.2 methodology).

Implements the paper's concise model: computation cycles from Eq. (6), data
movement from Table 3 / Eqs. (7)-(10), latency = max(compute, per-type load)
(loading overlaps the systolic computation), and movement-dominated energy.

Three evaluation paths:
  * :func:`gconv_chain_cost` — the paper's system: every node auto-mapped by
    Algorithm 1 (+ §4.3 consistent-mapping loop exchange between
    producer/consumer pairs) on the full PE array.
  * :func:`baseline_cost` — the accelerator's native operation (§6.2):
      - CIP: traditional layers on-chip (same mapper = their native
        dataflow; GCONV is "no worse" on convs), non-traditional layers
        offloaded to an ARM-A53-class host over PCIe 4.0;
      - TIP: everything on-chip but via im2col-style matrix ops — input
        replication, no overlap-reuse;
      - LIP: two fixed pipeline stages (traditional / non-traditional
        units), resources partitioned by the suite-wide computation ratio —
        pipeline bubbles when a network deviates from that ratio.

Energy units are relative to one local-scratchpad access = 1.0 (Eyeriss
convention); offload costs 146x an on-chip GB access (paper §2.3).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .accelerators import AcceleratorSpec
from .chain import Chain, Concat, Movement
from .gconv import GConv
from .mapping import Mapping, apply_loop_exchange, map_gconv

# ---------------------------------------------------------------------------
# constants (§6.2): 700 MHz accelerators; ARM A53 host over PCIe 4.0
# ---------------------------------------------------------------------------
PCIE_WORDS_PER_CYCLE = 2.9       # ~4 GB/s effective / 2 B / 700 MHz
HOST_OPS_PER_CYCLE = 4.0         # A53-class, memory-bound on tensor ops
OFFLOAD_LAUNCH_CYCLES = 7000.0   # ~10 us driver/DMA setup per offload
E_MAC = 0.2
E_LS = 1.0
E_GB = 6.0
E_OFFLOAD = 146.0 * E_GB         # per word shipped to/from the host
LIP_TRAD_FRACTION = 0.8          # suite-wide trad/non-trad resource split
MISALIGN_FACTOR = 3.9            # strided (format-inconsistent) load penalty
                                 # = the paper's max loop-exchange gain (§4.3)
TIP_ISSUE_CYCLES = 2000.0        # per-instruction-group issue/drain bubble


@dataclass
class NodeCost:
    name: str
    kind: str                    # "gconv" | "movement" | "offload"
    cycles: float = 0.0
    load_cycles: float = 0.0
    latency: float = 0.0
    movement: Dict[str, float] = field(default_factory=dict)
    energy: float = 0.0
    traditional: bool = True
    mapping: Optional[Mapping] = None


@dataclass
class ChainCost:
    chain_name: str
    accel: str
    mode: str
    nodes: List[NodeCost]

    @property
    def latency(self) -> float:
        return sum(n.latency for n in self.nodes)

    @property
    def compute_cycles(self) -> float:
        return sum(n.cycles for n in self.nodes)

    @property
    def movement_words(self) -> float:
        return sum(sum(n.movement.values()) for n in self.nodes)

    @property
    def energy(self) -> float:
        return sum(n.energy for n in self.nodes)

    @property
    def offload_latency(self) -> float:
        return sum(n.latency for n in self.nodes if n.kind == "offload")

    def summary(self) -> dict:
        return dict(chain=self.chain_name, accel=self.accel, mode=self.mode,
                    latency=self.latency, cycles=self.compute_cycles,
                    movement=self.movement_words, energy=self.energy,
                    offload_latency=self.offload_latency)


def _movement_node_cost(node, chain: Chain, spec: AcceleratorSpec,
                        traditional: bool) -> NodeCost:
    elems = node.out_elems
    bw = max(spec.gb_bandwidth.values())
    return NodeCost(name=node.name, kind="movement",
                    latency=elems / bw, load_cycles=elems / bw,
                    movement={"I": elems, "O": elems},
                    energy=2 * elems * E_GB, traditional=traditional)


def kernel_movement_scale(g: GConv,
                          k_actual_elems: Optional[int]) -> float:
    """Kernel-words adjustment shared by the analytic model and the
    cycle-level simulator: no kernel parameters at all for main == 'none';
    broadcast kernels (Table 2: FP1 as FP2's kernel, etc.) only move their
    actual elements, not the full per-dim k_size product."""
    if g.main == "none":
        return 0.0
    if k_actual_elems is not None and g.k_elems > 0:
        return min(1.0, k_actual_elems / g.k_elems)
    return 1.0


def gconv_energy(g: GConv, movement: Dict[str, float],
                 energy_overhead: float = 0.0) -> float:
    """Movement-dominated node energy (relative units), shared by both
    evaluation engines."""
    return (g.macs * E_MAC + g.macs * E_LS
            + sum(movement.values()) * E_GB) * (1.0 + energy_overhead)


def _gconv_node_cost(g: GConv, spec: AcceleratorSpec,
                     load_width: Dict[str, int] = None,
                     im2col: bool = False,
                     energy_overhead: float = 0.0,
                     mapping: Optional[Mapping] = None,
                     k_actual_elems: Optional[int] = None) -> NodeCost:
    m = mapping if mapping is not None else map_gconv(g, spec)
    mov = dict(m.movement())
    mov["K"] = mov["K"] * kernel_movement_scale(g, k_actual_elems)
    if im2col:
        # TIP path: inputs replicated into matrix columns — overlap-reuse
        # becomes data replication (paper Fig. 1(c) / Table 1(b) col 1).
        repl = 1.0
        for d in g.dims:
            unique = d.ng * d.nips
            loaded = d.ng * d.nopc * d.nks
            repl *= max(1.0, loaded / unique)
        mov["I"] = mov["I"] * repl
    load = {}
    for t in mov:
        bw = max(1, spec.gb_bandwidth.get(t, 1))
        aligned = (load_width or {}).get(t, True)
        # format misalignment only hurts scratchpad loading (§4.3 is about
        # the ILS fill path); stream-from-GB accelerators (ls=1) don't care
        penalize = (not aligned) and spec.ls.get(t, 1) > 1
        load[t] = mov[t] / bw * (MISALIGN_FACTOR if penalize else 1.0)
    cycles = m.cycles()
    latency = max(float(cycles), *load.values())
    energy = gconv_energy(g, mov, energy_overhead)
    return NodeCost(name=g.name, kind="gconv", cycles=cycles,
                    load_cycles=max(load.values()), latency=latency,
                    movement=mov, energy=energy, mapping=m)


def _offload_node_cost(node, chain: Chain) -> NodeCost:
    """Ship inputs out + results back over PCIe; compute on the host."""
    if isinstance(node, GConv):
        in_elems, out_elems, macs = node.in_elems, node.out_elems, node.macs
    else:
        out_elems = node.out_elems
        in_elems, macs = out_elems, 0
    transfer = (in_elems + out_elems) / PCIE_WORDS_PER_CYCLE
    host = macs / HOST_OPS_PER_CYCLE
    return NodeCost(name=node.name, kind="offload",
                    latency=OFFLOAD_LAUNCH_CYCLES + transfer + host,
                    load_cycles=transfer,
                    movement={"I": in_elems, "O": out_elems},
                    energy=(in_elems + out_elems) * E_OFFLOAD,
                    traditional=False)


# ---------------------------------------------------------------------------
# GCONV Chain path
# ---------------------------------------------------------------------------
def _check_override_resources(ov_spec: AcceleratorSpec,
                              spec: AcceleratorSpec, node: str):
    """An override's spec may differ from the chain's target spec only in
    Algorithm-1 priorities (per §4.4) — never in physical resources."""
    from .mapping import MappingError

    same = (
        tuple((s.name, s.size, s.reduce, s.overlap)
              for s in ov_spec.spatial)
        == tuple((s.name, s.size, s.reduce, s.overlap)
                 for s in spec.spatial)
        and ov_spec.ls == spec.ls and ov_spec.gb == spec.gb
        and ov_spec.gb_bandwidth == spec.gb_bandwidth
        and ov_spec.has_overlap_primitive == spec.has_overlap_primitive)
    if not same:
        raise MappingError(
            f"override for node {node!r} was mapped on {ov_spec.name!r}, "
            f"whose resources differ from target {spec.name!r}")


def chain_mappings(chain: Chain, spec: AcceleratorSpec,
                   consistent: bool = True,
                   overrides: Optional[Dict[str, Mapping]] = None,
                   ) -> Tuple[Dict[str, Mapping], Dict[str, bool]]:
    """Map every GCONV node (Algorithm 1) and resolve §4.3 producer/consumer
    load-format alignment across the chain.

    Returns ``(mappings, aligned)``: the per-node mappings (after the
    consistent-mapping loop exchange when ``consistent`` is set) and, per
    node, whether its intermediate input loads run at full bus width or pay
    the strided-access penalty. Shared between the analytic model below and
    the cycle-level simulator (``repro.sim.engine``), which must charge the
    exact same mappings to be comparable.

    ``overrides`` replaces Algorithm 1's output for the named nodes with
    externally-supplied mappings (e.g. ``repro.dse`` search results). Each
    override is cloned (the loop exchange mutates entry lists in place) and
    re-checked through :meth:`Mapping.validate` — the same resource-limit
    path the mapper itself runs. An override may carry a priority-variant
    ``spec`` (different Algorithm-1 priorities) but its *resources* (array
    axes, scratchpads, buffers, bandwidth) must match ``spec`` — a mapping
    built for a bigger accelerator cannot smuggle that accelerator's
    resources into this chain's cost. Override names not present as GCONV
    nodes raise (silently dropping a searched mapping would misreport).
    """
    from .mapping import MappingError, consistent_load_width

    if overrides:
        unknown = [n for n in overrides
                   if not isinstance(chain.nodes.get(n), GConv)]
        if unknown:
            raise MappingError(
                f"overrides name non-GCONV/unknown nodes {unknown} "
                f"of chain {chain.name!r}")
    mappings: Dict[str, Mapping] = {}
    for name, node in chain.nodes.items():
        if isinstance(node, GConv):
            ov = overrides.get(name) if overrides else None
            if ov is not None:
                _check_override_resources(ov.spec, spec, name)
                mappings[name] = ov.clone().validate()
            else:
                mappings[name] = map_gconv(node, spec)
    # §4.3 consistent mapping between chain producer/consumer pairs: where
    # the consumer's load format can be made consistent with the producer's
    # store format (loop exchange), intermediate loads run at full bus width;
    # otherwise they pay the strided-access penalty.
    aligned: Dict[str, bool] = {}
    for name, node in chain.nodes.items():
        if not isinstance(node, GConv):
            continue
        prod = node.input
        if prod in mappings:
            if consistent:
                w = apply_loop_exchange(mappings[prod], mappings[name])
            else:
                w = consistent_load_width(mappings[prod], mappings[name])
            aligned[name] = w > 1
        else:
            aligned[name] = True       # chain inputs stream from DRAM
    return mappings, aligned


def gconv_chain_cost(chain: Chain, spec: AcceleratorSpec,
                     consistent: bool = True,
                     energy_overhead: float = 0.19,
                     precomputed: Optional[Tuple[Dict[str, Mapping],
                                                 Dict[str, bool]]] = None,
                     overrides: Optional[Dict[str, Mapping]] = None,
                     ) -> ChainCost:
    """Every node auto-mapped on the full array (paper's GC-<accel>).

    ``energy_overhead`` charges the GCONV augmentation (instruction buffers,
    generalized main/reduce ALUs): +19 % power per paper Fig. 17.
    ``precomputed`` takes a :func:`chain_mappings` result so callers scoring
    the same chain with several engines share one mapping pass.
    ``overrides`` forwards per-node mapping replacements to
    :func:`chain_mappings`; mutually exclusive with ``precomputed`` (bake
    overrides into the precomputed result instead — silently dropping them
    would misreport the searched cost).
    """
    if precomputed is not None:
        if overrides:
            raise ValueError("pass overrides to chain_mappings() when "
                             "supplying precomputed, not both here")
        mappings, aligned = precomputed
    else:
        mappings, aligned = chain_mappings(chain, spec, consistent=consistent,
                                           overrides=overrides)
    nodes = []
    for name, node in chain.nodes.items():
        trad = chain.meta.get(name, {}).get("traditional", True)
        if isinstance(node, (Concat, Movement)):
            nodes.append(_movement_node_cost(node, chain, spec, trad))
        else:
            lw = {"I": aligned.get(name, True)}
            nc = _gconv_node_cost(node, spec, load_width=lw,
                                  energy_overhead=energy_overhead,
                                  mapping=mappings[name],
                                  k_actual_elems=_k_elems(chain, node))
            nc.traditional = trad
            nodes.append(nc)
    return ChainCost(chain.name, spec.name, "gconv", nodes)


def _k_elems(chain: Chain, g: GConv) -> Optional[int]:
    if g.kernel is None:
        return None
    n = 1
    for s in chain.shape_of(g.kernel):
        n *= s
    return n


# ---------------------------------------------------------------------------
# baseline paths (§6.2)
# ---------------------------------------------------------------------------
def baseline_cost(chain: Chain, spec: AcceleratorSpec) -> ChainCost:
    kind = spec.kind
    nodes: List[NodeCost] = []
    # baselines do not coordinate producer/consumer storage formats across
    # layers (that is the §4.3 GCONV-Chain feature): evaluate the natural
    # (exchange-free) load alignment between consecutive on-chip nodes
    aligned = _natural_alignment(chain, spec)
    if kind == "CIP":
        for name, node in chain.nodes.items():
            trad = chain.meta.get(name, {}).get("traditional", False)
            if trad and isinstance(node, GConv):
                nc = _gconv_node_cost(node, spec, energy_overhead=0.0,
                                      load_width={"I": aligned.get(name,
                                                                   True)},
                                      k_actual_elems=_k_elems(chain, node))
                nc.traditional = True
                nodes.append(nc)
            else:
                nodes.append(_offload_node_cost(node, chain))
        return ChainCost(chain.name, spec.name, "baseline", nodes)

    if kind == "TIP":
        # TIPs issue explicit load + matrix/vector instructions per op and
        # cannot fuse (pre/post operators don't exist): every intermediate
        # round-trips the GB, plus a per-op issue/drain bubble (paper Fig. 12:
        # TPU all-busy 31%; Fig. 15: 2.6x worse code density than GC-CIP).
        for name, node in chain.nodes.items():
            trad = chain.meta.get(name, {}).get("traditional", False)
            if isinstance(node, (Concat, Movement)):
                nodes.append(_movement_node_cost(node, chain, spec, trad))
            else:
                nc = _gconv_node_cost(node, spec, im2col=True,
                                      energy_overhead=0.0,
                                      load_width={"I": aligned.get(name,
                                                                   True)},
                                      k_actual_elems=_k_elems(chain, node))
                nc.latency += TIP_ISSUE_CYCLES
                nc.traditional = trad
                nodes.append(nc)
        return ChainCost(chain.name, spec.name, "baseline", nodes)

    if kind == "LIP":
        # Fixed two-stage pipeline. Resources split by the suite-wide ratio;
        # per-layer cycles scale inversely with the allotted fraction, and the
        # pipeline throughput is set by the slower stage (bubbles in the
        # other — paper Table 1(b) col 3).
        r = LIP_TRAD_FRACTION
        t_time = n_time = 0.0
        for name, node in chain.nodes.items():
            trad = chain.meta.get(name, {}).get("traditional", False)
            if isinstance(node, (Concat, Movement)):
                nc = _movement_node_cost(node, chain, spec, trad)
            else:
                nc = _gconv_node_cost(node, spec, energy_overhead=0.0,
                                      load_width={"I": aligned.get(name,
                                                                   True)},
                                      k_actual_elems=_k_elems(chain, node))
                nc.traditional = trad
            scale = (1.0 / r) if trad else (1.0 / (1.0 - r))
            nc.latency *= scale
            nc.cycles *= scale
            if trad:
                t_time += nc.latency
            else:
                n_time += nc.latency
            nodes.append(nc)
        cost = ChainCost(chain.name, spec.name, "baseline", nodes)
        cost.pipeline_stage_times = (t_time, n_time)     # type: ignore
        return cost

    raise ValueError(f"no baseline semantics for accelerator kind {kind!r}")


def _natural_alignment(chain: Chain, spec: AcceleratorSpec):
    """Exchange-free producer/consumer format consistency per node."""
    return chain_mappings(chain, spec, consistent=False)[1]


def lip_utilization(cost: ChainCost) -> float:
    """All-busy fraction of the 2-stage LIP pipeline (paper Fig. 12)."""
    t, n = getattr(cost, "pipeline_stage_times", (0.0, 0.0))
    hi = max(t, n)
    if hi == 0:
        return 1.0
    return min(t, n) / hi


def speedup(chain: Chain, spec: AcceleratorSpec, consistent: bool = True,
            fuse: bool = True) -> Tuple[float, ChainCost, ChainCost]:
    """End-to-end GCONV-Chain-vs-baseline speedup (paper Fig. 14 method):
    the GC path runs the full compiler pipeline (§4.3 fusion + consistent
    mapping); the baseline runs the accelerator's native mode."""
    from .fusion import fuse_chain

    base = baseline_cost(chain, spec)
    if spec.kind == "LIP":
        base_latency = max(getattr(base, "pipeline_stage_times"))
    else:
        base_latency = base.latency
    gchain = fuse_chain(chain)[0] if fuse else chain
    gc = gconv_chain_cost(gchain, spec, consistent=consistent)
    return base_latency / gc.latency, base, gc
