"""GCONV Chain IR (paper §3.2).

A :class:`Chain` is an ordered producer/consumer DAG whose nodes are
:class:`~repro.core.gconv.GConv` operations (plus a lightweight ``Concat``
pseudo-node for pure data-movement layers such as GoogLeNet/DenseNet concat).

Node inputs/kernels/operands reference, by name, one of
  * an external chain input      (``chain.inputs``),
  * a learned/constant parameter (``chain.params``),
  * a previous node's output.

Shape discipline: every tensor in a chain is carried with an explicit
N-dimensional *named* layout. A consumer GCONV must agree with its producer
axis-by-axis on the *total* axis sizes (it may re-interpret the grouping of an
axis — e.g. view a size-``C`` axis as ``Ng:C`` where the producer wrote it as
``Nop:C``; that re-interpretation is exactly the paper's Figure 5/Table 2
usage). Kernels and pre/post operands may *broadcast*: a size-1 axis matches
anything (Table 2, e.g. FP4's kernel is the per-channel FP3 output broadcast
over the batch axis).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple, Union

from .gconv import GConv


@dataclass
class Concat:
    """Concatenation pseudo-node (pure data movement, no arithmetic)."""

    name: str
    inputs: Tuple[str, ...]
    axis: int
    out_shape: Tuple[int, ...] = ()

    @property
    def macs(self) -> int:
        return 0

    @property
    def out_elems(self) -> int:
        n = 1
        for s in self.out_shape:
            n *= s
        return n


@dataclass
class Movement:
    """Transpose-and/or-reshape pseudo-node (pure data movement).

    Applied as: ``y = x.transpose(perm).reshape(out_shape)``. Used to re-view
    tensors between GCONVs whose dim decompositions differ (e.g. (B,T,C) ->
    (B,H,T,D) for the attention chain segment). In hardware terms this is the
    paper's "storage format" concern — the consistent-mapping pass (§4.3)
    tries to make these free by loop exchange; any that remain are charged as
    data movement by the cost model.
    """

    name: str
    input: str
    perm: Optional[Tuple[int, ...]] = None
    out_shape: Tuple[int, ...] = ()
    pre_shape: Optional[Tuple[int, ...]] = None   # reshape before perm
    flip: Tuple[int, ...] = ()                    # axes to reverse (rot180
                                                  # weight views for conv BP)
    gather: bool = False    # element-count-changing movement (RoI gather,
                            # proposal selection): interpreter-opaque, cost
                            # model charges the moved output elements

    @property
    def macs(self) -> int:
        return 0

    @property
    def out_elems(self) -> int:
        n = 1
        for s in self.out_shape:
            n *= s
        return n


Node = Union[GConv, Concat, Movement]


@dataclass
class TensorInfo:
    shape: Tuple[int, ...]
    dtype: str = "float32"


class Chain:
    """An ordered GCONV chain with external inputs and parameters."""

    def __init__(self, name: str):
        self.name = name
        self.inputs: Dict[str, TensorInfo] = {}
        self.params: Dict[str, TensorInfo] = {}
        self.nodes: Dict[str, Node] = {}          # insertion-ordered
        self.outputs: List[str] = []
        # optional per-node metadata (layer provenance, traditional-or-not)
        self.meta: Dict[str, dict] = {}

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------
    def add_input(self, name: str, shape: Sequence[int], dtype="float32") -> str:
        if name in self.inputs:
            raise ValueError(f"duplicate input {name!r}")
        self.inputs[name] = TensorInfo(tuple(int(s) for s in shape), dtype)
        return name

    def add_param(self, name: str, shape: Sequence[int], dtype="float32") -> str:
        if name in self.params:
            raise ValueError(f"duplicate param {name!r}")
        self.params[name] = TensorInfo(tuple(int(s) for s in shape), dtype)
        return name

    def fresh(self, base: str) -> str:
        if not self.known(base):
            return base
        i = 1
        # probe all three namespaces: a candidate colliding with an input
        # or param would make add() raise "duplicate node name"
        while self.known(f"{base}_{i}"):
            i += 1
        return f"{base}_{i}"

    def add(self, node: Node, **meta) -> str:
        if node.name in self.nodes or node.name in self.inputs or node.name in self.params:
            raise ValueError(f"duplicate node name {node.name!r}")
        for ref in self._refs(node):
            if not self.known(ref):
                raise ValueError(
                    f"node {node.name!r} references unknown tensor {ref!r}")
        self._check_shapes(node)
        self.nodes[node.name] = node
        if meta:
            self.meta[node.name] = dict(meta)
        return node.name

    def mark_output(self, name: str):
        if name not in self.nodes:
            raise ValueError(f"cannot mark non-node {name!r} as output")
        if name not in self.outputs:
            self.outputs.append(name)

    # ------------------------------------------------------------------
    # queries
    # ------------------------------------------------------------------
    def known(self, ref: str) -> bool:
        return ref in self.inputs or ref in self.params or ref in self.nodes

    def shape_of(self, ref: str) -> Tuple[int, ...]:
        if ref in self.inputs:
            return self.inputs[ref].shape
        if ref in self.params:
            return self.params[ref].shape
        node = self.nodes[ref]
        if isinstance(node, GConv):
            return node.out_shape
        return tuple(node.out_shape)

    @staticmethod
    def _refs(node: Node) -> List[str]:
        if isinstance(node, Concat):
            return list(node.inputs)
        if isinstance(node, Movement):
            return [node.input]
        refs = [node.input]
        if node.kernel is not None:
            refs.append(node.kernel)
        for op in tuple(node.pre) + tuple(node.post):
            if op.operand is not None:
                refs.append(op.operand)
        return refs

    def consumers(self) -> Dict[str, List[str]]:
        out: Dict[str, List[str]] = {}
        for name, node in self.nodes.items():
            for ref in self._refs(node):
                out.setdefault(ref, []).append(name)
        return out

    def gconv_nodes(self) -> List[GConv]:
        return [n for n in self.nodes.values() if isinstance(n, GConv)]

    # ------------------------------------------------------------------
    # validation
    # ------------------------------------------------------------------
    def _check_shapes(self, node: Node):
        if isinstance(node, Movement):
            in_shape = self.shape_of(node.input)
            if node.pre_shape is not None:
                n_a = 1
                for s in in_shape:
                    n_a *= s
                n_b = 1
                for s in node.pre_shape:
                    n_b *= s
                if n_a != n_b:
                    raise ValueError(f"{node.name}: pre_shape elems mismatch")
                in_shape = tuple(node.pre_shape)
            if node.perm is not None:
                if sorted(node.perm) != list(range(len(in_shape))):
                    raise ValueError(f"{node.name}: bad perm {node.perm} "
                                     f"for rank {len(in_shape)}")
                in_shape = tuple(in_shape[p] for p in node.perm)
            if not node.out_shape:
                node.out_shape = tuple(in_shape)
            n_in = 1
            for s in in_shape:
                n_in *= s
            n_out = 1
            for s in node.out_shape:
                n_out *= s
            if n_in != n_out and not node.gather:
                raise ValueError(
                    f"{node.name}: movement elems mismatch {in_shape} -> "
                    f"{node.out_shape}")
            return
        if isinstance(node, Concat):
            shapes = [self.shape_of(r) for r in node.inputs]
            base = list(shapes[0])
            for s in shapes[1:]:
                if len(s) != len(base):
                    raise ValueError(f"{node.name}: concat rank mismatch {shapes}")
                for ax, (a, b) in enumerate(zip(base, s)):
                    if ax == node.axis:
                        continue
                    if a != b:
                        raise ValueError(
                            f"{node.name}: concat non-axis mismatch {shapes}")
            base[node.axis] = sum(s[node.axis] for s in shapes)
            node.out_shape = tuple(base)
            return
        # GConv: input must match in_shape exactly; kernel/operands broadcast.
        in_shape = self.shape_of(node.input)
        want = node.in_shape
        if tuple(in_shape) != tuple(want):
            raise ValueError(
                f"{node.name}: input {node.input!r} has shape {in_shape}, "
                f"GCONV dims imply {want} "
                f"({' '.join(d.pretty() for d in node.dims)})")
        if node.kernel is not None:
            k_shape = self.shape_of(node.kernel)
            want_k = node.k_shape
            if len(k_shape) != len(want_k):
                raise ValueError(
                    f"{node.name}: kernel {node.kernel!r} rank {len(k_shape)} "
                    f"!= {len(want_k)}")
            for a, b in zip(k_shape, want_k):
                if a != b and a != 1:
                    raise ValueError(
                        f"{node.name}: kernel {node.kernel!r} shape {k_shape} "
                        f"not broadcastable to {want_k}")
        out_shape = node.out_shape
        for op in tuple(node.pre) + tuple(node.post):
            if op.operand is None:
                continue
            o_shape = self.shape_of(op.operand)
            ref_shape = in_shape if op in node.pre else out_shape
            if len(o_shape) != len(ref_shape):
                raise ValueError(
                    f"{node.name}: operand {op.operand!r} rank mismatch "
                    f"{o_shape} vs {ref_shape}")
            for a, b in zip(o_shape, ref_shape):
                if a != b and a != 1:
                    raise ValueError(
                        f"{node.name}: operand {op.operand!r} shape {o_shape} "
                        f"not broadcastable to {ref_shape}")

    def validate(self):
        """Re-validate the whole chain (used after transformation passes)."""
        seen = set(self.inputs) | set(self.params)
        for name, node in self.nodes.items():
            for ref in self._refs(node):
                if ref not in seen:
                    raise ValueError(
                        f"{name} consumes {ref!r} before production")
            self._check_shapes(node)
            seen.add(name)
        for o in self.outputs:
            if o not in self.nodes:
                raise ValueError(f"output {o!r} is not a node")

    # ------------------------------------------------------------------
    # statistics (paper Table 1)
    # ------------------------------------------------------------------
    def stats(self) -> dict:
        macs = sum(n.macs for n in self.nodes.values())
        data = sum(n.out_elems for n in self.nodes.values())
        n_gconv = sum(1 for n in self.nodes.values() if isinstance(n, GConv))
        trad = sum(
            n.macs for name, n in self.nodes.items()
            if self.meta.get(name, {}).get("traditional", False))
        trad_data = sum(
            n.out_elems for name, n in self.nodes.items()
            if self.meta.get(name, {}).get("traditional", False))
        return dict(
            name=self.name,
            n_nodes=len(self.nodes),
            n_gconv=n_gconv,
            macs=macs,
            intermediate_elems=data,
            traditional_macs=trad,
            nontraditional_macs=macs - trad,
            traditional_elems=trad_data,
            nontraditional_elems=data - trad_data,
        )

    def pretty(self) -> str:
        lines = [f"Chain {self.name!r}  "
                 f"(inputs={list(self.inputs)}, params={len(self.params)}, "
                 f"nodes={len(self.nodes)})"]
        for name, node in self.nodes.items():
            if isinstance(node, Concat):
                lines.append(f"  {name}: concat(axis={node.axis}) "
                             f"{list(node.inputs)} -> {node.out_shape}")
            else:
                lines.append("  " + node.pretty())
        lines.append(f"  outputs: {self.outputs}")
        return "\n".join(lines)
