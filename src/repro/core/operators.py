"""GCONV operator registries (paper §3.1).

``pre``/``post`` are elementwise unary ops, optionally parameterized by a
scalar ``const`` or a broadcastable tensor ``operand`` (fusion, §4.3).
``main`` combines input and kernel parameter; ``reduce`` folds the Nks taps.

The TPU adaptation (DESIGN.md §2): GCONVs with main=mul/reduce=add run on the
MXU; every other combination runs on the VPU. The registry records the unit so
the cost model can price each GCONV correctly.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# pre/post unary operators: fn(x, const, operand) -> array
# ---------------------------------------------------------------------------
_EPS_DEFAULT = 1e-5


def _need_operand(name):
    raise ValueError(f"operator {name!r} requires an operand tensor")


UNARY: Dict[str, Callable] = {
    "id": lambda x, c, p: x,
    "neg": lambda x, c, p: -x,
    "abs": lambda x, c, p: jnp.abs(x),
    "square": lambda x, c, p: x * x,
    "sqrt": lambda x, c, p: jnp.sqrt(x),
    "recip": lambda x, c, p: 1.0 / x,
    "exp": lambda x, c, p: jnp.exp(x),
    "log": lambda x, c, p: jnp.log(x),
    "relu": lambda x, c, p: jnp.maximum(x, 0),
    "gtz": lambda x, c, p: (x > 0).astype(x.dtype),   # relu' (BP mask)
    "sigmoid": lambda x, c, p: jax.nn.sigmoid(x),
    "silu": lambda x, c, p: jax.nn.silu(x),
    "gelu": lambda x, c, p: jax.nn.gelu(x),
    "tanh": lambda x, c, p: jnp.tanh(x),
    # scalar-parameterized ("LUT"-class in the paper)
    "scale": lambda x, c, p: x * c,
    "add_const": lambda x, c, p: x + c,
    "pow": lambda x, c, p: x ** c,
    "rsqrt_eps": lambda x, c, p: jax.lax.rsqrt(x + (c if c is not None else _EPS_DEFAULT)),
    "leaky_relu": lambda x, c, p: jnp.where(x >= 0, x, x * c),
    "clip_max": lambda x, c, p: jnp.minimum(x, c),
    # tensor-parameterized (post-fusion pre/post ops, paper §4.3)
    "mul": lambda x, c, p: x * p if p is not None else _need_operand("mul"),
    "add": lambda x, c, p: x + p if p is not None else _need_operand("add"),
    "sub": lambda x, c, p: x - p if p is not None else _need_operand("sub"),
    "rsub": lambda x, c, p: p - x if p is not None else _need_operand("rsub"),
    "div": lambda x, c, p: x / p if p is not None else _need_operand("div"),
    "maximum": lambda x, c, p: jnp.maximum(x, p) if p is not None else _need_operand("maximum"),
}

# ---------------------------------------------------------------------------
# main operators: fn(input_window, kernel_param) -> array
# ---------------------------------------------------------------------------
MAIN: Dict[str, Callable] = {
    "mul": lambda i, k: i * k,
    "add": lambda i, k: i + k,
    "sub": lambda i, k: i - k,        # Table 2: FP2, BP4, BP5 use main='-'
    "rsub": lambda i, k: k - i,
    "max": lambda i, k: jnp.maximum(i, k),
    "min": lambda i, k: jnp.minimum(i, k),
    "sqdiff": lambda i, k: (i - k) * (i - k),
    "div": lambda i, k: i / k,
    # "none" handled by the evaluator: pass input through
}

# ---------------------------------------------------------------------------
# reduce operators: (associative fn, identity) — identity doubles as pad value
# ---------------------------------------------------------------------------
REDUCE: Dict[str, tuple] = {
    "add": (jnp.add, 0.0),
    "max": (jnp.maximum, -jnp.inf),
    "min": (jnp.minimum, jnp.inf),
    # "none": no reduction (all nks == 1)
}


def pad_value(reduce: str) -> float:
    if reduce == "none":
        return 0.0
    return REDUCE[reduce][1]


def apply_unary_seq(ops, x, operand_lookup: Optional[Callable] = None):
    """Apply a pre/post operator sequence. ``operand_lookup(op) -> array``
    resolves tensor operands (already broadcast to x's layout by the caller)."""
    for op in ops:
        fn = UNARY.get(op.name)
        if fn is None:
            raise KeyError(f"unknown unary operator {op.name!r}")
        p = operand_lookup(op) if (op.operand is not None and operand_lookup) else None
        x = fn(x, op.const, p)
    return x


def apply_main(name: str, i, k):
    fn = MAIN.get(name)
    if fn is None:
        raise KeyError(f"unknown main operator {name!r}")
    return fn(i, k)


def apply_reduce(name: str, x, axes):
    if name == "none":
        return x
    fn, _ = REDUCE[name]
    if name == "add":
        return jnp.sum(x, axis=axes)
    if name == "max":
        return jnp.max(x, axis=axes)
    if name == "min":
        return jnp.min(x, axis=axes)
    raise KeyError(name)


def unit_for(main: str, reduce: str) -> str:
    """TPU execution unit for an operator combo (cost model)."""
    return "mxu" if (main == "mul" and reduce == "add") else "vpu"
