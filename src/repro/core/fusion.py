"""Operation fusion (paper §4.3).

"We apply operation fusion by fusing the GCONVs with no *reduce* operator
into the pre, post or main operators of their consumer or producer. [...]
Since the outputs only need to be processed once, fusing to the post operator
is preferred. After fusion, the pre and post operators may have more than one
parameter."

A GCONV is *fusible* when it performs no reduction (all ``Nks==1``, reduce ==
'none') and no replication (all ``Nop==1`` — its output is elementwise in its
input). Two directions, tried in order:

  1. **producer-post** (preferred): if its input is a GCONV node whose sole
     consumer it is, its pre/main/post collapse into the producer's ``post``
     sequence (the elementwise kernel, if any, becomes a tensor-operand
     ``post`` op — this is how FP2's ``-mu`` rides on FP1's output path).
  2. **consumer-pre**: otherwise, if every consumer reads it as ``input``,
     its operation is replicated into each consumer's ``pre`` sequence
     (paper: "FP2 can be processed as the pre of FP3 and FP4").

Either way one intermediate tensor is never materialized in the global
buffer; the eliminated movement is returned for the Fig.-18-style benchmark.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

from .chain import Chain
from .gconv import GConv, Op

# main operators expressible as a unary op with a tensor operand
_MAIN_AS_UNARY = {"mul": "mul", "add": "add", "sub": "sub", "rsub": "rsub",
                  "div": "div", "max": "maximum"}


@dataclass
class FusionReport:
    before_len: int
    after_len: int
    fused: List[str]
    saved_elems: int
    # surviving node -> the fusible nodes absorbed into it (transitively).
    # The cycle-level simulator (repro.sim) uses these groups: members stream
    # tile-by-tile through their host's pre/post operators and never make a
    # global-buffer round trip. The compiled execution engine (repro.exec)
    # uses the same groups as its unit of dispatch: one group = one emitted
    # step whose member operations run as fused pre/post sequences.
    groups: Dict[str, List[str]] = field(default_factory=dict)

    @property
    def length_reduction(self) -> float:
        return 1.0 - self.after_len / max(1, self.before_len)


@dataclass(frozen=True)
class ExecGroup:
    """One execution partition of a fused chain: the surviving ``host`` node
    plus the fused nodes riding on its operator path. ``members`` is empty
    for nodes nothing was fused into (singleton groups)."""

    host: str
    members: Tuple[str, ...] = ()

    @property
    def size(self) -> int:
        return 1 + len(self.members)


def execution_partitions(chain: Chain, report: FusionReport) -> List[ExecGroup]:
    """Partition a *fused* chain into ordered execution groups.

    Every surviving node of ``chain`` yields exactly one group, in chain
    order; ``report.groups`` supplies the absorbed members. Note that
    consumer-``pre`` fusion replicates a node into each consumer, so a
    fused-away node may legitimately appear in several groups' members
    (the paper's "FP2 can be processed as the pre of FP3 *and* FP4").
    """
    return [ExecGroup(host=name,
                      members=tuple(report.groups.get(name, ())))
            for name in chain.nodes]


def _is_fusible(g: GConv) -> bool:
    if g.reduce != "none":
        return False
    if g.out_dtype is not None:
        # the node is a quantization point: its intermediate's dtype is
        # semantic, and riding on a neighbor's operator path would drop
        # the cast (the pre/post vocabulary carries no dtype change)
        return False
    if any(d.nks > 1 or d.nop > 1 for d in g.dims):
        return False
    if g.main != "none" and g.main not in _MAIN_AS_UNARY:
        return False
    return True


def _as_unary_ops(g: GConv) -> Tuple[Op, ...]:
    """The fusible GCONV's whole computation as a pre/post op sequence."""
    ops = tuple(g.pre)
    if g.main != "none":
        ops += (Op(_MAIN_AS_UNARY[g.main], operand=g.kernel),)
    ops += tuple(g.post)
    return ops


def fuse_chain(chain: Chain) -> Tuple[Chain, FusionReport]:
    """Return a new, fused chain plus the fusion report. Pure (input chain is
    not mutated); iterates to fixpoint."""
    import copy

    chain = copy.deepcopy(chain)
    before_len = len(chain.nodes)
    fused_names: List[str] = []
    saved = 0
    order = list(chain.nodes)
    positions = {n: i for i, n in enumerate(order)}
    groups: Dict[str, List[str]] = {}

    def absorb(host: str, name: str):
        """Record that ``name`` (and anything already fused into it) now
        rides on ``host``'s operator path."""
        members = groups.get(name, [])
        groups.setdefault(host, []).append(name)
        groups[host].extend(members)

    changed = True
    while changed:
        changed = False
        consumers = chain.consumers()
        for name in list(chain.nodes):
            node = chain.nodes.get(name)
            if node is None or not isinstance(node, GConv):
                continue
            if not _is_fusible(node):
                continue
            if name in chain.outputs:
                continue
            cons = consumers.get(name, [])
            if not cons:
                continue
            # never eliminate a tensor someone consumes as kernel/operand
            used_as_input_only = all(
                isinstance(chain.nodes[c], GConv)
                and chain.nodes[c].input == name
                and chain.nodes[c].kernel != name
                and all(op.operand != name for op in
                        tuple(chain.nodes[c].pre) + tuple(chain.nodes[c].post))
                for c in cons)
            if not used_as_input_only:
                continue
            unary = _as_unary_ops(node)
            # operand tensors must already exist before the fusion target
            producer = node.input
            # --- direction 1: fuse into producer's post --------------------
            prod_node = chain.nodes.get(producer)
            if (isinstance(prod_node, GConv)
                    and consumers.get(producer, []) == [name]
                    and producer not in chain.outputs
                    and tuple(chain.shape_of(producer)) == node.out_shape
                    and all(op.operand is None
                            or positions.get(op.operand, -1)
                            < positions[producer]
                            for op in unary)):
                prod_node.post = tuple(prod_node.post) + unary
                for c in cons:
                    cn = chain.nodes[c]
                    cn.input = producer  # type: ignore[union-attr]
                del chain.nodes[name]
                chain.meta.pop(name, None)
                absorb(producer, name)
                groups.pop(name, None)
                fused_names.append(f"{name}->post({producer})")
                saved += node.out_elems
                changed = True
                break
            # --- direction 2: fuse into every consumer's pre ---------------
            ok = all(
                positions.get(op.operand, -1) < positions[c]
                for c in cons for op in unary if op.operand is not None)
            same_shape = tuple(chain.shape_of(node.input)) == node.out_shape
            if ok and same_shape:
                for c in cons:
                    cn = chain.nodes[c]
                    cn.pre = unary + tuple(cn.pre)   # type: ignore
                    cn.input = node.input            # type: ignore
                    absorb(c, name)
                del chain.nodes[name]
                chain.meta.pop(name, None)
                groups.pop(name, None)
                fused_names.append(f"{name}->pre({','.join(cons)})")
                saved += node.out_elems
                changed = True
                break
        if changed:
            consumers = chain.consumers()
    chain.validate()
    return chain, FusionReport(before_len, len(chain.nodes),
                               fused_names, saved, groups)
