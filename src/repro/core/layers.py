"""Layer -> GCONV-chain decompositions (paper §3.2, Table 2).

Every function appends GCONV node(s) realizing one network layer to a
:class:`~repro.core.chain.Chain` and returns the output node name. The
decompositions follow the paper exactly where the paper gives them (batch
normalization FP1–FP4 / BP1–BP6 in Table 2; LRN/conv/pool per §3.1's examples)
and follow the same dependency-analysis recipe for the rest.

``traditional`` metadata marks the LeNet-era layers (conv/FC/maxpool/ReLU/
softmax) that CIP accelerators natively handle (paper §2.2); everything else
is a "non-traditional" layer that baseline CIPs must offload.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

from .chain import Chain, Concat, Movement
from .gconv import DimSpec, GConv, Op

# Default CNN layout: (B, C, H, W); 3-D CNNs use (B, C, T, H, W);
# LM chains use (B, T, C) or (B, H, Tq, Tk, D).


def _names(chain: Chain, base: str) -> str:
    """Fresh node-name prefix (multi-GCONV layers create '<base>.fpN' etc.)."""
    taken = list(chain.nodes) + list(chain.params) + list(chain.inputs)

    def clash(cand):
        return any(n == cand or n.startswith(cand + ".") for n in taken)

    if not clash(base):
        return base
    i = 1
    while clash(f"{base}_{i}"):
        i += 1
    return f"{base}_{i}"


def _elemwise_dims(names: Sequence[str], shape: Sequence[int]) -> Tuple[DimSpec, ...]:
    return tuple(DimSpec(name=n, ng=s) for n, s in zip(names, shape))


def _axis_names(rank: int) -> Tuple[str, ...]:
    if rank == 2:
        return ("B", "C")
    if rank == 3:
        return ("B", "T", "C")
    if rank == 4:
        return ("B", "C", "H", "W")
    if rank == 5:
        return ("B", "C", "T", "H", "W")
    return tuple(f"D{i}" for i in range(rank))


# ---------------------------------------------------------------------------
# traditional layers
# ---------------------------------------------------------------------------
def conv2d(chain: Chain, x: str, *, out_c: int, k: int, stride: int = 1,
           pad: int = 0, groups: int = 1, bias: bool = True,
           name: Optional[str] = None) -> str:
    """Standard/grouped/depthwise 2-D convolution as ONE GCONV (paper Fig. 5).

    Weight layout: ``(1, OC*ICg, kh, kw)`` — i.e. the standard
    ``(OC, ICg, kh, kw)`` tensor with the leading axes flattened into the C
    axis, which reads as ``(g, op, ks)`` per the GCONV kernel convention.
    """
    B, C, H, W = chain.shape_of(x)
    if C % groups:
        raise ValueError(f"C={C} not divisible by groups={groups}")
    if out_c % groups:
        raise ValueError(f"out_c={out_c} not divisible by groups={groups}")
    icg, ocg = C // groups, out_c // groups
    oh, pr_h = _slide(H, k, stride, pad, False)
    ow, pr_w = _slide(W, k, stride, pad, False)
    name = name or _names(chain, "conv")
    w = chain.add_param(f"{name}.w", (1, groups * ocg * icg, k, k))
    post = ()
    if bias:
        b = chain.add_param(f"{name}.b", (1, out_c, 1, 1))
        post = (Op("add", operand=b),)
    depthwise = (groups == C and ocg >= 1 and icg == 1)
    node = GConv(
        name=name,
        dims=(
            DimSpec("B", nopc=B),
            DimSpec("C", ng=groups, nop=ocg, nks=icg),
            DimSpec("H", nopc=oh, nks=k, stride=stride, pad=pad, pad_r=pr_h),
            DimSpec("W", nopc=ow, nks=k, stride=stride, pad=pad, pad_r=pr_w),
        ),
        input=x, kernel=w, main="mul", reduce="add", post=post)
    return chain.add(node, layer="depthwise_conv" if depthwise else "conv2d",
                     traditional=not depthwise)


def conv3d(chain: Chain, x: str, *, out_c: int, k: int, kt: int,
           stride: int = 1, stride_t: int = 1, pad: int = 0, pad_t: int = 0,
           bias: bool = True, name: Optional[str] = None) -> str:
    """3-D convolution (C3D): GCONV with an extra T dimension (paper §3.1)."""
    B, C, T, H, W = chain.shape_of(x)
    ot, pr_t = _slide(T, kt, stride_t, pad_t, False)
    oh, pr_h = _slide(H, k, stride, pad, False)
    ow, pr_w = _slide(W, k, stride, pad, False)
    name = name or _names(chain, "conv3d")
    w = chain.add_param(f"{name}.w", (1, out_c * C, kt, k, k))
    post = ()
    if bias:
        b = chain.add_param(f"{name}.b", (1, out_c, 1, 1, 1))
        post = (Op("add", operand=b),)
    node = GConv(
        name=name,
        dims=(
            DimSpec("B", nopc=B),
            DimSpec("C", nop=out_c, nks=C),
            DimSpec("T", nopc=ot, nks=kt, stride=stride_t, pad=pad_t, pad_r=pr_t),
            DimSpec("H", nopc=oh, nks=k, stride=stride, pad=pad, pad_r=pr_h),
            DimSpec("W", nopc=ow, nks=k, stride=stride, pad=pad, pad_r=pr_w),
        ),
        input=x, kernel=w, main="mul", reduce="add", post=post)
    return chain.add(node, layer="conv3d", traditional=False)


def fc(chain: Chain, x: str, *, out_f: int, bias: bool = True,
       name: Optional[str] = None) -> str:
    """Fully-connected layer: GCONV whose kernel covers the whole input."""
    B, C = chain.shape_of(x)
    name = name or _names(chain, "fc")
    w = chain.add_param(f"{name}.w", (1, out_f * C))
    post = ()
    if bias:
        b = chain.add_param(f"{name}.b", (1, out_f))
        post = (Op("add", operand=b),)
    node = GConv(
        name=name,
        dims=(DimSpec("B", nopc=B), DimSpec("C", nop=out_f, nks=C)),
        input=x, kernel=w, main="mul", reduce="add", post=post)
    return chain.add(node, layer="fc", traditional=True)


def linear(chain: Chain, x: str, *, out_f: int, bias: bool = False,
           name: Optional[str] = None) -> str:
    """Linear over the last axis of a rank-3 (B, T, C) tensor (LM layers)."""
    B, T, C = chain.shape_of(x)
    name = name or _names(chain, "linear")
    w = chain.add_param(f"{name}.w", (1, 1, out_f * C))
    post = ()
    if bias:
        b = chain.add_param(f"{name}.b", (1, 1, out_f))
        post = (Op("add", operand=b),)
    node = GConv(
        name=name,
        dims=(DimSpec("B", ng=B), DimSpec("T", nopc=T),
              DimSpec("C", nop=out_f, nks=C)),
        input=x, kernel=w, main="mul", reduce="add", post=post)
    return chain.add(node, layer="linear", traditional=True)


def activation(chain: Chain, x: str, fn: str = "relu", const: float = None,
               name: Optional[str] = None) -> str:
    shape = chain.shape_of(x)
    names = _axis_names(len(shape))
    name = name or _names(chain, fn)
    node = GConv(name=name, dims=_elemwise_dims(names, shape), input=x,
                 main="none", reduce="none", post=(Op(fn, const=const),))
    return chain.add(node, layer=fn, traditional=(fn == "relu"))


def relu(chain: Chain, x: str, name: Optional[str] = None) -> str:
    return activation(chain, x, "relu", name=name)


def _slide(size: int, k: int, stride: int, pad: int, ceil_mode: bool):
    """Output count + right padding for possibly-inexact sliding geometry."""
    num = size + 2 * pad - k
    n_out = (-(-num // stride) if ceil_mode else num // stride) + 1
    span = (n_out - 1) * stride + k
    pad_r = span - size - pad           # may differ from pad; may be negative
    return n_out, pad_r


def _pool(chain: Chain, x: str, k, stride, pad, mode: str, kt=None,
          stride_t=None, ceil_mode=False, name=None) -> str:
    shape = chain.shape_of(x)
    rank = len(shape)
    name = name or _names(chain, f"{mode}pool")
    if rank == 4:
        B, C, H, W = shape
        oh, pr_h = _slide(H, k, stride, pad, ceil_mode)
        ow, pr_w = _slide(W, k, stride, pad, ceil_mode)
        dims = (DimSpec("B", ng=B), DimSpec("C", ng=C),
                DimSpec("H", nopc=oh, nks=k, stride=stride, pad=pad, pad_r=pr_h),
                DimSpec("W", nopc=ow, nks=k, stride=stride, pad=pad, pad_r=pr_w))
        win = k * k
        layer = f"{mode}pool2d"
        traditional = (mode == "max")
    else:
        B, C, T, H, W = shape
        kt = kt or k
        stride_t = stride_t or stride
        ot, pr_t = _slide(T, kt, stride_t, 0, ceil_mode)
        oh, pr_h = _slide(H, k, stride, pad, ceil_mode)
        ow, pr_w = _slide(W, k, stride, pad, ceil_mode)
        dims = (DimSpec("B", ng=B), DimSpec("C", ng=C),
                DimSpec("T", nopc=ot, nks=kt, stride=stride_t, pad_r=pr_t),
                DimSpec("H", nopc=oh, nks=k, stride=stride, pad=pad, pad_r=pr_h),
                DimSpec("W", nopc=ow, nks=k, stride=stride, pad=pad, pad_r=pr_w))
        win = k * k * kt
        layer = f"{mode}pool3d"
        traditional = False
    post = (Op("scale", const=1.0 / win),) if mode == "avg" else ()
    node = GConv(name=name, dims=dims, input=x, main="none",
                 reduce="max" if mode == "max" else "add", post=post)
    return chain.add(node, layer=layer, traditional=traditional)


def maxpool2d(chain, x, *, k, stride, pad=0, ceil_mode=False, name=None) -> str:
    return _pool(chain, x, k, stride, pad, "max", ceil_mode=ceil_mode, name=name)


def avgpool2d(chain, x, *, k, stride, pad=0, ceil_mode=False, name=None) -> str:
    return _pool(chain, x, k, stride, pad, "avg", ceil_mode=ceil_mode, name=name)


def maxpool3d(chain, x, *, k, stride, kt, stride_t, pad=0, name=None) -> str:
    return _pool(chain, x, k, stride, pad, "max", kt=kt, stride_t=stride_t,
                 name=name)


def global_avgpool2d(chain, x, name=None) -> str:
    _, _, H, W = chain.shape_of(x)
    return _pool(chain, x, H, 1, 0, "avg", name=name)


def softmax(chain: Chain, x: str, axis: int = -1,
            name: Optional[str] = None) -> str:
    """Softmax over one axis: 4 GCONVs (max, sub+exp, sum, div)."""
    shape = chain.shape_of(x)
    rank = len(shape)
    axis = axis % rank
    names = _axis_names(rank)
    name = name or _names(chain, "softmax")

    def dims(reduce_axis: bool):
        out = []
        for i, (n, s) in enumerate(zip(names, shape)):
            if i == axis and reduce_axis:
                out.append(DimSpec(n, nks=s))
            else:
                out.append(DimSpec(n, ng=s))
        return tuple(out)

    m = chain.add(GConv(name=f"{name}.max", dims=dims(True), input=x,
                        main="none", reduce="max"),
                  layer="softmax", traditional=True)
    e = chain.add(GConv(name=f"{name}.exp", dims=dims(False), input=x,
                        kernel=m, main="sub", reduce="none",
                        post=(Op("exp"),)),
                  layer="softmax", traditional=True)
    s = chain.add(GConv(name=f"{name}.sum", dims=dims(True), input=e,
                        main="none", reduce="add"),
                  layer="softmax", traditional=True)
    node = GConv(name=name, dims=dims(False), input=e, kernel=s,
                 main="div", reduce="none")
    return chain.add(node, layer="softmax", traditional=True)


# ---------------------------------------------------------------------------
# non-traditional layers
# ---------------------------------------------------------------------------
def lrn(chain: Chain, x: str, *, n: int = 5, alpha: float = 1e-4,
        beta: float = 0.75, k_const: float = 2.0,
        name: Optional[str] = None) -> str:
    """Local response normalization (AlexNet): GCONV in the C dimension
    (paper §1: "LRN can be viewed as a general convolution in the channel
    dimension"). b = a / (k + (alpha/n) * sum_window a^2)^beta."""
    B, C, H, W = chain.shape_of(x)
    assert n % 2 == 1
    name = name or _names(chain, "lrn")
    denom = chain.add(
        GConv(name=f"{name}.den",
              dims=(DimSpec("B", ng=B),
                    DimSpec("C", nopc=C, nks=n, pad=n // 2),
                    DimSpec("H", ng=H), DimSpec("W", ng=W)),
              input=x, main="none", reduce="add",
              pre=(Op("square"),),
              post=(Op("scale", const=alpha / n),
                    Op("add_const", const=k_const),
                    Op("pow", const=-beta))),
        layer="lrn", traditional=False)
    node = GConv(name=name, dims=_elemwise_dims(("B", "C", "H", "W"),
                                                (B, C, H, W)),
                 input=x, kernel=denom, main="mul", reduce="none")
    return chain.add(node, layer="lrn", traditional=False)


def dropout(chain: Chain, x: str, rate: float = 0.5,
            name: Optional[str] = None) -> str:
    """Training-mode dropout: elementwise multiply with a mask tensor
    (the mask is a chain input — RNG happens outside the accelerator)."""
    shape = chain.shape_of(x)
    names = _axis_names(len(shape))
    name = name or _names(chain, "dropout")
    mask = chain.add_input(f"{name}.mask", shape)
    node = GConv(name=name, dims=_elemwise_dims(names, shape), input=x,
                 kernel=mask, main="mul", reduce="none",
                 post=(Op("scale", const=1.0 / (1.0 - rate)),))
    return chain.add(node, layer="dropout", traditional=False)


def batch_norm_fp(chain: Chain, x: str, eps: float = 1e-5,
                  name: Optional[str] = None,
                  spatial: bool = False) -> Tuple[str, dict]:
    """Batch normalization forward, paper Table 2 FP1–FP4 (exact).

    ``spatial=False`` reproduces Table 2 literally (statistics over the batch
    dimension only — per-activation normalization). ``spatial=True`` also
    reduces H/W (the convnet-usual per-channel statistics); the GCONV
    decomposition is identical, with Nks instead of Nopc on H/W in FP1/FP3.
    Returns (output node, dict of intermediate node names FP1..FP4).
    """
    B, C, H, W = chain.shape_of(x)
    name = name or _names(chain, "bn")
    nred = B * (H * W if spatial else 1)

    def stat_dims():
        # FP1/FP3 rows of Table 2: [Nks: Nbs] in B; Nopc elsewhere.
        if spatial:
            return (DimSpec("B", nks=B), DimSpec("C", nopc=C),
                    DimSpec("H", nks=H), DimSpec("W", nks=W))
        return (DimSpec("B", nks=B), DimSpec("C", nopc=C),
                DimSpec("H", nopc=H), DimSpec("W", nopc=W))

    def bcast_dims():
        # FP2/FP4 rows: [Nopc: Nbs] in B; Ng elsewhere.
        return (DimSpec("B", nopc=B), DimSpec("C", ng=C),
                DimSpec("H", ng=H), DimSpec("W", ng=W))

    fp1 = chain.add(GConv(name=f"{name}.fp1", dims=stat_dims(), input=x,
                          main="none", reduce="add",
                          post=(Op("scale", const=1.0 / nred),)),
                    layer="batchnorm", traditional=False)        # mu
    fp2 = chain.add(GConv(name=f"{name}.fp2", dims=bcast_dims(), input=x,
                          kernel=fp1, main="sub", reduce="none"),
                    layer="batchnorm", traditional=False)        # t1 = I - mu
    fp3 = chain.add(GConv(name=f"{name}.fp3", dims=stat_dims(), input=fp2,
                          pre=(Op("square"),), main="none", reduce="add",
                          post=(Op("scale", const=1.0 / nred),
                                Op("rsqrt_eps", const=eps))),
                    layer="batchnorm", traditional=False)        # t2
    fp4 = chain.add(GConv(name=f"{name}.fp4", dims=bcast_dims(), input=fp2,
                          kernel=fp3, main="mul", reduce="none"),
                    layer="batchnorm", traditional=False)        # O
    return fp4, dict(fp1=fp1, fp2=fp2, fp3=fp3, fp4=fp4)


def batch_norm_bp(chain: Chain, g_out: str, fp: dict,
                  name: Optional[str] = None,
                  spatial: bool = False) -> Tuple[str, dict]:
    """Batch normalization backward, paper Table 2 BP1–BP6 + Eq. (5).

    ``g_out`` is the upstream gradient gO; ``fp`` is the dict returned by
    :func:`batch_norm_fp` (needs fp3 = 1/sqrt(var+eps) and fp4 = O).
    """
    B, C, H, W = chain.shape_of(g_out)
    name = name or _names(chain, "bn_bp")
    nred = B * (H * W if spatial else 1)

    def stat_dims():
        if spatial:
            return (DimSpec("B", nks=B), DimSpec("C", nopc=C),
                    DimSpec("H", nks=H), DimSpec("W", nks=W))
        return (DimSpec("B", nks=B), DimSpec("C", nopc=C),
                DimSpec("H", nopc=H), DimSpec("W", nopc=W))

    def kstat_dims():
        # Table 2 BP1 row: [Nks:Nbs][Ng:Nic][Ng:Nix][Ng:Niy] — with a kernel
        # the per-position independence is groups, so the kernel (= FP4 = O)
        # varies across C/H/W while the taps reduce the batch.
        if spatial:
            return (DimSpec("B", nks=B), DimSpec("C", ng=C),
                    DimSpec("H", nks=H), DimSpec("W", nks=W))
        return (DimSpec("B", nks=B), DimSpec("C", ng=C),
                DimSpec("H", ng=H), DimSpec("W", ng=W))

    def bcast_dims():
        return (DimSpec("B", nopc=B), DimSpec("C", ng=C),
                DimSpec("H", ng=H), DimSpec("W", ng=W))

    def elem_dims():
        return (DimSpec("B", ng=B), DimSpec("C", ng=C),
                DimSpec("H", ng=H), DimSpec("W", ng=W))

    bp1 = chain.add(GConv(name=f"{name}.bp1", dims=kstat_dims(), input=g_out,
                          kernel=fp["fp4"], main="mul", reduce="add",
                          post=(Op("scale", const=1.0 / nred),)),
                    layer="batchnorm_bp", traditional=False)  # t3
    bp2 = chain.add(GConv(name=f"{name}.bp2", dims=bcast_dims(),
                          input=fp["fp4"], kernel=bp1, main="mul",
                          reduce="none"),
                    layer="batchnorm_bp", traditional=False)  # t4 = O*t3
    bp3 = chain.add(GConv(name=f"{name}.bp3", dims=stat_dims(), input=g_out,
                          main="none", reduce="add",
                          post=(Op("scale", const=1.0 / nred),)),
                    layer="batchnorm_bp", traditional=False)  # t5
    bp4 = chain.add(GConv(name=f"{name}.bp4", dims=bcast_dims(), input=g_out,
                          kernel=bp3, main="sub", reduce="none"),
                    layer="batchnorm_bp", traditional=False)  # t6 = gO - t5
    bp5 = chain.add(GConv(name=f"{name}.bp5", dims=elem_dims(), input=bp4,
                          kernel=bp2, main="sub", reduce="none"),
                    layer="batchnorm_bp", traditional=False)  # t7 = t6 - t4
    bp6 = chain.add(GConv(name=f"{name}.bp6", dims=elem_dims(), input=bp5,
                          kernel=fp["fp3"], main="mul", reduce="none"),
                    layer="batchnorm_bp", traditional=False)  # gI = t7 * t2
    return bp6, dict(bp1=bp1, bp2=bp2, bp3=bp3, bp4=bp4, bp5=bp5, bp6=bp6)


def scale_layer(chain: Chain, x: str, name: Optional[str] = None) -> str:
    """Caffe Scale layer (DenseNet): per-channel y = gamma*x + beta."""
    B, C, H, W = chain.shape_of(x)
    name = name or _names(chain, "scale")
    gamma = chain.add_param(f"{name}.gamma", (1, C, 1, 1))
    beta = chain.add_param(f"{name}.beta", (1, C, 1, 1))
    node = GConv(name=name,
                 dims=(DimSpec("B", nopc=B), DimSpec("C", ng=C),
                       DimSpec("H", ng=H), DimSpec("W", ng=W)),
                 input=x, kernel=gamma, main="mul", reduce="none",
                 post=(Op("add", operand=beta),))
    return chain.add(node, layer="scale", traditional=False)


def add_tensors(chain: Chain, a: str, b: str, name: Optional[str] = None,
                layer: str = "add", traditional: bool = False) -> str:
    """Elementwise residual add: GCONV with main=add, kernel = other tensor."""
    shape = chain.shape_of(a)
    names = _axis_names(len(shape))
    name = name or _names(chain, "add")
    node = GConv(name=name, dims=_elemwise_dims(names, shape), input=a,
                 kernel=b, main="add", reduce="none")
    return chain.add(node, layer=layer, traditional=traditional)


def mul_tensors(chain: Chain, a: str, b: str, name: Optional[str] = None,
                layer: str = "mul", traditional: bool = False) -> str:
    shape = chain.shape_of(a)
    names = _axis_names(len(shape))
    name = name or _names(chain, "mul")
    node = GConv(name=name, dims=_elemwise_dims(names, shape), input=a,
                 kernel=b, main="mul", reduce="none")
    return chain.add(node, layer=layer, traditional=traditional)


def concat(chain: Chain, xs: Sequence[str], axis: int = 1,
           name: Optional[str] = None) -> str:
    name = name or _names(chain, "concat")
    return chain.add(Concat(name=name, inputs=tuple(xs), axis=axis),
                     layer="concat", traditional=False)


def view(chain: Chain, x: str, out_shape: Sequence[int],
         perm: Optional[Sequence[int]] = None,
         pre_shape: Optional[Sequence[int]] = None,
         name: Optional[str] = None) -> str:
    name = name or _names(chain, "view")
    return chain.add(Movement(name=name, input=x,
                              perm=tuple(perm) if perm else None,
                              pre_shape=tuple(pre_shape) if pre_shape
                              else None,
                              out_shape=tuple(out_shape)),
                     layer="view", traditional=True)


# ---------------------------------------------------------------------------
# LM-era layers (framework integration; DESIGN.md §3)
# ---------------------------------------------------------------------------
def rms_norm(chain: Chain, x: str, eps: float = 1e-6,
             name: Optional[str] = None) -> str:
    """RMSNorm: 2 GCONVs (square-mean-rsqrt; scale) + learned gamma."""
    B, T, C = chain.shape_of(x)
    name = name or _names(chain, "rmsnorm")
    gamma = chain.add_param(f"{name}.gamma", (1, 1, C))
    denom = chain.add(
        GConv(name=f"{name}.ms",
              dims=(DimSpec("B", ng=B), DimSpec("T", ng=T),
                    DimSpec("C", nks=C)),
              input=x, pre=(Op("square"),), main="none", reduce="add",
              post=(Op("scale", const=1.0 / C), Op("rsqrt_eps", const=eps))),
        layer="rmsnorm", traditional=False)
    node = GConv(name=name,
                 dims=(DimSpec("B", ng=B), DimSpec("T", ng=T),
                       DimSpec("C", ng=C)),
                 input=x, kernel=denom, main="mul", reduce="none",
                 post=(Op("mul", operand=gamma),))
    return chain.add(node, layer="rmsnorm", traditional=False)


def attention_scores(chain: Chain, q: str, k: str, scale: float,
                     name: Optional[str] = None) -> str:
    """QK^T as a 5-D GCONV. q: (B,H,Tq,1,D) kernel view; k: (B,H,1,Tk,D).

    Dims: B[Ng], H[Ng], Tq[Nop], Tk[Nopc], D[Nks]; input=K, kernel=Q —
    exactly the paper's "kernel covers the entire input" FC pattern, with the
    query axis playing Nop and the key axis playing Nopc.
    """
    Bq, Hq, Tq, oneq, D = chain.shape_of(q)
    Bk, Hk, onek, Tk, Dk = chain.shape_of(k)
    assert (Bq, Hq, D) == (Bk, Hk, Dk) and oneq == 1 and onek == 1
    name = name or _names(chain, "scores")
    node = GConv(
        name=name,
        dims=(DimSpec("B", ng=Bq), DimSpec("H", ng=Hq),
              DimSpec("Tq", nop=Tq), DimSpec("Tk", nopc=Tk),
              DimSpec("D", nks=D)),
        input=k, kernel=q, main="mul", reduce="add",
        post=(Op("scale", const=scale),))
    return chain.add(node, layer="attention", traditional=True)


def attention_values(chain: Chain, probs: str, v: str,
                     name: Optional[str] = None) -> str:
    """P @ V as a 5-D GCONV: input=probs (B,H,Tq,Tk,1), kernel=V (B,H,1,Tk,D).

    Dims: B[Ng], H[Ng], Tq[Ng], Tk[Nks], D[Nop]: per (b,h,tq) the kernel's
    D-many taps reduce over the key axis.
    """
    B, H, Tq, Tk, one = chain.shape_of(probs)
    Bv, Hv, onev, Tkv, D = chain.shape_of(v)
    assert (B, H, Tk) == (Bv, Hv, Tkv) and one == 1 and onev == 1
    name = name or _names(chain, "attnv")
    node = GConv(
        name=name,
        dims=(DimSpec("B", ng=B), DimSpec("H", ng=H), DimSpec("Tq", ng=Tq),
              DimSpec("Tk", nks=Tk), DimSpec("D", nop=D)),
        input=probs, kernel=v, main="mul", reduce="add")
    return chain.add(node, layer="attention", traditional=True)
