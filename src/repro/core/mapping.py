"""GCONV mapping (paper §4.1, Algorithm 1) generalized over accelerators.

The mapper unrolls the 4-loops-per-dimension nest of a GCONV
  * **spatially** onto the accelerator's spatial unrolling dimensions
    (PE-array axes; which loop goes to which axis decides parallel reuse and
    whether the axis' special function — reduce links, output bandwidth,
    overlap primitive — is exploited), and
  * **temporally** into the local scratchpads (deciding per-PE data reuse).

Faithful to Algorithm 1: overlap-reuse primitives are allocated first to any
dimension with overlap-reuse (not hardwired to W/H); then spatial dims fill by
their per-accelerator parameter priority; then temporal unrolling fills the
scratchpads; remaining loops are appended outside the reuse pointers. Per
§4.4, different accelerators only change the priorities and resources.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from .accelerators import AcceleratorSpec
from .gconv import GConv

PARAMS = ("ks", "opc", "op", "g")
# Algorithm 1 iterates dimensions in ["W","H","C","B"] order; we generalize to
# "reversed axis order" (innermost/fastest-varying first) for N-D GCONVs.


class MappingError(ValueError):
    """A :class:`Mapping` violates the accelerator's resource limits or the
    GCONV's loop structure (raised by :meth:`Mapping.validate`)."""


@dataclass(frozen=True)
class Entry:
    param: str          # 'ks' | 'opc' | 'op' | 'g'
    dim: str            # dimension name
    factor: int
    where: str          # spatial dim name, or "T" for temporal
    sliding: bool = False   # overlap-reuse primitive: loads s new inputs/step

    def pretty(self) -> str:
        tag = "~" if self.sliding else ""
        return f"[{self.param},{self.dim},{self.factor}]{tag}@{self.where}"


def _loop_counts(g: GConv) -> Dict[str, Dict[str, int]]:
    return {d.name: {"g": d.ng, "op": d.nop, "opc": d.nopc, "ks": d.nks}
            for d in g.dims}


def _dim_order(g: GConv) -> List[str]:
    return [d.name for d in reversed(g.dims)]


def factors_by(entries: Sequence[Entry]) -> Dict[Tuple[str, str], int]:
    """(param, dim) -> product of unrolling factors."""
    out: Dict[Tuple[str, str], int] = {}
    for e in entries:
        key = (e.param, e.dim)
        out[key] = out.get(key, 1) * e.factor
    return out


def tile_sizes(entries: Sequence[Entry], g: GConv) -> Dict[str, int]:
    """Paper Table 3: data footprint of a set of unrollings, per data type."""
    f = factors_by(entries)
    I = K = O = 1
    for d in g.dims:
        pg = f.get(("g", d.name), 1)
        pop = f.get(("op", d.name), 1)
        popc = f.get(("opc", d.name), 1)
        pks = f.get(("ks", d.name), 1)
        I *= pg * (pks + d.stride * (popc - 1))
        K *= pg * pop * pks
        O *= pg * pop * popc
    return {"I": I, "K": K, "O": O}


# which data types grow when unrolling parameter p (Table 3 reuse columns)
_AFFECTS = {"ks": ("I", "K"), "opc": ("I", "O"), "op": ("K", "O"),
            "g": ("I", "K", "O")}


@dataclass(frozen=True)
class TileStructure:
    """Per-data-type resident-tile decomposition of a :class:`Mapping`.

    This is the structure the cycle-level simulator (``repro.sim``) lowers
    into an ordered tile trace. Every quantity follows :meth:`Mapping.movement`
    / Eqs. (7)-(10) exactly, so trace aggregates reproduce the analytic
    movement word-for-word:

      * the node executes ``n_steps`` tile steps of ``compute_per_step``
        cycles each (the temporal loops inside the innermost reuse pointer);
      * data type ``d`` refills its buffers every ``strides[d]`` steps with
        ``tile_words[d]`` words, ``reloads[d]`` times over the node, hence
        ``tile_words[d] * reloads[d] == movement()[d]`` and
        ``strides[d] * reloads[d] == n_steps``.

    Strides form a divisibility chain (each is a product of a prefix of the
    outer temporal factors), which the trace scheduler exploits to aggregate
    arbitrarily long traces without enumeration.
    """

    pointers: Dict[str, int]       # per-dtype reuse pointer into ``temporal``
    tile_words: Dict[str, int]     # words per refill (I/K) or drain (O)
    reloads: Dict[str, int]        # refills/drains over the whole node
    strides: Dict[str, int]        # tile steps between consecutive refills
    n_steps: int                   # total tile steps of the node
    compute_per_step: int          # cycles per tile step


@dataclass
class Mapping:
    gconv: GConv
    spec: AcceleratorSpec
    spatial: List[Entry] = field(default_factory=list)
    temporal: List[Entry] = field(default_factory=list)   # innermost first

    # ------------------------------------------------------------------
    @classmethod
    def from_entries(cls, gconv: GConv, spec: AcceleratorSpec,
                     spatial: Sequence[Entry] = (),
                     temporal: Sequence[Entry] = (),
                     validate: bool = True) -> "Mapping":
        """Build a mapping from externally-supplied unrolling entries (e.g. a
        design-space-explorer candidate) through the same resource-limit
        checks :func:`map_gconv` runs on its own output."""
        m = cls(gconv=gconv, spec=spec,
                spatial=list(spatial), temporal=list(temporal))
        if validate:
            m.validate()
        return m

    def clone(self) -> "Mapping":
        """Entry-list copy (loop exchange mutates mappings in place)."""
        return Mapping(gconv=self.gconv, spec=self.spec,
                       spatial=list(self.spatial),
                       temporal=list(self.temporal))

    def validate(self) -> "Mapping":
        """Check resource limits and loop coverage; raise :class:`MappingError`.

        One shared code path for every mapping source — Algorithm 1 calls it
        on its own output and ``repro.dse`` candidates go through
        :meth:`from_entries` — so externally-supplied mappings cannot bypass
        the checks the mapper enforces:

          * every entry names a known GCONV dimension and loop parameter;
          * spatial entries target existing array axes and their combined
            unrolling never exceeds an axis' PE count;
          * temporal entries live at ``where == "T"``; sliding (overlap
            primitive) entries are temporal ``opc`` streams;
          * every loop is fully covered: the product of all factors for a
            ``(param, dim)`` reaches the GCONV's loop count (ceil-division
            nests compose, so factor order is immaterial).

        Scratchpad capacity needs no check here: entries whose prefix tile
        overflows a scratchpad simply sit outside the reuse pointer and
        stream from the GB (:meth:`pointer`), which is costed, not illegal.
        """
        axis_size = {s.name: s.size for s in self.spec.spatial}
        known = {d.name for d in self.gconv.dims}
        used: Dict[str, int] = {}
        for e in self.spatial:
            if e.param not in PARAMS:
                raise MappingError(f"{e.pretty()}: unknown param {e.param!r}")
            if e.dim not in known:
                raise MappingError(f"{e.pretty()}: unknown dim {e.dim!r}")
            if e.factor < 1:
                raise MappingError(f"{e.pretty()}: factor must be >= 1")
            if e.where not in axis_size:
                raise MappingError(
                    f"{e.pretty()}: no spatial axis {e.where!r} on "
                    f"{self.spec.name}")
            if e.sliding:
                raise MappingError(
                    f"{e.pretty()}: sliding entries are temporal")
            used[e.where] = used.get(e.where, 1) * e.factor
        for axis, u in used.items():
            if u > axis_size[axis]:
                raise MappingError(
                    f"spatial axis {axis!r}: unrolled {u} > {axis_size[axis]} "
                    f"PEs on {self.spec.name}")
        for e in self.temporal:
            if e.param not in PARAMS:
                raise MappingError(f"{e.pretty()}: unknown param {e.param!r}")
            if e.dim not in known:
                raise MappingError(f"{e.pretty()}: unknown dim {e.dim!r}")
            if e.factor < 1:
                raise MappingError(f"{e.pretty()}: factor must be >= 1")
            if e.where != "T":
                raise MappingError(
                    f"{e.pretty()}: temporal entries must be @T")
            if e.sliding and e.param != "opc":
                raise MappingError(
                    f"{e.pretty()}: only opc entries slide (overlap reuse)")
        f = factors_by(list(self.spatial) + list(self.temporal))
        for d in self.gconv.dims:
            for p, n in (("g", d.ng), ("op", d.nop),
                         ("opc", d.nopc), ("ks", d.nks)):
                have = f.get((p, d.name), 1)
                if have < n:
                    raise MappingError(
                        f"loop ({p},{d.name}) of {self.gconv.name}: unrolling "
                        f"covers {have} of {n} iterations")
        return self

    @property
    def spatial_factors(self) -> Dict[Tuple[str, str], int]:
        return factors_by(self.spatial)

    def cycles(self) -> int:
        """Paper Eq. (6): computation cycles from spatial unrolling."""
        sp = self.spatial_factors
        cyc = 1
        for d in self.gconv.dims:
            for p in PARAMS:
                n = {"g": d.ng, "op": d.nop, "opc": d.nopc, "ks": d.nks}[p]
                cyc *= math.ceil(n / sp.get((p, d.name), 1))
        return cyc

    def pe_utilization(self) -> float:
        used = 1
        for e in self.spatial:
            used *= e.factor
        return used / self.spec.n_pes

    def pointer(self, dtype: str) -> int:
        """Index of the last temporal entry whose prefix tile still fits the
        ``dtype`` scratchpad (paper's ilst/olst/klst). -1 if even the first
        entry overflows; sliding entries count as inside (they stream)."""
        cap = self.spec.ls[dtype]
        ptr = -1
        for i in range(len(self.temporal)):
            e = self.temporal[i]
            if e.sliding and dtype == "I":
                ptr = i
                continue
            tile = tile_sizes(
                [t for t in self.temporal[: i + 1]
                 if not (t.sliding and dtype == "I")], self.gconv)[dtype]
            if tile <= cap:
                ptr = i
            else:
                break
        return ptr

    def movement(self) -> Dict[str, int]:
        """Paper Eqs. (7)-(10): GB<->array words moved per data type.

        Derived from :meth:`tile_structure` so the analytic totals and the
        cycle-level simulator's tile trace share one source of truth."""
        ts = self.tile_structure()
        return {d: ts.reloads[d] * ts.tile_words[d]              # Eq. (10)
                for d in ("I", "K", "O")}

    def tile_structure(self) -> TileStructure:
        """Lower the temporal nest into the per-dtype tile structure used by
        the cycle-level simulator (``repro.sim.schedule``).

        The tile-step boundary is the innermost reuse pointer across the
        three data types: everything inside it is one tile's compute;
        everything outside it is the ordered tile iteration space.
        """
        sp_tiles = tile_sizes(self.spatial, self.gconv)
        ptrs: Dict[str, int] = {}
        words: Dict[str, int] = {}
        reloads: Dict[str, int] = {}
        for dtype in ("I", "K", "O"):
            ptr = self.pointer(dtype)
            in_tile = tile_sizes(self.temporal[: ptr + 1], self.gconv)[dtype]
            r = 1
            for e in self.temporal[ptr + 1:]:
                r *= e.factor                            # Eq. (8)
            ptrs[dtype] = ptr
            words[dtype] = sp_tiles[dtype] * in_tile     # Eq. (10) per refill
            reloads[dtype] = r
        pmin = min(ptrs.values())
        n_steps = 1
        for e in self.temporal[pmin + 1:]:
            n_steps *= e.factor
        compute = 1
        for e in self.temporal[: pmin + 1]:
            compute *= e.factor
        strides = {d: n_steps // reloads[d] for d in reloads}
        return TileStructure(pointers=ptrs, tile_words=words,
                             reloads=reloads, strides=strides,
                             n_steps=n_steps, compute_per_step=compute)

    def load_cycles(self, load_width: Dict[str, int] = None) -> Dict[str, float]:
        mov = self.movement()
        lw = load_width or {}
        out = {}
        for dtype, m in mov.items():
            bw = self.spec.gb_bandwidth.get(dtype, 1)
            out[dtype] = m / max(1, min(bw, lw.get(dtype, bw)))
        return out

    def latency(self, load_width: Dict[str, int] = None) -> float:
        """max(compute, per-type load) — systolic load/compute overlap."""
        return max(self.cycles(), *self.load_cycles(load_width).values())

    def pretty(self) -> str:
        sp = " ".join(e.pretty() for e in self.spatial)
        tp = " ".join(e.pretty() for e in self.temporal)
        return (f"{self.gconv.name}@{self.spec.name}: spatial[{sp}] "
                f"temporal[{tp}] cycles={self.cycles()}")


# ---------------------------------------------------------------------------
# Algorithm 1
# ---------------------------------------------------------------------------
def map_gconv(g: GConv, spec: AcceleratorSpec) -> Mapping:
    m = Mapping(gconv=g, spec=spec)
    loops = _loop_counts(g)
    dims = {d.name: d for d in g.dims}
    order = _dim_order(g)
    remaining = {s.name: s.size for s in spec.spatial}

    def unroll_spatial(sname: str, p: str, d: str,
                       insert_at: Optional[int] = None) -> int:
        uf = min(remaining[sname], loops[d][p])
        if uf <= 1:
            return 0
        loops[d][p] = math.ceil(loops[d][p] / uf)
        remaining[sname] = remaining[sname] // uf
        e = Entry(p, d, uf, sname)
        if insert_at is None:
            m.spatial.append(e)
        else:
            m.spatial.insert(insert_at, e)
        return uf

    def ls_max_factor(p: str, d: str, prefix: List[Entry]) -> int:
        """Largest factor of Loop[d][p] whose temporal tile fits every
        affected scratchpad (binary search; Table 3 is monotone in f)."""
        hi = loops[d][p]
        if hi <= 1:
            return 0
        lo_ok = 0
        lo, hicur = 1, hi
        while lo <= hicur:
            mid = (lo + hicur) // 2
            cand = prefix + [Entry(p, d, mid, "T")]
            tiles = tile_sizes(cand, g)
            if all(tiles[t] <= spec.ls[t] for t in _AFFECTS[p]):
                lo_ok = mid
                lo = mid + 1
            else:
                hicur = mid - 1
        return lo_ok

    # ---- Lines 7-13: overlap-reuse primitives -----------------------------
    overlap_dims = [d for d in order if dims[d].has_overlap_reuse]
    sliding_entries: List[Entry] = []
    if spec.has_overlap_primitive and overlap_dims:
        ov_spatial = [s for s in spec.spatial if s.overlap]
        d0 = overlap_dims[0]
        if len(ov_spatial) >= 2:
            # Eyeriss-style: ks vertically (reduce links), opc horizontally
            red = next((s for s in ov_spatial if s.reduce), ov_spatial[0])
            oth = next((s for s in ov_spatial if s.name != red.name),
                       ov_spatial[-1])
            unroll_spatial(red.name, "ks", d0)
            unroll_spatial(oth.name, "opc", d0)
        else:
            unroll_spatial(ov_spatial[0].name, "ks", d0)
            unroll_spatial(ov_spatial[0].name, "opc", d0)
        if len(overlap_dims) > 1:
            # second overlap-reuse dim -> temporal primitive (Fig. 8a):
            # Loop[d][ks] into ILS, then Loop[d][opc] slides (s new inputs).
            d1 = overlap_dims[1]
            f = ls_max_factor("ks", d1, m.temporal)
            if f > 1:
                loops[d1]["ks"] = math.ceil(loops[d1]["ks"] / f)
                m.temporal.append(Entry("ks", d1, f, "T"))
            if loops[d1]["opc"] > 1:
                e = Entry("opc", d1, loops[d1]["opc"], "T", sliding=True)
                sliding_entries.append(e)
                loops[d1]["opc"] = 1

    # ---- Lines 14-19: fill the spatial dims by priority --------------------
    for sdim in spec.spatial:
        for p in sdim.priority:
            for d in order:
                unroll_spatial(sdim.name, p, d)

    # ---- Lines 20-22: temporal unrolling to fill local scratchpads ---------
    for p in spec.temporal_priority:
        for d in order:
            f = ls_max_factor(p, d, m.temporal)
            if f > 1:
                loops[d][p] = math.ceil(loops[d][p] / f)
                m.temporal.append(Entry(p, d, f, "T"))
    # the sliding opc of the temporal overlap primitive sits right after the
    # scratchpad-resident region (it streams, loading s inputs per step)
    m.temporal.extend(sliding_entries)

    # ---- Lines 23-25: append every remaining loop --------------------------
    for p in ("opc", "op", "ks", "g"):
        for d in order:
            if loops[d][p] > 1:
                m.temporal.append(Entry(p, d, loops[d][p], "T"))
                loops[d][p] = 1
    return m.validate()


# ---------------------------------------------------------------------------
# §4.3 consistent mapping: loop exchange
# ---------------------------------------------------------------------------
_OUT_PARAMS = ("opc", "op", "g")      # output-indexing params -> store format
_IN_PARAMS = ("ks", "opc", "g")       # input-indexing params  -> load format


def store_format(m: Mapping) -> Optional[Tuple[str, int]]:
    """(dim, width) of the producer's output storage: the innermost
    output-indexing unrolling on a non-reduce spatial dim (outputs unrolled in
    px are collected in parallel — paper Fig. 10(c))."""
    for e in m.spatial:
        sd = m.spec.spatial_by_name(e.where)
        if not sd.reduce and e.param in _OUT_PARAMS:
            return (e.dim, e.factor)
    return None


def load_format(m: Mapping) -> Optional[Tuple[str, int]]:
    """(dim, width) the consumer wants to load in parallel: the innermost
    input-indexing temporal unrolling (paper Fig. 10(d))."""
    for e in m.temporal:
        if e.param in _IN_PARAMS:
            return (e.dim, e.factor)
    return None


def consistent_load_width(producer: Mapping, consumer: Mapping) -> int:
    sf, lf = store_format(producer), load_format(consumer)
    if sf is None or lf is None:
        return 1
    return lf[1] if sf[0] == lf[0] else 1


def apply_loop_exchange(producer: Mapping, consumer: Mapping) -> int:
    """Make the consumer's load format consistent with the producer's store
    format by exchanging unrolling loops (paper Fig. 10(e)). Tries, in order:
    (1) exchange within the consumer's temporal list; (2) exchange within the
    producer's spatial (px) list. Returns the resulting parallel load width.

    Per the paper, a legal exchange "does not affect the performance or data
    movement based on Equations (6) and (10)"; an exchange that would move an
    entry across a reuse pointer *does* change Eq. (10), so such candidates
    are rejected (movement snapshot + revert)."""
    sf = store_format(producer)
    if sf is None:
        return 1
    want_dim = sf[0]
    # (1) find an input-indexing temporal entry of the consumer on want_dim
    for i, e in enumerate(consumer.temporal):
        if e.param in _IN_PARAMS and e.dim == want_dim:
            first = next((j for j, t in enumerate(consumer.temporal)
                          if t.param in _IN_PARAMS), None)
            if first is not None and first != i:
                before = consumer.movement()
                consumer.temporal[first], consumer.temporal[i] = (
                    consumer.temporal[i], consumer.temporal[first])
                after = consumer.movement()
                if any(after[t] > before[t] for t in before):
                    consumer.temporal[first], consumer.temporal[i] = (
                        consumer.temporal[i], consumer.temporal[first])
                    continue
            return consistent_load_width(producer, consumer)
    # (2) exchange in the producer: promote a px entry matching the
    # consumer's current load dim
    lf = load_format(consumer)
    if lf is None:
        return 1
    px_entries = [(i, e) for i, e in enumerate(producer.spatial)
                  if not producer.spec.spatial_by_name(e.where).reduce
                  and e.param in _OUT_PARAMS]
    for i, e in px_entries:
        if e.dim == lf[0]:
            j = px_entries[0][0]
            if j != i:
                producer.spatial[j], producer.spatial[i] = (
                    producer.spatial[i], producer.spatial[j])
            return consistent_load_width(producer, consumer)
    return consistent_load_width(producer, consumer)
