"""GCONV: the paper's parameterized general convolution (§3.1).

A GCONV is a 1-D convolution scaled to N named dimensions. Per dimension it is
characterized by four loop parameters (``Ng``, ``Nop``, ``Nopc``, ``Nks``) plus
the auxiliary stride ``s`` and padding ``ps``:

  * the inputs are separated into ``Ng`` groups with no inter-group reuse;
  * within a group, ``Nop`` kernels are applied in parallel;
  * each kernel has ``Nks`` taps;
  * each kernel produces ``Nopc`` outputs (sliding with stride ``s``).

Four *operators* complete the definition: ``pre`` (input preprocess), ``main``
(input ⊗ kernel-parameter), ``reduce`` (partial-result reduction over the
``Nks`` taps) and ``post`` (output postprocess). ``main`` is not restricted to
multiply nor ``reduce`` to add — that generality is what lets every CNN/LM layer
be expressed as a GCONV (paper Table 2).

Shape conventions (matching the paper's Figure 5 reading of a conv layer):
  input axis size per dim   = Ng * Nips,  Nips = (Nopc-1)*s + Nks - 2*ps
  kernel axis size per dim  = Ng * Nop * Nks   (or 1 => broadcast)
  output axis size per dim  = Ng * Nop * Nopc

Note: the paper's Eq. (1) prints ``(Nopc+1)*s``; the dimensionally consistent
relation used in all of the paper's own examples is ``(Nopc-1)*s`` — see
DESIGN.md §1 (erratum).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

DEFAULTS = dict(ng=1, nop=1, nopc=1, nks=1, stride=1, pad=0)


@dataclass(frozen=True)
class DimSpec:
    """The four GCONV loop parameters (+ stride/pad) of one dimension.

    ``pad`` is the left padding; ``pad_r`` the right padding (``None`` means
    symmetric, = ``pad``). ``pad_r`` may exceed ``pad`` (Caffe ceil-mode
    pooling) or be negative (trailing input elements the sliding window never
    reads — floor-mode with inexact geometry). The paper's Eq. (1) assumes the
    exact symmetric case; this is the natural generalization.
    """

    name: str
    ng: int = 1
    nop: int = 1
    nopc: int = 1
    nks: int = 1
    stride: int = 1
    pad: int = 0
    pad_r: Optional[int] = None

    def __post_init__(self):
        for f in ("ng", "nop", "nopc", "nks", "stride"):
            v = getattr(self, f)
            if not isinstance(v, int) or v < 1:
                raise ValueError(f"DimSpec {self.name}: {f}={v} must be int >= 1")
        if self.pad < 0:
            raise ValueError(f"DimSpec {self.name}: pad={self.pad} must be >= 0")
        if self.nips < 1:
            raise ValueError(
                f"DimSpec {self.name}: derived Nips={self.nips} < 1 "
                f"(nopc={self.nopc}, s={self.stride}, nks={self.nks}, ps={self.pad})"
            )
        if self.nips + min(self.padr, 0) < 1:
            raise ValueError(f"DimSpec {self.name}: crop exceeds input")

    # ---- derived sizes (paper Eq. (1), corrected) ----
    @property
    def padr(self) -> int:
        return self.pad if self.pad_r is None else self.pad_r

    @property
    def nips(self) -> int:
        return (self.nopc - 1) * self.stride + self.nks - self.pad - self.padr

    @property
    def in_size(self) -> int:
        return self.ng * self.nips

    @property
    def k_size(self) -> int:
        return self.ng * self.nop * self.nks

    @property
    def out_size(self) -> int:
        return self.ng * self.nop * self.nopc

    @property
    def is_default(self) -> bool:
        """True if this dim carries no effectual loop (paper: prunable)."""
        return (self.ng, self.nop, self.nopc, self.nks) == (1, 1, 1, 1)

    @property
    def has_overlap_reuse(self) -> bool:
        """Paper §3.1: inputs are overlap-reused by outputs when Nks > s."""
        return self.nks > self.stride and self.nopc > 1

    def effectual_loops(self) -> Tuple[Tuple[str, int], ...]:
        out = []
        for p in ("ks", "opc", "op", "g"):
            n = {"ks": self.nks, "opc": self.nopc, "op": self.nop, "g": self.ng}[p]
            if n > 1:
                out.append((p, n))
        return tuple(out)

    def pretty(self) -> str:
        parts = []
        for label, attr in (("Ng", "ng"), ("Nop", "nop"), ("Nks", "nks"),
                            ("Nopc", "nopc"), ("s", "stride"), ("ps", "pad")):
            v = getattr(self, attr)
            if v != DEFAULTS[attr if attr != "stride" else "stride"]:
                parts.append(f"{label}:{v}")
        return f"{self.name}[{', '.join(parts) or 'default'}]"


@dataclass(frozen=True)
class Op:
    """One pre/post operator application.

    ``const``   — scalar parameter (e.g. scale factor, epsilon).
    ``operand`` — optional reference (chain node / param name) to a tensor used
                  as the second argument; after operation fusion (paper §4.3)
                  pre/post operators "may have more than one parameter" — this
                  is how fused kernel parameters are carried.
    """

    name: str
    const: Optional[float] = None
    operand: Optional[str] = None

    def pretty(self) -> str:
        s = self.name
        if self.const is not None:
            s += f"({self.const:g})"
        if self.operand is not None:
            s += f"[{self.operand}]"
        return s


@dataclass
class GConv:
    """One GCONV operation in a chain (paper Fig. 3/4 scaled to N dims)."""

    name: str
    dims: Tuple[DimSpec, ...]
    input: str                              # producer node or external input name
    kernel: Optional[str] = None            # producer node / parameter name / None
    pre: Tuple[Op, ...] = ()
    main: str = "mul"                       # "none" => no kernel parameter
    reduce: str = "add"                     # "none" => no reduction (all nks==1)
    post: Tuple[Op, ...] = ()
    out_dtype: Optional[str] = None         # None => same as input

    def __post_init__(self):
        names = [d.name for d in self.dims]
        if len(set(names)) != len(names):
            raise ValueError(f"GCONV {self.name}: duplicate dim names {names}")
        if self.main == "none" and self.kernel is not None:
            raise ValueError(f"GCONV {self.name}: main='none' but kernel given")
        if self.main != "none" and self.kernel is None:
            raise ValueError(f"GCONV {self.name}: main={self.main!r} needs a kernel")
        has_taps = any(d.nks > 1 for d in self.dims)
        if has_taps and self.reduce == "none":
            raise ValueError(
                f"GCONV {self.name}: Nks>1 in some dim but reduce='none'")

    # ---- shapes ----
    @property
    def in_shape(self) -> Tuple[int, ...]:
        return tuple(d.in_size for d in self.dims)

    @property
    def k_shape(self) -> Tuple[int, ...]:
        return tuple(d.k_size for d in self.dims)

    @property
    def out_shape(self) -> Tuple[int, ...]:
        return tuple(d.out_size for d in self.dims)

    def dim(self, name: str) -> DimSpec:
        for d in self.dims:
            if d.name == name:
                return d
        raise KeyError(name)

    def with_dims(self, dims: Sequence[DimSpec]) -> "GConv":
        return dataclasses.replace(self, dims=tuple(dims))

    # ---- workload statistics (used by cost model & Table-1 benchmark) ----
    @property
    def macs(self) -> int:
        """Main-op applications (the paper's 'computation')."""
        n = 1
        for d in self.dims:
            n *= d.ng * d.nop * d.nopc * d.nks
        return n

    @property
    def out_elems(self) -> int:
        n = 1
        for d in self.dims:
            n *= d.out_size
        return n

    @property
    def in_elems(self) -> int:
        n = 1
        for d in self.dims:
            n *= d.in_size
        return n

    @property
    def k_elems(self) -> int:
        n = 1
        for d in self.dims:
            n *= d.k_size
        return n

    @property
    def is_mxu_eligible(self) -> bool:
        """mul/add GCONVs map to the MXU (TPU adaptation; DESIGN.md §2)."""
        return self.main == "mul" and self.reduce == "add"

    def pretty(self) -> str:
        dims = " ".join(d.pretty() for d in self.dims if not d.is_default)
        ops = []
        if self.pre:
            ops.append("pre=" + ",".join(o.pretty() for o in self.pre))
        ops.append(f"main={self.main}")
        ops.append(f"reduce={self.reduce}")
        if self.post:
            ops.append("post=" + ",".join(o.pretty() for o in self.post))
        k = f" k={self.kernel}" if self.kernel else ""
        return (f"{self.name}: <{dims or 'scalar'}> in={self.input}{k} "
                f"[{' '.join(ops)}] -> {self.out_shape}")


def dims_from_shape(names: Sequence[str], shape: Sequence[int],
                    **overrides) -> Tuple[DimSpec, ...]:
    """Helper: elementwise-style dims (Ng=size) unless overridden per name."""
    out = []
    for n, s in zip(names, shape):
        kw = overrides.get(n, {"ng": s})
        out.append(DimSpec(name=n, **kw))
    return tuple(out)
