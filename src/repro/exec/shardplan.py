"""Per-chain sharding plans: the compiled engine joins the mesh world.

Before this module, ``repro.exec`` was strictly single-device while the
full mesh machinery lived in ``repro.launch`` — two disjoint subsystems.
A :class:`ShardPlan` is derived once at ``compile_chain(mesh=...)`` time
and applies the SAME divisibility-guarded policy as the launch-layer model
sharder (both import :mod:`repro.shardpolicy`; nothing is duplicated):

  * **data parallel** — the leading batch axis of every chain input shards
    over the mesh's "data" axis bundle when it divides
    (:func:`repro.shardpolicy.guard`); in the batched/vmapped mode the
    *bucket* axis shards instead, and the engine raises the bucket floor
    to the data-axis size so every bucket divides by construction.
  * **tensor parallel** — grouped-matmul fusion groups split their
    ``(G, M, K) @ (G, K, N)`` contraction over the "model" axis:
    column-split (kernel sharded on N = the Cout/channel GCONV axis, no
    collective) when N divides; otherwise row-split (both operands sharded
    on K) with an **explicit psum** inside a ``shard_map`` — the one place
    the chain program needs a collective; otherwise replicate.
  * **replication fallback** — any axis that doesn't divide falls back to
    replication for that dim, exactly as in ``launch/sharding.py``.

Everything not pinned by the plan is left to GSPMD propagation, so the
sharded program is allclose to the single-device one by construction (the
only numerical difference is reduction order inside the psum).
Differentially tested on 8 faked host devices in
``tests/test_exec_sharded.py`` / ``python -m repro.exec.shardcheck``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .. import shardpolicy as policy
from ..core.chain import Chain
from ..core.gconv import GConv
from . import lowering as low

COLUMN, ROW = "column", "row"


@dataclass(frozen=True)
class ShardPlan:
    """How one compiled chain maps onto a mesh (derived, never mutated)."""

    mesh: Mesh
    dp: tuple                            # data-parallel axis bundle
    tp: Optional[str]                    # tensor-parallel axis name or None
    in_specs: Dict[str, P] = field(default_factory=dict)
    param_specs: Dict[str, P] = field(default_factory=dict)
    step_tp: Dict[str, str] = field(default_factory=dict)  # node -> col/row

    @property
    def dp_size(self) -> int:
        return policy.axis_size(self.mesh, self.dp)

    @property
    def tp_size(self) -> int:
        return policy.axis_size(self.mesh, self.tp)

    # -- NamedSharding trees matching the engine's (inputs, params) args --
    def input_shardings(self):
        return {n: NamedSharding(self.mesh, s)
                for n, s in self.in_specs.items()}

    def param_shardings(self):
        return {n: NamedSharding(self.mesh, s)
                for n, s in self.param_specs.items()}

    def batched_input_shardings(self, chain: Chain, bucket: int):
        """Leading-bucket-axis data parallelism for the vmapped mode."""
        dp = self.dp if bucket % self.dp_size == 0 else None
        return {n: NamedSharding(self.mesh, P(dp, *([None] * len(i.shape))))
                for n, i in chain.inputs.items()}

    def describe(self) -> str:
        lines = [f"ShardPlan mesh={dict(self.mesh.shape)} dp={self.dp} "
                 f"tp={self.tp}"]
        for n, s in self.in_specs.items():
            lines.append(f"  in  {n}: {s}")
        for n, m in self.step_tp.items():
            lines.append(f"  tp  {n}: {m}-split")
        return "\n".join(lines)


def _matmul_geometry(node: GConv, chain: Chain):
    """(match plan, G, M, N, K) of a grouped-matmul node, or None."""
    if node.kernel is None:
        return None
    classes = low.dim_classes(node)
    k_shape = tuple(chain.shape_of(node.kernel))
    mplan = low.match_grouped_matmul(node, classes, k_shape)
    if mplan is None:
        return None
    g_ix, m_ix, c_ix = mplan
    G = M = N = K = 1
    for i in g_ix:
        G *= node.dims[i].ng
    for i in m_ix:
        M *= node.dims[i].in_size
    for i in c_ix:
        N *= node.dims[i].nop
        K *= node.dims[i].nks
    return mplan, G, M, N, K


def derive_plan(chain: Chain, dispatch: Dict[str, str], mesh: Mesh) \
        -> ShardPlan:
    """Derive the chain's plan from its dispatch table and a mesh.

    ``dispatch`` is the compiled plan's node -> backend-tag table; only
    ``matmul:jnp`` nodes are candidates for the explicit tensor-parallel
    split (the Pallas path keeps its single-device kernel; GSPMD may still
    shard it).
    """
    dp = policy.dp_axes(mesh)
    tp = "model" if "model" in mesh.axis_names else None
    tp_n = policy.axis_size(mesh, tp)

    in_specs = {n: policy.leading_batch_spec(mesh, i.shape, dp)
                for n, i in chain.inputs.items()}
    # params replicate: at chain scale the kernels are small relative to
    # activations, and the TP shard_map partitions its (G, K, N) form
    # in-program — pinning a host-side layout would only force reshards
    param_specs = {n: P() for n in chain.params}

    step_tp: Dict[str, str] = {}
    if tp is not None and tp_n > 1:
        for name, tag in dispatch.items():
            if tag != "matmul:jnp":
                continue
            node = chain.nodes[name]
            geo = _matmul_geometry(node, chain)
            if geo is None:
                continue
            _mplan, _G, _M, N, K = geo
            if N % tp_n == 0:
                step_tp[name] = COLUMN       # local matmul, no collective
            elif K % tp_n == 0:
                step_tp[name] = ROW          # explicit psum over tp
            # else: replicate — the divisibility fallback

    return ShardPlan(mesh=mesh, dp=dp, tp=tp, in_specs=in_specs,
                     param_specs=param_specs, step_tp=step_tp)


def wrap_steps(chain: Chain, steps, plan: ShardPlan):
    """Re-lower the plan's tensor-parallel matmul steps with their
    column/row split; every other step passes through untouched."""
    if not plan.step_tp:
        return list(steps)
    from .dispatch import Step, _gconv_step

    out = []
    dp_n = plan.dp_size
    for s in steps:
        mode = plan.step_tp.get(s.name)
        if mode is None:
            out.append(s)
            continue
        node = chain.nodes[s.name]
        geo = _matmul_geometry(node, chain)
        mplan, G, M, _N, _K = geo
        # the data axis rides along on G (batched/grouped kernels) or M
        # (plain batch rows) when it divides, so DP + TP compose without
        # gathers; otherwise the operands replicate over data for this
        # step (the with_sharding_constraint in _tp_matmul enforces it)
        dp_g = plan.dp if G % dp_n == 0 else None
        dp_m = plan.dp if dp_g is None and M % dp_n == 0 else None
        fn = low.lower_grouped_matmul(
            node, mplan, tp=(plan.mesh, plan.tp, mode, dp_g, dp_m))
        out.append(Step(s.name, f"{s.backend}+tp:{mode}",
                        _gconv_step(node, fn),
                        meta=dict(getattr(fn, "tp_meta", {}))))
    return out
