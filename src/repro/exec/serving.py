"""Batch-aware serving programs: the execution substrate of launch/serve.

The serving driver used to hand-roll its own execution (per-slot prefill
through teacher-forced decode steps of the *whole* batch, global position
bookkeeping), corrupting neighbouring slots' caches. This module moves
serving execution into ``repro.exec``, sharing the batched engine's
machinery rather than duplicating it: the same bucketed compile-cache
type that backs the batched :class:`~repro.exec.engine.CompiledChain`
(:class:`~repro.exec.batch.BucketedCache`) keys the prefill programs on
``(batch bucket, length bucket)``, decode is ONE fixed-shape jitted
program over the slot batch, and all slot-state surgery (KV-row splicing,
slot reset) is pure tree arithmetic over the model's ``serve_axes`` table
— no per-family code and no cross-slot writes. To be precise about the
layering: the serving programs jit the models' fused decode/prefill paths
(``models.common`` norm/attention — the very implementations the chain
engine's segment dispatch lowers to, equivalence-tested in
tests/test_exec.py); the per-GCONV lowerings themselves are the *offline*
face of ``repro.exec`` and are not re-derived per token here.

Layering::

    launch/serve.py   policy: queue, slots, admission, stats
    exec/serving.py   mechanism: compiled programs + slot-state surgery
    exec/batch.py     bucketing + compile cache (shared with CompiledChain)
    models/api.py     decode_step / prefill(lengths=...) / serve_axes

Correctness contract (regression-tested in tests/test_serve.py): a
staggered multi-slot workload produces byte-identical token streams to
sequential single-slot decode. This holds because every program here is
row-independent — per-slot positions mean a pad-token tick on an idle slot
never advances or overwrites an active slot's rows, and right-padded
prefill is masked (causally, then by ``pos``) so pad rows are inert.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import shardpolicy as policy
from .batch import BucketedCache, batch_bucket

MIN_LEN_BUCKET = 8      # shortest prompt-length bucket (compile-count floor)


class ServeEngine:
    """Compiled decode/prefill programs + slot-state surgery for one model.

    ``decode``  — one jitted program over the fixed ``slots`` batch.
    ``prefill`` — right-padded batched prefill over the newly admitted
                  requests, one compiled program per ``(batch bucket,
                  length bucket)`` via the shared bucketed cache; falls
                  back to per-request teacher-forced decode for families
                  without a batched prefill (SSM/hybrid) or with sliding
                  windows (where padded prefill is unsound).
    ``splice``  — write prefilled rows (K/V rows, SSM state, positions)
                  into their slots, ONE jitted scatter over the whole
                  admission (``splice_many``); ``reset_slot`` zeroes a
                  slot on release (also jitted).

    Mesh mode (``mesh=``): the engine runs data-parallel replicas of its
    one fixed-shape decode program — every program (decode, prefill,
    splice, reset) shards the SLOT axis of each serve-state leaf over the
    mesh's data bundle (the ``serve_axes`` table names the axis per leaf),
    params replicate, and the same divisibility guard as
    ``launch/sharding.py`` applies: a slot count the data axis doesn't
    divide falls back to replication (``repro.shardpolicy``). Per-slot
    row independence (the PR-4 correctness contract) is exactly what
    makes this sound: no program communicates across the slot axis, so
    each device decodes its own slots bit-for-bit as a single device
    would — staggered serving under a mesh stays byte-identical to the
    sequential single-slot reference (tests/test_exec_sharded.py).
    """

    def __init__(self, model, *, slots: int, max_len: int, mesh=None,
                 tracer=None, chaos=None):
        self.model = model
        self.cfg = model.cfg
        self.slots = int(slots)
        self.max_len = int(max_len)
        # optional repro.obs tracer: engine-category spans around the
        # compiled programs (decode / prefill / splice / reset), device-
        # synced so span durations are real device time. None (the
        # default) keeps every hot path on a single flag check.
        self.tracer = tracer
        # optional repro.runtime.chaos injector: each compiled-program
        # site calls chaos.enter(site) (which may raise/stall) and
        # applies the returned data faults to its outputs. None (the
        # default) keeps every hot path on a single flag check.
        self.chaos = chaos
        self.axes: Dict[str, int] = dict(model.serve_axes)
        self.mesh = None if mesh is None or mesh.empty else mesh
        if self.mesh is not None:
            self._dp = policy.dp_axes(self.mesh)
            self._dp_n = policy.axis_size(self.mesh, self._dp)
            slot_dp = self._dp if self.slots % self._dp_n == 0 else None
            state_shape = jax.eval_shape(
                lambda: model.serve_state_init(self.slots, self.max_len,
                                               per_slot_pos=True))
            self._cache_sh = {
                k: NamedSharding(self.mesh, P(*[
                    slot_dp if a == self.axes[k] else None
                    for a in range(leaf.ndim)]))
                for k, leaf in state_shape.items()}
            self._tok_sh = NamedSharding(self.mesh, P(slot_dp, None))
            self._decode_fn = jax.jit(
                model.decode_step,
                in_shardings=(None, self._tok_sh, self._cache_sh),
                out_shardings=(None, self._cache_sh))
            # surgery keeps the cache canonically slot-sharded so the next
            # decode never pays a reshard
            self._splice_fn = jax.jit(self._splice_many,
                                      out_shardings=self._cache_sh)
            self._reset_fn = jax.jit(self._reset_impl,
                                     out_shardings=self._cache_sh)
        else:
            self._dp_n = 1
            self._decode_fn = jax.jit(model.decode_step)
            # slot surgery compiles once per (row-state shape, admission
            # count) — both bucket-bounded; jitting fuses the per-leaf
            # updates into one program instead of eager per-leaf dispatch
            self._splice_fn = jax.jit(self._splice_many)
            self._reset_fn = jax.jit(self._reset_impl)
        self._prefill_cache = BucketedCache(self._build_prefill)
        self._batched_prefill_ok = (
            getattr(model, "prefill", None) is not None
            and not self.cfg.sliding_window)
        self.tune_report = None          # set by tune()

    # -- state ----------------------------------------------------------
    def init_state(self):
        state = self.model.serve_state_init(self.slots, self.max_len,
                                            per_slot_pos=True)
        if self.mesh is not None:
            state = jax.device_put(state, self._cache_sh)
        return state

    def shard_params(self, params):
        """Replicate params across the mesh (the data-parallel serving
        story; tensor-parallel param rules stay in launch/sharding)."""
        if self.mesh is None:
            return params
        rep = jax.tree.map(lambda _: NamedSharding(self.mesh, P()), params)
        return jax.device_put(params, rep)

    # -- decode: ONE program, fixed (slots, 1) shape --------------------
    def decode(self, params, tokens, cache):
        """tokens: (slots, 1) int32 -> (logits, cache). Row-independent:
        idle slots step a pad token but only their own rows move."""
        ch = self.chaos
        post = ch.enter("decode") if ch is not None else ()
        tr = self.tracer
        if tr is not None and tr.enabled:
            with tr.span("engine.decode", cat="engine",
                         attrs={"slots": self.slots}):
                out = self._decode_fn(params, tokens, cache)
                jax.block_until_ready(out)
        else:
            out = self._decode_fn(params, tokens, cache)
        if post:
            out = ch.apply_decode(post, out[0], out[1], self.axes)
        return out

    # -- prefill: bucketed batched programs -----------------------------
    def _build_prefill(self, key):
        nb, lb = key
        if lb == 0:                       # fallback: single decode step
            return jax.jit(self.model.decode_step)
        fn = lambda params, tokens, lengths: \
            self.model.prefill(params, tokens, lengths=lengths)
        if self.mesh is not None:
            # admission rows data-parallel: nb is bucketed to a multiple
            # of the data-axis size, so the guard only fires for meshes
            # whose data axis is not a power of two
            row_dp = self._dp if nb % self._dp_n == 0 else None
            tok_sh = NamedSharding(self.mesh, P(row_dp, None))
            len_sh = NamedSharding(self.mesh, P(row_dp))
            return jax.jit(fn, in_shardings=(None, tok_sh, len_sh))
        return jax.jit(fn)

    def prefill(self, params, prompts: Sequence[Sequence[int]]):
        """Prefill ``prompts`` together; returns (logits, row_state, n).

        ``logits[j]`` is row j's own last-real-token logits (never another
        request's — the old driver's unbound/stale-``logits`` bug class);
        ``row_state`` holds the per-row caches to splice into slots.
        """
        n = len(prompts)
        if n == 0:
            raise ValueError("prefill of zero prompts")
        if any(len(p) == 0 for p in prompts):
            raise ValueError("empty prompt reached prefill; the driver "
                             "seeds BOS or rejects at submit")
        longest = max(len(p) for p in prompts)
        if longest > self.max_len:
            raise ValueError(f"prompt length {longest} > max_len "
                             f"{self.max_len}")
        ch = self.chaos
        post = ch.enter("prefill") if ch is not None else ()
        if not self._batched_prefill_ok:
            logits, row_state, n = self._prefill_loop(params, prompts)
            if post:
                logits, row_state = ch.apply_decode(post, logits, row_state,
                                                    self.axes)
            return logits, row_state, n
        # sharded engines raise the row-bucket floor to the data-axis size
        # (see exec.batch): every admission bucket then divides the mesh
        nb = batch_bucket(n, self._dp_n)
        # longest <= max_len (checked above), so the clamp keeps lb valid
        lb = min(batch_bucket(longest, MIN_LEN_BUCKET), self.max_len)
        tokens = np.zeros((nb, lb), np.int32)
        lengths = np.ones((nb,), np.int32)     # pad rows: 1 (inert, valid)
        for j, p in enumerate(prompts):
            tokens[j, :len(p)] = p
            lengths[j] = len(p)
        tr = self.tracer
        if tr is not None and tr.enabled:
            before = self._prefill_cache.compiles
            fn = self._prefill_cache.get((nb, lb))
            cat = "compile" if self._prefill_cache.compiles > before \
                else "execute"
            with tr.span("engine.prefill", cat=cat,
                         attrs={"n": n, "batch_bucket": nb,
                                "len_bucket": lb}):
                logits, row_state = fn(params, jnp.asarray(tokens),
                                       jnp.asarray(lengths))
                jax.block_until_ready((logits, row_state))
        else:
            fn = self._prefill_cache.get((nb, lb))
            logits, row_state = fn(params, jnp.asarray(tokens),
                                   jnp.asarray(lengths))
        if post:
            logits, row_state = ch.apply_decode(post, logits, row_state,
                                                self.axes)
        return logits, row_state, n

    def _prefill_loop(self, params, prompts):
        """Teacher-forced per-request prefill on a fresh single-row state
        (SSM/hybrid/windowed families): still isolated — the scratch state
        is private, nothing touches the live slot batch."""
        step = self._prefill_cache.get((1, 0))
        rows, logits = [], []
        for p in prompts:
            st = self.model.serve_state_init(1, self.max_len,
                                             per_slot_pos=True)
            lg = None
            for t in p:
                lg, st = step(params, jnp.asarray([[t]], jnp.int32), st)
            logits.append(lg[:, -1] if lg.ndim == 3 else lg)
            rows.append(st)
        row_state = {k: jnp.concatenate([r[k] for r in rows],
                                        axis=self.axes[k])
                     for k in rows[0]}
        return jnp.concatenate(logits), row_state, len(prompts)

    @property
    def prefill_compiles(self) -> int:
        return self._prefill_cache.compiles

    # -- slot-state surgery (tree arithmetic over serve_axes) -----------
    def _splice_many(self, cache, slots, row_state, js):
        """Scatter rows ``js`` of ``row_state`` into ``slots`` of
        ``cache`` — the ONLY slots whose leaves change; all other rows
        pass through untouched (no cross-slot cache writes, by
        construction). ``slots``/``js``: (m,) int32."""
        def one(leaf, rows_leaf, axis):
            rows = jnp.take(rows_leaf, js, axis=axis)
            rows = jnp.moveaxis(rows, axis, 0)               # (m, ...)
            tgt = jnp.moveaxis(leaf, axis, 0)                # (slots, ...)
            pad = [(0, 0)] + [(0, int(t) - int(r))
                              for t, r in zip(tgt.shape[1:], rows.shape[1:])]
            if any(p != (0, 0) for p in pad):                # lb -> max_len
                rows = jnp.pad(rows, pad)
            out = tgt.at[slots].set(rows.astype(leaf.dtype))
            return jnp.moveaxis(out, 0, axis)

        return {k: one(cache[k], row_state[k], self.axes[k]) for k in cache}

    def splice_many(self, cache, slots: Sequence[int], row_state,
                    js: Optional[Sequence[int]] = None):
        """Write each row ``js[i]`` of ``row_state`` into slot
        ``slots[i]``: one fused jitted scatter for the whole admission."""
        if js is None:
            js = list(range(len(slots)))
        if self.chaos is not None:
            self.chaos.enter("splice")
        tr = self.tracer
        if tr is not None and tr.enabled:
            with tr.span("engine.splice", cat="engine",
                         attrs={"rows": len(js)}):
                out = self._splice_fn(cache, jnp.asarray(slots, jnp.int32),
                                      row_state, jnp.asarray(js, jnp.int32))
                jax.block_until_ready(out)
                return out
        return self._splice_fn(cache, jnp.asarray(slots, jnp.int32),
                               row_state, jnp.asarray(js, jnp.int32))

    def splice(self, cache, slot: int, row_state, j: int = 0):
        """Single-slot convenience form of :meth:`splice_many`."""
        return self.splice_many(cache, [slot], row_state, [j])

    def _reset_impl(self, cache, slot):
        def one(leaf, axis):
            shape = list(leaf.shape)
            shape[axis] = 1
            zeros = jnp.zeros(shape, leaf.dtype)
            start = [0] * leaf.ndim
            start[axis] = slot
            return jax.lax.dynamic_update_slice(leaf, zeros, start)

        return {k: one(cache[k], self.axes[k]) for k in cache}

    def reset_slot(self, cache, slot: int):
        """Zero a slot's rows on release — a reused slot starts from a
        clean state even before its next splice."""
        if self.chaos is not None:
            self.chaos.enter("reset")
        tr = self.tracer
        if tr is not None and tr.enabled:
            with tr.span("engine.reset", cat="engine",
                         attrs={"slot": int(slot)}):
                out = self._reset_fn(cache, jnp.asarray(slot, jnp.int32))
                jax.block_until_ready(out)
                return out
        return self._reset_fn(cache, jnp.asarray(slot, jnp.int32))

    # -- degraded-mode fallback: one request, private single-row state --
    def decode_single(self, params, prompt: Sequence[int],
                      max_new: int) -> List[int]:
        """Greedy-decode ONE request end to end on a private single-row
        state, bypassing the live slot batch — the driver's graceful-
        degradation path when the batched decode program keeps failing.

        Byte-identity with a single-slot server holds by construction:
        the prompt goes through the SAME bucketed prefill program a
        ``slots=1`` server would use (``batch_bucket(1) == 1``, same
        length bucket), the row is spliced into a fresh 1-slot state by
        the same (eagerly evaluated — pure data movement, bitwise
        identical either way) splice arithmetic, and every decode step
        runs ``jax.jit(model.decode_step)`` at the same (1, 1) shape.
        The batched decode program — the thing that is failing — is
        never touched, and neither is the live slot cache.
        """
        tr = self.tracer
        if tr is not None and tr.enabled:
            with tr.span("engine.decode_single", cat="engine",
                         attrs={"prompt_len": len(prompt),
                                "max_new": int(max_new)}):
                return self._decode_single(params, prompt, max_new)
        return self._decode_single(params, prompt, max_new)

    # -- measured variant selection (repro.exec.tune) -------------------
    def tune(self, params, *, mode: str = "auto",
             db_path: Optional[str] = None, budget: int = 8, seed: int = 0,
             warmup: int = 1, repeats: int = 3) -> dict:
        """Measured selection over the model's serving variants, sharing
        the kernel autotuner's DB, modes and search engines
        (:mod:`repro.exec.tune` / :mod:`repro.search`).

        The serving programs jit the models' fused decode/prefill paths —
        they are not chain-compiled — so the tunable points are the
        model-level variants the config exposes, rebuilt via
        ``models.api.build``:

          * ``decode``  — ``perf_flags`` ± ``gqa_norepeat`` (only when KV
                          heads actually repeat);
          * ``prefill`` — ``attn_impl`` in chunked/naive (+ pallas off
                          interpret mode).

        Winners are applied in place (the engine rebuilds its jitted
        programs on the winning config); decisions persist under
        ``serve:``-prefixed DB keys so warm starts are pure lookups. The
        cache layout is invariant under both knobs, so live slot state
        survives an applied decision."""
        import hashlib
        import json as _json
        from dataclasses import asdict, replace

        from ..kernels.common import use_interpret
        from ..models import api
        from . import tune as T

        if mode not in ("readonly", "auto", "force"):
            raise ValueError(f"tune mode {mode!r}: want "
                             f"readonly|auto|force")
        cfg = self.cfg
        report = dict(mode=mode, groups={}, applied={})
        if getattr(cfg, "attn_impl", None) is None:
            return report
        db = T.load_db(db_path)
        dev = T.device_key()
        report.update(device=dev, db_path=db.path)
        # config identity EXCLUDING the tuned knobs (else the key would
        # chase the decision), plus the serving geometry
        ident = asdict(cfg)
        ident.pop("attn_impl", None)
        base_flags = tuple(f for f in cfg.perf_flags
                           if f != "gqa_norepeat")
        ident["perf_flags"] = sorted(base_flags)
        sig = hashlib.sha256(
            _json.dumps(ident, sort_keys=True,
                        default=str).encode()).hexdigest()[:16]
        base_key = (f"{dev}|serve:{cfg.name}:{sig}"
                    f":s{self.slots}x{self.max_len}")
        choice = dict(attn_impl=cfg.attn_impl,
                      gqa="gqa_norepeat" in cfg.perf_flags)

        def variant(attn_impl=None, gqa=None):
            flags = base_flags + (
                ("gqa_norepeat",)
                if (choice["gqa"] if gqa is None else gqa) else ())
            return replace(cfg, perf_flags=flags,
                           attn_impl=attn_impl or choice["attn_impl"])

        groups = []
        if cfg.n_kv_heads and cfg.n_heads > cfg.n_kv_heads:
            groups.append(("decode", [("flags:-", dict(gqa=False)),
                                      ("flags:gqa_norepeat",
                                       dict(gqa=True))]))
        if self._batched_prefill_ok:
            impls = ["chunked", "naive"]
            if not use_interpret():
                impls.append("pallas")
            groups.append(("prefill", [(f"attn:{i}", dict(attn_impl=i))
                                       for i in impls]))
        dirty = False
        for gname, cands in groups:
            cur = (("flags:gqa_norepeat" if choice["gqa"] else "flags:-")
                   if gname == "decode" else f"attn:{choice['attn_impl']}")
            ix = next((i for i, (t, _kw) in enumerate(cands) if t == cur),
                      0)
            cands.insert(0, cands.pop(ix))       # incumbent wins ties
            key = f"{base_key}|{gname}"
            entry = db.lookup(key) if mode != "force" else None
            if entry is not None and (entry["backend"]
                                      not in [t for t, _kw in cands]):
                entry = None
            if entry is None and mode == "readonly":
                report["groups"][gname] = dict(backend=cur,
                                               source="heuristic")
                continue
            if entry is None:
                def _measure(i, _cands=cands, _g=gname):
                    tag, kw = _cands[i]
                    m = api.build(variant(**kw))
                    if _g == "decode":
                        fn = jax.jit(m.decode_step)
                        st = m.serve_state_init(self.slots, self.max_len,
                                                per_slot_pos=True)
                        tok = jnp.zeros((self.slots, 1), jnp.int32)
                        return T.measure_callable(
                            fn, params, tok, st,
                            warmup=warmup, repeats=repeats)
                    lb = min(batch_bucket(MIN_LEN_BUCKET, MIN_LEN_BUCKET),
                             self.max_len)
                    fn = jax.jit(lambda p, t, l, _m=m:
                                 _m.prefill(p, t, lengths=l))
                    tok = jnp.zeros((1, lb), jnp.int32)
                    ln = jnp.full((1,), lb, jnp.int32)
                    return T.measure_callable(fn, params, tok, ln,
                                              warmup=warmup,
                                              repeats=repeats)

                win, win_s, res = T.measured_select(
                    len(cands), _measure, budget=budget, seed=seed)
                entry = dict(backend=cands[win][0], block=None,
                             latency_us=round(win_s * 1e6, 3),
                             heuristic_backend=cur,
                             n_candidates=len(cands),
                             n_evals=res.n_evals, strategy=res.strategy)
                db.record(key, entry)
                dirty = True
                src = "measured"
            else:
                src = "db"
            tag = entry["backend"]
            if gname == "decode":
                choice["gqa"] = tag == "flags:gqa_norepeat"
            else:
                choice["attn_impl"] = tag.split(":", 1)[1]
            report["groups"][gname] = dict(
                backend=tag, source=src,
                latency_us=entry["latency_us"])
        if dirty:
            T.save_db(db)
        final = variant()
        if (final.attn_impl, final.perf_flags) != (cfg.attn_impl,
                                                   cfg.perf_flags):
            # rebuild the jitted programs on the winning config; params
            # and slot-state layouts are invariant under both knobs
            self.__init__(api.build(final), slots=self.slots,
                          max_len=self.max_len, mesh=self.mesh,
                          tracer=self.tracer, chaos=self.chaos)
            report["applied"] = dict(attn_impl=final.attn_impl,
                                     perf_flags=list(final.perf_flags))
        self.tune_report = report
        return report

    def _decode_single(self, params, prompt, max_new):
        logits, rows, _n = self.prefill(params, [list(prompt)])
        st = self.model.serve_state_init(1, self.max_len,
                                         per_slot_pos=True)
        st = self._splice_many(st, jnp.asarray([0], jnp.int32), rows,
                               jnp.asarray([0], jnp.int32))
        step = self._prefill_cache.get((1, 0))   # jit(model.decode_step)
        tok = int(np.asarray(jnp.argmax(logits[:1], axis=-1)).reshape(-1)[0])
        out = [tok]
        while len(out) < max_new:
            lg, st = step(params, jnp.asarray([[tok]], jnp.int32), st)
            lg = lg[:, -1] if lg.ndim == 3 else lg
            tok = int(np.asarray(jnp.argmax(lg, axis=-1)).reshape(-1)[0])
            out.append(tok)
        return out
