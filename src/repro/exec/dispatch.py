"""Backend dispatch + fused-segment detection for the compiled engine.

Per-node dispatch picks the cheapest sound lowering from
:mod:`repro.exec.lowering` using the dim-class vector and the kernel
tensor's (possibly broadcast) shape. On top of that, a peephole pass
recognizes multi-GCONV *segments* and lowers each to the hand-fused
implementation it denotes — proving the engine subsumes the paths that
used to be hand-wired into the LM models:

  * softmax   (max / sub-exp / sum / div, both the 4-node form and the
               §4.3-fused 3-node form)        -> ``jax.nn.softmax``
  * rmsnorm   (reduce-GCONV + broadcast-GCONV) -> ``models.common.norm``
               or the Pallas ``kernels.chain_norm``
  * attention (scores -> softmax -> values)    -> ``models.common.
               attention_naive`` or the Pallas ``kernels.flash_attention``

Interior segment nodes are never materialized; they appear in the dispatch
table as ``fused:<segment output>``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.chain import Chain, Concat, Movement
from ..core.gconv import GConv, Op
from ..kernels.common import use_interpret
from . import lowering as low


@dataclass
class Step:
    """One compiled execution step: produces env[name] from env."""

    name: str
    backend: str
    run: Callable                        # fn(env) -> array
    # static contract the lowering declares about `run` (e.g. the tensor-
    # parallel tp_mode/psum/constrained facts from lower_grouped_matmul);
    # audited by the repro.lint shard passes, never read at execution time
    meta: Dict[str, object] = field(default_factory=dict)


@dataclass
class Plan:
    steps: List[Step]
    dispatch: Dict[str, str]             # every original node -> backend tag
    signature: str = ""                  # stable program identity: chain
                                         # name + input shapes + per-step
                                         # backend decisions (the engine
                                         # appends mesh + tensor-parallel
                                         # splits for sharded programs).
                                         # Introspection/reporting only —
                                         # compile caches are per-engine,
                                         # so their keys need only
                                         # (keep_all, bucket)


# ---------------------------------------------------------------------------
# per-node dispatch
# ---------------------------------------------------------------------------
def _prefer_pallas_matmul(backend: str, mxu_min: int, plan, node) -> bool:
    """Static MXU-worthiness heuristic — the no-DB fallback the autotuner
    (:mod:`repro.exec.tune`) measures against. All three work axes must
    clear a threshold: K/N feed the MXU contraction, and M must at least
    fill one sublane tile — a tiny-M huge-K product (e.g. a (1, 4096) @
    (4096, 4096) head projection) is a matvec whose Pallas grid degenerates
    to one M-row of padded tiles, where ``jnp.matmul`` wins. The group
    axis never compensates for small M: G maps to the kernel grid, not the
    tile."""
    if backend == "pallas":
        return True
    if backend != "auto" or use_interpret():
        return False
    from ..kernels.gconv_matmul import M_ALIGN
    g_ix, m_ix, c_ix = plan
    M = int(np.prod([node.dims[i].in_size for i in m_ix])) if m_ix else 1
    K = int(np.prod([node.dims[i].nks for i in c_ix])) if c_ix else 1
    N = int(np.prod([node.dims[i].nop for i in c_ix])) if c_ix else 1
    return M >= M_ALIGN and K >= mxu_min and N >= mxu_min


def dispatch_gconv(node: GConv, k_shape: Optional[Tuple[int, ...]],
                   backend: str = "auto",
                   mxu_min: int = 128) -> Tuple[str, Callable]:
    """Pick (backend_tag, fn(x, k, lookup)) for one GCONV node."""
    classes = low.dim_classes(node)
    if all(c == low.BCAST for c in classes):
        return "elementwise", low.lower_elementwise(node)
    if low.GENERAL in classes:
        return "oracle", low.lower_oracle(node)
    if node.main == "none" and node.reduce in ("add", "max", "min"):
        if all(d.nop == 1 for d in node.dims):
            return "reduce", low.lower_reduce(node, classes)
        return "oracle", low.lower_oracle(node)
    if node.main == "mul" and node.reduce == "add":
        if low.WINDOW not in classes:
            plan = low.match_grouped_matmul(node, classes, k_shape)
            if plan is not None:
                if _prefer_pallas_matmul(backend, mxu_min, plan, node):
                    return ("matmul:pallas",
                            low.lower_grouped_matmul(node, plan, pallas=True))
                return "matmul:jnp", low.lower_grouped_matmul(node, plan)
        cplan = low.match_conv(node, classes, k_shape)
        if cplan is not None:
            if backend == "pallas" or (backend == "auto"
                                       and not use_interpret()):
                fn = low.lower_conv_pallas(node, cplan)
                if fn is not None:
                    return "conv:pallas", fn
            return "conv:lax", low.lower_conv(node, cplan)
        return "einsum", low.lower_einsum(node, classes)
    return "oracle", low.lower_oracle(node)


# ---------------------------------------------------------------------------
# segment detection
# ---------------------------------------------------------------------------
@dataclass
class Segment:
    kind: str
    out: str                             # the node whose value the segment produces
    members: Tuple[str, ...]             # interior nodes, never materialized
    run: Callable = None                 # fn(env) -> array


def _is_op(op: Op, name: str, operand: Optional[str] = None) -> bool:
    return (op.name == name and op.operand == operand)


def _single_axis_reduce(node: GConv, kind: str) -> Optional[int]:
    """Axis index when the node is a pure one-dim full reduction."""
    if not isinstance(node, GConv):
        return None
    if node.main != "none" or node.reduce != kind:
        return None
    classes = low.dim_classes(node)
    tap_ix = [i for i, d in enumerate(node.dims) if d.nks > 1]
    if len(tap_ix) != 1:
        return None
    i = tap_ix[0]
    if classes[i] != low.CONTRACT or node.dims[i].ng != 1:
        return None
    if node.dims[i].nop != 1:
        return None
    if any(c != low.BCAST for j, c in enumerate(classes) if j != i):
        return None
    return i


def _softmax_parts(chain: Chain, consumers, div_name: str):
    """Match the softmax segment ending at ``div_name``.

    Returns (x, axis, members) or None. Handles both the unfused 4-node
    form (max / sub-exp / sum / div) and the form §4.3 fusion produces
    (max / sum[pre=sub,exp] / div[pre=sub,exp])."""
    div = chain.nodes.get(div_name)
    if not isinstance(div, GConv) or div.main != "div":
        return None
    if div.reduce != "none" or div.post or div.kernel is None:
        return None
    s = chain.nodes.get(div.kernel)
    if not isinstance(s, GConv):
        return None

    def fused_pre(pre, m_name):
        return (len(pre) == 2 and _is_op(pre[0], "sub", m_name)
                and pre[0].const is None and _is_op(pre[1], "exp"))

    if not div.pre:                                      # unfused form
        e = chain.nodes.get(div.input)
        if (not isinstance(e, GConv) or e.main != "sub" or e.reduce != "none"
                or e.pre or len(e.post) != 1 or not _is_op(e.post[0], "exp")):
            return None
        m_name = e.kernel
        if s.input != e.name or s.pre or s.post:
            return None
        ax = _single_axis_reduce(s, "add")
        m = chain.nodes.get(m_name)
        if not isinstance(m, GConv) or m.input != e.input:
            return None
        if m.pre or m.post or _single_axis_reduce(m, "max") != ax:
            return None
        members = (m_name, e.name, s.name)
        x = e.input
        cons_ok = (sorted(consumers.get(e.name, [])) == sorted([s.name,
                                                                div_name])
                   and consumers.get(m_name, []) == [e.name]
                   and consumers.get(s.name, []) == [div_name])
    else:                                                # fused form
        if len(div.pre) != 2:
            return None
        m_name = div.pre[0].operand
        if m_name is None or not fused_pre(div.pre, m_name):
            return None
        if s.input != div.input or s.post or not fused_pre(s.pre, m_name):
            return None
        ax = _single_axis_reduce(s, "add")
        m = chain.nodes.get(m_name)
        if not isinstance(m, GConv) or m.input != div.input:
            return None
        if m.pre or m.post or _single_axis_reduce(m, "max") != ax:
            return None
        members = (m_name, s.name)
        x = div.input
        cons_ok = (sorted(consumers.get(m_name, []))
                   == sorted([s.name, div_name])
                   and consumers.get(s.name, []) == [div_name])
    if ax is None or not cons_ok:
        return None
    if any(n in chain.outputs for n in members):
        return None
    # interior nodes with an out_dtype quantize their intermediate in the
    # oracle; a segment computing end-to-end in f32 would diverge — refuse
    # and let per-node dispatch handle the mixed-precision chain
    if any(chain.nodes[n].out_dtype is not None for n in members):
        return None
    return x, ax, members


def match_softmax(chain: Chain, consumers, div_name: str) -> Optional[Segment]:
    parts = _softmax_parts(chain, consumers, div_name)
    if parts is None:
        return None
    x, ax, members = parts
    out_dtype = chain.nodes[div_name].out_dtype

    def run(env, _x=x, _ax=ax, _od=out_dtype):
        v = env[_x]
        y = jax.nn.softmax(v.astype(jnp.result_type(v.dtype, jnp.float32)),
                           axis=_ax)
        return y if _od is None else y.astype(_od)

    return Segment("segment:softmax", div_name, members, run)


def match_norm(chain: Chain, consumers, name: str,
               backend: str = "auto") -> Optional[Segment]:
    """rmsnorm pair: reduce-GCONV (square-mean-rsqrt) + broadcast-GCONV."""
    n2 = chain.nodes.get(name)
    if not isinstance(n2, GConv) or n2.main != "mul" or n2.reduce != "none":
        return None
    if n2.pre or len(n2.post) != 1 or n2.post[0].name != "mul":
        return None
    gamma = n2.post[0].operand
    if gamma is None or n2.kernel is None:
        return None
    ms = chain.nodes.get(n2.kernel)
    if not isinstance(ms, GConv) or ms.input != n2.input:
        return None
    if (len(ms.pre) != 1 or not _is_op(ms.pre[0], "square")
            or len(ms.post) != 2 or ms.post[0].name != "scale"
            or ms.post[1].name != "rsqrt_eps"):
        return None
    ax = _single_axis_reduce(ms, "add")
    if ax is None or ax != len(ms.dims) - 1:             # norm is over -1
        return None
    nks = ms.dims[ax].nks
    if not np.isclose(ms.post[0].const, 1.0 / nks):
        return None
    eps = ms.post[1].const if ms.post[1].const is not None else 1e-5
    if consumers.get(ms.name, []) != [name] or ms.name in chain.outputs:
        return None
    if ms.out_dtype is not None:         # oracle would quantize the stat
        return None
    if any(c != low.BCAST for c in low.dim_classes(n2)):
        return None
    try:
        gshape = chain.shape_of(gamma)
    except KeyError:
        return None
    # canonical (1, ..., C) gamma only: the chain_norm kernel reshapes it
    # to (C,); a further-broadcast gamma falls back to per-node dispatch
    C = ms.dims[ax].nks
    if gshape[-1] != C or any(s != 1 for s in gshape[:-1]):
        return None
    use_pallas = backend == "pallas" or (backend == "auto"
                                         and not use_interpret())
    x_name = n2.input

    out_dtype = n2.out_dtype

    def run(env, _x=x_name, _g=gamma, _eps=eps, _pallas=use_pallas,
            _od=out_dtype):
        x = env[_x]
        x = x.astype(jnp.result_type(x.dtype, jnp.float32))
        g = env[_g]
        if _pallas:
            from ..kernels.chain_norm import chain_norm
            y = chain_norm(x.reshape(-1, x.shape[-1]),
                           g.reshape(x.shape[-1]), eps=_eps, mode="rms")
            y = y.reshape(x.shape)
        else:
            from ..models import common
            y = common.norm(x, g, kind="rms", eps=_eps)
        return y if _od is None else y.astype(_od)

    tag = "segment:norm:" + ("pallas" if use_pallas else "jnp")
    return Segment(tag, name, (ms.name,), run)


def _canonical_attention(s: GConv, v: GConv, ks_shape, kv_shape):
    """(B, H..., Tq, Tk, D) scores/values pair in the layers.attention_*
    layout: returns (tk_axis, d_axis, scale) or None."""
    if len(s.dims) != len(v.dims):
        return None
    n = len(s.dims)
    if n < 3:
        return None
    tk, d = n - 2, n - 1
    ds, dv = s.dims, v.dims
    # scores: Tq=nop at n-3, Tk=nopc at n-2, D=nks at n-1, groups before
    tq = n - 3
    ok_s = (ds[tq].ng == 1 and ds[tq].nks == 1 and ds[tq].nopc == 1
            and ds[tk].nks == 1 and ds[tk].nop == 1 and ds[tk].ng == 1
            and ds[d].nopc == 1 and ds[d].nop == 1 and ds[d].ng == 1
            and all(low.classify_dim(ds[i]) == low.BCAST
                    and ds[i].nopc == 1 for i in range(tq)))
    ok_v = (dv[tq].ng >= 1 and dv[tq].nks == 1 and dv[tq].nop == 1
            and dv[tk].ng == 1 and dv[tk].nop == 1 and dv[tk].nopc == 1
            and dv[d].ng == 1 and dv[d].nks == 1 and dv[d].nopc == 1
            and all(low.classify_dim(dv[i]) == low.BCAST
                    and dv[i].nopc == 1 for i in range(tq)))
    if not (ok_s and ok_v):
        return None
    if ks_shape is None or kv_shape is None:
        return None
    # q broadcastless on groups/Tq/D, singleton on Tk; v singleton on Tq
    if ks_shape[tk] != 1 or kv_shape[tq] != 1:
        return None
    if not s.post:
        scale = 1.0
    elif len(s.post) == 1 and s.post[0].name == "scale":
        scale = float(s.post[0].const)
    else:
        return None
    return tk, d, scale


def match_attention(chain: Chain, consumers, v_name: str,
                    backend: str = "auto") -> Optional[Segment]:
    v = chain.nodes.get(v_name)
    if not isinstance(v, GConv) or v.main != "mul" or v.reduce != "add":
        return None
    if v.pre or v.post or v.kernel is None:
        return None
    probs_name = v.input
    parts = _softmax_parts(chain, consumers, probs_name)
    if parts is None or consumers.get(probs_name, []) != [v_name]:
        return None
    s_name, sm_ax, sm_members = parts
    if probs_name in chain.outputs:
        return None
    s = chain.nodes.get(s_name)
    if not isinstance(s, GConv) or s.main != "mul" or s.reduce != "add":
        return None
    if s.pre or s.kernel is None:
        return None
    if not set(consumers.get(s_name, [])) <= set(sm_members) | {probs_name}:
        return None
    if s_name in chain.outputs or any(m in chain.outputs for m in sm_members):
        return None
    # interior scores/probs with an out_dtype would be quantized by the
    # oracle; the fused segment computes in f32 — refuse (see _softmax_parts)
    if s.out_dtype is not None or chain.nodes[probs_name].out_dtype is not None:
        return None
    try:
        ks_shape = chain.shape_of(s.kernel)
        kv_shape = chain.shape_of(v.kernel)
    except KeyError:
        return None
    canon = _canonical_attention(s, v, ks_shape, kv_shape)
    if canon is None:
        return None
    tk, d_ax, scale = canon
    if sm_ax != tk:
        return None
    # values must contract the Tk axis and replicate over D
    if v.dims[tk].nks == 1 or v.dims[d_ax].nop == 1:
        return None
    use_pallas = backend == "pallas" or (backend == "auto"
                                         and not use_interpret())
    q_name, k_name, vv_name = s.kernel, s.input, v.kernel
    out_shape = v.out_shape
    n = len(s.dims)
    lead = tuple(s.dims[i].ng for i in range(n - 3))     # (B, H, ...) groups
    Tq, Tk, D = s.dims[n - 3].nop, s.dims[tk].nopc, s.dims[d_ax].nks
    out_dtype = v.out_dtype

    def run(env, _q=q_name, _k=k_name, _v=vv_name, _scale=scale,
            _pallas=use_pallas, _out=out_shape, _od=out_dtype):
        q, kk, vv = env[_q], env[_k], env[_v]
        ct = jnp.result_type(kk.dtype, jnp.float32)
        B = int(np.prod(lead)) if lead else 1
        q_ = jnp.broadcast_to(q.astype(ct), lead + (Tq, 1, D))
        q_ = q_.reshape(B, Tq, D)
        k_ = jnp.broadcast_to(kk.astype(ct), lead + (1, Tk, D))
        k_ = k_.reshape(B, Tk, D)
        v_ = jnp.broadcast_to(vv.astype(ct), lead + (1, Tk, D))
        v_ = v_.reshape(B, Tk, D)
        if _pallas:
            from ..kernels.flash_attention import flash_attention
            o = flash_attention(q_, k_, v_, causal=False, scale=_scale)
        else:
            from ..models import common
            o = common.attention_naive(
                q_[:, :, None], k_[:, :, None], v_[:, :, None],
                causal=False, scale=_scale)[:, :, 0]
        o = o.reshape(_out)
        return o if _od is None else o.astype(_od)

    tag = "segment:attention:" + ("pallas" if use_pallas else "jnp")
    members = (s_name,) + sm_members + (probs_name,)
    return Segment(tag, v_name, members, run)


# ---------------------------------------------------------------------------
# chain planning
# ---------------------------------------------------------------------------
def plan_chain(chain: Chain, *, backend: str = "auto", mxu_min: int = 128,
               segments: bool = True) -> Plan:
    consumers = chain.consumers()
    segs: Dict[str, Segment] = {}
    claimed: Dict[str, str] = {}         # interior node -> segment out
    if segments:
        # priority order matters: an attention segment's interior softmax
        # must not be claimed by the standalone softmax matcher first
        matchers = (
            lambda n: match_attention(chain, consumers, n, backend),
            lambda n: match_softmax(chain, consumers, n),
            lambda n: match_norm(chain, consumers, n, backend),
        )
        for matcher in matchers:
            for name in chain.nodes:
                if name in claimed or name in segs:
                    continue
                seg = matcher(name)
                if seg is None:
                    continue
                if any(m in claimed or m in segs for m in seg.members):
                    continue
                segs[seg.out] = seg
                for m in seg.members:
                    claimed[m] = seg.out

    steps: List[Step] = []
    dispatch: Dict[str, str] = {}
    for name, node in chain.nodes.items():
        if name in claimed:
            dispatch[name] = f"fused:{claimed[name]}"
            continue
        if name in segs:
            seg = segs[name]
            dispatch[name] = seg.kind
            steps.append(Step(name, seg.kind, seg.run))
            continue
        if isinstance(node, Concat):
            dispatch[name] = "concat"
            steps.append(Step(name, "concat", _concat_step(node)))
            continue
        if isinstance(node, Movement):
            dispatch[name] = "movement"
            steps.append(Step(name, "movement", _movement_step(node)))
            continue
        k_shape = (tuple(chain.shape_of(node.kernel))
                   if node.kernel is not None else None)
        tag, fn = dispatch_gconv(node, k_shape, backend, mxu_min)
        dispatch[name] = tag
        steps.append(Step(name, tag, _gconv_step(node, fn)))
    ins = ";".join(f"{n}:{'x'.join(map(str, i.shape))}:{i.dtype}"
                   for n, i in chain.inputs.items())
    prog = ";".join(f"{s.name}={s.backend}" for s in steps)
    return Plan(steps, dispatch, signature=f"{chain.name}|{ins}|{prog}")


def _gconv_step(node: GConv, fn: Callable) -> Callable:
    def run(env):
        x = env[node.input]
        k = env[node.kernel] if node.kernel is not None else None
        lookup = lambda op: env[op.operand]
        return fn(x, k, lookup)

    return run


def _concat_step(node: Concat) -> Callable:
    def run(env):
        return jnp.concatenate([env[r] for r in node.inputs], axis=node.axis)

    return run


def _movement_step(node: Movement) -> Callable:
    """Metadata-only reshape/transpose — the oracle's own Movement
    semantics (shared definition, gather stand-in included)."""
    from ..core.interpreter import apply_movement

    def run(env):
        return apply_movement(node, env[node.input])

    return run
