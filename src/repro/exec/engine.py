"""The compiled GCONV-chain execution engine.

``compile_chain`` turns a :class:`~repro.core.chain.Chain` into a
:class:`CompiledChain`: §4.3 fusion partitions the chain into fusion
groups (``exec.partition``), each group is dispatched to its best backend
(``exec.dispatch`` / ``exec.lowering``) and the whole program is emitted as
ONE jitted function — Movement/Concat nodes lower to metadata-only
reshape/transpose inside the same XLA program, so intermediates never make
the per-node round trip the oracle interpreter pays for.

The engine is differentially tested allclose against
:class:`~repro.core.interpreter.ChainExecutor` on the full CNN zoo and the
LM chain segments (tests/test_exec.py), and benchmarked against it per zoo
network (``python -m benchmarks.run --only exec``).

Usage mirrors the oracle::

    eng = compile_chain(chain)
    params = eng.init_params(jax.random.PRNGKey(0))
    outs = eng(inputs, params)            # dict of chain outputs
    eng.dispatch                          # node -> backend table

Mesh-aware mode: ``compile_chain(chain, mesh=mesh)`` derives a per-chain
:class:`~repro.exec.shardplan.ShardPlan` (data-parallel leading batch
axis, tensor-parallel grouped matmuls, divisibility-guarded fallback to
replication — the same policy as ``launch/sharding.py`` via
``repro.shardpolicy``) and compiles the SAME program against the mesh:
exact-shape calls jit with the plan's in-shardings and run the
tensor-parallel-wrapped steps; batched calls shard the leading bucket
axis over the data bundle (the bucket floor rises to the data-axis size
so every bucket divides). Differentially tested against the single-device
engine on faked host devices (tests/test_exec_sharded.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Mapping, Optional

import jax
import jax.numpy as jnp

from ..core.chain import Chain
from ..core.fusion import ExecGroup, FusionReport
from .batch import BucketedCache, batch_bucket, pad_leading, unpad_leading
from .dispatch import Plan, plan_chain
from .partition import partition_chain


@dataclass(frozen=True)
class CompileOptions:
    fuse: bool = True            # run §4.3 operation fusion first
    segments: bool = True        # recognize softmax/norm/attention segments
    backend: str = "auto"        # auto | jnp | pallas
    mxu_min: int = 128           # min K/N to prefer the Pallas matmul (auto)
    jit: bool = True
    profile: bool = False        # per-step timed spans into a repro.obs
                                 # tracer (see CompiledChain docstring)
    lint: Optional[str] = None   # off|info|warn|error: run the repro.lint
                                 # passes post-compile and raise LintError
                                 # at/above that severity. None reads the
                                 # REPRO_LINT env var (tests default it to
                                 # "error" in conftest.py; "off" elsewhere)
    tune: str = "off"            # off|readonly|auto|force: measured
                                 # (backend, block) selection per tunable
                                 # step against the persisted tuning DB
                                 # (repro.exec.tune; "readonly" never
                                 # measures, "force" always re-measures)
    tune_db: Optional[str] = None    # DB path; None -> results/tune/
    tune_budget: int = 16        # max measured candidates per step


class CompiledChain:
    """A chain compiled to one jitted function (plus introspection)."""

    def __init__(self, source: Chain, chain: Chain, report: FusionReport,
                 partitions: List[ExecGroup], plan: Plan,
                 options: CompileOptions, shard_plan=None, tracer=None):
        self.source = source
        self.chain = chain                   # the fused chain actually run
        self.fusion_report = report
        self.partitions = partitions
        self._plan = plan
        self.steps = plan.steps
        self.dispatch: Dict[str, str] = plan.dispatch
        self.options = options
        self.lint_report = None          # set by compile_chain when linted
        self.tune_report = None          # set by compile_chain when tuned
        # mesh-aware mode: the ShardPlan plus the step list with the
        # tensor-parallel matmuls re-lowered to their column/row split
        self.shard_plan = shard_plan
        self.mesh = shard_plan.mesh if shard_plan is not None else None
        if shard_plan is not None:
            from .shardplan import wrap_steps
            self._steps_sharded = wrap_steps(chain, self.steps, shard_plan)
            self._min_bucket = shard_plan.dp_size
        else:
            self._steps_sharded = self.steps
            self._min_bucket = 1
        self._fns: Dict[bool, object] = {}
        # leading-batch execution: one vmapped program per (keep_all,
        # batch bucket), cached per engine (exec.batch.BucketedCache)
        self._batched = BucketedCache(self._build_batched)
        # profiling (repro.obs): per-step jitted programs so each fusion-
        # group step can be timed device-synced. The DISABLED path costs
        # exactly one flag check in __call__ — no tracer object, span or
        # dict is ever allocated unless profiling is live.
        self._profile = options.profile
        self.tracer = None
        if options.profile:
            from ..obs.trace import Tracer
            self.tracer = tracer if tracer is not None else Tracer()
            self._step_fns: Dict[str, object] = {}

    # -- parameter init (the oracle's own recipe, shared) ---------------
    def init_params(self, key, scale: float = 0.1) -> Dict[str, jnp.ndarray]:
        from ..core.interpreter import init_chain_params
        return init_chain_params(self.chain, key, scale)

    # -- execution ------------------------------------------------------
    def _execute(self, inputs, params, keep_all: bool, steps=None):
        """``keep_all`` mirrors the oracle's contract (the whole
        environment: inputs, params and every produced node) — except
        that §4.3-fused members and segment-interior nodes do not exist
        in the compiled program and therefore have no entry (that is the
        point of fusing them; see ``dispatch`` for the ``fused:`` tags)."""
        env: Dict[str, jnp.ndarray] = dict(inputs)
        env.update(params)
        for step in (self.steps if steps is None else steps):
            env[step.name] = step.run(env)
        if keep_all:
            return env
        outs = self.chain.outputs or [list(self.chain.nodes)[-1]]
        return {o: env[o] for o in outs}

    def _fn(self, keep_all: bool):
        fn = self._fns.get(keep_all)
        if fn is None:
            if self.shard_plan is not None:
                run = (lambda inputs, params, _k=keep_all:
                       self._execute(inputs, params, _k,
                                     self._steps_sharded))
                if self.options.jit:
                    run = jax.jit(run, in_shardings=(
                        self.shard_plan.input_shardings(),
                        self.shard_plan.param_shardings()))
                fn = run
            elif self.options.jit:
                fn = jax.jit(
                    lambda inputs, params, _k=keep_all:
                    self._execute(inputs, params, _k))
            else:
                fn = (lambda inputs, params, _k=keep_all:
                      self._execute(inputs, params, _k))
            self._fns[keep_all] = fn
        return fn

    def _build_batched(self, key):
        keep_all, bucket = key           # bucket fixes the traced shape;
        run = (lambda ins, ps, _k=keep_all:   # one compile per cache entry
               self._execute(ins, ps, _k))
        fn = jax.vmap(run, in_axes=(0, None))
        if not self.options.jit:
            return fn
        if self.shard_plan is not None:
            # data-parallel replicas over the bucket axis: the tensor-
            # parallel step rewrites stay out of the vmapped program — the
            # mesh's contribution here is the leading-axis sharding (the
            # bucket floor is the dp size, so the axis always divides)
            return jax.jit(fn, in_shardings=(
                self.shard_plan.batched_input_shardings(self.chain, bucket),
                self.shard_plan.param_shardings()))
        return jax.jit(fn)

    def _batch_size(self, ins: Dict[str, jnp.ndarray]) -> Optional[int]:
        """None for exact chain shapes; N when every input carries one
        extra leading batch axis of the same size N (the batched mode)."""
        exact = all(tuple(a.shape) == self.chain.inputs[n].shape
                    for n, a in ins.items())
        if exact:
            return None
        sizes = set()
        for name, arr in ins.items():
            want = self.chain.inputs[name].shape
            if arr.ndim != len(want) + 1 or tuple(arr.shape[1:]) != want:
                raise ValueError(
                    f"input {name!r}: got {arr.shape}, want {want} or "
                    f"batch-extended (N,)+{want}")
            sizes.add(arr.shape[0])
        if len(sizes) != 1:
            raise ValueError(
                f"inconsistent leading batch sizes {sorted(sizes)}")
        return sizes.pop()

    # -- profiled execution (repro.obs) ---------------------------------
    def _step_fn(self, step):
        """Per-step jitted program (profile mode runs steps one by one so
        each can be block_until_ready-timed; the single fused program of
        the fast path cannot attribute time to its interior)."""
        fn = self._step_fns.get(step.name)
        if fn is None:
            run = step.run
            fn = jax.jit(run) if self.options.jit else run
            self._step_fns[step.name] = fn
        return fn

    def _profiled(self, ins, ps, keep_all):
        """Exact-shape execution with one device-synced span per fusion-
        group step, attributed with the step's backend tag and the plan
        signature. The first run of a step is recorded under cat
        ``compile`` (trace + XLA compile + execute), steady-state runs
        under cat ``execute`` — so compile time never pollutes the
        execute-time attribution. The loop keeps only two clock reads of
        bookkeeping per step and defers event construction until after
        the enclosing chain span closes, so >= 95% of the chain span's
        wall time is attributed to named steps (the report CLI's
        ``profile.coverage``)."""
        import time as _time

        tr = self.tracer
        sig = self._plan.signature
        env: Dict[str, jnp.ndarray] = dict(ins)
        env.update(ps)
        steps = self._steps_sharded
        step_fns = self._step_fns
        marks = []
        with tr.span(f"chain:{self.chain.name}", cat="chain",
                     attrs={"signature": sig,
                            "steps": len(steps)}) as chain_span:
            for step in steps:
                compiled = step.name in step_fns
                fn = step_fns[step.name] if compiled else self._step_fn(step)
                t0 = _time.perf_counter()
                out = jax.block_until_ready(fn(env))
                t1 = _time.perf_counter()
                env[step.name] = out
                marks.append((step, compiled, t0, t1))
        parent = getattr(chain_span, "id", None)
        for step, compiled, t0, t1 in marks:
            tr.add_span(step.name, "execute" if compiled else "compile",
                        t0, t1, parent=parent,
                        attrs={"backend": step.backend, "signature": sig})
        if keep_all:
            return env
        outs = self.chain.outputs or [list(self.chain.nodes)[-1]]
        return {o: env[o] for o in outs}

    def __call__(self,
                 inputs: Mapping[str, jnp.ndarray],
                 params: Optional[Mapping[str, jnp.ndarray]] = None,
                 keep_all: bool = False) -> Dict[str, jnp.ndarray]:
        params = params or {}
        ins = {}
        for name in self.chain.inputs:
            if name not in inputs:
                raise ValueError(f"missing chain input {name!r}")
            ins[name] = jnp.asarray(inputs[name])
        ps = {}
        for name in self.chain.params:
            if name not in params:
                raise ValueError(f"missing chain param {name!r}")
            ps[name] = jnp.asarray(params[name])
        n = self._batch_size(ins)
        profiling = self._profile and self.tracer.enabled
        if n is None:
            if profiling:
                return self._profiled(ins, ps, keep_all)
            return dict(self._fn(keep_all)(ins, ps))
        bucket = batch_bucket(n, self._min_bucket)
        if profiling:
            # batched programs are one fused vmap: attribute the call as a
            # whole (per-step attribution is an exact-shape-mode feature)
            with self.tracer.span(f"batched:{self.chain.name}", cat="chain",
                                  attrs={"backend": "batched", "n": n,
                                         "bucket": bucket,
                                         "signature":
                                             self._plan.signature}):
                fn = self._batched.get((keep_all, bucket))
                out = jax.block_until_ready(fn(pad_leading(ins, bucket), ps))
            return dict(unpad_leading(out, n))
        fn = self._batched.get((keep_all, bucket))
        out = fn(pad_leading(ins, bucket), ps)
        return dict(unpad_leading(out, n))

    # -- batched-mode introspection -------------------------------------
    @property
    def batch_compiles(self) -> int:
        """Distinct batched programs compiled so far (== #buckets seen)."""
        return self._batched.compiles

    @property
    def batch_buckets(self):
        return sorted({b for _k, b in self._batched.keys()})

    @property
    def signature(self) -> str:
        """Stable program identity (chain name + input shapes + dispatch
        decisions, plus the mesh and tensor-parallel splits when sharded);
        introspection/reporting metadata — equal-signature engines run the
        same program."""
        sig = self._plan.signature
        if self.shard_plan is not None:
            mesh_s = "x".join(f"{a}{n}"
                              for a, n in self.shard_plan.mesh.shape.items())
            tp_s = ",".join(f"{n}={m}"
                            for n, m in sorted(self.shard_plan.step_tp.items()))
            sig += f"|mesh={mesh_s}|tp={tp_s}"
        return sig

    # -- introspection --------------------------------------------------
    def backend_histogram(self) -> Dict[str, int]:
        hist: Dict[str, int] = {}
        for tag in self.dispatch.values():
            key = tag.split(":")[0] if tag.startswith("fused") else tag
            hist[key] = hist.get(key, 0) + 1
        return hist

    def pretty(self) -> str:
        lines = [f"CompiledChain {self.chain.name!r}: "
                 f"{len(self.steps)} steps from {len(self.source.nodes)} "
                 f"nodes (fusion {self.fusion_report.before_len}->"
                 f"{self.fusion_report.after_len})"]
        for name, tag in self.dispatch.items():
            lines.append(f"  {name}: {tag}")
        return "\n".join(lines)


def compile_chain(chain: Chain, mesh=None, tracer=None,
                  **options) -> CompiledChain:
    """Compile a chain for execution. See :class:`CompileOptions`.

    ``mesh``: a ``jax.sharding.Mesh`` to compile a SHARDED program against
    (see the module docstring); ``None`` keeps the single-device engine.

    ``profile=True``: wrap each fusion-group step in a device-synced timed
    span recorded into ``engine.tracer`` (a fresh ``repro.obs.trace.
    Tracer`` unless ``tracer=`` is given) — backend + plan-signature
    attributed, compile events separate from execute events; export with
    ``engine.tracer.write(path)`` and summarize with ``python -m
    repro.obs.report``. With the default ``profile=False`` the hot path
    is untouched beyond one flag check per call.

    ``lint="error"``: run the `repro.lint` static passes over the compiled
    artifacts (chain + plan + shard plan) and raise
    :class:`~repro.lint.LintError` on findings at/above the given
    severity; the full report lands on ``engine.lint_report`` either way.
    ``lint=None`` (default) reads the ``REPRO_LINT`` env var ("off" when
    unset; conftest.py defaults it to "error" so every test-compiled
    chain is verified).

    ``tune="auto"``: after heuristic planning, re-lower each tunable step
    to the measured-fastest (backend, block) candidate — DB hits under
    ``results/tune/`` are pure lookups, misses are measured on-device and
    persisted (see :mod:`repro.exec.tune`). ``tune="readonly"`` applies
    hits but never measures; ``tune="force"`` re-measures everything. The
    decisions land in ``Step.meta['tuned']`` (audited by the
    ``plan.tuned-contract`` lint rule), the per-group report on
    ``engine.tune_report``.
    """
    import os

    opts = CompileOptions(**options)
    chain.validate()
    fused, report, parts = partition_chain(chain, fuse=opts.fuse)
    plan = plan_chain(fused, backend=opts.backend, mxu_min=opts.mxu_min,
                      segments=opts.segments)
    tune_report = None
    if opts.tune != "off":
        from .tune import tune_plan
        plan, tune_report = tune_plan(
            fused, plan, mode=opts.tune, db_path=opts.tune_db,
            budget=opts.tune_budget, backend=opts.backend, tracer=tracer)
    shard_plan = None
    if mesh is not None and not mesh.empty:
        from .shardplan import derive_plan
        shard_plan = derive_plan(fused, plan.dispatch, mesh)
    # §4.3-fused nodes no longer exist in the fused chain; record them in
    # the dispatch table so every ORIGINAL node has an entry
    for host, members in report.groups.items():
        for m in members:
            plan.dispatch.setdefault(m, f"fused:{host}")
    eng = CompiledChain(chain, fused, report, parts, plan, opts,
                        shard_plan, tracer)
    eng.tune_report = tune_report
    level = opts.lint if opts.lint is not None \
        else os.environ.get("REPRO_LINT", "off")
    if level and level != "off":
        from ..lint import LintError, lint_compiled
        eng.lint_report = lint_compiled(eng)
        if eng.lint_report.at_least(level):
            raise LintError(eng.lint_report, level)
    return eng
