"""The compiled GCONV-chain execution engine.

``compile_chain`` turns a :class:`~repro.core.chain.Chain` into a
:class:`CompiledChain`: §4.3 fusion partitions the chain into fusion
groups (``exec.partition``), each group is dispatched to its best backend
(``exec.dispatch`` / ``exec.lowering``) and the whole program is emitted as
ONE jitted function — Movement/Concat nodes lower to metadata-only
reshape/transpose inside the same XLA program, so intermediates never make
the per-node round trip the oracle interpreter pays for.

The engine is differentially tested allclose against
:class:`~repro.core.interpreter.ChainExecutor` on the full CNN zoo and the
LM chain segments (tests/test_exec.py), and benchmarked against it per zoo
network (``python -m benchmarks.run --only exec``).

Usage mirrors the oracle::

    eng = compile_chain(chain)
    params = eng.init_params(jax.random.PRNGKey(0))
    outs = eng(inputs, params)            # dict of chain outputs
    eng.dispatch                          # node -> backend table
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Mapping, Optional

import jax
import jax.numpy as jnp

from ..core.chain import Chain
from ..core.fusion import ExecGroup, FusionReport
from .batch import BucketedCache, batch_bucket, pad_leading, unpad_leading
from .dispatch import Plan, plan_chain
from .partition import partition_chain


@dataclass(frozen=True)
class CompileOptions:
    fuse: bool = True            # run §4.3 operation fusion first
    segments: bool = True        # recognize softmax/norm/attention segments
    backend: str = "auto"        # auto | jnp | pallas
    mxu_min: int = 128           # min K/N to prefer the Pallas matmul (auto)
    jit: bool = True


class CompiledChain:
    """A chain compiled to one jitted function (plus introspection)."""

    def __init__(self, source: Chain, chain: Chain, report: FusionReport,
                 partitions: List[ExecGroup], plan: Plan,
                 options: CompileOptions):
        self.source = source
        self.chain = chain                   # the fused chain actually run
        self.fusion_report = report
        self.partitions = partitions
        self._plan = plan
        self.steps = plan.steps
        self.dispatch: Dict[str, str] = plan.dispatch
        self.options = options
        self._fns: Dict[bool, object] = {}
        # leading-batch execution: one vmapped program per (keep_all,
        # batch bucket), cached per engine (exec.batch.BucketedCache)
        self._batched = BucketedCache(self._build_batched)

    # -- parameter init (the oracle's own recipe, shared) ---------------
    def init_params(self, key, scale: float = 0.1) -> Dict[str, jnp.ndarray]:
        from ..core.interpreter import init_chain_params
        return init_chain_params(self.chain, key, scale)

    # -- execution ------------------------------------------------------
    def _execute(self, inputs, params, keep_all: bool):
        """``keep_all`` mirrors the oracle's contract (the whole
        environment: inputs, params and every produced node) — except
        that §4.3-fused members and segment-interior nodes do not exist
        in the compiled program and therefore have no entry (that is the
        point of fusing them; see ``dispatch`` for the ``fused:`` tags)."""
        env: Dict[str, jnp.ndarray] = dict(inputs)
        env.update(params)
        for step in self.steps:
            env[step.name] = step.run(env)
        if keep_all:
            return env
        outs = self.chain.outputs or [list(self.chain.nodes)[-1]]
        return {o: env[o] for o in outs}

    def _fn(self, keep_all: bool):
        fn = self._fns.get(keep_all)
        if fn is None:
            if self.options.jit:
                fn = jax.jit(
                    lambda inputs, params, _k=keep_all:
                    self._execute(inputs, params, _k))
            else:
                fn = (lambda inputs, params, _k=keep_all:
                      self._execute(inputs, params, _k))
            self._fns[keep_all] = fn
        return fn

    def _build_batched(self, key):
        keep_all, _bucket = key          # bucket fixes the traced shape;
        run = (lambda ins, ps, _k=keep_all:   # one compile per cache entry
               self._execute(ins, ps, _k))
        fn = jax.vmap(run, in_axes=(0, None))
        return jax.jit(fn) if self.options.jit else fn

    def _batch_size(self, ins: Dict[str, jnp.ndarray]) -> Optional[int]:
        """None for exact chain shapes; N when every input carries one
        extra leading batch axis of the same size N (the batched mode)."""
        exact = all(tuple(a.shape) == self.chain.inputs[n].shape
                    for n, a in ins.items())
        if exact:
            return None
        sizes = set()
        for name, arr in ins.items():
            want = self.chain.inputs[name].shape
            if arr.ndim != len(want) + 1 or tuple(arr.shape[1:]) != want:
                raise ValueError(
                    f"input {name!r}: got {arr.shape}, want {want} or "
                    f"batch-extended (N,)+{want}")
            sizes.add(arr.shape[0])
        if len(sizes) != 1:
            raise ValueError(
                f"inconsistent leading batch sizes {sorted(sizes)}")
        return sizes.pop()

    def __call__(self,
                 inputs: Mapping[str, jnp.ndarray],
                 params: Optional[Mapping[str, jnp.ndarray]] = None,
                 keep_all: bool = False) -> Dict[str, jnp.ndarray]:
        params = params or {}
        ins = {}
        for name in self.chain.inputs:
            if name not in inputs:
                raise ValueError(f"missing chain input {name!r}")
            ins[name] = jnp.asarray(inputs[name])
        ps = {}
        for name in self.chain.params:
            if name not in params:
                raise ValueError(f"missing chain param {name!r}")
            ps[name] = jnp.asarray(params[name])
        n = self._batch_size(ins)
        if n is None:
            return dict(self._fn(keep_all)(ins, ps))
        bucket = batch_bucket(n)
        fn = self._batched.get((keep_all, bucket))
        out = fn(pad_leading(ins, bucket), ps)
        return dict(unpad_leading(out, n))

    # -- batched-mode introspection -------------------------------------
    @property
    def batch_compiles(self) -> int:
        """Distinct batched programs compiled so far (== #buckets seen)."""
        return self._batched.compiles

    @property
    def batch_buckets(self):
        return sorted({b for _k, b in self._batched.keys()})

    @property
    def signature(self) -> str:
        """Stable program identity (chain name + input shapes + dispatch
        decisions); introspection/reporting metadata — equal-signature
        engines run the same program."""
        return self._plan.signature

    # -- introspection --------------------------------------------------
    def backend_histogram(self) -> Dict[str, int]:
        hist: Dict[str, int] = {}
        for tag in self.dispatch.values():
            key = tag.split(":")[0] if tag.startswith("fused") else tag
            hist[key] = hist.get(key, 0) + 1
        return hist

    def pretty(self) -> str:
        lines = [f"CompiledChain {self.chain.name!r}: "
                 f"{len(self.steps)} steps from {len(self.source.nodes)} "
                 f"nodes (fusion {self.fusion_report.before_len}->"
                 f"{self.fusion_report.after_len})"]
        for name, tag in self.dispatch.items():
            lines.append(f"  {name}: {tag}")
        return "\n".join(lines)


def compile_chain(chain: Chain, **options) -> CompiledChain:
    """Compile a chain for execution. See :class:`CompileOptions`."""
    opts = CompileOptions(**options)
    chain.validate()
    fused, report, parts = partition_chain(chain, fuse=opts.fuse)
    plan = plan_chain(fused, backend=opts.backend, mxu_min=opts.mxu_min,
                      segments=opts.segments)
    # §4.3-fused nodes no longer exist in the fused chain; record them in
    # the dispatch table so every ORIGINAL node has an entry
    for host, members in report.groups.items():
        for m in members:
            plan.dispatch.setdefault(m, f"fused:{host}")
    return CompiledChain(chain, fused, report, parts, plan, opts)
