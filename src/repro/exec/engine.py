"""The compiled GCONV-chain execution engine.

``compile_chain`` turns a :class:`~repro.core.chain.Chain` into a
:class:`CompiledChain`: §4.3 fusion partitions the chain into fusion
groups (``exec.partition``), each group is dispatched to its best backend
(``exec.dispatch`` / ``exec.lowering``) and the whole program is emitted as
ONE jitted function — Movement/Concat nodes lower to metadata-only
reshape/transpose inside the same XLA program, so intermediates never make
the per-node round trip the oracle interpreter pays for.

The engine is differentially tested allclose against
:class:`~repro.core.interpreter.ChainExecutor` on the full CNN zoo and the
LM chain segments (tests/test_exec.py), and benchmarked against it per zoo
network (``python -m benchmarks.run --only exec``).

Usage mirrors the oracle::

    eng = compile_chain(chain)
    params = eng.init_params(jax.random.PRNGKey(0))
    outs = eng(inputs, params)            # dict of chain outputs
    eng.dispatch                          # node -> backend table
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Mapping, Optional

import jax
import jax.numpy as jnp

from ..core.chain import Chain
from ..core.fusion import ExecGroup, FusionReport
from .dispatch import Plan, plan_chain
from .partition import partition_chain


@dataclass(frozen=True)
class CompileOptions:
    fuse: bool = True            # run §4.3 operation fusion first
    segments: bool = True        # recognize softmax/norm/attention segments
    backend: str = "auto"        # auto | jnp | pallas
    mxu_min: int = 128           # min K/N to prefer the Pallas matmul (auto)
    jit: bool = True


class CompiledChain:
    """A chain compiled to one jitted function (plus introspection)."""

    def __init__(self, source: Chain, chain: Chain, report: FusionReport,
                 partitions: List[ExecGroup], plan: Plan,
                 options: CompileOptions):
        self.source = source
        self.chain = chain                   # the fused chain actually run
        self.fusion_report = report
        self.partitions = partitions
        self.steps = plan.steps
        self.dispatch: Dict[str, str] = plan.dispatch
        self.options = options
        self._fns: Dict[bool, object] = {}

    # -- parameter init (the oracle's own recipe, shared) ---------------
    def init_params(self, key, scale: float = 0.1) -> Dict[str, jnp.ndarray]:
        from ..core.interpreter import init_chain_params
        return init_chain_params(self.chain, key, scale)

    # -- execution ------------------------------------------------------
    def _execute(self, inputs, params, keep_all: bool):
        """``keep_all`` mirrors the oracle's contract (the whole
        environment: inputs, params and every produced node) — except
        that §4.3-fused members and segment-interior nodes do not exist
        in the compiled program and therefore have no entry (that is the
        point of fusing them; see ``dispatch`` for the ``fused:`` tags)."""
        env: Dict[str, jnp.ndarray] = dict(inputs)
        env.update(params)
        for step in self.steps:
            env[step.name] = step.run(env)
        if keep_all:
            return env
        outs = self.chain.outputs or [list(self.chain.nodes)[-1]]
        return {o: env[o] for o in outs}

    def _fn(self, keep_all: bool):
        fn = self._fns.get(keep_all)
        if fn is None:
            if self.options.jit:
                fn = jax.jit(
                    lambda inputs, params, _k=keep_all:
                    self._execute(inputs, params, _k))
            else:
                fn = (lambda inputs, params, _k=keep_all:
                      self._execute(inputs, params, _k))
            self._fns[keep_all] = fn
        return fn

    def __call__(self,
                 inputs: Mapping[str, jnp.ndarray],
                 params: Optional[Mapping[str, jnp.ndarray]] = None,
                 keep_all: bool = False) -> Dict[str, jnp.ndarray]:
        params = params or {}
        ins = {}
        for name, info in self.chain.inputs.items():
            if name not in inputs:
                raise ValueError(f"missing chain input {name!r}")
            arr = jnp.asarray(inputs[name])
            if tuple(arr.shape) != info.shape:
                raise ValueError(
                    f"input {name!r}: got {arr.shape}, want {info.shape}")
            ins[name] = arr
        ps = {}
        for name in self.chain.params:
            if name not in params:
                raise ValueError(f"missing chain param {name!r}")
            ps[name] = jnp.asarray(params[name])
        return dict(self._fn(keep_all)(ins, ps))

    # -- introspection --------------------------------------------------
    def backend_histogram(self) -> Dict[str, int]:
        hist: Dict[str, int] = {}
        for tag in self.dispatch.values():
            key = tag.split(":")[0] if tag.startswith("fused") else tag
            hist[key] = hist.get(key, 0) + 1
        return hist

    def pretty(self) -> str:
        lines = [f"CompiledChain {self.chain.name!r}: "
                 f"{len(self.steps)} steps from {len(self.source.nodes)} "
                 f"nodes (fusion {self.fusion_report.before_len}->"
                 f"{self.fusion_report.after_len})"]
        for name, tag in self.dispatch.items():
            lines.append(f"  {name}: {tag}")
        return "\n".join(lines)


def compile_chain(chain: Chain, **options) -> CompiledChain:
    """Compile a chain for execution. See :class:`CompileOptions`."""
    opts = CompileOptions(**options)
    chain.validate()
    fused, report, parts = partition_chain(chain, fuse=opts.fuse)
    plan = plan_chain(fused, backend=opts.backend, mxu_min=opts.mxu_min,
                      segments=opts.segments)
    # §4.3-fused nodes no longer exist in the fused chain; record them in
    # the dispatch table so every ORIGINAL node has an entry
    for host, members in report.groups.items():
        for m in members:
            plan.dispatch.setdefault(m, f"fused:{host}")
    return CompiledChain(chain, fused, report, parts, plan, opts)
