"""Fusion-group partitioning for the compiled engine (paper §4.3).

Runs operation fusion over a chain and re-exposes the resulting groups as
ordered *execution partitions*: one partition per surviving node, carrying
the fused members that now ride on its pre/post operator path. The engine
emits exactly one step per partition, so the §4.3 movement savings become
real: a fused member's intermediate tensor never exists in the compiled
program — XLA sees only the host node's fused operator sequence.
"""
from __future__ import annotations

from typing import List, Tuple

from ..core.chain import Chain
from ..core.fusion import (ExecGroup, FusionReport, execution_partitions,
                           fuse_chain)


def partition_chain(chain: Chain,
                    fuse: bool = True) -> Tuple[Chain, FusionReport,
                                                List[ExecGroup]]:
    """Fuse (optionally) and partition. With ``fuse=False`` the chain is
    returned as-is with singleton partitions — the differential-testing
    configuration (compiled-unfused vs compiled-fused vs oracle)."""
    if fuse:
        fused, report = fuse_chain(chain)
    else:
        fused = chain
        report = FusionReport(len(chain.nodes), len(chain.nodes), [], 0, {})
    return fused, report, execution_partitions(fused, report)
