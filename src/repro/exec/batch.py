"""Leading-batch bucketing for the compiled engine (and the serve driver).

A compiled program is shape-specialized, so executing "any batch size"
naively means one XLA compile per batch size ever seen — a compile storm
under continuous batching, where the number of co-resident requests
changes every admission. The standard fix (vLLM-style serving stacks, XLA
bucketing) is to quantize the leading axis to a small ladder of *buckets*:
pad the batch up to the nearest bucket, run the bucket-shaped program,
slice the real rows back out. The compile count is then bounded by the
number of buckets, not the number of batch sizes.

:class:`BucketedCache` is the shared compile-cache type: the batched
:class:`~repro.exec.engine.CompiledChain` path keys its jitted programs on
``(keep_all, batch bucket)`` through one instance per engine, and the
serving programs in :mod:`repro.exec.serving` key theirs on
``(batch bucket, length bucket)``. Caches are per-program-family (one per
engine), so the program identity (``CompiledChain.signature``) stays out
of the key; it is introspection/reporting metadata.

Mesh-aware mode (``compile_chain(mesh=...)`` / ``ServeEngine(mesh=...)``)
threads through here as the ``min_bucket`` floor: sharded engines bucket
with ``min_bucket = data-axis size``, so every bucket is
``dp_size * 2**k`` and the leading axis ALWAYS divides the data-parallel
mesh axis — the sharded batched program never needs a padding-vs-sharding
special case, and :func:`pad_leading`'s zero rows stay inert per replica
exactly as they are on one device (row independence, see exec.lowering).
"""
from __future__ import annotations

from typing import Callable, Dict, Hashable, List

import jax
import jax.numpy as jnp


def batch_bucket(n: int, min_bucket: int = 1) -> int:
    """Smallest ``min_bucket * 2**k`` >= n (power-of-two ladder).

    Contract (property-tested in tests/test_exec_batched.py): the result
    is >= n, >= min_bucket, exactly ``min_bucket`` times a power of two,
    monotone in ``n``, and idempotent — so with ``min_bucket`` set to a
    mesh's data-axis size, every bucket divides that axis.
    """
    if n < 1:
        raise ValueError(f"batch size must be >= 1, got {n}")
    b = max(1, min_bucket)
    while b < n:
        b *= 2
    return b


def pad_leading(x, bucket: int):
    """Pad axis 0 of every array leaf up to ``bucket`` rows (zeros).

    Padded rows run through the same program as real rows; callers slice
    them away with :func:`unpad_leading`. Sound because every batched
    program here is row-independent (vmap / per-row cache bookkeeping).
    """
    def one(a):
        a = jnp.asarray(a)
        n = a.shape[0]
        if n == bucket:
            return a
        pad = [(0, bucket - n)] + [(0, 0)] * (a.ndim - 1)
        return jnp.pad(a, pad)

    return jax.tree.map(one, x)


def unpad_leading(x, n: int):
    """Slice axis 0 of every array leaf back to the real ``n`` rows."""
    return jax.tree.map(lambda a: a[:n], x)


class BucketedCache:
    """Compile cache keyed on bucket tuples.

    ``build(key)`` is called once per distinct key; the result (a jitted
    callable) is cached forever. ``compiles`` counts distinct programs —
    the invariant the tests pin down: after any sequence of batch sizes,
    ``compiles == len(set(buckets seen))``.
    """

    def __init__(self, build: Callable[[Hashable], Callable]):
        self._build = build
        self._fns: Dict[Hashable, Callable] = {}
        self.compiles = 0

    def get(self, key: Hashable) -> Callable:
        fn = self._fns.get(key)
        if fn is None:
            fn = self._build(key)
            self._fns[key] = fn
            self.compiles += 1
        return fn

    def keys(self) -> List[Hashable]:
        return list(self._fns)

    def __len__(self) -> int:
        return len(self._fns)
