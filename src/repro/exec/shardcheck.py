"""Sharded-vs-single-device checks for the mesh-aware compiled engine.

The one driver behind tests/test_exec_sharded.py, the ``exec_sharded``
benchmark cell and the ``exec_sharded_micro`` FAST CI gate: compile each
requested program twice — single-device and against a mesh — and compare.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python -m repro.exec.shardcheck \\
        --mesh 8x1 --nets MN --lm --serve --bench 0

Run WITHOUT enough devices, the driver re-execs itself in a subprocess
with the fake-device flag set (the device count locks at the first jax
initialization, so it cannot be raised in-process).

Checks (each a row in the JSON report printed as the last stdout line):

  * ``net:<name>``  — zoo chain, sharded exact-mode outputs vs the
                      single-device engine, allclose rtol 1e-4;
  * ``lm:dense`` / ``lm:moe`` — the LM block chains, same comparison,
                      plus the dense block in batched (leading-batch)
                      mode against single-device per-sample rows;
  * ``serve``       — staggered continuous batching on a data-parallel
                      mesh vs the sequential single-slot reference,
                      byte-identical token streams required;
  * ``bench``       — steady-state batched throughput, single vs sharded
                      (items/s and the scaling ratio; smoke scale).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

RTOL = 1e-4
# the scaling bench needs enough per-device work to amortize multi-device
# dispatch; these smoke-scale shapes give >1.2x on a 2-core CI host
BENCH_D_MODEL, BENCH_SEQ, BENCH_BATCH = 128, 64, 128


def _mesh_devices(spec: str) -> int:
    from repro.shardpolicy import parse_mesh_spec

    d, m = parse_mesh_spec(spec)
    return d * m


def _reexec(argv, devices: int) -> int:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={devices}")
    proc = subprocess.run([sys.executable, "-m", "repro.exec.shardcheck",
                           *argv], env=env)
    return proc.returncode


def _tiny_cfg(**kw):
    from repro.models.common import ModelConfig

    base = dict(name="tiny", family="dense", n_layers=1, d_model=16,
                n_heads=2, n_kv_heads=2, d_ff=32, vocab=64)
    base.update(kw)
    return ModelConfig(**base)


def _compare(chain, mesh):
    """(max_err, ok, tp_steps) of sharded vs single-device exact mode."""
    import jax
    import jax.numpy as jnp

    from repro.core.interpreter import ChainExecutor
    from repro.exec import compile_chain
    from repro.models import cnn

    params = ChainExecutor(chain).init_params(jax.random.PRNGKey(0))
    inputs = cnn.random_inputs(chain, 1)
    ref = compile_chain(chain)(inputs, params)
    eng = compile_chain(chain, mesh=mesh)
    got = eng(inputs, params)
    err = 0.0
    ok = True
    for o in ref:
        r = jnp.asarray(ref[o], jnp.float32)
        g = jnp.asarray(got[o], jnp.float32)
        err_o = float(jnp.max(jnp.abs(g - r)))
        tol_o = RTOL * float(jnp.max(jnp.abs(r))) + RTOL
        err = max(err, err_o)
        ok = ok and err_o <= tol_o        # each output vs its OWN scale
    return err, ok, len(eng.shard_plan.step_tp)


def check_net(name, mesh):
    from repro.models import cnn

    chain = cnn.build(name, reduced=True, batch=2)
    err, ok, tp = _compare(chain, mesh)
    return {"check": f"net:{name}", "max_err": err, "tp_steps": tp,
            "ok": ok}


def check_lm(kind, mesh):
    import jax
    import jax.numpy as jnp

    from repro.core.interpreter import ChainExecutor
    from repro.exec import compile_chain
    from repro.models import cnn, lm_chain

    cfg = (_tiny_cfg() if kind == "dense"
           else _tiny_cfg(name="tiny-moe", family="moe", n_experts=4,
                          top_k=2))
    chain = lm_chain.block_chain(cfg, 2, 8)
    err, ok, tp = _compare(chain, mesh)
    row = {"check": f"lm:{kind}", "max_err": err, "tp_steps": tp, "ok": ok}
    if kind == "dense":
        # batched mode: sharded leading-batch rows vs single-device
        # per-sample execution
        params = ChainExecutor(chain).init_params(jax.random.PRNGKey(0))
        ins = cnn.random_inputs(chain, 1)
        n = 2 * mesh.devices.size
        key = jax.random.PRNGKey(7)
        batched = {k: jax.random.normal(jax.random.fold_in(key, i),
                                        (n,) + tuple(v.shape))
                   for i, (k, v) in enumerate(sorted(ins.items()))}
        e1 = compile_chain(chain)
        e8 = compile_chain(chain, mesh=mesh)
        got = e8(batched, params)
        berr = 0.0
        for j in range(n):
            one = e1({k: v[j] for k, v in batched.items()}, params)
            for o in one:
                berr = max(berr, float(jnp.max(jnp.abs(
                    got[o][j] - one[o]))))
        row["batched_max_err"] = berr
        row["batched_buckets"] = e8.batch_buckets
        row["ok"] = bool(row["ok"] and berr <= RTOL)
    return row


def check_serve(mesh):
    """Staggered DP-mesh serving vs the sequential single-slot reference."""
    from repro.launch.serve import Request, Server, sequential_reference
    import numpy as np

    slots = mesh.devices.size
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, 256, rng.integers(2, 6)).tolist(),
                    max_new=6)
            for i in range(slots + 4)]
    srv = Server("tinyllama-1.1b", smoke=True, slots=slots, max_len=48,
                 mesh=mesh)
    srv.run_workload([Request(rid=r.rid, prompt=list(r.prompt),
                              max_new=r.max_new) for r in reqs],
                     stagger_ticks=2)
    got = {r.rid: r.out for r in srv.finished}
    ref = sequential_reference(
        "tinyllama-1.1b",
        [Request(rid=r.rid, prompt=list(r.prompt), max_new=r.max_new)
         for r in reqs], max_len=48)
    identical = all(got[r.rid] == ref[i] for i, r in enumerate(reqs))
    return {"check": "serve", "slots": slots, "requests": len(reqs),
            "identical_to_sequential": bool(identical),
            "ok": bool(identical)}


def bench_scaling(iters=3):
    """Steady-state batched throughput: single device vs data-parallel.

    Benches a pure data-parallel mesh over ALL devices (not the check
    mesh — its model axis is deliberately ignored): the scaling story at
    smoke scale is DP replicas — tensor-splitting matmuls this small only
    adds dispatch overhead, which the correctness checks tolerate but a
    throughput gate must not."""
    import jax
    import jax.numpy as jnp

    from repro.core.interpreter import ChainExecutor
    from repro.exec import compile_chain
    from repro.models import cnn, lm_chain

    mesh = jax.make_mesh((len(jax.devices()), 1), ("data", "model"))

    cfg = _tiny_cfg(d_model=BENCH_D_MODEL, n_heads=4, n_kv_heads=4,
                    d_ff=2 * BENCH_D_MODEL, vocab=256)
    chain = lm_chain.block_chain(cfg, 2, BENCH_SEQ)
    params = ChainExecutor(chain).init_params(jax.random.PRNGKey(0))
    ins = cnn.random_inputs(chain, 1)
    batched = {k: jnp.stack([v] * BENCH_BATCH) for k, v in ins.items()}

    def best(eng):
        t = float("inf")
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(eng(batched, params))
            t = min(t, time.perf_counter() - t0)
        return t

    e1 = compile_chain(chain)
    en = compile_chain(chain, mesh=mesh)
    jax.block_until_ready(e1(batched, params))            # compile+warm
    jax.block_until_ready(en(batched, params))
    # interleaved rounds, gate on the best: scheduling noise on a small
    # shared CI host (8 device threads on ~2 cores) swings single-round
    # ratios by +-30%, and a flaky throughput gate is worse than a
    # slightly lenient one — a genuinely broken sharded path stays below
    # 1.0 in every round
    t1 = tn = float("inf")
    scaling = 0.0
    for _ in range(3):
        t1 = min(t1, best(e1))
        tn = min(tn, best(en))
        scaling = t1 / tn
        if scaling > 1.0:
            break
    return {"check": "bench", "devices": mesh.devices.size,
            "batch": BENCH_BATCH,
            "single_items_per_s": round(BENCH_BATCH / t1, 1),
            "sharded_items_per_s": round(BENCH_BATCH / tn, 1),
            "scaling": round(scaling, 3),
            "ok": bool(scaling > 1.0)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="8x1", help="'D' or 'DxM'")
    ap.add_argument("--nets", default="",
                    help="comma list of zoo nets, or 'all'")
    ap.add_argument("--lm", action="store_true",
                    help="check the LM dense + MoE blocks")
    ap.add_argument("--serve", action="store_true",
                    help="check staggered DP serving vs sequential")
    ap.add_argument("--bench", type=int, default=-1, metavar="ITERS",
                    help="scaling bench iters (0 = default 3, -1 = skip)")
    args = ap.parse_args(argv)

    need = _mesh_devices(args.mesh)
    import jax                       # first init locks the device count

    if len(jax.devices()) < need:
        raise SystemExit(_reexec(sys.argv[1:] if argv is None else argv,
                                 need))

    from repro.launch.mesh import mesh_from_spec
    from repro.models import cnn

    mesh = mesh_from_spec(args.mesh)
    rows = []
    nets = (list(cnn.ZOO) if args.nets == "all"
            else [n for n in args.nets.split(",") if n])
    for name in nets:
        rows.append(check_net(name, mesh))
    if args.lm:
        rows.append(check_lm("dense", mesh))
        rows.append(check_lm("moe", mesh))
    if args.serve:
        rows.append(check_serve(mesh))
    if args.bench >= 0:
        rows.append(bench_scaling(iters=args.bench or 3))
    report = {"mesh": args.mesh, "devices": len(jax.devices()),
              "rows": rows, "ok": bool(rows) and all(r["ok"] for r in rows)}
    print(json.dumps(report))
    raise SystemExit(0 if report["ok"] else 1)


if __name__ == "__main__":
    main()
