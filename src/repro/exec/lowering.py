"""Per-GCONV backend lowerings for the compiled chain engine.

Every GCONV dimension falls into one of four classes (derived from its four
loop parameters, paper §3.1):

  * ``bcast``    — no taps, no kernel replication, unit stride/pad: the
                   input axis maps to the output axis identically
                   (``Ng*Nopc`` elements pass through).
  * ``contract`` — ``Nopc == 1``, no padding: the ``Nks`` taps cover the
                   whole (per-group) axis; a pure reduction/contraction
                   with no window overlap (FC's C dim, softmax's axis,
                   batch-norm's batch axis).
  * ``window``   — true sliding windows (``Nopc > 1`` and ``Nks > 1``) with
                   stride/padding: conv/pool spatial dims, LRN's C dim.
  * ``general``  — anything else (strided decimation etc.): falls back to
                   the oracle interpreter semantics.

The class vector decides the backend (see ``dispatch``): elementwise jnp,
axis reductions, ``lax.conv_general_dilated`` / the Pallas spatial kernel,
grouped matmul (``jnp.matmul`` / the Pallas ``gconv_matmul``), a generic
windowed ``einsum``, or — for exotic operator combinations — the
:func:`repro.core.interpreter.eval_gconv` oracle itself. Each lowering is
allclose-equivalent to the oracle but never materializes the full
``(Ng, Nop, Nopc, Nks)`` expansion when the ``reduce`` operator folds it.

All lowerings share the signature ``fn(x, k, lookup) -> y`` where ``lookup``
resolves pre/post tensor operands from the execution environment, and
mirror the oracle's dtype discipline: compute in
``result_type(x.dtype, float32)``, cast to ``out_dtype`` at the end.

Batched-mode contract: the leading-batch execution path
(:class:`~repro.exec.engine.CompiledChain` with batch-extended inputs)
``jax.vmap``-wraps the whole step program, so every lowering here must be
(a) traceable with the chain's declared shapes only — all reshapes /
window index tables are built from the STATIC ``DimSpec`` geometry, never
from runtime values — and (b) row-independent: nothing may reduce or
gather across the (abstracted) batch axis. (a) is what lets one bucket
compile serve every batch size in the bucket; (b) is what makes zero-pad
rows inert, in the same way per-slot positions make pad-token decode
ticks inert in the serving programs (exec.serving).

Row-independence is ALSO the sharding invariant the mesh-aware mode
(``compile_chain(mesh=...)``, :mod:`repro.exec.shardplan`) relies on:
because no lowering communicates across the leading batch axis, sharding
that axis over the mesh's "data" bundle partitions the program into
independent per-device replicas — GSPMD inserts no batch-axis collectives,
so the sharded program computes bit-for-bit the same per-row arithmetic as
the single-device one. The only collective a chain program ever needs is
the explicit ``psum`` of a row-split tensor-parallel grouped matmul
(:func:`lower_grouped_matmul` with ``tp=...``), which changes reduction
order but stays within the engine's differential-test tolerance.
"""
from __future__ import annotations

import string
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import operators as ops
from ..core.gconv import DimSpec, GConv

BCAST, CONTRACT, WINDOW, GENERAL = "bcast", "contract", "window", "general"


def classify_dim(d: DimSpec) -> str:
    if (d.nks == 1 and d.nop == 1 and d.stride == 1
            and d.pad == 0 and d.padr == 0):
        return BCAST
    if d.nopc == 1 and d.pad == 0 and d.padr == 0:
        return CONTRACT
    if d.ng == 1 and d.nop == 1:
        return WINDOW
    return GENERAL


def dim_classes(node: GConv) -> Tuple[str, ...]:
    return tuple(classify_dim(d) for d in node.dims)


def _compute_dtype(x):
    return jnp.result_type(x.dtype, jnp.float32)


def _finish(node: GConv, y, lookup):
    y = ops.apply_unary_seq(node.post, y, lookup)
    if node.out_dtype is not None:
        y = y.astype(node.out_dtype)
    return y


def _window_gather(x, axis: int, d: DimSpec, pad_val: float):
    """(…, Nips, …) -> (…, Nopc, Nks) at the end; ``axis`` must have ng==1."""
    x = jnp.moveaxis(x, axis, -1)
    if d.padr < 0:                      # crop: trailing elements never read
        x = x[..., : d.nips + d.padr]
    if d.pad > 0 or d.padr > 0:
        pad = [(0, 0)] * (x.ndim - 1) + [(d.pad, max(d.padr, 0))]
        x = jnp.pad(x, pad, constant_values=pad_val)
    idx = (np.arange(d.nopc)[:, None] * d.stride + np.arange(d.nks)[None, :])
    return x[..., idx]                  # (…, Nopc, Nks)


# ---------------------------------------------------------------------------
# elementwise: all dims bcast (any reduce is a no-op over singleton taps)
# ---------------------------------------------------------------------------
def lower_elementwise(node: GConv) -> Callable:
    dims = node.dims

    def fn(x, k, lookup):
        x = x.astype(_compute_dtype(x))
        x = ops.apply_unary_seq(node.pre, x, lookup)
        if node.main != "none":
            xs, ks = [], []
            for d, ka in zip(dims, k.shape):
                xs += [d.ng, d.nopc]
                ks += [d.ng, 1] if ka != 1 else [1, 1]
            y = ops.apply_main(node.main, x.reshape(xs),
                               k.astype(x.dtype).reshape(ks))
        else:
            y = x
        return _finish(node, y.reshape(node.out_shape), lookup)

    return fn


# ---------------------------------------------------------------------------
# reductions: main == 'none', reduce folds contract/window taps
# ---------------------------------------------------------------------------
def _reducer(name: str):
    return {"add": jnp.sum, "max": jnp.max, "min": jnp.min}[name]


def lower_reduce(node: GConv, classes: Sequence[str]) -> Callable:
    dims = node.dims
    red = _reducer(node.reduce)
    pad_val = ops.pad_value(node.reduce)
    window_ix = [i for i, c in enumerate(classes) if c == WINDOW]
    contract_ix = [i for i, c in enumerate(classes) if c == CONTRACT]

    def fn(x, k, lookup):
        x = x.astype(_compute_dtype(x))
        x = ops.apply_unary_seq(node.pre, x, lookup)
        for i in window_ix:             # window + immediate fold, per dim
            w = _window_gather(x, i, dims[i], pad_val)
            w = red(w, axis=-1)         # (…, Nopc)
            x = jnp.moveaxis(w, -1, i)
        if contract_ix:
            shape, axes = [], []
            for i, d in enumerate(dims):
                if i in contract_ix:
                    shape += [d.ng, d.nks]
                    axes.append(len(shape) - 1)
                else:
                    shape.append(x.shape[i])
            x = red(x.reshape(shape), axis=tuple(axes))
        return _finish(node, x.reshape(node.out_shape), lookup)

    return fn


# ---------------------------------------------------------------------------
# conv: main=mul/reduce=add with one grouped channel contraction + sliding
# spatial dims -> lax.conv_general_dilated (or the Pallas spatial kernel)
# ---------------------------------------------------------------------------
def match_conv(node: GConv, classes: Sequence[str],
               k_shape: Optional[Tuple[int, ...]]):
    """Return (channel_ix, window_ix, batch_ix) or None."""
    if node.main != "mul" or node.reduce != "add" or k_shape is None:
        return None
    channel = [i for i, c in enumerate(classes)
               if c == CONTRACT and k_shape[i] == node.dims[i].k_size]
    if not channel:
        # depthwise: icg == 1 makes the channel dim a pure-Ng (bcast) dim
        # with a full kernel axis — feature_group_count = Ng, I = 1
        channel = [i for i, (d, c) in enumerate(zip(node.dims, classes))
                   if c == BCAST and d.nopc == 1 and k_shape[i] == d.k_size
                   and k_shape[i] != 1]
    windows = [i for i, c in enumerate(classes)
               if c == WINDOW and k_shape[i] == node.dims[i].nks]
    batch = [i for i, c in enumerate(classes)
             if c == BCAST and k_shape[i] == 1]
    if len(channel) != 1 or not windows:
        return None
    if sorted(channel + windows + batch) != list(range(len(classes))):
        return None
    return channel[0], windows, batch


def lower_conv(node: GConv, plan) -> Callable:
    ch, windows, batch = plan
    dims = node.dims
    dch = dims[ch]
    groups, ocg, icg = dch.ng, dch.nop, dch.nks
    spatial = "".join("xyzuv"[i] for i in range(len(windows)))
    dn = ("NC" + spatial, "OI" + spatial, "NC" + spatial)
    strides = tuple(dims[i].stride for i in windows)

    def fn(x, k, lookup):
        ct = _compute_dtype(x)
        x = x.astype(ct)
        x = ops.apply_unary_seq(node.pre, x, lookup)
        # N = flattened batch axes; C = Ng*Nks of the channel dim
        perm = batch + [ch] + windows
        xb = jnp.transpose(x, perm)
        b_sizes = [dims[i].in_size for i in batch]
        nb = int(np.prod(b_sizes)) if b_sizes else 1
        xb = xb.reshape((nb, dch.in_size)
                        + tuple(dims[i].nips for i in windows))
        padding = []
        for i in windows:
            d = dims[i]
            if d.padr < 0:              # crop trailing elements never read
                ax = 2 + windows.index(i)
                xb = jax.lax.slice_in_dim(xb, 0, d.nips + d.padr, axis=ax)
            padding.append((d.pad, max(d.padr, 0)))
        kb = jnp.transpose(k.astype(ct), [ch] + windows + batch)
        kb = kb.reshape((groups * ocg, icg)
                        + tuple(dims[i].nks for i in windows))
        y = jax.lax.conv_general_dilated(
            xb, kb, strides, padding, dimension_numbers=dn,
            feature_group_count=groups)
        # (N, G*Nop, *Nopc) -> original dim order -> out_shape
        y = y.reshape(tuple(b_sizes) + (groups * ocg,)
                      + tuple(dims[i].nopc for i in windows))
        inv = np.argsort(perm)
        y = jnp.transpose(y, inv).reshape(node.out_shape)
        return _finish(node, y, lookup)

    return fn


def lower_conv_pallas(node: GConv, plan,
                      block_o: int = 128) -> Optional[Callable]:
    """NHWC Pallas spatial kernel for the plain 2-D case (groups=1, square
    stride, symmetric padding); None when the geometry doesn't fit.
    ``block_o`` threads the tuner's output-channel block through to
    ``gconv_spatial`` (the default matches the kernel's own)."""
    ch, windows, batch = plan
    dims = node.dims
    dch = dims[ch]
    if len(windows) != 2 or dch.ng != 1:
        return None
    dh, dw = dims[windows[0]], dims[windows[1]]
    if (dh.stride, dh.pad) != (dw.stride, dw.pad):
        return None
    if dh.padr != dh.pad or dw.padr != dw.pad:
        return None

    from ..kernels.gconv_spatial import gconv_spatial

    def fn(x, k, lookup):
        ct = _compute_dtype(x)
        x = x.astype(ct)
        x = ops.apply_unary_seq(node.pre, x, lookup)
        perm = batch + [ch] + windows
        xb = jnp.transpose(x, perm)
        b_sizes = [dims[i].in_size for i in batch]
        nb = int(np.prod(b_sizes)) if b_sizes else 1
        xb = xb.reshape(nb, dch.in_size, dh.nips, dw.nips)
        xb = jnp.transpose(xb, (0, 2, 3, 1))                 # NHWC
        kb = jnp.transpose(k.astype(ct), [ch] + windows + batch)
        kb = kb.reshape(dch.nop, dch.nks, dh.nks, dw.nks)    # OIHW
        kb = jnp.transpose(kb, (2, 3, 1, 0))                 # HWIO
        y = gconv_spatial(xb, kb, stride=dh.stride, pad=dh.pad,
                          block_o=block_o)
        y = jnp.transpose(y, (0, 3, 1, 2))
        y = y.reshape(tuple(b_sizes) + (dch.nop, dh.nopc, dw.nopc))
        y = jnp.transpose(y, np.argsort(perm)).reshape(node.out_shape)
        return _finish(node, y, lookup)

    return fn


# ---------------------------------------------------------------------------
# grouped matmul: main=mul/reduce=add, no window dims -> (G,M,K) @ (G,K,N)
# ---------------------------------------------------------------------------
def match_grouped_matmul(node: GConv, classes: Sequence[str],
                         k_shape: Optional[Tuple[int, ...]]):
    """Assign each dim a role in the grouped contraction, or None.

    roles: g_ix (batch groups, kernel varies per group), m_ix (x-only
    output axes), c_ix (contractions contributing N=Nop / K=Nks).
    """
    if node.main != "mul" or node.reduce != "add" or k_shape is None:
        return None
    g_ix, m_ix, c_ix = [], [], []
    for i, (d, c) in enumerate(zip(node.dims, classes)):
        ka = k_shape[i]
        if c == BCAST and ka == 1:
            m_ix.append(i)
        elif c == BCAST and ka == d.k_size and d.nopc == 1:
            g_ix.append(i)
        elif c == CONTRACT and d.ng == 1 and ka == d.k_size:
            c_ix.append(i)
        elif c == CONTRACT and d.ng == 1 and ka == 1 and d.nop == 1:
            c_ix.append(i)              # kernel constant across the taps
        else:
            return None
    return g_ix, m_ix, c_ix


def _fused_matmul_seq(seq, dims, g_ix, m_ix, c_ix, stage, lookup):
    """Translate a pre/post Op sequence into the Pallas ``gconv_matmul``
    ``prologue``/``epilogue`` form: ``(name, const, slot)`` triples plus
    operand arrays reshaped to ``(G|1, M|1, 1)`` / ``(G|1, 1, L|1)``
    (L = K for the prologue, N for the epilogue). Returns None when an
    operand's broadcast pattern doesn't fit those layouts — the caller
    then applies the sequence in jnp instead."""
    triples, arrays = [], []
    for op in seq:
        if op.operand is None:
            triples.append((op.name, op.const, None))
            continue
        arr = lookup(op)
        if arr.ndim != len(dims):
            return None
        at = jnp.transpose(arr, g_ix + m_ix + c_ix)
        ng = len(g_ix)
        nm = len(m_ix)
        g_sz = at.shape[:ng]
        m_sz = at.shape[ng:ng + nm]
        c_sz = at.shape[ng + nm:]
        g_full = tuple(dims[i].ng for i in g_ix)
        m_full = tuple(dims[i].in_size for i in m_ix)
        c_full = tuple((dims[i].nks if stage == "pro" else dims[i].nop)
                       for i in c_ix)

        def collapse(sz, full):
            if all(s == 1 for s in sz):
                return 1
            if tuple(sz) == tuple(full):
                return int(np.prod(full)) if full else 1
            return None                      # mixed broadcast: not fusable

        gp, mp, cp = (collapse(g_sz, g_full), collapse(m_sz, m_full),
                      collapse(c_sz, c_full))
        if gp is None or mp is None or cp is None:
            return None
        if mp != 1 and cp != 1:              # (G, M, L) has no kernel layout
            return None
        triples.append((op.name, op.const, len(arrays)))
        arrays.append(at.reshape(gp, mp, cp))
    return tuple(triples), tuple(arrays)


def _tp_matmul(xb, kb, tp):
    """Tensor-parallel ``(G,M,K) @ (G,K,N)`` under a ``shard_map``.

    column: kernel sharded on N (the Cout/channel GCONV axis) — each shard
            computes its own output columns, no collective; the result
            stays N-sharded for downstream GSPMD propagation.
    row:    both operands sharded on K — partial products need the one
            explicit collective in the engine, a psum over the model axis.

    The data-parallel axis rides along on G (grouped/batched kernels) or
    M (plain batch rows) when it divides — ``dp_g``/``dp_m`` come from the
    plan — so DP + TP compose without gathers. Operands are explicitly
    constrained to the in_specs first: shard_map TRUSTS (does not enforce)
    that an unmentioned mesh axis means "replicated along it", and under
    data parallelism the operands arrive data-sharded — skipping the
    constraint silently computes garbage (caught by the zoo differential
    sweep on a (4, 2) mesh).

    Divisibility of N/K over the model axis is guaranteed by the plan
    (repro.exec.shardplan); an axis that doesn't divide never reaches
    here.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import NamedSharding as _NS, PartitionSpec as _P

    mesh, ax, mode, dp_g, dp_m = tp
    if mode == "column":
        x_spec = _P(dp_g, dp_m, None)
        k_spec = _P(dp_g, None, ax)
        out_spec = _P(dp_g, dp_m, ax)
        mm = jnp.matmul
    else:
        x_spec = _P(dp_g, dp_m, ax)
        k_spec = _P(dp_g, ax, None)
        out_spec = _P(dp_g, dp_m, None)

        def mm(xs, ks):
            return jax.lax.psum(jnp.matmul(xs, ks), ax)

    xb = jax.lax.with_sharding_constraint(xb, _NS(mesh, x_spec))
    kb = jax.lax.with_sharding_constraint(kb, _NS(mesh, k_spec))
    return shard_map(mm, mesh=mesh, in_specs=(x_spec, k_spec),
                     out_specs=out_spec)(xb, kb)


def lower_grouped_matmul(node: GConv, plan, *, pallas: bool = False,
                         tp=None, block=None) -> Callable:
    """``block`` (Pallas path only): a tuner-materialized ``(bm, bn, bk)``
    forwarded to ``gconv_matmul``; None keeps the kernel's static
    defaults."""
    g_ix, m_ix, c_ix = plan
    dims = node.dims
    G = int(np.prod([dims[i].ng for i in g_ix])) if g_ix else 1
    M = int(np.prod([dims[i].in_size for i in m_ix])) if m_ix else 1
    K = int(np.prod([dims[i].nks for i in c_ix])) if c_ix else 1
    N = int(np.prod([dims[i].nop for i in c_ix])) if c_ix else 1

    def fn(x, k, lookup):
        ct = _compute_dtype(x)
        x = x.astype(ct)
        # on the Pallas path, ride the fused pre/post sequences in-register
        # (the §4.3 result) when their operands fit the kernel layouts
        pro = epi = None
        if pallas:
            pro = _fused_matmul_seq(node.pre, dims, g_ix, m_ix, c_ix,
                                    "pro", lookup)
            epi = _fused_matmul_seq(node.post, dims, g_ix, m_ix, c_ix,
                                    "epi", lookup)
        if pro is None:
            x = ops.apply_unary_seq(node.pre, x, lookup)
        xb = jnp.transpose(x, g_ix + m_ix + c_ix).reshape(G, M, K)
        # kernel: per-dim axes (g | squeeze-1 | (nop, nks)) -> (G, K, N)
        kshape, full, g_pos, nop_pos, nks_pos = [], [], [], [], []
        for i in g_ix + m_ix + c_ix:
            d, ka = dims[i], k.shape[i]
            if i in g_ix:
                g_pos.append(len(kshape))
                kshape.append(ka)       # kernel always full on g dims
                full.append(ka)
            elif i in m_ix:
                kshape.append(1)
                full.append(1)
            else:
                nop_pos.append(len(kshape))
                kshape.append(d.nop if ka != 1 else 1)
                full.append(d.nop)
                nks_pos.append(len(kshape))
                kshape.append(d.nks if ka != 1 else 1)
                full.append(d.nks)
        kb = jnp.transpose(k.astype(ct), g_ix + m_ix + c_ix).reshape(kshape)
        kb = jnp.broadcast_to(kb, full)   # expand broadcast-1 nop/nks axes
        rest = [p for p in range(len(kshape))
                if p not in g_pos + nop_pos + nks_pos]
        kb = jnp.transpose(kb, g_pos + nop_pos + nks_pos + rest)
        kb = kb.reshape(G, N, K).swapaxes(1, 2)              # (G, K, N)
        if pallas:
            from ..kernels.gconv_matmul import gconv_matmul
            pro_seq, pro_ops = pro if pro is not None else ((), ())
            epi_seq, epi_ops = epi if epi is not None else ((), ())
            epi_seq = tuple((nm, c, None if s is None else s + len(pro_ops))
                            for nm, c, s in epi_seq)
            bkw = (dict(block_m=block[0], block_n=block[1],
                        block_k=block[2]) if block is not None else {})
            y = gconv_matmul(xb, kb, prologue=pro_seq, epilogue=epi_seq,
                             operands=pro_ops + epi_ops, **bkw)
        elif tp is not None:
            y = _tp_matmul(xb, kb, tp)                       # (G, M, N)
        else:
            y = jnp.matmul(xb, kb)                           # (G, M, N)
        out_axes = ([dims[i].ng for i in g_ix]
                    + [dims[i].in_size for i in m_ix]
                    + [dims[i].nop for i in c_ix])
        y = y.reshape(out_axes)
        y = jnp.transpose(y, np.argsort(g_ix + m_ix + c_ix))
        y = y.reshape(node.out_shape)
        if epi is not None:                  # post already ran in-register
            if node.out_dtype is not None:
                y = y.astype(node.out_dtype)
            return y
        return _finish(node, y, lookup)

    if tp is not None:
        # declare the tensor-parallel contract of this lowering where the
        # static verifier can see it: the branch conditions mirror
        # _tp_matmul exactly (row splits psum partial products; both modes
        # pin operand replication with with_sharding_constraint). The
        # repro.lint shard passes audit this against the ShardPlan.
        _mesh, _ax, _mode, _dp_g, _dp_m = tp
        fn.tp_meta = {"tp_mode": _mode, "axis": _ax,
                      "psum": _mode == "row", "constrained": True,
                      "dp_g": _dp_g, "dp_m": _dp_m}
    return fn


# ---------------------------------------------------------------------------
# generic windowed einsum: main=mul/reduce=add over any bcast/contract/window
# mix (conv-like weight-gradient patterns, grouped attention exotica)
# ---------------------------------------------------------------------------
def lower_einsum(node: GConv, classes: Sequence[str]) -> Callable:
    dims = node.dims
    letters = iter(string.ascii_letters)
    # per dim: labels (g, opc/ks-free, ks) for x; (g, op, ks) for kernel
    lab = [(next(letters), next(letters), next(letters), next(letters))
           for _ in dims]               # (g, op, opc, ks)

    def fn(x, k, lookup):
        ct = _compute_dtype(x)
        x = x.astype(ct)
        x = ops.apply_unary_seq(node.pre, x, lookup)
        x_sub = []
        offset = 0
        for i, (d, c) in enumerate(zip(dims, classes)):
            g, o, cc, ks = lab[i]
            ax = i + offset
            if c == BCAST:
                x = jnp.reshape(x, x.shape[:ax] + (d.ng, d.nopc)
                                + x.shape[ax + 1:])
                x_sub += [g, cc]
                offset += 1
            elif c == CONTRACT:
                x = jnp.reshape(x, x.shape[:ax] + (d.ng, d.nks)
                                + x.shape[ax + 1:])
                x_sub += [g, ks]
                offset += 1
            else:                       # window (ng == 1)
                w = _window_gather(x, ax, d, 0.0)
                x = jnp.moveaxis(w, (-2, -1), (ax, ax + 1))
                x_sub += [cc, ks]
                offset += 1
        k_sub, kshape = [], []
        for i, (d, c) in enumerate(zip(dims, classes)):
            g, o, cc, ks = lab[i]
            ka = k.shape[i]
            if ka == 1:
                kshape += [1, 1, 1]
            else:
                kshape += [d.ng, d.nop, d.nks]
            k_sub += [g, o, ks]
        kb = k.astype(ct).reshape(kshape)
        # drop singleton axes from both operands (einsum labels must agree
        # on size; a broadcast-1 axis simply leaves the label out)
        x_sub2 = [s for s, n in zip(x_sub, x.shape) if n != 1]
        xv = x.reshape([n for n in x.shape if n != 1])
        k_sub2 = [s for s, n in zip(k_sub, kb.shape) if n != 1]
        kv = kb.reshape([n for n in kb.shape if n != 1])
        # output labels: (g, op, opc) per dim, sizes from the dims
        out_sub, out_sizes = [], []
        for i, d in enumerate(dims):
            g, o, cc, ks = lab[i]
            for s, n in ((g, d.ng), (o, d.nop), (cc, d.nopc)):
                out_sub.append(s)
                out_sizes.append(n)
        kept = set(x_sub2) | set(k_sub2)
        out_keep = [s for s, n in zip(out_sub, out_sizes)
                    if n != 1 and s in kept]
        eq = (f"{''.join(x_sub2)},{''.join(k_sub2)}->{''.join(out_keep)}")
        y = jnp.einsum(eq, xv, kv)
        # re-broadcast output axes whose size>1 label vanished (kernel
        # broadcast across Nop) and restore singleton axes
        full = []
        pos = 0
        for s, n in zip(out_sub, out_sizes):
            if n != 1 and s in kept:
                full.append(y.shape[pos])
                pos += 1
            else:
                full.append(1)
        y = y.reshape(full)
        y = jnp.broadcast_to(y, out_sizes)
        y = y.reshape(node.out_shape)
        return _finish(node, y, lookup)

    return fn


# ---------------------------------------------------------------------------
# oracle fallback
# ---------------------------------------------------------------------------
def lower_oracle(node: GConv) -> Callable:
    from ..core.interpreter import eval_gconv

    def fn(x, k, lookup):
        return eval_gconv(node, x, k, lookup)

    return fn
