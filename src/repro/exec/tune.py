"""Compile-time kernel autotuner: measured (backend, block) selection.

`exec.dispatch` picks backends with a fixed ``mxu_min`` threshold and
``kernels.common.pick_block`` is a static formula — neither ever consults a
measurement. This module adds the measurement: for every tunable step of a
compiled plan (grouped matmuls, convs, and their einsum-expressible
alternatives), enumerate the candidate (backend, block-shape) points whose
materialized blocks satisfy ``block_contract_ok``, time each candidate
on-device (``block_until_ready``-timed runs, warmup + interquartile mean
over repeats), and re-lower the step to the winner.

Decisions persist in a tuning database under ``results/tune/`` keyed by
``device kind | heuristic plan signature | step name`` — the signature
already encodes the chain name, input shapes and every heuristic dispatch
decision, so any change to shapes, fusion or the heuristic invalidates the
key and the group re-tunes. Subsequent compiles are pure lookups (the
in-process cache makes a warm-cache compile a dict hit per group; the
<5% compile-overhead bound is gated by ``benchmarks/tune_bench.py``).
Entries that fail structural validation are *quarantined* on load — a
corrupted DB can cost a re-measure, never a crash and never a bogus plan
(the ``plan.tuned-contract`` lint rule audits every applied decision).

The search itself is a second consumer of the shared :mod:`repro.search`
engines (the DSE is the first): a :class:`KernelSpace` over candidate
indices, the same seeded strategies, the same budget accounting, the same
trajectory records.

Modes (``compile_chain(tune=...)``):

  * ``"off"``      — heuristic dispatch only (the default).
  * ``"readonly"`` — apply DB hits, keep the heuristic for misses; never
                     measures (the serving/production path).
  * ``"auto"``     — apply DB hits, measure + persist misses.
  * ``"force"``    — re-measure every group and overwrite the DB.
"""
from __future__ import annotations

import json
import os
import time
from contextlib import nullcontext
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.gconv import GConv
from ..kernels.common import block_contract_ok, pick_block, use_interpret
from ..kernels.gconv_matmul import (BLOCK_K, BLOCK_M, BLOCK_N, K_ALIGN,
                                    M_ALIGN, N_ALIGN)
from ..search import STRATEGIES, TrajectoryRecorder
from . import lowering as low

SCHEMA = "repro.tune/v1"
WARMUP = 2          # un-timed runs per candidate (compile + cache warm)
REPEATS = 5         # timed runs per candidate (IQM taken)
MARGIN = 1.25       # a switch must beat the heuristic by this factor
                    # standalone; marginal wins routinely invert inside
                    # the fused whole-chain program (XLA fuses/layouts
                    # the step differently in context)
DEFAULT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "tune")

# dispatch tags a tuned decision may carry (chain-plan groups); serve-level
# groups use "attn:*" / "flags:*" tags — validation is structural, not
# enumerated, so both vocabularies share one DB format
TUNABLE = ("matmul:jnp", "matmul:pallas", "conv:lax", "conv:pallas",
           "einsum")


def default_db_path() -> str:
    return os.path.join(DEFAULT_DIR, "tune_db.json")


def device_key() -> str:
    """DB partition key for the measuring device: the JAX device kind,
    plus the interpret-mode flag — interpret-mode Pallas timings must
    never masquerade as real-kernel timings of the same device."""
    kind = jax.devices()[0].device_kind.replace("|", ";")
    return kind + ("+interpret" if use_interpret() else "")


# ---------------------------------------------------------------------------
# tuning database
# ---------------------------------------------------------------------------
def entry_ok(entry) -> bool:
    """Structural validation of one DB entry; failures are quarantined.
    Geometry-aware validation (does the block satisfy the pick_block
    contract *for this node*?) happens at apply time and is additionally
    audited by the ``plan.tuned-contract`` lint rule."""
    if not isinstance(entry, dict):
        return False
    if not (isinstance(entry.get("backend"), str) and entry["backend"]):
        return False
    block = entry.get("block")
    if block is not None:
        if not isinstance(block, dict) or not block:
            return False
        for a, v in block.items():
            if a not in ("m", "n", "k", "o"):
                return False
            if not isinstance(v, int) or isinstance(v, bool) or v < 1:
                return False
    lat = entry.get("latency_us")
    if not isinstance(lat, (int, float)) or isinstance(lat, bool):
        return False
    if not (lat > 0 and lat == lat and lat != float("inf")):
        return False
    return True


class TuneDB:
    """Persisted (backend, block) decisions, one JSON file per results
    tree. Load is tolerant by construction: an unreadable file starts an
    empty DB; an entry failing :func:`entry_ok` moves to ``quarantined``
    (kept in the file for inspection) and reads as a miss — the caller
    falls back to the heuristic or re-measures, it never raises."""

    def __init__(self, path: str, entries: Optional[Dict[str, dict]] = None,
                 quarantined: Optional[Dict[str, dict]] = None):
        self.path = path
        self.entries: Dict[str, dict] = dict(entries or {})
        self.quarantined: Dict[str, dict] = dict(quarantined or {})

    @classmethod
    def load(cls, path: str) -> "TuneDB":
        try:
            with open(path) as f:
                raw = json.load(f)
        except (OSError, ValueError):
            return cls(path)
        if not isinstance(raw, dict) or raw.get("schema") != SCHEMA:
            # unknown schema: quarantine wholesale (re-tune, don't guess)
            return cls(path, quarantined={"__file__": {
                "reason": f"unrecognized schema {raw.get('schema')!r}"
                if isinstance(raw, dict) else "non-object DB file"}})
        entries, quarantined = {}, dict(raw.get("quarantined") or {})
        src = raw.get("entries")
        for key, entry in (src.items() if isinstance(src, dict) else ()):
            if entry_ok(entry):
                entries[key] = entry
            else:
                quarantined[key] = {"entry": entry,
                                    "reason": "failed entry validation"}
        return cls(path, entries, quarantined)

    def lookup(self, key: str) -> Optional[dict]:
        entry = self.entries.get(key)
        return entry if entry is not None and entry_ok(entry) else None

    def record(self, key: str, entry: dict) -> None:
        assert entry_ok(entry), entry
        self.entries[key] = entry
        self.quarantined.pop(key, None)

    def save(self) -> None:
        os.makedirs(os.path.dirname(os.path.abspath(self.path)),
                    exist_ok=True)
        with open(self.path, "w") as f:
            json.dump(dict(schema=SCHEMA, entries=self.entries,
                           quarantined=self.quarantined),
                      f, indent=1, sort_keys=True, default=float)


# warm-cache compiles must not re-read JSON per compile: one in-process
# cache keyed by (path, mtime), refreshed by save()
_DB_CACHE: Dict[str, Tuple[Optional[float], TuneDB]] = {}

# ... nor re-lower a switched step per compile: lowered run closures are
# cached per (DB key, decision) and reused when the node is structurally
# identical (GConv dataclass equality covers dims, operand names, ops and
# dtype — everything the lowering reads)
_RUN_CACHE: Dict[Tuple[str, str, str], Tuple[object, Callable]] = {}
_RUN_CACHE_MAX = 512


def load_db(path: Optional[str] = None) -> TuneDB:
    path = path or default_db_path()
    try:
        mtime: Optional[float] = os.path.getmtime(path)
    except OSError:
        mtime = None
    hit = _DB_CACHE.get(path)
    if hit is not None and hit[0] == mtime:
        return hit[1]
    db = TuneDB.load(path)
    _DB_CACHE[path] = (mtime, db)
    return db


def save_db(db: TuneDB) -> None:
    db.save()
    try:
        mtime: Optional[float] = os.path.getmtime(db.path)
    except OSError:
        mtime = None
    _DB_CACHE[db.path] = (mtime, db)


# ---------------------------------------------------------------------------
# candidate space (a repro.search PointSpace over candidate indices)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class KernelSpace:
    """Index space over a group's candidate list — points are ``(i,)``.
    Index 0 is always the heuristic's own choice, so the scorer's
    deterministic tie-break (``min`` over ``(score, point)``) resolves a
    measured tie in the heuristic's favor."""

    n: int

    def sample(self, rng) -> Tuple[int, ...]:
        return (rng.randrange(self.n),)

    def mutate(self, point, rng, n_fields: int = 1) -> Tuple[int, ...]:
        if self.n <= 1:
            return point
        j = rng.randrange(self.n - 1)
        if j >= point[0]:
            j += 1
        return (j,)

    def crossover(self, a, b, rng) -> Tuple[int, ...]:
        return a if rng.random() < 0.5 else b


def measured_select(n: int, measure: Callable[[int], float], *,
                    budget: int, seed: int = 0,
                    strategy: str = "random") -> Tuple[int, float, "object"]:
    """Pick the candidate index minimizing ``measure(i)`` (seconds) with a
    shared-strategy search over :class:`KernelSpace`; returns
    ``(winner_index, winner_seconds, SearchResult)``. ``budget`` is capped
    at ``n`` — a full enumeration when affordable, a seeded subset when
    not. Index 0 (the heuristic) is always measured first."""
    space = KernelSpace(n)
    res = STRATEGIES[strategy]().run(
        space, lambda p: measure(p[0]), min(max(1, budget), n),
        seed=seed, seeds=[(0,)])
    return res.best[0], res.best_score, res


# ---------------------------------------------------------------------------
# per-group candidates + measured objective
# ---------------------------------------------------------------------------
def _matmul_blocks(M: int, N: int, K: int) -> List[Dict[str, int]]:
    """Materialized (bm, bn, bk) candidates around the static defaults —
    every emitted block satisfies ``block_contract_ok`` by construction
    (same ``min(target, pick_block(...))`` form the lint audit uses)."""
    out, seen = [], set()
    for tm in (128, BLOCK_M):
        for tn in (128, BLOCK_N):
            for tk in (256, BLOCK_K):
                bm = min(tm, pick_block(M, tm, M_ALIGN))
                bn = min(tn, pick_block(N, tn, N_ALIGN))
                bk = min(tk, pick_block(K, tk, K_ALIGN))
                if (bm, bn, bk) not in seen:
                    seen.add((bm, bn, bk))
                    out.append(dict(m=bm, n=bn, k=bk))
    return out


def _conv_blocks(O: int) -> List[Dict[str, int]]:
    out, seen = [], set()
    for to in (64, 128, 256):
        bo = max(1, min(to, O))
        if bo not in seen:
            seen.add(bo)
            out.append(dict(o=bo))
    return out


@dataclass
class _Group:
    """One tunable step: classification + lowering plans, with the
    candidate list built lazily — the warm-compile (DB hit) path only
    needs :meth:`legal` and :meth:`lower`, never the enumeration."""

    name: str
    node: GConv
    heuristic: str
    classes: Tuple[str, ...] = ()
    mplan: object = None
    cplan: object = None
    einsum_ok: bool = False
    pallas_ok: bool = False
    _cands: Optional[List[Tuple[str, Optional[Dict[str, int]]]]] = None

    @property
    def geometry(self) -> Tuple[int, ...]:
        """(M, N, K) for matmul groups, (O,) for conv groups."""
        if self.mplan is not None:
            g_ix, m_ix, c_ix = self.mplan
            dims = self.node.dims
            M = (int(np.prod([dims[i].in_size for i in m_ix]))
                 if m_ix else 1)
            K = int(np.prod([dims[i].nks for i in c_ix])) if c_ix else 1
            N = int(np.prod([dims[i].nop for i in c_ix])) if c_ix else 1
            return M, N, K
        return (self.node.dims[self.cplan[0]].nop,)

    @property
    def candidates(self) -> List[Tuple[str, Optional[Dict[str, int]]]]:
        if self._cands is not None:
            return self._cands
        cands: List[Tuple[str, Optional[Dict[str, int]]]] = []
        if self.mplan is not None:
            M, N, K = self.geometry
            cands.append(("matmul:jnp", None))
            if self.pallas_ok:
                cands += [("matmul:pallas", b)
                          for b in _matmul_blocks(M, N, K)]
            if self.einsum_ok:
                cands.append(("einsum", None))
        elif self.cplan is not None:
            cands.append(("conv:lax", None))
            if (self.pallas_ok
                    and low.lower_conv_pallas(self.node, self.cplan)
                    is not None):
                cands += [("conv:pallas", b)
                          for b in _conv_blocks(self.geometry[0])]
            if self.einsum_ok:
                cands.append(("einsum", None))
        # heuristic first: measured ties resolve to the incumbent
        h_ix = next((i for i, (t, _b) in enumerate(cands)
                     if t == self.heuristic), 0)
        if cands:
            cands.insert(0, cands.pop(h_ix))
        self._cands = cands
        return cands

    def legal(self, tag: str, block: Optional[Dict[str, int]]) -> bool:
        """Is a (possibly DB-recalled) decision still a sound lowering of
        this node here? Cheap direct checks — no candidate enumeration —
        mirroring what the ``plan.tuned-contract`` lint rule audits."""
        if tag == "matmul:jnp":
            return self.mplan is not None and block is None
        if tag == "matmul:pallas":
            if self.mplan is None or not self.pallas_ok:
                return False
            if block is None:
                return True
            if sorted(block) != ["k", "m", "n"]:
                return False
            M, N, K = self.geometry
            return (block_contract_ok(M, block["m"], M_ALIGN)
                    and block_contract_ok(N, block["n"], N_ALIGN)
                    and block_contract_ok(K, block["k"], K_ALIGN))
        if tag == "conv:lax":
            return self.cplan is not None and block is None
        if tag == "conv:pallas":
            if (self.cplan is None or not self.pallas_ok
                    or low.lower_conv_pallas(self.node, self.cplan) is None):
                return False
            return (block is None
                    or (sorted(block) == ["o"] and 1 <= block["o"]))
        if tag == "einsum":
            return self.einsum_ok and block is None
        return False

    def lower(self, tag: str, block: Optional[Dict[str, int]]) -> Callable:
        if tag == "matmul:jnp":
            return low.lower_grouped_matmul(self.node, self.mplan)
        if tag == "matmul:pallas":
            blk = (block["m"], block["n"], block["k"]) if block else None
            return low.lower_grouped_matmul(self.node, self.mplan,
                                            pallas=True, block=blk)
        if tag == "conv:lax":
            return low.lower_conv(self.node, self.cplan)
        if tag == "conv:pallas":
            fn = low.lower_conv_pallas(self.node, self.cplan,
                                       block_o=block["o"] if block else 128)
            assert fn is not None, "conv:pallas candidate without geometry"
            return fn
        if tag == "einsum":
            return low.lower_einsum(self.node, self.classes)
        raise ValueError(f"untunable tag {tag!r}")


def _group_for(step, chain) -> Optional[_Group]:
    """Build the group for one plan step, or None when the step is not
    tunable (non-GConv, segment, or no alternative lowering exists).

    Pallas candidates are only offered where the kernels actually compile
    to Mosaic — in interpret mode (any non-TPU backend) they are a
    correctness tool, never a performance candidate."""
    if step.backend not in TUNABLE:
        return None
    node = chain.nodes.get(step.name)
    if not isinstance(node, GConv):
        return None
    classes = low.dim_classes(node)
    k_shape = (tuple(chain.shape_of(node.kernel))
               if node.kernel is not None else None)
    g = _Group(step.name, node, step.backend, classes,
               einsum_ok=low.GENERAL not in classes,
               pallas_ok=not use_interpret())
    if step.backend.startswith("matmul:"):
        g.mplan = low.match_grouped_matmul(node, classes, k_shape)
        if g.mplan is None:
            return None
    elif step.backend.startswith("conv:"):
        g.cplan = low.match_conv(node, classes, k_shape)
        if g.cplan is None:
            return None
    else:                                # einsum heuristic: need a plan to
        g.mplan = low.match_grouped_matmul(node, classes, k_shape)
        g.cplan = (low.match_conv(node, classes, k_shape)
                   if g.mplan is None else None)
        if g.mplan is None and g.cplan is None:
            return None
    return g


def _synth_names(chain, names, seed: int = 0):
    """Deterministic measurement operands at the chain's declared shapes
    (inputs, params and intermediate producers all resolve through
    ``chain.shape_of``)."""
    rng = np.random.default_rng(seed)
    env = {}
    for name in names:
        if name in env:
            continue
        shape = tuple(chain.shape_of(name))
        info = chain.inputs.get(name) or chain.params.get(name)
        if info is not None:
            dtype = info.dtype
        else:
            src = chain.nodes.get(name)
            dtype = (getattr(src, "out_dtype", None) or "float32")
        if jnp.issubdtype(jnp.dtype(dtype), jnp.integer):
            env[name] = jnp.zeros(shape, dtype)
        else:
            env[name] = jnp.asarray(
                0.1 * rng.standard_normal(shape), dtype)
    return env


def _synth_env(chain, group: _Group, seed: int = 0):
    """Measurement operands for one group's step in isolation."""
    node = group.node
    names = [node.input]
    if node.kernel is not None:
        names.append(node.kernel)
    for op in tuple(node.pre) + tuple(node.post):
        if op.operand is not None:
            names.append(op.operand)
    return _synth_names(chain, names, seed)


def _iqm(ts: List[float]) -> float:
    ts = sorted(ts)
    q = len(ts) // 4
    mid = ts[q:len(ts) - q] or ts
    return sum(mid) / len(mid)


def measure_callable(fn: Callable, *args, warmup: int = WARMUP,
                     repeats: int = REPEATS) -> float:
    """Device-synced wall seconds for one jitted callable: ``warmup``
    un-timed runs (trace + XLA compile + cache warm), then the
    interquartile mean over ``repeats`` ``block_until_ready``-timed
    runs."""
    for _ in range(max(1, warmup)):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return _iqm(ts)


# ---------------------------------------------------------------------------
# plan tuning driver
# ---------------------------------------------------------------------------
def _tuned_meta(tag: str, block, source: str, group: str,
                latency_us: float, heuristic_us: Optional[float]) -> dict:
    return dict(backend=tag, block=dict(block) if block else None,
                source=source, group=group,
                latency_us=latency_us, heuristic_us=heuristic_us)


def _blk_token(block) -> str:
    return "" if not block else repr(sorted(block.items()))


def _cache_run(key: str, tag: str, block, node, run) -> None:
    if len(_RUN_CACHE) >= _RUN_CACHE_MAX:
        _RUN_CACHE.clear()
    _RUN_CACHE[(key, tag, _blk_token(block))] = (node, run)


def _apply(step, group: _Group, tag: str, block, meta: dict, dispatch):
    if tag != step.backend or block is not None:
        from .dispatch import _gconv_step
        step.run = _gconv_step(group.node, group.lower(tag, block))
        step.backend = tag
    step.meta = dict(step.meta or {})
    step.meta["tuned"] = meta
    dispatch[group.name] = tag


def _validate_e2e(chain, plan, orig_runs: Dict[str, Callable], *,
                  seed: int, warmup: int,
                  repeats: int) -> Tuple[bool, float, float]:
    """Whole-plan arbitration for this compile's measured switches.

    Per-group wall times are blind to cross-step fusion and layout
    effects — a backend that wins standalone can lose once XLA sees the
    step inside the full program. Measure the tuned plan against the
    heuristic plan (switched steps restored from ``orig_runs``)
    end-to-end on synthetic operands; the caller reverts every switch
    when the tuned plan is not faster. Returns
    ``(keep, heuristic_us, tuned_us)``."""
    env = _synth_names(chain, list(chain.inputs) + list(chain.params),
                       seed)
    outs = chain.outputs or [list(chain.nodes)[-1]]

    def runner(use_orig: bool):
        def run(e):
            e = dict(e)
            for st in plan.steps:
                fn = (orig_runs.get(st.name, st.run) if use_orig
                      else st.run)
                e[st.name] = fn(e)
            return [e[o] for o in outs]
        return jax.jit(run)

    tuned_s = measure_callable(runner(False), env, warmup=warmup,
                               repeats=repeats)
    heur_s = measure_callable(runner(True), env, warmup=warmup,
                              repeats=repeats)
    return (tuned_s <= heur_s, round(heur_s * 1e6, 3),
            round(tuned_s * 1e6, 3))


def _signature(plan, chain) -> str:
    """The heuristic signature with tuned block choices appended to the
    per-step backend tokens — equal-signature engines run the same tuned
    program."""
    base = plan.signature.rsplit("|", 1)[0]
    toks = []
    for s in plan.steps:
        tok = f"{s.name}={s.backend}"
        tuned = (s.meta or {}).get("tuned")
        if tuned and tuned.get("block"):
            tok += "@" + ",".join(f"{a}{v}" for a, v
                                  in sorted(tuned["block"].items()))
        toks.append(tok)
    return f"{base}|{';'.join(toks)}"


def tune_plan(chain, plan, *, mode: str = "auto",
              db_path: Optional[str] = None, budget: int = 16,
              seed: int = 0, strategy: str = "random",
              backend: str = "auto", warmup: int = WARMUP,
              repeats: int = REPEATS, tracer=None) -> Tuple[object, dict]:
    """Tune a compiled plan in place (steps re-lowered to the winning
    (backend, block), ``Step.meta['tuned']`` recorded, signature extended)
    and return ``(plan, report)``.

    ``chain`` is the FUSED chain the plan was built from. ``backend``
    forwards the compile option: a forced backend restricts candidates to
    that backend's family (block-only tuning); ``"auto"`` tunes across
    backends. Measurement spans land on ``tracer`` (`repro.obs`) when one
    is given."""
    if mode not in ("readonly", "auto", "force"):
        raise ValueError(f"tune mode {mode!r}: want readonly|auto|force")
    from ..obs import Metrics
    reg = Metrics()
    db = load_db(db_path)
    dev = device_key()
    base_sig = plan.signature
    report = dict(mode=mode, device=dev, db_path=db.path, groups={},
                  measured=0, from_db=0, kept_heuristic=0)
    dirty = False
    # freshly-measured switches pending whole-plan validation:
    # (step, group, db key, db entry, original run, original backend)
    switched: List[tuple] = []
    fam = {"jnp": ("matmul:jnp", "conv:lax", "einsum"),
           "pallas": ("matmul:pallas", "conv:pallas")}.get(backend)
    for step in plan.steps:
        if step.backend not in TUNABLE:
            continue
        key = f"{dev}|{base_sig}|{step.name}"
        entry = db.lookup(key) if mode != "force" else None
        if (entry is not None and entry["backend"] == step.backend
                and entry.get("block") is None
                and (fam is None or entry["backend"] in fam)):
            # kept-heuristic decision (the warm path's common case): the
            # step is already lowered exactly this way, so no group
            # geometry or legality probe is needed — annotate and move on
            meta = _tuned_meta(entry["backend"], None, "db", step.name,
                               entry["latency_us"],
                               entry.get("heuristic_us"))
            step.meta = dict(step.meta or {})
            step.meta["tuned"] = meta
            plan.dispatch[step.name] = step.backend
            report["from_db"] += 1
            report["groups"][step.name] = meta
            continue
        if entry is not None and (fam is None or entry["backend"] in fam):
            # switched decision already lowered this process for a
            # structurally identical node: reuse the run closure (the
            # decision was legality-checked when the cache was filled)
            cached = _RUN_CACHE.get((key, entry["backend"],
                                     _blk_token(entry.get("block"))))
            if cached is not None and cached[0] == chain.nodes.get(
                    step.name):
                meta = _tuned_meta(entry["backend"], entry.get("block"),
                                   "db", step.name, entry["latency_us"],
                                   entry.get("heuristic_us"))
                step.run = cached[1]
                step.backend = entry["backend"]
                step.meta = dict(step.meta or {})
                step.meta["tuned"] = meta
                plan.dispatch[step.name] = entry["backend"]
                report["from_db"] += 1
                report["groups"][step.name] = meta
                continue
        group = _group_for(step, chain)
        if group is None:
            continue
        if entry is not None:
            tag_ok = fam is None or entry["backend"] in fam
            if not tag_ok or not group.legal(entry["backend"],
                                             entry.get("block")):
                entry = None          # decision no longer a legal lowering
        if entry is not None:
            meta = _tuned_meta(entry["backend"], entry.get("block"), "db",
                               step.name, entry["latency_us"],
                               entry.get("heuristic_us"))
            _apply(step, group, entry["backend"], entry.get("block"), meta,
                   plan.dispatch)
            _cache_run(key, entry["backend"], entry.get("block"),
                       group.node, step.run)
            report["from_db"] += 1
            report["groups"][step.name] = meta
            continue
        if mode == "readonly":
            report["kept_heuristic"] += 1
            continue
        # ---- measure -----------------------------------------------------
        if fam is not None:           # forced backend: family-only tuning
            group._cands = [c for c in group.candidates if c[0] in fam]
        if len(group.candidates) < 2:
            continue
        env = _synth_env(chain, group, seed=seed)
        from .dispatch import _gconv_step
        times: Dict[int, float] = {}

        def _measure(i: int, _g=group, _env=env, _times=times) -> float:
            tag, block = _g.candidates[i]
            run = jax.jit(_gconv_step(_g.node, _g.lower(tag, block)))
            s = measure_callable(run, _env, warmup=warmup, repeats=repeats)
            _times[i] = s
            reg.counter("tune_measurements", group=_g.name).inc()
            reg.histogram("tune_candidate_us",
                          buckets=[10, 100, 1000, 10000, 100000],
                          backend=tag).observe(s * 1e6)
            return s

        span = (tracer.span(f"tune:{step.name}", cat="tune",
                            attrs={"candidates": len(group.candidates)})
                if tracer is not None else nullcontext())
        with span:
            win, win_s, res = measured_select(
                len(group.candidates), _measure, budget=budget, seed=seed,
                strategy=strategy)
        tag, block = group.candidates[win]
        heur_s = times.get(0)
        rejected = None
        if win != 0 and heur_s is not None and heur_s < win_s * MARGIN:
            # not a decisive standalone win: keep the incumbent (see
            # MARGIN — marginal wins tend to invert in fused context)
            rejected = dict(backend=tag,
                            block=dict(block) if block else None,
                            latency_us=round(win_s * 1e6, 3),
                            reason="margin")
            win, win_s = 0, heur_s
            tag, block = group.candidates[0]
        recorder = TrajectoryRecorder(metric="latency_us")
        recorder.extend([s * 1e6 for _p, s in res.history])
        from ..obs import provenance
        entry = dict(backend=tag, block=dict(block) if block else None,
                     latency_us=round(win_s * 1e6, 3),
                     heuristic_us=(round(heur_s * 1e6, 3)
                                   if heur_s is not None else None),
                     heuristic_backend=group.heuristic,
                     n_candidates=len(group.candidates),
                     n_evals=res.n_evals, strategy=res.strategy,
                     trajectory=recorder.to_json(group=step.name),
                     provenance=provenance())
        if rejected is not None:
            entry["rejected"] = rejected
        if tag != step.backend or block is not None:
            switched.append((step, group, key, entry, step.run,
                             step.backend))
        db.record(key, entry)
        dirty = True
        meta = _tuned_meta(tag, block, "measured", step.name,
                           entry["latency_us"], entry["heuristic_us"])
        _apply(step, group, tag, block, meta, plan.dispatch)
        _cache_run(key, tag, block, group.node, step.run)
        report["measured"] += 1
        report["groups"][step.name] = meta
    if switched:
        keep, heur_us, tuned_us = _validate_e2e(
            chain, plan, {st.name: run for st, _g, _k, _e, run, _b
                          in switched},
            seed=seed, warmup=warmup, repeats=max(repeats, 7))
        report["e2e"] = dict(heuristic_us=heur_us, tuned_us=tuned_us,
                             kept=keep)
        if not keep:
            for step, group, key, entry, orig_run, orig_backend \
                    in switched:
                step.run = orig_run
                step.backend = orig_backend
                plan.dispatch[group.name] = orig_backend
                lat = entry["heuristic_us"] or entry["latency_us"]
                meta = _tuned_meta(orig_backend, None, "e2e-reject",
                                   step.name, lat, entry["heuristic_us"])
                step.meta["tuned"] = meta
                report["groups"][step.name] = meta
                db.record(key, dict(
                    entry, backend=orig_backend, block=None,
                    latency_us=lat,
                    rejected=dict(backend=entry["backend"],
                                  block=entry["block"],
                                  latency_us=entry["latency_us"],
                                  reason="e2e",
                                  heuristic_e2e_us=heur_us,
                                  tuned_e2e_us=tuned_us)))
    if dirty:
        save_db(db)
    plan.signature = _signature(plan, chain)
    report["signature"] = plan.signature
    report["metrics"] = reg.to_dict()
    return plan, report
