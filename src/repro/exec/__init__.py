"""Compiled GCONV-chain execution engine (the fast path).

The oracle interpreter (``repro.core.interpreter``) materializes the full
``(Ng, Nop, Nopc, Nks)`` expansion per node; this package compiles a chain
once — §4.3 fusion-group partitioning, per-GCONV backend dispatch
(grouped matmul / spatial conv / reductions / elementwise / fused
segments), Movement and Concat as metadata — and executes it as a single
jitted function.
"""
from .batch import BucketedCache, batch_bucket, pad_leading, unpad_leading
from .engine import CompiledChain, CompileOptions, compile_chain
from .dispatch import dispatch_gconv, plan_chain
from .lowering import classify_dim, dim_classes
from .serving import ServeEngine
from .shardplan import ShardPlan, derive_plan


def execute_gconv(node, x, k=None, operands=None, backend: str = "jnp"):
    """Execute ONE GCONV through the compiled-engine dispatch (testing
    helper: the differential property tests compare this against
    ``core.interpreter.eval_gconv``)."""
    k_shape = tuple(k.shape) if k is not None else None
    _tag, fn = dispatch_gconv(node, k_shape, backend=backend)
    lookup = (lambda op: operands[op.operand]) if operands else None
    return fn(x, k, lookup)


__all__ = ["CompiledChain", "CompileOptions", "compile_chain",
           "dispatch_gconv", "plan_chain", "classify_dim", "dim_classes",
           "execute_gconv", "BucketedCache", "batch_bucket", "pad_leading",
           "unpad_leading", "ServeEngine", "ShardPlan", "derive_plan"]
