"""Cycle-level tiled accelerator simulator (MPNA-style instrument).

The analytic cost model (:mod:`repro.core.costmodel`, paper Eqs. (6)-(10))
scores a whole node at once and therefore cannot see tile-granularity
effects: double-buffering stalls, load/compute overlap breakdowns at tile
boundaries, drain bubbles and contention between the input/kernel/output
streams. This package is the standard instrument for exactly those effects —
a tick-driven, tile-by-tile simulator that executes a mapped GCONV chain on
an :class:`~repro.core.accelerators.AcceleratorSpec` and reports per-node and
per-chain cycle/energy/stall/utilization breakdowns in the same units as the
analytic model, so the two can be cross-validated
(:mod:`repro.sim.validate`).

Layering:

  * :mod:`repro.sim.schedule` — lower a :class:`~repro.core.mapping.Mapping`
    into an ordered tile trace (per-tile word counts, MAC slots, refill and
    drain events), run-length aggregated via the trace's congruence
    structure so arbitrarily long traces stay O(1);
  * :mod:`repro.sim.buffers` — double-buffered I/K/O stream models charging
    GB-bandwidth-limited fill/drain cycles with per-buffer stall accounting;
  * :mod:`repro.sim.engine` — per-node tick loop overlapping next-tile loads
    and previous-tile drains with current-tile compute, plus chain-level
    handoff that respects operation-fusion groups;
  * :mod:`repro.sim.stats` — the result dataclasses;
  * :mod:`repro.sim.validate` — analytic-vs-sim cross-check over the CNN zoo
    and the Table-4 accelerator configurations.
"""
from .engine import simulate_chain, simulate_node
from .schedule import TileSchedule, TileStep
from .stats import ChainSimStats, NodeSimStats
from .validate import cross_validate

__all__ = ["simulate_chain", "simulate_node", "TileSchedule", "TileStep",
           "ChainSimStats", "NodeSimStats", "cross_validate"]
