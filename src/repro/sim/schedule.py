"""Lower a Mapping into an ordered tile trace.

A mapped GCONV executes as ``n_steps`` tile steps (the temporal loops outside
the innermost scratchpad reuse pointer); each step computes
``compute_per_step`` cycles on the array while the buffers refill/drain at
their own cadence: data type ``d`` refills ``tile_words[d]`` words every
``strides[d]`` steps (see :meth:`repro.core.mapping.Mapping.tile_structure`).
Aggregate trace totals equal the analytic movement (Eqs. (7)-(10)) exactly.

Two views of the same trace:

  * :meth:`TileSchedule.steps` — the explicit ordered trace, one
    :class:`TileStep` per tile step, with that step's refills (window start)
    and drains (window end). Feasible only for short traces; used by tests
    and inspection.
  * :meth:`TileSchedule.overlap_segments` — the double-buffer-aligned trace
    the engine consumes: per step, the words prefetched for the *next* tile
    and written back from the *previous* one. Identical steps are aggregated
    by exact congruence counting (the refill cadences form a divisibility
    chain, so the step classes are residue classes and their populations
    close-form), keeping million-tile traces O(1).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, FrozenSet, Iterator, List, Optional, Tuple

from repro.core.gconv import GConv
from repro.core.mapping import Mapping, TileStructure

DTYPES = ("I", "K", "O")


@dataclass(frozen=True)
class TileStep:
    """One tile step of the natural (un-overlapped) trace."""

    index: int                    # position in [0, n_steps)
    compute_cycles: int           # array-busy cycles of this step
    mac_slots: int                # PE slots issued (>= effectual MACs)
    loads: Dict[str, float]       # words refilled before this step (I/K/O-in)
    drains: Dict[str, float]      # words drained after this step completes


@dataclass(frozen=True)
class OverlapSegment:
    """``count`` consecutive identical steps of the double-buffered trace:
    while each computes, ``prefetch`` words stream in for the next tile and
    ``writeback`` words of the previous output window stream out."""

    count: int
    prefetch: Dict[str, float]    # {"I": words, "K": words}
    writeback: Dict[str, float]   # {"O": words}


# ---------------------------------------------------------------------------
# congruence arithmetic: the refill pattern of a nested loop trace
# ---------------------------------------------------------------------------
def _congruence_count(lo: int, hi: int, r: int, m: int) -> int:
    """#{t in [lo, hi] : t = r (mod m)}."""
    if lo > hi:
        return 0
    return (hi - r) // m - (lo - 1 - r) // m


def _merge_congruence(c1: Optional[Tuple[int, int]],
                      c2: Tuple[int, int]) -> Optional[Tuple[int, int]]:
    """Intersect two congruences (r, m) via CRT; None when incompatible."""
    if c1 is None:
        return None
    r1, m1 = c1
    r2, m2 = c2
    g = math.gcd(m1, m2)
    if (r2 - r1) % g:
        return None
    lcm = m1 // g * m2
    m2g = m2 // g
    if m2g == 1:
        x = r1
    else:
        k = ((r2 - r1) // g * pow(m1 // g, -1, m2g)) % m2g
        x = r1 + k * m1
    return (x % lcm, lcm)


def _event_counts(conds: Dict[str, Tuple[int, int]], lo: int, hi: int,
                  ) -> Dict[FrozenSet[str], int]:
    """Exact population of every event-subset class over t in [lo, hi].

    ``conds`` maps an event key to its congruence (residue, modulus). The
    returned dict gives, for each subset S of keys, the number of steps where
    *exactly* the events in S fire (inclusion-exclusion over the 'at least S'
    counts). Subsets with zero population are omitted; the empty frozenset
    holds the event-free steps.
    """
    keys = list(conds)
    at_least: Dict[FrozenSet[str], int] = {}
    for bits in range(1 << len(keys)):
        subset = frozenset(k for i, k in enumerate(keys) if bits >> i & 1)
        merged: Optional[Tuple[int, int]] = (0, 1)
        for k in subset:
            merged = _merge_congruence(merged, conds[k])
        at_least[subset] = (_congruence_count(lo, hi, *merged)
                            if merged is not None else 0)
    exact: Dict[FrozenSet[str], int] = {}
    for subset in at_least:
        n = 0
        for sup in at_least:
            if subset <= sup:
                n += (-1) ** (len(sup) - len(subset)) * at_least[sup]
        if n:
            exact[subset] = n
    return exact


# ---------------------------------------------------------------------------
# the schedule
# ---------------------------------------------------------------------------
class TileSchedule:
    """The ordered tile trace of one mapped GCONV node.

    ``k_scale`` scales kernel words per refill for broadcast kernels (Table
    2: e.g. FP1's output serving as FP2's kernel moves only its actual
    elements) and is 0 for ``main == 'none'`` nodes — mirroring the analytic
    model's movement adjustments so totals stay comparable.
    """

    def __init__(self, gconv: GConv, mapping: Mapping, k_scale: float = 1.0):
        assert mapping.gconv is gconv or mapping.gconv.name == gconv.name
        self.gconv = gconv
        self.mapping = mapping
        self.structure: TileStructure = mapping.tile_structure()
        self.k_scale = k_scale
        ts = self.structure
        self.n_steps: int = ts.n_steps
        self.compute_per_step: int = ts.compute_per_step
        spatial_slots = 1
        for e in mapping.spatial:
            spatial_slots *= e.factor
        self.mac_slots_per_step: int = ts.compute_per_step * spatial_slots
        self.tile_words: Dict[str, float] = {
            "I": float(ts.tile_words["I"]),
            "K": float(ts.tile_words["K"]) * k_scale,
            "O": float(ts.tile_words["O"]),
        }
        self.strides: Dict[str, int] = dict(ts.strides)

    # -- aggregate invariants ------------------------------------------------
    def total_words(self) -> Dict[str, float]:
        """Equals ``mapping.movement()`` (with the kernel scaling applied)."""
        return {d: self.tile_words[d] * self.structure.reloads[d]
                for d in DTYPES}

    def total_compute_cycles(self) -> int:
        """>= Eq. (6) cycles (ceil-split temporal loops can over-cover)."""
        return self.compute_per_step * self.n_steps

    def total_mac_slots(self) -> int:
        return self.mac_slots_per_step * self.n_steps

    # -- explicit ordered trace ---------------------------------------------
    def steps(self, limit: Optional[int] = 1 << 20) -> Iterator[TileStep]:
        """Enumerate the trace tile by tile (window-start refills,
        window-end drains). Guarded by ``limit`` — use the aggregated
        :meth:`overlap_segments` for long traces."""
        if limit is not None and self.n_steps > limit:
            raise ValueError(
                f"{self.gconv.name}: {self.n_steps} tile steps exceed the "
                f"explicit-trace limit {limit}; use overlap_segments()")
        s = self.strides
        w = self.tile_words
        for t in range(self.n_steps):
            loads = {d: w[d] for d in ("I", "K") if t % s[d] == 0 and w[d] > 0}
            drains = ({"O": w["O"]}
                      if (t + 1) % s["O"] == 0 and w["O"] > 0 else {})
            yield TileStep(index=t, compute_cycles=self.compute_per_step,
                           mac_slots=self.mac_slots_per_step,
                           loads=loads, drains=drains)

    # -- double-buffer-aligned aggregated trace ------------------------------
    def overlap_segments(self) -> Tuple[Dict[str, float],
                                        List[OverlapSegment],
                                        Dict[str, float]]:
        """Return ``(first_fill, segments, final_drain)``.

        ``first_fill`` are the words that must land before step 0 computes;
        each :class:`OverlapSegment` then covers steps whose overlapped
        traffic is identical: the prefetch for step t+1 (due when t+1 starts
        a new I/K window) and the write-back of the output window that closed
        at step t-1 (due when t starts a new O window). ``final_drain`` is
        the last output window, exposed after the trace ends.

        Ordering: segments are emitted first-occurrence-first — step 0, then
        the interior residue classes (by first firing step), then the last
        step. Within a class every step is identical, so order inside is
        immaterial to any cost the engine can charge.
        """
        T = self.n_steps
        w = self.tile_words
        s = self.strides
        first_fill = {d: w[d] for d in ("I", "K") if w[d] > 0}
        final_drain = {"O": w["O"]} if w["O"] > 0 else {}

        def seg(count: int, pre_i: bool, pre_k: bool, wb_o: bool,
                ) -> OverlapSegment:
            prefetch = {}
            if pre_i and w["I"] > 0:
                prefetch["I"] = w["I"]
            if pre_k and w["K"] > 0:
                prefetch["K"] = w["K"]
            writeback = {"O": w["O"]} if wb_o and w["O"] > 0 else {}
            return OverlapSegment(count=count, prefetch=prefetch,
                                  writeback=writeback)

        if T == 1:
            return first_fill, [seg(1, False, False, False)], final_drain

        segments: List[OverlapSegment] = []
        # step 0: prefetch for step 1; the first O window cannot have closed
        segments.append(seg(1, 1 % s["I"] == 0, 1 % s["K"] == 0, False))
        if T >= 3:
            # interior steps t in [1, T-2]:
            #   prefetch_d  <=> (t+1) % s_d == 0   <=> t = s_d - 1 (mod s_d)
            #   writeback_O <=> t % s_O == 0 (window ended at t-1)
            conds = {"I": ((s["I"] - 1) % s["I"], s["I"]),
                     "K": ((s["K"] - 1) % s["K"], s["K"]),
                     "O": (0, s["O"])}
            classes = _event_counts(conds, 1, T - 2)
            first_at = {}
            for subset in classes:
                merged: Optional[Tuple[int, int]] = (0, 1)
                for k in subset:
                    merged = _merge_congruence(merged, conds[k])
                first_at[subset] = merged[0] if merged else T
            for subset in sorted(classes, key=lambda ss: (first_at[ss],
                                                          sorted(ss))):
                segments.append(seg(classes[subset], "I" in subset,
                                    "K" in subset, "O" in subset))
        # last step: nothing left to prefetch; possibly a window closed at T-2
        segments.append(seg(1, False, False,
                            (T - 1) % s["O"] == 0 and T - 1 > 0))
        return first_fill, segments, final_drain
