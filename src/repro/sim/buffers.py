"""Double-buffered GB<->array stream models (ibuf/kbuf/obuf).

Each :class:`BufferPort` is one data-type stream between the global buffer
and the PE array: the input buffer (I) and kernel buffer (K) fill before a
tile computes, the output buffer (O) drains after it completes. All three
are double-buffered — the engine overlaps the *next* tile's fills and the
*previous* tile's drain with the current tile's compute and charges a stall
only for the exposed remainder.

Transfer cycles are GB-bandwidth-limited (``spec.gb_bandwidth``, words per
cycle, per data type — matching the analytic model's per-type ports). A
format-inconsistent input stream (§4.3: the producer's store format does not
match this consumer's parallel-load format and no loop exchange fixed it)
pays ``MISALIGN_FACTOR`` on its scratchpad fill path, exactly as the
analytic model charges it; accelerators that stream from the GB without
input scratchpads (``ls == 1``) don't care about formats.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.core.accelerators import AcceleratorSpec
from repro.core.costmodel import MISALIGN_FACTOR


@dataclass
class BufferPort:
    """One double-buffered data stream with per-buffer accounting."""

    dtype: str                   # "I" | "K" | "O"
    bandwidth: float             # GB<->array words/cycle for this stream
    misalign: float = 1.0        # §4.3 strided-access penalty multiplier
    words: float = 0.0           # total words moved through this stream
    transfers: int = 0           # refills (I/K) or drains (O)
    busy_cycles: float = 0.0     # cycles the stream was transferring
    stall_cycles: float = 0.0    # exposed cycles the array waited on it

    def transfer_cycles(self, words: float) -> float:
        if words <= 0:
            return 0.0
        return words / self.bandwidth * self.misalign

    def record_transfer(self, words: float, n: int = 1):
        if words <= 0 or n <= 0:
            return
        self.words += words * n
        self.transfers += n
        self.busy_cycles += self.transfer_cycles(words) * n

    def record_stall(self, cycles: float, n: int = 1):
        if cycles > 0 and n > 0:
            self.stall_cycles += cycles * n


def make_ports(spec: AcceleratorSpec, aligned: bool = True,
               ) -> Dict[str, BufferPort]:
    """The three streams of one node. ``aligned`` is the §4.3 load-format
    flag from :func:`repro.core.costmodel.chain_mappings`; the penalty only
    applies to the input scratchpad fill path (ls > 1), as in the analytic
    model."""
    ports = {}
    for dtype in ("I", "K", "O"):
        bw = max(1, spec.gb_bandwidth.get(dtype, 1))
        misalign = 1.0
        if dtype == "I" and not aligned and spec.ls.get("I", 1) > 1:
            misalign = MISALIGN_FACTOR
        ports[dtype] = BufferPort(dtype=dtype, bandwidth=bw,
                                  misalign=misalign)
    return ports
