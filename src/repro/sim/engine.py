"""Per-node tick loop and chain-level simulation.

A node runs tile by tile: while tile t computes, the double-buffered input
and kernel streams prefetch tile t+1 and the output stream writes back the
window that closed at t-1 (``repro.sim.schedule`` provides that
double-buffer-aligned trace). A tile step therefore costs

    max(compute_per_step, exposed overlapped traffic)

— the per-tile analogue of the analytic model's per-*node*
``max(compute, load)`` (Eq. 6 vs Eqs. 7-10). The difference between the two
is exactly what this simulator exists to measure: the first-tile fill, the
last-window drain, and every step where one stream's tile transfer exceeds
one tile's compute even though the *node-total* load would have fit under
the node-total compute.

Contention models:
  * ``"ports"`` (default) — each data type owns its GB port
    (``spec.gb_bandwidth`` is per type), streams transfer in parallel and a
    step waits on the slowest one; matches the analytic model's assumption.
  * ``"shared"`` — the three streams serialize on one bus (their cycles
    add), exposing I/K/O contention the analytic model cannot see.

Chain level: operation-fusion groups (``fuse_chain``) stream through their
host node's operators with no GB round trip — they are simulated as part of
the host (the fused chain simply no longer contains them). At unfused
producer->consumer handoffs the consumer's first-tile fill overlaps the
producer's exposed drain (both move through the GB, back to back), credited
as ``handoff_overlap_cycles``.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

from repro.core.accelerators import AcceleratorSpec
from repro.core.chain import Chain, Concat, Movement
from repro.core.costmodel import (chain_mappings, gconv_energy,
                                  kernel_movement_scale, _k_elems,
                                  _movement_node_cost)
from repro.core.fusion import fuse_chain
from repro.core.gconv import GConv
from repro.core.mapping import Mapping

from .buffers import make_ports
from .schedule import TileSchedule
from .stats import ChainSimStats, NodeSimStats


def simulate_node(g: GConv, spec: AcceleratorSpec,
                  mapping: Optional[Mapping] = None,
                  aligned: bool = True,
                  k_actual_elems: Optional[int] = None,
                  energy_overhead: float = 0.19,
                  contention: str = "ports") -> NodeSimStats:
    """Tick through one mapped GCONV node tile by tile."""
    if contention not in ("ports", "shared"):
        raise ValueError(f"unknown contention model {contention!r}")
    if mapping is None:
        from repro.core.mapping import map_gconv
        mapping = map_gconv(g, spec)
    sched = TileSchedule(g, mapping,
                         k_scale=kernel_movement_scale(g, k_actual_elems))
    ports = make_ports(spec, aligned=aligned)
    C = float(sched.compute_per_step)

    def overlap_cost(traffic: Dict[str, float]) -> Tuple[float, Dict[str, float]]:
        cycles = {d: ports[d].transfer_cycles(w) for d, w in traffic.items()}
        if contention == "shared":
            return sum(cycles.values()), cycles
        return max(cycles.values(), default=0.0), cycles

    def charge_exposed(per: Dict[str, float], over: float, exposed: float,
                       count: int = 1):
        """Attribute an exposed wait to the responsible stream(s): the
        binding (slowest) stream under per-type ports, prorated by bus share
        under a shared bus. Keeps sum(stalls) == total - compute exactly."""
        if exposed <= 0 or over <= 0 or not per:
            return
        if contention == "shared":
            for d, cyc in per.items():
                ports[d].record_stall(exposed * cyc / over, count)
        else:
            bind = max(per, key=lambda d: per[d])
            ports[bind].record_stall(exposed, count)

    first_fill, segments, final_drain = sched.overlap_segments()

    # --- prologue: nothing computes while the first tile lands -------------
    fill_cost, fill_per = overlap_cost(first_fill)
    for d, w in first_fill.items():
        ports[d].record_transfer(w)
    charge_exposed(fill_per, fill_cost, fill_cost)
    total = fill_cost

    # --- steady state: compute overlaps prefetch + write-back --------------
    for seg in segments:
        traffic = dict(seg.prefetch)
        traffic.update(seg.writeback)
        over, per = overlap_cost(traffic)
        step_cost = max(C, over)
        total += step_cost * seg.count
        for d, w in seg.prefetch.items():
            ports[d].record_transfer(w, seg.count)
        for d, w in seg.writeback.items():
            ports[d].record_transfer(w, seg.count)
        charge_exposed(per, over, step_cost - C, seg.count)

    # --- epilogue: the last output window drains with nothing to hide it ---
    drain_cost, drain_per = overlap_cost(final_drain)
    for d, w in final_drain.items():
        ports[d].record_transfer(w)
    charge_exposed(drain_per, drain_cost, drain_cost)
    total += drain_cost

    movement = sched.total_words()
    energy = gconv_energy(g, movement, energy_overhead)
    return NodeSimStats(
        name=g.name, kind="gconv", tiles=sched.n_steps,
        compute_cycles=float(sched.total_compute_cycles()),
        total_cycles=total, fill_cycles=fill_cost, drain_cycles=drain_cost,
        stalls={d: p.stall_cycles for d, p in ports.items()},
        buffers=ports, movement=movement, energy=energy,
        aligned=aligned, mapping=mapping)


def _simulate_movement(node, chain: Chain,
                       spec: AcceleratorSpec) -> NodeSimStats:
    """Concat/Movement pseudo-nodes: pure GB traffic, no array compute —
    delegated to the analytic model's cost so the two engines stay in exact
    parity on movement nodes."""
    nc = _movement_node_cost(node, chain, spec, traditional=True)
    # the array idles for the full transfer: book it as I/O stall time so
    # compute + stalls == total holds chain-wide, not just on gconv nodes
    return NodeSimStats(name=node.name, kind="movement",
                        total_cycles=nc.latency, fill_cycles=nc.latency,
                        stalls={"I": nc.latency / 2, "O": nc.latency / 2},
                        movement={k: float(v) for k, v in nc.movement.items()},
                        energy=nc.energy)


def handoff_credit(prev_name: Optional[str],
                   prev_stats: Optional[NodeSimStats],
                   node, node_stats: NodeSimStats,
                   contention: str = "ports") -> float:
    """Producer-drain/consumer-fill overlap credited at a back-to-back
    GCONV handoff: a consumer scheduled right after its producer starts
    filling its first tile while the producer's last window drains. Only
    possible with per-type ports — on a shared bus the drain and the fill
    serialize by definition, so no credit. Shared with ``repro.syssim``
    (which applies it only when both nodes land back-to-back on the same
    unit) so the two engines charge the identical rule."""
    if (contention == "ports" and prev_stats is not None
            and isinstance(node, GConv)
            and node.input == prev_name
            and prev_stats.kind == "gconv"):
        return min(prev_stats.drain_cycles, node_stats.fill_cycles)
    return 0.0


def simulate_chain(chain: Chain, spec: AcceleratorSpec,
                   fuse: bool = True, consistent: bool = True,
                   energy_overhead: float = 0.19,
                   contention: str = "ports",
                   precomputed: Optional[Tuple[Dict[str, Mapping],
                                               Dict[str, bool]]] = None,
                   overrides: Optional[Dict[str, Mapping]] = None,
                   ) -> ChainSimStats:
    """Simulate a whole GCONV chain (the paper's GC-<accel> system mode:
    §4.3 fusion + consistent mapping, every node on the full array).

    ``precomputed`` takes a :func:`repro.core.costmodel.chain_mappings`
    result (only meaningful with ``fuse=False`` on an already-fused chain)
    so analytic and sim engines charge structurally identical mappings.
    ``overrides`` forwards per-node mapping replacements (e.g. ``repro.dse``
    search results) to :func:`chain_mappings`; mutually exclusive with
    ``precomputed`` (bake overrides into that result instead)."""
    groups: Dict[str, list] = {}
    if fuse:
        chain, report = fuse_chain(chain)
        groups = report.groups
    if precomputed is not None and not fuse:
        if overrides:
            raise ValueError("pass overrides to chain_mappings() when "
                             "supplying precomputed, not both here")
        mappings, aligned = precomputed
    else:
        mappings, aligned = chain_mappings(chain, spec, consistent=consistent,
                                           overrides=overrides)

    nodes = []
    prev_name: Optional[str] = None
    prev_stats: Optional[NodeSimStats] = None
    handoff = 0.0
    for name, node in chain.nodes.items():
        if isinstance(node, (Concat, Movement)):
            ns = _simulate_movement(node, chain, spec)
        else:
            ns = simulate_node(node, spec, mapping=mappings[name],
                               aligned=aligned.get(name, True),
                               k_actual_elems=_k_elems(chain, node),
                               energy_overhead=energy_overhead,
                               contention=contention)
        handoff += handoff_credit(prev_name, prev_stats, node, ns,
                                  contention=contention)
        nodes.append(ns)
        prev_name, prev_stats = name, ns
    return ChainSimStats(chain_name=chain.name, accel=spec.name, nodes=nodes,
                         fused_groups=groups,
                         handoff_overlap_cycles=handoff)
