"""Analytic-model vs cycle-level-simulator cross-validation.

Runs both evaluation engines over the CNN zoo x the Table-4 accelerator
configurations and reports their divergence. The simulator is a strict
refinement of the analytic model — same mappings (Algorithm 1 + §4.3
consistent mapping), same fusion, same movement totals, same energy units —
so three invariants must hold for every (network, accelerator) pair:

  * ``sim cycles >= analytic compute cycles`` (Eq. 6 is a lower bound: the
    sim adds fills, drains and per-tile stalls on top of array-busy time);
  * ``sim movement == analytic movement`` (Eqs. 7-10 word-for-word);
  * ``sim energy == analytic energy`` (movement-dominated, same constants).

The interesting number is ``cycles_ratio`` — how much latency the
tile-granularity effects add on top of the analytic ``max(compute, load)``
estimate. Pairs where it is large are exactly where the paper's headline
speedups would need a cycle-accurate caveat.
"""
from __future__ import annotations

import json
import os
from typing import List, Optional, Sequence, Tuple

from repro.core import accelerators as acc
from repro.core.costmodel import chain_mappings, gconv_chain_cost
from repro.core.fusion import fuse_chain

from .engine import simulate_chain

DEFAULT_ACCELS = ("ER", "TPU", "EP")

# Analytic-vs-sim agreement contract, shared by the validation tests, the
# multi-fidelity promoter in ``repro.dse.evaluate`` and the ``dse_micro`` CI
# gate: on identical mappings the sim's total latency may exceed the analytic
# ``max(compute, load)`` by first-tile fills, last-window drains and per-tile
# quantization (observed zoo x {ER,TPU,EP} max: 1.41x) but must never fall
# below the Eq.-6 compute bound, while movement and energy agree
# word-for-word (both are derived from the same TileStructure).
CYCLES_RATIO_TOL = 1.75
DRIFT_TOL = 1e-9


def agreement(sim_total_cycles: float, analytic) -> dict:
    """Per-point agreement record between a :class:`ChainSimStats` total and
    its analytic :class:`~repro.core.costmodel.ChainCost` counterpart."""
    ratio = sim_total_cycles / max(analytic.latency, 1e-12)
    return dict(
        cycles_ratio=round(ratio, 4),
        above_compute_bound=bool(
            sim_total_cycles >= analytic.compute_cycles * (1 - 1e-9)),
        within_tolerance=bool(ratio <= CYCLES_RATIO_TOL),
    )


def validate_pair(chain, spec, fuse: bool = True, consistent: bool = True,
                  contention: str = "ports",
                  fusion_report=None) -> Tuple[dict, "object"]:
    """One (chain, accelerator) cross-check; returns (row, ChainSimStats).

    Pass ``fuse=False`` with an already-fused chain (and its
    ``fusion_report``) to share one fusion pass across accelerators —
    fusion is accelerator-independent."""
    if fuse:
        fused, report = fuse_chain(chain)
    else:
        fused, report = chain, fusion_report
    # both engines score the same fused chain and charge the exact same
    # mappings (fused and mapped once, here): parity by construction
    pre = chain_mappings(fused, spec, consistent=consistent)
    analytic = gconv_chain_cost(fused, spec, consistent=consistent,
                                precomputed=pre)
    sim = simulate_chain(fused, spec, fuse=False, consistent=consistent,
                         contention=contention, precomputed=pre)
    if report is not None:
        sim.fused_groups = report.groups
    worst = max((n for n in sim.nodes if n.kind == "gconv"),
                key=lambda n: n.stall_cycles, default=None)
    agree = agreement(sim.total_cycles, analytic)
    row = dict(
        net=chain.name, accel=spec.name,
        sim_cycles=round(sim.total_cycles, 1),
        analytic_latency=round(analytic.latency, 1),
        analytic_compute=round(analytic.compute_cycles, 1),
        cycles_ratio=agree["cycles_ratio"],
        above_compute_bound=agree["above_compute_bound"],
        stall_frac=round(sim.stall_cycles / max(sim.total_cycles, 1e-12), 4),
        utilization=round(sim.utilization, 4),
        energy_drift=round(abs(sim.energy / max(analytic.energy, 1e-12) - 1),
                           6),
        movement_drift=round(
            abs(sim.movement_words / max(analytic.movement_words, 1e-12) - 1),
            6),
        top_stall_node=(worst.name if worst is not None else None),
    )
    return row, sim


def cross_validate(nets: Optional[Sequence[str]] = None,
                   accels: Sequence[str] = DEFAULT_ACCELS,
                   fuse: bool = True, consistent: bool = True,
                   contention: str = "ports",
                   out_dir: Optional[str] = None,
                   ) -> Tuple[List[dict], dict]:
    """Zoo x accelerators sweep; returns (rows, summary) in the benchmark
    harness convention. When ``out_dir`` is given, writes one JSON per pair
    with the full per-node stall/utilization breakdown."""
    from repro.models import cnn

    nets = tuple(nets) if nets else tuple(cnn.ZOO)
    rows: List[dict] = []
    for net in nets:
        chain = cnn.build(net)
        # fusion is accelerator-independent: fuse once per network
        if fuse:
            chain, report = fuse_chain(chain)
        else:
            report = None
        for name in accels:
            spec = acc.get(name)
            row, sim = validate_pair(chain, spec, fuse=False,
                                     consistent=consistent,
                                     contention=contention,
                                     fusion_report=report)
            rows.append(row)
            if out_dir:
                os.makedirs(out_dir, exist_ok=True)
                detail = dict(net=net, accel=name, chain=sim.summary(),
                              nodes=[n.summary() for n in sim.nodes],
                              fused_groups=sim.fused_groups)
                path = os.path.join(out_dir, f"{net}__{name}.json")
                with open(path, "w") as f:
                    json.dump(detail, f, indent=1, default=str)
    ratios = [r["cycles_ratio"] for r in rows]
    summary = dict(
        pairs=len(rows),
        all_above_compute_bound=all(r["above_compute_bound"] for r in rows),
        max_cycles_ratio=round(max(ratios), 4),
        mean_cycles_ratio=round(sum(ratios) / len(ratios), 4),
        max_energy_drift=max(r["energy_drift"] for r in rows),
        max_movement_drift=max(r["movement_drift"] for r in rows),
        mean_stall_frac=round(sum(r["stall_frac"] for r in rows) / len(rows),
                              4),
    )
    return rows, summary
