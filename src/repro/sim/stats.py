"""Result dataclasses for the cycle-level simulator.

Cycle counts are accelerator cycles; energy is in the same relative units as
:mod:`repro.core.costmodel` (one local-scratchpad access = 1.0, Eyeriss
convention), so sim and analytic numbers are directly comparable.

Both stat classes emit through the unified :mod:`repro.obs.metrics`
registry: ``to_metrics()`` populates labeled counter/gauge families
(``sim_cycles{phase=...}``, ``sim_stall_cycles{buffer=...}``,
``sim_movement_words{tensor=...}``, ...) and ``summary()`` — the dict
shape ``sim/validate.py`` and ``results/sim/`` artifacts consume — is
*derived from that registry*, so the flat summaries and the versioned
metrics schema can never drift apart.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.mapping import Mapping
from repro.obs.metrics import Metrics

from .buffers import BufferPort


@dataclass
class NodeSimStats:
    name: str
    kind: str                          # "gconv" | "movement"
    tiles: int = 0                     # tile steps executed
    compute_cycles: float = 0.0        # array-busy cycles (>= Eq. 6)
    total_cycles: float = 0.0          # compute + exposed fill/stall/drain
    fill_cycles: float = 0.0           # un-hidable first-tile fill
    drain_cycles: float = 0.0          # un-hidable last-window drain
    stalls: Dict[str, float] = field(default_factory=dict)  # per buffer
    buffers: Dict[str, BufferPort] = field(default_factory=dict)
    movement: Dict[str, float] = field(default_factory=dict)
    energy: float = 0.0
    aligned: bool = True
    mapping: Optional[Mapping] = None

    @property
    def stall_cycles(self) -> float:
        return sum(self.stalls.values())

    @property
    def utilization(self) -> float:
        """Array-busy fraction of the node's wall-clock cycles."""
        if self.total_cycles <= 0:
            return 1.0 if self.kind == "gconv" else 0.0
        return self.compute_cycles / self.total_cycles

    def to_metrics(self, reg: Optional[Metrics] = None,
                   **labels) -> Metrics:
        """Emit this node into a metrics registry (extra ``labels`` — e.g.
        ``chain=``/``accel=`` — ride along on every series)."""
        reg = Metrics() if reg is None else reg
        lbl = dict(node=self.name, kind=self.kind, **labels)
        reg.counter("sim_tiles", **lbl).inc(self.tiles)
        for phase, v in (("total", self.total_cycles),
                         ("compute", self.compute_cycles),
                         ("fill", self.fill_cycles),
                         ("drain", self.drain_cycles)):
            reg.counter("sim_cycles", phase=phase, **lbl).inc(v)
        for buf, v in self.stalls.items():
            reg.counter("sim_stall_cycles", buffer=buf, **lbl).inc(v)
        for tensor, v in self.movement.items():
            reg.counter("sim_movement_words", tensor=tensor, **lbl).inc(v)
        reg.counter("sim_energy", **lbl).inc(self.energy)
        reg.gauge("sim_utilization", **lbl).set(self.utilization)
        return reg

    def summary(self) -> dict:
        reg = self.to_metrics()
        lbl = dict(node=self.name, kind=self.kind)
        cyc = lambda phase: reg.value("sim_cycles", phase=phase, **lbl)
        return dict(name=self.name, kind=self.kind,
                    tiles=int(reg.value("sim_tiles", **lbl)),
                    cycles=cyc("total"),
                    compute_cycles=cyc("compute"),
                    fill_cycles=round(cyc("fill"), 1),
                    drain_cycles=round(cyc("drain"), 1),
                    stall_cycles=round(self.stall_cycles, 1),
                    stalls={d: round(reg.value("sim_stall_cycles",
                                               buffer=d, **lbl), 1)
                            for d in self.stalls},
                    utilization=round(reg.value("sim_utilization",
                                                **lbl), 4),
                    movement={t: reg.value("sim_movement_words",
                                           tensor=t, **lbl)
                              for t in self.movement},
                    energy=reg.value("sim_energy", **lbl))


@dataclass
class ChainSimStats:
    chain_name: str
    accel: str
    nodes: List[NodeSimStats]
    # surviving host -> fused-in members streaming through its operators
    # (no GB round trip); from repro.core.fusion.FusionReport.groups
    fused_groups: Dict[str, List[str]] = field(default_factory=dict)
    # producer-drain/consumer-fill overlap credited at node handoffs
    handoff_overlap_cycles: float = 0.0

    @property
    def total_cycles(self) -> float:
        return (sum(n.total_cycles for n in self.nodes)
                - self.handoff_overlap_cycles)

    @property
    def compute_cycles(self) -> float:
        return sum(n.compute_cycles for n in self.nodes)

    @property
    def stall_cycles(self) -> float:
        # handoff-hidden cycles come out of per-node fill/drain stalls, so
        # they are no longer exposed waiting at chain level; subtracting
        # them here keeps compute + stalls == total_cycles exactly
        return (sum(n.stall_cycles for n in self.nodes)
                - self.handoff_overlap_cycles)

    @property
    def movement_words(self) -> float:
        return sum(sum(n.movement.values()) for n in self.nodes)

    @property
    def energy(self) -> float:
        return sum(n.energy for n in self.nodes)

    @property
    def utilization(self) -> float:
        total = self.total_cycles
        return self.compute_cycles / total if total > 0 else 1.0

    def to_metrics(self, reg: Optional[Metrics] = None,
                   per_node: bool = False) -> Metrics:
        """Chain-level series labeled ``chain``/``accel``; with
        ``per_node=True`` every node's series is emitted alongside under
        the same labels."""
        reg = Metrics() if reg is None else reg
        lbl = dict(chain=self.chain_name, accel=self.accel)
        for phase, v in (("total", self.total_cycles),
                         ("compute", self.compute_cycles),
                         ("stall", self.stall_cycles)):
            reg.counter("sim_chain_cycles", phase=phase, **lbl).inc(v)
        reg.counter("sim_chain_movement_words", **lbl).inc(
            self.movement_words)
        reg.counter("sim_chain_energy", **lbl).inc(self.energy)
        reg.counter("sim_handoff_overlap_cycles", **lbl).inc(
            self.handoff_overlap_cycles)
        reg.gauge("sim_chain_utilization", **lbl).set(self.utilization)
        reg.gauge("sim_fused_groups", **lbl).set(len(self.fused_groups))
        if per_node:
            for n in self.nodes:
                n.to_metrics(reg, **lbl)
        return reg

    def summary(self) -> dict:
        reg = self.to_metrics()
        lbl = dict(chain=self.chain_name, accel=self.accel)
        cyc = lambda phase: reg.value("sim_chain_cycles", phase=phase,
                                      **lbl)
        return dict(chain=self.chain_name, accel=self.accel, mode="sim",
                    cycles=cyc("total"),
                    compute_cycles=cyc("compute"),
                    stall_cycles=round(cyc("stall"), 1),
                    utilization=round(reg.value("sim_chain_utilization",
                                                **lbl), 4),
                    movement=reg.value("sim_chain_movement_words", **lbl),
                    energy=reg.value("sim_chain_energy", **lbl),
                    fused_groups=int(reg.value("sim_fused_groups", **lbl)),
                    handoff_overlap=round(
                        reg.value("sim_handoff_overlap_cycles", **lbl), 1))
