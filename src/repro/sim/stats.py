"""Result dataclasses for the cycle-level simulator.

Cycle counts are accelerator cycles; energy is in the same relative units as
:mod:`repro.core.costmodel` (one local-scratchpad access = 1.0, Eyeriss
convention), so sim and analytic numbers are directly comparable.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.mapping import Mapping

from .buffers import BufferPort


@dataclass
class NodeSimStats:
    name: str
    kind: str                          # "gconv" | "movement"
    tiles: int = 0                     # tile steps executed
    compute_cycles: float = 0.0        # array-busy cycles (>= Eq. 6)
    total_cycles: float = 0.0          # compute + exposed fill/stall/drain
    fill_cycles: float = 0.0           # un-hidable first-tile fill
    drain_cycles: float = 0.0          # un-hidable last-window drain
    stalls: Dict[str, float] = field(default_factory=dict)  # per buffer
    buffers: Dict[str, BufferPort] = field(default_factory=dict)
    movement: Dict[str, float] = field(default_factory=dict)
    energy: float = 0.0
    aligned: bool = True
    mapping: Optional[Mapping] = None

    @property
    def stall_cycles(self) -> float:
        return sum(self.stalls.values())

    @property
    def utilization(self) -> float:
        """Array-busy fraction of the node's wall-clock cycles."""
        if self.total_cycles <= 0:
            return 1.0 if self.kind == "gconv" else 0.0
        return self.compute_cycles / self.total_cycles

    def summary(self) -> dict:
        return dict(name=self.name, kind=self.kind, tiles=self.tiles,
                    cycles=self.total_cycles,
                    compute_cycles=self.compute_cycles,
                    fill_cycles=round(self.fill_cycles, 1),
                    drain_cycles=round(self.drain_cycles, 1),
                    stall_cycles=round(self.stall_cycles, 1),
                    stalls={d: round(v, 1) for d, v in self.stalls.items()},
                    utilization=round(self.utilization, 4),
                    movement=self.movement, energy=self.energy)


@dataclass
class ChainSimStats:
    chain_name: str
    accel: str
    nodes: List[NodeSimStats]
    # surviving host -> fused-in members streaming through its operators
    # (no GB round trip); from repro.core.fusion.FusionReport.groups
    fused_groups: Dict[str, List[str]] = field(default_factory=dict)
    # producer-drain/consumer-fill overlap credited at node handoffs
    handoff_overlap_cycles: float = 0.0

    @property
    def total_cycles(self) -> float:
        return (sum(n.total_cycles for n in self.nodes)
                - self.handoff_overlap_cycles)

    @property
    def compute_cycles(self) -> float:
        return sum(n.compute_cycles for n in self.nodes)

    @property
    def stall_cycles(self) -> float:
        # handoff-hidden cycles come out of per-node fill/drain stalls, so
        # they are no longer exposed waiting at chain level; subtracting
        # them here keeps compute + stalls == total_cycles exactly
        return (sum(n.stall_cycles for n in self.nodes)
                - self.handoff_overlap_cycles)

    @property
    def movement_words(self) -> float:
        return sum(sum(n.movement.values()) for n in self.nodes)

    @property
    def energy(self) -> float:
        return sum(n.energy for n in self.nodes)

    @property
    def utilization(self) -> float:
        total = self.total_cycles
        return self.compute_cycles / total if total > 0 else 1.0

    def summary(self) -> dict:
        return dict(chain=self.chain_name, accel=self.accel, mode="sim",
                    cycles=self.total_cycles,
                    compute_cycles=self.compute_cycles,
                    stall_cycles=round(self.stall_cycles, 1),
                    utilization=round(self.utilization, 4),
                    movement=self.movement_words, energy=self.energy,
                    fused_groups=len(self.fused_groups),
                    handoff_overlap=round(self.handoff_overlap_cycles, 1))
