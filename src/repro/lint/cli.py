"""``python -m repro.lint`` — sweep the zoo + LM chains through the
static verifier with a severity-gated exit code.

    python -m repro.lint                         # reduced zoo + LM,
                                                 # backends auto+pallas,
                                                 # no-mesh + faked 4x2
    python -m repro.lint --scale full            # paper-scale networks
    python -m repro.lint --mutants               # + seeded mutation corpus
    python -m repro.lint --rules                 # print the rule catalog

Exit codes: 0 — no findings at/above ``--fail-on`` (default ``error``)
anywhere in the sweep; 1 — such findings exist (with ``--mutants`` this
is the EXPECTED outcome: the corpus deliberately contains broken
artifacts); 2 — the verifier itself is broken (a mutant was missed, a
clean base produced a false positive, or a clean corpus chain has
errors). The last stdout line is a one-line JSON summary for machine
consumers (the ``lint_micro`` CI gate).

The "mesh" column needs no devices: shard-plan derivation only reads
axis geometry, so the sweep fakes an 8-device DxM=4x2 mesh in-process
(:func:`repro.lint.fake_mesh`) — no subprocess, no
``--xla_force_host_platform_device_count``.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List

from . import fake_mesh, lint_chain
from .findings import LintReport
from .registry import RULES


def _tiny_lm_cfg(kind: str):
    from ..models.common import ModelConfig
    base = dict(name=f"tiny-{kind}", family="dense", n_layers=1,
                d_model=16, n_heads=2, n_kv_heads=2, d_ff=32, vocab=64)
    if kind == "moe":
        base.update(family="moe", n_experts=4, top_k=2)
    return ModelConfig(**base)


def corpus_chains(scale: str = "reduced") -> list:
    """The sweep corpus: all 7 zoo nets + the LM dense/MoE block chains."""
    from ..models import cnn, lm_chain
    reduced = scale != "full"
    chains = []
    for name in cnn.ZOO:
        kw = {"batch": 2} if reduced else {}
        chains.append(cnn.build(name, reduced=reduced, **kw))
    for kind in ("dense", "moe"):
        chains.append(lm_chain.block_chain(_tiny_lm_cfg(kind), 2, 8,
                                           name=f"lm_{kind}"))
    return chains


def sweep(scale: str = "reduced", backends=("auto", "pallas"),
          mesh_specs=(None, "4x2")) -> List[LintReport]:
    reports = []
    for chain in corpus_chains(scale):
        for backend in backends:
            for spec in mesh_specs:
                mesh = fake_mesh(spec) if spec else None
                reports.append(lint_chain(chain, backend=backend,
                                          mesh=mesh))
    return reports


def _print_rules():
    width = max(len(r) for r in RULES)
    for rid, info in sorted(RULES.items()):
        print(f"{rid:{width}s}  {info.layer:5s} {info.severity:5s} "
              f"{info.summary}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="static verifier sweep over the zoo + LM chains")
    ap.add_argument("--scale", choices=("reduced", "full"),
                    default="reduced")
    ap.add_argument("--backends", default="auto,pallas",
                    help="comma list of dispatch backends to sweep")
    ap.add_argument("--mesh", default="4x2",
                    help="faked mesh spec ('D' or 'DxM'; 'none' disables "
                         "the mesh column — the no-mesh column always "
                         "runs)")
    ap.add_argument("--fail-on", choices=("info", "warn", "error"),
                    default="error", help="exit 1 on findings at/above "
                                          "this severity")
    ap.add_argument("--show", choices=("info", "warn", "error"),
                    default="warn", help="minimum severity to print")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--mutants", action="store_true",
                    help="also run the seeded mutation corpus (exit 2 if "
                         "any mutant is missed or a clean base "
                         "false-positives)")
    ap.add_argument("--rules", action="store_true",
                    help="print the rule catalog and exit")
    args = ap.parse_args(argv)

    if args.rules:
        _print_rules()
        return 0

    backends = [b for b in args.backends.split(",") if b]
    meshes = [None] + ([args.mesh] if args.mesh.lower() != "none" else [])
    reports = sweep(args.scale, backends, meshes)

    gated = sum(len(r.at_least(args.fail_on)) for r in reports)
    counts = {s: sum(r.counts()[s] for r in reports)
              for s in ("error", "warn", "info")}

    mut_rows, mut_ok = None, True
    if args.mutants:
        from .mutations import corpus_ok, run_corpus
        mut_rows = run_corpus()
        mut_ok = corpus_ok(mut_rows)

    if args.format == "text":
        for r in reports:
            print(r.to_text(min_severity=args.show))
        if mut_rows is not None:
            print(f"\nmutation corpus: {len(mut_rows)} mutants, "
                  f"{sum(r['caught'] for r in mut_rows)} caught, "
                  f"{sum(r['false_positive'] for r in mut_rows)} false "
                  f"positives")
            for r in mut_rows:
                mark = "caught" if r["caught"] else "MISSED"
                fp = "" if not r["false_positive"] else "  FALSE-POSITIVE"
                print(f"  {r['mutant']:28s} -> {r['rule']:32s} {mark}{fp}")

    # the verifier itself is broken if a mutant is missed or a clean
    # mutant base false-positives
    broken = not mut_ok
    summary = dict(
        scale=args.scale, backends=backends,
        meshes=[m or "none" for m in meshes], chains=len(reports),
        counts=counts, gated=gated, fail_on=args.fail_on,
        clean=gated == 0,
        mutants=(None if mut_rows is None else dict(
            total=len(mut_rows),
            caught=sum(r["caught"] for r in mut_rows),
            false_positives=sum(r["false_positive"] for r in mut_rows),
            all_caught=mut_ok)),
        ok=not broken)
    print(json.dumps(summary))
    if broken:
        return 2
    # with --mutants the corpus is present, so the gated sweep findings
    # plus the (deliberately broken) mutants make nonzero the expected
    # outcome; without it, nonzero means the real corpus is dirty
    if args.mutants:
        return 1
    return 1 if gated else 0


if __name__ == "__main__":
    sys.exit(main())
