"""Chain-IR passes: whole-chain re-validation beyond the add-time checks.

These run on the *source* chain (plus the fused chain, for liveness, when
one is available): structural integrity (dangling outputs, use-before-def,
shape re-check), reachability (dead nodes, unused inputs/params), no-op
``Movement`` detection, the dtype-propagation audit (``out_dtype``
quantization points §4.3 fusion refuses to absorb — the ROADMAP int8
item's work list), and an interval-based liveness analysis whose
peak-live-words is checked against each Table-4 accelerator's global
buffer (the static half of the paged-KV roadmap item).
"""
from __future__ import annotations

import copy

from ..core.chain import Chain, Movement
from ..core.fusion import _MAIN_AS_UNARY
from ..core.gconv import GConv
from .registry import lint_pass, make_finding, rule

R_DANGLING = rule("chain.dangling-output", "chain", "error",
                  "a chain output names no node")
R_USE_BEFORE_DEF = rule("chain.use-before-def", "chain", "error",
                        "a node references a tensor produced later "
                        "(or never)")
R_SHAPE = rule("chain.shape-mismatch", "chain", "error",
               "a node's operand shapes violate the GCONV dim contract")
R_DEAD = rule("chain.dead-node", "chain", "warn",
              "a node is unreachable from the chain outputs")
R_UNUSED_INPUT = rule("chain.unused-input", "chain", "warn",
                      "a chain input is referenced by no node")
R_UNUSED_PARAM = rule("chain.unused-param", "chain", "warn",
                      "a chain param is referenced by no node")
R_NOOP_MOVE = rule("chain.noop-movement", "chain", "warn",
                   "a Movement node is an identity (no reshape, "
                   "transpose, flip, or gather)")
R_QUANT = rule("chain.quant-fusion-barrier", "chain", "info",
               "an out_dtype quantization point blocks §4.3 fusion "
               "(int8 roadmap work list)")
R_PEAK = rule("chain.peak-live-bytes", "chain", "info",
              "interval-liveness peak live footprint of the chain")
R_GB = rule("chain.gb-capacity", "chain", "warn",
            "peak live words exceed a Table-4 accelerator's global buffer")

_DTYPE_BYTES = {"float64": 8, "int64": 8, "float32": 4, "int32": 4,
                "float16": 2, "bfloat16": 2, "int16": 2,
                "int8": 1, "uint8": 1, "fp8": 1, "bool": 1}


def _dtype_bytes(dtype) -> int:
    return _DTYPE_BYTES.get(str(dtype), 4)


def _node_dtype(node) -> str:
    if isinstance(node, GConv) and node.out_dtype is not None:
        return str(node.out_dtype)
    return "float32"


def _implicit_outputs(chain: Chain):
    if chain.outputs:
        return [o for o in chain.outputs if o in chain.nodes]
    names = list(chain.nodes)
    return names[-1:] if names else []


@lint_pass("chain")
def check_structure(ctx):
    """Dangling outputs, use-before-def, full shape re-check (the
    ``validate()`` invariants, reported as findings instead of raising
    on the first hit). Runs on a deepcopy: ``_check_shapes`` canonicalizes
    Concat/Movement out_shapes in place."""
    c = copy.deepcopy(ctx.source)
    seen = set(c.inputs) | set(c.params)
    for name, node in c.nodes.items():
        for ref in Chain._refs(node):
            if ref not in seen:
                yield make_finding(
                    ctx, R_USE_BEFORE_DEF, node=name, ref=ref,
                    message=f"consumes {ref!r} before production")
        try:
            c._check_shapes(node)
        except (ValueError, KeyError) as e:
            yield make_finding(ctx, R_SHAPE, node=name, message=str(e))
        seen.add(name)
    for o in c.outputs:
        if o not in c.nodes:
            yield make_finding(ctx, R_DANGLING, node=o,
                               message=f"output {o!r} is not a node")


@lint_pass("chain")
def check_reachability(ctx):
    """Dead nodes (unreachable from the outputs) and unused
    inputs/params (referenced by no node at all)."""
    c = ctx.source
    live = set()
    stack = list(_implicit_outputs(c))
    while stack:
        n = stack.pop()
        if n in live:
            continue
        live.add(n)
        node = c.nodes.get(n)
        if node is not None:
            stack.extend(r for r in Chain._refs(node) if r in c.nodes)
    for name in c.nodes:
        if name not in live:
            yield make_finding(ctx, R_DEAD, node=name,
                               message="unreachable from the chain outputs")
    refs = set()
    for node in c.nodes.values():
        refs.update(Chain._refs(node))
    for name in c.inputs:
        if name not in refs:
            yield make_finding(ctx, R_UNUSED_INPUT, node=name,
                               message="input referenced by no node")
    for name in c.params:
        if name not in refs:
            yield make_finding(ctx, R_UNUSED_PARAM, node=name,
                               message="param referenced by no node")


def _movement_is_noop(chain: Chain, node: Movement) -> bool:
    if node.gather or node.flip:
        return False
    try:
        shape = tuple(chain.shape_of(node.input))
    except KeyError:
        return False
    if node.pre_shape is not None and tuple(node.pre_shape) != shape:
        return False
    if node.perm is not None \
            and tuple(node.perm) != tuple(range(len(shape))):
        return False
    return not node.out_shape or tuple(node.out_shape) == shape


@lint_pass("chain")
def check_noop_movement(ctx):
    for name, node in ctx.source.nodes.items():
        if isinstance(node, Movement) and _movement_is_noop(ctx.source, node):
            yield make_finding(
                ctx, R_NOOP_MOVE, node=name,
                message="identity movement (same shape, identity perm); "
                        "drop it or fold it into a neighbor")


@lint_pass("chain")
def check_quant_barriers(ctx):
    """Nodes that WOULD be §4.3-fusible but for their ``out_dtype``: the
    quantization point is semantic (fusion's pre/post vocabulary carries
    no dtype change), so the intermediate materializes. These are exactly
    the sites a quantized-kernel path (int8/fp8 epilogues) would absorb."""
    for name, node in ctx.source.nodes.items():
        if not isinstance(node, GConv) or node.out_dtype is None:
            continue
        fusible_otherwise = (
            node.reduce == "none"
            and all(d.nks == 1 and d.nop == 1 for d in node.dims)
            and (node.main == "none" or node.main in _MAIN_AS_UNARY))
        if fusible_otherwise:
            yield make_finding(
                ctx, R_QUANT, node=name, out_dtype=str(node.out_dtype),
                message=f"quantization point (out_dtype="
                        f"{node.out_dtype}) blocks fusion of an "
                        f"otherwise-fusible node")


@lint_pass("chain")
def check_liveness(ctx):
    """Interval-based liveness over the program that actually runs (the
    fused chain when available): each tensor is live from its definition
    step to its last use (chain outputs to the end). Reports the peak as
    info and flags every Table-4 accelerator whose total global buffer
    (I+O+K words) the peak exceeds."""
    c = ctx.fused if ctx.fused is not None else ctx.source
    order = list(c.nodes)
    if not order:
        return
    pos = {n: i + 1 for i, n in enumerate(order)}   # inputs/params at 0
    end = len(order) + 1
    last_use = {}
    for name, node in c.nodes.items():
        for ref in Chain._refs(node):
            last_use[ref] = max(last_use.get(ref, 0), pos[name])
    for o in _implicit_outputs(c):
        last_use[o] = end

    def tensor_cost(ref):
        if ref in c.inputs:
            info = c.inputs[ref]
            shape, dtype = info.shape, info.dtype
        elif ref in c.params:
            info = c.params[ref]
            shape, dtype = info.shape, info.dtype
        else:
            node = c.nodes[ref]
            shape, dtype = tuple(node.out_shape), _node_dtype(node)
        elems = 1
        for s in shape:
            elems *= s
        return elems, elems * _dtype_bytes(dtype)

    # sweep: +size at start, -size after last use
    deltas_w = [0] * (end + 2)
    deltas_b = [0] * (end + 2)
    for ref in list(c.inputs) + list(c.params) + order:
        start = pos.get(ref, 0)
        stop = last_use.get(ref, start)
        words, nbytes = tensor_cost(ref)
        deltas_w[start] += words
        deltas_w[stop + 1] -= words
        deltas_b[start] += nbytes
        deltas_b[stop + 1] -= nbytes
    peak_w = peak_b = cur_w = cur_b = 0
    peak_step = 0
    for i in range(end + 1):
        cur_w += deltas_w[i]
        cur_b += deltas_b[i]
        if cur_w > peak_w:
            peak_w, peak_b, peak_step = cur_w, cur_b, i
    at = order[peak_step - 1] if 0 < peak_step <= len(order) else None
    yield make_finding(
        ctx, R_PEAK, node=at, peak_words=peak_w, peak_bytes=peak_b,
        peak_step=peak_step,
        message=f"peak live footprint {peak_w} words "
                f"({peak_b} bytes) at step {peak_step}/{end - 1}")

    from ..core.accelerators import TABLE4
    for name, spec in TABLE4.items():
        cap = sum(spec.gb.values())
        if peak_w > cap:
            yield make_finding(
                ctx, R_GB, node=at, accelerator=name, capacity_words=cap,
                peak_words=peak_w,
                message=f"peak {peak_w} words exceeds {name}'s global "
                        f"buffer ({cap} words) — needs tiling/paging "
                        f"beyond whole-tensor residency")
