"""`repro.lint` — static verifier over chain IR, execution plans, and
shard plans.

Three pass layers (see the README rule catalog):

  * **chain** — whole-chain re-validation beyond the add-time checks:
    dangling outputs, dead nodes, unused inputs/params, no-op Movements,
    out_dtype quantization points fusion refuses to absorb, and an
    interval-liveness peak checked against each Table-4 accelerator's
    global buffer.
  * **plan** — the compiled plan vs the fused chain: dispatch coverage,
    step consistency, §4.3 fusion-group legality, Pallas
    ``pick_block``/``mxu_min`` preconditions, and the oracle-fallback
    detector (a hot node on the O(macs) oracle is an ``error``).
  * **shard** — the ShardPlan without devices: TP split divisibility,
    row splits carry their explicit psum, replication pinned by sharding
    constraints (the PR 5 bug class as a compile-time ``error``), input
    spec divisibility/policy, params-replicate contract.

Entry points::

    lint_chain(chain)                       # build artifacts + run passes
    lint_chain(chain, mesh=fake_mesh("4x2"))
    compile_chain(chain, lint="error")      # gate at compile time
    python -m repro.lint                    # zoo + LM sweep CLI

The shard layer needs only ``mesh.shape``/``mesh.axis_names``
(`repro.shardpolicy` is duck-typed), so :func:`fake_mesh` fakes an
8-device mesh with no devices, subprocesses, or XLA flags.
"""
from __future__ import annotations

from typing import Mapping, Optional

from .findings import Finding, LintError, LintReport, severity_rank
from .registry import (LintContext, RULES, Rule, make_finding, run_passes)
from . import chain_passes, plan_passes, shard_passes  # noqa: F401  (register passes)
from .plan_passes import R_COMPILE


class FakeMesh:
    """Duck-typed stand-in for ``jax.sharding.Mesh``: carries only the
    axis geometry (``shape`` mapping + ``axis_names``), which is all the
    shard-plan derivation and the lint passes consult. Executing a
    program against it is impossible by design."""

    def __init__(self, shape: Mapping[str, int]):
        self.shape = dict(shape)

    @property
    def axis_names(self):
        return tuple(self.shape)

    @property
    def empty(self) -> bool:
        return not self.shape

    @property
    def size(self) -> int:
        n = 1
        for s in self.shape.values():
            n *= s
        return n

    def __repr__(self):
        return f"FakeMesh({self.shape})"


def fake_mesh(spec: str = "4x2") -> FakeMesh:
    """A deviceless mesh from the ``--mesh`` grammar (``"8"`` or
    ``"4x2"`` = (data, model))."""
    from ..shardpolicy import parse_mesh_spec
    d, m = parse_mesh_spec(spec)
    shape = {"data": d}
    if m > 1:
        shape["model"] = m
    return FakeMesh(shape)


def build_context(chain, *, backend: str = "auto", mxu_min: int = 128,
                  mesh=None, fuse: bool = True, segments: bool = True,
                  config: str = "") -> LintContext:
    """Compile the chain's static artifacts (fused chain, plan, shard
    plan) exactly as ``compile_chain`` would, without building an
    engine — ``mesh`` may be a :class:`FakeMesh`."""
    from ..exec.dispatch import plan_chain
    from ..exec.partition import partition_chain
    fused, report, parts = partition_chain(chain, fuse=fuse)
    plan = plan_chain(fused, backend=backend, mxu_min=mxu_min,
                      segments=segments)
    for host, members in report.groups.items():
        for m in members:
            plan.dispatch.setdefault(m, f"fused:{host}")
    shard_plan = sharded_steps = None
    if mesh is not None and not mesh.empty:
        from ..exec.shardplan import derive_plan, wrap_steps
        shard_plan = derive_plan(fused, plan.dispatch, mesh)
        sharded_steps = wrap_steps(fused, plan.steps, shard_plan)
    return LintContext(source=chain, fused=fused, fusion=report,
                       partitions=parts, plan=plan, backend=backend,
                       mxu_min=mxu_min, shard_plan=shard_plan,
                       sharded_steps=sharded_steps, config=config)


def lint_chain(chain, *, backend: str = "auto", mxu_min: int = 128,
               mesh=None, fuse: bool = True, segments: bool = True,
               config: str = "") -> LintReport:
    """Lint a chain end to end: compile the static artifacts and run all
    applicable passes. A chain too broken to compile gets the chain-layer
    report (plus ``plan.compile-failed`` if no chain finding explains the
    failure)."""
    if not config:
        parts = [f"backend={backend}"]
        if mesh is not None:
            parts.append("mesh=" + "x".join(str(s)
                                            for s in mesh.shape.values()))
        config = " ".join(parts)
    try:
        ctx = build_context(chain, backend=backend, mxu_min=mxu_min,
                            mesh=mesh, fuse=fuse, segments=segments,
                            config=config)
    except Exception as e:
        ctx = LintContext(source=chain, config=config)
        rep = run_passes(ctx, layers=("chain",))
        if not rep.errors():
            rep.add(make_finding(ctx, R_COMPILE, error=repr(e),
                                 message=f"chain failed to compile: {e}"))
        return rep
    return run_passes(ctx)


def lint_compiled(engine) -> LintReport:
    """Lint a :class:`~repro.exec.engine.CompiledChain` in place — the
    artifacts it already built are audited, nothing is recompiled."""
    opts = engine.options
    shard_plan = engine.shard_plan
    config = f"backend={opts.backend}"
    if shard_plan is not None:
        config += " mesh=" + "x".join(str(s)
                                      for s in shard_plan.mesh.shape.values())
    ctx = LintContext(
        source=engine.source, fused=engine.chain,
        fusion=engine.fusion_report, partitions=engine.partitions,
        plan=engine._plan, backend=opts.backend, mxu_min=opts.mxu_min,
        shard_plan=shard_plan,
        sharded_steps=(engine._steps_sharded
                       if shard_plan is not None else None),
        config=config)
    return run_passes(ctx)


__all__ = ["Finding", "LintReport", "LintError", "LintContext", "Rule",
           "RULES", "FakeMesh", "fake_mesh", "build_context", "lint_chain",
           "lint_compiled", "run_passes", "severity_rank"]
