"""Seeded mutation corpus: deliberately-broken chains/plans/shard-plans
proving every lint rule actually fires.

Each mutant is (name, intended rule, base builder, mutate fn). The
corpus check is two-sided:

  * **no false negatives** — linting the mutated artifact must produce
    the intended rule;
  * **no false positives** — linting the clean base must NOT produce it.

``plan``/``shard`` mutants tamper the compiled artifacts (dispatch
table, step list, ShardPlan, step meta) the way a buggy
partition/dispatch/lowering change would — including a reconstruction
of the PR 5 missing-psum / unconstrained-replication bug, which the
runtime 8-fake-device sweep only caught after the fact.
"""
from __future__ import annotations

import copy
from typing import Callable, List, Tuple

from jax.sharding import PartitionSpec as P

from ..core import layers as L
from ..core.chain import Chain, Movement
from . import build_context, fake_mesh, lint_chain
from .registry import run_passes

MESH_SPEC = "4x2"


# ---------------------------------------------------------------------------
# clean bases
# ---------------------------------------------------------------------------
def base_small(name: str = "lint_small") -> Chain:
    """fc/relu/fc at C=64: small enough to stay under every Table-4
    global buffer (so chain.gb-capacity is clean on the base)."""
    c = Chain(name)
    x = c.add_input("x", (8, 64))
    h = L.fc(c, x, out_f=64, name="fc1")
    h = L.relu(c, h, name="act1")
    h = L.fc(c, h, out_f=64, name="fc2")
    c.mark_output(h)
    return c


def base_hot(name: str = "lint_hot") -> Chain:
    """fc/relu/fc at C=512: each matmul carries ~2M macs (>= HOT_MACS),
    so forcing one onto the oracle is plan.oracle-hot."""
    c = Chain(name)
    x = c.add_input("x", (8, 512))
    h = L.fc(c, x, out_f=512, name="fc1")
    h = L.relu(c, h, name="act1")
    h = L.fc(c, h, out_f=512, name="fc2")
    c.mark_output(h)
    return c


def base_tiny16(name: str = "lint_tiny16") -> Chain:
    """K=N=16 fc: far below mxu_min, auto dispatch keeps it on jnp."""
    c = Chain(name)
    x = c.add_input("x", (4, 16))
    h = L.fc(c, x, out_f=16, name="fc1")
    c.mark_output(h)
    return c


def base_col(name: str = "lint_col") -> Chain:
    """K=511 (odd), N=512: on a DxM=4x2 mesh the plan column-splits."""
    c = Chain(name)
    x = c.add_input("x", (8, 511))
    h = L.fc(c, x, out_f=512, name="fc1")
    c.mark_output(h)
    return c


def base_row(name: str = "lint_row") -> Chain:
    """K=512, N=511 (odd): N doesn't divide the model axis, K does —
    the plan row-splits with an explicit psum."""
    c = Chain(name)
    x = c.add_input("x", (8, 512))
    h = L.fc(c, x, out_f=511, name="fc1")
    c.mark_output(h)
    return c


def base_odd_batch(name: str = "lint_oddb") -> Chain:
    """Batch 6 on a data axis of 4: the leading-batch policy replicates
    (6 % 4 != 0); pinning it anyway is shard.input-spec-divisibility."""
    c = Chain(name)
    x = c.add_input("x", (6, 512))
    h = L.fc(c, x, out_f=512, name="fc1")
    c.mark_output(h)
    return c


# ---------------------------------------------------------------------------
# chain-layer mutants (mutate the Chain, lint via lint_chain)
# ---------------------------------------------------------------------------
def mut_dangling_output(c: Chain):
    c.outputs.append("ghost")


def mut_use_before_def(c: Chain):
    c.nodes = dict(reversed(list(c.nodes.items())))


def mut_shape_mismatch(c: Chain):
    info = c.params["fc2.w"]
    c.params["fc2.w"] = type(info)((1, info.shape[1] - 3), info.dtype)


def mut_dead_node(c: Chain):
    L.fc(c, "act1", out_f=8, name="fc_dead")   # never marked as output


def mut_unused_input(c: Chain):
    c.add_input("x_unused", (4, 4))


def mut_unused_param(c: Chain):
    c.add_param("w_unused", (4, 4))


def mut_noop_movement(c: Chain):
    out = c.outputs[-1]
    shape = c.shape_of(out)
    c.add(Movement("mv_id", input=out, perm=tuple(range(len(shape))),
                   out_shape=tuple(shape)))
    c.outputs = ["mv_id"]


def mut_quant_barrier(c: Chain):
    c.nodes["act1"].out_dtype = "float16"


def mut_gb_overflow(c: Chain):
    # an activation bigger than every Table-4 global buffer (words)
    x2 = c.add_input("x_big", (64, 65536))
    h = L.relu(c, x2, name="act_big")
    c.mark_output(h)


# ---------------------------------------------------------------------------
# plan-layer mutants (tamper the built LintContext's plan artifacts)
# ---------------------------------------------------------------------------
def mut_missing_dispatch(ctx):
    del ctx.plan.dispatch["fc2"]


def mut_oracle_hot(ctx):
    ctx.plan.dispatch["fc2"] = "oracle"
    for st in ctx.plan.steps:
        if st.name == "fc2":
            st.backend = "oracle"


def mut_pallas_mxu(ctx):
    ctx.plan.dispatch["fc1"] = "matmul:pallas"
    for st in ctx.plan.steps:
        if st.name == "fc1":
            st.backend = "matmul:pallas"


def mut_fusion_illegal(ctx):
    # claim a still-materialized reducing matmul as a fused member
    ctx.fusion.groups.setdefault("fc1", []).append("fc2")


def mut_step_disorder(ctx):
    ctx.plan.steps.reverse()


def mut_unknown_step(ctx):
    ctx.plan.steps[0].name = "ghost"


def mut_tuned_corrupt_block(ctx):
    # a corrupted tuning-DB entry that slipped past quarantine and was
    # applied: the tuned M-block wildly overshoots the node's M=8 axis
    # (pick_block never-overshoot contract). Also demonstrates the R_MXU
    # tuned-step exemption: only plan.tuned-contract / the block audit
    # fire, not the heuristic mxu_min gate.
    ctx.plan.dispatch["fc1"] = "matmul:pallas"
    for st in ctx.plan.steps:
        if st.name == "fc1":
            st.backend = "matmul:pallas"
            st.meta = dict(st.meta or {})
            st.meta["tuned"] = dict(backend="matmul:pallas",
                                    block=dict(m=8192, n=256, k=512),
                                    source="db", group="fc1")


# ---------------------------------------------------------------------------
# shard-layer mutants (tamper ShardPlan / re-lowered step meta)
# ---------------------------------------------------------------------------
def mut_tp_indivisible(ctx):
    # flip the column split to row: K=511 does not divide model=2
    ctx.shard_plan.step_tp["fc1"] = "row"


def mut_missing_psum(ctx):
    # PR 5 reconstruction, part 1: lowering "forgets" the psum a
    # row-split's partial products need
    for st in ctx.sharded_steps:
        if st.meta:
            st.meta["psum"] = False


def mut_unconstrained(ctx):
    # PR 5 reconstruction, part 2: lowering skips the
    # with_sharding_constraint pinning operand replication under DP
    for st in ctx.sharded_steps:
        if st.meta:
            st.meta["constrained"] = False


def mut_bad_input_spec(ctx):
    # pin the (indivisible) leading batch dim anyway
    ctx.shard_plan.in_specs["x"] = P("data", None)


# (name, intended rule, base builder, mutate, layer)
MUTANTS: List[Tuple[str, str, Callable, Callable, str]] = [
    ("dangling_output", "chain.dangling-output", base_small,
     mut_dangling_output, "chain"),
    ("use_before_def", "chain.use-before-def", base_small,
     mut_use_before_def, "chain"),
    ("shape_mismatch", "chain.shape-mismatch", base_small,
     mut_shape_mismatch, "chain"),
    ("dead_node", "chain.dead-node", base_small, mut_dead_node, "chain"),
    ("unused_input", "chain.unused-input", base_small,
     mut_unused_input, "chain"),
    ("unused_param", "chain.unused-param", base_small,
     mut_unused_param, "chain"),
    ("noop_movement", "chain.noop-movement", base_small,
     mut_noop_movement, "chain"),
    ("quant_barrier", "chain.quant-fusion-barrier", base_small,
     mut_quant_barrier, "chain"),
    ("gb_overflow", "chain.gb-capacity", base_small,
     mut_gb_overflow, "chain"),
    ("missing_dispatch", "plan.missing-dispatch", base_hot,
     mut_missing_dispatch, "plan"),
    ("oracle_hot", "plan.oracle-hot", base_hot, mut_oracle_hot, "plan"),
    ("pallas_mxu", "plan.pallas-mxu-min", base_tiny16,
     mut_pallas_mxu, "plan"),
    ("fusion_illegal", "plan.fusion-illegal", base_hot,
     mut_fusion_illegal, "plan"),
    ("step_disorder", "plan.step-order", base_hot,
     mut_step_disorder, "plan"),
    ("unknown_step", "plan.unknown-step", base_hot,
     mut_unknown_step, "plan"),
    ("tuned_corrupt_block", "plan.tuned-contract", base_hot,
     mut_tuned_corrupt_block, "plan"),
    ("tp_indivisible", "shard.tp-divisibility", base_col,
     mut_tp_indivisible, "shard"),
    ("missing_psum", "shard.missing-psum", base_row,
     mut_missing_psum, "shard"),
    ("unconstrained_replication", "shard.unconstrained-replication",
     base_row, mut_unconstrained, "shard"),
    ("bad_input_spec", "shard.input-spec-divisibility", base_odd_batch,
     mut_bad_input_spec, "shard"),
]


def _lint_mutant(layer: str, base: Chain, mutate) :
    """Lint (clean_report, mutated_report) at the mutant's layer."""
    if layer == "chain":
        clean = lint_chain(base)
        broken = copy.deepcopy(base)
        mutate(broken)
        return clean, lint_chain(broken)
    mesh = fake_mesh(MESH_SPEC) if layer == "shard" else None
    ctx = build_context(base, mesh=mesh)
    clean = run_passes(ctx)
    ctx = build_context(base, mesh=mesh)
    mutate(ctx)
    return clean, run_passes(ctx)


def run_corpus() -> List[dict]:
    """Lint every mutant and its clean base; one row per mutant with the
    two-sided verdict."""
    rows = []
    for name, rule_id, builder, mutate, layer in MUTANTS:
        clean, broken = _lint_mutant(layer, builder(), mutate)
        caught = any(f.rule == rule_id for f in broken)
        clean_hit = any(f.rule == rule_id for f in clean)
        rows.append(dict(
            mutant=name, rule=rule_id, layer=layer, caught=caught,
            false_positive=clean_hit, clean_errors=len(clean.errors()),
            fired=sorted(broken.by_rule())))
    return rows


def corpus_ok(rows=None) -> bool:
    rows = run_corpus() if rows is None else rows
    return all(r["caught"] and not r["false_positive"]
               and r["clean_errors"] == 0 for r in rows)
