"""Rule catalog + pass registry + analysis context for `repro.lint`.

A *rule* is a stable dotted ID with a fixed layer and severity (the
catalog below is rendered by ``python -m repro.lint --rules`` and the
README "Static analysis" section). A *pass* is a function
``pass(ctx) -> iterable[Finding]`` registered for one layer; passes for
a layer only run when the context carries that layer's artifacts
(``plan`` for plan passes, ``shard_plan`` for shard passes), so the same
registry serves chain-only lints and fully-compiled engines.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from .findings import Finding, LintReport, severity_rank

LAYERS = ("chain", "plan", "shard")


@dataclass(frozen=True)
class Rule:
    id: str
    layer: str
    severity: str
    summary: str


RULES: Dict[str, Rule] = {}


def rule(rule_id: str, layer: str, severity: str, summary: str) -> str:
    """Register a rule in the catalog (module-import time)."""
    if layer not in LAYERS:
        raise ValueError(f"unknown layer {layer!r}")
    severity_rank(severity)              # validates
    if rule_id in RULES:
        raise ValueError(f"duplicate rule {rule_id!r}")
    RULES[rule_id] = Rule(rule_id, layer, severity, summary)
    return rule_id


_PASSES: List[Tuple[str, Callable]] = []


def lint_pass(layer: str):
    """Decorator registering a pass for one layer."""
    if layer not in LAYERS:
        raise ValueError(f"unknown layer {layer!r}")

    def wrap(fn):
        _PASSES.append((layer, fn))
        return fn

    return wrap


def passes(layers=None):
    return [(layer, fn) for layer, fn in _PASSES
            if layers is None or layer in layers]


# defaults for the oracle-fallback hot-path thresholds: a node is "hot"
# when it carries >= HOT_MACS macs AND >= HOT_FRAC of the chain's total —
# tiny deliberately-oracle test chains stay info-level
HOT_MACS = 1 << 20
HOT_FRAC = 0.01


@dataclass
class LintContext:
    """Everything the passes may inspect. Only ``source`` is mandatory;
    plan/shard passes skip themselves when their artifacts are absent."""

    source: object                       # the original Chain
    fused: object = None                 # the fused chain actually run
    fusion: object = None                # core.fusion.FusionReport
    partitions: list = None              # exec.partition ExecGroups
    plan: object = None                  # exec.dispatch.Plan
    backend: str = "auto"
    mxu_min: int = 128
    shard_plan: object = None            # exec.shardplan.ShardPlan
    sharded_steps: list = None           # wrap_steps output (Step w/ meta)
    hot_macs: int = HOT_MACS
    hot_frac: float = HOT_FRAC
    config: str = ""                     # report label, e.g. "backend=auto"
    data: dict = field(default_factory=dict)   # pass-to-pass scratch


def make_finding(ctx: LintContext, rule_id: str, message: str,
                 node: Optional[str] = None, group: Optional[str] = None,
                 **data) -> Finding:
    info = RULES[rule_id]
    return Finding(rule=rule_id, severity=info.severity, layer=info.layer,
                   chain=ctx.source.name, message=message, node=node,
                   group=group, data=data)


def run_passes(ctx: LintContext, layers=None) -> LintReport:
    rep = LintReport(chain=ctx.source.name, config=ctx.config)
    for layer, fn in passes(layers):
        if layer == "plan" and ctx.plan is None:
            continue
        if layer == "shard" and ctx.shard_plan is None:
            continue
        rep.extend(fn(ctx))
    return rep
